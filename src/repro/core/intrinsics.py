"""Layer 1 -- portable kernel intrinsics (the KernelIntrinsics.jl analogue).

KernelIntrinsics.jl isolates the three capabilities vendor-competitive
primitives need -- warp shuffles over arbitrary types, ordered memory access,
and vectorized loads -- behind backend-dispatched abstractions.  On TPU the
same *purposes* are served by different mechanisms (see DESIGN.md §2); this
module provides them:

* **In-tile combines** (:func:`tile_scan`, :func:`tile_reduce`): the shuffle
  analogue.  A Pallas block holds an ``(sublane, 128)``-aligned tile in vector
  registers; log-step shifted combines emitted here lower to in-register VPU
  ops.  Arbitrary element types are pytrees -- JAX tracing specializes the
  structural recursion at compile time like Julia's ``@generated``.
* **Alignment / vectorization helpers** (:func:`min_tile`,
  :func:`block_shape`, :func:`pattern_decompose`): the ``vload`` /
  ``vload_pattern`` analogue.  Block shapes are chosen so every HBM->VMEM
  transfer is wide and aligned; ragged tails become *statically generated*
  masked patterns, never dynamic shapes.
* **Grid-carry protocol** (documented here, implemented in kernels/scan.py):
  the ordered-memory-access analogue.  TPU Pallas grid steps execute
  sequentially per core, so a scratch carry gives the decoupled-lookback
  guarantee (prior tiles' aggregates visible) by construction -- no
  release/acquire flags, no spinning.
* **Tuning-policy dispatch** (:class:`TuningPolicy`): the paper's
  ``A40 <: Ampere <: AbstractArch`` hierarchy, as a chip-family registry
  resolved at trace time.
* **Backend dispatch** (:func:`register_impl` / :func:`resolve_impl`): the
  package-extension mechanism.  Algorithms in ``core/primitives.py`` never
  name a backend; implementations register themselves per backend and the
  dispatcher picks ``pallas-tpu`` on TPU, ``xla`` elsewhere (and
  ``pallas-interpret`` under the validation flag).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

LANES = 128  # TPU vector lane count (minor-most tile dimension)

_SUBLANE_BY_ITEMSIZE = {8: 4, 4: 8, 2: 16, 1: 32}


def min_tile(dtype) -> tuple[int, int]:
    """Minimum (sublane, lane) tile for ``dtype`` on current-gen TPUs."""
    itemsize = jnp.dtype(dtype).itemsize
    return (_SUBLANE_BY_ITEMSIZE.get(itemsize, 8), LANES)


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


# --------------------------------------------------------------------------
# vload_pattern analogue: static decomposition of a ragged extent.
# --------------------------------------------------------------------------


def pattern_decompose(n: int, block: int) -> tuple[int, int]:
    """Split extent ``n`` into (full_blocks, tail).

    The paper's ``vload_pattern`` emits an optimal aligned load sequence for a
    statically known misalignment; our blocks are always aligned (JAX arrays
    start aligned and block starts are multiples of the block shape), so the
    pattern reduces to (main body, masked tail).  The tail mask is generated
    at trace time from static shape arithmetic in the kernels.
    """
    return n // block, n % block


def tile_mask(tile_shape: Sequence[int], axis: int, start: Any, valid_until: Any):
    """Boolean mask marking in-bounds elements along ``axis`` of a tile.

    ``start`` is the global offset of the tile along ``axis`` (may be traced),
    ``valid_until`` the global extent.  Used for masked tail tiles.
    """
    idx = jax.lax.broadcasted_iota(jnp.int32, tuple(tile_shape), axis)
    return (idx + start) < valid_until


# --------------------------------------------------------------------------
# Shuffle analogue: in-tile ordered scans and reductions over pytrees.
# --------------------------------------------------------------------------


def _shift_along(x, s: int, axis: int):
    """Shift ``x`` by ``s`` along ``axis`` (towards higher indices)."""
    return jnp.roll(x, s, axis=axis)


def tile_scan(op, x: Pytree, axis: int) -> Pytree:
    """In-order inclusive scan of a tile along ``axis`` (Hillis–Steele).

    log2(extent) shifted combines; order-preserving, so correct for
    non-commutative ``op`` (quaternions, affine maps, 2x2 matrices).
    No identity needed: out[i] = i >= s ? op(x[i-s], x[i]) : x[i].
    """
    leaves = jax.tree.leaves(x)
    extent = leaves[0].shape[axis]
    shape = leaves[0].shape
    idx = jax.lax.broadcasted_iota(jnp.int32, shape, axis)
    s = 1
    while s < extent:
        shifted = jax.tree.map(lambda l: _shift_along(l, s, axis), x)
        combined = op(shifted, x)
        keep = idx >= s
        x = jax.tree.map(lambda c, o: jnp.where(keep, c, o), combined, x)
        s *= 2
    return x


def tile_take_last(x: Pytree, axis: int) -> Pytree:
    """Slice the last element along ``axis`` (keepdims)."""
    def take(l):
        sl = [slice(None)] * l.ndim
        sl[axis] = slice(l.shape[axis] - 1, l.shape[axis])
        return l[tuple(sl)]

    return jax.tree.map(take, x)


def _split_along(x: Pytree, axis: int, k: int) -> tuple[Pytree, Pytree]:
    """Split pytree ``x`` into ([0:k], [k:2k]) slices along ``axis``."""
    treedef = jax.tree.structure(x)
    pairs = []
    for l in jax.tree.leaves(x):
        sl_lo = [slice(None)] * l.ndim
        sl_hi = [slice(None)] * l.ndim
        sl_lo[axis] = slice(0, k)
        sl_hi[axis] = slice(k, 2 * k)
        pairs.append((l[tuple(sl_lo)], l[tuple(sl_hi)]))
    lo = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    hi = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return lo, hi


def tile_reduce(op, x: Pytree, axis: int) -> Pytree:
    """Reduce a tile along ``axis``, keepdims.

    Commutative ops with power-of-two extents use a balanced halving fold
    (fewest combines); otherwise an order-preserving scan + take-last.  The
    commutativity dispatch is itself a tuning decision exposed by the
    operator algebra (DESIGN.md §3).
    """
    extent = jax.tree.leaves(x)[0].shape[axis]
    pow2 = extent > 0 and (extent & (extent - 1)) == 0
    if not getattr(op, "commutative", False) or not pow2:
        return tile_take_last(tile_scan(op, x, axis), axis)
    k = extent
    while k > 1:
        k //= 2
        lo, hi = _split_along(x, axis, k)
        x = op(lo, hi)
    return x


# --------------------------------------------------------------------------
# Tuning-policy dispatch hierarchy (A40 <: Ampere <: AbstractArch analogue).
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TuningPolicy:
    """Per-chip static kernel parameters, resolved at trace time."""

    name: str = "generic"
    # Items-per-grid-step multiplier (the paper's Nitem): how many minimum
    # tiles one grid step processes.  Scan uses a larger Nitem to amortize
    # carry propagation, exactly like the paper's 16-items-per-thread scan.
    nitem_copy: int = 8
    nitem_scan: int = 16
    nitem_reduce: int = 8
    # matvec / vecmat block parameters (rows, cols are in units of min tile).
    matvec_rows: int = 16
    matvec_cols: int = 2
    vecmat_rows: int = 8
    vecmat_cols: int = 8
    # Wide/tall shape cutover (aspect ratio heuristic, paper §V-C).
    tall_threshold: float = 64.0
    vmem_budget_bytes: int = 64 * 1024 * 1024
    # Radix-sort digit width in bits (2^bits buckets per pass).  Wider digits
    # mean fewer passes but a larger per-pass rank scan; the sweet spot is
    # shape- and chip-dependent, so it sits on the tuning ladder.
    sort_digit_bits: int = 8


_TUNING_REGISTRY: dict[str, TuningPolicy] = {}
_TUNING_PARENTS: dict[str, str] = {}


def register_tuning(name: str, policy: TuningPolicy, parent: str = "generic"):
    _TUNING_REGISTRY[name] = policy
    _TUNING_PARENTS[name] = parent


register_tuning("generic", TuningPolicy())
# TPU v5e: 16 GiB HBM @ 819 GB/s, 197 bf16 TFLOP/s, ~128 MiB VMEM/core.
register_tuning(
    "tpu_v5e",
    TuningPolicy(name="tpu_v5e", nitem_scan=16, nitem_reduce=8, nitem_copy=8,
                 vmem_budget_bytes=96 * 1024 * 1024),
)
# v5p: larger HBM/bandwidth; deeper pipelining pays off.
register_tuning(
    "tpu_v5p",
    TuningPolicy(name="tpu_v5p", nitem_scan=32, nitem_reduce=16, nitem_copy=16,
                 vmem_budget_bytes=96 * 1024 * 1024),
    parent="tpu_v5e",
)
# Interpret mode: tiny tiles keep the Python loop fast while exercising the
# same code paths (masking, carries, patterns).
register_tuning(
    "interpret",
    TuningPolicy(name="interpret", nitem_copy=2, nitem_scan=2, nitem_reduce=2,
                 matvec_rows=2, matvec_cols=1, vecmat_rows=2, vecmat_cols=1,
                 sort_digit_bits=4),
)


def resolve_tuning(name: str | None = None) -> TuningPolicy:
    if name is None:
        name = detect_chip()
    while name not in _TUNING_REGISTRY:
        name = _TUNING_PARENTS.get(name, "generic")
    return _TUNING_REGISTRY[name]


def detect_chip() -> str:
    dev = jax.devices()[0]
    if dev.platform == "tpu":
        kind = getattr(dev, "device_kind", "").lower()
        if "v5 lite" in kind or "v5e" in kind:
            return "tpu_v5e"
        if "v5p" in kind or "v5" in kind:
            return "tpu_v5p"
        return "tpu_v5e"
    return "generic"


# --------------------------------------------------------------------------
# Backend dispatch registry (package-extension analogue).
# --------------------------------------------------------------------------

_IMPL_REGISTRY: dict[tuple[str, str], Callable] = {}
_FORCED_BACKEND: str | None = None
# Optional autotuner hook (installed by core.tuning to avoid a layering
# cycle): called as hook(primitive, backend, impl) and may return a wrapped
# impl that injects a benchmarked TuningPolicy, or None to pass through.
_TUNER_HOOK: Callable[[str, str, Callable], Callable | None] | None = None


def set_tuner_hook(hook: Callable | None):
    """Install (or clear) the autotune wrapper consulted by resolve_impl."""
    global _TUNER_HOOK
    _TUNER_HOOK = hook


def register_impl(primitive: str, backend: str):
    def deco(fn):
        _IMPL_REGISTRY[(primitive, backend)] = fn
        return fn

    return deco


def force_backend(backend: str | None):
    """Force a backend globally (used by tests to pin pallas-interpret)."""
    global _FORCED_BACKEND
    _FORCED_BACKEND = backend


def current_backend() -> str:
    if _FORCED_BACKEND is not None:
        return _FORCED_BACKEND
    return "pallas-tpu" if jax.default_backend() == "tpu" else "xla"


def resolve_impl(primitive: str, backend: str | None = None) -> Callable:
    backend = backend or current_backend()
    key = (primitive, backend)
    impl = _IMPL_REGISTRY.get(key)
    if impl is None:
        # Fall back to the portable XLA implementation -- the algorithmic
        # layer is always available even on backends with no Pallas lowering.
        impl = _IMPL_REGISTRY.get((primitive, "xla"))
    if impl is None:
        raise NotImplementedError(f"no implementation registered for {primitive}")
    if _TUNER_HOOK is not None:
        wrapped = _TUNER_HOOK(primitive, backend, impl)
        if wrapped is not None:
            return wrapped
    return impl
