"""Layer 1 -- portable kernel intrinsics (the KernelIntrinsics.jl analogue).

KernelIntrinsics.jl isolates the three capabilities vendor-competitive
primitives need -- warp shuffles over arbitrary types, ordered memory access,
and vectorized loads -- behind backend-dispatched abstractions.  On TPU the
same *purposes* are served by different mechanisms (see DESIGN.md §2); this
module provides them:

* **In-tile combines** (:func:`tile_scan`, :func:`tile_reduce`): the shuffle
  analogue.  Log-step shifted combines over pytrees of tile values -- JAX
  tracing specializes the structural recursion at compile time like Julia's
  ``@generated``.  The *shift primitive* is flavor-dispatched
  (:class:`IntrinsicsFlavor`): the TPU flavor emits roll+select combines
  that lower to in-register VPU ops over ``(sublane, 128)`` tiles; the GPU
  flavor emits identity-padded shifts -- the ``shfl_up`` formulation, where
  lanes below the shift distance receive the operator identity so no
  post-combine select is needed.
* **Ordered visibility** (:func:`memory_fence`): the release/acquire
  analogue behind the decoupled-lookback scan.  The TPU flavor is the
  identity (grid steps execute sequentially per core, so prior tiles'
  aggregates are visible by construction); the GPU flavor pins ordering
  with an optimization barrier so the publish of a block's aggregate
  cannot be reordered past the status flag derived from it (a hardware
  Mosaic-GPU lowering strengthens this to a device-scope fence).
* **Alignment / vectorization helpers** (:func:`min_tile`,
  :func:`vec_width`, :func:`pattern_decompose`): the ``vload`` /
  ``vload_pattern`` analogue.  Block shapes are chosen so every transfer
  is wide and aligned -- ``vec_width`` is the float4-style vectorized
  load/store width hint the GPU block arithmetic uses; ragged tails become
  *statically generated* masked patterns, never dynamic shapes.
* **Tuning-policy dispatch** (:class:`TuningPolicy`): the paper's
  ``A40 <: Ampere <: AbstractArch`` hierarchy, as a chip-family registry
  resolved at trace time.
* **Backend dispatch** (:func:`register_impl` / :func:`resolve_impl`) and
  the **backend selection API** (:func:`use_backend`,
  :func:`available_backends`, :func:`supports`): the package-extension
  mechanism.  Algorithms in ``core/primitives.py`` never name a backend;
  implementations register themselves per backend and the dispatcher picks
  ``pallas-tpu`` on TPU, ``pallas-gpu`` on GPU, ``xla`` elsewhere --
  overridable per call (``backend=``) or per scope
  (``with use_backend("pallas-gpu"): ...``, thread-safe).
* **The primitive registry** (:class:`PrimitiveDef` / :class:`RouteDef` /
  :func:`dispatch`): the declarative table behind the layout-polymorphic
  Layer-2 API.  One row per (primitive, layout) names the registered
  implementation key (``"scan@batched"``), the validation rules (segment
  descriptor exclusivity, leaf-rank checks, commutativity requirements),
  the zero-extent behavior, and the tuning-key recipe -- so the guards,
  reroutes and cache keys are single data-driven implementations instead
  of per-family copies.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
import warnings
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout as lay

Pytree = Any

LANES = 128  # TPU vector lane count (minor-most tile dimension)
WARP = 32    # GPU subgroup width (warp/wavefront32; the shuffle scope)

_SUBLANE_BY_ITEMSIZE = {8: 4, 4: 8, 2: 16, 1: 32}


def min_tile(dtype) -> tuple[int, int]:
    """Minimum (sublane, lane) tile for ``dtype`` on current-gen TPUs."""
    itemsize = jnp.dtype(dtype).itemsize
    return (_SUBLANE_BY_ITEMSIZE.get(itemsize, 8), LANES)


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


# --------------------------------------------------------------------------
# vload_pattern analogue: static decomposition of a ragged extent.
# --------------------------------------------------------------------------


def pattern_decompose(n: int, block: int) -> tuple[int, int]:
    """Split extent ``n`` into (full_blocks, tail).

    The paper's ``vload_pattern`` emits an optimal aligned load sequence for a
    statically known misalignment; our blocks are always aligned (JAX arrays
    start aligned and block starts are multiples of the block shape), so the
    pattern reduces to (main body, masked tail).  The tail mask is generated
    at trace time from static shape arithmetic in the kernels.
    """
    return n // block, n % block


def tile_mask(tile_shape: Sequence[int], axis: int, start: Any, valid_until: Any):
    """Boolean mask marking in-bounds elements along ``axis`` of a tile.

    ``start`` is the global offset of the tile along ``axis`` (may be traced),
    ``valid_until`` the global extent.  Used for masked tail tiles.
    """
    idx = jax.lax.broadcasted_iota(jnp.int32, tuple(tile_shape), axis)
    return (idx + start) < valid_until


# --------------------------------------------------------------------------
# Shuffle analogue: in-tile ordered scans and reductions over pytrees.
#
# The log-step structure is shared; the *shift primitive* underneath it is
# flavor-dispatched (IntrinsicsFlavor below), because TPUs and GPUs reach
# "combine with the value s slots back" through different hardware:
#
# * "tpu": roll the tile and select -- lowers to in-register VPU permutes
#   over (sublane, 128) tiles; no operator identity is needed.
# * "gpu": identity-padded shift -- the warp/subgroup ``shfl_up``
#   formulation, where slots below the shift distance receive the operator
#   identity so the combine is unconditional (no post-select), exactly the
#   shuffle-scan inner loop of the paper's KernelIntrinsics layer.
#
# Both produce bit-identical scans for any associative op (identity is
# two-sided), so every flavor validates against the same oracle.
# --------------------------------------------------------------------------


def _shift_along(x, s: int, axis: int):
    """Shift ``x`` by ``s`` along ``axis`` (towards higher indices)."""
    return jnp.roll(x, s, axis=axis)


def _tpu_shift_combine(op, x: Pytree, s: int, axis: int, idx) -> Pytree:
    """Roll + select: out[i] = i >= s ? op(x[i-s], x[i]) : x[i]."""
    shifted = jax.tree.map(lambda l: _shift_along(l, s, axis), x)
    combined = op(shifted, x)
    keep = idx >= s
    return jax.tree.map(lambda c, o: jnp.where(keep, c, o), combined, x)


def _slice_head(l, s: int, axis: int):
    sl = [slice(None)] * l.ndim
    sl[axis] = slice(0, l.shape[axis] - s)
    return l[tuple(sl)]


def _gpu_shift_combine(op, x: Pytree, s: int, axis: int, idx) -> Pytree:
    """shfl_up analogue: slots < s receive the operator identity, so the
    combine needs no keep-mask select afterwards."""
    def pad_shape(l):
        shape = list(l.shape)
        shape[axis] = s
        return jax.ShapeDtypeStruct(tuple(shape), l.dtype)

    ident = op.identity(jax.tree.map(pad_shape, x))
    shifted = jax.tree.map(
        lambda il, l: jnp.concatenate([il, _slice_head(l, s, axis)],
                                      axis=axis), ident, x)
    return op(shifted, x)


def _fence_noop(values: Pytree) -> Pytree:
    return values


def _fence_barrier(values: Pytree) -> Pytree:
    return jax.lax.optimization_barrier(values)


@dataclasses.dataclass(frozen=True)
class IntrinsicsFlavor:
    """One Layer-1 lowering strategy (per backend family).

    ``shift_combine`` is the primitive under :func:`tile_scan` /
    :func:`tile_reduce`; ``fence`` implements :func:`memory_fence`;
    ``vec_bytes`` is the default vectorized load/store transaction width
    :func:`vec_width` derives element counts from.
    """

    name: str
    shift_combine: Callable
    fence: Callable
    vec_bytes: int


_FLAVORS: dict[str, IntrinsicsFlavor] = {}
_BACKEND_FLAVOR: dict[str, str] = {}


def register_flavor(flavor: IntrinsicsFlavor, backends: Sequence[str] = ()):
    """Register a Layer-1 flavor and map backend names onto it."""
    _FLAVORS[flavor.name] = flavor
    for b in backends:
        _BACKEND_FLAVOR[b] = flavor.name


register_flavor(
    IntrinsicsFlavor("tpu", _tpu_shift_combine, _fence_noop,
                     vec_bytes=4 * LANES),
    backends=("pallas-tpu", "pallas-interpret", "xla"))
register_flavor(
    IntrinsicsFlavor("gpu", _gpu_shift_combine, _fence_barrier,
                     vec_bytes=16),
    backends=("pallas-gpu",))


def get_flavor(name_or_backend: str) -> IntrinsicsFlavor:
    """Resolve a flavor by its own name or by a backend name."""
    name = _BACKEND_FLAVOR.get(name_or_backend, name_or_backend)
    flavor = _FLAVORS.get(name)
    if flavor is None:
        raise ValueError(
            f"unknown intrinsics flavor {name_or_backend!r} "
            f"(flavors: {sorted(_FLAVORS)}; "
            f"backends: {sorted(_BACKEND_FLAVOR)})")
    return flavor


def memory_fence(values: Pytree, *, flavor: str = "tpu") -> Pytree:
    """Ordered-visibility edge: the returned values are guaranteed to be
    materialized before anything computed *from them* afterwards.

    Kernels thread a (publish, flag) pair through the fence so the status
    flag a successor observes cannot be reordered before the aggregate it
    guards -- the release/acquire protocol of decoupled lookback.  The TPU
    flavor is the identity (per-core sequential grids order memory by
    construction); the GPU flavor lowers to an optimization barrier today
    and is the seam where a hardware Mosaic-GPU lowering emits a
    device-scope fence.
    """
    return get_flavor(flavor).fence(values)


def vec_width(dtype, *, flavor: str = "gpu") -> int:
    """Elements per vectorized load/store transaction for ``dtype`` --
    the float4-style width hint (16-byte transactions on GPUs, a full
    lane-row on TPUs)."""
    return max(1, get_flavor(flavor).vec_bytes // jnp.dtype(dtype).itemsize)


def tile_scan(op, x: Pytree, axis: int, *, flavor: str = "tpu") -> Pytree:
    """In-order inclusive scan of a tile along ``axis`` (Hillis–Steele).

    log2(extent) shifted combines; order-preserving, so correct for
    non-commutative ``op`` (quaternions, affine maps, 2x2 matrices).
    The shift primitive is flavor-dispatched (see module docstring): the
    TPU form needs no identity (roll + select), the GPU form is the
    identity-padded ``shfl_up`` combine.
    """
    shift_combine = get_flavor(flavor).shift_combine
    leaves = jax.tree.leaves(x)
    extent = leaves[0].shape[axis]
    shape = leaves[0].shape
    idx = jax.lax.broadcasted_iota(jnp.int32, shape, axis)
    s = 1
    while s < extent:
        x = shift_combine(op, x, s, axis, idx)
        s *= 2
    return x


def tile_take_last(x: Pytree, axis: int) -> Pytree:
    """Slice the last element along ``axis`` (keepdims)."""
    def take(l):
        sl = [slice(None)] * l.ndim
        sl[axis] = slice(l.shape[axis] - 1, l.shape[axis])
        return l[tuple(sl)]

    return jax.tree.map(take, x)


def _split_along(x: Pytree, axis: int, k: int) -> tuple[Pytree, Pytree]:
    """Split pytree ``x`` into ([0:k], [k:2k]) slices along ``axis``."""
    treedef = jax.tree.structure(x)
    pairs = []
    for l in jax.tree.leaves(x):
        sl_lo = [slice(None)] * l.ndim
        sl_hi = [slice(None)] * l.ndim
        sl_lo[axis] = slice(0, k)
        sl_hi[axis] = slice(k, 2 * k)
        pairs.append((l[tuple(sl_lo)], l[tuple(sl_hi)]))
    lo = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    hi = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return lo, hi


def tile_reduce(op, x: Pytree, axis: int, *, flavor: str = "tpu") -> Pytree:
    """Reduce a tile along ``axis``, keepdims.

    Commutative ops with power-of-two extents use a balanced halving fold
    (fewest combines, flavor-independent); otherwise an order-preserving
    flavored scan + take-last.  The commutativity dispatch is itself a
    tuning decision exposed by the operator algebra (DESIGN.md §3).
    """
    extent = jax.tree.leaves(x)[0].shape[axis]
    pow2 = extent > 0 and (extent & (extent - 1)) == 0
    if not getattr(op, "commutative", False) or not pow2:
        return tile_take_last(tile_scan(op, x, axis, flavor=flavor), axis)
    k = extent
    while k > 1:
        k //= 2
        lo, hi = _split_along(x, axis, k)
        x = op(lo, hi)
    return x


# --------------------------------------------------------------------------
# Tuning-policy dispatch hierarchy (A40 <: Ampere <: AbstractArch analogue).
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TuningPolicy:
    """Per-chip static kernel parameters, resolved at trace time."""

    name: str = "generic"
    # Items-per-grid-step multiplier (the paper's Nitem): how many minimum
    # tiles one grid step processes.  Scan uses a larger Nitem to amortize
    # carry propagation, exactly like the paper's 16-items-per-thread scan.
    nitem_copy: int = 8
    nitem_scan: int = 16
    nitem_reduce: int = 8
    # matvec / vecmat block parameters (rows, cols are in units of min tile).
    matvec_rows: int = 16
    matvec_cols: int = 2
    vecmat_rows: int = 8
    vecmat_cols: int = 8
    # Wide/tall shape cutover (aspect ratio heuristic, paper §V-C).
    tall_threshold: float = 64.0
    vmem_budget_bytes: int = 64 * 1024 * 1024
    # Radix-sort digit width in bits (2^bits buckets per pass).  Wider digits
    # mean fewer passes but a larger per-pass rank scan; the sweet spot is
    # shape- and chip-dependent, so it sits on the tuning ladder.
    sort_digit_bits: int = 8
    # GPU (pallas-gpu) block arithmetic: a block covers
    # gpu_threads x nitem_* x vec_width(dtype) elements -- threads per
    # block times the paper's items-per-thread times the vectorized
    # transaction width, so the existing nitem_* ladders race meaningful
    # GPU knobs with no new tuning keys.  gpu_vec_bytes is the vectorized
    # load/store transaction width (float4-style 128-bit accesses).
    gpu_threads: int = 128
    gpu_vec_bytes: int = 16
    # @sharded staged-plan driver (distributed/primitives.py): how many
    # slabs a chunkable plan splits into, so each slab's collective can be
    # issued while the next slab's local stage computes.  1 disables
    # chunking; the knob is raced on the topology-keyed tuning ladder (a
    # winner on one mesh shape is never replayed on another).
    overlap_chunks: int = 4


_TUNING_REGISTRY: dict[str, TuningPolicy] = {}
_TUNING_PARENTS: dict[str, str] = {}


def register_tuning(name: str, policy: TuningPolicy, parent: str = "generic"):
    _TUNING_REGISTRY[name] = policy
    _TUNING_PARENTS[name] = parent


register_tuning("generic", TuningPolicy())
# TPU v5e: 16 GiB HBM @ 819 GB/s, 197 bf16 TFLOP/s, ~128 MiB VMEM/core.
register_tuning(
    "tpu_v5e",
    TuningPolicy(name="tpu_v5e", nitem_scan=16, nitem_reduce=8, nitem_copy=8,
                 vmem_budget_bytes=96 * 1024 * 1024),
)
# v5p: larger HBM/bandwidth; deeper pipelining pays off.
register_tuning(
    "tpu_v5p",
    TuningPolicy(name="tpu_v5p", nitem_scan=32, nitem_reduce=16, nitem_copy=16,
                 vmem_budget_bytes=96 * 1024 * 1024),
    parent="tpu_v5e",
)
# Interpret mode: tiny tiles keep the Python loop fast while exercising the
# same code paths (masking, carries, patterns).
register_tuning(
    "interpret",
    TuningPolicy(name="interpret", nitem_copy=2, nitem_scan=2, nitem_reduce=2,
                 matvec_rows=2, matvec_cols=1, vecmat_rows=2, vecmat_cols=1,
                 sort_digit_bits=4, overlap_chunks=2),
)
# GPU family (the paper's A40 <: Ampere chain, across vendors): blocks are
# gpu_threads x nitem x vec elements.  Datacenter parts get more threads
# per block; the MI300 wavefront64 part doubles the subgroup multiple.
register_tuning("gpu_generic", TuningPolicy(name="gpu_generic"))
register_tuning(
    "gpu_a100",
    TuningPolicy(name="gpu_a100", nitem_scan=16, nitem_reduce=8,
                 gpu_threads=256),
    parent="gpu_generic")
register_tuning(
    "gpu_h100",
    TuningPolicy(name="gpu_h100", nitem_scan=32, nitem_reduce=16,
                 gpu_threads=256),
    parent="gpu_a100")
register_tuning(
    "gpu_mi300",
    TuningPolicy(name="gpu_mi300", nitem_scan=16, nitem_reduce=8,
                 gpu_threads=256),
    parent="gpu_generic")
# GPU kernel bodies under the Pallas interpreter (CI's gpu-interpret job):
# small blocks keep the Python grid loop fast, same code paths as hardware.
register_tuning(
    "gpu_interpret",
    TuningPolicy(name="gpu_interpret", nitem_scan=2, nitem_reduce=2,
                 nitem_copy=2, matvec_rows=2, matvec_cols=1, vecmat_rows=2,
                 vecmat_cols=1, sort_digit_bits=4, gpu_threads=32,
                 overlap_chunks=2),
    parent="gpu_generic")


def resolve_tuning(name: str | None = None) -> TuningPolicy:
    if name is None:
        name = detect_chip()
    while name not in _TUNING_REGISTRY:
        name = _TUNING_PARENTS.get(name, "generic")
    return _TUNING_REGISTRY[name]


_GPU_PLATFORMS = ("gpu", "cuda", "rocm")


def detect_chip() -> str:
    dev = jax.devices()[0]
    if dev.platform == "tpu":
        kind = getattr(dev, "device_kind", "").lower()
        if "v5 lite" in kind or "v5e" in kind:
            return "tpu_v5e"
        if "v5p" in kind or "v5" in kind:
            return "tpu_v5p"
        return "tpu_v5e"
    if dev.platform in _GPU_PLATFORMS:
        kind = getattr(dev, "device_kind", "").lower()
        for tag, name in (("h100", "gpu_h100"), ("h200", "gpu_h100"),
                          ("a100", "gpu_a100"), ("mi300", "gpu_mi300"),
                          ("mi250", "gpu_mi300")):
            if tag in kind:
                return name
        return "gpu_generic"
    return "generic"


def default_policy_name(backend: str | None) -> str | None:
    """Tuning-policy name a backend's kernels should resolve when no policy
    is passed (None means: detect the chip).  Shared by the kernel wrappers
    and the autotuner hook so both start from the same base policy."""
    if backend == "pallas-interpret":
        return "interpret"
    if backend == "pallas-gpu":
        # On a real GPU the chip detector picks the family; everywhere else
        # the kernel bodies run under the interpreter and want tiny blocks.
        return None if jax.default_backend() in _GPU_PLATFORMS \
            else "gpu_interpret"
    return None


# --------------------------------------------------------------------------
# Backend dispatch registry (package-extension analogue) and the public
# backend-selection API: a thread-safe scoped override (use_backend) plus
# registry-driven capability queries (available_backends / supports).
# --------------------------------------------------------------------------

_IMPL_REGISTRY: dict[tuple[str, str], Callable] = {}
_FORCED_BACKEND: str | None = None           # legacy force_backend() shim
_FORCE_BACKEND_WARNED = False
# Optional autotuner hook (installed by core.tuning to avoid a layering
# cycle): called as hook(primitive, backend, impl) and may return a wrapped
# impl that injects a benchmarked TuningPolicy, or None to pass through.
_TUNER_HOOK: Callable[[str, str, Callable], Callable | None] | None = None


class _BackendScope(threading.local):
    """Per-thread stack of use_backend() overrides (innermost wins)."""

    def __init__(self):
        self.stack: list[str] = []


_BACKEND_SCOPE = _BackendScope()


def set_tuner_hook(hook: Callable | None):
    """Install (or clear) the autotune wrapper consulted by resolve_impl."""
    global _TUNER_HOOK
    _TUNER_HOOK = hook


def register_impl(primitive: str, backend: str):
    def deco(fn):
        _IMPL_REGISTRY[(primitive, backend)] = fn
        return fn

    return deco


def registered_backends(key: str) -> list[str]:
    """Backends with an implementation registered for ``key`` (sorted)."""
    return sorted(b for (p, b) in _IMPL_REGISTRY if p == key)


def _known_backends() -> set[str]:
    # Registration happens when kernels/ops.py imports; pull it in lazily so
    # the query API works from a bare `import repro` without making Layer 1
    # depend on the kernels package at import time.
    if not _IMPL_REGISTRY:
        from repro.kernels import ops as _ops  # noqa: F401
    return {b for (_, b) in _IMPL_REGISTRY}


def available_backends() -> tuple[str, ...]:
    """All backend names with at least one registered implementation."""
    return tuple(sorted(_known_backends()))


def supports(route: str, backend: str) -> bool:
    """Whether ``route`` (e.g. ``"scan@batched"``) has a native ``backend``
    implementation.  False means dispatch would use the xla fallback;
    unknown route or backend *names* raise ValueError, mirroring what
    dispatch itself (and :func:`use_backend`) would do with them."""
    if route not in route_keys():
        raise ValueError(
            f"unknown route {route!r} (routes: {sorted(route_keys())})")
    if backend not in _known_backends():
        raise ValueError(
            f"unknown backend {backend!r} "
            f"(available: {', '.join(available_backends())})")
    return (route, backend) in _IMPL_REGISTRY


@contextlib.contextmanager
def use_backend(backend: str):
    """Scoped backend override: ``with use_backend("pallas-gpu"): ...``.

    Thread-safe (each thread keeps its own stack; innermost scope wins) and
    validated against the registry up front, so a typo fails at the `with`
    statement rather than as a silent xla fallback deep in a trace.  An
    explicit ``backend=`` argument on a primitive call still takes
    precedence over the scope.
    """
    if backend not in _known_backends():
        raise ValueError(
            f"unknown backend {backend!r} "
            f"(available: {', '.join(available_backends())})")
    _BACKEND_SCOPE.stack.append(backend)
    try:
        yield backend
    finally:
        _BACKEND_SCOPE.stack.pop()


def force_backend(backend: str | None):
    """Deprecated: process-global backend pin.  Use :func:`use_backend`.

    Kept as a warn-once shim with unchanged behavior (a global default that
    scoped overrides and explicit ``backend=`` arguments still beat).
    """
    global _FORCED_BACKEND, _FORCE_BACKEND_WARNED
    if not _FORCE_BACKEND_WARNED:
        warnings.warn(
            "force_backend() is deprecated; use the scoped "
            "repro.use_backend(...) context manager instead",
            DeprecationWarning, stacklevel=2)
        _FORCE_BACKEND_WARNED = True
    _FORCED_BACKEND = backend


_SUB_BACKEND_WARNED = False


def sub_backend_alias(fn):
    """Deprecated-alias shim: the composition entry points (radix sorts,
    sharded folds) used to spell their backend parameter ``sub_backend=``.
    The alias still works -- warn once per process, like
    :func:`force_backend` -- and forwards to ``backend=``; passing both
    spellings is an error."""

    @functools.wraps(fn)
    def wrapper(*args, sub_backend=None, **kwargs):
        global _SUB_BACKEND_WARNED
        if sub_backend is not None:
            if "backend" in kwargs:
                raise TypeError(
                    f"{fn.__name__}: got both backend= and its deprecated "
                    "alias sub_backend=; pass backend= only")
            if not _SUB_BACKEND_WARNED:
                warnings.warn(
                    "the sub_backend= keyword is deprecated; compositions "
                    "now take the same backend= spelling as every other "
                    "primitive",
                    DeprecationWarning, stacklevel=2)
                _SUB_BACKEND_WARNED = True
            kwargs["backend"] = sub_backend
        return fn(*args, **kwargs)

    return wrapper


def current_backend() -> str:
    """The backend dispatch uses when no explicit ``backend=`` is passed:
    innermost use_backend() scope, else the (deprecated) forced global,
    else the platform default."""
    if _BACKEND_SCOPE.stack:
        return _BACKEND_SCOPE.stack[-1]
    if _FORCED_BACKEND is not None:
        return _FORCED_BACKEND
    platform = jax.default_backend()
    if platform == "tpu":
        return "pallas-tpu"
    if platform in _GPU_PLATFORMS:
        return "pallas-gpu"
    return "xla"


def resolve_impl(primitive: str, backend: str | None = None) -> Callable:
    backend = backend or current_backend()
    key = (primitive, backend)
    impl = _IMPL_REGISTRY.get(key)
    if impl is None:
        if backend not in _known_backends():
            # Unknown backend *names* are user errors and fail loudly,
            # uniformly naming the route; known backends without a native
            # implementation for this route fall back below.
            raise ValueError(
                f"{primitive}: unknown backend {backend!r} "
                f"(available: {', '.join(available_backends())})")
        # Fall back to the portable XLA implementation -- the algorithmic
        # layer is always available even on backends with no Pallas lowering.
        impl = _IMPL_REGISTRY.get((primitive, "xla"))
    if impl is None:
        raise NotImplementedError(f"no implementation registered for {primitive}")
    if _TUNER_HOOK is not None:
        wrapped = _TUNER_HOOK(primitive, backend, impl)
        if wrapped is not None:
            return wrapped
    return impl


# --------------------------------------------------------------------------
# The declarative primitive registry: one table drives dispatch, validation,
# zero-extent guards, non-commutative rerouting, tuning keys and the
# generated docs/conformance enumerations.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TuneRecipe:
    """How to build a tuning cache key + which policy knobs to race.

    ``dims`` selects the generic key extractor in ``core.tuning``:

    * ``"flat"``   -- ``n`` = total element count over the data's leaves;
    * ``"row"``    -- ``(B, n)`` leaves: per-row extent + batch bucket;
    * ``"trail2"`` -- ``(B, d1, d2)`` leading leaf: the two trailing dims
      bucket *separately* (``"8192x128"``) because block selection branches
      on the aspect ratio, plus the batch bucket.
    """

    ladder: tuple  # TuningPolicy field-override dicts to race
    # Argument indices default to the enclosing RouteDef's data_arg/op_arg
    # (resolved in core.tuning) -- override only when the key should read a
    # different operand than dispatch validates.
    data_arg: int | None = None
    op_arg: int | None = None      # positional index of the AssocOp, or
    op_label: str | None = None    # a fixed label when the op is implicit
    dims: str = "flat"


@dataclasses.dataclass(frozen=True)
class RouteDef:
    """One (primitive, layout) row of the registry.

    ``args`` indices refer to the positional call convention of the public
    entry point (and of the registered implementations, which share it).
    """

    primitive: str
    layout: str
    data_arg: int = 0
    op_arg: int | None = None
    # ((arg index, required leaf rank), ...) -- checked on every leaf.
    arg_ranks: tuple = ()
    # ((kwarg name, required value), ...) -- kwargs the layout pins; they are
    # validated then stripped before the implementation call.
    fixed_kwargs: tuple = ()
    commutative_only: bool = False
    # Registered key to reroute non-commutative ops through (mapreduce ->
    # order-preserving scan of the mapped values, take-last).
    noncomm_route: str | None = None
    # Name of a shared zero-extent guard in _ZERO_GUARDS (None: the
    # implementation/composition handles zero extents itself).
    zero_extent: str | None = None
    needs_descriptor: bool = False    # Segmented: exactly one of flags/offsets
    needs_num_segments: bool = False  # Segmented flag variant: static extent
    # Sharded: validate the mesh/axis pair and inject them as kwargs
    # (axis_name=, mesh=) before the implementation call.
    needs_mesh: bool = False
    tuning: TuneRecipe | None = None
    notes: str = ""                   # surfaced in the generated docs table

    @property
    def key(self) -> str:
        return f"{self.primitive}@{self.layout}"


@dataclasses.dataclass(frozen=True)
class PrimitiveDef:
    """A public primitive and its layout routes."""

    name: str
    routes: dict  # layout kind -> RouteDef
    doc: str = ""


PRIMITIVE_DEFS: dict[str, PrimitiveDef] = {}


def define_primitive(name: str, *routes: RouteDef, doc: str = ""):
    PRIMITIVE_DEFS[name] = PrimitiveDef(
        name=name, routes={r.layout: r for r in routes}, doc=doc)


def iter_routes():
    """Every RouteDef in the registry, in definition order."""
    for pdef in PRIMITIVE_DEFS.values():
        yield from pdef.routes.values()


def route_keys() -> set[str]:
    return {r.key for r in iter_routes()}


def get_route(primitive: str, kind: str) -> RouteDef:
    pdef = PRIMITIVE_DEFS.get(primitive)
    if pdef is None:
        raise NotImplementedError(f"unknown primitive {primitive!r}")
    route = pdef.routes.get(kind)
    if route is None:
        raise ValueError(
            f"{primitive}: unsupported layout {kind!r} "
            f"(supported: {sorted(pdef.routes)})")
    return route


# -- shared zero-extent guards (single implementations, wired by name) ------


def _zg_passthrough(route, args, kwargs):
    """Any zero extent in the data: the input already is the output."""
    data = args[route.data_arg]
    lead = jax.tree.leaves(data)[0]
    if any(d == 0 for d in lead.shape):
        return True, data
    return False, None


def _zg_batched_reduce_identity(route, args, kwargs):
    """(B, 0) rows / B == 0: reducing zero elements yields identity rows."""
    f, op, xs = args[0], args[1], args[2]
    B, n = jax.tree.leaves(xs)[0].shape
    if B and n:
        return False, None
    one = jax.eval_shape(
        f, jax.tree.map(lambda l: jax.ShapeDtypeStruct((1, 1), l.dtype), xs))
    return True, op.identity(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((B,), l.dtype), one))


def _zg_segmented_reduce_identity(route, args, kwargs):
    """Zero-length stream: every declared segment reduces to identity."""
    f, op, xs = args[0], args[1], args[2]
    if jax.tree.leaves(xs)[0].shape[0] != 0:
        return False, None
    offsets = kwargs.get("offsets")
    ns = (kwargs.get("num_segments") if offsets is None
          else offsets.shape[0] - 1)
    vals = jax.eval_shape(f, xs)
    return True, op.identity(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((ns,) + l.shape[1:], l.dtype), vals))


def _zg_batched_mv_identity(route, args, kwargs):
    """(B, n, p) with any zero extent: identity rows of the output extent."""
    f, op, A, x = args[0], args[1], args[2], args[3]
    B, n, p = A.shape
    if B and n and p:
        return False, None
    if route.primitive == "matvec":       # y[b, j]: extent p, f(x, a)
        out_extent, arg_dtypes = p, (x.dtype, A.dtype)
    else:                                 # z[b, i]: extent n, f(a, x)
        out_extent, arg_dtypes = n, (A.dtype, x.dtype)
    one = jax.eval_shape(
        f, jax.ShapeDtypeStruct((1, 1), arg_dtypes[0]),
        jax.ShapeDtypeStruct((1, 1), arg_dtypes[1]))
    return True, op.identity(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((B, out_extent), l.dtype), one))


_ZERO_GUARDS = {
    "passthrough": _zg_passthrough,
    "batched_reduce_identity": _zg_batched_reduce_identity,
    "segmented_reduce_identity": _zg_segmented_reduce_identity,
    "batched_mv_identity": _zg_batched_mv_identity,
}


# -- the dispatch pipeline --------------------------------------------------


def _validate(route: RouteDef, layout, args, kwargs):
    where = route.key
    for name, required in route.fixed_kwargs:
        if name in kwargs:
            got = kwargs.pop(name)
            if got is not required and got != required:
                raise ValueError(
                    f"{where}: {name}= is pinned by the "
                    f"{layout.describe()} layout -- leave it at its "
                    f"default ({required!r}); got {got!r}"
                    + (f". {route.notes}" if route.notes else ""))
    if route.needs_descriptor:
        lay.validate_descriptor(layout.flags, layout.offsets, where=where)
        if (route.needs_num_segments and layout.offsets is None
                and layout.num_segments is None):
            raise ValueError(
                f"{where}: the flags descriptor needs Segmented("
                f"num_segments=...) -- the output extent is static")
    if route.needs_mesh:
        if not isinstance(layout.axis, str) or not layout.axis:
            raise ValueError(
                f"{where}: Sharded(axis=...) must name a mesh axis, got "
                f"{layout.axis!r}")
        if layout.mesh is not None:
            names = tuple(getattr(layout.mesh, "axis_names", ()))
            if layout.axis not in names:
                raise ValueError(
                    f"{where}: axis {layout.axis!r} is not an axis of the "
                    f"mesh (axes: {names})")
    for idx, rank in route.arg_ranks:
        for leaf in jax.tree.leaves(args[idx]):
            if leaf.ndim != rank:
                raise ValueError(
                    f"{where}: argument {idx} expects rank-{rank} leaves "
                    f"for the {layout.describe()} layout, got shape "
                    f"{tuple(leaf.shape)}")
    if route.op_arg is not None and route.commutative_only:
        op = args[route.op_arg]
        if not getattr(op, "commutative", False):
            raise ValueError(
                f"{where}: requires a commutative operator, got "
                f"{getattr(op, 'name', op)!r} (non-commutative ops take "
                f"the order-preserving scan routes)")


def dispatch(primitive: str, layout, backend: str | None,
             args: tuple, kwargs: dict):
    """Resolve and call one (primitive, layout, backend) route.

    The pipeline -- validation, layout-descriptor injection, zero-extent
    guard, non-commutative reroute, tuner-wrapped implementation -- is
    driven entirely by the RouteDef row, so it is written once for every
    primitive family.
    """
    layout = lay.as_layout(layout)
    route = get_route(primitive, layout.kind)
    kwargs = dict(kwargs)
    _validate(route, layout, args, kwargs)
    if route.needs_descriptor:
        kwargs["flags"] = layout.flags
        kwargs["offsets"] = layout.offsets
        if route.needs_num_segments:
            kwargs["num_segments"] = layout.num_segments
    if route.needs_mesh:
        kwargs["axis_name"] = layout.axis
        kwargs["mesh"] = layout.mesh
        kwargs["overlap"] = layout.overlap
    if route.zero_extent is not None:
        handled, result = _ZERO_GUARDS[route.zero_extent](route, args, kwargs)
        if handled:
            return result
    if route.noncomm_route is not None and not getattr(
            args[route.op_arg], "commutative", False):
        # Order-preserving reroute: scan the mapped values with the same
        # layout, take each problem's last element.  (Registered scans are
        # order-preserving, so the batched family relaxes mapreduce's
        # commutativity contract for free.)
        f, op, xs = args[0], args[1], args[2]
        incl = resolve_impl(route.noncomm_route, backend)(
            op, f(xs), inclusive=True)
        return jax.tree.map(lambda l: l[:, -1], incl)
    return resolve_impl(route.key, backend)(*args, **kwargs)


# -- the table itself -------------------------------------------------------

_NITEM_SCAN = tuple({"nitem_scan": v} for v in (4, 8, 16, 32))
_NITEM_REDUCE = tuple({"nitem_reduce": v} for v in (4, 8, 16))
_NITEM_COPY = tuple({"nitem_copy": v} for v in (4, 8, 16))
# Radix sort races digit width x block policy: wider digits mean fewer
# scatter passes but a larger per-pass rank scan, and the rank scan's own
# block size (nitem_scan) interacts with the digit count.
_SORT_LADDER = tuple({"sort_digit_bits": d, "nitem_scan": m}
                     for d in (2, 4, 8) for m in (8, 16))
_MATVEC_ROWS = tuple({"matvec_rows": v} for v in (4, 8, 16))
_VECMAT_ROWS = tuple({"vecmat_rows": v} for v in (4, 8, 16))
# Chunk count raced by the @sharded staged-plan driver: more chunks expose
# more communication/compute overlap but shrink each local launch.  Sharded
# tuning keys carry the mesh topology, so a winner on one axis extent is
# never replayed on another.
_OVERLAP_CHUNKS = tuple({"overlap_chunks": v} for v in (1, 2, 4, 8))

_SORT_TUNE = TuneRecipe(_SORT_LADDER, op_label="keys")

define_primitive(
    "copy",
    RouteDef("copy", "flat", zero_extent="passthrough",
             tuning=TuneRecipe(_NITEM_COPY, op_label="copy")),
    doc="bandwidth-ceiling tiled copy")

define_primitive(
    "scan",
    RouteDef("scan", "flat", data_arg=1, op_arg=0, zero_extent="passthrough",
             tuning=TuneRecipe(_NITEM_SCAN)),
    RouteDef("scan", "batched", data_arg=1, op_arg=0, arg_ranks=((1, 2),),
             fixed_kwargs=(("axis", 0),), zero_extent="passthrough",
             tuning=TuneRecipe(_NITEM_SCAN, dims="row"),
             notes="per-row scan along axis 1 of (B, n) leaves"),
    RouteDef("scan", "segmented", data_arg=1, op_arg=0, arg_ranks=((1, 1),),
             fixed_kwargs=(("axis", 0), ("reverse", False)),
             needs_descriptor=True, zero_extent="passthrough",
             tuning=TuneRecipe(_NITEM_SCAN),
             notes="restarts at every segment boundary"),
    RouteDef("scan", "sharded", data_arg=1, op_arg=0, arg_ranks=((1, 1),),
             fixed_kwargs=(("axis", 0), ("reverse", False)),
             needs_mesh=True, zero_extent="passthrough",
             tuning=TuneRecipe(_NITEM_SCAN),
             notes="local scan per shard + exclusive cross-device scan of "
                   "per-shard carries; order-preserving, so non-commutative "
                   "ops are valid"),
    doc="prefix scan with any associative operator")

define_primitive(
    "mapreduce",
    RouteDef("mapreduce", "flat", data_arg=2, op_arg=1,
             commutative_only=True,
             tuning=TuneRecipe(_NITEM_REDUCE)),
    RouteDef("mapreduce", "batched", data_arg=2, op_arg=1,
             arg_ranks=((2, 2),), fixed_kwargs=(("axis", None),),
             noncomm_route="scan@batched",
             zero_extent="batched_reduce_identity",
             # Non-commutative ops never reach this tuner: dispatch reroutes
             # them to scan@batched, whose own ladder races nitem_scan.
             tuning=TuneRecipe(_NITEM_REDUCE, dims="row"),
             notes="non-commutative ops reroute via scan@batched"),
    RouteDef("mapreduce", "segmented", data_arg=2, op_arg=1,
             arg_ranks=((2, 1),), fixed_kwargs=(("axis", None),),
             needs_descriptor=True, needs_num_segments=True,
             zero_extent="segmented_reduce_identity",
             tuning=TuneRecipe(_NITEM_SCAN),
             notes="one output element per segment; empties yield identity; "
                   "order-preserving (segmented scan + gather), so "
                   "non-commutative ops are valid"),
    RouteDef("mapreduce", "sharded", data_arg=2, op_arg=1,
             commutative_only=True, fixed_kwargs=(("axis", None),),
             needs_mesh=True,
             tuning=TuneRecipe(_NITEM_REDUCE + _OVERLAP_CHUNKS),
             notes="local reduce along leaf axis 0 + the operator's "
                   "collective fold (psum/pmax/pmin rewrite when the monoid "
                   "allows, all_gather fold otherwise); the cross-device "
                   "fold requires commutativity; rank>=2 mapped leaves are "
                   "chunked along axis 1 for collective/compute overlap"),
    doc="op-reduction of f(x)")

define_primitive(
    "matvec",
    RouteDef("matvec", "flat", data_arg=2, op_arg=1,
             arg_ranks=((2, 2), (3, 1))),
    RouteDef("matvec", "batched", data_arg=2, op_arg=1,
             arg_ranks=((2, 3), (3, 2)), zero_extent="batched_mv_identity",
             tuning=TuneRecipe(_MATVEC_ROWS, dims="trail2")),
    RouteDef("matvec", "sharded", data_arg=2, op_arg=1,
             arg_ranks=((2, 2), (3, 1)), needs_mesh=True,
             tuning=TuneRecipe(_OVERLAP_CHUNKS, dims="row"),
             notes="contraction-axis (row) tensor parallelism: local strip "
                   "matvec per shard + the operator's collective fold over "
                   "strip partials (ADD -> psum for the decode GEMV); a "
                   "< shards row remainder rides replicated and folds in "
                   "last, so reduction order matches the flat route"),
    doc="y[j] = op_i f(x[i], A[i, j]) (generalized semiring matvec)")

define_primitive(
    "vecmat",
    RouteDef("vecmat", "flat", data_arg=2, op_arg=1,
             arg_ranks=((2, 2), (3, 1))),
    RouteDef("vecmat", "batched", data_arg=2, op_arg=1,
             arg_ranks=((2, 3), (3, 2)), zero_extent="batched_mv_identity",
             tuning=TuneRecipe(_VECMAT_ROWS, dims="trail2")),
    RouteDef("vecmat", "sharded", data_arg=2, op_arg=1,
             arg_ranks=((2, 2), (3, 1)), needs_mesh=True,
             tuning=TuneRecipe(_OVERLAP_CHUNKS, dims="row"),
             notes="contraction-axis (column) tensor parallelism, the "
                   "row-wise mirror of matvec@sharded: column strips are "
                   "sharded, strip partials fold across the axis, and the "
                   "< shards column remainder rides replicated"),
    doc="z[i] = op_j f(A[i, j], x[j]) (generalized semiring vecmat)")

define_primitive(
    "linear_recurrence",
    RouteDef("linear_recurrence", "flat", arg_ranks=((0, 3), (1, 3))),
    RouteDef("linear_recurrence", "batched", arg_ranks=((0, 3), (1, 3)),
             tuning=TuneRecipe(_NITEM_SCAN, op_label="affine",
                               dims="trail2"),
             notes="the decode hot path; tuner keys carry a batch bucket"),
    RouteDef("linear_recurrence", "sharded", arg_ranks=((0, 3), (1, 3)),
             fixed_kwargs=(("reverse", False),), needs_mesh=True,
             tuning=TuneRecipe(_OVERLAP_CHUNKS, op_label="affine",
                               dims="trail2"),
             notes="sequence (T) sharding for long-context prefill: local "
                   "affine scan per shard + an exclusive cross-device carry "
                   "of per-shard (A, B) totals; h0 rides replicated; uneven "
                   "T pads with the affine identity (a=1, b=0)"),
    doc="h_t = a_t * h_{t-1} + b_t along axis 1 of (B, T, C)")

_SHARDED_SORT_NOTES = {
    "sort_pairs": "shard-local sort, then a splitter exchange in portable "
                  "form (gathered sorted runs merged by cross-run rank); "
                  "each shard keeps its slice of the global order",
    "top_k": "per-shard top-k candidates + a k-way partial merge; result "
             "replicated across the axis",
}

for _sort_prim, _sort_notes in (
        ("sort", "stable LSD radix; zero extents short-circuit in the "
                 "shared composition (kernels/sort.py)"),
        ("sort_pairs", "payload pytree rides the same permutation"),
        ("argsort", "segmented variant returns within-segment offsets"),
        ("top_k", "extreme-first; segmented fills short segments with "
                  "identity and index -1")):
    _sort_routes = [
        RouteDef(_sort_prim, "flat", arg_ranks=((0, 1),),
                 tuning=_SORT_TUNE),
        RouteDef(_sort_prim, "segmented", arg_ranks=((0, 1),),
                 needs_descriptor=True,
                 needs_num_segments=(_sort_prim == "top_k"),
                 tuning=_SORT_TUNE, notes=_sort_notes),
    ]
    if _sort_prim in _SHARDED_SORT_NOTES:
        _sort_routes.append(
            RouteDef(_sort_prim, "sharded", arg_ranks=((0, 1),),
                     needs_mesh=True, tuning=_SORT_TUNE,
                     notes=_SHARDED_SORT_NOTES[_sort_prim]))
    define_primitive(_sort_prim, *_sort_routes,
                     doc=f"radix-sort family: {_sort_prim}")
