"""Empirical autotuner for kernel block/tile parameters.

The static :class:`~repro.core.intrinsics.TuningPolicy` hierarchy encodes
*priors* per chip family; this module adds the measurement layer on top.
Kokkos/RAJA-style portability studies find tile/block-size selection to be
the dominant cost of moving performance-portable kernels between devices, so
instead of trusting the prior everywhere, the first call of a tunable
primitive on a new (primitive, operator, dtype, shape-bucket, platform) key
benchmarks a small candidate ladder of policies on the *actual* inputs and
memoizes the winner in an on-disk JSON cache.  Every later call -- including
calls from inside ``jax.jit`` traces, where timing would be meaningless --
reuses the cached winner with zero measurement overhead.

Layering: ``core.intrinsics`` knows nothing about this module; it exposes a
hook (:func:`~repro.core.intrinsics.set_tuner_hook`) that :func:`enable`
installs.  ``resolve_impl`` consults the hook, so *every* primitive dispatch
site gets tuning for free and the algorithmic layer stays backend- and
tuner-agnostic.

Usage::

    from repro.core import tuning
    tuning.enable()                      # or REPRO_AUTOTUNE=1 in the env
    forge.scan(alg.ADD, x)               # first call: benchmarks + caches
    forge.scan(alg.ADD, jnp.ones_like(x))  # same key: cache hit, no bench

The cache path defaults to ``~/.cache/repro/tuning.json`` and can be moved
with ``REPRO_TUNING_CACHE=/path/to/tuning.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable

import jax

from repro.core import intrinsics as ki


def default_cache_path() -> str:
    return os.environ.get(
        "REPRO_TUNING_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "tuning.json"))


def shape_bucket(n: int) -> int:
    """Power-of-two bucket so dimension jitter shares one tuning entry."""
    return 1 << max(int(n) - 1, 0).bit_length()


# ---------------------------------------------------------------------------
# What is tunable: derived from the PrimitiveDef registry.  Each RouteDef
# carries a TuneRecipe (candidate ladder + key-extraction recipe); one
# generic keyer below interprets the recipe, so adding a tunable route is a
# table entry in core/intrinsics.py, not a new keyer function here.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TunableSpec:
    """How to tune one route: cache-key fields + candidate overrides.

    ``keyer`` returns ``(op_name, dtype, n)`` or, for the batched family,
    ``(op_name, dtype, n, batch)`` -- the batch rides its own bucket in the
    cache key, and because the batched primitives are single launches, one
    tuning race covers the whole batch rather than one race per row.
    """

    keyer: Callable[[tuple, dict], tuple | None]
    candidates: tuple[dict, ...]  # TuningPolicy field overrides to race


def _recipe_keyer(route: "ki.RouteDef") -> Callable:
    """Generic key extraction driven by a route's TuneRecipe.

    * ``flat``: total element count over the data's leaves.
    * ``row``: ``(B, n)`` leaves -- per-row extent + batch bucket.
    * ``trail2``: ``(B, d1, d2)`` leading leaf -- the trailing dims bucket
      *separately* ("128x8192", not their product) because block selection
      branches on the aspect ratio, so a tall-narrow winner must never be
      replayed on a wide-short problem; batch rides its own bucket.

    ``@sharded`` routes additionally key the **mesh topology** (axis name,
    axis extent, total device count): a block policy raced on one device
    must never be replayed as the winner for an 8-way mesh, where the
    local extent and the collective/compute overlap are different problems.

    Argument indices default to the route's own ``data_arg``/``op_arg`` --
    the ones dispatch validates -- so they are declared once per row.
    """
    recipe = route.tuning
    data_arg = recipe.data_arg if recipe.data_arg is not None else route.data_arg
    op_arg = recipe.op_arg if recipe.op_arg is not None else route.op_arg
    sharded = route.layout == "sharded"

    def keyer(args, kwargs):
        op_name = (recipe.op_label if recipe.op_label is not None
                   else getattr(args[op_arg], "name", "?"))
        leaves = jax.tree.leaves(args[data_arg])
        lead = leaves[0]
        # Quantized operands carry their own dtype tag ("int8q64",
        # "fp8_e4m3q64", ...): the raw storage dtype would collide across
        # quantization modes and block sizes, leaking cached block winners
        # between routes with different dequant footprints.
        qtag = getattr(args[data_arg], "qtag", None)
        dtype = qtag if qtag is not None else str(jax.numpy.result_type(lead))
        topo = _mesh_topology(kwargs) if sharded else None
        if recipe.dims == "flat":
            return (op_name, dtype, sum(int(l.size) for l in leaves),
                    None, topo)
        if recipe.dims == "row":
            return (op_name, dtype, int(lead.shape[1]), int(lead.shape[0]),
                    topo)
        b, d1, d2 = lead.shape
        return (op_name, dtype,
                f"{shape_bucket(int(d1))}x{shape_bucket(int(d2))}", int(b),
                topo)

    return keyer


def _mesh_topology(kwargs) -> str:
    """Topology cache-key component for an @sharded route call.

    With a mesh in hand: the sharded axis name + extent and the full mesh
    shape.  In the in-mesh form (already inside a shard_map) the mesh object
    is unavailable, so the key degrades to the axis name + process-wide
    device count -- still enough to keep 1-device winners off N-device runs.
    """
    axis = kwargs.get("axis_name")
    mesh = kwargs.get("mesh")
    if mesh is not None:
        shape = "x".join(str(s) for s in mesh.devices.shape)
        return f"{axis}={mesh.shape[axis]}:{shape}"
    return f"{axis}=?:d{jax.device_count()}"


TUNABLE: dict[str, TunableSpec] = {
    route.key: TunableSpec(_recipe_keyer(route), tuple(route.tuning.ladder))
    for route in ki.iter_routes() if route.tuning is not None
}


def resolve_overlap_chunks(policy: "ki.TuningPolicy | None",
                           backend: str | None) -> int:
    """Chunk count for the @sharded staged-plan driver.

    An explicit policy (including one injected by the tuner racing the
    ``overlap_chunks`` ladder) wins; otherwise the backend's base policy
    supplies the prior.  Clamped to >= 1 (1 disables chunking).
    """
    if policy is None:
        policy = ki.resolve_tuning(ki.default_policy_name(backend))
    return max(1, int(getattr(policy, "overlap_chunks", 1)))


# ---------------------------------------------------------------------------
# The tuner itself.
# ---------------------------------------------------------------------------


class Autotuner:
    """Benchmark-once, memoize-forever policy selection with a JSON cache."""

    def __init__(self, cache_path: str | None = None, *, bench_repeats: int = 2):
        self.cache_path = cache_path or default_cache_path()
        self.bench_repeats = bench_repeats
        self.stats = {"benchmarks": 0, "hits": 0, "bench_calls": 0}
        self._cache: dict[str, dict] = {}
        self._load()

    # -- persistence --------------------------------------------------------

    def _read_disk(self) -> dict:
        """Best-effort read; a corrupt/truncated cache (e.g. a concurrent
        writer interrupted mid-line before atomic writes) means re-tuning,
        never an exception."""
        try:
            with open(self.cache_path) as f:
                data = json.load(f)
            return data if isinstance(data, dict) else {}
        except (OSError, ValueError):
            return {}

    def _load(self):
        self._cache = self._read_disk()

    def _save(self):
        """Atomic, concurrency-tolerant persist.

        Parallel test shards and self-hosted CI runners share one cache
        path.  The read-merge-write cycle is serialized with an advisory
        ``flock`` on a sidecar lock file (so a concurrent tuner's freshly
        benchmarked entries are merged, not overwritten with our stale view
        of the file), the temp file carries the pid so two processes never
        clobber each other's half-written file, and ``os.replace`` makes
        the publish atomic -- a reader never sees a truncated file (and if
        one ever does appear, ``_read_disk`` treats it as empty).  Where
        ``fcntl`` is unavailable the lock degrades to merge-on-save, which
        narrows the lost-update window to the merge itself.
        """
        try:
            os.makedirs(os.path.dirname(self.cache_path), exist_ok=True)
            with open(self.cache_path + ".lock", "w") as lk:
                try:
                    import fcntl
                    fcntl.flock(lk, fcntl.LOCK_EX)
                except (ImportError, OSError):
                    pass  # non-POSIX: fall back to unserialized merge-on-save
                merged = self._read_disk()
                merged.update(self._cache)
                self._cache = merged
                tmp = f"{self.cache_path}.{os.getpid()}.tmp"
                try:
                    with open(tmp, "w") as f:
                        json.dump(merged, f, indent=1, sort_keys=True)
                    os.replace(tmp, self.cache_path)
                finally:
                    if os.path.exists(tmp):
                        os.unlink(tmp)
        except OSError:
            pass  # caching is best-effort; never fail the computation

    # -- keys ---------------------------------------------------------------

    def make_key(self, primitive: str, backend: str, op_name: str,
                 dtype: str, n, batch: int | None = None,
                 topo: str | None = None) -> str:
        """Cache key; ``batch`` (batched family only) gets its own bucket so
        a B=4 decode batch and a B=256 one tune independently while keeping
        one entry -- one race -- per whole batch.  ``n`` is a flat extent to
        bucket, or a pre-bucketed string for multi-dim rows (e.g.
        ``"8192x128"``) whose aspect ratio drives block selection.
        ``topo`` (@sharded routes) pins the mesh topology, and the platform
        component always carries the process device count -- a 1-device
        winner must never be silently replayed on an N-device run."""
        platform = (f"{jax.default_backend()}/{ki.detect_chip()}"
                    f"/d{jax.device_count()}")
        batch_part = "" if batch is None else f"|batch={shape_bucket(batch)}"
        topo_part = "" if topo is None else f"|mesh={topo}"
        n_part = n if isinstance(n, str) else shape_bucket(n)
        return (f"{primitive}|op={op_name}|dtype={dtype}"
                f"|n={n_part}{batch_part}{topo_part}"
                f"|backend={backend}|platform={platform}")

    def lookup(self, key: str) -> dict | None:
        entry = self._cache.get(key)
        if entry is not None:
            self.stats["hits"] += 1
        return entry

    # -- measurement --------------------------------------------------------

    def _time(self, fn) -> float:
        out = fn()                                   # compile + warm cache
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(self.bench_repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    def benchmark(self, key: str, spec: TunableSpec, base: ki.TuningPolicy,
                  impl: Callable, args: tuple, kwargs: dict) -> dict:
        """Race the candidate ladder on the actual inputs; memoize winner."""
        self.stats["benchmarks"] += 1
        best_t, best_ov = float("inf"), {}
        for overrides in spec.candidates:
            policy = dataclasses.replace(base, **overrides)
            try:
                t = self._time(lambda: impl(*args, **kwargs, policy=policy))
            except Exception:
                continue  # candidate invalid for this shape -- skip it
            self.stats["bench_calls"] += 1
            if t < best_t:
                best_t, best_ov = t, dict(overrides)
        entry = {"overrides": best_ov, "seconds": best_t}
        if best_t != float("inf"):
            # Only memoize a real measurement: if every candidate failed
            # (e.g. a transient compile/OOM error), retry on the next call
            # instead of pinning the untuned base policy forever -- and never
            # write non-standard `Infinity` into the JSON cache.
            self._cache[key] = entry
            self._save()
        return entry


# ---------------------------------------------------------------------------
# resolve_impl hook.
# ---------------------------------------------------------------------------

_ACTIVE: Autotuner | None = None


def active() -> Autotuner | None:
    return _ACTIVE


def _all_concrete(args, kwargs) -> bool:
    return not any(isinstance(l, jax.core.Tracer)
                   for l in jax.tree.leaves((args, kwargs)))


def _hook(primitive: str, backend: str, impl: Callable) -> Callable | None:
    spec = TUNABLE.get(primitive)
    if spec is None or not backend.startswith("pallas"):
        return None  # nothing to tune: XLA fallbacks ignore the policy

    def tuned(*args, **kwargs):
        tuner = _ACTIVE
        if tuner is None or kwargs.get("policy") is not None:
            return impl(*args, **kwargs)
        keyinfo = spec.keyer(args, kwargs)
        if keyinfo is None:
            return impl(*args, **kwargs)
        key = tuner.make_key(primitive, backend, *keyinfo)
        base = ki.resolve_tuning(ki.default_policy_name(backend))
        entry = tuner.lookup(key)
        if entry is None:
            if not _all_concrete(args, kwargs):
                # Under tracing there is nothing meaningful to time; run the
                # prior policy and leave the key for a concrete call.
                return impl(*args, **kwargs)
            entry = tuner.benchmark(key, spec, base, impl, args, kwargs)
        policy = dataclasses.replace(base, **entry["overrides"])
        return impl(*args, **kwargs, policy=policy)

    return tuned


def enable(cache_path: str | None = None, **kw) -> Autotuner:
    """Install the autotuner behind every resolve_impl dispatch."""
    global _ACTIVE
    _ACTIVE = Autotuner(cache_path, **kw)
    ki.set_tuner_hook(_hook)
    return _ACTIVE


def disable():
    global _ACTIVE
    _ACTIVE = None
    ki.set_tuner_hook(None)


def maybe_enable_from_env():
    if os.environ.get("REPRO_AUTOTUNE", "") not in ("", "0"):
        enable()
