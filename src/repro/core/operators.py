"""Associative operators and semirings over arbitrary pytree element types.

This is the algebra layer of the paper's "arbitrary types and operators"
contribution.  KernelForge.jl supports any Julia Bitstype through recursive
``@generated`` decomposition into 32-bit shuffles; the JAX-native analogue is
a *pytree of arrays*: an element type is any pytree whose leaves are JAX
arrays, and an operator is any function combining two such pytrees leafwise /
structurally.  JAX tracing unrolls the structural recursion at compile time
exactly like Julia's generated functions -- zero runtime dispatch.

Every operator used by the kernels is an :class:`AssocOp`:

* ``combine(a, b)`` must be **associative** and **vectorized** (it is applied
  to whole tiles, combining along the scanned/reduced dimension while staying
  elementwise over the remaining tile dimensions).
* ``identity(like)`` materializes the identity element matching the
  shape/dtype of ``like`` (used for tile padding masks and carry init).
* ``commutative`` selects between the balanced-fold reduction tree (fast) and
  the order-preserving scan-fold (required for e.g. quaternion products or
  matrix-affine composition) inside the kernels.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def _leaf_shape_dtype(x):
    return jnp.shape(x), jnp.result_type(x)


def full_like_spec(like, value):
    """``jnp.full`` matching a concrete array *or* a ShapeDtypeStruct leaf."""
    shape, dtype = _leaf_shape_dtype(like)
    return jnp.full(shape, value, dtype=dtype)


def _min_value(dtype):
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return -jnp.inf
    if jnp.issubdtype(dtype, jnp.bool_):
        return False
    return jnp.iinfo(dtype).min


def _max_value(dtype):
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.inf
    if jnp.issubdtype(dtype, jnp.bool_):
        return True
    return jnp.iinfo(dtype).max


@dataclasses.dataclass(frozen=True)
class AssocOp:
    """An associative binary operator over pytree elements."""

    name: str
    combine: Callable[[Pytree, Pytree], Pytree]
    identity: Callable[[Pytree], Pytree]  # (pytree of shape/dtype likes) -> pytree
    commutative: bool = False

    def __call__(self, a: Pytree, b: Pytree) -> Pytree:
        return self.combine(a, b)

    def __repr__(self):  # keep kernel cache keys short
        return f"AssocOp({self.name})"


def _elementwise_identity(fill_fn):
    def identity(like):
        return jax.tree.map(lambda l: full_like_spec(l, fill_fn(_leaf_shape_dtype(l)[1])), like)

    return identity


# --------------------------------------------------------------------------
# Standard scalar/elementwise operators
# --------------------------------------------------------------------------

ADD = AssocOp(
    name="add",
    combine=lambda a, b: jax.tree.map(jnp.add, a, b),
    identity=_elementwise_identity(lambda dt: 0),
    commutative=True,
)

MUL = AssocOp(
    name="mul",
    combine=lambda a, b: jax.tree.map(jnp.multiply, a, b),
    identity=_elementwise_identity(lambda dt: 1),
    commutative=True,
)

MAX = AssocOp(
    name="max",
    combine=lambda a, b: jax.tree.map(jnp.maximum, a, b),
    identity=_elementwise_identity(_min_value),
    commutative=True,
)

MIN = AssocOp(
    name="min",
    combine=lambda a, b: jax.tree.map(jnp.minimum, a, b),
    identity=_elementwise_identity(_max_value),
    commutative=True,
)


def _logaddexp(a, b):
    return jax.tree.map(jnp.logaddexp, a, b)


LOGSUMEXP = AssocOp(
    name="logsumexp",
    combine=_logaddexp,
    identity=_elementwise_identity(lambda dt: -jnp.inf),
    commutative=True,
)

# Tropical semiring reducers (the paper's shortest-path use case).
TROPICAL_MIN = MIN   # (min, +) semiring: reduce with min, map with +
TROPICAL_MAX = MAX   # (max, +) semiring


# --------------------------------------------------------------------------
# Affine composition: the operator behind diagonal linear recurrences
#   h_t = a_t * h_{t-1} + b_t.
# Elements are pairs (a, b) representing x -> a*x + b; composition is applied
# left-to-right: (g1 . g2)(x) = g2(g1(x)).  NON-commutative.
# --------------------------------------------------------------------------


def _affine_combine(p, q):
    (a1, b1), (a2, b2) = p, q
    return (
        jax.tree.map(jnp.multiply, a2, a1),
        jax.tree.map(lambda a2_, b1_, b2_: a2_ * b1_ + b2_, a2, b1, b2),
    )


def _affine_identity(like):
    a_like, b_like = like
    return (
        jax.tree.map(lambda l: full_like_spec(l, 1), a_like),
        jax.tree.map(lambda l: full_like_spec(l, 0), b_like),
    )


AFFINE = AssocOp(
    name="affine",
    combine=_affine_combine,
    identity=_affine_identity,
    commutative=False,
)


# Max-plus affine: elements (a, b) represent m -> max(m + a, b).  This is the
# AFFINE operator over the (max, +) semiring -- the recurrence behind xLSTM's
# exponential-gating stabilizer m_t = max(log f_t + m_{t-1}, log i_t).
# NON-commutative; exercised by the xlstm-1.3b architecture via core.scan.


def _maxplus_affine_combine(p, q):
    (a1, b1), (a2, b2) = p, q
    return (
        jax.tree.map(jnp.add, a1, a2),
        jax.tree.map(lambda b1_, a2_, b2_: jnp.maximum(b1_ + a2_, b2_), b1, a2, b2),
    )


def _maxplus_affine_identity(like):
    a_like, b_like = like
    return (
        jax.tree.map(lambda l: full_like_spec(l, 0), a_like),
        jax.tree.map(lambda l: full_like_spec(l, _min_value(_leaf_shape_dtype(l)[1])), b_like),
    )


MAXPLUS_AFFINE = AssocOp(
    name="maxplus_affine",
    combine=_maxplus_affine_combine,
    identity=_maxplus_affine_identity,
    commutative=False,
)


# --------------------------------------------------------------------------
# Softmax-merge: combining partial attention results (m, l, o) where
#   m = running max of logits, l = sum of exp(logit - m), o = weighted values.
# Associative and commutative; the operator behind distributed flash-decoding.
# --------------------------------------------------------------------------


def _softmax_merge(p, q):
    (m1, l1, o1), (m2, l2, o2) = p, q
    m = jnp.maximum(m1, m2)
    # Guard exp(-inf - -inf): where both sides are empty keep weights at 0.
    w1 = jnp.where(jnp.isneginf(m1), 0.0, jnp.exp(m1 - m)).astype(l1.dtype)
    w2 = jnp.where(jnp.isneginf(m2), 0.0, jnp.exp(m2 - m)).astype(l2.dtype)
    l = l1 * w1 + l2 * w2
    o = o1 * w1[..., None] + o2 * w2[..., None] if o1.ndim == l1.ndim + 1 else o1 * w1 + o2 * w2
    return (m, l, o)


def _softmax_identity(like):
    m_like, l_like, o_like = like
    return (
        jax.tree.map(lambda l: full_like_spec(l, -jnp.inf), m_like),
        jax.tree.map(lambda l: full_like_spec(l, 0), l_like),
        jax.tree.map(lambda l: full_like_spec(l, 0), o_like),
    )


SOFTMAX_MERGE = AssocOp(
    name="softmax_merge",
    combine=_softmax_merge,
    identity=_softmax_identity,
    commutative=True,
)


# --------------------------------------------------------------------------
# Quaternion multiplication: the paper's canonical non-commutative composite
# type (a 4-field struct).  Elements are tuples (w, x, y, z) of arrays.
# --------------------------------------------------------------------------


def _quat_mul(p, q):
    w1, x1, y1, z1 = p
    w2, x2, y2, z2 = q
    return (
        w1 * w2 - x1 * x2 - y1 * y2 - z1 * z2,
        w1 * x2 + x1 * w2 + y1 * z2 - z1 * y2,
        w1 * y2 - x1 * z2 + y1 * w2 + z1 * x2,
        w1 * z2 + x1 * y2 - y1 * x2 + z1 * w2,
    )


def _quat_identity(like):
    w, x, y, z = like
    return (
        full_like_spec(w, 1),
        full_like_spec(x, 0),
        full_like_spec(y, 0),
        full_like_spec(z, 0),
    )


QUATERNION_MUL = AssocOp(
    name="quaternion_mul",
    combine=_quat_mul,
    identity=_quat_identity,
    commutative=False,
)


# --------------------------------------------------------------------------
# 2x2 matrix product under flattened (m00, m01, m10, m11) representation --
# exercises a non-commutative struct type distinct from quaternions.
# --------------------------------------------------------------------------


def _mat2_mul(p, q):
    a00, a01, a10, a11 = p
    b00, b01, b10, b11 = q
    # Row-vector convention (state @ M): compose left-to-right as p then q.
    return (
        a00 * b00 + a01 * b10,
        a00 * b01 + a01 * b11,
        a10 * b00 + a11 * b10,
        a10 * b01 + a11 * b11,
    )


def _mat2_identity(like):
    m00, m01, m10, m11 = like
    return (
        full_like_spec(m00, 1),
        full_like_spec(m01, 0),
        full_like_spec(m10, 0),
        full_like_spec(m11, 1),
    )


MAT2_MUL = AssocOp(
    name="mat2_mul",
    combine=_mat2_mul,
    identity=_mat2_identity,
    commutative=False,
)


# --------------------------------------------------------------------------
# Segmented lift: turn any AssocOp into an operator over (flag, value) pairs
# that resets at segment boundaries (Blelloch's segmented-scan construction).
# Elements are ``(flag, value)`` where a nonzero flag marks the first element
# of a segment.  The lift preserves associativity; it is never commutative
# (segment boundaries are positional), so kernels always take the
# order-preserving scan path.
# --------------------------------------------------------------------------


def segmented(op: AssocOp) -> AssocOp:
    """Lift ``op`` to the segment-resetting operator over (flag, value).

    combine((f1, v1), (f2, v2)) = (f1 | f2, v2 if f2 else op(v1, v2)):
    once the right operand starts a new segment, everything to its left is
    discarded.  Identity is (0, identity_of_op).
    """

    def combine(p, q):
        f1, v1 = p
        f2, v2 = q
        started = f2 != 0
        merged = op(v1, v2)
        v = jax.tree.map(lambda m, r: jnp.where(started, r, m), merged, v2)
        return (jnp.maximum(f1, f2), v)

    def identity(like):
        f_like, v_like = like
        return (
            jax.tree.map(lambda l: full_like_spec(l, 0), f_like),
            op.identity(v_like),
        )

    return AssocOp(
        name=f"segmented[{op.name}]",
        combine=combine,
        identity=identity,
        commutative=False,
    )


# --------------------------------------------------------------------------
# Collective folds: the multi-device analogue of the in-tile shuffle combine.
# A mesh axis is the device-level lane dimension, and folding an AssocOp
# across it is the same algebraic object as tile_reduce -- so, exactly as the
# kernels rewrite tile combines into VPU shifts, the distributed layer
# rewrites operator folds into the native collectives (psum/pmax/pmin) when
# the monoid structure allows, and falls back to an order-preserving
# all_gather + local fold otherwise.
#
# The registry *returns a descriptor* (:class:`FoldSpec`) rather than
# eagerly executing a fold: ``distributed/primitives.py`` stages every
# ``@sharded`` route as a ShardPlan (local stage -> collective stage ->
# epilogue), and the plan driver decides *when* each collective is issued
# (chunked, overlapped with the next chunk's local compute).  The
# ``collectives`` tuple names the collective ops the built fold emits, so
# the structural byte models in benchmarks/analytic.py can price the
# cross-device stage without running it.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FoldSpec:
    """Descriptor for one operator's cross-device fold.

    ``build(axis_name)`` returns the function mapping one *local* element (a
    pytree) to the fold of all devices' elements along that mesh axis --
    algebraically ``functools.reduce(op, shards-in-axis-order)``.
    ``collectives`` names the collectives the built fold emits, in issue
    order (``"psum"``/``"pmax"``/``"pmin"``/``"all_gather"``).
    """

    op_name: str
    collectives: tuple[str, ...]
    build: Callable[[str], Callable]

    @property
    def native(self) -> bool:
        """True when the fold is a native-collective rewrite (no gather)."""
        return "all_gather" not in self.collectives


_COLLECTIVE_FOLDS: dict[str, FoldSpec] = {}


def register_collective_fold(op_name: str, *, collectives: tuple[str, ...]):
    """Register a collective-form rewrite for the operator named ``op_name``.

    The decorated builder takes the mesh ``axis_name`` and returns a function
    mapping one *local* element (a pytree) to the fold of all devices'
    elements along that axis.  Rewrites must be algebraically equivalent to
    ``functools.reduce(op, shards-in-axis-order)``.  ``collectives`` declares
    the collective ops the built fold emits (metadata for the staged plan
    layer and the analytic byte models).
    """

    def deco(builder):
        _COLLECTIVE_FOLDS[op_name] = FoldSpec(
            op_name=op_name, collectives=tuple(collectives), build=builder)
        return builder

    return deco


def has_collective_rewrite(op: AssocOp) -> bool:
    """True when ``op`` folds via native collectives (no all_gather)."""
    return op.name in _COLLECTIVE_FOLDS


def _gather_fold(op: AssocOp, axis_name: str) -> Callable:
    """Portable fallback: gather every shard's element, fold in axis order.

    ``all_gather`` stacks shards along a new leading axis in axis-index
    order, so the Python fold (static extent: the mesh axis size) preserves
    device order -- non-commutative operators are safe here, exactly like
    the order-preserving scan path inside the kernels.
    """

    def fold(x):
        g = jax.tree.map(
            lambda l: jax.lax.all_gather(l, axis_name, axis=0), x)
        extent = jax.tree.leaves(g)[0].shape[0]
        out = jax.tree.map(lambda l: l[0], g)
        for i in range(1, extent):
            out = op(out, jax.tree.map(lambda l: l[i], g))
        return out

    return fold


def collective_fold_spec(op: AssocOp) -> FoldSpec:
    """The :class:`FoldSpec` describing ``op``'s cross-device fold.

    Returns the registered native-collective rewrite when the operator's
    monoid structure allows, otherwise the portable ``all_gather`` + ordered
    local fold -- always algebraically the same reduction, so callers never
    branch on the operator.  This is the descriptor form the staged
    ``@sharded`` plans consume: the caller decides when to ``build`` and
    issue the fold, not this registry.
    """
    spec = _COLLECTIVE_FOLDS.get(op.name)
    if spec is not None:
        return spec
    return FoldSpec(op_name=op.name, collectives=("all_gather",),
                    build=functools.partial(_gather_fold, op))


def collective_fold(op: AssocOp, axis_name: str) -> Callable:
    """Fold ``op`` across mesh axis ``axis_name``: local element -> total.

    Eager convenience form of :func:`collective_fold_spec` (build the fold
    for one axis immediately); kept for callers that do not stage.
    """
    return collective_fold_spec(op).build(axis_name)


@register_collective_fold("add", collectives=("psum",))
def _add_collective(axis_name):
    return lambda x: jax.tree.map(
        lambda l: jax.lax.psum(l, axis_name), x)


@register_collective_fold("max", collectives=("pmax",))
def _max_collective(axis_name):
    return lambda x: jax.tree.map(
        lambda l: jax.lax.pmax(l, axis_name), x)


@register_collective_fold("min", collectives=("pmin",))
def _min_collective(axis_name):
    return lambda x: jax.tree.map(
        lambda l: jax.lax.pmin(l, axis_name), x)


@register_collective_fold("logsumexp", collectives=("pmax", "psum"))
def _logsumexp_collective(axis_name):
    """log(psum(exp(x - pmax x))) + pmax x, guarded for all--inf shards."""

    def fold(x):
        def one(l):
            m = jax.lax.pmax(l, axis_name)
            w = jnp.where(jnp.isneginf(l), 0.0, jnp.exp(l - m)).astype(l.dtype)
            s = jax.lax.psum(w, axis_name)
            return jnp.where(jnp.isneginf(m), m, m + jnp.log(s))

        return jax.tree.map(one, x)

    return fold


@register_collective_fold("softmax_merge", collectives=("pmax", "psum", "psum"))
def _softmax_merge_collective(axis_name):
    """The distributed flash-decoding merge: m* = pmax m; w = exp(m - m*);
    l* = psum(w l); o* = psum(w o) -- SOFTMAX_MERGE's fold in collective
    form (``tests/test_sharded.py`` pins the equivalence to the operator
    fold).  The ``isneginf`` guard matches the operator's combine; finite
    mask sentinels (e.g. -1e30 with a finite m*) underflow ``exp`` to the
    same exact zero.
    """

    def fold(part):
        m, l, o = part
        m_g = jax.lax.pmax(m, axis_name)
        w = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_g)).astype(l.dtype)
        wo = w[..., None] if o.ndim == l.ndim + 1 else w
        # A zero-weight shard contributes exactly zero even when its o is
        # poisoned (NaN/inf from masked garbage rows): 0 * NaN is NaN, so the
        # product alone would leak the garbage into the psum.
        o_w = jnp.where(wo > 0, o * wo, jnp.zeros_like(o))
        l_g = jax.lax.psum(l * w, axis_name)
        o_g = jax.lax.psum(o_w, axis_name)
        return (m_g, l_g, o_g)

    return fold


# --------------------------------------------------------------------------
# Radix-sortable key transforms: order-preserving bijections from every
# supported key dtype onto unsigned integers of the same width, so the LSD
# radix sort (kernels/sort.py) only ever manipulates unsigned bit patterns.
#
# The induced total order is pinned down exactly:
#
# * unsigned ints -- numeric order (identity transform);
# * signed ints   -- numeric order (flip the sign bit);
# * floats        -- IEEE numeric order with two canonicalizations applied
#   *before* the transform: ``-0.0`` maps to ``+0.0`` (so the two zeros
#   compare equal, matching ``np.sort``), and every NaN maps to the
#   all-ones-mantissa positive NaN (so **all NaNs compare equal and sort
#   after +inf**, again matching ``np.sort``'s NaN-last order).  The float
#   transform is the classic sign-magnitude fix-up: negative values are
#   bitwise complemented, non-negative values get the sign bit set.
# --------------------------------------------------------------------------

_RADIX_UINT_FOR_WIDTH = {8: jnp.uint8, 16: jnp.uint16, 32: jnp.uint32}


def radix_key_bits(dtype) -> int:
    """Total significant bits in the sortable-transformed key."""
    dtype = jnp.dtype(dtype)
    if dtype not in {jnp.dtype(d) for d in
                     (jnp.uint8, jnp.uint16, jnp.uint32, jnp.int8, jnp.int16,
                      jnp.int32, jnp.float32, jnp.bfloat16, jnp.float16)}:
        raise TypeError(f"radix sort: unsupported key dtype {dtype}")
    return dtype.itemsize * 8


def key_to_radix_bits(keys: jax.Array) -> jax.Array:
    """Map keys onto same-width unsigned bits; ``a < b`` iff ``bits(a) < bits(b)``
    under the pinned total order documented above."""
    dtype = jnp.dtype(keys.dtype)
    width = radix_key_bits(dtype)
    udt = _RADIX_UINT_FOR_WIDTH[width]
    if jnp.issubdtype(dtype, jnp.unsignedinteger):
        return keys
    if jnp.issubdtype(dtype, jnp.signedinteger):
        sign = jnp.asarray(1 << (width - 1), udt)
        return jax.lax.bitcast_convert_type(keys, udt) ^ sign
    # Floats: canonicalize -0.0 and NaN, then sign-magnitude fix-up.
    keys = jnp.where(keys == 0, jnp.zeros_like(keys), keys)
    bits = jax.lax.bitcast_convert_type(keys, udt)
    nan_bits = jnp.asarray((1 << (width - 1)) - 1, udt)   # +NaN, max mantissa
    bits = jnp.where(jnp.isnan(keys), nan_bits, bits)
    sign = jnp.asarray(1 << (width - 1), udt)
    return jnp.where((bits & sign) != 0, ~bits, bits | sign)


def radix_bits_to_key(bits: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`key_to_radix_bits` (up to the documented float
    canonicalizations: ``-0.0`` comes back as ``+0.0`` and NaNs as the
    canonical quiet NaN)."""
    dtype = jnp.dtype(dtype)
    width = radix_key_bits(dtype)
    udt = _RADIX_UINT_FOR_WIDTH[width]
    if jnp.issubdtype(dtype, jnp.unsignedinteger):
        return bits.astype(dtype)
    sign = jnp.asarray(1 << (width - 1), udt)
    if jnp.issubdtype(dtype, jnp.signedinteger):
        return jax.lax.bitcast_convert_type(bits ^ sign, dtype)
    raw = jnp.where((bits & sign) != 0, bits ^ sign, ~bits)
    return jax.lax.bitcast_convert_type(raw, dtype)


# --------------------------------------------------------------------------
# Semirings: (map f, reduce op) pairs for generalized matvec / mapreduce.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Semiring:
    """Generalized (f, op): y = op_i f(x_i, a_i).

    ``f`` is applied elementwise to (vector element, matrix element) pairs and
    ``op`` reduces.  ``f`` may change the element type (e.g. UnitFloat8 ->
    Float32 promotion in the paper's mapreduce benchmark).
    """

    name: str
    f: Callable[[Any, Any], Pytree]
    op: AssocOp


ARITHMETIC = Semiring("arithmetic", f=lambda x, a: x * a, op=ADD)
TROPICAL_MIN_PLUS = Semiring("tropical_min_plus", f=lambda x, a: x + a, op=MIN)
TROPICAL_MAX_PLUS = Semiring("tropical_max_plus", f=lambda x, a: x + a, op=MAX)
LOG_SEMIRING = Semiring("log", f=lambda x, a: x + a, op=LOGSUMEXP)


# --------------------------------------------------------------------------
# UnitFloat8: the paper's custom 8-bit type -- values in [-1, 1] encoded as
# 256 evenly spaced uint8 levels, promoted to f32 before accumulation.
# --------------------------------------------------------------------------


def unitfloat8_encode(x: jax.Array) -> jax.Array:
    x = jnp.clip(x, -1.0, 1.0)
    return jnp.round((x + 1.0) * (255.0 / 2.0)).astype(jnp.uint8)


def unitfloat8_decode(u: jax.Array) -> jax.Array:
    return u.astype(jnp.float32) * (2.0 / 255.0) - 1.0


# --------------------------------------------------------------------------
# Quantized: blockwise-scaled (values, scales) matrices -- the "arbitrary
# types" stress test on the decode GEMV hot path.  A matrix is stored as a
# small-dtype values array plus one f32 scale per ``block`` rows per column
# (blocks tile the leading/reduction axis), so HBM traffic drops ~2-4x vs
# bf16 while the matvec/vecmat kernels dequantize per tile and accumulate
# in f32.  The pytree has exactly two leaves (values, scales) of the SAME
# rank as the plain matrix they replace, so the registry's rank validation
# and tree surgery (scatter/poison/jit) all work unchanged.
# --------------------------------------------------------------------------

# mode -> (exponent bits, mantissa bits, exponent bias, max finite value).
# e4m3 follows the "fn" convention (no inf, 448 max); e5m2 keeps 57344 as
# its largest finite.  Both are *emulated*: values are stored as uint8 bit
# patterns and decoded with integer ops + exp2, so the routes work on any
# backend/jax pin regardless of native float8 support.
FP8_FORMATS = {"fp8_e4m3": (4, 3, 7, 448.0), "fp8_e5m2": (5, 2, 15, 57344.0)}
QUANT_MODES = ("int8",) + tuple(FP8_FORMATS)


def fp8_decode(u: jax.Array, mode: str) -> jax.Array:
    """uint8 bit patterns -> f32 (sign/exponent/mantissa field decode).

    Pure integer ops + ``exp2``, so it is safe to call *inside* a Pallas
    kernel body (the dequant-in-kernel path) as well as on the host.
    """
    _, man, bias, _ = FP8_FORMATS[mode]
    b = u.astype(jnp.int32)
    sign = jnp.where(b >= 128, -1.0, 1.0).astype(jnp.float32)
    exp = (b >> man) & ((1 << (7 - man)) - 1)
    frac = (b & ((1 << man) - 1)).astype(jnp.float32) * (1.0 / (1 << man))
    # 2**(exp-bias) built as f32 bits: exact, unlike exp2 (which some
    # backends lower through exp(x*ln2) and round).
    pow2 = jax.lax.bitcast_convert_type(
        ((exp - bias + 127) << 23).astype(jnp.int32), jnp.float32)
    normal = pow2 * (1.0 + frac)
    subnormal = (2.0 ** (1 - bias)) * frac
    return sign * jnp.where(exp > 0, normal, subnormal)


def fp8_encode(x: jax.Array, mode: str) -> jax.Array:
    """f32 -> uint8 bit patterns, round-to-nearest onto the fp8 grid,
    saturating at the format's max finite value (no inf/nan encodings)."""
    _, man, bias, fmax = FP8_FORMATS[mode]
    sign = jnp.where(x < 0, jnp.uint8(0x80), jnp.uint8(0))
    a = jnp.minimum(jnp.abs(x.astype(jnp.float32)), fmax)
    mant, e = jnp.frexp(a)                 # a == mant * 2**e, mant in [.5, 1)
    E = e - 1 + bias                       # tentative biased exponent
    # Normal path: field = round((1.f - 1) * 2^man), carrying into E.
    nf = jnp.round((mant * 2.0 - 1.0) * (1 << man)).astype(jnp.int32)
    E = jnp.where(nf >= (1 << man), E + 1, E)
    nf = jnp.where(nf >= (1 << man), 0, nf)
    # Subnormal path (E <= 0): field = round(a / 2^(1-bias) * 2^man); a
    # field of 2^man is exactly the smallest normal.
    sf = jnp.round(a * (2.0 ** (bias - 1 + man))).astype(jnp.int32)
    sub = sf < (1 << man)
    bits = jnp.where(
        E <= 0,
        jnp.where(sub, sf, (1 << man)),              # 1<<man == E=1, field 0
        (jnp.minimum(E, (1 << (7 - man)) - 1) << man) | nf)
    # Saturate anything that rounded past fmax back to the max finite code.
    maxcode = _fp8_max_code(mode)
    bits = jnp.where(a >= fmax, maxcode, jnp.minimum(bits, maxcode))
    bits = jnp.where(a == 0.0, 0, bits)
    return (bits.astype(jnp.uint8) | sign).astype(jnp.uint8)


@functools.lru_cache(maxsize=None)
def _fp8_max_code(mode: str) -> int:
    """Bit pattern of the largest finite value (exponent all-usable-ones,
    mantissa at the format's top finite field).  Pure host float math --
    every grid value is exactly representable in double -- so it stays
    concrete even when ``fp8_encode`` is first reached inside a trace
    (jit / eval_shape)."""
    _, man, bias, fmax = FP8_FORMATS[mode]
    for code in range(127, -1, -1):                  # positive half suffices
        exp = code >> man
        frac = (code & ((1 << man) - 1)) / (1 << man)
        v = ((2.0 ** (exp - bias)) * (1.0 + frac) if exp > 0
             else (2.0 ** (1 - bias)) * frac)
        if v == fmax:
            return code
    raise AssertionError(f"fmax {fmax} not on the {mode} grid")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quantized:
    """Blockwise-quantized matrix operand: ``values`` is int8 (mode
    ``"int8"``) or uint8 fp8 bit patterns, ``scales`` holds one f32 per
    ``block`` rows per column -- shape ``(ceil(n/block), p)`` for an
    ``(n, p)`` matrix, ``(B, ceil(n/block), p)`` batched, i.e. the same
    rank as ``values`` so registry rank validation passes untouched.

    ``dequantize()`` is the reference semantics every kernel must match:
    ``decode(values) * scales`` with scales repeated ``block``-wise along
    the row axis.  ``error_bound()`` is the per-element dequantization
    error bound the conformance oracles integrate (kernels/ref.py).
    """

    values: jax.Array
    scales: jax.Array
    block: int = 64
    mode: str = "int8"

    def tree_flatten(self):
        return (self.values, self.scales), (self.block, self.mode)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def shape(self):
        return self.values.shape

    @property
    def dtype(self):
        # The *compute* dtype: kernels dequantize to f32 before applying f,
        # so shape/dtype probes (zero-extent guards, einsum fast paths) see
        # the matrix this object stands in for.
        return jnp.dtype(jnp.float32)

    @property
    def qtag(self) -> str:
        """Tuning-key dtype tag: distinct from the plain dtypes so cached
        block choices never leak between quantized and dense routes."""
        return f"{self.mode}q{self.block}"

    def _expanded_scales(self) -> jax.Array:
        s = self.scales
        nb, p = s.shape[-2], s.shape[-1]
        lead = s.shape[:-2]
        e = jnp.broadcast_to(s[..., :, None, :], lead + (nb, self.block, p))
        return e.reshape(lead + (nb * self.block, p))[
            ..., : self.values.shape[-2], :]

    def decoded(self) -> jax.Array:
        """values -> f32 on the quantization grid (scales NOT applied)."""
        if self.mode == "int8":
            return self.values.astype(jnp.float32)
        return fp8_decode(self.values, self.mode)

    def dequantize(self) -> jax.Array:
        return self.decoded() * self._expanded_scales()

    def error_bound(self) -> jax.Array:
        """Per-element bound on |original - dequantize()| for a matrix
        produced by :func:`quantize`: half a quantization step.  int8 steps
        are uniform (scale); fp8 steps are relative for normals plus the
        subnormal absolute step, both scaled by the block scale."""
        s = self._expanded_scales()
        if self.mode == "int8":
            return 0.5 * s
        _, man, bias, _ = FP8_FORMATS[self.mode]
        rel = jnp.abs(self.decoded()) * (2.0 ** -man)
        sub_step = 2.0 ** (1 - bias - man)
        return (0.5 * rel + 0.5 * sub_step) * s


def quantize(A: jax.Array, *, mode: str = "int8", block: int = 64) -> Quantized:
    """Blockwise-quantize ``A`` along its row (reduction) axis.

    Each ``(block, 1)`` column strip gets scale ``absmax / QMAX`` so the
    scaled values fill the representable range; encode is round-to-nearest
    (int8) or round-onto-the-fp8-grid, giving the half-step error bound
    :meth:`Quantized.error_bound` advertises.  Works on ``(n, p)`` and
    batched ``(B, n, p)`` operands.
    """
    if mode not in QUANT_MODES:
        raise ValueError(f"mode {mode!r} not in {QUANT_MODES}")
    if block < 1:
        raise ValueError(f"block must be positive, got {block}")
    A = jnp.asarray(A, jnp.float32)
    n, p = A.shape[-2], A.shape[-1]
    lead = A.shape[:-2]
    nb = -(-n // block) if n else 0
    pad = nb * block - n
    Ap = jnp.pad(A, [(0, 0)] * len(lead) + [(0, pad), (0, 0)])
    blocks = Ap.reshape(lead + (nb, block, p))
    absmax = jnp.max(jnp.abs(blocks), axis=-2)            # (..., nb, p)
    qmax = 127.0 if mode == "int8" else FP8_FORMATS[mode][3]
    scales = jnp.maximum(absmax, jnp.finfo(jnp.float32).tiny) / qmax
    scaled = Ap / jnp.repeat(scales, block, axis=-2)
    scaled = scaled[..., :n, :]
    if mode == "int8":
        values = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    else:
        values = fp8_encode(scaled, mode)
    return Quantized(values, scales, block=block, mode=mode)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVQuant:
    """Per-vector quantized KV-cache leaf (serving's ``quantize_kv=`` mode).

    ``values`` holds int8 (mode ``"int8"``) or uint8 fp8 bit patterns with
    the cached vector on the last axis; ``scales`` holds one f32 per vector
    (same shape with a trailing 1).  Unlike :class:`Quantized` -- whose
    scales tile the reduction axis of a matrix in ``block``-row strips --
    this is the cache-resident form: one scale per (token, head) vector, so
    slot scatter / ring updates address values and scales with the *same*
    index arithmetic as the unquantized leaf.  ``mode`` is static aux data,
    so it survives jit/eval_shape and the decode read can branch on it.
    """

    values: jax.Array
    scales: jax.Array
    mode: str = "int8"

    def tree_flatten(self):
        return (self.values, self.scales), (self.mode,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def shape(self):
        return self.values.shape

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        dec = (self.values.astype(jnp.float32) if self.mode == "int8"
               else fp8_decode(self.values, self.mode))
        return (dec * self.scales).astype(dtype)


def quantize_kv(x: jax.Array, mode: str = "int8") -> KVQuant:
    """Quantize cache vectors along the last axis, one scale per vector."""
    if mode not in QUANT_MODES:
        raise ValueError(f"mode {mode!r} not in {QUANT_MODES}")
    a = jnp.asarray(x, jnp.float32)
    absmax = jnp.max(jnp.abs(a), axis=-1, keepdims=True)
    qmax = 127.0 if mode == "int8" else FP8_FORMATS[mode][3]
    scales = jnp.maximum(absmax, jnp.finfo(jnp.float32).tiny) / qmax
    scaled = a / scales
    if mode == "int8":
        values = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    else:
        values = fp8_encode(scaled, mode)
    return KVQuant(values, scales, mode=mode)


STD_OPS = {
    op.name: op
    for op in [ADD, MUL, MAX, MIN, LOGSUMEXP, AFFINE, MAXPLUS_AFFINE,
               SOFTMAX_MERGE, QUATERNION_MUL, MAT2_MUL]
}

STD_SEMIRINGS = {
    s.name: s for s in [ARITHMETIC, TROPICAL_MIN_PLUS, TROPICAL_MAX_PLUS, LOG_SEMIRING]
}
