"""KernelForge-TPU core: two-layer portable primitives.

Layer 1: ``intrinsics`` -- tile combines, alignment patterns, tuning/backend
dispatch (the KernelIntrinsics.jl analogue).
Layer 2: ``primitives`` -- scan / mapreduce / semiring matvec / copy over
arbitrary operators and pytree element types (the KernelForge.jl analogue).
"""
