"""Layer 2 -- the public primitives API (the KernelForge.jl analogue).

One entry point per primitive, polymorphic over data **layout**: ``scan``,
``mapreduce``, ``matvec``/``vecmat`` (+ semiring bundles), the sort family
(``sort``, ``sort_pairs``, ``argsort``, ``top_k``), ``linear_recurrence``
and ``copy``, each taking ``layout=`` -- :class:`~repro.core.layout.Flat`
(default), :class:`~repro.core.layout.Batched` (uniform batch on a parallel
grid dimension), :class:`~repro.core.layout.Segmented` (ragged contiguous
segments of one flat stream) or :class:`~repro.core.layout.Sharded` (one
problem whose leading axis spans the devices of a mesh axis; the route
lowers to the local route per shard plus a collective fold derived from the
operator algebra).  Layout is a *value*, not a function name, so new
layouts compose with every primitive instead of multiplying the API.

All algorithms are expressed exclusively through the Layer-1 registry
(``core.intrinsics``): which (primitive, layout) routes exist, their
validation rules, zero-extent behavior and tuning recipes live in the
declarative ``PrimitiveDef`` table there; the per-backend implementations
register themselves from ``kernels/ops.py``.  No function here names a
backend, and adding a backend -- or a layout -- means adding table rows and
registrations, not touching call sites.

Usage:

    from repro.core import primitives as forge
    from repro.core import operators as alg
    from repro.core.layout import Batched, Segmented

    y = forge.scan(alg.ADD, x)                       # prefix sum
    q = forge.scan(alg.QUATERNION_MUL, (w, i, j, k)) # non-commutative pytree
    c = forge.scan(alg.ADD, probs, layout=Batched()) # (B, n): one launch
    s = forge.scan(alg.ADD, vals, layout=Segmented(offsets=offs))
    d = forge.semiring_matvec(alg.TROPICAL_MIN_PLUS, A, x)  # shortest paths

The pre-layout names (``segmented_scan``, ``batched_mapreduce``, ...) remain
as deprecation shims that forward to the polymorphic surface; each warns
once per process.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable

import jax

from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.core import tuning as _tuning
from repro.core.layout import (  # noqa: F401  (re-exported)
    FLAT, Batched, Flat, Layout, Segmented, Sharded)
from repro.kernels import ops as _ops  # noqa: F401  (registers backends)

_tuning.maybe_enable_from_env()  # REPRO_AUTOTUNE=1 turns on autotuned dispatch

Pytree = Any


# ---------------------------------------------------------------------------
# The layout-polymorphic surface: one entry point per primitive.
# ---------------------------------------------------------------------------


def copy(x: jax.Array, *, nitem: int | None = None,
         layout: Layout | None = None,
         backend: str | None = None) -> jax.Array:
    """Bandwidth-ceiling tiled copy (paper Fig. 1)."""
    return ki.dispatch("copy", layout, backend, (x,), {"nitem": nitem})


def scan(op: alg.AssocOp, xs: Pytree, *, axis: int = 0,
         inclusive: bool = True, reverse: bool = False,
         layout: Layout | None = None,
         backend: str | None = None) -> Pytree:
    """Prefix scan with any associative ``op`` (paper §V-B).

    ``op`` need not be commutative (quaternions, affine maps, 2x2 matrices);
    element types are arbitrary pytrees of arrays with matching shapes.

    * ``Flat()`` (default): one scan along ``axis`` of the leaves.
    * ``Batched()``: per-row scan along axis 1 of ``(B, n)`` leaves -- the
      batch rides a parallel grid dimension, one launch for all rows.
    * ``Segmented(flags=... | offsets=...)``: per-segment scan over the flat
      ``(n,)`` stream; the scan restarts at every boundary.
    * ``Sharded(axis, mesh=...)``: one scan whose stream spans the devices
      of a mesh axis -- local scan per shard + an exclusive cross-device
      scan of per-shard carries (order-preserving, so ``op`` need not be
      commutative).  ``mesh=None`` means the caller is already inside a
      ``shard_map`` over ``axis`` and passes its local shard.
    """
    return ki.dispatch("scan", layout, backend, (op, xs),
                       {"axis": axis, "inclusive": inclusive,
                        "reverse": reverse})


def mapreduce(f: Callable, op: alg.AssocOp, xs: Pytree, *, axis=None,
              layout: Layout | None = None,
              backend: str | None = None) -> Pytree:
    """``op``-reduction of ``f(x)`` (paper §V-A).

    * ``Flat()``: reduce everything (or one axis of a 2-D array).  ``op``
      must be commutative.
    * ``Batched()``: per-row reduction of ``(B, n)`` leaves -> ``(B,)``;
      non-commutative ops reroute through the order-preserving batched
      scan.  Length-0 rows yield ``op``'s identity.
    * ``Segmented(...)``: one output element per segment; the flag variant
      needs ``Segmented(num_segments=...)``; empty segments yield identity.
      Order-preserving (segmented scan + gather), so ``op`` need not be
      commutative.
    * ``Sharded(axis, mesh=...)``: the reduction spans the devices of a
      mesh axis (local reduce along leaf axis 0, then the operator's
      collective fold -- psum/pmax/pmin or the pmax+psum rewrites where the
      monoid allows).  The cross-device fold requires a commutative ``op``;
      the result is replicated across the axis.
    """
    return ki.dispatch("mapreduce", layout, backend, (f, op, xs),
                       {"axis": axis})


def matvec(f: Callable, op: alg.AssocOp, A: jax.Array, x: jax.Array, *,
           layout: Layout | None = None,
           backend: str | None = None) -> Pytree:
    """y[j] = op_i f(x[i], A[i, j]) over ``(n, p)`` / ``(n,)`` -- or, under
    ``Batched()``, ``y[b, j]`` over ``(B, n, p)`` / ``(B, n)`` in one
    launch (``n == 0`` yields identity rows).

    ``Sharded(axis, mesh=...)`` shards the *contraction* axis ``n`` (rows of
    ``A`` and the matching ``x`` entries) over a mesh axis -- each device
    folds its strip into a ``(p,)`` partial and the operator's collective
    fold combines them (tensor parallelism over the reduced dimension, the
    decode-GEMV split).  ``op`` must be commutative.  Uneven ``n`` keeps the
    ``n % shards`` remainder rows replicated; they are folded in last."""
    return ki.dispatch("matvec", layout, backend, (f, op, A, x), {})


def vecmat(f: Callable, op: alg.AssocOp, A: jax.Array, x: jax.Array, *,
           layout: Layout | None = None,
           backend: str | None = None) -> Pytree:
    """z[i] = op_j f(A[i, j], x[j]) -- the row-wise mirror of
    :func:`matvec`, with the same ``Batched()`` form over ``(B, n, p)`` /
    ``(B, p)`` and the same ``Sharded(axis, mesh=...)`` contraction-axis
    split (columns of ``A`` and matching ``x`` entries span the mesh axis;
    strip partials meet in the operator's collective fold)."""
    return ki.dispatch("vecmat", layout, backend, (f, op, A, x), {})


def semiring_matvec(semiring: alg.Semiring, A: jax.Array, x: jax.Array, *,
                    layout: Layout | None = None,
                    backend: str | None = None) -> Pytree:
    """Semiring-bundled :func:`matvec` (paper §V-C)."""
    return matvec(semiring.f, semiring.op, A, x, layout=layout,
                  backend=backend)


def semiring_vecmat(semiring: alg.Semiring, A: jax.Array, x: jax.Array, *,
                    layout: Layout | None = None,
                    backend: str | None = None) -> Pytree:
    """Semiring-bundled :func:`vecmat` (paper §V-C)."""
    return vecmat(semiring.f, semiring.op, A, x, layout=layout,
                  backend=backend)


def linear_recurrence(a: jax.Array, b: jax.Array,
                      h0: jax.Array | None = None, *, reverse: bool = False,
                      layout: Layout | None = None,
                      backend: str | None = None) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1 of (B, T, C) inputs.

    The model-facing specialization of ``scan`` with the AFFINE operator --
    the compute core of RG-LRU (recurrentgemma) and mLSTM inter-chunk state
    propagation (xlstm).  The ``(B, T, C)`` layout is batch-native already,
    so ``Flat()`` and ``Batched()`` share implementations; decode-hot-path
    consumers pass ``Batched()``, which is the route the autotuner keys
    with a batch bucket.  ``h0`` is an optional per-row ``(B, C)`` initial
    state.

    ``Sharded(axis, mesh=...)`` shards the *time* axis ``T`` over a mesh
    axis (sequence-parallel prefill): each device runs the local affine
    scan, per-shard ``(A, B)`` totals meet in an exclusive cross-device
    AFFINE scan, and the carry is applied locally -- ``reverse`` is not
    supported on this route.  ``h0`` must be replicated.
    """
    return ki.dispatch("linear_recurrence", layout, backend, (a, b),
                       {"h0": h0, "reverse": reverse})


def sort(keys: jax.Array, *, descending: bool = False,
         key_bits: int | None = None, layout: Layout | None = None,
         backend: str | None = None) -> jax.Array:
    """Stable LSD radix sort (CUB's flagship derived primitive, composed
    from mapreduce + exclusive scan + scatter -- see kernels/sort.py).

    Keys may be u8/u16/u32, i8/i16/i32, f32/bf16/f16.  The total order is
    numeric with ``-0.0 == +0.0`` and all NaNs equal, sorting after ``+inf``
    (ascending); float outputs are canonicalized accordingly.  ``key_bits``
    (unsigned keys only) caps the significant bits so small-range keys --
    e.g. expert ids -- pay proportionally fewer passes.  Under
    ``Segmented(...)`` every contiguous segment sorts independently, in
    place in the flat layout.
    """
    return ki.dispatch("sort", layout, backend, (keys,),
                       {"descending": descending, "key_bits": key_bits})


def sort_pairs(keys: jax.Array, values: Pytree, *, descending: bool = False,
               key_bits: int | None = None, layout: Layout | None = None,
               backend: str | None = None) -> tuple[jax.Array, Pytree]:
    """Stable key sort carrying an arbitrary pytree payload (leaves of
    leading extent ``n``) through the same permutation.  Under
    ``Sharded(axis, mesh=...)`` the stream spans a mesh axis: shard-local
    sort, then a portable splitter exchange (gathered runs merged by
    cross-run rank) leaves each shard holding its slice of the global
    stable order."""
    return ki.dispatch("sort_pairs", layout, backend, (keys, values),
                       {"descending": descending, "key_bits": key_bits})


def argsort(keys: jax.Array, *, descending: bool = False,
            key_bits: int | None = None, layout: Layout | None = None,
            backend: str | None = None) -> jax.Array:
    """The stable sorting permutation (int32) of ``keys``.  Under
    ``Segmented(...)``, position ``i`` holds the *offset inside its
    segment* of the element sorted into slot ``i``."""
    return ki.dispatch("argsort", layout, backend, (keys,),
                       {"descending": descending, "key_bits": key_bits})


def top_k(keys: jax.Array, k: int, *, largest: bool = True,
          key_bits: int | None = None, layout: Layout | None = None,
          backend: str | None = None) -> tuple[jax.Array, jax.Array]:
    """(values, indices) of the ``k`` extreme elements, extreme-first and
    tie-stable.  NaNs rank above ``+inf``, so with ``largest=True`` they
    surface first (the pinned NaN order of :func:`sort`).  Under
    ``Segmented(...)`` the result is per-segment ``(S, k)`` values and
    within-segment indices; slots past a segment's length are filled with
    the reduction identity and index ``-1`` (the flag variant needs
    ``Segmented(num_segments=...)``).  Under ``Sharded(axis, mesh=...)``
    the stream spans a mesh axis: per-shard candidates + a k-way partial
    merge yield the global (values, global indices), replicated across the
    axis."""
    return ki.dispatch("top_k", layout, backend, (keys, k),
                       {"largest": largest, "key_bits": key_bits})


# ---------------------------------------------------------------------------
# Deprecation shims: the pre-layout names.  Each forwards verbatim to the
# polymorphic surface and warns once per process.
# ---------------------------------------------------------------------------

_WARNED: set[str] = set()


def _warn_deprecated(name: str, replacement: str) -> None:
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"forge.{name} is deprecated; use {replacement}",
        DeprecationWarning, stacklevel=3)


def batched_scan(op: alg.AssocOp, xs: Pytree, *, inclusive: bool = True,
                 reverse: bool = False, backend: str | None = None) -> Pytree:
    """Deprecated: use ``scan(op, xs, layout=Batched())``."""
    _warn_deprecated("batched_scan", "scan(op, xs, layout=Batched())")
    return scan(op, xs, inclusive=inclusive, reverse=reverse,
                layout=Batched(), backend=backend)


def batched_mapreduce(f: Callable, op: alg.AssocOp, xs: Pytree, *,
                      backend: str | None = None) -> Pytree:
    """Deprecated: use ``mapreduce(f, op, xs, layout=Batched())``."""
    _warn_deprecated("batched_mapreduce",
                     "mapreduce(f, op, xs, layout=Batched())")
    return mapreduce(f, op, xs, layout=Batched(), backend=backend)


def batched_matvec(f: Callable, op: alg.AssocOp, A: jax.Array, x: jax.Array,
                   *, backend: str | None = None) -> Pytree:
    """Deprecated: use ``matvec(f, op, A, x, layout=Batched())``."""
    _warn_deprecated("batched_matvec",
                     "matvec(f, op, A, x, layout=Batched())")
    return matvec(f, op, A, x, layout=Batched(), backend=backend)


def batched_vecmat(f: Callable, op: alg.AssocOp, A: jax.Array, x: jax.Array,
                   *, backend: str | None = None) -> Pytree:
    """Deprecated: use ``vecmat(f, op, A, x, layout=Batched())``."""
    _warn_deprecated("batched_vecmat",
                     "vecmat(f, op, A, x, layout=Batched())")
    return vecmat(f, op, A, x, layout=Batched(), backend=backend)


def batched_semiring_matvec(semiring: alg.Semiring, A: jax.Array,
                            x: jax.Array, *,
                            backend: str | None = None) -> Pytree:
    """Deprecated: use ``semiring_matvec(..., layout=Batched())``."""
    _warn_deprecated("batched_semiring_matvec",
                     "semiring_matvec(semiring, A, x, layout=Batched())")
    return semiring_matvec(semiring, A, x, layout=Batched(), backend=backend)


def batched_semiring_vecmat(semiring: alg.Semiring, A: jax.Array,
                            x: jax.Array, *,
                            backend: str | None = None) -> Pytree:
    """Deprecated: use ``semiring_vecmat(..., layout=Batched())``."""
    _warn_deprecated("batched_semiring_vecmat",
                     "semiring_vecmat(semiring, A, x, layout=Batched())")
    return semiring_vecmat(semiring, A, x, layout=Batched(), backend=backend)


def batched_linear_recurrence(a: jax.Array, b: jax.Array,
                              h0: jax.Array | None = None, *,
                              reverse: bool = False,
                              backend: str | None = None) -> jax.Array:
    """Deprecated: use ``linear_recurrence(a, b, h0, layout=Batched())``."""
    _warn_deprecated("batched_linear_recurrence",
                     "linear_recurrence(a, b, h0, layout=Batched())")
    return linear_recurrence(a, b, h0, reverse=reverse, layout=Batched(),
                             backend=backend)


def segmented_scan(op: alg.AssocOp, xs: Pytree, *,
                   flags: jax.Array | None = None,
                   offsets: jax.Array | None = None, inclusive: bool = True,
                   backend: str | None = None) -> Pytree:
    """Deprecated: use ``scan(op, xs, layout=Segmented(...))``."""
    _warn_deprecated("segmented_scan",
                     "scan(op, xs, layout=Segmented(flags=... | offsets=...))")
    return scan(op, xs, inclusive=inclusive,
                layout=Segmented(flags=flags, offsets=offsets),
                backend=backend)


def segmented_mapreduce(f: Callable, op: alg.AssocOp, xs: Pytree, *,
                        flags: jax.Array | None = None,
                        offsets: jax.Array | None = None,
                        num_segments: int | None = None,
                        backend: str | None = None) -> Pytree:
    """Deprecated: use ``mapreduce(f, op, xs, layout=Segmented(...))``."""
    _warn_deprecated("segmented_mapreduce",
                     "mapreduce(f, op, xs, layout=Segmented(...))")
    return mapreduce(f, op, xs,
                     layout=Segmented(flags=flags, offsets=offsets,
                                      num_segments=num_segments),
                     backend=backend)


def segmented_sort(keys: jax.Array, *, flags: jax.Array | None = None,
                   offsets: jax.Array | None = None,
                   descending: bool = False, key_bits: int | None = None,
                   backend: str | None = None) -> jax.Array:
    """Deprecated: use ``sort(keys, layout=Segmented(...))``."""
    _warn_deprecated("segmented_sort", "sort(keys, layout=Segmented(...))")
    return sort(keys, descending=descending, key_bits=key_bits,
                layout=Segmented(flags=flags, offsets=offsets),
                backend=backend)


def segmented_sort_pairs(keys: jax.Array, values: Pytree, *,
                         flags: jax.Array | None = None,
                         offsets: jax.Array | None = None,
                         descending: bool = False,
                         key_bits: int | None = None,
                         backend: str | None = None
                         ) -> tuple[jax.Array, Pytree]:
    """Deprecated: use ``sort_pairs(keys, values, layout=Segmented(...))``."""
    _warn_deprecated("segmented_sort_pairs",
                     "sort_pairs(keys, values, layout=Segmented(...))")
    return sort_pairs(keys, values, descending=descending, key_bits=key_bits,
                      layout=Segmented(flags=flags, offsets=offsets),
                      backend=backend)


def segmented_argsort(keys: jax.Array, *, flags: jax.Array | None = None,
                      offsets: jax.Array | None = None,
                      descending: bool = False, key_bits: int | None = None,
                      backend: str | None = None) -> jax.Array:
    """Deprecated: use ``argsort(keys, layout=Segmented(...))``."""
    _warn_deprecated("segmented_argsort",
                     "argsort(keys, layout=Segmented(...))")
    return argsort(keys, descending=descending, key_bits=key_bits,
                   layout=Segmented(flags=flags, offsets=offsets),
                   backend=backend)


def segmented_top_k(keys: jax.Array, k: int, *,
                    flags: jax.Array | None = None,
                    offsets: jax.Array | None = None,
                    num_segments: int | None = None, largest: bool = True,
                    key_bits: int | None = None,
                    backend: str | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Deprecated: use ``top_k(keys, k, layout=Segmented(...))``."""
    _warn_deprecated("segmented_top_k",
                     "top_k(keys, k, layout=Segmented(...))")
    return top_k(keys, k, largest=largest, key_bits=key_bits,
                 layout=Segmented(flags=flags, offsets=offsets,
                                  num_segments=num_segments),
                 backend=backend)
