"""Layer 2 -- the public primitives API (the KernelForge.jl analogue).

``scan``, ``mapreduce``, ``semiring_matvec``/``semiring_vecmat`` and ``copy``
for arbitrary associative operators and arbitrary (pytree) element types.
All algorithms are expressed exclusively through the Layer-1 intrinsics and
the backend registry: no function here names a backend, and adding a backend
means registering implementations, not touching this file.

Usage:

    from repro.core import primitives as forge
    from repro.core import operators as alg

    y = forge.scan(alg.ADD, x)                       # prefix sum
    q = forge.scan(alg.QUATERNION_MUL, (w, i, j, k)) # non-commutative pytree
    s = forge.mapreduce(lambda v: v.astype(jnp.float32), alg.ADD, u8)
    d = forge.semiring_matvec(alg.TROPICAL_MIN_PLUS, A, x)  # shortest paths
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.core import tuning as _tuning
from repro.kernels import ops as _ops  # noqa: F401  (registers backends)

_tuning.maybe_enable_from_env()  # REPRO_AUTOTUNE=1 turns on autotuned dispatch

Pytree = Any


def copy(x: jax.Array, *, nitem: int | None = None,
         backend: str | None = None) -> jax.Array:
    """Bandwidth-ceiling tiled copy (paper Fig. 1)."""
    return ki.resolve_impl("copy", backend)(x, nitem=nitem)


def scan(op: alg.AssocOp, xs: Pytree, *, axis: int = 0,
         inclusive: bool = True, reverse: bool = False,
         backend: str | None = None) -> Pytree:
    """Single-pass prefix scan with any associative ``op`` (paper §V-B).

    ``op`` need not be commutative (quaternions, affine maps, 2x2 matrices);
    element types are arbitrary pytrees of arrays with matching shapes.
    """
    return ki.resolve_impl("scan", backend)(
        op, xs, axis=axis, inclusive=inclusive, reverse=reverse)


def mapreduce(f: Callable, op: alg.AssocOp, xs: Pytree, *, axis=None,
              backend: str | None = None) -> Pytree:
    """``op``-reduction of ``f(x)`` (paper §V-A). ``op`` must be commutative."""
    return ki.resolve_impl("mapreduce", backend)(f, op, xs, axis=axis)


def batched_scan(op: alg.AssocOp, xs: Pytree, *, inclusive: bool = True,
                 reverse: bool = False, backend: str | None = None) -> Pytree:
    """Per-row prefix scan over ``(B, n)`` pytree leaves in a single launch.

    Each of the ``B`` rows is scanned independently along axis 1 -- the
    batch rides a parallel grid dimension instead of paying one kernel
    launch (and one tuning lookup) per row.  ``op`` may be non-commutative
    and elements arbitrary pytrees, exactly as for :func:`scan`.  ``B == 0``
    and ``n == 0`` are valid and return the input unchanged.
    """
    return ki.resolve_impl("batched_scan", backend)(
        op, xs, inclusive=inclusive, reverse=reverse)


def batched_mapreduce(f: Callable, op: alg.AssocOp, xs: Pytree, *,
                      backend: str | None = None) -> Pytree:
    """Per-row ``op``-reduction of ``f(x)`` over ``(B, n)`` leaves -> ``(B,)``.

    One launch for the whole batch.  Unlike the flat :func:`mapreduce`,
    ``op`` need not be commutative: non-commutative operators take the
    order-preserving batched-scan route internally.  Rows of length 0 (and
    ``B == 0`` batches) yield ``op``'s identity per row.
    """
    return ki.resolve_impl("batched_mapreduce", backend)(f, op, xs)


def batched_matvec(f: Callable, op: alg.AssocOp, A: jax.Array, x: jax.Array,
                   *, backend: str | None = None) -> Pytree:
    """y[b, j] = op_i f(x[b, i], A[b, i, j]) over ``(B, n, p)`` / ``(B, n)``.

    The generalized matvec of :func:`matvec`, one instance per batch row,
    single launch.  ``n == 0`` yields identity rows.
    """
    return ki.resolve_impl("batched_matvec", backend)(f, op, A, x)


def batched_vecmat(f: Callable, op: alg.AssocOp, A: jax.Array, x: jax.Array,
                   *, backend: str | None = None) -> Pytree:
    """z[b, i] = op_j f(A[b, i, j], x[b, j]) over ``(B, n, p)`` / ``(B, p)``."""
    return ki.resolve_impl("batched_vecmat", backend)(f, op, A, x)


def batched_semiring_matvec(semiring: alg.Semiring, A: jax.Array,
                            x: jax.Array, *,
                            backend: str | None = None) -> Pytree:
    """Semiring-bundled form of :func:`batched_matvec`."""
    return ki.resolve_impl("batched_matvec", backend)(
        semiring.f, semiring.op, A, x)


def batched_semiring_vecmat(semiring: alg.Semiring, A: jax.Array,
                            x: jax.Array, *,
                            backend: str | None = None) -> Pytree:
    """Semiring-bundled form of :func:`batched_vecmat`."""
    return ki.resolve_impl("batched_vecmat", backend)(
        semiring.f, semiring.op, A, x)


def batched_linear_recurrence(a: jax.Array, b: jax.Array,
                              h0: jax.Array | None = None, *,
                              reverse: bool = False,
                              backend: str | None = None) -> jax.Array:
    """h[b]_t = a[b]_t * h[b]_{t-1} + b[b]_t along axis 1 of (B, T, C).

    The explicitly batch-native registration of :func:`linear_recurrence`:
    the whole ``(B, T, C)`` recurrence is one kernel launch with batch and
    channel blocks on parallel grid dimensions (channels ride the 128 lanes,
    so no cross-lane combine is ever emitted).  ``h0`` is an optional
    per-row ``(B, C)`` initial state.  This is the entry point the serving
    and recurrent-model decode paths call, and the one the autotuner keys
    with a batch bucket.
    """
    return ki.resolve_impl("batched_linear_recurrence", backend)(
        a, b, h0=h0, reverse=reverse)


def segmented_scan(op: alg.AssocOp, xs: Pytree, *, flags: jax.Array = None,
                   offsets: jax.Array = None, inclusive: bool = True,
                   backend: str | None = None) -> Pytree:
    """Per-segment prefix scan over flat ragged data (MoE groups, ragged
    decode batches).

    Segments are contiguous runs of the flat ``(n,)`` leaves, described by
    exactly one of:

    * ``flags`` -- ``(n,)`` int/bool array, nonzero marks a segment start
      (element 0 always implicitly starts a segment);
    * ``offsets`` -- ``(num_segments + 1,)`` CSR-style monotone starts with
      ``offsets[0] == 0`` and ``offsets[-1] == n``.

    ``op`` may be non-commutative and elements arbitrary pytrees, exactly as
    for :func:`scan`; the scan restarts at every boundary.
    """
    return ki.resolve_impl("segmented_scan", backend)(
        op, xs, flags=flags, offsets=offsets, inclusive=inclusive)


def segmented_mapreduce(f: Callable, op: alg.AssocOp, xs: Pytree, *,
                        flags: jax.Array = None, offsets: jax.Array = None,
                        num_segments: int | None = None,
                        backend: str | None = None) -> Pytree:
    """Per-segment op-reduction of ``f(x)`` -> one element per segment.

    With ``offsets``, the output length is ``len(offsets) - 1``; with
    ``flags``, a static ``num_segments`` is required (JAX shapes are static)
    and segments are numbered in flag order.  Empty segments yield ``op``'s
    identity.
    """
    return ki.resolve_impl("segmented_mapreduce", backend)(
        f, op, xs, flags=flags, offsets=offsets, num_segments=num_segments)


def sort(keys: jax.Array, *, descending: bool = False,
         key_bits: int | None = None, backend: str | None = None) -> jax.Array:
    """Stable LSD radix sort of a flat key array (paper follow-on: CUB's
    flagship derived primitive, composed from mapreduce + exclusive scan +
    scatter -- see kernels/sort.py).

    Keys may be u8/u16/u32, i8/i16/i32, f32/bf16/f16.  The total order is
    numeric with ``-0.0 == +0.0`` and all NaNs equal, sorting after ``+inf``
    (ascending); float outputs are canonicalized accordingly.  ``key_bits``
    (unsigned keys only) caps the significant bits so small-range keys --
    e.g. expert ids -- pay proportionally fewer passes.
    """
    return ki.resolve_impl("sort", backend)(
        keys, descending=descending, key_bits=key_bits)


def sort_pairs(keys: jax.Array, values: Pytree, *, descending: bool = False,
               key_bits: int | None = None,
               backend: str | None = None) -> tuple[jax.Array, Pytree]:
    """Stable key sort carrying an arbitrary pytree payload (leaves of
    leading extent ``n``) through the same permutation."""
    return ki.resolve_impl("sort_pairs", backend)(
        keys, values, descending=descending, key_bits=key_bits)


def argsort(keys: jax.Array, *, descending: bool = False,
            key_bits: int | None = None,
            backend: str | None = None) -> jax.Array:
    """The stable sorting permutation (int32) of ``keys``."""
    return ki.resolve_impl("argsort", backend)(
        keys, descending=descending, key_bits=key_bits)


def top_k(keys: jax.Array, k: int, *, largest: bool = True,
          key_bits: int | None = None,
          backend: str | None = None) -> tuple[jax.Array, jax.Array]:
    """(values, indices) of the ``k`` extreme elements, extreme-first and
    tie-stable.  NaNs rank above ``+inf``, so with ``largest=True`` they
    surface first (the pinned NaN order of :func:`sort`)."""
    return ki.resolve_impl("top_k", backend)(keys, k, largest=largest,
                                             key_bits=key_bits)


def segmented_sort(keys: jax.Array, *, flags: jax.Array = None,
                   offsets: jax.Array = None, descending: bool = False,
                   key_bits: int | None = None,
                   backend: str | None = None) -> jax.Array:
    """Independent stable sort of every contiguous segment, in place in the
    flat layout.  Segments use the same descriptors as
    :func:`segmented_scan` (flag array or CSR ``offsets``)."""
    return ki.resolve_impl("segmented_sort", backend)(
        keys, flags=flags, offsets=offsets, descending=descending,
        key_bits=key_bits)


def segmented_sort_pairs(keys: jax.Array, values: Pytree, *,
                         flags: jax.Array = None, offsets: jax.Array = None,
                         descending: bool = False, key_bits: int | None = None,
                         backend: str | None = None
                         ) -> tuple[jax.Array, Pytree]:
    """Per-segment :func:`sort_pairs` over the flat ragged stream."""
    return ki.resolve_impl("segmented_sort_pairs", backend)(
        keys, values, flags=flags, offsets=offsets, descending=descending,
        key_bits=key_bits)


def segmented_argsort(keys: jax.Array, *, flags: jax.Array = None,
                      offsets: jax.Array = None, descending: bool = False,
                      key_bits: int | None = None,
                      backend: str | None = None) -> jax.Array:
    """Within-segment sorting permutation: position ``i`` of the output holds
    the *offset inside its segment* of the element sorted into slot ``i``."""
    return ki.resolve_impl("segmented_argsort", backend)(
        keys, flags=flags, offsets=offsets, descending=descending,
        key_bits=key_bits)


def segmented_top_k(keys: jax.Array, k: int, *, flags: jax.Array = None,
                    offsets: jax.Array = None, num_segments: int | None = None,
                    largest: bool = True, key_bits: int | None = None,
                    backend: str | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Per-segment top-k over the flat ragged stream -> ``(S, k)`` values and
    within-segment indices, extreme-first.  Slots past a segment's length are
    filled with the reduction identity and index ``-1``; with ``flags`` a
    static ``num_segments`` is required (as for :func:`segmented_mapreduce`).
    """
    return ki.resolve_impl("segmented_top_k", backend)(
        keys, k, flags=flags, offsets=offsets, num_segments=num_segments,
        largest=largest, key_bits=key_bits)


def semiring_matvec(semiring: alg.Semiring, A: jax.Array, x: jax.Array, *,
                    backend: str | None = None) -> Pytree:
    """y[j] = op_i f(x[i], A[i, j]) for any semiring (paper §V-C)."""
    return ki.resolve_impl("matvec", backend)(semiring.f, semiring.op, A, x)


def semiring_vecmat(semiring: alg.Semiring, A: jax.Array, x: jax.Array, *,
                    backend: str | None = None) -> Pytree:
    """z[i] = op_j f(A[i, j], x[j]) for any semiring (paper §V-C)."""
    return ki.resolve_impl("vecmat", backend)(semiring.f, semiring.op, A, x)


def matvec(f: Callable, op: alg.AssocOp, A: jax.Array, x: jax.Array, *,
           backend: str | None = None) -> Pytree:
    return ki.resolve_impl("matvec", backend)(f, op, A, x)


def vecmat(f: Callable, op: alg.AssocOp, A: jax.Array, x: jax.Array, *,
           backend: str | None = None) -> Pytree:
    return ki.resolve_impl("vecmat", backend)(f, op, A, x)


def linear_recurrence(a: jax.Array, b: jax.Array, h0: jax.Array | None = None,
                      *, reverse: bool = False,
                      backend: str | None = None) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1 of (B, T, C) inputs.

    The model-facing specialization of ``scan`` with the AFFINE operator --
    the compute core of RG-LRU (recurrentgemma) and mLSTM inter-chunk state
    propagation (xlstm).  Identical implementations to
    :func:`batched_linear_recurrence` (the layout is batch-native already);
    consumers on the decode hot path call the ``batched_`` name so the
    tuner's batch-bucketed keys apply.
    """
    return ki.resolve_impl("linear_recurrence", backend)(
        a, b, h0=h0, reverse=reverse)
