"""Layout descriptors: *where the independent problems live* in the data.

The paper's thesis is that one algorithm expressed over backend-agnostic
abstractions serves arbitrary types and operators.  The same argument applies
one level up: one *entry point* per primitive serves arbitrary data layouts,
provided layout is a **value** the caller passes, not a function name.  The
four layouts of the current matrix:

* :class:`Flat` -- one problem over the whole (leading axis of the) data.
  The default; ``forge.scan(op, xs)`` reads exactly as the paper's API.
* :class:`Batched` -- a uniform grid of ``B`` independent problems riding a
  parallel kernel grid dimension: ``(B, n)`` rows for scan/mapreduce,
  ``(B, n, p)`` instances for matvec/vecmat, ``(B, T, C)`` for the linear
  recurrence.  One launch, one tuning decision, per whole batch.
* :class:`Segmented` -- a ragged concatenation of problems in one flat
  stream, boundaries carried as data: either a ``flags`` array (nonzero
  marks a segment start) or CSR ``offsets`` (``(num_segments + 1,)``
  monotone starts).  Exactly one descriptor must be given; reductions over
  the flag variant additionally need a static ``num_segments`` (JAX shapes
  are static).
* :class:`Sharded` -- one problem whose leading axis spans the devices of a
  mesh axis.  The multi-device analogue of a warp shuffle is a mesh
  collective, so the sharded routes lower to the corresponding *local*
  route per shard plus a collective fold derived from the operator algebra
  (``core.operators.collective_fold``).  With ``mesh=`` given the route
  wraps itself in ``shard_map``; with ``mesh=None`` the caller is already
  inside a ``shard_map`` over ``axis`` and passes its local shard.

Every public primitive in ``core.primitives`` takes ``layout=`` and
dispatches through the declarative ``PrimitiveDef`` registry in
``core.intrinsics``; which (primitive, layout) pairs exist, their validation
rules, zero-extent behavior and tuning recipes all live in that one table.
Adding a future layout (multi-dim, async) means adding a descriptor here
and table rows there -- not a new family of public names.
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class Layout:
    """Base class for layout descriptors.  ``kind`` keys the registry."""

    kind = "abstract"

    def describe(self) -> str:
        return f"{type(self).__name__}()"


@dataclasses.dataclass(frozen=True)
class Flat(Layout):
    """One problem over the whole data (the paper's default layout)."""

    kind = "flat"


@dataclasses.dataclass(frozen=True)
class Batched(Layout):
    """B independent problems of identical extent, batch on grid axis 0."""

    kind = "batched"


@dataclasses.dataclass(frozen=True, eq=False)
class Segmented(Layout):
    """Contiguous ragged segments of one flat stream.

    Exactly one of ``flags`` (``(n,)`` int/bool, nonzero starts a segment;
    element 0 always implicitly starts one) or ``offsets``
    (``(num_segments + 1,)`` CSR monotone starts, ``offsets[0] == 0``,
    ``offsets[-1] == n``) must be given.  ``num_segments`` is required by
    per-segment *reductions* (mapreduce, top_k) under the flag variant,
    where the output extent cannot be read off the descriptor.
    """

    kind = "segmented"
    flags: jax.Array | None = None
    offsets: jax.Array | None = None
    num_segments: int | None = None

    # eq=False suppresses the generated (field-wise) __eq__, which would
    # elementwise-compare jax arrays; descriptors compare by *identity* of
    # the flag/offset arrays instead, so two Segmented values are equal only
    # when they describe the same segmentation objects -- never a silent
    # always-True between distinct descriptors.
    def __eq__(self, other):
        if not isinstance(other, Segmented):
            return NotImplemented
        return (self.flags is other.flags and self.offsets is other.offsets
                and self.num_segments == other.num_segments)

    def __hash__(self):
        return hash((id(self.flags), id(self.offsets), self.num_segments))

    def describe(self) -> str:
        d = "flags" if self.flags is not None else (
            "offsets" if self.offsets is not None else "<no descriptor>")
        ns = f", num_segments={self.num_segments}" \
            if self.num_segments is not None else ""
        return f"Segmented({d}=...{ns})"


@dataclasses.dataclass(frozen=True, eq=False)
class Sharded(Layout):
    """One problem whose leading axis is sharded over a mesh axis.

    ``axis`` names the mesh axis the data's leading dimension spans.  Two
    calling forms:

    * ``Sharded(axis, mesh=mesh)`` -- the *global* form: arguments are
      global arrays; the route shards the leading data axis over ``axis``
      of ``mesh`` via ``shard_map`` (padding uneven remainders with the
      operator's identity / an order sentinel, sliced back off), runs the
      local route per shard, and composes shards with the collective fold.
    * ``Sharded(axis)`` (``mesh=None``) -- the *in-mesh* form: the caller
      is already inside a ``shard_map`` over ``axis`` and passes its local
      shard; only the local compute + collective fold are emitted.  This is
      the form consumers like ``distributed/collectives.py`` use.

    ``overlap`` controls the staged plan driver
    (``distributed/primitives.py``): ``True`` (default) issues each chunk's
    collective as soon as its local stage is emitted, so the collective for
    chunk *i* can proceed while chunk *i+1* computes; ``False`` emits every
    local stage before any collective -- the blocking-barrier issue order.
    Both orders run the identical per-chunk arithmetic, so they are
    bit-identical; ``overlap=False`` is the latency-hiding escape hatch,
    not a numerics switch.
    """

    kind = "sharded"
    axis: str = "model"
    mesh: object | None = None  # jax.sharding.Mesh in the global form
    overlap: bool = True        # staged-plan collective issue order

    # Mesh equality is well-defined but descriptors follow the Segmented
    # convention: compare the mesh by identity (two Sharded values are equal
    # only when they name the same axis of the same mesh object).
    def __eq__(self, other):
        if not isinstance(other, Sharded):
            return NotImplemented
        return (self.axis == other.axis and self.mesh is other.mesh
                and self.overlap == other.overlap)

    def __hash__(self):
        return hash((self.axis, id(self.mesh), self.overlap))

    def describe(self) -> str:
        m = "in-mesh" if self.mesh is None else "mesh=..."
        return f"Sharded(axis={self.axis!r}, {m})"


FLAT = Flat()


def as_layout(layout: Layout | None) -> Layout:
    """Normalize the public ``layout=`` argument (None means Flat)."""
    if layout is None:
        return FLAT
    if not isinstance(layout, Layout):
        raise TypeError(
            f"layout= must be a Layout descriptor "
            f"(Flat/Batched/Segmented/Sharded), got {layout!r}")
    return layout


def validate_descriptor(flags, offsets, *, where: str) -> None:
    """The one segment-descriptor exclusivity check (used by dispatch)."""
    if (flags is None) == (offsets is None):
        raise ValueError(
            f"{where}: pass exactly one of flags= or offsets= in "
            f"Segmented(...)")
