"""Segmented scan / mapreduce kernels for ragged workloads.

Ragged batches (variable-length decode, MoE expert grouping) are flat arrays
partitioned into contiguous segments.  The paper's single-pass scan machinery
extends to them through Blelloch's *segmented lift* (``operators.segmented``):
each element becomes a ``(flag, value)`` pair, a nonzero flag marking a
segment start, and the lifted combine discards everything left of a boundary.
The lift preserves associativity, so the entire grid-carry protocol of
``kernels/scan.py`` carries over unchanged -- the carry itself resets when a
tile containing a boundary flows through it.

Two input conventions are supported at the dispatch layer (kernels/ops.py):

* **flag array** -- ``flags[i] != 0`` marks the first element of a segment
  (position 0 is always implicitly a start);
* **offsets** -- a ``(num_segments + 1,)`` monotone array of segment starts
  with ``offsets[0] == 0`` and ``offsets[-1] == n`` (CSR-style).  Offsets are
  scattered into a flag array before the kernel; empty segments contribute no
  flags and are handled at the gather step of mapreduce.

The kernel here is the flag-array form: a single-pass segmented scan over
flat ``(n,)`` pytree leaves with arbitrary (possibly non-commutative)
operators.  Flags ride along as one extra int32 input; scanned flags are
*not* written back (they are only needed in-register), so the data movement
is ``2n + n_flags`` -- one read and one write per value element, one read per
flag.  Segmented mapreduce = segmented inclusive scan + a gather of each
segment's last element, composed in kernels/ops.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import pltpu

from repro.core import intrinsics as ki
from repro.core import operators as alg

Pytree = Any


def _tile_likes(treedef, shape, dtypes):
    return jax.tree.unflatten(
        treedef, [jax.ShapeDtypeStruct(shape, d) for d in dtypes])


def _segscan1d_kernel(op, treedef, n, rows, inclusive, n_leaves, *refs):
    """Grid-carry segmented scan over one (rows, LANES) tile per step.

    Refs: [flags] + value inputs + value outputs + [flag carry] + value
    carries.  The carry is an element of the *lifted* type: its flag half
    records whether any boundary has flowed past, which makes the lifted
    combine reset the value half automatically.
    """
    seg = alg.segmented(op)
    f_ref = refs[0]
    x_refs = refs[1:1 + n_leaves]
    o_refs = refs[1 + n_leaves:1 + 2 * n_leaves]
    cf_ref = refs[1 + 2 * n_leaves]
    cv_refs = refs[2 + 2 * n_leaves:]
    g = pl.program_id(0)
    block = rows * ki.LANES

    dtypes = [r.dtype for r in x_refs]
    ident_tile = seg.identity(
        (jax.ShapeDtypeStruct((rows, ki.LANES), jnp.int32),
         _tile_likes(treedef, (rows, ki.LANES), dtypes)))
    ident_carry = seg.identity(
        (jax.ShapeDtypeStruct((1, 1), jnp.int32),
         _tile_likes(treedef, (1, 1), dtypes)))

    @pl.when(g == 0)
    def _init():
        cf_ref[...] = ident_carry[0]
        for cr, ic in zip(cv_refs, jax.tree.leaves(ident_carry[1])):
            cr[...] = ic

    flags = f_ref[...].reshape(rows, ki.LANES)
    vals = jax.tree.unflatten(
        treedef, [xr[...].reshape(rows, ki.LANES) for xr in x_refs])

    # Masked tail: out-of-bounds positions become the lifted identity
    # (flag 0, value identity) so they cannot contaminate the carry.
    ridx = jax.lax.broadcasted_iota(jnp.int32, (rows, ki.LANES), 0)
    cidx = jax.lax.broadcasted_iota(jnp.int32, (rows, ki.LANES), 1)
    valid = (g * block + ridx * ki.LANES + cidx) < n
    flags = jnp.where(valid, flags, ident_tile[0])
    vals = jax.tree.map(
        lambda l, i: jnp.where(valid, l, i), vals, ident_tile[1])
    x = (flags, vals)

    # Block-local lifted scan, entirely in registers (same three-stage shape
    # as the flat scan: lane scan -> row-total prefix -> broadcast combine).
    lane_scan = ki.tile_scan(seg, x, axis=1)
    row_tot = ki.tile_take_last(lane_scan, axis=1)           # (rows, 1)
    row_pref = ki.tile_scan(seg, row_tot, axis=0)            # inclusive
    ident_col = seg.identity(
        (jax.ShapeDtypeStruct((rows, 1), jnp.int32),
         _tile_likes(treedef, (rows, 1), dtypes)))
    row0 = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) == 0
    row_excl = jax.tree.map(
        lambda p, i: jnp.where(row0, i, jnp.roll(p, 1, axis=0)),
        row_pref, ident_col)
    local = seg(row_excl, lane_scan)                         # broadcast over lanes

    carry = (cf_ref[...],
             jax.tree.unflatten(treedef, [cr[...] for cr in cv_refs]))
    incl = seg(carry, local)                                 # broadcast over tile

    if inclusive:
        out = incl[1]
    else:
        # exclusive[k] = inclusive[k-1] within the segment; the first element
        # of every segment gets the identity instead.  Shift the inclusive
        # values by one element (lane roll + row-boundary fixup + carry at
        # (0, 0)), then overwrite segment starts.
        incl_v = incl[1]
        prev_lane = jax.tree.map(lambda l: jnp.roll(l, 1, axis=1), incl_v)
        row_last = ki.tile_take_last(incl_v, axis=1)
        prev_row_last = jax.tree.map(
            lambda rl, c: jnp.where(row0, c, jnp.roll(rl, 1, axis=0)),
            row_last, carry[1])
        shifted = jax.tree.map(
            lambda pl_, prl: jnp.where(cidx == 0, prl, pl_),
            prev_lane, prev_row_last)
        out = jax.tree.map(
            lambda s, i: jnp.where(flags != 0, i, s),
            shifted, ident_tile[1])

    new_carry = seg(carry, ki.tile_take_last(row_pref, axis=0))
    cf_ref[...] = new_carry[0]
    for cr, nc in zip(cv_refs, jax.tree.leaves(new_carry[1])):
        cr[...] = nc
    for orf, o in zip(o_refs, jax.tree.leaves(out)):
        orf[...] = o.reshape(-1)


def segmented_scan_1d_pallas(op, xs: Pytree, flags: jax.Array, *,
                             inclusive: bool = True,
                             policy: ki.TuningPolicy | None = None,
                             interpret: bool = False) -> Pytree:
    """Single-pass segmented scan over flat ``(n,)`` pytree leaves.

    ``flags`` is an int ``(n,)`` array; nonzero entries start a new segment
    (element 0 implicitly starts one regardless).  ``op`` is any associative
    AssocOp over pytree elements; non-commutative operators are supported --
    the lifted operator is order-preserving by construction.
    """
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    leaves, treedef = jax.tree.flatten(xs)
    n = leaves[0].shape[0]
    assert all(l.shape == (n,) for l in leaves), "segmented scan: uniform leaves"
    assert flags.shape == (n,), "flags must match the scanned extent"
    flags = flags.astype(jnp.int32)
    sub = max(ki.min_tile(l.dtype)[0] for l in leaves)
    rows = policy.nitem_scan * sub
    block = rows * ki.LANES
    grid = ki.cdiv(n, block)

    kernel = functools.partial(
        _segscan1d_kernel, op, treedef, n, rows, inclusive, len(leaves))
    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda g: (g,))
                  for _ in range(1 + len(leaves))],
        out_specs=[pl.BlockSpec((block,), lambda g: (g,)) for _ in leaves],
        out_shape=[jax.ShapeDtypeStruct((n,), l.dtype) for l in leaves],
        scratch_shapes=([pltpu.VMEM((1, 1), jnp.int32)] +
                        [pltpu.VMEM((1, 1), l.dtype) for l in leaves]),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(flags, *leaves)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Segment bookkeeping shared by the dispatch wrappers (kernels/ops.py).
# ---------------------------------------------------------------------------


def offsets_to_flags(offsets: jax.Array, n: int) -> jax.Array:
    """CSR offsets -> flag array.  Empty segments leave no flag behind."""
    flags = jnp.zeros((n,), jnp.int32)
    if n == 0:
        return flags
    return flags.at[offsets[:-1]].set(1, mode="drop").at[0].set(1)


def flags_to_segment_ids(flags: jax.Array) -> jax.Array:
    """0-based contiguous segment id per element (element 0 starts seg 0)."""
    if flags.shape[0] == 0:
        return jnp.zeros((0,), jnp.int32)
    f = flags.astype(jnp.int32).at[0].set(1)
    return jnp.cumsum(f) - 1


def gather_segment_lasts(op, incl: Pytree, *,
                         offsets: jax.Array | None = None,
                         flags: jax.Array | None = None,
                         num_segments: int | None = None) -> Pytree:
    """Pick each segment's last inclusive-scan element; identity for empties.

    ``incl`` is the segmented *inclusive* scan of the mapped values; its
    element at the last index of segment ``s`` is that segment's reduction.
    """
    leaves = jax.tree.leaves(incl)
    n = leaves[0].shape[0]
    if offsets is not None:
        num_segments = offsets.shape[0] - 1
        last = offsets[1:] - 1
        empty = offsets[1:] == offsets[:-1]
        idx = jnp.clip(last, 0, n - 1)
        picked = jax.tree.map(lambda l: l[idx], incl)
        ident = op.identity(picked)
        return jax.tree.map(
            lambda p, i: jnp.where(empty, i, p), picked, ident)
    assert flags is not None and num_segments is not None, (
        "flag-variant segmented mapreduce needs num_segments")
    seg_ids = flags_to_segment_ids(flags)
    # Deterministic scatter-max finds each segment's last position; segments
    # past the flag count (or never started) keep -1 and take the identity.
    lasts = jnp.full((num_segments,), -1, jnp.int32).at[seg_ids].max(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    idx = jnp.clip(lasts, 0, n - 1)
    picked = jax.tree.map(lambda l: l[idx], incl)
    ident = op.identity(picked)
    return jax.tree.map(
        lambda p, i: jnp.where(lasts < 0, i, p), picked, ident)
