"""Single-pass mapreduce kernel (paper §V-A), TPU adaptation.

Paper: fixed grid of blocks, each thread strides the input accumulating in
registers; hierarchical register -> warp-shuffle -> shared-memory reduction;
single launch via release/acquire completion flags instead of a second
kernel.

TPU adaptation: the sequential Pallas grid *is* the strided loop -- one VMEM
accumulator tile persists across grid steps (register accumulation analogue),
each step folds ``Nitem`` aligned tiles into it elementwise, and the final
step collapses the accumulator with log-step in-register combines
(shuffle-tree analogue) and writes the scalar: one kernel launch, exactly n
element reads, O(1) writes.  ``f`` may change element type (e.g. the paper's
UnitFloat8 -> Float32 promotion), so the accumulator carries the *mapped*
dtype.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import pltpu

from repro.core import intrinsics as ki

Pytree = Any


def _mapreduce_kernel(f, op, in_treedef, out_treedef, n, rows, n_in, n_out,
                      grid_axis, *refs):
    """Strided accumulate into a persistent VMEM tile, collapse on last step.

    ``grid_axis`` names the sequential (reduction) grid dimension: 0 for the
    flat 1-D kernel, 1 for the grid-batched kernel (kernels/batched.py) whose
    leading grid dimension rides the batch in parallel.  The accumulator
    resets at step 0 of the sequential axis, which for the batched layout is
    exactly the start of each new row.
    """
    x_refs = refs[:n_in]
    o_refs = refs[n_in:n_in + n_out]
    acc_refs = refs[n_in + n_out:]
    g = pl.program_id(grid_axis)
    ng = pl.num_programs(grid_axis)
    block = rows * ki.LANES

    acc_like = jax.tree.unflatten(
        out_treedef,
        [jax.ShapeDtypeStruct((rows, ki.LANES), r.dtype) for r in acc_refs])
    ident_acc = op.identity(acc_like)

    @pl.when(g == 0)
    def _init():
        for ar, ia in zip(acc_refs, jax.tree.leaves(ident_acc)):
            ar[...] = ia

    x = jax.tree.unflatten(
        in_treedef, [xr[...].reshape(rows, ki.LANES) for xr in x_refs])
    vals = f(x)

    ridx = jax.lax.broadcasted_iota(jnp.int32, (rows, ki.LANES), 0)
    cidx = jax.lax.broadcasted_iota(jnp.int32, (rows, ki.LANES), 1)
    valid = (g * block + ridx * ki.LANES + cidx) < n
    vals = jax.tree.map(lambda v, i: jnp.where(valid, v, i), vals, ident_acc)

    acc = jax.tree.unflatten(out_treedef, [ar[...] for ar in acc_refs])
    acc = op(acc, vals)
    for ar, a in zip(acc_refs, jax.tree.leaves(acc)):
        ar[...] = a

    @pl.when(g == ng - 1)
    def _finalize():
        r = ki.tile_reduce(op, acc, axis=0)
        r = ki.tile_reduce(op, r, axis=1)
        for orf, l in zip(o_refs, jax.tree.leaves(r)):
            orf[...] = l


def mapreduce_1d_pallas(f, op, xs: Pytree, *,
                        policy: ki.TuningPolicy | None = None,
                        interpret: bool = False) -> Pytree:
    """op-reduce of ``f(x)`` over flat ``(n,)`` pytree leaves -> scalar pytree.

    ``op`` must be commutative (paper §II-C requires commutativity for
    mapreduce; scan relaxes it).
    """
    assert op.commutative, "mapreduce requires a commutative operator (use scan)"
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    in_leaves, in_treedef = jax.tree.flatten(xs)
    n = in_leaves[0].shape[0]
    assert all(l.shape == (n,) for l in in_leaves)

    # Trace f on abstract tiles to learn the mapped (output) structure.
    out_shape_tree = jax.eval_shape(
        f, jax.tree.unflatten(
            in_treedef,
            [jax.ShapeDtypeStruct((1, ki.LANES), l.dtype) for l in in_leaves]))
    out_leaves, out_treedef = jax.tree.flatten(out_shape_tree)

    sub = max(ki.min_tile(l.dtype)[0] for l in in_leaves)
    rows = policy.nitem_reduce * sub
    block = rows * ki.LANES
    grid = ki.cdiv(n, block)

    kernel = functools.partial(
        _mapreduce_kernel, f, op, in_treedef, out_treedef, n, rows,
        len(in_leaves), len(out_leaves), 0)
    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda g: (g,)) for _ in in_leaves],
        out_specs=[pl.BlockSpec((1, 1), lambda g: (0, 0)) for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((1, 1), l.dtype) for l in out_leaves],
        scratch_shapes=[pltpu.VMEM((rows, ki.LANES), l.dtype)
                        for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*in_leaves)
    return jax.tree.unflatten(out_treedef, [o[0, 0] for o in out])
