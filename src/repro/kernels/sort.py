"""LSD radix sort / argsort / top-k built *only* on the portable primitives.

CUB's flagship derived primitive is radix sort, and the paper's thesis is
that vendor-competitive primitives compose from portable scan/mapreduce
machinery.  This module is that composition made explicit: every pass of the
least-significant-digit radix sort is

1. **bit-extract map** -- the current digit of every key
   (``operators.key_to_radix_bits`` first maps any supported key dtype onto
   order-preserving unsigned bits, so passes only ever see unsigned ints);
2. **per-digit histogram** via ``mapreduce`` over the one-hot digit matrix;
3. **digit base offsets** via an exclusive ``scan`` of the histogram;
4. **within-bucket stable rank** via an exclusive ``scan`` down the one-hot
   matrix with the ``2^digit_bits`` buckets riding the 128 lanes (the
   ``(1, n, R)`` channel layout -- no cross-lane combine);
5. **scatter** of keys (and any payload pytree) to
   ``base[digit] + rank``.

No step hardcodes a backend: every scan/mapreduce goes through the Layer-1
dispatch registry keyed by the ``backend=`` parameter, so the same code runs
on ``pallas-tpu``, ``pallas-gpu``, ``pallas-interpret`` and ``xla`` -- the
scatter/gather glue between passes is dispatch-layer XLA, exactly like the
segmented primitives' descriptor bookkeeping.

The segmented variants reuse the PR 1 descriptors (flag array / CSR
offsets): a segmented sort is two chained stable radix phases -- key digits
first, then segment-id digits -- which is sort-by-``(segment, key)`` without
ever packing the pair into one word (so u32 keys plus any segment count
compose).  Because segments are contiguous and the sort is stable, the
output layout (segment boundaries) is identical to the input layout.

Total order (pinned in ``operators.key_to_radix_bits``): unsigned/signed
ints numerically; floats numerically with ``-0.0 == +0.0`` and **all NaNs
equal, sorting after +inf** (NaN-last ascending, NaN-first for
``descending``/``largest`` -- the ``np.sort`` convention).  Ties preserve
input order (LSD radix is stable).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.kernels import segmented as seg_k

Pytree = Any


def _resolve_policy(policy, backend):
    if policy is not None:
        return policy
    return ki.resolve_tuning(ki.default_policy_name(backend))


def _full_mask(kb: int, dtype) -> jax.Array:
    return jnp.asarray((1 << kb) - 1, dtype)


def _key_bits_for(keys, key_bits):
    """Validate/resolve the significant-bit hint (unsigned keys only)."""
    width = alg.radix_key_bits(keys.dtype)
    if key_bits is None:
        return width
    if not jnp.issubdtype(keys.dtype, jnp.unsignedinteger):
        raise ValueError(
            "key_bits= is only meaningful for unsigned integer keys (signed "
            "and float transforms touch the high bits)")
    if not 0 < key_bits <= width:
        raise ValueError(f"key_bits must be in (0, {width}], got {key_bits}")
    return key_bits


# ---------------------------------------------------------------------------
# The radix pass: histogram (mapreduce) + offsets (scan) + rank (scan) +
# scatter, all through the backend registry.
# ---------------------------------------------------------------------------


def _radix_pass(bits, payloads, shift, digit_bits, backend, policy):
    n = bits.shape[0]
    n_buckets = 1 << digit_bits
    scan = ki.resolve_impl("scan@flat", backend)
    mapreduce = ki.resolve_impl("mapreduce@flat", backend)

    digit = jnp.right_shift(bits, jnp.asarray(shift, bits.dtype))
    digit = (digit & _full_mask(digit_bits, bits.dtype)).astype(jnp.int32)
    onehot = (digit[:, None] ==
              jnp.arange(n_buckets, dtype=jnp.int32)[None, :]).astype(jnp.int32)

    # Within-bucket stable rank: exclusive +scan along the element axis,
    # buckets on the lanes ((1, n, R) channel layout).
    rank = scan(alg.ADD, onehot[None], axis=1, inclusive=False,
                policy=policy)[0]
    # Per-digit histogram and its exclusive scan = each bucket's base offset.
    hist = mapreduce(lambda v: v, alg.ADD, onehot, axis=0, policy=policy)
    base = scan(alg.ADD, hist, inclusive=False, policy=policy)

    dest = base[digit] + jnp.take_along_axis(rank, digit[:, None], axis=1)[:, 0]
    out_bits = jnp.zeros_like(bits).at[dest].set(bits, unique_indices=True)
    out_payloads = tuple(
        jnp.zeros_like(p).at[dest].set(p, unique_indices=True)
        for p in payloads)
    return out_bits, out_payloads


def _radix_passes(bits, payloads, key_bits, digit_bits, backend, policy):
    shift = 0
    while shift < key_bits:
        d = min(digit_bits, key_bits - shift)
        bits, payloads = _radix_pass(bits, payloads, shift, d, backend, policy)
        shift += d
    return bits, payloads


def radix_pass_count(key_bits: int, digit_bits: int) -> int:
    """Number of scatter passes an LSD sort of ``key_bits``-bit keys makes."""
    return ki.cdiv(key_bits, digit_bits)


def _to_bits(keys, kb, descending):
    bits = alg.key_to_radix_bits(keys)
    if descending:
        # Complement reverses the unsigned order; mask back to the
        # significant bits so high bits stay outside the sorted digits.
        bits = jnp.invert(bits) & _full_mask(kb, bits.dtype)
    return bits


def _from_bits(bits, dtype, kb, descending):
    if descending:
        bits = jnp.invert(bits) & _full_mask(kb, bits.dtype)
    return alg.radix_bits_to_key(bits, dtype)


# ---------------------------------------------------------------------------
# Flat sorts.
# ---------------------------------------------------------------------------


@ki.sub_backend_alias
def sort_radix(keys, *, descending=False, key_bits=None, backend="xla",
               policy=None):
    """Stable LSD radix sort of a flat key array (keys only: 2n/pass)."""
    policy = _resolve_policy(policy, backend)
    kb = _key_bits_for(keys, key_bits)
    if keys.shape[0] == 0:
        return keys
    bits = _to_bits(keys, kb, descending)
    bits, _ = _radix_passes(bits, (), kb, policy.sort_digit_bits,
                            backend, policy)
    return _from_bits(bits, keys.dtype, kb, descending)


@ki.sub_backend_alias
def sort_pairs_radix(keys, values, *, descending=False, key_bits=None,
                     backend="xla", policy=None):
    """Stable key sort carrying an arbitrary pytree payload along."""
    policy = _resolve_policy(policy, backend)
    kb = _key_bits_for(keys, key_bits)
    leaves, treedef = jax.tree.flatten(values)
    n = keys.shape[0]
    if any(l.shape[0] != n for l in leaves):
        raise ValueError(
            "sort_pairs: every payload leaf needs leading extent "
            f"{n}, got {[l.shape for l in leaves]}")
    if n == 0:
        return keys, values
    bits = _to_bits(keys, kb, descending)
    bits, leaves = _radix_passes(bits, tuple(leaves), kb,
                                 policy.sort_digit_bits, backend, policy)
    return (_from_bits(bits, keys.dtype, kb, descending),
            jax.tree.unflatten(treedef, list(leaves)))


@ki.sub_backend_alias
def argsort_radix(keys, *, descending=False, key_bits=None,
                  backend="xla", policy=None):
    """Stable sorting permutation (int32), via an index payload."""
    n = keys.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    _, perm = sort_pairs_radix(keys, iota, descending=descending,
                               key_bits=key_bits, backend=backend,
                               policy=policy)
    return perm


@ki.sub_backend_alias
def top_k_radix(keys, k, *, largest=True, key_bits=None, backend="xla",
                policy=None):
    """(values, indices) of the k extreme elements, sorted, ties stable."""
    n = keys.shape[0]
    if not 0 <= k <= n:
        raise ValueError(f"top_k: need 0 <= k <= n, got k={k}, n={n}")
    policy = _resolve_policy(policy, backend)
    kb = _key_bits_for(keys, key_bits)
    if k == 0:
        return keys[:0], jnp.zeros((0,), jnp.int32)
    bits = _to_bits(keys, kb, largest)
    iota = jnp.arange(n, dtype=jnp.int32)
    bits, (idx,) = _radix_passes(bits, (iota,), kb, policy.sort_digit_bits,
                                 backend, policy)
    return _from_bits(bits[:k], keys.dtype, kb, largest), idx[:k]


# ---------------------------------------------------------------------------
# Segmented variants (PR 1 descriptors: flag array / CSR offsets).  The
# descriptor-exclusivity and num_segments checks live in the registry's
# dispatch pipeline (core/intrinsics.py), which is the only caller of these
# registered compositions.
# ---------------------------------------------------------------------------


def _segment_ids_and_starts(n, flags, offsets, backend, policy):
    """(seg_ids, start_per_elem, seg_bits): contiguous-run bookkeeping.

    ``seg_ids`` are monotone run ids (offsets-declared empty segments do not
    shift them -- only the relative order matters for the sort phase);
    ``start_per_elem[i]`` is the flat index where element i's run begins,
    computed as a running MAX scan of flagged positions -- primitive reuse,
    not a parallel codepath.
    """
    scan = ki.resolve_impl("scan@flat", backend)
    if offsets is not None:
        f = seg_k.offsets_to_flags(offsets, n)
        s_bound = int(offsets.shape[0]) - 1
    else:
        f = flags.astype(jnp.int32)
        s_bound = n  # static bound: at most one segment per element
    seg_ids = seg_k.flags_to_segment_ids(f)
    iota = jnp.arange(n, dtype=jnp.int32)
    flagged = jnp.where((f != 0) | (iota == 0), iota, -1)
    starts = scan(alg.MAX, flagged, policy=policy)
    seg_bits = max(int(s_bound - 1).bit_length(), 0) if s_bound > 1 else 0
    return seg_ids, starts, seg_bits


def _segmented_sort_core(keys, payload_leaves, *, flags, offsets, descending,
                         key_bits, backend, policy, carry_starts=False):
    """Two stable phases: key digits, then segment-id digits.

    With ``carry_starts`` each element's run-start index rides along as one
    extra int32 payload (argsort / top_k need it to localize indices).
    """
    policy = _resolve_policy(policy, backend)
    kb = _key_bits_for(keys, key_bits)
    n = keys.shape[0]
    if n == 0:
        return keys, tuple(payload_leaves), jnp.zeros((0,), jnp.int32)
    seg_ids, starts, seg_bits = _segment_ids_and_starts(
        n, flags, offsets, backend, policy)
    bits = _to_bits(keys, kb, descending)
    extra = (starts,) if carry_starts else ()
    carried = (seg_ids.astype(jnp.uint32),) + extra + tuple(payload_leaves)
    bits, carried = _radix_passes(bits, carried, kb, policy.sort_digit_bits,
                                  backend, policy)
    payload = (bits,) + tuple(carried[1:])
    if seg_bits > 0:
        _, payload = _radix_passes(
            carried[0], payload, seg_bits, policy.sort_digit_bits,
            backend, policy)
    if carry_starts:
        bits, starts, leaves = payload[0], payload[1], tuple(payload[2:])
    else:
        bits, leaves, starts = payload[0], tuple(payload[1:]), None
    return _from_bits(bits, keys.dtype, kb, descending), leaves, starts


@ki.sub_backend_alias
def segmented_sort_radix(keys, *, flags=None, offsets=None, descending=False,
                         key_bits=None, backend="xla", policy=None):
    """Independent stable sort of every contiguous segment (layout kept)."""
    out, _, _ = _segmented_sort_core(
        keys, (), flags=flags, offsets=offsets, descending=descending,
        key_bits=key_bits, backend=backend, policy=policy)
    return out


@ki.sub_backend_alias
def segmented_sort_pairs_radix(keys, values, *, flags=None, offsets=None,
                               descending=False, key_bits=None,
                               backend="xla", policy=None):
    leaves, treedef = jax.tree.flatten(values)
    n = keys.shape[0]
    if any(l.shape[0] != n for l in leaves):
        raise ValueError(
            "segmented_sort_pairs: every payload leaf needs leading extent "
            f"{n}, got {[l.shape for l in leaves]}")
    out, out_leaves, _ = _segmented_sort_core(
        keys, tuple(leaves), flags=flags, offsets=offsets,
        descending=descending, key_bits=key_bits, backend=backend,
        policy=policy)
    return out, jax.tree.unflatten(treedef, list(out_leaves))


@ki.sub_backend_alias
def segmented_argsort_radix(keys, *, flags=None, offsets=None,
                            descending=False, key_bits=None,
                            backend="xla", policy=None):
    """Within-segment sorting permutation: out[i] is the *offset inside its
    segment* of the element placed at flat position i."""
    n = keys.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    _, (perm,), starts = _segmented_sort_core(
        keys, (iota,), flags=flags, offsets=offsets, descending=descending,
        key_bits=key_bits, backend=backend, policy=policy,
        carry_starts=True)
    # The sorted stream keeps the input's segment layout, and each element's
    # run start rode along through both phases -- so within-segment position
    # is just the carried global index minus the carried run start.
    return perm - starts


@ki.sub_backend_alias
def segmented_top_k_radix(keys, k, *, flags=None, offsets=None,
                          num_segments=None, largest=True, key_bits=None,
                          backend="xla", policy=None):
    """Per-segment (values, indices): ``(S, k)`` each, extreme-first.

    ``indices`` are within-segment offsets into the original layout; slots
    past a segment's length are filled with the reduction identity
    (``-inf``/dtype-min for ``largest``, ``+inf``/dtype-max otherwise) and
    index ``-1``.  With ``flags``, a static ``num_segments`` is required
    (trailing never-started segments come back entirely filled).
    """
    policy = _resolve_policy(policy, backend)
    if k < 0:
        raise ValueError(f"top_k: k must be >= 0, got {k}")
    n = keys.shape[0]
    scan = ki.resolve_impl("scan@flat", backend)
    if offsets is not None:
        num_segments = int(offsets.shape[0]) - 1
        offs = offsets.astype(jnp.int32)
    else:
        seg_ids = seg_k.flags_to_segment_ids(flags.astype(jnp.int32))
        counts = jnp.zeros((num_segments,), jnp.int32).at[seg_ids].add(
            1, mode="drop")
        csum = scan(alg.ADD, counts, policy=policy)
        offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), csum])
    counts = offs[1:] - offs[:-1]

    fill = alg.full_like_spec(
        jax.ShapeDtypeStruct((num_segments, k), keys.dtype),
        alg._min_value(keys.dtype) if largest else alg._max_value(keys.dtype))
    if n == 0 or k == 0:
        return fill, jnp.full((num_segments, k), -1, jnp.int32)

    iota = jnp.arange(n, dtype=jnp.int32)
    sorted_keys, (perm,), starts = _segmented_sort_core(
        keys, (iota,), flags=flags, offsets=offsets, descending=largest,
        key_bits=key_bits, backend=backend, policy=policy,
        carry_starts=True)
    within = perm - starts

    pos = offs[:-1, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    valid = jnp.arange(k, dtype=jnp.int32)[None, :] < counts[:, None]
    safe = jnp.clip(pos, 0, n - 1)
    vals = jnp.where(valid, sorted_keys[safe], fill)
    idx = jnp.where(valid, within[safe], -1)
    return vals, idx
