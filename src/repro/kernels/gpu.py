"""pallas-gpu kernel bodies: the paper's GPU lowering of the primitives.

These are the Merrill–Garland-style GPU forms of the kernel families, built
on the ``"gpu"`` intrinsics flavor (identity-padded ``shfl_up`` combines,
``memory_fence`` ordered visibility, ``vec_width`` float4-style transaction
hints) instead of the TPU tile machinery:

* :func:`scan_flat_gpu` / :func:`scan_batched_gpu` -- **single-pass
  decoupled-lookback scan** (paper §V-B): every block scans its tile in
  registers, publishes its inclusive prefix through a release fence, and
  combines its predecessor's published prefix -- exactly-once reads and
  writes (~2n bytes), no multi-pass partials round trip.  Cross-block state
  (per-block prefix + status flag) lives in extra kernel *outputs* rather
  than scratch, because on a GPU the lookback mailbox is global memory; the
  chained single-probe form used here is exact wherever grid steps execute
  in order (the Pallas interpreter, and sequential-grid lowerings), and the
  fence marks the seam where a hardware Triton/Mosaic-GPU lowering inserts
  the acquire spin on the same mailbox.  Until that acquire spin exists the
  scan kernels **refuse to compile** for real hardware (parallel grid
  blocks would race the probe) -- see ``HARDWARE_LOOKBACK_READY`` below;
  the registered routes fall back to xla on a GPU platform instead.
* :func:`mapreduce_flat_gpu` / :func:`mapreduce_batched_gpu` -- grid-strided
  block reduction to a per-block partials array, folded with the same
  flavored combine outside the kernel (paper §V-A's two-phase form).
* :func:`matvec_gpu` / :func:`vecmat_gpu` (+ batched) -- strip-mined
  semiring GEMV in the same two-phase partials form: each reduction grid
  step writes its own identity-masked ``tile_reduce`` partial (no block
  ever revisits an output), and the strip partials fold with the flavored
  combine outside the kernel -- well-defined on parallel grids.
* :func:`copy_gpu` -- bandwidth-ceiling tiled copy.

Block sizes come from the shared tuning ladder: a block covers
``gpu_threads x nitem x vec_width(dtype)`` elements, so the existing
``nitem_*`` ladders race real GPU knobs with no new tuning keys.  When no
GPU platform is attached (``interpret=None`` auto-detection) the same
bodies run under the Pallas interpreter -- CI's ``gpu-interpret`` job.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.kernels import matvec as matvec_k
from repro.kernels.pallas_compat import gpu_compiler_params, pl

Pytree = Any


def _auto_interpret(interpret: bool | None) -> bool:
    """pallas-gpu compiles on a GPU platform and interprets elsewhere."""
    if interpret is None:
        return jax.default_backend() not in ki._GPU_PLATFORMS
    return interpret


# The chained single-probe lookback in the scan kernels is exact only when
# grid steps execute in order (the Pallas interpreter; sequential-grid
# lowerings).  Triton/Mosaic-GPU run grid programs as parallel blocks with
# no cross-block ordering or visibility guarantee, so compiling the current
# form would silently fall back to the operator identity whenever a
# predecessor has not published yet -- wrong results, not an error.  Until
# an acquire-spin lookback lands for the hardware lowering the scan kernels
# refuse to compile (below), and the registered pallas-gpu scan routes
# (kernels/ops.py) dispatch to the portable xla implementation on a real
# GPU platform, so the racy path cannot be reached by default.
HARDWARE_LOOKBACK_READY = False


def _require_lookback(interpret: bool, what: str) -> None:
    if not interpret and not HARDWARE_LOOKBACK_READY:
        raise NotImplementedError(
            f"pallas-gpu {what}: the single-probe decoupled lookback is "
            "exact only under in-order grids; the parallel Triton/"
            "Mosaic-GPU lowering needs an acquire-spin lookback that is "
            "not implemented yet.  Pass interpret=True (validation) or "
            "use the xla backend on GPU hardware.")


def _policy(policy: ki.TuningPolicy | None) -> ki.TuningPolicy:
    return policy or ki.resolve_tuning(ki.default_policy_name("pallas-gpu"))


def _cparams(policy: ki.TuningPolicy, interpret: bool):
    if interpret:
        return None
    return gpu_compiler_params(
        num_warps=max(1, policy.gpu_threads // ki.WARP))


def _likes(treedef, shape, dtypes):
    return jax.tree.unflatten(
        treedef, [jax.ShapeDtypeStruct(shape, d) for d in dtypes])


def _mask(valid, x, ident):
    return jax.tree.map(lambda l, i: jnp.where(valid, l, i), x, ident)


def _vec_block(policy, nitem, dtypes) -> int:
    """threads x items-per-thread x vectorized width (narrowest leaf)."""
    vw = min(ki.vec_width(d) for d in dtypes)
    return policy.gpu_threads * nitem * vw


# ---------------------------------------------------------------------------
# Single-pass decoupled-lookback scan
# ---------------------------------------------------------------------------


def _scan_kernel(op, treedef, n, block, inclusive, batched, n_leaves, *refs):
    """One block of the lookback scan.

    ``part``/``stat`` are full-extent mailbox refs (every grid step maps the
    whole array): block ``g`` publishes its inclusive prefix to ``part[g]``
    *through the release fence* before raising ``stat[g]``, and acquires its
    predecessor's prefix with a single ordered probe of ``stat[g-1]`` --
    exact under in-order grids; a hardware lowering spins on the same flag.
    """
    x_refs = refs[:n_leaves]
    o_refs = refs[n_leaves:2 * n_leaves]
    part_refs = refs[2 * n_leaves:3 * n_leaves]
    stat_ref = refs[3 * n_leaves]
    g = pl.program_id(1 if batched else 0)

    dtypes = [r.dtype for r in x_refs]
    x = jax.tree.unflatten(
        treedef, [r[...].reshape(block) for r in x_refs])
    ident = op.identity(_likes(treedef, (block,), dtypes))
    idx = jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    x = _mask(g * block + idx < n, x, ident)

    # Register-resident block scan: log-step identity-padded shuffles.
    local = ki.tile_scan(op, x, axis=0, flavor="gpu")

    # Lookback (chained form): one ordered probe of the predecessor.
    gm1 = jnp.maximum(g - 1, 0)
    if batched:
        ready = stat_ref[0, gm1]
        pred = jax.tree.unflatten(treedef, [pr[0, gm1] for pr in part_refs])
    else:
        ready = stat_ref[gm1]
        pred = jax.tree.unflatten(treedef, [pr[gm1] for pr in part_refs])
    live = (g > 0) & (ready > 0)
    ident1 = op.identity(_likes(treedef, (1,), dtypes))
    carry = jax.tree.map(
        lambda p, i: jnp.where(live, p.reshape(1), i), pred, ident1)

    incl = op(carry, local)                       # (1,) broadcast over block
    if inclusive:
        out = incl
    else:
        out = jax.tree.map(
            lambda c, l: jnp.concatenate([c, l[:-1]]), carry, incl)

    # Release: the published prefix must be visible before the flag.
    tot = ki.tile_take_last(incl, axis=0)
    pub, flag = ki.memory_fence((tot, jnp.int32(1)), flavor="gpu")
    for pr, t in zip(part_refs, jax.tree.leaves(pub)):
        if batched:
            pr[0, g] = t[0]
        else:
            pr[g] = t[0]
    if batched:
        stat_ref[0, g] = flag
    else:
        stat_ref[g] = flag

    for o_ref, l in zip(o_refs, jax.tree.leaves(out)):
        o_ref[...] = l.reshape(o_ref.shape)


def scan_flat_gpu(op, xs: Pytree, *, inclusive: bool = True,
                  policy: ki.TuningPolicy | None = None,
                  interpret: bool | None = None) -> Pytree:
    """Single-pass scan over flat ``(n,)`` pytree leaves (lookback form)."""
    interpret = _auto_interpret(interpret)
    _require_lookback(interpret, "scan_flat")
    policy = _policy(policy)
    leaves, treedef = jax.tree.flatten(xs)
    n = leaves[0].shape[0]
    assert all(l.shape == (n,) for l in leaves), "gpu scan: uniform leaves"
    block = _vec_block(policy, policy.nitem_scan, [l.dtype for l in leaves])
    nb = ki.cdiv(n, block)

    kernel = functools.partial(
        _scan_kernel, op, treedef, n, block, inclusive, False, len(leaves))
    outs = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda g: (g,)) for _ in leaves],
        out_specs=(
            [pl.BlockSpec((block,), lambda g: (g,)) for _ in leaves]
            + [pl.BlockSpec((nb,), lambda g: (0,)) for _ in leaves]
            + [pl.BlockSpec((nb,), lambda g: (0,))]),
        out_shape=(
            [jax.ShapeDtypeStruct((n,), l.dtype) for l in leaves]
            + [jax.ShapeDtypeStruct((nb,), l.dtype) for l in leaves]
            + [jax.ShapeDtypeStruct((nb,), jnp.int32)]),
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(*leaves)
    return jax.tree.unflatten(treedef, outs[:len(leaves)])


def scan_batched_gpu(op, xs: Pytree, *, inclusive: bool = True,
                     policy: ki.TuningPolicy | None = None,
                     interpret: bool | None = None) -> Pytree:
    """Per-row lookback scan along axis 1 of ``(B, n)`` pytree leaves.

    The batch rides the leading (outer) grid dimension, so each row's block
    sequence is in order and carries its own mailbox row ``part[b, :]``.
    """
    interpret = _auto_interpret(interpret)
    _require_lookback(interpret, "scan_batched")
    policy = _policy(policy)
    leaves, treedef = jax.tree.flatten(xs)
    B, n = leaves[0].shape
    assert all(l.shape == (B, n) for l in leaves), "gpu scan: uniform leaves"
    block = _vec_block(policy, policy.nitem_scan, [l.dtype for l in leaves])
    nb = ki.cdiv(n, block)

    kernel = functools.partial(
        _scan_kernel, op, treedef, n, block, inclusive, True, len(leaves))
    outs = pl.pallas_call(
        kernel,
        grid=(B, nb),
        in_specs=[pl.BlockSpec((1, block), lambda b, g: (b, g))
                  for _ in leaves],
        out_specs=(
            [pl.BlockSpec((1, block), lambda b, g: (b, g)) for _ in leaves]
            + [pl.BlockSpec((1, nb), lambda b, g: (b, 0)) for _ in leaves]
            + [pl.BlockSpec((1, nb), lambda b, g: (b, 0))]),
        out_shape=(
            [jax.ShapeDtypeStruct((B, n), l.dtype) for l in leaves]
            + [jax.ShapeDtypeStruct((B, nb), l.dtype) for l in leaves]
            + [jax.ShapeDtypeStruct((B, nb), jnp.int32)]),
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(*leaves)
    return jax.tree.unflatten(treedef, outs[:len(leaves)])


# ---------------------------------------------------------------------------
# Two-phase mapreduce: per-block partials kernel + flavored fold
# ---------------------------------------------------------------------------


def _partials_kernel(f, op, in_treedef, out_treedef, n, block, batched,
                     n_in, *refs):
    x_refs = refs[:n_in]
    o_refs = refs[n_in:]
    g = pl.program_id(1 if batched else 0)

    xs = jax.tree.unflatten(
        in_treedef, [r[...].reshape(block) for r in x_refs])
    vals = f(xs)
    out_dtypes = [r.dtype for r in o_refs]
    ident = op.identity(_likes(out_treedef, (block,), out_dtypes))
    idx = jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    vals = _mask(g * block + idx < n, vals, ident)

    part = ki.tile_reduce(op, vals, axis=0, flavor="gpu")     # (1,)
    for o_ref, p in zip(o_refs, jax.tree.leaves(part)):
        o_ref[...] = p.reshape(o_ref.shape)


def _out_struct_map(f, in_treedef, in_leaves):
    probe = jax.eval_shape(
        f, jax.tree.unflatten(
            in_treedef,
            [jax.ShapeDtypeStruct((1,), l.dtype) for l in in_leaves]))
    return jax.tree.flatten(probe)


def mapreduce_flat_gpu(f, op, xs: Pytree, *,
                       policy: ki.TuningPolicy | None = None,
                       interpret: bool | None = None) -> Pytree:
    """op-reduce of ``f(x)`` over flat ``(n,)`` leaves -> scalar pytree."""
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    in_leaves, in_treedef = jax.tree.flatten(xs)
    n = in_leaves[0].shape[0]
    out_leaves, out_treedef = _out_struct_map(f, in_treedef, in_leaves)
    block = _vec_block(policy, policy.nitem_reduce,
                       [l.dtype for l in in_leaves])
    nb = ki.cdiv(n, block)

    kernel = functools.partial(
        _partials_kernel, f, op, in_treedef, out_treedef, n, block, False,
        len(in_leaves))
    parts = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda g: (g,)) for _ in in_leaves],
        out_specs=[pl.BlockSpec((1,), lambda g: (g,)) for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((nb,), l.dtype) for l in out_leaves],
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(*in_leaves)
    folded = ki.tile_reduce(
        op, jax.tree.unflatten(out_treedef, list(parts)), axis=0,
        flavor="gpu")
    return jax.tree.map(lambda l: l[0], folded)


def mapreduce_batched_gpu(f, op, xs: Pytree, *,
                          policy: ki.TuningPolicy | None = None,
                          interpret: bool | None = None) -> Pytree:
    """Per-row op-reduce of ``f(x)`` over ``(B, n)`` leaves -> ``(B,)``."""
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    in_leaves, in_treedef = jax.tree.flatten(xs)
    B, n = in_leaves[0].shape
    out_leaves, out_treedef = _out_struct_map(f, in_treedef, in_leaves)
    block = _vec_block(policy, policy.nitem_reduce,
                       [l.dtype for l in in_leaves])
    nb = ki.cdiv(n, block)

    kernel = functools.partial(
        _partials_kernel, f, op, in_treedef, out_treedef, n, block, True,
        len(in_leaves))
    parts = pl.pallas_call(
        kernel,
        grid=(B, nb),
        in_specs=[pl.BlockSpec((1, block), lambda b, g: (b, g))
                  for _ in in_leaves],
        out_specs=[pl.BlockSpec((1, 1), lambda b, g: (b, g))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((B, nb), l.dtype)
                   for l in out_leaves],
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(*in_leaves)
    folded = ki.tile_reduce(
        op, jax.tree.unflatten(out_treedef, list(parts)), axis=1,
        flavor="gpu")
    return jax.tree.map(lambda l: l[:, 0], folded)


# ---------------------------------------------------------------------------
# Semiring matvec / vecmat: two-phase partials form.  Each reduction grid
# step writes its own identity-masked tile_reduce partial -- no output
# block is ever revisited, so (unlike an output-accumulator form) the
# kernels are exact when grid steps run as parallel blocks -- and the strip
# partials fold with the same flavored combine outside the kernel, exactly
# like mapreduce.
# ---------------------------------------------------------------------------


def _mv_blocks(policy, dtype, rows_knob, cols_knob):
    rows = rows_knob * ki.WARP
    cols = max(cols_knob * ki.vec_width(dtype), 1)
    return rows, cols


def _out_struct_mv(f, lhs_dtype, rhs_dtype):
    probe = jax.eval_shape(
        f, jax.ShapeDtypeStruct((1, 1), lhs_dtype),
        jax.ShapeDtypeStruct((1, 1), rhs_dtype))
    return jax.tree.flatten(probe)


def _matvec_kernel(f, op, out_treedef, n, rows, cols, batched, *refs):
    """One partial of y[j] = op_i f(x[i], A[i, j]) per (row-strip, j) block.

    Grid step ``ig`` owns row ``ig`` of the partials output, so parallel
    blocks never share an output block; the caller folds the strip
    partials outside the kernel.
    """
    A_ref, x_ref = refs[0], refs[1]
    o_refs = refs[2:]
    ig = pl.program_id(2 if batched else 1)

    A = A_ref[...].reshape(rows, cols)
    x = x_ref[...].reshape(rows)
    vals = f(x[:, None], A)
    out_dtypes = [r.dtype for r in o_refs]
    ident = op.identity(_likes(out_treedef, (rows, cols), out_dtypes))
    ridx = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 0)
    vals = _mask(ig * rows + ridx < n, vals, ident)
    red = ki.tile_reduce(op, vals, axis=0, flavor="gpu")      # (1, cols)
    for o_ref, r in zip(o_refs, jax.tree.leaves(red)):
        o_ref[...] = r.reshape(o_ref.shape)


def matvec_gpu(f, op, A, x, *, policy: ki.TuningPolicy | None = None,
               interpret: bool | None = None):
    if isinstance(A, alg.Quantized):
        return matvec_quantized_gpu(f, op, A, x, policy=policy,
                                    interpret=interpret)
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    n, p = A.shape
    rows, cols = _mv_blocks(policy, A.dtype, policy.matvec_rows,
                            policy.matvec_cols)
    out_leaves, out_treedef = _out_struct_mv(f, x.dtype, A.dtype)
    nbi = ki.cdiv(n, rows)
    kernel = functools.partial(
        _matvec_kernel, f, op, out_treedef, n, rows, cols, False)
    parts = pl.pallas_call(
        kernel,
        grid=(ki.cdiv(p, cols), nbi),
        in_specs=[pl.BlockSpec((rows, cols), lambda j, i: (i, j)),
                  pl.BlockSpec((rows,), lambda j, i: (i,))],
        out_specs=[pl.BlockSpec((1, cols), lambda j, i: (i, j))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((nbi, p), l.dtype)
                   for l in out_leaves],
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(A, x)
    folded = ki.tile_reduce(
        op, jax.tree.unflatten(out_treedef, list(parts)), axis=0,
        flavor="gpu")
    return jax.tree.map(lambda l: l[0], folded)


def batched_matvec_gpu(f, op, A, x, *, policy: ki.TuningPolicy | None = None,
                       interpret: bool | None = None):
    if isinstance(A, alg.Quantized):
        return batched_matvec_quantized_gpu(f, op, A, x, policy=policy,
                                            interpret=interpret)
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    B, n, p = A.shape
    rows, cols = _mv_blocks(policy, A.dtype, policy.matvec_rows,
                            policy.matvec_cols)
    out_leaves, out_treedef = _out_struct_mv(f, x.dtype, A.dtype)
    nbi = ki.cdiv(n, rows)
    kernel = functools.partial(
        _matvec_kernel, f, op, out_treedef, n, rows, cols, True)
    parts = pl.pallas_call(
        kernel,
        grid=(B, ki.cdiv(p, cols), nbi),
        in_specs=[pl.BlockSpec((1, rows, cols), lambda b, j, i: (b, i, j)),
                  pl.BlockSpec((1, rows), lambda b, j, i: (b, i))],
        out_specs=[pl.BlockSpec((1, 1, cols), lambda b, j, i: (b, i, j))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((B, nbi, p), l.dtype)
                   for l in out_leaves],
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(A, x)
    folded = ki.tile_reduce(
        op, jax.tree.unflatten(out_treedef, list(parts)), axis=1,
        flavor="gpu")
    return jax.tree.map(lambda l: l[:, 0], folded)


def _vecmat_kernel(f, op, out_treedef, p, rows, cols, batched, *refs):
    """One partial of z[i] = op_j f(A[i, j], x[j]) per (i, col-strip) block.

    Grid step ``jg`` owns row ``jg`` of the partials output, so parallel
    blocks never share an output block; the caller folds the strip
    partials outside the kernel.
    """
    A_ref, x_ref = refs[0], refs[1]
    o_refs = refs[2:]
    jg = pl.program_id(2 if batched else 1)

    A = A_ref[...].reshape(rows, cols)
    x = x_ref[...].reshape(cols)
    vals = f(A, x[None, :])
    out_dtypes = [r.dtype for r in o_refs]
    ident = op.identity(_likes(out_treedef, (rows, cols), out_dtypes))
    cidx = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1)
    vals = _mask(jg * cols + cidx < p, vals, ident)
    red = ki.tile_reduce(op, vals, axis=1, flavor="gpu")      # (rows, 1)
    for o_ref, r in zip(o_refs, jax.tree.leaves(red)):
        o_ref[...] = r.reshape(o_ref.shape)


def vecmat_gpu(f, op, A, x, *, policy: ki.TuningPolicy | None = None,
               interpret: bool | None = None):
    if isinstance(A, alg.Quantized):
        return vecmat_quantized_gpu(f, op, A, x, policy=policy,
                                    interpret=interpret)
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    n, p = A.shape
    rows, cols = _mv_blocks(policy, A.dtype, policy.vecmat_rows,
                            policy.vecmat_cols)
    out_leaves, out_treedef = _out_struct_mv(f, A.dtype, x.dtype)
    nbj = ki.cdiv(p, cols)
    kernel = functools.partial(
        _vecmat_kernel, f, op, out_treedef, p, rows, cols, False)
    parts = pl.pallas_call(
        kernel,
        grid=(ki.cdiv(n, rows), nbj),
        in_specs=[pl.BlockSpec((rows, cols), lambda i, j: (i, j)),
                  pl.BlockSpec((cols,), lambda i, j: (j,))],
        out_specs=[pl.BlockSpec((1, rows), lambda i, j: (j, i))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((nbj, n), l.dtype)
                   for l in out_leaves],
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(A, x)
    folded = ki.tile_reduce(
        op, jax.tree.unflatten(out_treedef, list(parts)), axis=0,
        flavor="gpu")
    return jax.tree.map(lambda l: l[0], folded)


def batched_vecmat_gpu(f, op, A, x, *, policy: ki.TuningPolicy | None = None,
                       interpret: bool | None = None):
    if isinstance(A, alg.Quantized):
        return batched_vecmat_quantized_gpu(f, op, A, x, policy=policy,
                                            interpret=interpret)
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    B, n, p = A.shape
    rows, cols = _mv_blocks(policy, A.dtype, policy.vecmat_rows,
                            policy.vecmat_cols)
    out_leaves, out_treedef = _out_struct_mv(f, A.dtype, x.dtype)
    nbj = ki.cdiv(p, cols)
    kernel = functools.partial(
        _vecmat_kernel, f, op, out_treedef, p, rows, cols, True)
    parts = pl.pallas_call(
        kernel,
        grid=(B, ki.cdiv(n, rows), nbj),
        in_specs=[pl.BlockSpec((1, rows, cols), lambda b, i, j: (b, i, j)),
                  pl.BlockSpec((1, cols), lambda b, i, j: (b, j))],
        out_specs=[pl.BlockSpec((1, 1, rows), lambda b, i, j: (b, j, i))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((B, nbj, n), l.dtype)
                   for l in out_leaves],
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(A, x)
    folded = ki.tile_reduce(
        op, jax.tree.unflatten(out_treedef, list(parts)), axis=1,
        flavor="gpu")
    return jax.tree.map(lambda l: l[:, 0], folded)


# ---------------------------------------------------------------------------
# Quantized-operand matvec / vecmat: the same two-phase partials form over a
# ``Quantized`` (values, scales) matrix.  Each strip loads int8/fp8 value
# tiles plus the per-(block, column) scale rows covering them, dequantizes in
# registers (f32), and proceeds exactly like the dense kernels -- the HBM
# traffic for A drops to ~1 byte/element + scales.  The row strip is rounded
# to a multiple of ``q.block`` so every strip owns whole scale rows.
# ---------------------------------------------------------------------------


def _q_rows(rows: int, qblock: int) -> int:
    """Round the row-strip extent up so it covers whole scale blocks."""
    return math.lcm(rows, qblock)


def _matvec_q_kernel_gpu(f, op, out_treedef, n, rows, cols, qblock, qmode,
                         batched, *refs):
    v_ref, s_ref, x_ref = refs[0], refs[1], refs[2]
    o_refs = refs[3:]
    ig = pl.program_id(2 if batched else 1)

    A = matvec_k._dequant_tile(
        v_ref[...].reshape(rows, cols),
        s_ref[...].reshape(rows // qblock, cols), qblock, qmode)
    x = x_ref[...].reshape(rows)
    vals = f(x[:, None], A)
    out_dtypes = [r.dtype for r in o_refs]
    ident = op.identity(_likes(out_treedef, (rows, cols), out_dtypes))
    ridx = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 0)
    vals = _mask(ig * rows + ridx < n, vals, ident)
    red = ki.tile_reduce(op, vals, axis=0, flavor="gpu")      # (1, cols)
    for o_ref, r in zip(o_refs, jax.tree.leaves(red)):
        o_ref[...] = r.reshape(o_ref.shape)


def matvec_quantized_gpu(f, op, q, x, *,
                         policy: ki.TuningPolicy | None = None,
                         interpret: bool | None = None):
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    n, p = q.shape
    rows, cols = _mv_blocks(policy, q.dtype, policy.matvec_rows,
                            policy.matvec_cols)
    rows = _q_rows(rows, q.block)
    rpb = rows // q.block
    out_leaves, out_treedef = _out_struct_mv(f, x.dtype, jnp.float32)
    nbi = ki.cdiv(n, rows)
    kernel = functools.partial(
        _matvec_q_kernel_gpu, f, op, out_treedef, n, rows, cols, q.block,
        q.mode, False)
    parts = pl.pallas_call(
        kernel,
        grid=(ki.cdiv(p, cols), nbi),
        in_specs=[pl.BlockSpec((rows, cols), lambda j, i: (i, j)),
                  pl.BlockSpec((rpb, cols), lambda j, i: (i, j)),
                  pl.BlockSpec((rows,), lambda j, i: (i,))],
        out_specs=[pl.BlockSpec((1, cols), lambda j, i: (i, j))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((nbi, p), l.dtype)
                   for l in out_leaves],
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(q.values, q.scales, x)
    folded = ki.tile_reduce(
        op, jax.tree.unflatten(out_treedef, list(parts)), axis=0,
        flavor="gpu")
    return jax.tree.map(lambda l: l[0], folded)


def batched_matvec_quantized_gpu(f, op, q, x, *,
                                 policy: ki.TuningPolicy | None = None,
                                 interpret: bool | None = None):
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    B, n, p = q.shape
    rows, cols = _mv_blocks(policy, q.dtype, policy.matvec_rows,
                            policy.matvec_cols)
    rows = _q_rows(rows, q.block)
    rpb = rows // q.block
    out_leaves, out_treedef = _out_struct_mv(f, x.dtype, jnp.float32)
    nbi = ki.cdiv(n, rows)
    kernel = functools.partial(
        _matvec_q_kernel_gpu, f, op, out_treedef, n, rows, cols, q.block,
        q.mode, True)
    parts = pl.pallas_call(
        kernel,
        grid=(B, ki.cdiv(p, cols), nbi),
        in_specs=[pl.BlockSpec((1, rows, cols), lambda b, j, i: (b, i, j)),
                  pl.BlockSpec((1, rpb, cols), lambda b, j, i: (b, i, j)),
                  pl.BlockSpec((1, rows), lambda b, j, i: (b, i))],
        out_specs=[pl.BlockSpec((1, 1, cols), lambda b, j, i: (b, i, j))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((B, nbi, p), l.dtype)
                   for l in out_leaves],
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(q.values, q.scales, x)
    folded = ki.tile_reduce(
        op, jax.tree.unflatten(out_treedef, list(parts)), axis=1,
        flavor="gpu")
    return jax.tree.map(lambda l: l[:, 0], folded)


def _vecmat_q_kernel_gpu(f, op, out_treedef, p, rows, cols, qblock, qmode,
                         batched, *refs):
    v_ref, s_ref, x_ref = refs[0], refs[1], refs[2]
    o_refs = refs[3:]
    jg = pl.program_id(2 if batched else 1)

    A = matvec_k._dequant_tile(
        v_ref[...].reshape(rows, cols),
        s_ref[...].reshape(rows // qblock, cols), qblock, qmode)
    x = x_ref[...].reshape(cols)
    vals = f(A, x[None, :])
    out_dtypes = [r.dtype for r in o_refs]
    ident = op.identity(_likes(out_treedef, (rows, cols), out_dtypes))
    cidx = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1)
    vals = _mask(jg * cols + cidx < p, vals, ident)
    red = ki.tile_reduce(op, vals, axis=1, flavor="gpu")      # (rows, 1)
    for o_ref, r in zip(o_refs, jax.tree.leaves(red)):
        o_ref[...] = r.reshape(o_ref.shape)


def vecmat_quantized_gpu(f, op, q, x, *,
                         policy: ki.TuningPolicy | None = None,
                         interpret: bool | None = None):
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    n, p = q.shape
    rows, cols = _mv_blocks(policy, q.dtype, policy.vecmat_rows,
                            policy.vecmat_cols)
    rows = _q_rows(rows, q.block)
    rpb = rows // q.block
    out_leaves, out_treedef = _out_struct_mv(f, jnp.float32, x.dtype)
    nbj = ki.cdiv(p, cols)
    kernel = functools.partial(
        _vecmat_q_kernel_gpu, f, op, out_treedef, p, rows, cols, q.block,
        q.mode, False)
    parts = pl.pallas_call(
        kernel,
        grid=(ki.cdiv(n, rows), nbj),
        in_specs=[pl.BlockSpec((rows, cols), lambda i, j: (i, j)),
                  pl.BlockSpec((rpb, cols), lambda i, j: (i, j)),
                  pl.BlockSpec((cols,), lambda i, j: (j,))],
        out_specs=[pl.BlockSpec((1, rows), lambda i, j: (j, i))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((nbj, n), l.dtype)
                   for l in out_leaves],
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(q.values, q.scales, x)
    folded = ki.tile_reduce(
        op, jax.tree.unflatten(out_treedef, list(parts)), axis=0,
        flavor="gpu")
    return jax.tree.map(lambda l: l[0], folded)


def batched_vecmat_quantized_gpu(f, op, q, x, *,
                                 policy: ki.TuningPolicy | None = None,
                                 interpret: bool | None = None):
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    B, n, p = q.shape
    rows, cols = _mv_blocks(policy, q.dtype, policy.vecmat_rows,
                            policy.vecmat_cols)
    rows = _q_rows(rows, q.block)
    rpb = rows // q.block
    out_leaves, out_treedef = _out_struct_mv(f, jnp.float32, x.dtype)
    nbj = ki.cdiv(p, cols)
    kernel = functools.partial(
        _vecmat_q_kernel_gpu, f, op, out_treedef, p, rows, cols, q.block,
        q.mode, True)
    parts = pl.pallas_call(
        kernel,
        grid=(B, ki.cdiv(n, rows), nbj),
        in_specs=[pl.BlockSpec((1, rows, cols), lambda b, i, j: (b, i, j)),
                  pl.BlockSpec((1, rpb, cols), lambda b, i, j: (b, i, j)),
                  pl.BlockSpec((1, cols), lambda b, i, j: (b, j))],
        out_specs=[pl.BlockSpec((1, 1, rows), lambda b, i, j: (b, j, i))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((B, nbj, n), l.dtype)
                   for l in out_leaves],
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(q.values, q.scales, x)
    folded = ki.tile_reduce(
        op, jax.tree.unflatten(out_treedef, list(parts)), axis=1,
        flavor="gpu")
    return jax.tree.map(lambda l: l[:, 0], folded)


# ---------------------------------------------------------------------------
# Bandwidth-ceiling copy
# ---------------------------------------------------------------------------


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def copy_gpu(x, *, nitem: int | None = None,
             policy: ki.TuningPolicy | None = None,
             interpret: bool | None = None):
    interpret = _auto_interpret(interpret)
    policy = _policy(policy)
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = policy.gpu_threads * (nitem or policy.nitem_copy) \
        * ki.vec_width(x.dtype)
    out = pl.pallas_call(
        _copy_kernel,
        grid=(ki.cdiv(n, block),),
        in_specs=[pl.BlockSpec((block,), lambda g: (g,))],
        out_specs=pl.BlockSpec((block,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        compiler_params=_cparams(policy, interpret),
        interpret=interpret,
    )(flat)
    return out.reshape(x.shape)
