"""Vectorized copy kernel (paper Fig. 1): the practical bandwidth ceiling.

Each grid step moves ``Nitem`` aligned (sublane, 128) tiles HBM->VMEM->HBM.
The ``nitem`` parameter is the paper's items-per-thread: larger blocks
amortize grid overhead until VMEM pressure wins -- the benchmark sweeps it
exactly like Fig. 1 sweeps 1/4/8 items.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import pltpu

from repro.core import intrinsics as ki


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def copy_pallas(x: jax.Array, *, nitem: int | None = None,
                policy: ki.TuningPolicy | None = None,
                interpret: bool = False) -> jax.Array:
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    nitem = nitem or policy.nitem_copy
    n = x.shape[0]
    sub = ki.min_tile(x.dtype)[0]
    block = nitem * sub * ki.LANES
    grid = ki.cdiv(n, block)
    return pl.pallas_call(
        _copy_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda g: (g,))],
        out_specs=pl.BlockSpec((block,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
