"""jit-ready wrappers + backend registration for every kernel.

This module is the "package extension" of the two-layer design: it registers
each (primitive, layout) route's implementations with the Layer-1 registry
(``core.intrinsics``) under four backends:

* ``pallas-tpu``       -- the Pallas kernels, compiled by Mosaic (TARGET);
* ``pallas-gpu``       -- the GPU kernel bodies (kernels/gpu.py):
                          decoupled-lookback scan, two-phase (partials)
                          mapreduce and semiring matvec/vecmat -- compiled
                          by Triton/Mosaic-GPU on a GPU platform,
                          interpreted elsewhere (the kernels auto-detect).
                          The scan routes dispatch to xla on real hardware
                          until the acquire-spin lookback lands (the
                          single-probe form is exact only on in-order
                          grids; see _gpu_lookback_unavailable);
* ``pallas-interpret`` -- the TPU kernel bodies executed in Python on CPU
                          (correctness validation of the TPU path);
* ``xla``              -- portable pure-XLA fallbacks (used by the CPU
                          dry-run; also the baseline the benchmarks compare
                          bytes-moved against).

Registration is table-driven: ``IMPLS`` below maps every route key
(``"scan@batched"``) to its per-backend implementations, and the module
asserts at import time that the table covers exactly the routes declared in
the ``PrimitiveDef`` registry -- adding a route without implementations (or
an implementation without a registry row) is an import error, not a latent
dispatch failure.  Validation, zero-extent guards and non-commutative
rerouting live in the registry's dispatch pipeline, so the wrappers here
only ever see well-formed, non-empty problems through the public API.

The algorithmic layer (``core.primitives``) never names a backend.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.distributed import primitives as dist_k
from repro.kernels import batched as batched_k
from repro.kernels import copy as copy_k
from repro.kernels import gpu as gpu_k
from repro.kernels import mapreduce as mapreduce_k
from repro.kernels import matvec as matvec_k
from repro.kernels import ref
from repro.kernels import scan as scan_k
from repro.kernels import segmented as seg_k
from repro.kernels import sort as sort_k

Pytree = Any


# ---------------------------------------------------------------------------
# copy
# ---------------------------------------------------------------------------


def _copy_xla(x, *, nitem=None, policy=None):
    return jnp.copy(x)


# ---------------------------------------------------------------------------
# scan@flat
# ---------------------------------------------------------------------------


def _scan_pallas(op, xs, *, axis=0, inclusive=True, reverse=False,
                 interpret=False, policy=None):
    leaves = jax.tree.leaves(xs)
    ndim = leaves[0].ndim
    if ndim == 1:
        if reverse:
            xs = jax.tree.map(lambda l: jnp.flip(l, 0), xs)
        out = scan_k.scan_1d_pallas(op, xs, inclusive=inclusive,
                                    policy=policy, interpret=interpret)
        if reverse:
            out = jax.tree.map(lambda l: jnp.flip(l, 0), out)
        return out
    if ndim == 3 and axis == 1:
        return scan_k.scan_channel_pallas(
            op, xs, inclusive=inclusive, reverse=reverse, policy=policy,
            interpret=interpret)
    # Other layouts: normalize to (B, T, C) via moveaxis (metadata-only when
    # already contiguous along the scan axis).
    if ndim == 2:
        xs3 = jax.tree.map(lambda l: jnp.moveaxis(l, axis, 1)[:, :, None], xs)
        out = scan_k.scan_channel_pallas(
            op, xs3, inclusive=inclusive, reverse=reverse, policy=policy,
            interpret=interpret)
        return jax.tree.map(lambda l: jnp.moveaxis(l[:, :, 0], 1, axis), out)
    # >=3D general axis: flatten around the scan axis.
    def to3(l):
        l = jnp.moveaxis(l, axis, 1)
        lead = l.shape[0]
        t = l.shape[1]
        rest = int(np_prod(l.shape[2:])) if l.ndim > 2 else 1
        return l.reshape(lead, t, rest), l.shape

    shapes = [to3(l)[1] for l in leaves]
    xs3 = jax.tree.map(lambda l: to3(l)[0], xs)
    out = scan_k.scan_channel_pallas(
        op, xs3, inclusive=inclusive, reverse=reverse, policy=policy,
        interpret=interpret)
    outs = [l.reshape(s) for l, s in zip(jax.tree.leaves(out), shapes)]
    outs = [jnp.moveaxis(l, 1, axis) for l in outs]
    return jax.tree.unflatten(jax.tree.structure(xs), outs)


def np_prod(t):
    r = 1
    for v in t:
        r *= int(v)
    return r


def _scan_xla(op, xs, *, axis=0, inclusive=True, reverse=False, policy=None):
    return ref.ref_scan(op, xs, axis=axis, inclusive=inclusive, reverse=reverse)


# ---------------------------------------------------------------------------
# pallas-gpu wrappers: shape normalization onto the flat/batched lookback
# kernels (kernels/gpu.py).  The GPU kernels scan along the minor axis, so
# every other layout is moveaxis-normalized to (lead, n).
# ---------------------------------------------------------------------------


def _gpu_lookback_unavailable(interpret):
    """True when the lookback scan would have to *compile* for real GPU
    hardware, where the single-probe form races (kernels/gpu.py): the
    registered scan routes take the portable xla path instead, so the racy
    lowering is unreachable by default."""
    return (not gpu_k._auto_interpret(interpret)
            and not gpu_k.HARDWARE_LOOKBACK_READY)


def _scan_gpu(op, xs, *, axis=0, inclusive=True, reverse=False,
              interpret=None, policy=None):
    if _gpu_lookback_unavailable(interpret):
        return _scan_xla(op, xs, axis=axis, inclusive=inclusive,
                         reverse=reverse, policy=policy)
    leaves = jax.tree.leaves(xs)
    ndim = leaves[0].ndim
    if reverse:
        xs = jax.tree.map(lambda l: jnp.flip(l, axis), xs)
    if ndim == 1:
        out = gpu_k.scan_flat_gpu(op, xs, inclusive=inclusive,
                                  policy=policy, interpret=interpret)
    else:
        def to2(l):
            l2 = jnp.moveaxis(l, axis, -1)
            return l2.reshape(-1, l2.shape[-1]), l2.shape

        shapes = [to2(l)[1] for l in leaves]
        xs2 = jax.tree.map(lambda l: to2(l)[0], xs)
        out2 = gpu_k.scan_batched_gpu(op, xs2, inclusive=inclusive,
                                      policy=policy, interpret=interpret)
        outs = [jnp.moveaxis(l.reshape(s), -1, axis)
                for l, s in zip(jax.tree.leaves(out2), shapes)]
        out = jax.tree.unflatten(jax.tree.structure(xs), outs)
    if reverse:
        out = jax.tree.map(lambda l: jnp.flip(l, axis), out)
    return out


def _batched_scan_gpu(op, xs, *, inclusive=True, reverse=False,
                      interpret=None, policy=None):
    if _gpu_lookback_unavailable(interpret):
        return _batched_scan_xla(op, xs, inclusive=inclusive,
                                 reverse=reverse, policy=policy)
    if reverse:
        xs = jax.tree.map(lambda l: jnp.flip(l, 1), xs)
    out = gpu_k.scan_batched_gpu(op, xs, inclusive=inclusive,
                                 policy=policy, interpret=interpret)
    if reverse:
        out = jax.tree.map(lambda l: jnp.flip(l, 1), out)
    return out


def _mapreduce_gpu(f, op, xs, *, axis=None, interpret=None, policy=None):
    leaves = jax.tree.leaves(xs)
    ndim = leaves[0].ndim
    if axis is None:
        flat = jax.tree.map(lambda l: l.reshape(-1), xs)
        return gpu_k.mapreduce_flat_gpu(f, op, flat, policy=policy,
                                        interpret=interpret)
    if ndim == 2:
        # Rows of the batched reducer are whichever axis survives: reducing
        # axis 0 transposes so columns become independent rows.
        if axis == 0:
            xs = jax.tree.map(lambda l: l.T, xs)
        return gpu_k.mapreduce_batched_gpu(f, op, xs, policy=policy,
                                           interpret=interpret)
    raise NotImplementedError("mapreduce: gpu path supports axis=None or 2D")


def _linrec_gpu(a, b, h0=None, *, reverse=False, interpret=None, policy=None):
    if _gpu_lookback_unavailable(interpret):
        return _linrec_xla(a, b, h0, reverse=reverse, policy=policy)
    A, B = _scan_gpu(alg.AFFINE, (a, b), axis=1, inclusive=True,
                     reverse=reverse, interpret=interpret, policy=policy)
    if h0 is None:
        return B
    return A * h0[:, None, :] + B


# ---------------------------------------------------------------------------
# scan@segmented / mapreduce@segmented (ragged workloads)
# ---------------------------------------------------------------------------


def _segment_flags(xs, flags, offsets):
    """Normalize either segment descriptor to a flag array (the dispatch
    layer has already validated that exactly one is present)."""
    n = jax.tree.leaves(xs)[0].shape[0]
    if offsets is not None:
        return seg_k.offsets_to_flags(offsets, n)
    return flags.astype(jnp.int32)


def _segmented_scan_pallas(op, xs, *, flags=None, offsets=None, inclusive=True,
                           interpret=False, policy=None):
    f = _segment_flags(xs, flags, offsets)
    return seg_k.segmented_scan_1d_pallas(
        op, xs, f, inclusive=inclusive, policy=policy, interpret=interpret)


def _segmented_scan_xla(op, xs, *, flags=None, offsets=None, inclusive=True,
                        policy=None):
    """Portable path: associative_scan of the lifted (flag, value) operator."""
    f = _segment_flags(xs, flags, offsets)
    seg = alg.segmented(op)
    _, incl = jax.lax.associative_scan(seg.combine, (f, xs), axis=0)
    if inclusive:
        return incl
    ident = op.identity(jax.tree.map(lambda l: l[:1], xs))
    shifted = jax.tree.map(
        lambda l, i: jnp.concatenate([i, l[:-1]], axis=0), incl, ident)
    ident_full = op.identity(incl)
    return jax.tree.map(
        lambda s, i: jnp.where(f != 0, i, s), shifted, ident_full)


def _segmented_mapreduce_pallas(f, op, xs, *, flags=None, offsets=None,
                                num_segments=None, interpret=False,
                                policy=None):
    fl = _segment_flags(xs, flags, offsets)
    vals = f(xs)
    incl = seg_k.segmented_scan_1d_pallas(
        op, vals, fl, inclusive=True, policy=policy, interpret=interpret)
    return seg_k.gather_segment_lasts(
        op, incl, offsets=offsets, flags=None if offsets is not None else fl,
        num_segments=num_segments)


def _segmented_mapreduce_xla(f, op, xs, *, flags=None, offsets=None,
                             num_segments=None, policy=None):
    fl = _segment_flags(xs, flags, offsets)
    vals = f(xs)
    # Fast path: the standard algebra over plain arrays maps onto XLA's
    # native segment reductions.
    direct = {"add": jax.ops.segment_sum, "mul": jax.ops.segment_prod,
              "max": jax.ops.segment_max, "min": jax.ops.segment_min}
    ns = num_segments if offsets is None else offsets.shape[0] - 1
    if op.name in direct and isinstance(vals, jax.Array) and ns is not None:
        seg_ids = (seg_k.flags_to_segment_ids(fl) if offsets is None else
                   jnp.searchsorted(offsets[1:], jnp.arange(vals.shape[0]),
                                    side="right"))
        return direct[op.name](vals, seg_ids, num_segments=ns)
    seg = alg.segmented(op)
    _, incl = jax.lax.associative_scan(seg.combine, (fl, vals), axis=0)
    return seg_k.gather_segment_lasts(
        op, incl, offsets=offsets, flags=None if offsets is not None else fl,
        num_segments=num_segments)


# ---------------------------------------------------------------------------
# mapreduce@flat
# ---------------------------------------------------------------------------


def _mapreduce_pallas(f, op, xs, *, axis=None, interpret=False, policy=None):
    leaves = jax.tree.leaves(xs)
    ndim = leaves[0].ndim
    if axis is None:
        flat = jax.tree.map(lambda l: l.reshape(-1), xs)
        return mapreduce_k.mapreduce_1d_pallas(
            f, op, flat, policy=policy, interpret=interpret)
    if ndim == 2 and isinstance(xs, jax.Array):
        policy_ = policy or ki.resolve_tuning("interpret" if interpret else None)
        sub = ki.min_tile(xs.dtype)[0]
        n, p = xs.shape
        if axis == 0:
            # Reduce over rows -> one value per column: the matvec path
            # (paper §V-A dispatches 2-D mapreduce to the matvec kernels).
            dummy = jnp.zeros((n, 1), xs.dtype)
            return matvec_k.matvec_pallas(
                lambda _x, a: f(a), op, xs, dummy[:, 0],
                block_rows=policy_.matvec_rows * sub,
                block_cols=policy_.matvec_cols * ki.LANES,
                interpret=interpret)
        dummy = jnp.zeros((p,), xs.dtype)
        return matvec_k.vecmat_pallas(
            lambda a, _x: f(a), op, xs, dummy,
            block_rows=policy_.vecmat_rows * sub,
            block_cols=policy_.vecmat_cols * ki.LANES,
            interpret=interpret)
    raise NotImplementedError("mapreduce: pallas path supports axis=None or 2D")


def _mapreduce_xla(f, op, xs, *, axis=None, policy=None):
    # Fast paths for the standard algebra (XLA reductions); generic fallback
    # via associative_scan otherwise.
    direct = {"add": jnp.sum, "mul": jnp.prod, "max": jnp.max, "min": jnp.min}
    vals = f(xs)
    if op.name in direct and isinstance(vals, jax.Array):
        return direct[op.name](vals, axis=axis)
    if op.name == "logsumexp" and isinstance(vals, jax.Array):
        return jax.scipy.special.logsumexp(vals, axis=axis)
    return ref.ref_mapreduce(f, op, xs, axis=axis)


# ---------------------------------------------------------------------------
# matvec@flat / vecmat@flat (semiring generalized forms)
# ---------------------------------------------------------------------------


def _pick_blocks_matvec(policy, A, n, p):
    sub = ki.min_tile(A.dtype)[0]
    rn = policy.matvec_rows * sub
    cp = policy.matvec_cols * ki.LANES
    if p <= ki.LANES:                      # tall-narrow: stride more rows
        cp = ki.LANES
        rn = rn * 4
    elif n <= 8 * sub:                     # wide-short: widen columns
        cp = cp * 4
    rn = min(rn, ki.round_up(n, sub))
    cp = min(cp, ki.round_up(p, ki.LANES))
    return rn, cp


def _pick_blocks_vecmat(policy, A, n, p):
    sub = ki.min_tile(A.dtype)[0]
    ri = policy.vecmat_rows * sub
    cj = policy.vecmat_cols * ki.LANES
    if n <= 8:                              # short: widen columns
        cj = cj * 4
    elif p <= ki.LANES:                     # narrow: more rows
        ri = ri * 4
    ri = min(ri, ki.round_up(n, sub))
    cj = min(cj, ki.round_up(p, ki.LANES))
    return ri, cj


def _quant_row_block(bn: int, q) -> int:
    """Round a picked row-block extent up to whole ``q.block`` scale rows so
    every value tile owns complete scale rows (kernels/matvec.py enforces
    the invariant)."""
    return ki.round_up(bn, q.block)


def _matvec_pallas(f, op, A, x, *, interpret=False, policy=None):
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    n, p = A.shape
    if isinstance(A, alg.Quantized):
        rn, cp = _pick_blocks_matvec(policy, A, n, p)
        rn = _quant_row_block(rn, A)
        return matvec_k.matvec_quantized_pallas(
            f, op, A, x, block_rows=rn, block_cols=cp, interpret=interpret)
    if p <= 64 and n >= 4 * ki.LANES and getattr(op, "commutative", False):
        # Tall-narrow: lane-packed kernel (EXPERIMENTS.md §Kernel gap fix) --
        # g = 128//p row groups share the lanes instead of padding p to 128.
        # Commutative-only: groups interleave rows (i -> group i mod g).
        return matvec_k.matvec_packed_pallas(
            f, op, A, x, block_rows=policy.matvec_rows * ki.min_tile(A.dtype)[0],
            interpret=interpret)
    rn, cp = _pick_blocks_matvec(policy, A, n, p)
    return matvec_k.matvec_pallas(f, op, A, x, block_rows=rn, block_cols=cp,
                                  interpret=interpret)


def _vecmat_pallas(f, op, A, x, *, interpret=False, policy=None):
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    n, p = A.shape
    ri, cj = _pick_blocks_vecmat(policy, A, n, p)
    if isinstance(A, alg.Quantized):
        ri = _quant_row_block(ri, A)
        return matvec_k.vecmat_quantized_pallas(
            f, op, A, x, block_rows=ri, block_cols=cj, interpret=interpret)
    return matvec_k.vecmat_pallas(f, op, A, x, block_rows=ri, block_cols=cj,
                                  interpret=interpret)


def _matvec_xla(f, op, A, x, *, policy=None):
    if isinstance(A, alg.Quantized):
        A = A.dequantize()      # reference lowering: dequantize, then dense
    if op.name == "add" and _is_arithmetic(f, x, A):
        # Standard semiring -> MXU-friendly contraction.
        return jnp.einsum("n,np->p", x, A)
    return ref.ref_matvec(f, op, A, x)


def _vecmat_xla(f, op, A, x, *, policy=None):
    if isinstance(A, alg.Quantized):
        A = A.dequantize()
    if op.name == "add" and _is_arithmetic(f, x, A):
        return jnp.einsum("np,p->n", A, x)
    return ref.ref_vecmat(f, op, A, x)


def _is_arithmetic(f, x, A):
    """Detect f == multiply by probing on tiny concrete values."""
    try:
        a = f(jnp.asarray(3.0, x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32),
              jnp.asarray(5.0, A.dtype if jnp.issubdtype(A.dtype, jnp.floating) else jnp.float32))
        return isinstance(a, jax.Array) and a.shape == () and float(a) == 15.0
    except Exception:
        return False


# ---------------------------------------------------------------------------
# linear recurrence  h_t = a_t * h_{t-1} + b_t  on (B, T, C)
#
# The (B, T, C) channelwise scan IS the grid-batched layout (batch and
# channel blocks ride parallel grid dimensions), so the same implementations
# serve the flat and batched routes; the batched route is the one consumers
# (serving, recurrent models) call and the one the tuner keys with a batch
# bucket.
# ---------------------------------------------------------------------------


def _linrec_pallas(a, b, h0=None, *, reverse=False, interpret=False,
                   policy=None):
    A, B = scan_k.scan_channel_pallas(
        alg.AFFINE, (a, b), inclusive=True, reverse=reverse, policy=policy,
        interpret=interpret)
    if h0 is None:
        return B
    return A * h0[:, None, :] + B


def _linrec_xla(a, b, h0=None, *, reverse=False, policy=None):
    return ref.ref_linear_recurrence(a, b, h0=h0, axis=1, reverse=reverse)


# ---------------------------------------------------------------------------
# Batched family: one launch per uniform batch of independent rows
# (kernels/batched.py).  Zero-extent edges (B == 0, n == 0, p == 0) and the
# non-commutative mapreduce reroute are resolved by the registry's dispatch
# pipeline, so these wrappers only see grids of extent >= 1 and commutative
# reductions.
# ---------------------------------------------------------------------------


def _batched_scan_pallas(op, xs, *, inclusive=True, reverse=False,
                         interpret=False, policy=None):
    if reverse:
        xs = jax.tree.map(lambda l: jnp.flip(l, 1), xs)
    out = batched_k.batched_scan_pallas(op, xs, inclusive=inclusive,
                                        policy=policy, interpret=interpret)
    if reverse:
        out = jax.tree.map(lambda l: jnp.flip(l, 1), out)
    return out


def _batched_scan_xla(op, xs, *, inclusive=True, reverse=False, policy=None):
    return ref.ref_scan(op, xs, axis=1, inclusive=inclusive, reverse=reverse)


def _batched_mapreduce_pallas(f, op, xs, *, interpret=False, policy=None):
    return batched_k.batched_mapreduce_pallas(
        f, op, xs, policy=policy, interpret=interpret)


def _batched_mapreduce_xla(f, op, xs, *, policy=None):
    direct = {"add": jnp.sum, "mul": jnp.prod, "max": jnp.max, "min": jnp.min}
    vals = f(xs)
    if op.name in direct and isinstance(vals, jax.Array):
        return direct[op.name](vals, axis=1)
    if op.name == "logsumexp" and isinstance(vals, jax.Array):
        return jax.scipy.special.logsumexp(vals, axis=1)
    scanned = jax.lax.associative_scan(op.combine, vals, axis=1)
    return jax.tree.map(lambda l: l[:, -1], scanned)


def _batched_matvec_pallas(f, op, A, x, *, interpret=False, policy=None):
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    rn, cp = _pick_blocks_matvec(policy, A, A.shape[1], A.shape[2])
    if isinstance(A, alg.Quantized):
        rn = _quant_row_block(rn, A)
        return batched_k.batched_matvec_quantized_pallas(
            f, op, A, x, block_rows=rn, block_cols=cp, interpret=interpret)
    return batched_k.batched_matvec_pallas(
        f, op, A, x, block_rows=rn, block_cols=cp, interpret=interpret)


def _batched_vecmat_pallas(f, op, A, x, *, interpret=False, policy=None):
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    ri, cj = _pick_blocks_vecmat(policy, A, A.shape[1], A.shape[2])
    if isinstance(A, alg.Quantized):
        ri = _quant_row_block(ri, A)
        return batched_k.batched_vecmat_quantized_pallas(
            f, op, A, x, block_rows=ri, block_cols=cj, interpret=interpret)
    return batched_k.batched_vecmat_pallas(
        f, op, A, x, block_rows=ri, block_cols=cj, interpret=interpret)


def _batched_matvec_xla(f, op, A, x, *, policy=None):
    if isinstance(A, alg.Quantized):
        A = A.dequantize()
    if op.name == "add" and _is_arithmetic(f, x, A):
        return jnp.einsum("bn,bnp->bp", x, A)
    vals = f(x[:, :, None], A)
    scanned = jax.lax.associative_scan(op.combine, vals, axis=1)
    return jax.tree.map(lambda l: l[:, -1], scanned)


def _batched_vecmat_xla(f, op, A, x, *, policy=None):
    if isinstance(A, alg.Quantized):
        A = A.dequantize()
    if op.name == "add" and _is_arithmetic(f, x, A):
        return jnp.einsum("bnp,bp->bn", A, x)
    vals = f(A, x[:, None, :])
    scanned = jax.lax.associative_scan(op.combine, vals, axis=2)
    return jax.tree.map(lambda l: l[:, :, -1], scanned)


# ---------------------------------------------------------------------------
# The registration table.  ``_pallas_pair`` expands one kernel body into the
# compiled and interpreted backends; the radix-sort family is one shared
# composition (kernels/sort.py) whose scan/mapreduce steps dispatch to the
# named sub-backend, so ``pallas-interpret`` runs the real kernel bodies and
# ``xla`` stays a pure portable fallback -- no backend-specific sort code.
# ---------------------------------------------------------------------------


def _pallas_pair(fn):
    return {"pallas-tpu": functools.partial(fn, interpret=False),
            "pallas-interpret": functools.partial(fn, interpret=True)}


def _per_backend(fn):
    # Compositions (radix sorts, sharded folds) take the backend their
    # scan/mapreduce building blocks dispatch to -- the same ``backend``
    # spelling as everywhere else, so each registered row just pins it.
    return {b: functools.partial(fn, backend=b)
            for b in ("pallas-tpu", "pallas-gpu", "pallas-interpret", "xla")}


IMPLS: dict[str, dict[str, Any]] = {
    "copy@flat": {**_pallas_pair(copy_k.copy_pallas), "xla": _copy_xla,
                  "pallas-gpu": gpu_k.copy_gpu},
    "scan@flat": {**_pallas_pair(_scan_pallas), "xla": _scan_xla,
                  "pallas-gpu": _scan_gpu},
    "scan@batched": {**_pallas_pair(_batched_scan_pallas),
                     "xla": _batched_scan_xla,
                     "pallas-gpu": _batched_scan_gpu},
    # scan@segmented / mapreduce@segmented have no native pallas-gpu rows
    # (yet): dispatch falls back to xla, and supports() reports it.
    "scan@segmented": {**_pallas_pair(_segmented_scan_pallas),
                       "xla": _segmented_scan_xla},
    "mapreduce@flat": {**_pallas_pair(_mapreduce_pallas),
                       "xla": _mapreduce_xla,
                       "pallas-gpu": _mapreduce_gpu},
    "mapreduce@batched": {**_pallas_pair(_batched_mapreduce_pallas),
                          "xla": _batched_mapreduce_xla,
                          "pallas-gpu": gpu_k.mapreduce_batched_gpu},
    "mapreduce@segmented": {**_pallas_pair(_segmented_mapreduce_pallas),
                            "xla": _segmented_mapreduce_xla},
    "matvec@flat": {**_pallas_pair(_matvec_pallas), "xla": _matvec_xla,
                    "pallas-gpu": gpu_k.matvec_gpu},
    "matvec@batched": {**_pallas_pair(_batched_matvec_pallas),
                       "xla": _batched_matvec_xla,
                       "pallas-gpu": gpu_k.batched_matvec_gpu},
    "vecmat@flat": {**_pallas_pair(_vecmat_pallas), "xla": _vecmat_xla,
                    "pallas-gpu": gpu_k.vecmat_gpu},
    "vecmat@batched": {**_pallas_pair(_batched_vecmat_pallas),
                       "xla": _batched_vecmat_xla,
                       "pallas-gpu": gpu_k.batched_vecmat_gpu},
    "linear_recurrence@flat": {**_pallas_pair(_linrec_pallas),
                               "xla": _linrec_xla,
                               "pallas-gpu": _linrec_gpu},
    "linear_recurrence@batched": {**_pallas_pair(_linrec_pallas),
                                  "xla": _linrec_xla,
                                  "pallas-gpu": _linrec_gpu},
    "sort@flat": _per_backend(sort_k.sort_radix),
    "sort@segmented": _per_backend(sort_k.segmented_sort_radix),
    "sort_pairs@flat": _per_backend(sort_k.sort_pairs_radix),
    "sort_pairs@segmented": _per_backend(sort_k.segmented_sort_pairs_radix),
    "argsort@flat": _per_backend(sort_k.argsort_radix),
    "argsort@segmented": _per_backend(sort_k.segmented_argsort_radix),
    "top_k@flat": _per_backend(sort_k.top_k_radix),
    "top_k@segmented": _per_backend(sort_k.segmented_top_k_radix),
    # Device-spanning routes (distributed/primitives.py): staged ShardPlans
    # -- the local route, the operator's collective fold, an epilogue --
    # executed by one chunked, overlap-capable driver.  ``backend`` names
    # the backend the shard-local stages dispatch to, so pallas-interpret
    # runs the real kernel bodies (and pallas-gpu the GPU lowerings) under
    # the collective composition.
    "scan@sharded": _per_backend(dist_k.sharded_scan),
    "mapreduce@sharded": _per_backend(dist_k.sharded_mapreduce),
    "matvec@sharded": _per_backend(dist_k.sharded_matvec),
    "vecmat@sharded": _per_backend(dist_k.sharded_vecmat),
    "linear_recurrence@sharded": _per_backend(dist_k.sharded_linear_recurrence),
    "sort_pairs@sharded": _per_backend(dist_k.sharded_sort_pairs),
    "top_k@sharded": _per_backend(dist_k.sharded_top_k),
}

# The registration table and the declarative PrimitiveDef registry must
# enumerate exactly the same routes, and every route must keep a portable
# fallback.  Raised (not assert) so the check survives python -O.
if set(IMPLS) != ki.route_keys():
    raise RuntimeError(
        "kernels/ops.py IMPLS out of sync with the PrimitiveDef registry: "
        f"missing={sorted(ki.route_keys() - set(IMPLS))} "
        f"extra={sorted(set(IMPLS) - ki.route_keys())}")
for _key, _impls in IMPLS.items():
    if "xla" not in _impls:
        raise RuntimeError(f"{_key}: every route needs an xla fallback")
    for _backend, _fn in _impls.items():
        ki.register_impl(_key, _backend)(_fn)
