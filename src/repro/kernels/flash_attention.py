"""Fused causal attention kernel (Pallas TPU): the §Perf memory-term fix.

The portable XLA lowering of blockwise attention (models/attention.py) is
*algorithmically* flash but still materializes each [S, kv_block] score tile
in HBM -- O(S*T) traffic that dominates the memory roofline term of the dense
archs (EXPERIMENTS.md §Perf).  This kernel keeps the running (m, l, acc)
entirely in VMEM scratch across the sequential kv-block grid dimension, so
HBM traffic is exactly q + k + v read (+ k,v re-read per q block) + out
written -- the same 2n-style structural bound the paper's scan enjoys.

Layout: q/k/v flattened to (N, S, d) with N = batch x heads (the wrapper
broadcasts grouped KV); grid = (N, q_blocks, kv_blocks), kv innermost
("arbitrary" = sequential, the carry dimension -- decoupled-lookback's TPU
form again).  Causal/windowed masking via global indices; optional softcap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import pltpu

from repro.core import intrinsics as ki

NEG_INF = -1e30


def _flash_kernel(scale, causal, window, softcap, q_len, kv_len, qb, kb,
                  q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (qb, d)
    k = k_ref[0]                       # (kb, d)
    # Ragged-tail hygiene: OOB kv rows read garbage; zero them so masked
    # probabilities (p == 0) cannot meet NaN in the p @ v product.
    kv_valid = (kj * kb + jax.lax.broadcasted_iota(
        jnp.int32, (kb, 1), 0)) < kv_len
    k = jnp.where(kv_valid, k, 0)
    v = jnp.where(kv_valid, v_ref[0], 0)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (qb, kb)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    kpos = kj * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = (kpos < kv_len) & (qpos < q_len)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                # (qb, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0, softcap=0.0,
                           q_block=256, kv_block=256, interpret=False):
    """q: (N, S, d); k, v: (N, T, d) -> (N, S, d).  d padded to 128 lanes."""
    N, S, d = q.shape
    T = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    d_pad = ki.round_up(d, ki.LANES)
    if d_pad != d:
        pad = [(0, 0), (0, 0), (0, d_pad - d)]
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))
    qb = min(q_block, ki.round_up(S, 8))
    kb = min(kv_block, ki.round_up(T, 8))
    grid = (N, ki.cdiv(S, qb), ki.cdiv(T, kb))

    kernel = functools.partial(
        _flash_kernel, scale, causal, window, softcap, S, T, qb, kb)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, d_pad), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, kb, d_pad), lambda n, i, j: (n, j, 0)),
            pl.BlockSpec((1, kb, d_pad), lambda n, i, j: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, d_pad), lambda n, i, j: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, S, d_pad), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, d_pad), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[..., :d] if d_pad != d else out


def flash_attention_bytes(N, S, T, d, dtype, q_block=256, kv_block=256):
    """Structural HBM traffic: q + out once, k/v once per q block."""
    sz = jnp.dtype(dtype).itemsize
    d_pad = ki.round_up(d, ki.LANES)
    nq = ki.cdiv(S, min(q_block, ki.round_up(S, 8)))
    q_bytes = N * S * d_pad * sz
    kv_bytes = 2 * N * nq * ki.round_up(T, 8) * d_pad * sz
    out_bytes = N * S * d_pad * sz
    return q_bytes + kv_bytes + out_bytes


def flash_attention_flops(N, S, T, d, causal=True):
    f = 4.0 * N * S * T * d
    return f / 2 if causal else f
