"""Generalized semiring matrix-vector / vector-matrix kernels (paper §V-C).

``matvec``:  y[j] = op_{i=1..n} f(x[i], A[i, j])   (reduce over rows)
``vecmat``:  z[i] = op_{j=1..p} f(A[i, j], x[j])   (reduce over columns)

for *any* elementwise map ``f`` and associative (not necessarily commutative)
reduce ``op`` -- subsuming BLAS GEMV (f=*, op=+), tropical semirings and
log-space accumulation, for arbitrary element types.

TPU adaptation: the paper's two thread organizations (tall: fixed-grid block
striding per column; wide: warps covering column groups with row strides,
Fig. 2) become BlockSpec layouts.  Rows ride sublanes and columns ride lanes
in both orientations -- the *reduction axis* changes, not the storage layout:

* matvec reduces along sublanes (in-order log-step fold per tile, carried
  across row-tiles by accumulating into the resident output block);
* vecmat reduces along lanes the same way.

The output block is used as the accumulator: it stays VMEM-resident while the
inner (reduction) grid dimension advances and is flushed to HBM exactly once
when the outer index changes -- the single-launch / one-write-per-element
property of the paper's flag protocol, obtained from the sequential grid.

Tall/wide block-shape selection happens in ops.py from the TuningPolicy
(the ``A40 <: Ampere`` dispatch analogue).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import pltpu

from repro.core import intrinsics as ki
from repro.core import operators as alg

Pytree = Any


def _out_struct(f, x_like, a_like):
    out = jax.eval_shape(lambda xx, aa: f(xx, aa), x_like, a_like)
    return jax.tree.flatten(out)


def _dequant_tile(values, scales, block: int, mode: str) -> jax.Array:
    """In-kernel blockwise dequant of a ``(rn, cp)`` values tile.

    ``scales`` is the matching ``(rn // block, cp)`` tile; each scale row is
    broadcast over its ``block`` value rows (broadcast + reshape -- the
    sublane axis only ever merges with a new unit axis, which lowers to a
    plain relayout on TPU and is exact in interpret mode).  Output is f32:
    the accumulation dtype of every quantized route.
    """
    rpb, cp = scales.shape
    dec = (values.astype(jnp.float32) if mode == "int8"
           else alg.fp8_decode(values, mode))
    se = jnp.broadcast_to(scales[:, None, :], (rpb, block, cp))
    return dec * se.reshape(rpb * block, cp)


def _check_quant_blocks(rn: int, q) -> int:
    if rn % q.block:
        raise ValueError(
            f"quantized matvec/vecmat needs the row-tile extent ({rn}) to "
            f"be a multiple of the quantization block ({q.block}); the "
            "ops.py block pickers round it up -- fix the caller")
    return rn // q.block


def _matvec_kernel(f, op, out_treedef, n, rn, n_out, batched, *refs):
    """Column-stripe matvec body.

    ``batched`` shifts the reduction grid axis from 1 to 2: the batched
    layout (kernels/batched.py) prepends a parallel batch grid dimension and
    gives every block a leading singleton batch extent, but the reduction
    protocol -- output block doubles as the accumulator, reset at reduction
    step 0, in-order fold per tile -- is identical.
    """
    x_ref, a_ref = refs[0], refs[1]
    o_refs = refs[2:]
    i = pl.program_id(2 if batched else 1)
    cp = a_ref.shape[-1]

    acc_like = jax.tree.unflatten(
        out_treedef,
        [jax.ShapeDtypeStruct((1, cp), r.dtype) for r in o_refs])
    ident_acc = op.identity(acc_like)

    @pl.when(i == 0)
    def _init():
        for orf, ia in zip(o_refs, jax.tree.leaves(ident_acc)):
            orf[...] = ia.reshape(orf.shape)

    x = x_ref[...].reshape(rn, 1)
    a = a_ref[...].reshape(rn, cp)
    v = f(x, a)               # pytree of (rn, cp)

    tile_like = jax.tree.unflatten(
        out_treedef,
        [jax.ShapeDtypeStruct((rn, cp), r.dtype) for r in o_refs])
    ident_tile = op.identity(tile_like)
    ridx = jax.lax.broadcasted_iota(jnp.int32, (rn, cp), 0)
    valid = (i * rn + ridx) < n
    v = jax.tree.map(lambda l, id_: jnp.where(valid, l, id_), v, ident_tile)

    part = ki.tile_reduce(op, v, axis=0)        # (1, cp), in-order
    acc = jax.tree.unflatten(
        out_treedef, [orf[...].reshape(1, cp) for orf in o_refs])
    acc = op(acc, part)
    for orf, l in zip(o_refs, jax.tree.leaves(acc)):
        orf[...] = l.reshape(orf.shape)


def matvec_pallas(f, op, A: jax.Array, x: jax.Array, *,
                  block_rows: int, block_cols: int,
                  interpret: bool = False) -> Pytree:
    """y[j] = op_i f(x[i], A[i, j]).  A: (n, p), x: (n,) -> y: (p,) pytree."""
    n, p = A.shape
    rn = block_rows
    cp = block_cols
    out_leaves, out_treedef = _out_struct(
        f, jax.ShapeDtypeStruct((1, 1), x.dtype),
        jax.ShapeDtypeStruct((1, 1), A.dtype))

    grid = (ki.cdiv(p, cp), ki.cdiv(n, rn))
    kernel = functools.partial(
        _matvec_kernel, f, op, out_treedef, n, rn, len(out_leaves), False)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rn, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((rn, cp), lambda j, i: (i, j)),
        ],
        out_specs=[pl.BlockSpec((1, cp), lambda j, i: (0, j))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((1, p), l.dtype) for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x.reshape(n, 1), A)
    return jax.tree.unflatten(out_treedef, [o.reshape(p) for o in out])


def _matvec_q_kernel(f, op, out_treedef, n, rn, block, mode, batched, *refs):
    """Quantized column-stripe matvec body: :func:`_matvec_kernel` with the
    A tile rebuilt from (values, scales) before ``f`` -- scales broadcast
    per block inside the tile, products accumulated in f32."""
    x_ref, v_ref, s_ref = refs[0], refs[1], refs[2]
    o_refs = refs[3:]
    i = pl.program_id(2 if batched else 1)
    cp = v_ref.shape[-1]
    rpb = rn // block

    acc_like = jax.tree.unflatten(
        out_treedef,
        [jax.ShapeDtypeStruct((1, cp), r.dtype) for r in o_refs])
    ident_acc = op.identity(acc_like)

    @pl.when(i == 0)
    def _init():
        for orf, ia in zip(o_refs, jax.tree.leaves(ident_acc)):
            orf[...] = ia.reshape(orf.shape)

    x = x_ref[...].reshape(rn, 1)
    a = _dequant_tile(v_ref[...].reshape(rn, cp),
                      s_ref[...].reshape(rpb, cp), block, mode)
    v = f(x, a)               # pytree of (rn, cp), f32 accumulation

    tile_like = jax.tree.unflatten(
        out_treedef,
        [jax.ShapeDtypeStruct((rn, cp), r.dtype) for r in o_refs])
    ident_tile = op.identity(tile_like)
    ridx = jax.lax.broadcasted_iota(jnp.int32, (rn, cp), 0)
    valid = (i * rn + ridx) < n
    v = jax.tree.map(lambda l, id_: jnp.where(valid, l, id_), v, ident_tile)

    part = ki.tile_reduce(op, v, axis=0)        # (1, cp), in-order
    acc = jax.tree.unflatten(
        out_treedef, [orf[...].reshape(1, cp) for orf in o_refs])
    acc = op(acc, part)
    for orf, l in zip(o_refs, jax.tree.leaves(acc)):
        orf[...] = l.reshape(orf.shape)


def matvec_quantized_pallas(f, op, q, x: jax.Array, *,
                            block_rows: int, block_cols: int,
                            interpret: bool = False) -> Pytree:
    """y[j] = op_i f(x[i], deq(A)[i, j]) for a ``Quantized`` matrix operand.

    Same grid/stripe protocol as :func:`matvec_pallas`; HBM moves the int8/
    fp8 values plus one f32 scale per ``q.block`` rows per column instead of
    the dense matrix.  ``block_rows`` must be a multiple of ``q.block``.
    """
    n, p = q.shape
    rn = block_rows
    cp = block_cols
    rpb = _check_quant_blocks(rn, q)
    out_leaves, out_treedef = _out_struct(
        f, jax.ShapeDtypeStruct((1, 1), x.dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.float32))

    grid = (ki.cdiv(p, cp), ki.cdiv(n, rn))
    kernel = functools.partial(
        _matvec_q_kernel, f, op, out_treedef, n, rn, q.block, q.mode, False)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rn, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((rn, cp), lambda j, i: (i, j)),
            pl.BlockSpec((rpb, cp), lambda j, i: (i, j)),
        ],
        out_specs=[pl.BlockSpec((1, cp), lambda j, i: (0, j))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((1, p), l.dtype) for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x.reshape(n, 1), q.values, q.scales)
    return jax.tree.unflatten(out_treedef, [o.reshape(p) for o in out])


def _matvec_packed_kernel(f, op, out_treedef, n, p, g, rn, *refs):
    """Tall-narrow matvec with lane packing (p <= 64).  COMMUTATIVE ops only.

    The naive layout pads p columns to 128 lanes (12x waste at p=10,
    EXPERIMENTS.md §Kernel).  Here ``g = 128 // p`` row-groups ride the
    lanes: A is viewed (free, row-major) as (n/g, g*p); each lane column
    (r, j) accumulates rows i ≡ r (mod g) of original column j, and the
    final combine folds the g group partials.  That group fold is in tile
    order, but group r holds rows r, g+r, 2g+r, ... -- an *interleaving* of
    the row sequence -- and the ``n % g`` tail rows fold in separately after
    the packed body, so the reduction order is NOT the row order.  Only
    commutative operators are correct here; the dispatcher
    (ops.py ``_matvec_pallas``) sends non-commutative ops to the
    order-preserving :func:`matvec_pallas`, and :func:`matvec_packed_pallas`
    rejects them outright.
    """
    x_ref, a_ref = refs[0], refs[1]
    o_refs = refs[2:]
    i = pl.program_id(0)
    ni = pl.num_programs(0)
    w = g * p

    acc_like = jax.tree.unflatten(
        out_treedef, [jax.ShapeDtypeStruct((1, w), r.dtype) for r in o_refs])
    ident_acc = op.identity(acc_like)

    # o_refs double as accumulators (resident across the sequential grid);
    # the final group-fold happens on the last grid step.
    @pl.when(i == 0)
    def _init():
        for orf, ia in zip(o_refs, jax.tree.leaves(ident_acc)):
            orf[...] = ia

    x = x_ref[...]            # (rn, g)  packed rows
    a = a_ref[...]            # (rn, w)
    xw = jnp.repeat(x, p, axis=1)          # broadcast x across its p columns
    v = f(xw, a)              # pytree of (rn, w)

    tile_like = jax.tree.unflatten(
        out_treedef, [jax.ShapeDtypeStruct((rn, w), r.dtype) for r in o_refs])
    ident_tile = op.identity(tile_like)
    ridx = jax.lax.broadcasted_iota(jnp.int32, (rn, w), 0)
    cidx = jax.lax.broadcasted_iota(jnp.int32, (rn, w), 1)
    # Global original row of element (r_local, lane) = (i*rn + r_local)*g + lane//p
    grow = (i * rn + ridx) * g + cidx // p
    v = jax.tree.map(lambda l, id_: jnp.where(grow < n, l, id_),
                     v, ident_tile)

    part = ki.tile_reduce(op, v, axis=0)   # (1, w)
    acc = jax.tree.unflatten(out_treedef, [orf[...] for orf in o_refs])
    acc = op(acc, part)
    for orf, l in zip(o_refs, jax.tree.leaves(acc)):
        orf[...] = l

    @pl.when(i == ni - 1)
    def _fold_groups():
        accf = jax.tree.unflatten(out_treedef, [orf[...] for orf in o_refs])
        folded = jax.tree.map(lambda l: l.reshape(g, p), accf)
        folded = ki.tile_reduce(op, folded, axis=0)          # (1, p), in-order
        for orf, l in zip(o_refs, jax.tree.leaves(folded)):
            orf[...] = jnp.pad(l, ((0, 0), (0, w - p)),
                               constant_values=0).astype(orf.dtype) \
                if w != p else l


def matvec_packed_pallas(f, op, A: jax.Array, x: jax.Array, *,
                         block_rows: int, interpret: bool = False):
    """Lane-packed tall-narrow matvec: y[j] = op_i f(x[i], A[i, j]), p <= 64.

    Commutative ``op`` only (group interleave + separate tail fold reorder
    the reduction -- see :func:`_matvec_packed_kernel`).
    """
    if not getattr(op, "commutative", False):
        raise ValueError(
            "matvec_packed_pallas: lane packing interleaves row groups and "
            "folds the n % g tail out of row order; non-commutative "
            f"operators (got {getattr(op, 'name', op)!r}) must use "
            "matvec_pallas instead")
    n, p = A.shape
    g = max(ki.LANES // p, 1)
    w = g * p
    tail = None
    if n % g:
        # Slice (free, row-major view) instead of padding (full copy): the
        # <= g-1 tail rows fold in afterwards -- op is commutative here.
        nb = (n // g) * g
        tail = (A[nb:], x[nb:])
        A, x, n = A[:nb], x[:nb], nb
    ng = n // g
    rn = min(block_rows, ki.round_up(ng, 8))
    out_leaves, out_treedef = _out_struct(
        f, jax.ShapeDtypeStruct((1, 1), x.dtype),
        jax.ShapeDtypeStruct((1, 1), A.dtype))

    grid = (ki.cdiv(ng, rn),)
    kernel = functools.partial(
        _matvec_packed_kernel, f, op, out_treedef, n, p, g, rn)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rn, g), lambda i: (i, 0)),
            pl.BlockSpec((rn, w), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((1, w), lambda i: (0, 0))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((1, w), l.dtype) for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x.reshape(ng, g), A.reshape(ng, w))
    result = jax.tree.unflatten(out_treedef, [o[0, :p] for o in out])
    if tail is not None:
        a_t, x_t = tail
        vals = f(x_t[:, None], a_t)
        from repro.core import intrinsics as _ki
        t_red = _ki.tile_reduce(op, vals, axis=0)
        t_red = jax.tree.map(lambda l: l[0], t_red)
        result = op(result, t_red)
    return result


def _vecmat_kernel(f, op, out_treedef, p, cj, n_out, batched, *refs):
    """Row-stripe vecmat body; ``batched`` as in :func:`_matvec_kernel`."""
    x_ref, a_ref = refs[0], refs[1]
    o_refs = refs[2:]
    j = pl.program_id(2 if batched else 1)
    ri = a_ref.shape[-2]

    acc_like = jax.tree.unflatten(
        out_treedef,
        [jax.ShapeDtypeStruct((ri, 1), r.dtype) for r in o_refs])
    ident_acc = op.identity(acc_like)

    @pl.when(j == 0)
    def _init():
        for orf, ia in zip(o_refs, jax.tree.leaves(ident_acc)):
            orf[...] = ia.reshape(orf.shape)

    x = x_ref[...].reshape(1, cj)
    a = a_ref[...].reshape(ri, cj)
    v = f(a, x)               # pytree of (ri, cj)

    tile_like = jax.tree.unflatten(
        out_treedef,
        [jax.ShapeDtypeStruct((ri, cj), r.dtype) for r in o_refs])
    ident_tile = op.identity(tile_like)
    cidx = jax.lax.broadcasted_iota(jnp.int32, (ri, cj), 1)
    valid = (j * cj + cidx) < p
    v = jax.tree.map(lambda l, id_: jnp.where(valid, l, id_), v, ident_tile)

    part = ki.tile_reduce(op, v, axis=1)        # (ri, 1), in-order
    acc = jax.tree.unflatten(
        out_treedef, [orf[...].reshape(ri, 1) for orf in o_refs])
    acc = op(acc, part)
    for orf, l in zip(o_refs, jax.tree.leaves(acc)):
        orf[...] = l.reshape(orf.shape)


def vecmat_pallas(f, op, A: jax.Array, x: jax.Array, *,
                  block_rows: int, block_cols: int,
                  interpret: bool = False) -> Pytree:
    """z[i] = op_j f(A[i, j], x[j]).  A: (n, p), x: (p,) -> z: (n,) pytree."""
    n, p = A.shape
    ri = block_rows
    cj = block_cols
    out_leaves, out_treedef = _out_struct(
        f, jax.ShapeDtypeStruct((1, 1), A.dtype),
        jax.ShapeDtypeStruct((1, 1), x.dtype))

    grid = (ki.cdiv(n, ri), ki.cdiv(p, cj))
    kernel = functools.partial(
        _vecmat_kernel, f, op, out_treedef, p, cj, len(out_leaves), False)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cj), lambda i, j: (0, j)),
            pl.BlockSpec((ri, cj), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((ri, 1), lambda i, j: (i, 0))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((n, 1), l.dtype) for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x.reshape(1, p), A)
    return jax.tree.unflatten(out_treedef, [o.reshape(n) for o in out])


def _vecmat_q_kernel(f, op, out_treedef, p, cj, ri, block, mode, batched,
                     *refs):
    """Quantized row-stripe vecmat body: dequant-in-kernel, f32 accumulate.

    The scale blocks tile the *row* axis (a property of the stored matrix,
    not of the reduction), so the expansion is identical to matvec even
    though vecmat reduces along lanes."""
    x_ref, v_ref, s_ref = refs[0], refs[1], refs[2]
    o_refs = refs[3:]
    j = pl.program_id(2 if batched else 1)
    rpb = ri // block

    acc_like = jax.tree.unflatten(
        out_treedef,
        [jax.ShapeDtypeStruct((ri, 1), r.dtype) for r in o_refs])
    ident_acc = op.identity(acc_like)

    @pl.when(j == 0)
    def _init():
        for orf, ia in zip(o_refs, jax.tree.leaves(ident_acc)):
            orf[...] = ia.reshape(orf.shape)

    x = x_ref[...].reshape(1, cj)
    a = _dequant_tile(v_ref[...].reshape(ri, cj),
                      s_ref[...].reshape(rpb, cj), block, mode)
    v = f(a, x)               # pytree of (ri, cj), f32 accumulation

    tile_like = jax.tree.unflatten(
        out_treedef,
        [jax.ShapeDtypeStruct((ri, cj), r.dtype) for r in o_refs])
    ident_tile = op.identity(tile_like)
    cidx = jax.lax.broadcasted_iota(jnp.int32, (ri, cj), 1)
    valid = (j * cj + cidx) < p
    v = jax.tree.map(lambda l, id_: jnp.where(valid, l, id_), v, ident_tile)

    part = ki.tile_reduce(op, v, axis=1)        # (ri, 1), in-order
    acc = jax.tree.unflatten(
        out_treedef, [orf[...].reshape(ri, 1) for orf in o_refs])
    acc = op(acc, part)
    for orf, l in zip(o_refs, jax.tree.leaves(acc)):
        orf[...] = l.reshape(orf.shape)


def vecmat_quantized_pallas(f, op, q, x: jax.Array, *,
                            block_rows: int, block_cols: int,
                            interpret: bool = False) -> Pytree:
    """z[i] = op_j f(deq(A)[i, j], x[j]) for a ``Quantized`` matrix operand.

    ``block_rows`` must be a multiple of ``q.block`` (row-axis scale tiling,
    as in :func:`matvec_quantized_pallas`)."""
    n, p = q.shape
    ri = block_rows
    cj = block_cols
    _check_quant_blocks(ri, q)
    rpb = ri // q.block
    out_leaves, out_treedef = _out_struct(
        f, jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), x.dtype))

    grid = (ki.cdiv(n, ri), ki.cdiv(p, cj))
    kernel = functools.partial(
        _vecmat_q_kernel, f, op, out_treedef, p, cj, ri, q.block, q.mode,
        False)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cj), lambda i, j: (0, j)),
            pl.BlockSpec((ri, cj), lambda i, j: (i, j)),
            pl.BlockSpec((rpb, cj), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((ri, 1), lambda i, j: (i, 0))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((n, 1), l.dtype) for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x.reshape(1, p), q.values, q.scales)
    return jax.tree.unflatten(out_treedef, [o.reshape(n) for o in out])
