"""Pure-jnp oracles for every kernel (the ``ref.py`` contract).

These are deliberately written against independent JAX built-ins
(``lax.associative_scan``, ``jnp`` reductions) rather than sharing tile code
with the kernels, so that kernel-vs-ref agreement is a real check.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import operators as ops_alg

Pytree = Any


def ref_copy(x: jax.Array) -> jax.Array:
    return jnp.copy(x)


def ref_scan(op, xs: Pytree, axis: int = 0, inclusive: bool = True,
             reverse: bool = False) -> Pytree:
    """Inclusive/exclusive scan along ``axis`` with an arbitrary AssocOp."""
    out = jax.lax.associative_scan(op.combine, xs, axis=axis, reverse=reverse)
    if inclusive:
        return out
    # Exclusive: shift by one along axis, filling with the identity.
    ident = op.identity(_take_slice(xs, axis, 0, 1))

    def shift_leaf(o, i):
        if reverse:
            return jnp.concatenate([_slice_axis(o, axis, 1, None), i], axis=axis)
        return jnp.concatenate([i, _slice_axis(o, axis, 0, -1)], axis=axis)

    return jax.tree.map(shift_leaf, out, ident)


def _slice_axis(l, axis, start, stop):
    sl = [slice(None)] * l.ndim
    sl[axis] = slice(start, stop)
    return l[tuple(sl)]


def _take_slice(xs, axis, start, stop):
    return jax.tree.map(lambda l: _slice_axis(l, axis, start, stop), xs)


def ref_mapreduce(f, op, xs: Pytree, axis=None) -> Pytree:
    """op-reduce of f(x) over ``axis`` (None = all elements)."""
    vals = f(xs)
    if axis is None:
        vals = jax.tree.map(lambda l: l.reshape(-1), vals)
        axis = 0
    scanned = jax.lax.associative_scan(op.combine, vals, axis=axis)
    return jax.tree.map(lambda l: jnp.take(l, l.shape[axis] - 1, axis=axis), scanned)


def ref_matvec(f, op, A: jax.Array, x: jax.Array) -> Pytree:
    """y[j] = op_i f(x[i], A[i, j]); A is (n, p), x is (n,)."""
    vals = f(x[:, None], A)
    scanned = jax.lax.associative_scan(op.combine, vals, axis=0)
    return jax.tree.map(lambda l: l[-1], scanned)


def ref_vecmat(f, op, A: jax.Array, x: jax.Array) -> Pytree:
    """z[i] = op_j f(A[i, j], x[j]); A is (n, p), x is (p,)."""
    vals = f(A, x[None, :])
    scanned = jax.lax.associative_scan(op.combine, vals, axis=1)
    return jax.tree.map(lambda l: l[:, -1], scanned)


# ---------------------------------------------------------------------------
# Quantized-operand oracles.  The conformance contract for a Quantized
# matrix operand has two halves:
#
# * exact-grid: the route's output must match the flat oracle applied to
#   ``q.dequantize()`` at ordinary float tolerance (the kernel dequantizes
#   the same (values, scales) data, just tile-by-tile);
# * error-bounded: against the *unquantized* f32 oracle the route may only
#   deviate by the integrated dequantization error -- for an additive
#   reduction over products, |sum_i x_i (A - deq)_ij| <= sum_i |x_i| eb_ij,
#   with eb the per-element half-step bound from Quantized.error_bound()
#   (derived from the block max-abs via the stored scales).
# ---------------------------------------------------------------------------


def ref_quantized_matvec_bound(q, x: jax.Array) -> jax.Array:
    """Per-output atol for matvec(f=*, op=ADD) vs the f32 oracle: (p,)."""
    return jnp.einsum("...n,...np->...p", jnp.abs(x.astype(jnp.float32)),
                      q.error_bound())


def ref_quantized_vecmat_bound(q, x: jax.Array) -> jax.Array:
    """Per-output atol for vecmat(f=*, op=ADD) vs the f32 oracle: (n,)."""
    return jnp.einsum("...np,...p->...n", q.error_bound(),
                      jnp.abs(x.astype(jnp.float32)))


def ref_linear_recurrence(a: jax.Array, b: jax.Array, h0=None,
                          axis: int = 1, reverse: bool = False) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along ``axis`` (h_{-1} = h0 or 0)."""
    (A, B) = ref_scan(ops_alg.AFFINE, (a, b), axis=axis, reverse=reverse)
    if h0 is None:
        return B
    h0 = jnp.expand_dims(h0, axis)
    return A * h0 + B


# ---------------------------------------------------------------------------
# Batched primitives.  Per-family Python-loop oracles: each (B, ...) input is
# split into rows and the *flat* reference is applied per row -- deliberately
# sharing nothing with the grid-batched layout the kernels use, so batched
# kernel-vs-ref agreement checks the batching itself, not just the row math.
# ---------------------------------------------------------------------------


def _take_row(xs, i):
    return jax.tree.map(lambda l: l[i], xs)


def _stack_rows(rows, like):
    if not rows:                       # B == 0: zero-row leaves, shape known
        return jax.tree.map(lambda l: l[:0], like)
    return jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *rows)


def ref_batched_scan(op, xs: Pytree, *, inclusive: bool = True,
                     reverse: bool = False) -> Pytree:
    """Row-by-row flat scan of ``(B, n)`` leaves, restacked."""
    B = jax.tree.leaves(xs)[0].shape[0]
    rows = [ref_scan(op, _take_row(xs, i), axis=0, inclusive=inclusive,
                     reverse=reverse) for i in range(B)]
    return _stack_rows(rows, xs)


def ref_batched_mapreduce(f, op, xs: Pytree) -> Pytree:
    """Row-by-row op-reduce of ``f(row)`` -> one element per row.

    Length-0 rows (and B == 0 batches) yield ``op``'s identity per row --
    the reduction of zero elements.
    """
    B, n = jax.tree.leaves(xs)[0].shape[:2]
    one = jax.eval_shape(
        f, jax.tree.map(lambda l: jax.ShapeDtypeStruct((1,), l.dtype), xs))
    ident = op.identity(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((), l.dtype), one))
    if B == 0:
        return jax.tree.map(lambda l: jnp.zeros((0,), l.dtype), one)
    rows = [ident if n == 0 else ref_mapreduce(f, op, _take_row(xs, i))
            for i in range(B)]
    return _stack_rows(rows, None)


def _mv_row_identity(f, op, lhs_dtype, rhs_dtype, extent):
    """Identity row for a zero-term generalized matvec/vecmat reduction."""
    one = jax.eval_shape(
        f, jax.ShapeDtypeStruct((1, 1), lhs_dtype),
        jax.ShapeDtypeStruct((1, 1), rhs_dtype))
    return op.identity(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((extent,), l.dtype), one))


def ref_batched_matvec(f, op, A: jax.Array, x: jax.Array) -> Pytree:
    """Row-by-row :func:`ref_matvec` over (B, n, p) x (B, n).

    ``n == 0`` rows (zero reduction terms) yield ``op``'s identity.
    """
    B, n, p = A.shape
    if B == 0 or n == 0:
        ident = _mv_row_identity(f, op, x.dtype, A.dtype, p)
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l, (B,) + l.shape), ident)
    rows = [ref_matvec(f, op, A[b], x[b]) for b in range(B)]
    return _stack_rows(rows, None)


def ref_batched_vecmat(f, op, A: jax.Array, x: jax.Array) -> Pytree:
    """Row-by-row :func:`ref_vecmat` over (B, n, p) x (B, p)."""
    B, n, p = A.shape
    if B == 0 or p == 0:
        ident = _mv_row_identity(f, op, A.dtype, x.dtype, n)
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l, (B,) + l.shape), ident)
    rows = [ref_vecmat(f, op, A[b], x[b]) for b in range(B)]
    return _stack_rows(rows, None)


def ref_batched_linear_recurrence(a, b, h0=None, *, reverse: bool = False):
    """Sequential numpy time loop per batch row: h_t = a_t h_{t-1} + b_t.

    The most independent oracle available -- no associative_scan, no
    vectorized recurrence, just the defining equation stepped in order.
    """
    import numpy as np
    an, bn = np.asarray(a, np.float64), np.asarray(b, np.float64)
    B, T, C = an.shape
    out = np.zeros_like(bn)
    for i in range(B):
        h = (np.zeros((C,), np.float64) if h0 is None
             else np.asarray(h0, np.float64)[i])
        ts = range(T - 1, -1, -1) if reverse else range(T)
        for t in ts:
            h = an[i, t] * h + bn[i, t]
            out[i, t] = h
    return jnp.asarray(out.astype(np.asarray(b).dtype))


# ---------------------------------------------------------------------------
# Segmented primitives.  Oracles only: they require *concrete* segment
# descriptors and loop over segments in Python, applying the flat references
# per segment -- deliberately sharing no code with the lifted-operator
# construction the kernels use.
# ---------------------------------------------------------------------------


def _concrete_offsets(n, flags=None, offsets=None):
    import numpy as np
    if offsets is not None:
        offs = np.asarray(offsets).tolist()
    else:
        starts = np.flatnonzero(np.asarray(flags)).tolist()
        if not starts or starts[0] != 0:
            starts = [0] + starts
        offs = starts + [n]
    return offs


def ref_segmented_scan(op, xs: Pytree, *, flags=None, offsets=None,
                       inclusive: bool = True) -> Pytree:
    """Per-segment flat scan, concatenated back into the flat layout."""
    n = jax.tree.leaves(xs)[0].shape[0]
    if n == 0:
        return xs
    offs = _concrete_offsets(n, flags=flags, offsets=offsets)
    pieces = []
    for s, e in zip(offs[:-1], offs[1:]):
        if e > s:
            pieces.append(ref_scan(op, _take_slice(xs, 0, s, e),
                                   axis=0, inclusive=inclusive))
    return jax.tree.map(
        lambda *ls: jnp.concatenate(ls, axis=0), *pieces)


def ref_segmented_mapreduce(f, op, xs: Pytree, *, flags=None, offsets=None,
                            num_segments: int | None = None) -> Pytree:
    """Per-segment op-reduce of f(x); empty segments yield the identity."""
    n = jax.tree.leaves(xs)[0].shape[0]
    offs = _concrete_offsets(n, flags=flags, offsets=offsets)
    if num_segments is None:
        num_segments = len(offs) - 1
    one = jax.eval_shape(
        f, jax.tree.map(lambda l: jax.ShapeDtypeStruct((1,), l.dtype), xs))
    ident = op.identity(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((), l.dtype), one))
    results = []
    for i in range(num_segments):
        if i < len(offs) - 1 and offs[i + 1] > offs[i]:
            results.append(
                ref_mapreduce(f, op, _take_slice(xs, 0, offs[i], offs[i + 1])))
        else:
            results.append(ident)
    return jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *results)


# ---------------------------------------------------------------------------
# Sort / top-k oracles.  Deliberately numpy-based (lexsort + Python loops),
# sharing nothing with the radix composition: the pinned total order --
# numeric, -0.0 == +0.0, all NaNs equal and last ascending -- is re-derived
# here from a (nan-flag, value) lexicographic key instead of bit transforms.
# ---------------------------------------------------------------------------


def _np_sort_order(keys, descending: bool = False):
    """Stable sorting permutation under the pinned total order (numpy)."""
    import numpy as np
    a = np.asarray(keys)
    if a.dtype.kind not in "uif":          # bfloat16 et al: exact upcast
        a = a.astype(np.float32)
    n = a.shape[0]
    if a.dtype.kind in "ui":
        v = a.astype(np.int64)
        nanf = np.zeros(n, np.int64)
    else:
        v = a.astype(np.float64)
        nanf = np.isnan(v).astype(np.int64)
        v = np.where(nanf == 1, 0.0, v) + 0.0      # NaNs tie; -0.0 -> +0.0
    if descending:
        v, nanf = -v, -nanf
    return np.lexsort((v, nanf))           # stable: nan-flag first, then value


def ref_sort(keys, *, descending: bool = False):
    import numpy as np
    return jnp.asarray(np.asarray(keys)[_np_sort_order(keys, descending)])


def ref_sort_pairs(keys, values, *, descending: bool = False):
    import numpy as np
    order = _np_sort_order(keys, descending)
    return (jnp.asarray(np.asarray(keys)[order]),
            jax.tree.map(lambda l: jnp.asarray(np.asarray(l)[order]), values))


def ref_argsort(keys, *, descending: bool = False):
    return jnp.asarray(_np_sort_order(keys, descending).astype("int32"))


def ref_top_k(keys, k: int, *, largest: bool = True):
    import numpy as np
    order = _np_sort_order(keys, descending=largest)[:k]
    return (jnp.asarray(np.asarray(keys)[order]),
            jnp.asarray(order.astype(np.int32)))


def _topk_fill(dtype, largest):
    import numpy as np
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return -np.inf if largest else np.inf
    info = jnp.iinfo(dtype)
    return info.min if largest else info.max


def ref_segmented_sort(keys, *, flags=None, offsets=None,
                       descending: bool = False):
    import numpy as np
    a = np.asarray(keys)
    if a.shape[0] == 0:
        return jnp.asarray(a)
    offs = _concrete_offsets(a.shape[0], flags=flags, offsets=offsets)
    pieces = [a[s:e][_np_sort_order(a[s:e], descending)]
              for s, e in zip(offs[:-1], offs[1:]) if e > s]
    return jnp.asarray(np.concatenate(pieces))


def ref_segmented_sort_pairs(keys, values, *, flags=None, offsets=None,
                             descending: bool = False):
    import numpy as np
    a = np.asarray(keys)
    n = a.shape[0]
    if n == 0:
        return jnp.asarray(a), values
    offs = _concrete_offsets(n, flags=flags, offsets=offsets)
    orders = [s + _np_sort_order(a[s:e], descending)
              for s, e in zip(offs[:-1], offs[1:]) if e > s]
    order = np.concatenate(orders)
    return (jnp.asarray(a[order]),
            jax.tree.map(lambda l: jnp.asarray(np.asarray(l)[order]), values))


def ref_segmented_argsort(keys, *, flags=None, offsets=None,
                          descending: bool = False):
    import numpy as np
    a = np.asarray(keys)
    n = a.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    offs = _concrete_offsets(n, flags=flags, offsets=offsets)
    pieces = [_np_sort_order(a[s:e], descending)
              for s, e in zip(offs[:-1], offs[1:]) if e > s]
    return jnp.asarray(np.concatenate(pieces).astype(np.int32))


def ref_segmented_top_k(keys, k: int, *, flags=None, offsets=None,
                        num_segments=None, largest: bool = True):
    import numpy as np
    a = np.asarray(keys)
    n = a.shape[0]
    offs = (_concrete_offsets(n, flags=flags, offsets=offsets)
            if n else [0, 0])
    if num_segments is None:
        num_segments = len(offs) - 1
    fill = _topk_fill(a.dtype if a.dtype.kind in "uif" else jnp.float32,
                      largest)
    vals = np.full((num_segments, k), fill,
                   a.dtype if a.dtype.kind in "uif" else np.float32)
    idx = np.full((num_segments, k), -1, np.int32)
    for s in range(num_segments):
        if s >= len(offs) - 1 or offs[s + 1] <= offs[s]:
            continue
        seg = a[offs[s]:offs[s + 1]]
        order = _np_sort_order(seg, descending=largest)[:k]
        vals[s, :len(order)] = seg[order]
        idx[s, :len(order)] = order
    return jnp.asarray(vals.astype(np.asarray(keys).dtype)), jnp.asarray(idx)
