"""Pure-jnp oracles for every kernel (the ``ref.py`` contract).

These are deliberately written against independent JAX built-ins
(``lax.associative_scan``, ``jnp`` reductions) rather than sharing tile code
with the kernels, so that kernel-vs-ref agreement is a real check.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import operators as ops_alg

Pytree = Any


def ref_copy(x: jax.Array) -> jax.Array:
    return jnp.copy(x)


def ref_scan(op, xs: Pytree, axis: int = 0, inclusive: bool = True,
             reverse: bool = False) -> Pytree:
    """Inclusive/exclusive scan along ``axis`` with an arbitrary AssocOp."""
    out = jax.lax.associative_scan(op.combine, xs, axis=axis, reverse=reverse)
    if inclusive:
        return out
    # Exclusive: shift by one along axis, filling with the identity.
    ident = op.identity(_take_slice(xs, axis, 0, 1))

    def shift_leaf(o, i):
        if reverse:
            return jnp.concatenate([_slice_axis(o, axis, 1, None), i], axis=axis)
        return jnp.concatenate([i, _slice_axis(o, axis, 0, -1)], axis=axis)

    return jax.tree.map(shift_leaf, out, ident)


def _slice_axis(l, axis, start, stop):
    sl = [slice(None)] * l.ndim
    sl[axis] = slice(start, stop)
    return l[tuple(sl)]


def _take_slice(xs, axis, start, stop):
    return jax.tree.map(lambda l: _slice_axis(l, axis, start, stop), xs)


def ref_mapreduce(f, op, xs: Pytree, axis=None) -> Pytree:
    """op-reduce of f(x) over ``axis`` (None = all elements)."""
    vals = f(xs)
    if axis is None:
        vals = jax.tree.map(lambda l: l.reshape(-1), vals)
        axis = 0
    scanned = jax.lax.associative_scan(op.combine, vals, axis=axis)
    return jax.tree.map(lambda l: jnp.take(l, l.shape[axis] - 1, axis=axis), scanned)


def ref_matvec(f, op, A: jax.Array, x: jax.Array) -> Pytree:
    """y[j] = op_i f(x[i], A[i, j]); A is (n, p), x is (n,)."""
    vals = f(x[:, None], A)
    scanned = jax.lax.associative_scan(op.combine, vals, axis=0)
    return jax.tree.map(lambda l: l[-1], scanned)


def ref_vecmat(f, op, A: jax.Array, x: jax.Array) -> Pytree:
    """z[i] = op_j f(A[i, j], x[j]); A is (n, p), x is (p,)."""
    vals = f(A, x[None, :])
    scanned = jax.lax.associative_scan(op.combine, vals, axis=1)
    return jax.tree.map(lambda l: l[:, -1], scanned)


def ref_linear_recurrence(a: jax.Array, b: jax.Array, h0=None,
                          axis: int = 1, reverse: bool = False) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along ``axis`` (h_{-1} = h0 or 0)."""
    (A, B) = ref_scan(ops_alg.AFFINE, (a, b), axis=axis, reverse=reverse)
    if h0 is None:
        return B
    h0 = jnp.expand_dims(h0, axis)
    return A * h0 + B


# ---------------------------------------------------------------------------
# Segmented primitives.  Oracles only: they require *concrete* segment
# descriptors and loop over segments in Python, applying the flat references
# per segment -- deliberately sharing no code with the lifted-operator
# construction the kernels use.
# ---------------------------------------------------------------------------


def _concrete_offsets(n, flags=None, offsets=None):
    import numpy as np
    if offsets is not None:
        offs = np.asarray(offsets).tolist()
    else:
        starts = np.flatnonzero(np.asarray(flags)).tolist()
        if not starts or starts[0] != 0:
            starts = [0] + starts
        offs = starts + [n]
    return offs


def ref_segmented_scan(op, xs: Pytree, *, flags=None, offsets=None,
                       inclusive: bool = True) -> Pytree:
    """Per-segment flat scan, concatenated back into the flat layout."""
    n = jax.tree.leaves(xs)[0].shape[0]
    offs = _concrete_offsets(n, flags=flags, offsets=offsets)
    pieces = []
    for s, e in zip(offs[:-1], offs[1:]):
        if e > s:
            pieces.append(ref_scan(op, _take_slice(xs, 0, s, e),
                                   axis=0, inclusive=inclusive))
    return jax.tree.map(
        lambda *ls: jnp.concatenate(ls, axis=0), *pieces)


def ref_segmented_mapreduce(f, op, xs: Pytree, *, flags=None, offsets=None,
                            num_segments: int | None = None) -> Pytree:
    """Per-segment op-reduce of f(x); empty segments yield the identity."""
    n = jax.tree.leaves(xs)[0].shape[0]
    offs = _concrete_offsets(n, flags=flags, offsets=offsets)
    if num_segments is None:
        num_segments = len(offs) - 1
    one = jax.eval_shape(
        f, jax.tree.map(lambda l: jax.ShapeDtypeStruct((1,), l.dtype), xs))
    ident = op.identity(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((), l.dtype), one))
    results = []
    for i in range(num_segments):
        if i < len(offs) - 1 and offs[i + 1] > offs[i]:
            results.append(
                ref_mapreduce(f, op, _take_slice(xs, 0, offs[i], offs[i + 1])))
        else:
            results.append(ident)
    return jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *results)
