"""Version-compat shim for ``jax.experimental.pallas.tpu``.

The TPU compiler-params dataclass was renamed ``TPUCompilerParams`` ->
``CompilerParams`` across JAX releases.  Kernels import ``pltpu`` from here so
they are written against the current name and still run on older JAX.
"""
from __future__ import annotations

from jax.experimental import pallas as pl  # noqa: F401  (re-export)
from jax.experimental.pallas import tpu as pltpu

if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version-dependent
    pltpu.CompilerParams = pltpu.TPUCompilerParams

__all__ = ["pl", "pltpu"]
