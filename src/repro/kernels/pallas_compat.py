"""Version-compat shims for Pallas across the supported jax pins.

The per-lowering compiler-params dataclasses were renamed across JAX
releases: ``TPUCompilerParams`` -> ``CompilerParams`` (Mosaic-TPU),
``TritonCompilerParams`` -> ``CompilerParams`` (Triton), and
``GPUCompilerParams`` -> ``CompilerParams`` (Mosaic-GPU).  Kernels import
``pltpu`` / ``pltriton`` / ``plmgpu`` from here so they are written against
the current names and still run on the 0.4.37 pin.

:func:`gpu_compiler_params` builds Triton params tolerantly -- field names
drift between pins, so unknown fields are dropped rather than raising --
and returns ``None`` when no GPU lowering is importable at all, which
``pl.pallas_call`` accepts (interpret-mode calls never consult it).
"""
from __future__ import annotations

from jax.experimental import pallas as pl  # noqa: F401  (re-export)
from jax.experimental.pallas import tpu as pltpu

if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version-dependent
    pltpu.CompilerParams = pltpu.TPUCompilerParams

try:
    from jax.experimental.pallas import triton as pltriton
except ImportError:  # pragma: no cover - pin without a Triton lowering
    pltriton = None

if pltriton is not None and not hasattr(pltriton, "CompilerParams"):
    pltriton.CompilerParams = pltriton.TritonCompilerParams  # pragma: no cover

try:
    from jax.experimental.pallas import mosaic_gpu as plmgpu
except ImportError:  # pragma: no cover - pin without Mosaic-GPU
    plmgpu = None

if (plmgpu is not None and not hasattr(plmgpu, "CompilerParams")
        and hasattr(plmgpu, "GPUCompilerParams")):  # pragma: no cover
    plmgpu.CompilerParams = plmgpu.GPUCompilerParams


def gpu_compiler_params(num_warps: int | None = None,
                        num_stages: int | None = None):
    """Triton compiler params for ``pl.pallas_call``, or None without one."""
    if pltriton is None:  # pragma: no cover - pin without a Triton lowering
        return None
    fields = getattr(pltriton.CompilerParams, "__dataclass_fields__", {})
    kwargs = {k: v for k, v in
              (("num_warps", num_warps), ("num_stages", num_stages))
              if v is not None and k in fields}
    return pltriton.CompilerParams(**kwargs)


__all__ = ["pl", "pltpu", "pltriton", "plmgpu", "gpu_compiler_params"]
