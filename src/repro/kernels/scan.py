"""Single-pass scan kernels: the TPU adaptation of decoupled look-back.

Paper (§V-B): Merrill–Garland single-pass scan reads global memory exactly
once and writes exactly once per element (2n movement); inter-block prefix
propagation uses release/acquire status flags because CUDA thread blocks have
no execution-order guarantee.

TPU adaptation (DESIGN.md §2): Pallas grid steps on a TPU core execute
*sequentially*, so the look-back protocol collapses to an exact running carry
held in VMEM scratch -- the same 2n data movement, zero spinning, zero flag
traffic.  The block-local phase is unchanged in spirit: each grid step loads
``Nitem`` aligned tiles (vectorized HBM->VMEM transfer), scans them entirely
in registers via log-step shifted combines, applies the carry, and stores
exactly once.

Two layouts are provided:

* :func:`scan_1d_pallas` -- flat scan over ``(n,)`` pytree leaves with
  arbitrary associative (possibly non-commutative) operators.  Element order
  within a (R, 128) tile is row-major, so the in-tile scan is
  lane-scan -> sublane prefix of row totals -> broadcast combine.
* :func:`scan_channel_pallas` -- batched scan along the middle axis of
  ``(B, T, C)`` leaves (the layout of diagonal linear recurrences such as
  RG-LRU and mLSTM inter-chunk states).  Channels ride the 128 lanes, time
  rides sublanes: the scan needs *no cross-lane communication at all* -- the
  TPU-native answer to the paper's warp-shuffle scan.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import pltpu

from repro.core import intrinsics as ki

Pytree = Any


def _tile_likes(tree_shape, shape, leaves_dtypes):
    return jax.tree.unflatten(
        tree_shape, [jax.ShapeDtypeStruct(shape, d) for d in leaves_dtypes])


def _mask_tree(mask, x, ident):
    return jax.tree.map(lambda l, i: jnp.where(mask, l, i), x, ident)


# ---------------------------------------------------------------------------
# 1-D scan
# ---------------------------------------------------------------------------


def block_scan_rowmajor(op, treedef, dtypes, x, carry, *, rows, inclusive):
    """Scan one masked ``(rows, LANES)`` tile in row-major element order.

    ``carry`` is the running ``(1, 1)``-shaped pytree carried across the
    sequential grid.  Returns ``(out, new_carry)``.  Entirely in registers:

      1. scan along lanes within each row (row-major element order),
      2. prefix the per-row totals down the sublanes,
      3. broadcast-combine row prefixes back onto the lane scans.

    Shared by the flat 1-D kernel here and the grid-batched kernel
    (kernels/batched.py), which runs this exact body once per
    (row, block) grid step with a per-row carry reset.
    """
    cidx = jax.lax.broadcasted_iota(jnp.int32, (rows, ki.LANES), 1)
    lane_scan = ki.tile_scan(op, x, axis=1)
    row_tot = ki.tile_take_last(lane_scan, axis=1)           # (rows, 1)
    row_pref = ki.tile_scan(op, row_tot, axis=0)             # inclusive
    ident_col = op.identity(_tile_likes(treedef, (rows, 1), dtypes))
    row0 = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) == 0
    row_excl = jax.tree.map(
        lambda p, i: jnp.where(row0, i, jnp.roll(p, 1, axis=0)),
        row_pref, ident_col)
    local = op(row_excl, lane_scan)                          # broadcast over lanes

    incl = op(carry, local)                                  # broadcast over tile

    if inclusive:
        out = incl
    else:
        # exclusive[k] = inclusive[k-1]; the element entering each row 0 is
        # the previous row's last, and tile element (0, 0) gets the carry.
        prev_lane = jax.tree.map(lambda l: jnp.roll(l, 1, axis=1), incl)
        row_last = ki.tile_take_last(incl, axis=1)
        prev_row_last = jax.tree.map(
            lambda rl, c: jnp.where(row0, c, jnp.roll(rl, 1, axis=0)),
            row_last, carry)
        out = jax.tree.map(
            lambda pl_, prl: jnp.where(cidx == 0, prl, pl_),
            prev_lane, prev_row_last)

    new_carry = op(carry, ki.tile_take_last(row_pref, axis=0))
    return out, new_carry


def _scan1d_kernel(op, treedef, n, rows, inclusive, n_leaves, *refs):
    x_refs = refs[:n_leaves]
    o_refs = refs[n_leaves:2 * n_leaves]
    carry_refs = refs[2 * n_leaves:]
    g = pl.program_id(0)
    block = rows * ki.LANES

    dtypes = [r.dtype for r in x_refs]
    ident_tile = op.identity(_tile_likes(treedef, (rows, ki.LANES), dtypes))
    ident_carry = op.identity(
        _tile_likes(treedef, (1, 1), [r.dtype for r in carry_refs]))

    @pl.when(g == 0)
    def _init():
        for cr, ic in zip(carry_refs, jax.tree.leaves(ident_carry)):
            cr[...] = ic

    x = jax.tree.unflatten(
        treedef, [xr[...].reshape(rows, ki.LANES) for xr in x_refs])

    # Masked tail (vload_pattern analogue): OOB lanes read garbage; replace
    # with the operator identity so they cannot contaminate the carry.
    ridx = jax.lax.broadcasted_iota(jnp.int32, (rows, ki.LANES), 0)
    cidx = jax.lax.broadcasted_iota(jnp.int32, (rows, ki.LANES), 1)
    gidx = g * block + ridx * ki.LANES + cidx
    valid = gidx < n
    x = _mask_tree(valid, x, ident_tile)

    carry = jax.tree.unflatten(treedef, [cr[...] for cr in carry_refs])
    out, new_carry = block_scan_rowmajor(
        op, treedef, dtypes, x, carry, rows=rows, inclusive=inclusive)
    for cr, nc in zip(carry_refs, jax.tree.leaves(new_carry)):
        cr[...] = nc
    for orf, o in zip(o_refs, jax.tree.leaves(out)):
        orf[...] = o.reshape(-1)


def scan_1d_pallas(op, xs: Pytree, *, inclusive: bool = True,
                   policy: ki.TuningPolicy | None = None,
                   interpret: bool = False) -> Pytree:
    """Single-pass scan over flat ``(n,)`` pytree leaves."""
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    leaves, treedef = jax.tree.flatten(xs)
    n = leaves[0].shape[0]
    assert all(l.shape == (n,) for l in leaves), "1d scan: uniform leaf shapes"
    sub = max(ki.min_tile(l.dtype)[0] for l in leaves)
    rows = policy.nitem_scan * sub
    block = rows * ki.LANES
    grid = ki.cdiv(n, block)

    kernel = functools.partial(
        _scan1d_kernel, op, treedef, n, rows, inclusive, len(leaves))
    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda g: (g,)) for _ in leaves],
        out_specs=[pl.BlockSpec((block,), lambda g: (g,)) for _ in leaves],
        out_shape=[jax.ShapeDtypeStruct((n,), l.dtype) for l in leaves],
        scratch_shapes=[pltpu.VMEM((1, 1), l.dtype) for l in leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*leaves)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Channelwise scan along the middle axis of (B, T, C) -- recurrence layout
# ---------------------------------------------------------------------------


def _chan_kernel(op, treedef, t_extent, t_rows, inclusive, reverse, n_leaves,
                 *refs):
    x_refs = refs[:n_leaves]
    o_refs = refs[n_leaves:2 * n_leaves]
    carry_refs = refs[2 * n_leaves:]
    tb = pl.program_id(2)
    nt = pl.num_programs(2)

    carry_like = _tile_likes(treedef, (1, ki.LANES), [r.dtype for r in carry_refs])
    ident_carry = op.identity(carry_like)

    @pl.when(tb == 0)
    def _init():
        for cr, ic in zip(carry_refs, jax.tree.leaves(ident_carry)):
            cr[...] = ic

    x = jax.tree.unflatten(
        treedef, [xr[...].reshape(t_rows, ki.LANES) for xr in x_refs])

    tile_like = _tile_likes(treedef, (t_rows, ki.LANES), [r.dtype for r in x_refs])
    ident_tile = op.identity(tile_like)
    ridx = jax.lax.broadcasted_iota(jnp.int32, (t_rows, ki.LANES), 0)
    if reverse:
        # Grid walks T blocks back-to-front; flip in-tile so the combine
        # direction matches, then flip back on store.  After the flip, row r
        # corresponds to global time t_start + (t_rows - 1 - r).
        x = jax.tree.map(lambda l: jnp.flip(l, axis=0), x)
        t_start = (nt - 1 - tb) * t_rows
        valid = jnp.flip((t_start + ridx) < t_extent, axis=0)
    else:
        t_start = tb * t_rows
        valid = (t_start + ridx) < t_extent
    x = _mask_tree(valid, x, ident_tile)

    local = ki.tile_scan(op, x, axis=0)          # per-lane scan down sublanes
    carry = jax.tree.unflatten(treedef, [cr[...] for cr in carry_refs])
    incl = op(carry, local)

    if inclusive:
        out = incl
    else:
        out = jax.tree.map(
            lambda l, c: jnp.where(ridx == 0, c, jnp.roll(l, 1, axis=0)),
            incl, carry)

    new_carry = op(carry, ki.tile_take_last(local, axis=0))
    for cr, nc in zip(carry_refs, jax.tree.leaves(new_carry)):
        cr[...] = nc
    if reverse:
        out = jax.tree.map(lambda l: jnp.flip(l, axis=0), out)
    for orf, o in zip(o_refs, jax.tree.leaves(out)):
        orf[...] = o.reshape(1, t_rows, ki.LANES)


def scan_channel_pallas(op, xs: Pytree, *, inclusive: bool = True,
                        reverse: bool = False,
                        policy: ki.TuningPolicy | None = None,
                        interpret: bool = False) -> Pytree:
    """Scan along axis 1 of ``(B, T, C)`` leaves, independent per (b, c).

    Channels ride the lanes: no cross-lane combine is ever emitted.  This is
    the layout used by the RG-LRU / mLSTM linear recurrences.
    """
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    leaves, treedef = jax.tree.flatten(xs)
    B, T, C = leaves[0].shape
    assert all(l.shape == (B, T, C) for l in leaves)
    sub = max(ki.min_tile(l.dtype)[0] for l in leaves)
    t_rows = min(policy.nitem_scan * sub, max(sub, 1 << (max(T - 1, 1)).bit_length()))
    c_blocks = ki.cdiv(C, ki.LANES)
    t_blocks = ki.cdiv(T, t_rows)

    if reverse:
        def idx_map(b, c, t, _nt=t_blocks):
            return (b, _nt - 1 - t, c)
    else:
        def idx_map(b, c, t):
            return (b, t, c)

    kernel = functools.partial(
        _chan_kernel, op, treedef, T, t_rows, inclusive, reverse, len(leaves))
    out = pl.pallas_call(
        kernel,
        grid=(B, c_blocks, t_blocks),
        in_specs=[pl.BlockSpec((1, t_rows, ki.LANES), idx_map) for _ in leaves],
        out_specs=[pl.BlockSpec((1, t_rows, ki.LANES), idx_map) for _ in leaves],
        out_shape=[jax.ShapeDtypeStruct((B, T, C), l.dtype) for l in leaves],
        scratch_shapes=[pltpu.VMEM((1, ki.LANES), l.dtype) for l in leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*leaves)
    return jax.tree.unflatten(treedef, out)
