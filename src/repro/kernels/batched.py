"""Grid-batched primitive kernels: one launch for a whole batch of rows.

Serving and recurrent-model decode present *uniform* batches -- B independent
problems of identical extent (per-request candidate lists, per-request score
streams, per-head recurrences).  Dispatching the flat primitives per row pays
one kernel launch and one tuning lookup per request; ``vmap`` over the 1-D
kernels is not an option either (Pallas calls do not batch).  The portability
studies this repo tracks (Godoy et al., arXiv:2303.06195; Besard et al.,
arXiv:1604.03410) both find abstraction overhead concentrating exactly there:
dispatch/launch amplification on small per-item problems.

The batched family answers with a third grid-layout column next to the flat
and segmented ones: the batch rides a leading **parallel** grid dimension,
the per-row work keeps the flat kernels' sequential protocol on the *inner*
grid axis, and the per-row state (scan carry / mapreduce accumulator /
matvec output-block accumulator) resets at inner step 0 -- which, because the
inner axis is minor, is exactly the start of every new row.  One launch, one
tuning decision, B independent problems.

The kernel *bodies* are shared with the flat family -- see
``scan.block_scan_rowmajor``, ``mapreduce._mapreduce_kernel`` (``grid_axis``)
and ``matvec._matvec_kernel`` / ``matvec._vecmat_kernel`` (``batched``) --
so a correctness fix or a tiling improvement lands in both layouts at once.

Zero-extent edges (B == 0, n == 0) are handled by the dispatch wrappers in
kernels/ops.py; the kernels here require every grid dimension >= 1.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import pltpu

from repro.core import intrinsics as ki
from repro.kernels import mapreduce as mapreduce_k
from repro.kernels import matvec as matvec_k
from repro.kernels import scan as scan_k

Pytree = Any


# ---------------------------------------------------------------------------
# Batched scan: (B, n) leaves, scan along axis 1, independent per row.
# ---------------------------------------------------------------------------


def _batched_scan_kernel(op, treedef, n, rows, inclusive, n_leaves, *refs):
    x_refs = refs[:n_leaves]
    o_refs = refs[n_leaves:2 * n_leaves]
    carry_refs = refs[2 * n_leaves:]
    g = pl.program_id(1)            # within-row block (sequential, minor)
    block = rows * ki.LANES

    dtypes = [r.dtype for r in x_refs]
    ident_tile = op.identity(
        scan_k._tile_likes(treedef, (rows, ki.LANES), dtypes))
    ident_carry = op.identity(scan_k._tile_likes(treedef, (1, 1), dtypes))

    # Every row's first block resets the carry: rows are independent scans.
    @pl.when(g == 0)
    def _init():
        for cr, ic in zip(carry_refs, jax.tree.leaves(ident_carry)):
            cr[...] = ic

    x = jax.tree.unflatten(
        treedef, [xr[...].reshape(rows, ki.LANES) for xr in x_refs])
    ridx = jax.lax.broadcasted_iota(jnp.int32, (rows, ki.LANES), 0)
    cidx = jax.lax.broadcasted_iota(jnp.int32, (rows, ki.LANES), 1)
    valid = (g * block + ridx * ki.LANES + cidx) < n
    x = scan_k._mask_tree(valid, x, ident_tile)

    carry = jax.tree.unflatten(treedef, [cr[...] for cr in carry_refs])
    out, new_carry = scan_k.block_scan_rowmajor(
        op, treedef, dtypes, x, carry, rows=rows, inclusive=inclusive)
    for cr, nc in zip(carry_refs, jax.tree.leaves(new_carry)):
        cr[...] = nc
    for orf, o in zip(o_refs, jax.tree.leaves(out)):
        orf[...] = o.reshape(1, -1)


def batched_scan_pallas(op, xs: Pytree, *, inclusive: bool = True,
                        policy: ki.TuningPolicy | None = None,
                        interpret: bool = False) -> Pytree:
    """Per-row prefix scan over ``(B, n)`` pytree leaves, single launch."""
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    leaves, treedef = jax.tree.flatten(xs)
    B, n = leaves[0].shape
    assert all(l.shape == (B, n) for l in leaves), "batched scan: uniform leaves"
    sub = max(ki.min_tile(l.dtype)[0] for l in leaves)
    rows = policy.nitem_scan * sub
    block = rows * ki.LANES
    grid = (B, ki.cdiv(n, block))

    kernel = functools.partial(
        _batched_scan_kernel, op, treedef, n, rows, inclusive, len(leaves))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block), lambda b, g: (b, g))
                  for _ in leaves],
        out_specs=[pl.BlockSpec((1, block), lambda b, g: (b, g))
                   for _ in leaves],
        out_shape=[jax.ShapeDtypeStruct((B, n), l.dtype) for l in leaves],
        scratch_shapes=[pltpu.VMEM((1, 1), l.dtype) for l in leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*leaves)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Batched mapreduce: (B, n) leaves -> per-row scalars (B,).
# ---------------------------------------------------------------------------


def batched_mapreduce_pallas(f, op, xs: Pytree, *,
                             policy: ki.TuningPolicy | None = None,
                             interpret: bool = False) -> Pytree:
    """Per-row op-reduce of ``f(x)`` over ``(B, n)`` leaves, single launch.

    Commutative ``op`` only (same accumulate-tile argument as the flat
    kernel); non-commutative ops are routed through the batched scan by the
    dispatcher (kernels/ops.py).
    """
    assert op.commutative, \
        "batched_mapreduce kernel requires a commutative operator"
    policy = policy or ki.resolve_tuning("interpret" if interpret else None)
    in_leaves, in_treedef = jax.tree.flatten(xs)
    B, n = in_leaves[0].shape
    assert all(l.shape == (B, n) for l in in_leaves)

    out_shape_tree = jax.eval_shape(
        f, jax.tree.unflatten(
            in_treedef,
            [jax.ShapeDtypeStruct((1, ki.LANES), l.dtype) for l in in_leaves]))
    out_leaves, out_treedef = jax.tree.flatten(out_shape_tree)

    sub = max(ki.min_tile(l.dtype)[0] for l in in_leaves)
    rows = policy.nitem_reduce * sub
    block = rows * ki.LANES
    grid = (B, ki.cdiv(n, block))

    kernel = functools.partial(
        mapreduce_k._mapreduce_kernel, f, op, in_treedef, out_treedef, n,
        rows, len(in_leaves), len(out_leaves), 1)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block), lambda b, g: (b, g))
                  for _ in in_leaves],
        out_specs=[pl.BlockSpec((1, 1), lambda b, g: (b, 0))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((B, 1), l.dtype) for l in out_leaves],
        scratch_shapes=[pltpu.VMEM((rows, ki.LANES), l.dtype)
                        for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*in_leaves)
    return jax.tree.unflatten(out_treedef, [o[:, 0] for o in out])


# ---------------------------------------------------------------------------
# Batched matvec / vecmat: (B, n, p) matrices against per-row vectors.
# ---------------------------------------------------------------------------


def batched_matvec_pallas(f, op, A: jax.Array, x: jax.Array, *,
                          block_rows: int, block_cols: int,
                          interpret: bool = False) -> Pytree:
    """y[b, j] = op_i f(x[b, i], A[b, i, j]).  A: (B, n, p), x: (B, n)."""
    B, n, p = A.shape
    rn, cp = block_rows, block_cols
    out_leaves, out_treedef = matvec_k._out_struct(
        f, jax.ShapeDtypeStruct((1, 1), x.dtype),
        jax.ShapeDtypeStruct((1, 1), A.dtype))

    grid = (B, ki.cdiv(p, cp), ki.cdiv(n, rn))
    kernel = functools.partial(
        matvec_k._matvec_kernel, f, op, out_treedef, n, rn,
        len(out_leaves), True)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rn, 1), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, rn, cp), lambda b, j, i: (b, i, j)),
        ],
        out_specs=[pl.BlockSpec((1, 1, cp), lambda b, j, i: (b, 0, j))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((B, 1, p), l.dtype)
                   for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x.reshape(B, n, 1), A)
    return jax.tree.unflatten(out_treedef, [o.reshape(B, p) for o in out])


def batched_vecmat_pallas(f, op, A: jax.Array, x: jax.Array, *,
                          block_rows: int, block_cols: int,
                          interpret: bool = False) -> Pytree:
    """z[b, i] = op_j f(A[b, i, j], x[b, j]).  A: (B, n, p), x: (B, p)."""
    B, n, p = A.shape
    ri, cj = block_rows, block_cols
    out_leaves, out_treedef = matvec_k._out_struct(
        f, jax.ShapeDtypeStruct((1, 1), A.dtype),
        jax.ShapeDtypeStruct((1, 1), x.dtype))

    grid = (B, ki.cdiv(n, ri), ki.cdiv(p, cj))
    kernel = functools.partial(
        matvec_k._vecmat_kernel, f, op, out_treedef, p, cj,
        len(out_leaves), True)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, cj), lambda b, i, j: (b, 0, j)),
            pl.BlockSpec((1, ri, cj), lambda b, i, j: (b, i, j)),
        ],
        out_specs=[pl.BlockSpec((1, ri, 1), lambda b, i, j: (b, i, 0))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((B, n, 1), l.dtype)
                   for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x.reshape(B, 1, p), A)
    return jax.tree.unflatten(out_treedef, [o.reshape(B, n) for o in out])


def batched_matvec_quantized_pallas(f, op, q, x: jax.Array, *,
                                    block_rows: int, block_cols: int,
                                    interpret: bool = False) -> Pytree:
    """Batched matvec over a ``Quantized`` (B, n, p) matrix operand: the
    scale tiles ride the same (batch, stripe) grid as the value tiles, and
    the shared quantized kernel body dequantizes per tile (f32 accumulate).
    ``block_rows`` must be a multiple of ``q.block``."""
    B, n, p = q.shape
    rn, cp = block_rows, block_cols
    rpb = matvec_k._check_quant_blocks(rn, q)
    out_leaves, out_treedef = matvec_k._out_struct(
        f, jax.ShapeDtypeStruct((1, 1), x.dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.float32))

    grid = (B, ki.cdiv(p, cp), ki.cdiv(n, rn))
    kernel = functools.partial(
        matvec_k._matvec_q_kernel, f, op, out_treedef, n, rn, q.block,
        q.mode, True)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rn, 1), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, rn, cp), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, rpb, cp), lambda b, j, i: (b, i, j)),
        ],
        out_specs=[pl.BlockSpec((1, 1, cp), lambda b, j, i: (b, 0, j))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((B, 1, p), l.dtype)
                   for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x.reshape(B, n, 1), q.values, q.scales)
    return jax.tree.unflatten(out_treedef, [o.reshape(B, p) for o in out])


def batched_vecmat_quantized_pallas(f, op, q, x: jax.Array, *,
                                    block_rows: int, block_cols: int,
                                    interpret: bool = False) -> Pytree:
    """Batched vecmat over a ``Quantized`` (B, n, p) matrix operand; scale
    blocks tile the row axis exactly as in the flat quantized vecmat."""
    B, n, p = q.shape
    ri, cj = block_rows, block_cols
    rpb = matvec_k._check_quant_blocks(ri, q)
    out_leaves, out_treedef = matvec_k._out_struct(
        f, jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), x.dtype))

    grid = (B, ki.cdiv(n, ri), ki.cdiv(p, cj))
    kernel = functools.partial(
        matvec_k._vecmat_q_kernel, f, op, out_treedef, p, cj, ri, q.block,
        q.mode, True)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, cj), lambda b, i, j: (b, 0, j)),
            pl.BlockSpec((1, ri, cj), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, rpb, cj), lambda b, i, j: (b, i, j)),
        ],
        out_specs=[pl.BlockSpec((1, ri, 1), lambda b, i, j: (b, i, 0))
                   for _ in out_leaves],
        out_shape=[jax.ShapeDtypeStruct((B, n, 1), l.dtype)
                   for l in out_leaves],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x.reshape(B, 1, p), q.values, q.scales)
    return jax.tree.unflatten(out_treedef, [o.reshape(B, n) for o in out])
