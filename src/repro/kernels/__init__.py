"""Pallas TPU kernels for the KernelForge primitives.

Each kernel module provides ``<name>_pallas`` (pl.pallas_call + BlockSpec
VMEM tiling); ``ops.py`` holds the jit-ready wrappers + backend registration;
``ref.py`` the pure-jnp oracles used by the test suite.
"""
