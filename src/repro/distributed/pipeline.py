"""GPipe-style pipeline parallelism over the "pod" axis (optional feature).

On the multi-pod mesh the ``pod`` axis crosses the slower inter-pod links;
pipelining layers across pods trades the per-layer FSDP/TP collectives on
that axis for point-to-point microbatch handoffs (one ``ppermute`` of a
microbatch activation per stage step) -- the standard reason 1000+-node
deployments pipeline across the DCN boundary.

This module provides the schedule as a composable harness: a stage function
+ per-stage params stacked on a leading axis, lowered via ``shard_map`` over
``pod``.  Bubble fraction is (n_stages - 1) / (n_micro + n_stages - 1).
``tests/test_pipeline.py`` checks exact parity with sequential execution.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def gpipe_forward(mesh, stage_fn, stage_params, x, *, n_micro,
                  axis_name="pod"):
    """Run ``n_stages`` stage_fn's over the ``axis_name`` mesh axis.

    stage_params: pytree whose leaves have leading dim n_stages (stage i's
    slice lives on pod i).  x: (B, ...) with B divisible by n_micro.
    Returns stage_{n-1}(...stage_0(x)) with GPipe microbatching.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_stages = sizes[axis_name]
    B = x.shape[0]
    assert B % n_micro == 0
    mb = B // n_micro
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(params_local, xb):
        sid = jax.lax.axis_index(axis_name)
        params_local = jax.tree.map(lambda l: l[0], params_local)
        mbs = xb.reshape((n_micro, mb) + xb.shape[1:])
        recv = jnp.zeros((mb,) + xb.shape[1:], xb.dtype)
        outs = jnp.zeros_like(mbs)
        for t in range(n_micro + n_stages - 1):
            # Stage 0 injects microbatch t; others consume the handoff.
            feed_idx = min(max(t, 0), n_micro - 1)
            inject = (sid == 0) & (t < n_micro)
            inp = jnp.where(inject, mbs[feed_idx], recv)
            out = stage_fn(params_local, inp)
            # Last stage retires microbatch t - (n_stages - 1).
            ret = t - (n_stages - 1)
            if 0 <= ret < n_micro:
                retire = (sid == n_stages - 1)
                outs = outs.at[ret].set(jnp.where(retire, out, outs[ret]))
            recv = jax.lax.ppermute(out, axis_name, fwd_perm)
        # Result lives on the last stage; broadcast it to every pod so the
        # output is replicated along the axis (psum of one-hot contribution).
        mask = (jax.lax.axis_index(axis_name) == n_stages - 1)
        outs = jax.lax.psum(jnp.where(mask, outs, 0), axis_name)
        return outs.reshape(xb.shape)

    other_axes = [a for a in mesh.axis_names if a != axis_name]
    pspec = P(*([axis_name] + [None] * 0))

    def leaf_spec(l):
        return P(*([axis_name] + [None] * (l.ndim - 1)))

    in_specs = (jax.tree.map(leaf_spec, stage_params),
                P(*([None] * x.ndim)))
    fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                   out_specs=P(*([None] * x.ndim)), check_rep=False)
    return fn(stage_params, x)
