"""Device-spanning primitive lowerings: the ``@sharded`` routes.

The paper's thesis is that a thin layer of backend-agnostic intrinsics
(shuffles, ordered access, vectorized loads) is enough to build
vendor-competitive primitives portably.  The multi-device analogue of a warp
shuffle is a mesh collective, and this module is the analogue of
``kernels/*.py`` one level up: every ``primitive@sharded`` route lowers to

    the existing **local** route per shard  +  a **collective fold** derived
    from the operator algebra (``core.operators.collective_fold``)

with no new algorithmic code -- the cross-device step is the same monoid the
in-tile combines already implement:

* ``scan@sharded``      -- local scan per shard, then an exclusive
  cross-device scan of the per-shard carries (gathered totals folded in
  axis order, so non-commutative operators stay valid).
* ``mapreduce@sharded`` -- local reduce along leaf axis 0, then the
  operator's collective fold: psum/pmax/pmin (or the pmax+psum softmax /
  logsumexp rewrites) when the monoid allows, ``all_gather`` + fold
  otherwise.
* ``top_k@sharded``     -- per-shard top-k candidates, then a k-way partial
  merge of the gathered (value, global-index) candidates; tie-stability by
  global index is preserved because shards gather in axis order.
* ``sort_pairs@sharded`` -- shard-local sort, then a splitter exchange in
  portable form: gathered sorted runs are merged by cross-run rank
  (``searchsorted`` per run with the left/right side chosen by run order,
  the collision-free merge-path tie-break), and each shard keeps its slice
  of the global order.  The *compute* (local sort, ranking) is
  distributed; the portable merge step gathers the full stream per device,
  so per-device memory on that step is O(n) -- a backend with true
  splitter exchange (ppermute of run slices between ranked splitters)
  would replace the gather without touching the route's contract.

Two calling forms, selected by the layout descriptor
(``core.layout.Sharded``):

* ``mesh=`` given -- the global form: arguments are global arrays; the
  route wraps itself in ``shard_map`` over the named axis, padding uneven
  leading extents with the operator's identity (scan/mapreduce) or an
  order sentinel (sort family) and slicing the result back to size.
* ``mesh=None`` -- the in-mesh form: the caller is already inside a
  ``shard_map`` over the axis and passes its local shard; only the local
  compute and the collective fold are emitted.  This is how
  ``distributed/collectives.py`` dispatches the flash-decoding merge.

Registered for every backend in ``kernels/ops.py``; ``backend`` names the
backend the *local* routes dispatch to (the same spelling every primitive
uses), so ``pallas-interpret`` exercises the real kernel bodies and
``pallas-gpu`` runs the GPU lowerings under the collective composition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import intrinsics as ki
from repro.core import operators as alg

Pytree = object


def _axis_extent(mesh, axis_name: str) -> int:
    return int(mesh.shape[axis_name])


def _lead(xs) -> int:
    return jax.tree.leaves(xs)[0].shape[0]


def _pad_with(xs: Pytree, pad: int, fill: Pytree) -> Pytree:
    """Append ``pad`` copies of the (1,)-leading ``fill`` element."""
    return jax.tree.map(
        lambda l, f: jnp.concatenate(
            [l, jnp.broadcast_to(f, (pad,) + l.shape[1:])], axis=0),
        xs, fill)


def _order_sentinel(dtype, key_bits, extreme: str):
    """A key that sorts past every real key under the pinned total order.

    ``max``: canonical NaN for floats (NaN ranks above +inf), the integer
    max (capped to ``key_bits`` for unsigned small-range keys); ``min``:
    -inf / the integer min / 0.  Ties against real extremes resolve
    real-first because padding is appended after the stream.
    """
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.nan if extreme == "max" else -jnp.inf, dtype)
    info = jnp.iinfo(dtype)
    if extreme == "min":
        return jnp.asarray(info.min, dtype)
    if key_bits is not None and jnp.issubdtype(dtype, jnp.unsignedinteger):
        return jnp.asarray((1 << int(key_bits)) - 1, dtype)
    return jnp.asarray(info.max, dtype)


# ---------------------------------------------------------------------------
# scan@sharded
# ---------------------------------------------------------------------------


def _exclusive_carry(op: alg.AssocOp, total: Pytree, axis_name: str) -> Pytree:
    """Exclusive cross-device scan of per-shard totals, in axis order.

    ``all_gather`` stacks the (1,)-leading totals in axis-index order; the
    fold below combines exactly the shards *before* this one (a masked
    ordered fold over the static axis extent), so the carry is correct for
    non-commutative operators -- the distributed twin of the grid-carry
    protocol in kernels/scan.py.
    """
    g = jax.tree.map(lambda l: jax.lax.all_gather(l, axis_name, axis=0),
                     total)
    extent = jax.tree.leaves(g)[0].shape[0]
    rank = jax.lax.axis_index(axis_name)
    carry = op.identity(total)
    for i in range(extent):
        step = op(carry, jax.tree.map(lambda l: l[i], g))
        carry = jax.tree.map(
            lambda s, c: jnp.where(i < rank, s, c), step, carry)
    return carry


def _scan_local(op, xs_loc, *, axis_name, inclusive, backend, policy):
    incl = ki.dispatch("scan", None, backend, (op, xs_loc),
                       {"axis": 0, "inclusive": True, "reverse": False,
                        "policy": policy})
    total = jax.tree.map(lambda l: l[-1:], incl)
    carry = _exclusive_carry(op, total, axis_name)
    out = op(carry, incl)
    if not inclusive:
        # Shift right within the shard; slot 0 is exactly the carry (the
        # exclusive prefix of this shard's first element).
        out = jax.tree.map(
            lambda o, c: jnp.concatenate([c, o[:-1]], axis=0), out, carry)
    return out


@ki.sub_backend_alias
def sharded_scan(op, xs, *, axis_name, mesh, inclusive=True,
                 backend="xla", policy=None):
    if mesh is None:
        return _scan_local(op, xs, axis_name=axis_name, inclusive=inclusive,
                           backend=backend, policy=policy)
    shards = _axis_extent(mesh, axis_name)
    n = _lead(xs)
    n_pad = -(-n // shards) * shards
    if n_pad != n:
        ident = op.identity(jax.tree.map(lambda l: l[:1], xs))
        xs = _pad_with(xs, n_pad - n, ident)

    def local(xs_loc):
        return _scan_local(op, xs_loc, axis_name=axis_name,
                           inclusive=inclusive, backend=backend,
                           policy=policy)

    out = shard_map(local, mesh=mesh, in_specs=(P(axis_name),),
                    out_specs=P(axis_name), check_rep=False)(xs)
    if n_pad != n:
        out = jax.tree.map(lambda l: l[:n], out)
    return out


# ---------------------------------------------------------------------------
# mapreduce@sharded
# ---------------------------------------------------------------------------


def _fold_axis0(op, vals):
    """Balanced order-preserving fold of leaf axis 0 (any leaf ranks).

    Pairs adjacent elements each round -- a re-association, never a
    reordering, so it is exact for every associative operator including
    mixed-rank pytree elements (e.g. SOFTMAX_MERGE's (m, l, o)) that the
    uniform-shape tile combines cannot carry.
    """
    n = _lead(vals)
    while n > 1:
        even = n - (n % 2)
        lo = jax.tree.map(lambda l: l[0:even:2], vals)
        hi = jax.tree.map(lambda l: l[1:even:2], vals)
        merged = op(lo, hi)
        if n % 2:
            merged = jax.tree.map(
                lambda m, l: jnp.concatenate([m, l[even:]], axis=0),
                merged, vals)
        vals, n = merged, (n + 1) // 2
    return jax.tree.map(lambda l: l[0], vals)


def _reduce_local(op, vals_loc, *, backend, policy):
    """Reduce leaf axis 0 of the local shard, elementwise over the rest."""
    if all(l.ndim == 1 for l in jax.tree.leaves(vals_loc)):
        return ki.dispatch("mapreduce", None, backend,
                           (lambda v: v, op, vals_loc),
                           {"axis": None, "policy": policy})
    return _fold_axis0(op, vals_loc)


@ki.sub_backend_alias
def sharded_mapreduce(f, op, xs, *, axis_name, mesh, backend="xla",
                      policy=None):
    if mesh is None:
        part = _reduce_local(op, f(xs), backend=backend,
                             policy=policy)
        return alg.collective_fold(op, axis_name)(part)
    shards = _axis_extent(mesh, axis_name)
    n = _lead(xs)
    if n == 0:
        one = jax.eval_shape(f, jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((1,) + l.shape[1:], l.dtype), xs))
        return op.identity(jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), one))
    vals = f(xs)
    n_pad = -(-n // shards) * shards
    if n_pad != n:
        ident = op.identity(jax.tree.map(lambda l: l[:1], vals))
        vals = _pad_with(vals, n_pad - n, ident)

    def local(vals_loc):
        part = _reduce_local(op, vals_loc, backend=backend,
                             policy=policy)
        return alg.collective_fold(op, axis_name)(part)

    return shard_map(local, mesh=mesh, in_specs=(P(axis_name),),
                     out_specs=P(), check_rep=False)(vals)


# ---------------------------------------------------------------------------
# top_k@sharded
# ---------------------------------------------------------------------------


def _top_k_local(keys_loc, k, *, axis_name, largest, key_bits, backend,
                 policy):
    n_loc = keys_loc.shape[0]
    kk = min(k, n_loc)
    v, i = ki.dispatch("top_k", None, backend, (keys_loc, kk),
                       {"largest": largest, "key_bits": key_bits,
                        "policy": policy})
    gi = i + (jax.lax.axis_index(axis_name) * n_loc).astype(i.dtype)
    gv = jax.lax.all_gather(v, axis_name, axis=0)        # (S, kk), axis order
    ggi = jax.lax.all_gather(gi, axis_name, axis=0)
    shards = gv.shape[0]
    if k > shards * n_loc:
        raise ValueError(
            f"top_k@sharded: need 0 <= k <= n, got k={k}, "
            f"n={shards * n_loc}")
    # k-way partial merge: per-shard candidates are extreme-first and
    # tie-stable by local index; gathering in axis order makes the stable
    # merge sort tie-stable by *global* index -- identical to the flat
    # oracle's order.
    mv, mi = ki.dispatch("sort_pairs", None, backend,
                         (gv.reshape(-1), ggi.reshape(-1)),
                         {"descending": largest, "key_bits": key_bits,
                          "policy": policy})
    return mv[:k], mi[:k]


@ki.sub_backend_alias
def sharded_top_k(keys, k, *, axis_name, mesh, largest=True, key_bits=None,
                  backend="xla", policy=None):
    if k == 0:
        return keys[:0], jnp.zeros((0,), jnp.int32)
    if mesh is None:
        return _top_k_local(keys, k, axis_name=axis_name, largest=largest,
                            key_bits=key_bits, backend=backend,
                            policy=policy)
    n = keys.shape[0]
    if not 0 <= k <= n:
        raise ValueError(f"top_k@sharded: need 0 <= k <= n, got k={k}, n={n}")
    shards = _axis_extent(mesh, axis_name)
    n_pad = -(-n // shards) * shards
    if n_pad != n:
        # Pad with the key that *loses* every comparison, so padding can
        # only surface once all real candidates are taken (k <= n forbids
        # that); real extremes win ties because padding is appended last.
        sent = _order_sentinel(keys.dtype, key_bits,
                               "min" if largest else "max")
        keys = _pad_with(keys, n_pad - n, sent[None])

    def local(keys_loc):
        return _top_k_local(keys_loc, k, axis_name=axis_name,
                            largest=largest, key_bits=key_bits,
                            backend=backend, policy=policy)

    return shard_map(local, mesh=mesh, in_specs=(P(axis_name),),
                     out_specs=(P(), P()), check_rep=False)(keys)


# ---------------------------------------------------------------------------
# sort_pairs@sharded
# ---------------------------------------------------------------------------


def _sort_pairs_local(keys_loc, values_loc, *, axis_name, descending,
                      key_bits, backend, policy):
    n_loc = keys_loc.shape[0]
    ks, vs = ki.dispatch("sort_pairs", None, backend,
                         (keys_loc, values_loc),
                         {"descending": descending, "key_bits": key_bits,
                          "policy": policy})
    # Splitter exchange, portable form.  Ranks are computed on the pinned
    # radix bit order (descending = complemented bits); the side choice per
    # run pair is the collision-free merge-path tie-break: equal keys in an
    # earlier run precede equal keys in a later run, and local order breaks
    # ties within a run -- i.e. global stability.  One gather of the sorted
    # key runs (+ payload) crosses the wire; the rank bits are a pure local
    # function of the gathered keys, recomputed rather than re-gathered.
    gk = jax.lax.all_gather(ks, axis_name, axis=0)         # (S, n_loc)
    gv = jax.tree.map(lambda l: jax.lax.all_gather(l, axis_name, axis=0), vs)
    gb = alg.key_to_radix_bits(gk)
    if descending:
        gb = ~gb
    shards = gb.shape[0]
    rank_self = jax.lax.axis_index(axis_name)

    bits_all = gb.reshape(-1)
    run_id = jnp.repeat(jnp.arange(shards, dtype=jnp.int32), n_loc)
    rank_all = jnp.tile(jnp.arange(n_loc, dtype=jnp.int32), shards)
    for t in range(shards):
        right = jnp.searchsorted(gb[t], bits_all, side="right")
        left = jnp.searchsorted(gb[t], bits_all, side="left")
        cnt = jnp.where(run_id > t, right, left).astype(jnp.int32)
        rank_all = rank_all + jnp.where(run_id == t, 0, cnt)

    # My output slice of the merged order: global positions
    # [rank_self * n_loc, (rank_self + 1) * n_loc).
    pos = rank_all - rank_self * n_loc
    pos = jnp.where((pos >= 0) & (pos < n_loc), pos, n_loc)   # OOB -> drop
    out_k = jnp.zeros((n_loc,), gk.dtype).at[pos].set(
        gk.reshape(-1), mode="drop")
    out_v = jax.tree.map(
        lambda l: jnp.zeros((n_loc,) + l.shape[2:], l.dtype).at[pos].set(
            l.reshape((-1,) + l.shape[2:]), mode="drop"),
        gv)
    return out_k, out_v


@ki.sub_backend_alias
def sharded_sort_pairs(keys, values, *, axis_name, mesh, descending=False,
                       key_bits=None, backend="xla", policy=None):
    if mesh is None:
        return _sort_pairs_local(keys, values, axis_name=axis_name,
                                 descending=descending, key_bits=key_bits,
                                 backend=backend, policy=policy)
    n = keys.shape[0]
    if n == 0:
        return keys, values
    shards = _axis_extent(mesh, axis_name)
    n_pad = -(-n // shards) * shards
    if n_pad != n:
        # Padding sorts past every real key (ties resolve real-first), so
        # the [:n] slice of the merged stream is exactly the real sort.
        sent = _order_sentinel(keys.dtype, key_bits,
                               "min" if descending else "max")
        keys = _pad_with(keys, n_pad - n, sent[None])
        values = jax.tree.map(
            lambda l: jnp.concatenate(
                [l, jnp.zeros((n_pad - n,) + l.shape[1:], l.dtype)], axis=0),
            values)

    def local(keys_loc, values_loc):
        return _sort_pairs_local(keys_loc, values_loc, axis_name=axis_name,
                                 descending=descending, key_bits=key_bits,
                                 backend=backend, policy=policy)

    out_k, out_v = shard_map(
        local, mesh=mesh, in_specs=(P(axis_name), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name)), check_rep=False)(keys, values)
    if n_pad != n:
        out_k = out_k[:n]
        out_v = jax.tree.map(lambda l: l[:n], out_v)
    return out_k, out_v
