"""Device-spanning primitive lowerings: the staged ``@sharded`` routes.

The paper's thesis is that a thin layer of backend-agnostic intrinsics
(shuffles, ordered access, vectorized loads) is enough to build
vendor-competitive primitives portably.  The multi-device analogue of a warp
shuffle is a mesh collective, and this module is the analogue of
``kernels/*.py`` one level up -- with the same staging discipline the
kernels apply to DMA: every ``primitive@sharded`` route compiles to a
:class:`ShardPlan` of three stages,

    **local**      per-shard compute via the existing local route
                   ``-> (part, aux)``
    **collective** the cross-device fold of ``part``, built from the
                   operator algebra's :class:`~repro.core.operators.FoldSpec`
                   descriptor (``core.operators.collective_fold_spec``)
    **epilogue**   combine the folded result with the local ``aux``

and one driver, :func:`run_plan`, executes it.  Plans whose work is
elementwise over a non-stream axis declare ``chunk_axes``, and the driver
splits the operands into ``num_chunks`` slabs: with ``overlap=True`` the
collective for chunk *i* is dispatched as soon as chunk *i*'s local stage is
emitted -- before chunk *i+1*'s local stage -- so an async runtime (XLA with
``--xla_gpu_enable_async_collectives`` / the latency-hiding scheduler) can
run communication under the next chunk's compute.  ``overlap=False`` emits
every local stage, then every collective: the old blocking-barrier issue
order.  Both orders execute the *identical* per-chunk arithmetic, so they
are bit-identical by construction -- ``overlap`` is a scheduling knob, never
a numerics knob.  The chunk count is a tuned policy field
(``TuningPolicy.overlap_chunks``, raced on the topology-keyed ladder in
``core/tuning.py``).

The routes:

* ``scan@sharded``      -- local scan per shard, then an exclusive
  cross-device scan of the per-shard carries (gathered totals folded in
  axis order, so non-commutative operators stay valid).  Unchunkable: the
  stream axis is the scan axis.
* ``mapreduce@sharded`` -- local reduce along leaf axis 0, then the
  operator's collective fold.  Chunked along leaf axis 1 when every mapped
  leaf has one (the combine is elementwise over non-stream axes -- the same
  contract the tile kernels rely on when they slice elements into tiles).
* ``matvec@sharded`` / ``vecmat@sharded`` -- contraction-axis tensor
  parallelism: the contraction dimension (matvec rows / vecmat columns) is
  sharded, each device computes a strip partial with the local route, and
  the operator's collective fold (ADD -> psum for the decode GEMV) combines
  strip partials.  A ``< shards`` contraction remainder rides replicated
  and is folded in last by the epilogue, so uneven extents never pad the
  operand (no identity element of ``f`` exists in general).  Chunked along
  the *output* axis.
* ``linear_recurrence@sharded`` -- sequence (T) sharding for long-context
  prefill: local AFFINE scan per shard, exclusive cross-device carry of the
  per-shard (A, B) totals via the scan machinery, epilogue applies the
  incoming state.  Chunked along the channel axis.
* ``top_k@sharded``     -- per-shard top-k candidates, then a k-way partial
  merge of the gathered (value, global-index) candidates; tie-stability by
  global index is preserved because shards gather in axis order.
* ``sort_pairs@sharded`` -- shard-local sort, then a splitter exchange in
  portable form: gathered sorted runs are merged by cross-run rank
  (``searchsorted`` per run with the left/right side chosen by run order,
  the collision-free merge-path tie-break), and each shard keeps its slice
  of the global order.  The portable merge step gathers the full stream per
  device, so per-device memory on that step is O(n).

Two calling forms, selected by the layout descriptor
(``core.layout.Sharded``):

* ``mesh=`` given -- the global form: arguments are global arrays; the
  route wraps :func:`run_plan` in ``shard_map`` over the named axis,
  padding uneven leading extents with the operator's identity
  (scan/mapreduce), an order sentinel (sort family) or the affine identity
  (linear recurrence) and slicing the result back to size.
* ``mesh=None`` -- the in-mesh form: the caller is already inside a
  ``shard_map`` over the axis and passes its local shard; the plan runs
  directly.  This is how ``distributed/collectives.py`` dispatches the
  flash-decoding merge.

Registered for every backend in ``kernels/ops.py``; ``backend`` names the
backend the *local* stages dispatch to (the same spelling every primitive
uses), so ``pallas-interpret`` exercises the real kernel bodies and
``pallas-gpu`` runs the GPU lowerings under the collective composition.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.core import tuning

Pytree = object


def _axis_extent(mesh, axis_name: str) -> int:
    return int(mesh.shape[axis_name])


def _lead(xs) -> int:
    return jax.tree.leaves(xs)[0].shape[0]


def _pad_with(xs: Pytree, pad: int, fill: Pytree) -> Pytree:
    """Append ``pad`` copies of the (1,)-leading ``fill`` element."""
    return jax.tree.map(
        lambda l, f: jnp.concatenate(
            [l, jnp.broadcast_to(f, (pad,) + l.shape[1:])], axis=0),
        xs, fill)


def _order_sentinel(dtype, key_bits, extreme: str):
    """A key that sorts past every real key under the pinned total order.

    ``max``: canonical NaN for floats (NaN ranks above +inf), the integer
    max (capped to ``key_bits`` for unsigned small-range keys); ``min``:
    -inf / the integer min / 0.  Ties against real extremes resolve
    real-first because padding is appended after the stream.
    """
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.nan if extreme == "max" else -jnp.inf, dtype)
    info = jnp.iinfo(dtype)
    if extreme == "min":
        return jnp.asarray(info.min, dtype)
    if key_bits is not None and jnp.issubdtype(dtype, jnp.unsignedinteger):
        return jnp.asarray((1 << int(key_bits)) - 1, dtype)
    return jnp.asarray(info.max, dtype)


# ---------------------------------------------------------------------------
# The staged plan and its driver.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """One ``@sharded`` route, staged: local -> collective -> epilogue.

    ``local(*operands) -> (part, aux)`` runs the per-shard compute;
    ``collective(part) -> folded`` is the cross-device stage (built once
    from the operator's :class:`~repro.core.operators.FoldSpec` or the
    exclusive-carry machinery); ``epilogue(folded, aux) -> out`` combines.

    ``chunk_axes`` (None: unchunkable) gives, per operand, the axis along
    which the driver may slice that operand into independent slabs -- a
    ``None`` entry marks an operand that is replicated across chunks.  It
    must be an axis over which the plan's arithmetic is elementwise, so
    chunked and unchunked execution agree; chunk outputs are concatenated
    along ``out_axis``.  ``fold`` carries the FoldSpec (when the collective
    stage is an operator fold) for introspection -- e.g. the analytic byte
    models price the collective stage off ``fold.collectives``.
    """

    name: str
    local: Callable
    collective: Callable
    epilogue: Callable
    fold: alg.FoldSpec | None = None
    chunk_axes: tuple | None = None
    out_axis: int = 0


def dispatch_collective(plan: ShardPlan, part: Pytree) -> Pytree:
    """The collective issue point of :func:`run_plan`.

    Every cross-device stage of every plan funnels through this one
    module-level function, so its position in the emission order *is* the
    overlap schedule -- and tests/CI can monkeypatch it to count chunked
    collective dispatches (the overlap smoke).
    """
    return plan.collective(part)


def _chunk_slices(extent: int, num_chunks: int) -> list[tuple[int, int]]:
    """(start, size) per chunk: even split, remainder spread over the first
    chunks, empty chunks dropped (extent < num_chunks)."""
    num_chunks = max(1, int(num_chunks))
    base, rem = divmod(int(extent), num_chunks)
    out, start = [], 0
    for i in range(num_chunks):
        size = base + (1 if i < rem else 0)
        if size:
            out.append((start, size))
        start += size
    return out


def _chunk_take(operand, axis, start, size):
    if axis is None or operand is None:
        return operand
    return jax.tree.map(
        lambda l: jax.lax.slice_in_dim(l, start, start + size, axis=axis),
        operand)


def run_plan(plan: ShardPlan, operands: tuple, *, num_chunks: int = 1,
             overlap: bool = True) -> Pytree:
    """Execute a :class:`ShardPlan` over its operands.

    With ``num_chunks > 1`` on a chunkable plan, the operands are sliced
    along ``plan.chunk_axes`` and the stages run per chunk.  ``overlap``
    selects the collective *issue order* only -- local(0), collective(0),
    local(1), collective(1), ... (True: chunk i's fold is in flight while
    chunk i+1 computes) versus all locals then all collectives (False: the
    blocking-barrier shape).  Both orders run the same per-chunk arithmetic
    on the same slices, so the results are bit-identical.
    """
    axes = plan.chunk_axes
    slices = None
    if axes is not None and num_chunks > 1:
        extent = None
        for operand, axis in zip(operands, axes):
            if axis is not None and operand is not None:
                extent = jax.tree.leaves(operand)[0].shape[axis]
                break
        if extent:
            slices = _chunk_slices(extent, num_chunks)
    if slices is None or len(slices) <= 1:
        part, aux = plan.local(*operands)
        return plan.epilogue(dispatch_collective(plan, part), aux)
    chunks = [tuple(_chunk_take(o, ax, start, size)
                    for o, ax in zip(operands, axes))
              for start, size in slices]
    if overlap:
        staged = []
        for ops_c in chunks:
            part, aux = plan.local(*ops_c)
            staged.append((dispatch_collective(plan, part), aux))
    else:
        parts = [plan.local(*ops_c) for ops_c in chunks]
        staged = [(dispatch_collective(plan, part), aux)
                  for part, aux in parts]
    outs = [plan.epilogue(folded, aux) for folded, aux in staged]
    return jax.tree.map(
        lambda *ls: jnp.concatenate(ls, axis=plan.out_axis), *outs)


# ---------------------------------------------------------------------------
# scan@sharded
# ---------------------------------------------------------------------------


def _exclusive_carry(op: alg.AssocOp, total: Pytree, axis_name: str) -> Pytree:
    """Exclusive cross-device scan of per-shard totals, in axis order.

    ``all_gather`` stacks the totals in axis-index order; the fold below
    combines exactly the shards *before* this one (a masked ordered fold
    over the static axis extent), so the carry is correct for
    non-commutative operators -- the distributed twin of the grid-carry
    protocol in kernels/scan.py.  Works for totals of any leaf shape (the
    gather stacks a new leading axis).
    """
    g = jax.tree.map(lambda l: jax.lax.all_gather(l, axis_name, axis=0),
                     total)
    extent = jax.tree.leaves(g)[0].shape[0]
    rank = jax.lax.axis_index(axis_name)
    carry = op.identity(total)
    for i in range(extent):
        step = op(carry, jax.tree.map(lambda l: l[i], g))
        carry = jax.tree.map(
            lambda s, c: jnp.where(i < rank, s, c), step, carry)
    return carry


def _scan_plan(op, *, axis_name, inclusive, backend, policy) -> ShardPlan:
    def local(xs_loc):
        incl = ki.dispatch("scan", None, backend, (op, xs_loc),
                           {"axis": 0, "inclusive": True, "reverse": False,
                            "policy": policy})
        total = jax.tree.map(lambda l: l[-1:], incl)
        return total, incl

    def epilogue(carry, incl):
        out = op(carry, incl)
        if not inclusive:
            # Shift right within the shard; slot 0 is exactly the carry (the
            # exclusive prefix of this shard's first element).
            out = jax.tree.map(
                lambda o, c: jnp.concatenate([c, o[:-1]], axis=0), out, carry)
        return out

    return ShardPlan(
        name="scan@sharded", local=local,
        collective=lambda total: _exclusive_carry(op, total, axis_name),
        epilogue=epilogue)


@ki.sub_backend_alias
def sharded_scan(op, xs, *, axis_name, mesh, inclusive=True, overlap=True,
                 backend="xla", policy=None):
    plan = _scan_plan(op, axis_name=axis_name, inclusive=inclusive,
                      backend=backend, policy=policy)
    if mesh is None:
        return run_plan(plan, (xs,), overlap=overlap)
    shards = _axis_extent(mesh, axis_name)
    n = _lead(xs)
    n_pad = -(-n // shards) * shards
    if n_pad != n:
        ident = op.identity(jax.tree.map(lambda l: l[:1], xs))
        xs = _pad_with(xs, n_pad - n, ident)

    def local(xs_loc):
        return run_plan(plan, (xs_loc,), overlap=overlap)

    out = shard_map(local, mesh=mesh, in_specs=(P(axis_name),),
                    out_specs=P(axis_name), check_rep=False)(xs)
    if n_pad != n:
        out = jax.tree.map(lambda l: l[:n], out)
    return out


# ---------------------------------------------------------------------------
# mapreduce@sharded
# ---------------------------------------------------------------------------


def _fold_axis0(op, vals):
    """Balanced order-preserving fold of leaf axis 0 (any leaf ranks).

    Pairs adjacent elements each round -- a re-association, never a
    reordering, so it is exact for every associative operator including
    mixed-rank pytree elements (e.g. SOFTMAX_MERGE's (m, l, o)) that the
    uniform-shape tile combines cannot carry.
    """
    n = _lead(vals)
    while n > 1:
        even = n - (n % 2)
        lo = jax.tree.map(lambda l: l[0:even:2], vals)
        hi = jax.tree.map(lambda l: l[1:even:2], vals)
        merged = op(lo, hi)
        if n % 2:
            merged = jax.tree.map(
                lambda m, l: jnp.concatenate([m, l[even:]], axis=0),
                merged, vals)
        vals, n = merged, (n + 1) // 2
    return jax.tree.map(lambda l: l[0], vals)


def _reduce_local(op, vals_loc, *, backend, policy):
    """Reduce leaf axis 0 of the local shard, elementwise over the rest."""
    if all(l.ndim == 1 for l in jax.tree.leaves(vals_loc)):
        return ki.dispatch("mapreduce", None, backend,
                           (lambda v: v, op, vals_loc),
                           {"axis": None, "policy": policy})
    return _fold_axis0(op, vals_loc)


def _elementwise_chunk_axes(vals) -> tuple | None:
    """Chunk mapped values along leaf axis 1 when every leaf has one.

    Axis 0 is the reduced stream; the combine is elementwise over the rest
    (the contract the tile kernels already rely on when slicing elements
    into tiles), so slabbing axis 1 is exact.  Rank-1 leaves, or leaves
    whose axis-1 extents disagree, leave the plan unchunkable.
    """
    leaves = jax.tree.leaves(vals)
    if not leaves or any(l.ndim < 2 for l in leaves):
        return None
    if len({int(l.shape[1]) for l in leaves}) != 1:
        return None
    return (1,)


def _mapreduce_plan(op, *, axis_name, backend, policy,
                    chunk_axes) -> ShardPlan:
    spec = alg.collective_fold_spec(op)

    def local(vals_loc):
        return _reduce_local(op, vals_loc, backend=backend,
                             policy=policy), None

    return ShardPlan(
        name="mapreduce@sharded", local=local,
        collective=spec.build(axis_name),
        epilogue=lambda folded, aux: folded,
        fold=spec, chunk_axes=chunk_axes, out_axis=0)


@ki.sub_backend_alias
def sharded_mapreduce(f, op, xs, *, axis_name, mesh, overlap=True,
                      backend="xla", policy=None):
    num_chunks = tuning.resolve_overlap_chunks(policy, backend)
    if mesh is None:
        vals = f(xs)
        plan = _mapreduce_plan(op, axis_name=axis_name, backend=backend,
                               policy=policy,
                               chunk_axes=_elementwise_chunk_axes(vals))
        return run_plan(plan, (vals,), num_chunks=num_chunks,
                        overlap=overlap)
    shards = _axis_extent(mesh, axis_name)
    n = _lead(xs)
    if n == 0:
        one = jax.eval_shape(f, jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((1,) + l.shape[1:], l.dtype), xs))
        return op.identity(jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), one))
    vals = f(xs)
    n_pad = -(-n // shards) * shards
    if n_pad != n:
        ident = op.identity(jax.tree.map(lambda l: l[:1], vals))
        vals = _pad_with(vals, n_pad - n, ident)

    def local(vals_loc):
        plan = _mapreduce_plan(op, axis_name=axis_name, backend=backend,
                               policy=policy,
                               chunk_axes=_elementwise_chunk_axes(vals_loc))
        return run_plan(plan, (vals_loc,), num_chunks=num_chunks,
                        overlap=overlap)

    return shard_map(local, mesh=mesh, in_specs=(P(axis_name),),
                     out_specs=P(), check_rep=False)(vals)


# ---------------------------------------------------------------------------
# matvec@sharded / vecmat@sharded: contraction-axis tensor parallelism.
# ---------------------------------------------------------------------------


def _mv_plan(primitive, f, op, *, axis_name, backend, policy,
             remainder) -> ShardPlan:
    """Strip-partial plan shared by matvec (rows sharded, chunk output
    columns) and vecmat (columns sharded, chunk output rows).

    The contraction axis is sharded in contiguous blocks in axis order and
    the fold composes shards in axis order (the gather fallback for
    non-commutative operators), with the replicated remainder folded in
    last -- so the reduction order matches the flat route's element order
    exactly.
    """
    spec = alg.collective_fold_spec(op)
    out_chunk_axis = 1 if primitive == "matvec" else 0

    def local(A_loc, x_loc, *rem):
        part = ki.dispatch(primitive, None, backend, (f, op, A_loc, x_loc),
                           {"policy": policy})
        rem_part = None
        if remainder:
            A_rem, x_rem = rem
            rem_part = ki.dispatch(primitive, None, backend,
                                   (f, op, A_rem, x_rem), {"policy": policy})
        return part, rem_part

    def epilogue(folded, rem_part):
        if rem_part is None:
            return folded
        # Remainder rows/columns sit at the end of the contraction stream.
        return op(folded, rem_part)

    chunk_axes = ((out_chunk_axis, None, out_chunk_axis, None) if remainder
                  else (out_chunk_axis, None))
    return ShardPlan(
        name=f"{primitive}@sharded", local=local,
        collective=spec.build(axis_name), epilogue=epilogue,
        fold=spec, chunk_axes=chunk_axes, out_axis=0)


def _sharded_mv(primitive, f, op, A, x, *, axis_name, mesh, overlap,
                backend, policy):
    num_chunks = tuning.resolve_overlap_chunks(policy, backend)
    if mesh is None:
        plan = _mv_plan(primitive, f, op, axis_name=axis_name,
                        backend=backend, policy=policy, remainder=False)
        return run_plan(plan, (A, x), num_chunks=num_chunks, overlap=overlap)
    shards = _axis_extent(mesh, axis_name)
    contract_axis = 0 if primitive == "matvec" else 1
    n = A.shape[contract_axis]
    n_even = (n // shards) * shards
    if n_even == 0:
        # Fewer contraction elements than devices: nothing to distribute --
        # the flat route on the replicated operands is the whole problem.
        return ki.dispatch(primitive, None, backend, (f, op, A, x),
                           {"policy": policy})
    remainder = n_even != n
    plan = _mv_plan(primitive, f, op, axis_name=axis_name, backend=backend,
                    policy=policy, remainder=remainder)

    def local(*ops_loc):
        return run_plan(plan, ops_loc, num_chunks=num_chunks,
                        overlap=overlap)

    if primitive == "matvec":
        spec_even, spec_rep = P(axis_name, None), P(None, None)
        A_even, A_rem = A[:n_even], A[n_even:]
    else:
        spec_even, spec_rep = P(None, axis_name), P(None, None)
        A_even, A_rem = A[:, :n_even], A[:, n_even:]
    if remainder:
        args = (A_even, x[:n_even], A_rem, x[n_even:])
        in_specs = (spec_even, P(axis_name), spec_rep, P(None))
    else:
        args = (A_even, x)
        in_specs = (spec_even, P(axis_name))
    return shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)(*args)


@ki.sub_backend_alias
def sharded_matvec(f, op, A, x, *, axis_name, mesh, overlap=True,
                   backend="xla", policy=None):
    """y[j] = op_i f(x[i], A[i, j]) with rows i sharded over ``axis_name``."""
    return _sharded_mv("matvec", f, op, A, x, axis_name=axis_name, mesh=mesh,
                       overlap=overlap, backend=backend, policy=policy)


@ki.sub_backend_alias
def sharded_vecmat(f, op, A, x, *, axis_name, mesh, overlap=True,
                   backend="xla", policy=None):
    """z[i] = op_j f(A[i, j], x[j]) with columns j sharded over
    ``axis_name``."""
    return _sharded_mv("vecmat", f, op, A, x, axis_name=axis_name, mesh=mesh,
                       overlap=overlap, backend=backend, policy=policy)


# ---------------------------------------------------------------------------
# linear_recurrence@sharded: sequence (T) sharding with a cross-device carry.
# ---------------------------------------------------------------------------


def _linrec_plan(has_h0, *, axis_name, backend, policy) -> ShardPlan:
    def local(a_loc, b_loc, h0_c=None):
        incl_A, incl_B = ki.dispatch(
            "scan", None, backend, (alg.AFFINE, (a_loc, b_loc)),
            {"axis": 1, "inclusive": True, "reverse": False,
             "policy": policy})
        totals = (incl_A[:, -1], incl_B[:, -1])        # (B, C) each
        return totals, (incl_A, incl_B, h0_c)

    def collective(totals):
        # The affine maps of the shards before this one, composed in axis
        # order (AFFINE is non-commutative): E(h) = cA * h + cB.
        return _exclusive_carry(alg.AFFINE, totals, axis_name)

    def epilogue(carry, aux):
        cA, cB = carry
        incl_A, incl_B, h0_c = aux
        if h0_c is None:
            h = incl_A * cB[:, None, :] + incl_B
            # The first shard's carry is the identity (cB == 0); take its
            # incl_B directly so h0=None stays bit-identical to the flat
            # route, which never multiplies the absent state.
            return jnp.where(jax.lax.axis_index(axis_name) == 0, incl_B, h)
        h_in = cA * h0_c + cB
        return incl_A * h_in[:, None, :] + incl_B

    return ShardPlan(
        name="linear_recurrence@sharded", local=local, collective=collective,
        epilogue=epilogue, chunk_axes=(2, 2, 1) if has_h0 else (2, 2),
        out_axis=2)


@ki.sub_backend_alias
def sharded_linear_recurrence(a, b, *, h0=None, axis_name, mesh,
                              overlap=True, backend="xla", policy=None):
    """h_t = a_t * h_{t-1} + b_t over (B, T, C) with T sharded over
    ``axis_name``; ``h0`` (B, C) is replicated."""
    num_chunks = tuning.resolve_overlap_chunks(policy, backend)
    plan = _linrec_plan(h0 is not None, axis_name=axis_name, backend=backend,
                        policy=policy)
    operands = (a, b) if h0 is None else (a, b, h0)
    if mesh is None:
        return run_plan(plan, operands, num_chunks=num_chunks,
                        overlap=overlap)
    T = a.shape[1]
    if T == 0:
        return b
    shards = _axis_extent(mesh, axis_name)
    if shards == 1:
        # Degenerate axis: the flat route, bitwise.
        return ki.dispatch("linear_recurrence", None, backend, (a, b),
                           {"h0": h0, "reverse": False, "policy": policy})
    t_pad = -(-T // shards) * shards
    if t_pad != T:
        # The affine identity (a=1, b=0) propagates the running state
        # unchanged through padded steps, so the [:T] slice is exact.
        pad = t_pad - T
        a = jnp.concatenate(
            [a, jnp.ones((a.shape[0], pad, a.shape[2]), a.dtype)], axis=1)
        b = jnp.concatenate(
            [b, jnp.zeros((b.shape[0], pad, b.shape[2]), b.dtype)], axis=1)

    def local(*ops_loc):
        return run_plan(plan, ops_loc, num_chunks=num_chunks,
                        overlap=overlap)

    in_specs = (P(None, axis_name, None), P(None, axis_name, None))
    args = (a, b)
    if h0 is not None:
        in_specs += (P(None, None),)
        args += (h0,)
    h = shard_map(local, mesh=mesh, in_specs=in_specs,
                  out_specs=P(None, axis_name, None), check_rep=False)(*args)
    if t_pad != T:
        h = h[:, :T]
    return h


# ---------------------------------------------------------------------------
# top_k@sharded
# ---------------------------------------------------------------------------


def _top_k_plan(k, *, axis_name, largest, key_bits, backend,
                policy) -> ShardPlan:
    def local(keys_loc):
        n_loc = keys_loc.shape[0]
        kk = min(k, n_loc)
        v, i = ki.dispatch("top_k", None, backend, (keys_loc, kk),
                           {"largest": largest, "key_bits": key_bits,
                            "policy": policy})
        gi = i + (jax.lax.axis_index(axis_name) * n_loc).astype(i.dtype)
        return (v, gi), n_loc

    def collective(part):
        return jax.tree.map(
            lambda l: jax.lax.all_gather(l, axis_name, axis=0), part)

    def epilogue(gathered, n_loc):
        gv, ggi = gathered                              # (S, kk), axis order
        shards = gv.shape[0]
        if k > shards * n_loc:
            raise ValueError(
                f"top_k@sharded: need 0 <= k <= n, got k={k}, "
                f"n={shards * n_loc}")
        # k-way partial merge: per-shard candidates are extreme-first and
        # tie-stable by local index; gathering in axis order makes the
        # stable merge sort tie-stable by *global* index -- identical to the
        # flat oracle's order.
        mv, mi = ki.dispatch("sort_pairs", None, backend,
                             (gv.reshape(-1), ggi.reshape(-1)),
                             {"descending": largest, "key_bits": key_bits,
                              "policy": policy})
        return mv[:k], mi[:k]

    return ShardPlan(name="top_k@sharded", local=local,
                     collective=collective, epilogue=epilogue)


@ki.sub_backend_alias
def sharded_top_k(keys, k, *, axis_name, mesh, largest=True, key_bits=None,
                  overlap=True, backend="xla", policy=None):
    if k == 0:
        return keys[:0], jnp.zeros((0,), jnp.int32)
    plan = _top_k_plan(k, axis_name=axis_name, largest=largest,
                       key_bits=key_bits, backend=backend, policy=policy)
    if mesh is None:
        return run_plan(plan, (keys,), overlap=overlap)
    n = keys.shape[0]
    if not 0 <= k <= n:
        raise ValueError(f"top_k@sharded: need 0 <= k <= n, got k={k}, n={n}")
    shards = _axis_extent(mesh, axis_name)
    n_pad = -(-n // shards) * shards
    if n_pad != n:
        # Pad with the key that *loses* every comparison, so padding can
        # only surface once all real candidates are taken (k <= n forbids
        # that); real extremes win ties because padding is appended last.
        sent = _order_sentinel(keys.dtype, key_bits,
                               "min" if largest else "max")
        keys = _pad_with(keys, n_pad - n, sent[None])

    def local(keys_loc):
        return run_plan(plan, (keys_loc,), overlap=overlap)

    return shard_map(local, mesh=mesh, in_specs=(P(axis_name),),
                     out_specs=(P(), P()), check_rep=False)(keys)


# ---------------------------------------------------------------------------
# sort_pairs@sharded
# ---------------------------------------------------------------------------


def _sort_pairs_plan(*, axis_name, descending, key_bits, backend,
                     policy) -> ShardPlan:
    def local(keys_loc, values_loc):
        ks, vs = ki.dispatch("sort_pairs", None, backend,
                             (keys_loc, values_loc),
                             {"descending": descending, "key_bits": key_bits,
                              "policy": policy})
        return (ks, vs), None

    def collective(part):
        ks, vs = part
        gk = jax.lax.all_gather(ks, axis_name, axis=0)     # (S, n_loc)
        gv = jax.tree.map(
            lambda l: jax.lax.all_gather(l, axis_name, axis=0), vs)
        return gk, gv

    def epilogue(gathered, aux):
        # Splitter exchange, portable form.  Ranks are computed on the
        # pinned radix bit order (descending = complemented bits); the side
        # choice per run pair is the collision-free merge-path tie-break:
        # equal keys in an earlier run precede equal keys in a later run,
        # and local order breaks ties within a run -- i.e. global stability.
        # One gather of the sorted key runs (+ payload) crosses the wire;
        # the rank bits are a pure local function of the gathered keys,
        # recomputed rather than re-gathered.
        gk, gv = gathered
        gb = alg.key_to_radix_bits(gk)
        if descending:
            gb = ~gb
        shards, n_loc = gb.shape
        rank_self = jax.lax.axis_index(axis_name)

        bits_all = gb.reshape(-1)
        run_id = jnp.repeat(jnp.arange(shards, dtype=jnp.int32), n_loc)
        rank_all = jnp.tile(jnp.arange(n_loc, dtype=jnp.int32), shards)
        for t in range(shards):
            right = jnp.searchsorted(gb[t], bits_all, side="right")
            left = jnp.searchsorted(gb[t], bits_all, side="left")
            cnt = jnp.where(run_id > t, right, left).astype(jnp.int32)
            rank_all = rank_all + jnp.where(run_id == t, 0, cnt)

        # My output slice of the merged order: global positions
        # [rank_self * n_loc, (rank_self + 1) * n_loc).
        pos = rank_all - rank_self * n_loc
        pos = jnp.where((pos >= 0) & (pos < n_loc), pos, n_loc)  # OOB -> drop
        out_k = jnp.zeros((n_loc,), gk.dtype).at[pos].set(
            gk.reshape(-1), mode="drop")
        out_v = jax.tree.map(
            lambda l: jnp.zeros((n_loc,) + l.shape[2:], l.dtype).at[pos].set(
                l.reshape((-1,) + l.shape[2:]), mode="drop"),
            gv)
        return out_k, out_v

    return ShardPlan(name="sort_pairs@sharded", local=local,
                     collective=collective, epilogue=epilogue)


@ki.sub_backend_alias
def sharded_sort_pairs(keys, values, *, axis_name, mesh, descending=False,
                       key_bits=None, overlap=True, backend="xla",
                       policy=None):
    plan = _sort_pairs_plan(axis_name=axis_name, descending=descending,
                            key_bits=key_bits, backend=backend, policy=policy)
    if mesh is None:
        return run_plan(plan, (keys, values), overlap=overlap)
    n = keys.shape[0]
    if n == 0:
        return keys, values
    shards = _axis_extent(mesh, axis_name)
    n_pad = -(-n // shards) * shards
    if n_pad != n:
        # Padding sorts past every real key (ties resolve real-first), so
        # the [:n] slice of the merged stream is exactly the real sort.
        sent = _order_sentinel(keys.dtype, key_bits,
                               "min" if descending else "max")
        keys = _pad_with(keys, n_pad - n, sent[None])
        values = jax.tree.map(
            lambda l: jnp.concatenate(
                [l, jnp.zeros((n_pad - n,) + l.shape[1:], l.dtype)], axis=0),
            values)

    def local(keys_loc, values_loc):
        return run_plan(plan, (keys_loc, values_loc), overlap=overlap)

    out_k, out_v = shard_map(
        local, mesh=mesh, in_specs=(P(axis_name), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name)), check_rep=False)(keys, values)
    if n_pad != n:
        out_k = out_k[:n]
        out_v = jax.tree.map(lambda l: l[:n], out_v)
    return out_k, out_v
