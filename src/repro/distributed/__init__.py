"""Distribution layer: sharding policy, collectives, pipeline."""
