"""Sharding policy: logical-axis rules + per-parameter PartitionSpecs.

Fixed production mesh axes: ``("data", "model")`` single-pod or
``("pod", "data", "model")`` multi-pod; ``dp = ("pod","data")`` carries
batch + FSDP, ``model`` carries TP / SP / EP.

Per-arch policy (DESIGN.md §5):

* **TP** (Megatron) when ``n_heads % |model| == 0``: attention heads +
  d_ff + vocab on ``model``; residual stream replicated along seq.
* **SP** otherwise (gemma3 H=8, minitron H=24, dscoder H=56, rg H=10,
  xlstm H=4): the residual stream is sharded along *seq* on ``model``;
  attention/MLP weights that cannot shard on heads become pure ZeRO-3
  (sharded over dp x model jointly, gathered at use); d_ff stays TP.
* **EP**: experts on ``model`` in all cases.
* **FSDP/ZeRO**: every parameter additionally shards its non-TP major axis
  over dp; optimizer state inherits parameter specs.

Activation rules are consumed by ``models.layers.shard`` via logical names;
parameter specs are derived structurally from pytree paths + shapes.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _div(n: int, k: int) -> bool:
    return n > 0 and n % k == 0


def make_rules(cfg, mesh: Mesh | None) -> dict | None:
    """Logical-axis -> mesh-axis rules for activations (None = unsharded)."""
    if mesh is None:
        return None
    sizes = mesh_axis_sizes(mesh)
    m = sizes.get("model", 1)
    dp = dp_axes(mesh)
    tp = _div(cfg.n_heads, m)
    rules = {
        "_dp": dp,
        "_tp": tp,
        "batch": dp,
        "heads": "model" if tp else None,
        "kv_heads": "model" if (tp and _div(cfg.n_kv_heads, m)) else None,
        "seq_sp": None if tp else "model",
        "ffn": "model",
        "ffn_inner": None,
        "experts": "model",
        "vocab": "model" if (tp and _div(cfg.vocab_size, m)) else None,
        # Recurrent-width activations stay unsharded on model: the rnn archs
        # (rg H=10, xlstm H=4) are SP archs, so seq carries the model axis.
        "rnn": None,
        # Decode: when KV heads cannot shard on model, shard the cache's
        # *sequence* axis instead and merge partial softmaxes across the
        # axis (flash-decoding, distributed/collectives.py).  MLA caches are
        # compressed (no head axis) and always sequence-shard at decode.
        "_mesh": mesh,
        "decode_kv_shard": not (tp and _div(cfg.n_kv_heads, m)),
        "decode_mla_shard": True,
        # shard_map EP (zero-collective dispatch) needs experts divisible by
        # the model axis and a model-replicated residual stream (TP archs).
        "moe_shard_map": tp and _div(cfg.n_experts, m),
    }
    if os.environ.get("REPRO_BASELINE"):
        # Paper-faithful baseline lowering (EXPERIMENTS.md §Perf "before"):
        # replicated decode caches, GSPMD capacity-MoE dispatch.
        rules["decode_kv_shard"] = False
        rules["decode_mla_shard"] = False
        rules["moe_shard_map"] = False
    return rules


# ---------------------------------------------------------------------------
# Parameter specs (structural, path + shape based)
# ---------------------------------------------------------------------------


def _zero3(shape, axis, dp, m, sizes):
    """Spec sharding ``axis`` of ``shape`` over dp (+model when divisible)."""
    total_dp = int(np.prod([sizes[a] for a in dp])) if dp else 1
    full = total_dp * sizes.get("model", 1)
    spec = [None] * len(shape)
    if _div(shape[axis], full):
        spec[axis] = tuple(dp) + ("model",)
    elif _div(shape[axis], total_dp):
        spec[axis] = tuple(dp)
    return P(*spec)


def _dp_spec(shape, axis, dp, sizes):
    total_dp = int(np.prod([sizes[a] for a in dp])) if dp else 1
    spec = [None] * len(shape)
    if _div(shape[axis], total_dp):
        spec[axis] = tuple(dp)
    return P(*spec)


def param_spec(path: str, shape: tuple, cfg, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, keyed on its tree path."""
    _parts = path.split("/")
    if "units" in _parts:
        # Stacked scan-over-layers params: leading n_units axis is never
        # sharded; spec the per-layer shape and prepend None.
        _parts.remove("units")
        inner = param_spec("/".join(_parts), shape[1:], cfg, mesh)
        return P(*((None,) + tuple(inner)))
    sizes = mesh_axis_sizes(mesh)
    m = sizes.get("model", 1)
    dp = dp_axes(mesh)
    tp = _div(cfg.n_heads, m)
    name = path.split("/")[-1]
    nd = len(shape)

    def dpax(axis):
        return _dp_spec(shape, axis, dp, sizes)

    def z3(axis):
        return _zero3(shape, axis, dp, m, sizes)

    if name == "embedding":                       # (V, D)
        v_ok = _div(shape[0], m)
        return P("model" if v_ok else None,
                 dp if _div(shape[1], int(np.prod([sizes[a] for a in dp]))) else None)
    if name == "unembed":                         # (D, V)
        return P(dp, "model" if _div(shape[1], m) else None)
    if "mixer" in path and name in ("wq", "wk", "wv") and nd == 3:
        # Block-diagonal mixer weights (H, p, p): shard block dims.
        total_dp = int(np.prod([sizes[a] for a in dp])) if dp else 1
        return P(None,
                 dp if _div(shape[1], total_dp) else None,
                 "model" if _div(shape[2], m) else None)
    if name in ("wq", "wk", "wv") and nd == 3:    # (D, H, hd)
        h_ok = _div(shape[1], m)
        return P(dp, "model", None) if h_ok else z3(0)
    if name == "wo" and nd == 3:                  # (H, hd, D)
        h_ok = _div(shape[0], m)
        return P("model", None, dp) if h_ok else z3(2)
    if name in ("w_uq", "w_uk", "w_uv") and nd == 3:  # (r, H, k) -- MLA
        return P(None, "model" if _div(shape[1], m) else None, None)
    if name in ("w_dq", "w_dkv") and nd == 2:     # (D, r)
        return dpax(0)
    if "moe" in path and name in ("w_in", "w_gate", "w_out") and nd == 3:
        # (E, D, F) / (E, F, D): expert parallelism on model.
        e_ok = _div(shape[0], m)
        if name == "w_out":
            return P("model" if e_ok else None, None, dp)
        return P("model" if e_ok else None, dp, None)
    if name == "w_in" and nd == 3:                # slstm (D, 4, D)
        return P(dp, None, "model" if _div(shape[2], m) else None)
    if name in ("w_in", "w_gate", "w_up", "wx", "wy") and nd == 2:  # (D, F)
        return P(dp, "model" if _div(shape[1], m) else None)
    if name in ("w_out", "w_down", "wo") and nd == 2:               # (F, D)
        return P("model" if _div(shape[0], m) else None, dp)
    if name == "router":
        return dpax(0)
    if name == "kernel" and nd == 2:              # conv (W, C)
        return P(None, "model" if _div(shape[1], m) else None)
    if name == "proj" and nd == 2:                # mtp proj (2D, D)
        return dpax(0)
    if name in ("vr", "vc"):                      # adafactor factored moments
        return dpax(0) if nd >= 1 else P()
    if name == "r" or nd <= 1:                    # blockdiag / scales / biases
        return P()
    if nd >= 2:                                   # fallback: FSDP on axis 0
        return dpax(0)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params_shape: Pytree, cfg, mesh: Mesh) -> Pytree:
    """Tree of PartitionSpecs matching a params (shape) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(_path_str(path), leaf.shape, cfg, mesh),
        params_shape)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch_shape: dict, cfg, mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    out = {}
    for k, v in batch_shape.items():
        spec = [None] * len(v.shape)
        sizes = mesh_axis_sizes(mesh)
        total_dp = int(np.prod([sizes[a] for a in dp])) if dp else 1
        if _div(v.shape[0], total_dp):
            spec[0] = dp
        out[k] = P(*spec)
    return out


def cache_spec(path: str, shape: tuple, cfg, mesh: Mesh) -> P:
    """Decode-cache sharding: batch over dp; kv-heads on model when legal."""
    parts = path.split("/")
    if "units" in parts:
        # Stacked scan-over-layers caches: skip the leading n_units axis.
        parts.remove("units")
        inner = cache_spec("/".join(parts), shape[1:], cfg, mesh)
        return P(*((None,) + tuple(inner)))
    sizes = mesh_axis_sizes(mesh)
    m = sizes.get("model", 1)
    dp = dp_axes(mesh)
    total_dp = int(np.prod([sizes[a] for a in dp])) if dp else 1
    tp = _div(cfg.n_heads, m)
    name = path.split("/")[-1]
    b_ok = _div(shape[0], total_dp)
    b = dp if b_ok else None
    nd = len(shape)
    baseline = bool(os.environ.get("REPRO_BASELINE"))
    if name in ("k", "v") and nd == 4:            # (B, L, K, hd)
        kv_ok = tp and _div(shape[2], m)
        if kv_ok:
            return P(b, None, "model", None)
        if baseline:                              # replicated over model
            return P(b, None, None, None)
        # Flash-decoding layout: sequence axis sharded over model.
        return P(b, "model" if _div(shape[1], m) else None, None, None)
    if name in ("ckv", "krope") and nd == 3:      # (B, L, r) -- MLA
        # Compressed caches are small; seq-sharding them measured as a
        # regression at decode (EXPERIMENTS.md §Perf) -- keep replicated.
        return P(b, None, None)
    if name == "C" and nd == 4:                   # (B, H, dk, dv) -- mlstm
        return P(b, "model" if _div(shape[1], m) else None, None, None)
    if name in ("n",) and nd == 3:
        return P(b, "model" if _div(shape[1], m) else None, None)
    if name in ("h", "c", "m") and nd == 2:       # (B, w)
        return P(b, "model" if _div(shape[1], m) else None)
    if name == "conv" and nd == 3:                # (B, W-1, C)
        return P(b, None, "model" if _div(shape[2], m) else None)
    if nd >= 1 and b_ok:
        return P(*([b] + [None] * (nd - 1)))
    return P()


def cache_specs(cache_shape: Pytree, cfg, mesh: Mesh) -> Pytree:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(_path_str(path), leaf.shape, cfg, mesh),
        cache_shape)


def named(mesh: Mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
