"""Distributed decode attention: flash-decoding over the model axis.

Baseline pathology (EXPERIMENTS.md §Perf): for archs whose KV heads do not
divide the model axis (gemma3 K=4, minitron K=8, dscoder K=8, MQA K=1) the
decode cache was *replicated* across the 16 model-axis chips -- every chip
streamed the whole 32k-deep cache per token (memory term) and the ZeRO-3
parameter gathers piled onto that (collective term).

Fix: shard the cache along the *sequence* axis over ``model`` and give each
chip a partial softmax over its slice; the partials (m, l, o) form the
``SOFTMAX_MERGE`` monoid from the core operator algebra -- the distributed
combine IS ``mapreduce(SOFTMAX_MERGE, layout=Sharded("model"))``.  That
route now compiles to a staged ShardPlan (distributed/primitives.py): the
local reduce is one stage, and the operator's registered
:class:`~repro.core.operators.FoldSpec` (``pmax`` + two ``psum``) is the
collective stage the plan driver issues -- chunked along the partials'
row axis so later chunks' local math overlaps earlier chunks' collectives
(``tests/test_sharded.py`` pins the equivalence to the operator fold).
What used to be a hand-staged two-phase merge here is exactly the shape
the plan driver emits; no hand-rolled collective remains -- the merge
dispatches through the same registry route every other consumer uses.

Per-chip traffic drops from O(L) to O(L/16) cache reads plus O(B*H*hd)
collective bytes -- a ~16x cut of the decode memory term at the cost of a
tiny all-reduce (the §Perf before/after numbers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Sharded

NEG_INF = -1e30


def _partial_softmax(s, v):
    """s: (..., L) masked scores fp32; v: (..., L, hd).  -> (m, l, o)."""
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    # Rows with no valid key on this shard: m == NEG_INF, p must be 0.
    p = jnp.where(m[..., None] <= NEG_INF / 2, 0.0, p)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("...t,...td->...d", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def merge_partials(m, l, o, axis_name):
    """SOFTMAX_MERGE folded across ``axis_name``, via the @sharded route.

    Dispatches ``mapreduce(SOFTMAX_MERGE, layout=Sharded(axis_name))`` in
    its in-mesh form: each device contributes its one partial (a length-1
    stream along leaf axis 0) and the staged plan issues the operator's
    registered collective fold -- m* = pmax m; w = exp(m - m*);
    l* = psum(w l); o* = psum(w o) -- per batch-row chunk, so the fold for
    one chunk of rows flies while the next chunk reduces.

    Rows masked on **every** shard (batch-padding rows during decode) have
    l* == 0 and an o* that may carry masked garbage (0 * NaN from poisoned
    cache slots); dividing by the 1e-30 clamp would amplify it, so such
    rows return explicit zeros instead.
    """
    m_g, l_g, o_g = forge.mapreduce(
        lambda t: t, alg.SOFTMAX_MERGE,
        jax.tree.map(lambda t: t[None], (m, l, o)),
        layout=Sharded(axis_name))
    out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
    all_masked = m_g <= NEG_INF / 2
    return jnp.where(all_masked[..., None], jnp.zeros_like(out), out)


def _local_ring_update(cache_loc, new_row, slot, axis_name="model"):
    """Owner-shard cache write at traced ``slot`` on the sharded seq axis.

    A jnp-level dynamic_update_slice at a traced position on a sharded axis
    makes GSPMD all-gather the whole cache (the 86 GB/step pathology in the
    §Perf decode iteration); done shard-locally it is free.
    """
    L_loc = cache_loc.shape[1]
    start = jax.lax.axis_index(axis_name) * L_loc
    rel = jnp.clip(slot - start, 0, L_loc - 1)
    owns = (slot >= start) & (slot < start + L_loc)
    updated = jax.lax.dynamic_update_slice_in_dim(
        cache_loc, new_row.astype(cache_loc.dtype), rel, axis=1)
    return jnp.where(owns, updated, cache_loc)


def flash_decode_gqa(mesh, q, k_cache, v_cache, k_new, v_new, slot,
                     key_valid, *, softcap=0.0, batch_sharded=True):
    """Sequence-sharded decode attention with in-shard cache update.

    q: (B, 1, K, G, hd) replicated over model; caches: (B, L, K, hd) with L
    sharded over "model"; k_new/v_new: (B, 1, K, hd); slot: scalar write
    position; key_valid: (L,) bool (already accounting for the new token).
    Returns (out, new_k_cache, new_v_cache).
    """
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    b = dp if batch_sharded else None

    def local(qb, kb, vb, knb, vnb, slot_, validb):
        kb = _local_ring_update(kb, knb, slot_)
        vb = _local_ring_update(vb, vnb, slot_)
        s = jnp.einsum("bskgd,btkd->bskgt", qb.astype(jnp.float32) * scale,
                       kb.astype(jnp.float32))
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(validb[None, None, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)                      # (B,1,K,G)
        p = jnp.exp(s - m[..., None])
        p = jnp.where(m[..., None] <= NEG_INF / 2, 0.0, p)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bskgt,btkd->bskgd", p, vb.astype(jnp.float32))
        out = merge_partials(m, l, o, "model")
        return out.astype(qb.dtype), kb, vb

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(b, None, None, None, None),
                  P(b, "model", None, None),
                  P(b, "model", None, None),
                  P(b, None, None, None),
                  P(b, None, None, None),
                  P(),
                  P("model")),
        out_specs=(P(b, None, None, None, None),
                   P(b, "model", None, None),
                   P(b, "model", None, None)),
        check_rep=False)
    return fn(q, k_cache, v_cache, k_new, v_new, slot, key_valid)


def flash_decode_mla(mesh, q_abs, q_rope, ckv, krope, ckv_new, krope_new,
                     slot, key_valid, *, scale, batch_sharded=True):
    """Sequence-sharded MLA decode in the compressed latent space.

    q_abs: (B,1,H,r) and q_rope: (B,1,H,rd) replicated over model;
    ckv: (B,L,r), krope: (B,L,rd) with L sharded over "model";
    ckv_new/krope_new: (B,1,*) this step's compressed KV; slot: write pos.
    Returns (ctx: (B,1,H,r), new_ckv, new_krope).
    """
    def local(qa, qr, cb, kb, cnb, knb, slot_, validb):
        cb = _local_ring_update(cb, cnb, slot_)
        kb = _local_ring_update(kb, knb, slot_)
        s = (jnp.einsum("bshr,btr->bsht", qa.astype(jnp.float32),
                        cb.astype(jnp.float32)) +
             jnp.einsum("bshr,btr->bsht", qr.astype(jnp.float32),
                        kb.astype(jnp.float32))) * scale
        s = jnp.where(validb[None, None, None, :], s, NEG_INF)
        # cb broadcast over (s=1, H): v -> (B,1,1,t,r); o: (B,1,H,r).
        m, l, o = _partial_softmax(s, cb.astype(jnp.float32)[:, None, None])
        out = merge_partials(m, l, o, "model")
        return out, cb, kb

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    b = dp if batch_sharded else None
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(b, None, None, None), P(b, None, None, None),
                  P(b, "model", None), P(b, "model", None),
                  P(b, None, None), P(b, None, None), P(), P("model")),
        out_specs=(P(b, None, None, None),
                   P(b, "model", None), P(b, "model", None)),
        check_rep=False)
    return fn(q_abs, q_rope, ckv, krope, ckv_new, krope_new, slot, key_valid)
