"""shard_map MoE: dispatch without collectives (the dsv3 §Perf fix).

Baseline pathology: the GSPMD lowering of the sort-based capacity dispatch
(models/moe.py) all-gathers token buffers across the mesh -- the argsort and
scatter are *global* over tokens, so XLA materializes gathered operands:
deepseek-v3 train_4k showed a 3963s collective term vs 48s of compute.

Key observation: the residual stream is already **replicated across the
model axis** within each data-parallel row (activations are P(dp, None,
None)).  So every model rank can compute routing locally and simply *take*
the tokens destined for its own expert slice -- the dispatch "all-to-all"
costs zero bytes.  Only the combine needs communication: one psum of the
[T_local, D] output per MoE layer (what a dense TP layer pays anyway), which
also carries the shared-expert partial sums for free.

Per-layer collectives:  before: O(T*D) gathers of dispatch buffers;
after: 1 all-reduce of T_local x D (+ the FSDP weight gathers both pay).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Segmented, Sharded
from repro.models import layers as L


def _gather_axis(w, axis, dp_axes):
    """All-gather a weight's FSDP-sharded dim inside shard_map (ZeRO-3)."""
    for a in dp_axes:
        w = jax.lax.all_gather(w, a, axis=axis, tiled=True)
    return w


def moe_forward_sharded(params, cfg, x, mesh):
    """Drop-in replacement for moe_forward under a ("data","model") mesh.

    x: (B, S, D) with batch sharded over dp and replicated over model.
    Experts are sharded over "model" (EP); expert weights' D axis is
    FSDP-sharded over dp (gathered per layer, as GSPMD FSDP would).
    """
    dtype = x.dtype
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_total = int(np.prod([sizes[a] for a in dp_axes])) if dp_axes else 1
    E_loc = E // m
    T_loc = (B // dp_total) * S if B % dp_total == 0 else B * S
    # Per-expert capacity.  The denominator is the *global* expert count on
    # purpose: C bounds tokens **per expert id** (the `pos < C` cap below
    # counts within one expert's run of the sorted stream), and the dispatch
    # buffer allocates C slots for each of the E_loc local experts -- so
    # under expert parallelism (E_loc < E) every local expert still holds up
    # to its full even-share x capacity_factor.  Dividing by E_loc instead
    # would inflate capacity m-fold, not fix a drop.  tests/test_sharded.py
    # pins the E_loc != E no-drop parity at capacity_factor=1.0 with
    # exactly-even routing.
    C = int(np.ceil(T_loc * k * cfg.capacity_factor / E))
    C = max(8, ((C + 7) // 8) * 8)
    gated = "w_gate" in params
    shared = params.get("shared", {})
    has_shared = "w_in" in shared
    shared_gated = "w_gate" in shared

    def local(xb, router, router_bias, w_in, w_out, *rest):
        rest = list(rest)
        w_gate = rest.pop(0) if gated else None
        shared_in = rest.pop(0) if has_shared else None
        shared_gate = rest.pop(0) if shared_gated else None
        shared_out = rest.pop(0) if has_shared else None
        xf = xb.reshape(-1, D)                         # (T_loc, D)
        router = _gather_axis(router, 0, dp_axes)      # (D, E)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
        if cfg.router_type == "sigmoid":
            scores = jax.nn.sigmoid(logits)
            sel = scores + router_bias[None, :]
            _, idx = jax.lax.top_k(sel, k)
            gates = jnp.take_along_axis(scores, idx, axis=1)
            gates = gates / jnp.maximum(jnp.sum(gates, 1, keepdims=True), 1e-9)
            probs = scores / jnp.maximum(jnp.sum(scores, 1, keepdims=True), 1e-9)
        else:
            probs = jax.nn.softmax(logits, axis=-1)
            gates, idx = jax.lax.top_k(probs, k)
            gates = gates / jnp.maximum(jnp.sum(gates, 1, keepdims=True), 1e-9)

        # ---- router statistics: global across the data axes, through the
        # mapreduce@sharded route (in-mesh form).  The route is a staged
        # ShardPlan whose collective stage is the ADD FoldSpec's psum --
        # the same psum this replaced, but the expert-count reduction now
        # rides the same registry route (and overlap-capable plan driver)
        # as every other consumer; global counts / mean-probs make lb_loss
        # the whole-batch statistic rather than a mean of per-shard
        # products.
        def dp_mean(v):
            for a in dp_axes:
                v = forge.mapreduce(lambda t: t, alg.ADD, v[None],
                                    layout=Sharded(a)) / sizes[a]
            return v

        counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
        frac = dp_mean(counts / idx.size)
        lb_loss = E * jnp.sum(frac * dp_mean(jnp.mean(probs, axis=0)))
        router_z = dp_mean(jnp.mean(jnp.square(
            jax.scipy.special.logsumexp(logits, axis=-1))))

        # ---- local dispatch (identical math on every model rank) ----
        flat_e = idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T_loc), k)
        flat_g = gates.reshape(-1)
        # Expert-sorted stream via the stable radix sort_pairs primitive
        # (the portable replacement for the global XLA argsort): expert ids
        # span only ceil(log2(E)) bits, so key_bits= caps the sort at 1-2
        # digit passes instead of a full 32-bit comparison sort.
        se, (st, sg) = forge.sort_pairs(
            flat_e.astype(jnp.uint32), (flat_t, flat_g),
            key_bits=max(1, (E - 1).bit_length()))
        se = se.astype(jnp.int32)
        # Within-expert slot index = exclusive segmented +scan of ones over
        # the expert-sorted stream (segment = run of equal expert id).  This
        # is the ragged expert grouping done natively -- no E-sized
        # counts/starts scatter, no padded intermediate.
        run_flags = jnp.concatenate(
            [jnp.ones((1,), jnp.int32), (se[1:] != se[:-1]).astype(jnp.int32)])
        pos = forge.scan(
            alg.ADD, jnp.ones_like(se, jnp.int32), inclusive=False,
            layout=Segmented(flags=run_flags))
        keep = pos < C

        # ---- take only MY experts (zero-collective "all-to-all") ----
        my_e0 = jax.lax.axis_index("model") * E_loc
        mine = keep & (se >= my_e0) & (se < my_e0 + E_loc)
        se_rel = jnp.where(mine, se - my_e0, E_loc)    # OOB -> dropped
        xbuf = jnp.zeros((E_loc, C, D), dtype)
        xbuf = xbuf.at[se_rel, pos].set(
            xf[st] * mine[:, None].astype(dtype), mode="drop")

        w_in_g = _gather_axis(w_in, 1, dp_axes)        # (E_loc, D, F)
        h = jnp.einsum("ecd,edf->ecf", xbuf, w_in_g.astype(dtype))
        if gated:
            w_gate_g = _gather_axis(w_gate, 1, dp_axes)
            g = jnp.einsum("ecd,edf->ecf", xbuf, w_gate_g.astype(dtype))
            h = (jax.nn.silu(g) if cfg.activation == "swiglu"
                 else jax.nn.gelu(g)) * h
        else:
            h = jax.nn.gelu(h)
        w_out_g = _gather_axis(w_out, 2, dp_axes)      # (E_loc, F, D)
        y = jnp.einsum("ecf,efd->ecd", h, w_out_g.astype(dtype))

        gathered = y[se_rel.clip(0, E_loc - 1), pos.clip(0, C - 1)]
        contrib = gathered * (sg * mine).astype(dtype)[:, None]
        out = jnp.zeros((T_loc, D), dtype).at[st].add(contrib)

        # ---- shared expert: F sharded over model -> fold into same psum ----
        if shared_in is not None:
            s_in = _gather_axis(shared_in, 0, dp_axes)     # (D, F_loc)
            hs = jnp.einsum("td,df->tf", xf, s_in.astype(dtype))
            if shared_gate is not None:
                s_g = _gather_axis(shared_gate, 0, dp_axes)
                gs = jnp.einsum("td,df->tf", xf, s_g.astype(dtype))
                hs = (jax.nn.silu(gs) if cfg.activation == "swiglu"
                      else jax.nn.gelu(gs)) * hs
            else:
                hs = jax.nn.gelu(hs)
            s_out = _gather_axis(shared_out, 1, dp_axes)   # (F_loc, D)
            out = out + jnp.einsum("tf,fd->td", hs, s_out.astype(dtype))

        out = jax.lax.psum(out, "model")
        return (out.reshape(-1, S, D), lb_loss, router_z)

    dp = dp_axes if (B % dp_total == 0 and dp_total > 1) else None
    args = [x, params["router"], params["router_bias"],
            params["w_in"], params["w_out"]]
    specs = [P(dp, None, None),
             P(dp_axes, None),                    # router (D, E)
             P(),                                 # router bias
             P("model", dp_axes, None),           # w_in (E, D, F)
             P("model", None, dp_axes)]           # w_out (E, F, D)
    if gated:
        args.append(params["w_gate"])
        specs.append(P("model", dp_axes, None))
    if has_shared:
        args.append(shared["w_in"])
        specs.append(P(dp_axes, "model"))
        if shared_gated:
            args.append(shared["w_gate"])
            specs.append(P(dp_axes, "model"))
        args.append(shared["w_out"])
        specs.append(P("model", dp_axes))
    fn = shard_map(
        local, mesh=mesh,
        in_specs=tuple(specs),
        out_specs=(P(dp, None, None), P(), P()),
        check_rep=False)
    out, lb, rz = fn(*args)
    return out, {"lb_loss": lb, "router_z": rz}
