"""internvl2-76b [vlm]  [arXiv:2404.16821; unverified]

LM backbone: 80L, d_model=8192, 64H (GQA kv=8, head_dim=128), d_ff=28672,
vocab=128256 (Llama-3-70B backbone of InternVL2-Llama3-76B).  The InternViT
frontend is a STUB per the task spec: ``input_specs`` provides 256
precomputed patch embeddings at d_model, prepended to the token sequence.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    unit=("attn_global",),
    n_units=80,
    activation="swiglu",
    rope_theta=500000.0,
    num_prefix_embeds=256,
    tie_embeddings=False,
    quadratic=True,
)

SMOKE = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    unit=("attn_global",),
    n_units=3,
    activation="swiglu",
    num_prefix_embeds=8,
    tie_embeddings=False,
    quadratic=True,
)

register(FULL, SMOKE)
