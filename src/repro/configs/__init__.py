"""Per-architecture configs (one module per assigned arch).

Importing this package registers every architecture; use
``repro.configs.base.get_config(name)`` / ``list_archs()``.
"""
from repro.configs import (  # noqa: F401
    deepseek_coder_33b,
    deepseek_v3_671b,
    gemma2_27b,
    gemma3_4b,
    internvl2_76b,
    minitron_4b,
    moonshot_v1_16b_a3b,
    recurrentgemma_2b,
    seamless_m4t_medium,
    xlstm_1p3b,
)
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    input_specs,
    list_archs,
)
