"""moonshot-v1-16b-a3b [moe]  [hf:moonshotai/Moonlight-16B-A3B; hf]

48L, d_model=2048, 16H (GQA kv=16? head_dim=128), vocab=163840.
MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408, sigmoid router
(DeepSeek-V3-style aux-free); first layer dense (d_ff=11264, per the HF
config of Moonlight).
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=11264,                # dense stem layer width
    vocab_size=163840,
    prefix=("gqa_dense",),
    unit=("gqa_moe",),
    n_units=47,
    activation="swiglu",
    n_experts=64,
    moe_top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    capacity_factor=1.25,
    router_type="sigmoid",
    rope_theta=50000.0,
    tie_embeddings=False,
    quadratic=True,
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    prefix=("gqa_dense",),
    unit=("gqa_moe",),
    n_units=2,
    activation="swiglu",
    n_experts=8,
    moe_top_k=2,
    n_shared_experts=1,
    moe_d_ff=64,
    router_type="sigmoid",
    tie_embeddings=False,
    quadratic=True,
)

register(FULL, SMOKE)
