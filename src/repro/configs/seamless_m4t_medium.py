"""seamless-m4t-medium [audio, enc-dec]  [arXiv:2308.11596; hf]

12L decoder + 12L speech-encoder, d_model=1024, 16H (GQA kv=16, hd=64),
d_ff=4096, vocab=256206.  The modality frontend (w2v-BERT conformer feature
extractor) is a STUB: ``input_specs`` provides precomputed frame embeddings
at d_model, per the task spec.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    unit=("dec_attn",),
    n_units=12,
    activation="relu",
    is_encdec=True,
    n_enc_layers=12,
    audio_frontend=True,
    tie_embeddings=True,
    quadratic=True,
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    unit=("dec_attn",),
    n_units=2,
    activation="relu",
    is_encdec=True,
    n_enc_layers=2,
    audio_frontend=True,
    quadratic=True,
)

register(FULL, SMOKE)
