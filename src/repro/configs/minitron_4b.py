"""minitron-4b [dense]  [arXiv:2407.14679; hf]

32L, d_model=3072, 24H (GQA kv=8, head_dim=128), d_ff=9216, vocab=256000.
Pruned nemotron: squared-ReLU MLP (no gating), untied embeddings.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    unit=("attn_global",),
    n_units=32,
    activation="relu2",
    tie_embeddings=False,
    quadratic=True,
)

SMOKE = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    unit=("attn_global",),
    n_units=3,
    activation="relu2",
    tie_embeddings=False,
    quadratic=True,
)

register(FULL, SMOKE)
