"""gemma2-27b [dense]  [arXiv:2408.00118; hf]

46L, d_model=4608, 32H (GQA kv=16, head_dim=128), d_ff=36864, vocab=256000.
Alternating local(4096)/global attention, attn softcap 50, final logit
softcap 30, gemma post-norms + embed scaling.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    unit=("attn_local", "attn_global"),
    n_units=23,
    activation="geglu",
    local_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    post_norm=True,
    tie_embeddings=True,
    quadratic=True,
)

SMOKE = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    unit=("attn_local", "attn_global"),
    n_units=2,
    activation="geglu",
    local_window=32,
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    post_norm=True,
    quadratic=True,
)

register(FULL, SMOKE)
