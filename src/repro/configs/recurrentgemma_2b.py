"""recurrentgemma-2b [hybrid]  [arXiv:2402.19427; hf]

26L, d_model=2560, 10H (MQA kv=1, head_dim=256), d_ff=7680, vocab=256000.
Griffin pattern (rec, rec, local-attn) x8 + (rec, rec); RG-LRU width 2560,
temporal conv width 4, local window 2048.  Sub-quadratic: long_500k RUNS
(O(1) recurrent state + O(window) ring KV cache at decode).

The RG-LRU recurrence runs on the KernelForge scan primitive (AFFINE
operator, channel layout) -- the paper's technique powering this arch.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    unit=("rglru", "rglru", "attn_local"),
    n_units=8,
    suffix=("rglru", "rglru"),
    activation="geglu",
    local_window=2048,
    rnn_width=2560,
    conv_width=4,
    embed_scale=True,
    tie_embeddings=True,
    quadratic=False,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    unit=("rglru", "rglru", "attn_local"),
    n_units=1,
    suffix=("rglru", "rglru"),
    activation="geglu",
    local_window=32,
    rnn_width=64,
    conv_width=4,
    embed_scale=True,
    quadratic=False,
)

register(FULL, SMOKE)
