"""deepseek-coder-33b [dense]  [arXiv:2401.14196; hf]

62L, d_model=7168, 56H (GQA kv=8, head_dim=128), d_ff=19200, vocab=32256.
Llama-architecture: SwiGLU, RoPE theta 100000, untied embeddings.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    unit=("attn_global",),
    n_units=62,
    activation="swiglu",
    rope_theta=100000.0,
    tie_embeddings=False,
    quadratic=True,
)

SMOKE = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=512,
    unit=("attn_global",),
    n_units=3,
    activation="swiglu",
    rope_theta=100000.0,
    tie_embeddings=False,
    quadratic=True,
)

register(FULL, SMOKE)
