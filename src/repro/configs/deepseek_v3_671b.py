"""deepseek-v3-671b [moe]  [arXiv:2412.19437; hf]

61L, d_model=7168, 128H MLA (q_lora 1536, kv_lora 512, nope 128, rope 64,
v 128), vocab=129280.  First 3 layers dense (d_ff=18432); 58 MoE layers with
1 shared + 256 routed experts, top-8, sigmoid (aux-loss-free) router,
expert d_ff=2048.  Depth-1 multi-token prediction head.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,
    vocab_size=129280,
    prefix=("mla_dense",) * 3,
    unit=("mla_moe",),
    n_units=58,
    activation="swiglu",
    n_experts=256,
    moe_top_k=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    capacity_factor=1.25,
    router_type="sigmoid",
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp_depth=1,
    tie_embeddings=False,
    quadratic=True,
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    prefix=("mla_dense",),
    unit=("mla_moe",),
    n_units=2,
    activation="swiglu",
    n_experts=8,
    moe_top_k=2,
    n_shared_experts=1,
    moe_d_ff=64,
    router_type="sigmoid",
    use_mla=True,
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_head_dim=16,
    qk_rope_head_dim=8,
    v_head_dim=16,
    mtp_depth=1,
    tie_embeddings=False,
    quadratic=True,
)

register(FULL, SMOKE)
