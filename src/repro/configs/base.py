"""Config system: model + shape + run configs for every assigned architecture.

``ModelConfig`` is a frozen dataclass covering the union of features the 10
assigned architectures need (GQA, local/global attention, softcap, MLA, MoE,
RG-LRU, mLSTM/sLSTM, enc-dec, modality-frontend stubs).  Layer layout is
expressed as ``prefix + unit * n_units + suffix`` so homogeneous stacks can
be lowered as ``lax.scan`` over stacked params (compile-time scalability for
60-80 layer models).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 32000

    # Layer layout: pattern = prefix + unit * n_units + suffix.
    prefix: tuple = ()
    unit: tuple = ("attn_global",)
    n_units: int = 2
    suffix: tuple = ()

    # Attention.
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0   # 0 = same as rope_theta (gemma3: 1e6)
    local_window: int = 4096
    attn_softcap: float = 0.0       # 0 = disabled
    final_softcap: float = 0.0
    qk_norm: bool = False

    # MLP.
    activation: str = "swiglu"      # swiglu | geglu | relu2 | gelu

    # MoE.
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_type: str = "softmax"    # softmax | sigmoid (dsv3 aux-free)

    # MLA (deepseek-v3).
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # Recurrent (RG-LRU / xLSTM).
    rnn_width: int = 0
    conv_width: int = 4
    mlstm_chunk: int = 64
    mlstm_state_dtype: str = "float32"   # chunk-carry precision (perf knob)

    # Encoder-decoder (seamless).
    is_encdec: bool = False
    n_enc_layers: int = 0

    # Modality frontend stubs.
    num_prefix_embeds: int = 0      # vision tokens prepended to the sequence
    audio_frontend: bool = False    # source side consumes precomputed frames

    # Misc.
    embed_scale: bool = False       # gemma sqrt(d_model) embedding scaling
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    post_norm: bool = False         # gemma-2/3 post-block norms
    mtp_depth: int = 0              # deepseek-v3 multi-token prediction
    dtype: str = "bfloat16"
    # True when every layer is full (global) attention => quadratic in seq.
    # Sub-quadratic archs (ssm / hybrid with local attn) override to False
    # and are eligible for the long_500k cell.
    quadratic: bool = True

    def layer_pattern(self) -> tuple:
        pat = tuple(self.prefix) + tuple(self.unit) * self.n_units + tuple(self.suffix)
        assert len(pat) == self.n_layers, (
            f"{self.name}: layout gives {len(pat)} layers != n_layers={self.n_layers}")
        return pat

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Approximate parameter count (used for 6·N·D model FLOPs)."""
        d, hd = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = {}
        def attn_params():
            if self.use_mla:
                q = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.qk_rope_head_dim)
                kv = d * (self.kv_lora_rank + self.qk_rope_head_dim) + \
                    self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                o = self.n_heads * self.v_head_dim * d
                return q + kv + o
            return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

        def mlp_params(ff):
            mult = 3 if self.activation in ("swiglu", "geglu") else 2
            return mult * d * ff

        n = emb
        for kind in self.layer_pattern():
            if kind in ("attn_global", "attn_local"):
                n += attn_params() + mlp_params(self.d_ff)
            elif kind in ("mla_dense",):
                n += attn_params() + mlp_params(self.d_ff)
            elif kind in ("mla_moe", "gqa_moe"):
                n += attn_params()
                n += d * self.n_experts  # router
                n += self.n_experts * mlp_params(self.moe_d_ff) // d * d
                n += self.n_experts * (3 if self.activation in ("swiglu", "geglu") else 2) * d * self.moe_d_ff - self.n_experts * mlp_params(self.moe_d_ff)
                n += self.n_shared_experts * mlp_params(self.moe_d_ff)
            elif kind == "gqa_dense":
                n += attn_params() + mlp_params(self.d_ff)
            elif kind == "rglru":
                w = self.rnn_width or d
                n += 2 * d * w + w * d + self.conv_width * w + 3 * w
            elif kind == "mlstm":
                w = 2 * d
                n += d * w * 2 + w * d + 3 * (w // 1) + w * 3  # up/gates/down approx
                n += 3 * w * (w // max(self.n_heads, 1))  # qkv inside inner dim
            elif kind == "slstm":
                n += 4 * d * d + 4 * d * d // max(self.n_heads, 1) + (4 * d * d) // 3
            elif kind in ("enc_attn",):
                n += attn_params() + mlp_params(self.d_ff)
            elif kind == "dec_attn":
                n += 2 * attn_params() + mlp_params(self.d_ff)
        if self.is_encdec:
            for _ in range(self.n_enc_layers):
                n += attn_params() + mlp_params(self.d_ff)
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE discounts inactive experts)."""
        if self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        expert_p = mult * self.d_model * self.moe_d_ff
        n_moe_layers = sum(1 for k in self.layer_pattern() if k.endswith("_moe"))
        inactive = n_moe_layers * (self.n_experts - self.moe_top_k) * expert_p
        return int(total - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.is_encdec and cfg.audio_frontend:
            specs["src_embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), cfg.activation_dtype)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.num_prefix_embeds:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeds, cfg.d_model), cfg.activation_dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encdec and cfg.audio_frontend:
            specs["src_embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), cfg.activation_dtype)
        if cfg.num_prefix_embeds:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeds, cfg.d_model), cfg.activation_dtype)
        return specs
    # decode: one new token against a seq_len-deep cache (cache specs are
    # derived separately via jax.eval_shape on init_cache).
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


_REGISTRY: dict[str, ModelConfig] = {}
_SMOKE_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig):
    _REGISTRY[cfg.name] = cfg
    _SMOKE_REGISTRY[cfg.name] = smoke
    return cfg


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  triggers per-arch module imports
    return (_SMOKE_REGISTRY if smoke else _REGISTRY)[name]


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
