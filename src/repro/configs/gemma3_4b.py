"""gemma3-4b [dense]  [hf:google/gemma-3-4b-pt; unverified]

34L, d_model=2560, 8H (GQA kv=4, head_dim=256), d_ff=10240, vocab=262144.
5 local : 1 global interleaving (window 1024), qk-norm, RoPE theta 10k local
/ 1M global, gemma-style embed scaling + post-norms.  long_500k SKIPPED:
the global layers are full attention (quadratic) -- see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    unit=("attn_local",) * 5 + ("attn_global",),
    n_units=5,
    suffix=("attn_local",) * 4,
    activation="geglu",
    local_window=1024,
    rope_theta=10000.0,
    rope_theta_global=1000000.0,
    qk_norm=True,
    embed_scale=True,
    post_norm=True,
    tie_embeddings=True,
    quadratic=True,
)

SMOKE = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    unit=("attn_local",) * 2 + ("attn_global",),
    n_units=2,
    suffix=("attn_local",) * 2,
    activation="geglu",
    local_window=32,
    rope_theta_global=1000000.0,
    qk_norm=True,
    embed_scale=True,
    post_norm=True,
    quadratic=True,
)

register(FULL, SMOKE)
