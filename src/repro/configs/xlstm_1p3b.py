"""xlstm-1.3b [ssm]  [arXiv:2405.04517; unverified]

48L, d_model=2048, 4 heads, vocab=50304, d_ff=0 (mixers carry their own
up/down projections).  7:1 mLSTM:sLSTM interleave -- 6 units of
(mlstm x7, slstm x1).  Sub-quadratic: long_500k RUNS (O(d_head^2) matrix
state at decode; no KV cache).

mLSTM's exponential-gating stabilizer m_t = max(log f_t + m_{t-1}, log i_t)
runs on the KernelForge scan primitive with the non-commutative
MAXPLUS_AFFINE operator; sLSTM's gates read h_{t-1} (non-associative) and
are lowered as lax.scan over time -- see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    unit=("mlstm",) * 7 + ("slstm",),
    n_units=6,
    activation="gelu",
    conv_width=4,
    mlstm_chunk=64,
    tie_embeddings=True,
    quadratic=False,
)

SMOKE = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    head_dim=32,
    d_ff=0,
    vocab_size=512,
    unit=("mlstm", "mlstm", "mlstm", "slstm"),
    n_units=1,
    activation="gelu",
    conv_width=4,
    mlstm_chunk=8,
    quadratic=False,
)

register(FULL, SMOKE)
