"""Model wrappers: CausalLM / EncDec with scan-over-units lowering.

Layer stacks are grouped as ``prefix + unit * n_units + suffix`` (configs);
the homogeneous ``units`` segment is lowered as ``lax.scan`` over stacked
params (one HLO body for 58 deepseek-v3 MoE layers / 80 internvl layers)
with per-unit ``jax.checkpoint`` rematerialization -- both are what make the
full-scale configs compile tractably and fit memory.

Three entry points per model:
* ``forward_train(params, cfg, batch)``      -> (loss, metrics)
* ``prefill(params, cfg, tokens, ...)``      -> (last_logits, caches)
* ``decode_step(params, cfg, caches, tok, pos)`` -> (logits, caches)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Sharded
from repro.models import blocks as BK
from repro.models import layers as L

Pytree = Any

# When True, the units segment is fully unrolled instead of lax.scan'd.
# XLA's cost_analysis counts a while-loop body ONCE (not x trip count), so
# the dry-run sets this to get trip-count-correct FLOPs/bytes for the
# roofline; production lowering keeps the scan (small HLO, fast compiles).
SCAN_UNROLL = False


# ---------------------------------------------------------------------------
# Stack spec helpers
# ---------------------------------------------------------------------------


def _dec_spec(cfg):
    return (tuple(cfg.prefix), tuple(cfg.unit), cfg.n_units, tuple(cfg.suffix))


def _enc_spec(cfg):
    return ((), ("enc_attn",), cfg.n_enc_layers, ())


def _init_stack(key, spec, cfg, dtype):
    prefix, unit, n_units, suffix = spec
    kp, ku, ks = jax.random.split(key, 3)
    p = {}
    p["prefix"] = tuple(
        BK.init_block(k, kind, cfg, dtype)
        for k, kind in zip(jax.random.split(kp, max(len(prefix), 1)), prefix))
    if n_units:
        def init_unit(k):
            kk = jax.random.split(k, len(unit))
            return tuple(BK.init_block(kk[i], kind, cfg, dtype)
                         for i, kind in enumerate(unit))
        p["units"] = jax.vmap(init_unit)(jax.random.split(ku, n_units))
    else:
        p["units"] = ()
    p["suffix"] = tuple(
        BK.init_block(k, kind, cfg, dtype)
        for k, kind in zip(jax.random.split(ks, max(len(suffix), 1)), suffix))
    return p


def _run_stack(params, spec, cfg, h, positions, *, mode, caches=None,
               pos=None, enc_out=None, cache_len=0, remat="full",
               valid_len=None):
    """Returns (h, new_caches, aux).

    ``valid_len``: (prefill only) number of valid leading positions of ``h``
    -- the rest is right-padding from prompt-length bucketing.  Threaded to
    every block so cache construction snapshots the state *at* ``valid_len``
    instead of at the padded end (see serving engine ``prefill_buckets``).
    """
    prefix, unit, n_units, suffix = spec
    aux = dict(BK.ZERO_AUX)
    new_caches = {"prefix": [], "units": None, "suffix": []}

    def acc(a, b):
        return {k: a[k] + b[k] for k in a}

    for i, kind in enumerate(prefix):
        c = caches["prefix"][i] if mode == "decode" else None
        h, nc, ax = BK.block_forward(
            params["prefix"][i], kind, cfg, h, positions, mode=mode, cache=c,
            pos=pos, enc_out=enc_out, cache_len=cache_len,
            valid_len=valid_len)
        aux = acc(aux, ax)
        new_caches["prefix"].append(nc)

    if n_units:
        def unit_body(carry, xs):
            hh, aux_c = carry
            if mode == "decode":
                up, uc = xs
            else:
                up, uc = xs, None
            ncs = []
            for j, kind in enumerate(unit):
                cj = uc[j] if mode == "decode" else None
                hh, nc, ax = BK.block_forward(
                    up[j], kind, cfg, hh, positions, mode=mode, cache=cj,
                    pos=pos, enc_out=enc_out, cache_len=cache_len,
                    valid_len=valid_len)
                aux_c = acc(aux_c, ax)
                ncs.append(nc)
            ys = tuple(ncs) if mode != "train" else None
            return (hh, aux_c), ys

        body = unit_body
        if mode == "train" and remat != "none":
            policy = (jax.checkpoint_policies.nothing_saveable
                      if remat == "full" else
                      jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            body = jax.checkpoint(unit_body, policy=policy,
                                  prevent_cse=False)
        xs = (params["units"], caches["units"]) if mode == "decode" \
            else params["units"]
        (h, aux), unit_caches = jax.lax.scan(
            body, (h, aux), xs, unroll=n_units if SCAN_UNROLL else 1)
        new_caches["units"] = unit_caches

    for i, kind in enumerate(suffix):
        c = caches["suffix"][i] if mode == "decode" else None
        h, nc, ax = BK.block_forward(
            params["suffix"][i], kind, cfg, h, positions, mode=mode, cache=c,
            pos=pos, enc_out=enc_out, cache_len=cache_len,
            valid_len=valid_len)
        aux = acc(aux, ax)
        new_caches["suffix"].append(nc)

    new_caches["prefix"] = tuple(new_caches["prefix"])
    new_caches["suffix"] = tuple(new_caches["suffix"])
    return h, (new_caches if mode != "train" else None), aux


def _stack_cache(spec, cfg, batch, cache_len, dtype=jnp.bfloat16):
    prefix, unit, n_units, suffix = spec
    c = {
        "prefix": tuple(BK.init_block_cache(k, cfg, batch, cache_len, dtype)
                        for k in prefix),
        "suffix": tuple(BK.init_block_cache(k, cfg, batch, cache_len, dtype)
                        for k in suffix),
        "units": None,
    }
    if n_units:
        one = tuple(BK.init_block_cache(k, cfg, batch, cache_len, dtype)
                    for k in unit)
        c["units"] = jax.tree.map(
            lambda l: jnp.zeros((n_units,) + l.shape, l.dtype), one)
    return c


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(key, cfg, dtype=jnp.float32) -> Pytree:
    k_emb, k_dec, k_enc, k_norm, k_mtp = jax.random.split(key, 5)
    params = {
        "embed": L.init_embed(k_emb, cfg.vocab_size, cfg.d_model,
                              cfg.tie_embeddings, dtype),
        "decoder": _init_stack(k_dec, _dec_spec(cfg), cfg, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.is_encdec:
        params["encoder"] = _init_stack(k_enc, _enc_spec(cfg), cfg, dtype)
        params["enc_norm"] = L.init_rmsnorm(cfg.d_model, dtype)
    if cfg.mtp_depth:
        kind = "mla_dense" if cfg.use_mla else "attn_global"
        params["mtp"] = {
            "proj": L.dense_init(k_mtp, (2 * cfg.d_model, cfg.d_model), 0, dtype),
            "block": BK.init_block(k_mtp, kind, cfg, dtype),
            "norm_h": L.init_rmsnorm(cfg.d_model, dtype),
            "norm_e": L.init_rmsnorm(cfg.d_model, dtype),
            "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        }
    return params


def count_params(params: Pytree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Shared input embedding path
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg, tokens, vision_embeds=None):
    """Returns (h, positions, n_prefix)."""
    dtype = cfg.activation_dtype
    h = L.embed(params["embed"], tokens, cfg.embed_scale, dtype)
    n_prefix = 0
    if cfg.num_prefix_embeds and vision_embeds is not None:
        h = jnp.concatenate([vision_embeds.astype(dtype), h], axis=1)
        n_prefix = vision_embeds.shape[1]
    positions = jnp.arange(h.shape[1])
    return h, positions, n_prefix


def _encode(params, cfg, src_embeds):
    h = src_embeds.astype(cfg.activation_dtype)
    positions = jnp.arange(h.shape[1])
    h, _, _ = _run_stack(params["encoder"], _enc_spec(cfg), cfg, h, positions,
                         mode="train")
    return L.rmsnorm(params["enc_norm"], h, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def forward_train(params, cfg, batch, *, remat="full", z_loss=1e-4,
                  lb_coef=0.01, mtp_coef=0.3):
    tokens = batch["tokens"]
    labels = batch["labels"]
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, cfg, batch["src_embeds"])
    h, positions, n_prefix = _embed_inputs(
        params, cfg, tokens, batch.get("vision_embeds"))
    h = L.shard(h, "batch", "seq_sp", None)

    h, _, aux = _run_stack(params["decoder"], _dec_spec(cfg), cfg, h,
                           positions, mode="train", enc_out=enc_out,
                           remat=remat)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if n_prefix:
        h = h[:, n_prefix:]
    logits = L.unembed(params["embed"], h, cfg.final_softcap)
    loss = L.softmax_cross_entropy(logits, labels, z_loss=z_loss)
    total = loss
    metrics = {"ce_loss": loss, **aux}
    if cfg.n_experts:
        total = total + lb_coef * aux["lb_loss"] + 1e-4 * aux["router_z"]
    if cfg.mtp_depth:
        mtp_loss = _mtp_loss(params, cfg, h, tokens, labels, positions)
        total = total + mtp_coef * mtp_loss
        metrics["mtp_loss"] = mtp_loss
    metrics["loss"] = total
    return total, metrics


def _mtp_loss(params, cfg, h, tokens, labels, positions):
    """DeepSeek-V3 multi-token prediction: depth-1 extra causal block."""
    dtype = cfg.activation_dtype
    mp = params["mtp"]
    # Combine h_t with the embedding of t_{t+1} to predict t_{t+2}.
    h_in = L.rmsnorm(mp["norm_h"], h[:, :-1], cfg.norm_eps)
    e_next = L.embed(params["embed"], tokens[:, 1:], cfg.embed_scale, dtype)
    e_next = L.rmsnorm(mp["norm_e"], e_next, cfg.norm_eps)
    hm = jnp.concatenate([h_in, e_next], axis=-1)
    hm = jnp.einsum("bsd,de->bse", hm, mp["proj"].astype(dtype))
    kind = "mla_dense" if cfg.use_mla else "attn_global"
    hm, _, _ = BK.block_forward(mp["block"], kind, cfg, hm, positions[:-1],
                                mode="train")
    hm = L.rmsnorm(mp["final_norm"], hm, cfg.norm_eps)
    logits = L.unembed(params["embed"], hm, cfg.final_softcap)
    return L.softmax_cross_entropy(logits, labels[:, 1:])


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def unembed_sharded(params, h, softcap, mesh, axis="model"):
    """Tensor-parallel unembed: the decode GEMV through ``matvec@sharded``.

    ``logits[v] = sum_d h[d] * table[d, v]`` with the *contraction* axis D
    sharded over the ``axis`` devices of ``mesh`` -- each device folds its
    row strip of the unembed table into a vocab-sized partial and the ADD
    FoldSpec's psum combines them (the staged plan in
    distributed/primitives.py, so strip partials for one output chunk are
    in flight while the next chunk computes).  Opt-in replacement for
    ``L.unembed`` when the embedding table is row-sharded; the default
    dense path is untouched.  Batch rows ride ``vmap`` over the route.
    """
    table = params.get("unembed")
    if table is None:
        table = params["embedding"].T
    B, S, D = h.shape
    rows = h.reshape(B * S, D)
    tab = table.astype(h.dtype)

    def one(row):
        return forge.matvec(lambda x_i, a_ij: x_i * a_ij, alg.ADD, tab, row,
                            layout=Sharded(axis, mesh=mesh))

    logits = jax.vmap(one)(rows).astype(jnp.float32).reshape(B, S, -1)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def prefill(params, cfg, tokens, *, cache_len, src_embeds=None,
            vision_embeds=None, valid_len=None, tp_unembed=None):
    """Full-sequence forward building decode caches.

    ``valid_len``: number of valid leading *token* positions (scalar; may be
    traced) when ``tokens`` is right-padded to a bucket length -- the caches
    and returned logits are exactly those of a ``valid_len``-length prefill
    (causality keeps the pads out of every valid position's state; cache
    snapshots and the logit read move to ``valid_len``).  None = the whole
    sequence is valid (the historical exact-length path, byte-identical
    lowering).

    ``tp_unembed=(mesh, axis_name)`` routes the final logit projection
    through :func:`unembed_sharded` (contraction-sharded ``matvec@sharded``);
    None keeps the dense single-device unembed, byte-identical lowering.

    Returns (last_logits (B, vocab), caches).
    """
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, cfg, src_embeds)
    h, positions, n_prefix = _embed_inputs(params, cfg, tokens, vision_embeds)
    vl = None if valid_len is None else valid_len + n_prefix
    h, caches, _ = _run_stack(params["decoder"], _dec_spec(cfg), cfg, h,
                              positions, mode="prefill", enc_out=enc_out,
                              cache_len=cache_len, valid_len=vl)
    h_last = (h[:, -1:] if vl is None
              else jax.lax.dynamic_slice_in_dim(h, vl - 1, 1, axis=1))
    h = L.rmsnorm(params["final_norm"], h_last, cfg.norm_eps)
    if tp_unembed is None:
        logits = L.unembed(params["embed"], h, cfg.final_softcap)
    else:
        logits = unembed_sharded(params["embed"], h, cfg.final_softcap,
                                 *tp_unembed)
    return logits[:, 0], caches


def init_caches(cfg, batch, cache_len, dtype=jnp.bfloat16):
    return _stack_cache(_dec_spec(cfg), cfg, batch, cache_len, dtype)


def decode_step(params, cfg, caches, tokens, pos, *, tp_unembed=None):
    """One-token decode.  tokens: (B, 1) int32; pos: scalar int32 or a (B,)
    per-slot position vector (continuous batching: each batch row advances
    independently through its own cache slot -- see serving/engine.py).

    For enc-dec models, cross K/V caches must have been built by prefill.
    ``tp_unembed=(mesh, axis_name)`` opts the logit GEMV into the
    contraction-sharded ``matvec@sharded`` path (:func:`unembed_sharded`).
    Returns (logits (B, vocab), new_caches).
    """
    dtype = cfg.activation_dtype
    h = L.embed(params["embed"], tokens, cfg.embed_scale, dtype)
    positions = (pos.astype(jnp.int32)[:, None] if getattr(pos, "ndim", 0)
                 else jnp.full((1,), pos, jnp.int32))
    h, new_caches, _ = _run_stack(params["decoder"], _dec_spec(cfg), cfg, h,
                                  positions, mode="decode", caches=caches,
                                  pos=pos)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if tp_unembed is None:
        logits = L.unembed(params["embed"], h, cfg.final_softcap)
    else:
        logits = unembed_sharded(params["embed"], h, cfg.final_softcap,
                                 *tp_unembed)
    return logits[:, 0], new_caches
