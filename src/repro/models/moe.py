"""Mixture-of-Experts with deterministic capacity-based dispatch + EP.

Sort-based dispatch (GShard/Switch lineage): token->expert assignments are
argsorted by expert id, each token takes a position within its expert's
capacity-``C`` buffer, and the grouped GEMM runs as one batched einsum over
the ``[E, C, D]`` dispatch buffer.  Shapes are static (compile-friendly at
every scale); overflow tokens are dropped (capacity_factor controls the
rate).  Experts are sharded on the ``model`` mesh axis (expert parallelism);
the scatter from token-sharded to expert-sharded layouts is the all-to-all
the roofline's collective term sees.

Router variants: ``softmax`` top-k (GShard/Mixtral) and ``sigmoid``
(DeepSeek-V3 aux-loss-free with per-expert bias, bias updates are the
trainer's job).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def init_moe(key, cfg, dtype=jnp.float32):
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    gated = cfg.activation in ("swiglu", "geglu")
    ks = jax.random.split(key, 6)
    p = {
        "router": L.dense_init(ks[0], (d, E), 0, jnp.float32),
        "router_bias": jnp.zeros((E,), jnp.float32),
        "w_in": L.dense_init(ks[1], (E, d, ff), 1, dtype),
        "w_out": L.dense_init(ks[2], (E, ff, d), 1, dtype),
    }
    if gated:
        p["w_gate"] = L.dense_init(ks[3], (E, d, ff), 1, dtype)
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(
            ks[4], d, ff * cfg.n_shared_experts, cfg.activation, dtype)
    return p


def _capacity(cfg, T):
    C = int(np.ceil(T * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, ((C + 7) // 8) * 8)


def moe_forward(params, cfg, x):
    """x: (B, S, D) -> (y, aux) with aux = {'lb_loss', 'router_z'}"""
    rules = L.current_rules()
    if rules and rules.get("moe_shard_map") and rules.get("_mesh") is not None:
        # Zero-collective-dispatch EP path (distributed/moe_sharded.py):
        # the GSPMD lowering of the global sort/scatter gathers token
        # buffers across the mesh (EXPERIMENTS.md §Perf).
        from repro.distributed.moe_sharded import moe_forward_sharded
        return moe_forward_sharded(params, cfg, x, rules["_mesh"])
    dtype = x.dtype
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    T = B * S
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    if cfg.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["router_bias"][None, :]  # aux-free balancing bias
        gate_sel, idx = jax.lax.top_k(sel, k)
        gates = jnp.take_along_axis(scores, idx, axis=1)
        gates = gates / jnp.maximum(jnp.sum(gates, axis=1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(jnp.sum(scores, axis=1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(jnp.sum(gates, axis=1, keepdims=True), 1e-9)

    # Load-balance aux (Switch): E * sum_e frac_tokens_e * mean_prob_e.
    onehot_frac = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    mean_prob = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(onehot_frac * mean_prob)
    router_z = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))

    # ---- deterministic capacity dispatch (sort-based) ----
    C = _capacity(cfg, T)
    flat_e = idx.reshape(-1)                                # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
    keep = pos < C
    se_safe = jnp.where(keep, se, E)                        # OOB -> dropped

    xbuf = jnp.zeros((E, C, D), dtype)
    xbuf = xbuf.at[se_safe, pos].set(
        xf[st] * keep[:, None].astype(dtype), mode="drop")
    xbuf = L.shard(xbuf, "experts", None, None)

    h = jnp.einsum("ecd,edf->ecf", xbuf, params["w_in"].astype(dtype))
    if "w_gate" in params:
        g = jnp.einsum("ecd,edf->ecf", xbuf, params["w_gate"].astype(dtype))
        h = jax.nn.silu(g) * h if cfg.activation == "swiglu" else jax.nn.gelu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = L.shard(h, "experts", None, "ffn_inner")
    y = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(dtype))
    y = L.shard(y, "experts", None, None)

    # ---- combine ----
    gathered = y[se_safe.clip(0, E - 1), pos.clip(0, C - 1)]
    contrib = gathered * (sg * keep).astype(dtype)[:, None]
    out = jnp.zeros((T, D), dtype).at[st].add(contrib)
    out = L.shard(out.reshape(B, S, D), "batch", "seq_sp", None)

    if cfg.n_shared_experts:
        out = out + L.mlp(params["shared"], x, cfg.activation)
    return out, {"lb_loss": lb_loss, "router_z": router_z}
