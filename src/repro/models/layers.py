"""Shared model layers: norms, embeddings, rotary, MLPs, sharding hooks.

Everything is functional: ``init_*`` builds param pytrees (plain dicts),
``apply`` functions are pure.  Sharding is expressed through *logical axis*
annotations resolved against a rules table installed by the distributed
layer (``repro.distributed.sharding``); with no rules installed the
annotations are no-ops, so the same model code runs in CPU tests, the
dry-run, and on real meshes.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Logical-axis sharding context
# ---------------------------------------------------------------------------

_RULES: dict | None = None


@contextlib.contextmanager
def sharding_rules(rules: dict | None):
    global _RULES
    prev = _RULES
    _RULES = rules
    try:
        yield
    finally:
        _RULES = prev


def current_rules() -> dict | None:
    return _RULES


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op w/o rules)."""
    if _RULES is None:
        return x
    spec = jax.sharding.PartitionSpec(
        *[_RULES.get(name) if name is not None else None for name in logical])
    return jax.lax.with_sharding_constraint(x, spec)


def logical_spec(*logical):
    if _RULES is None:
        return jax.sharding.PartitionSpec()
    return jax.sharding.PartitionSpec(
        *[_RULES.get(name) if name is not None else None for name in logical])


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis=0, dtype=jnp.float32, scale=1.0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # Gemma-style (1 + scale) parameterization, zero-init.
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE.  x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(theta) * (2 * jnp.arange(half, dtype=jnp.float32) / hd))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated + plain variants)
# ---------------------------------------------------------------------------


def init_mlp(key, d, ff, activation, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    gated = activation in ("swiglu", "geglu")
    p = {
        "w_in": dense_init(k1, (d, ff), 0, dtype),
        "w_out": dense_init(k3, (ff, d), 0, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(k2, (d, ff), 0, dtype)
    return p


def _act(name, x):
    if name in ("swiglu",):
        return jax.nn.silu(x)
    if name in ("geglu", "gelu"):
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def mlp(params, x, activation, dtype=None):
    """x: (B, S, D) -> (B, S, D); inner dim sharded on 'ffn'."""
    dtype = dtype or x.dtype
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(dtype))
    if "w_gate" in params:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dtype))
        h = _act(activation, g) * h
    else:
        h = _act(activation, h)
    # Inside the MLP the ffn axis carries "model"; under SP the residual
    # stream's seq shards are all-gathered on entry and reduce-scattered on
    # exit (Megatron sequence parallelism) -- hence seq is None here.
    h = shard(h, "batch", None, "ffn")
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(key, vocab, d, tie, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    p = {"embedding": embed_init(k1, (vocab, d), dtype)}
    if not tie:
        p["unembed"] = dense_init(k2, (d, vocab), 0, dtype)
    return p


def embed(params, tokens, scale=False, dtype=jnp.bfloat16):
    x = jnp.take(params["embedding"], tokens, axis=0).astype(dtype)
    if scale:
        x = x * jnp.asarray(np.sqrt(x.shape[-1]), dtype)
    return x


def unembed(params, x, softcap=0.0):
    table = params.get("unembed")
    if table is None:
        table = params["embedding"].T
    logits = jnp.einsum("bsd,dv->bsv", x, table.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return shard(logits, "batch", "seq_sp", "vocab")


# ---------------------------------------------------------------------------
# Losses (via the KernelForge mapreduce algebra where natural)
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          z_loss: float = 0.0):
    """Mean token cross-entropy; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
