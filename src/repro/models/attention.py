"""Attention: GQA (global / sliding-window), softcap, qk-norm, MLA, cross.

Full-sequence paths use **blockwise attention** (lax.scan over KV blocks with
running (m, l, acc) -- the flash pattern): at the assigned shapes (4k train /
32k prefill) materializing S x S scores is impossible, so the online-softmax
merge is load-bearing.  The merge algebra is exactly the core library's
``SOFTMAX_MERGE`` operator (operators.py); the distributed decode combine in
``repro.distributed.collectives`` reuses it across model-axis shards.

Decode paths attend against fixed-shape caches: full-length for global
layers, **ring buffers of window size** for local layers (which is what makes
``long_500k`` decode O(window) instead of O(seq) for the hybrid archs).
MLA decode runs in the *absorbed* compressed space (q is projected into the
kv_lora latent; attention and value aggregation never expand per-head K/V) --
the memory-bandwidth point of MLA.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as alg
from repro.models import layers as L

NEG_INF = -1e30

# Dry-run cost-accounting mode: fully unroll the KV-block loop so XLA's
# cost_analysis (which counts while-loop bodies once) sees every block.
# Production lowering keeps the rolled loop.  Set via repro.models.lm.
KV_UNROLL = False


# ---------------------------------------------------------------------------
# Blockwise (flash) attention core
# ---------------------------------------------------------------------------


def blockwise_attention(q, k, v, *, qpos, causal=True, window=0,
                        softcap=0.0, kv_block=512, kv_len=None):
    """q: (B,S,K,G,hd); k,v: (B,T,K,hd).  Returns (B,S,K,G,hd).

    ``qpos``: (S,) absolute positions of queries.  ``window``>0 limits keys to
    (qpos - kpos) < window.  ``kv_len``: actual valid key count (<= T).
    """
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    kv_block = min(kv_block, T)
    nb = (T + kv_block - 1) // kv_block
    scale = 1.0 / np.sqrt(hd)
    kv_len = T if kv_len is None else kv_len

    qf = (q.astype(jnp.float32) * scale).astype(q.dtype)

    def step(carry, kb):
        m, l, acc = carry
        start = kb * kv_block
        ks = jax.lax.dynamic_slice_in_dim(k, start, kv_block, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, start, kv_block, axis=1)
        s = jnp.einsum("bskgd,btkd->bskgt", qf, ks,
                       preferred_element_type=jnp.float32)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        kpos = start + jnp.arange(kv_block)
        mask = kpos[None, :] < kv_len
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        if window:
            mask = mask & ((qpos[:, None] - kpos[None, :]) < window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bskgt,btkd->bskgd", p.astype(v.dtype), vs,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, K, G), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, v.shape[-1]), jnp.float32)  # v head dim (MLA)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nb),
                                  unroll=nb if KV_UNROLL else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, key_valid, softcap=0.0):
    """Single-step attention over a fixed cache.

    q: (B,1,K,G,hd); caches: (B,L,K,hd); key_valid: (L,) or (B,L) bool.
    """
    B, _, K, G, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bskgd,btkd->bskgt", q.astype(jnp.float32) * scale,
                   k_cache, preferred_element_type=jnp.float32)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    if key_valid.ndim == 1:
        mask = key_valid[None, None, None, None, :]
    else:
        mask = key_valid[:, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bskgt,btkd->bskgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (global or sliding-window)
# ---------------------------------------------------------------------------


def init_gqa(key, cfg, dtype=jnp.float32):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (d, H, hd), 0, dtype),
        "wk": L.dense_init(ks[1], (d, K, hd), 0, dtype),
        "wv": L.dense_init(ks[2], (d, K, hd), 0, dtype),
        "wo": L.dense_init(ks[3], (H, hd, d), (0, 1), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.init_rmsnorm(hd, dtype)
        p["k_norm"] = L.init_rmsnorm(hd, dtype)
    return p


def _project_qkv(params, cfg, x, positions, dtype, is_local=True):
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    theta = (cfg.rope_theta_global
             if (not is_local and cfg.rope_theta_global) else cfg.rope_theta)
    q = L.rope(q, positions, theta)
    k = L.rope(k, positions, theta)
    q = L.shard(q, "batch", "seq_sp", "heads", None)
    k = L.shard(k, "batch", None, "kv_heads", None)
    v = L.shard(v, "batch", None, "kv_heads", None)
    return q.reshape(q.shape[0], q.shape[1], K, H // K, hd), k, v


def gqa_forward(params, cfg, x, positions, *, is_local, causal=True,
                return_cache_len=0, valid_len=None):
    """Full-sequence forward.  positions: (S,).  Returns (y, cache|None).

    ``valid_len``: valid leading length of ``x`` (prompt bucketing).  The
    attention outputs at valid positions are already exact under right-
    padding -- the causal mask keeps every pad key (position >= valid_len)
    out of every valid query's window -- so only cache construction uses it.
    """
    dtype = x.dtype
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions, dtype, is_local)
    window = cfg.local_window if is_local else 0
    out = blockwise_attention(
        q, k, v, qpos=positions, causal=causal, window=window,
        softcap=cfg.attn_softcap)
    out = out.reshape(B, S, cfg.n_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    cache = None
    if return_cache_len:
        cache = _build_cache(k, v, return_cache_len, S, is_local, cfg,
                             valid_len=valid_len)
    return y, cache


def _build_cache(k, v, cache_len, seq_len, is_local, cfg, valid_len=None):
    """Build a decode cache from prefill K/V (ring layout for local).

    ``valid_len``: valid leading K/V length (scalar, may be traced) under
    prompt bucketing.  Global caches need no masking -- pad K/V lands at
    slots >= valid_len, and decode both writes each slot before its
    ``slot <= pos`` validity window reaches it, so garbage is overwritten
    before it is ever readable.  Local rings DO need it: the ring must hold
    the last ``W`` *valid* positions, not the last ``W`` rows of the padded
    sequence.
    """
    B, S, K, hd = k.shape
    assert is_local or cache_len >= S, (
        f"global-attention cache_len={cache_len} < prefill length {S}")
    if is_local:
        W = min(cache_len, cfg.local_window)
        if valid_len is None:
            # Ring: slot = t % W for the last W positions.
            last = k[:, max(S - W, 0):]
            lastv = v[:, max(S - W, 0):]
            t0 = max(S - W, 0)
            slots = (t0 + jnp.arange(last.shape[1])) % W
        else:
            # Last W valid positions end at a traced boundary: left-pad W
            # zero rows so padded row (t + W) is original row t, then slice
            # rows [valid_len - W, valid_len).  Rows with t < 0 are the
            # left-pad zeros and write zeros into slots the exact-length
            # path leaves at init (also zeros) -- bit-identical cache.
            kp = jnp.pad(k, ((0, 0), (W, 0), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (W, 0), (0, 0), (0, 0)))
            last = jax.lax.dynamic_slice_in_dim(kp, valid_len, W, axis=1)
            lastv = jax.lax.dynamic_slice_in_dim(vp, valid_len, W, axis=1)
            t = valid_len - W + jnp.arange(W)
            slots = jnp.mod(t, W)
        kc = jnp.zeros((B, W, K, hd), k.dtype).at[:, slots].set(last)
        vc = jnp.zeros((B, W, K, hd), v.dtype).at[:, slots].set(lastv)
        return {"k": kc, "v": vc}
    kc = jnp.zeros((B, cache_len, K, hd), k.dtype)
    vc = jnp.zeros((B, cache_len, K, hd), v.dtype)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k, 0, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v, 0, axis=1)
    return {"k": kc, "v": vc}


def init_gqa_cache(cfg, batch, cache_len, is_local, dtype=jnp.bfloat16):
    K, hd = cfg.n_kv_heads, cfg.head_dim
    Lc = min(cache_len, cfg.local_window) if is_local else cache_len
    return {
        "k": jnp.zeros((batch, Lc, K, hd), dtype),
        "v": jnp.zeros((batch, Lc, K, hd), dtype),
    }


def is_vector_pos(pos) -> bool:
    """True when ``pos`` is a per-row (B,) position vector.

    The serving engine's continuous-batching decode loop tracks one position
    per live batch slot (requests are admitted and evicted independently, so
    the batch is never position-aligned); the legacy padded path keeps the
    scalar form.  Scalar and vector paths are kept separate so the scalar
    lowering stays byte-for-byte what it was.
    """
    return getattr(pos, "ndim", 0) == 1


def _kv_scatter(leaf, new, bidx, slot, dtype):
    """Per-row slot write of ``new`` (B, K, hd) at ``[bidx, slot]``.

    Returns ``(stored, readable)``: the cache-resident form to carry
    forward and the dense form attention reads.  A ``KVQuant`` leaf stores
    values and scales with the same index arithmetic as the dense leaf and
    dequantizes the whole cache at read (quantize-at-write / dequant-at-read
    is the serving contract for ``quantize_kv=``).
    """
    if isinstance(leaf, alg.KVQuant):
        qn = alg.quantize_kv(new, leaf.mode)
        stored = alg.KVQuant(
            leaf.values.at[bidx, slot].set(qn.values),
            leaf.scales.at[bidx, slot].set(qn.scales), leaf.mode)
        return stored, stored.dequantize(dtype)
    stored = leaf.at[bidx, slot].set(new.astype(leaf.dtype))
    return stored, stored


def _kv_update_seq(leaf, new, slot, dtype):
    """Aligned-batch slot write of ``new`` (B, 1, K, hd) at sequence
    position ``slot``; same (stored, readable) contract as _kv_scatter."""
    if isinstance(leaf, alg.KVQuant):
        qn = alg.quantize_kv(new, leaf.mode)
        stored = alg.KVQuant(
            jax.lax.dynamic_update_slice_in_dim(
                leaf.values, qn.values, slot, axis=1),
            jax.lax.dynamic_update_slice_in_dim(
                leaf.scales, qn.scales, slot, axis=1), leaf.mode)
        return stored, stored.dequantize(dtype)
    stored = jax.lax.dynamic_update_slice_in_dim(
        leaf, new.astype(leaf.dtype), slot, axis=1)
    return stored, stored


def gqa_decode(params, cfg, x, cache, pos, *, is_local):
    """One-token decode.  x: (B,1,D); pos: scalar position, or a (B,)
    per-slot position vector (continuous batching: every row of the batch
    sits at its own depth in its own cache slot)."""
    dtype = x.dtype
    B = x.shape[0]
    K, H, hd = cfg.n_kv_heads, cfg.n_heads, cfg.head_dim
    vec = is_vector_pos(pos)
    positions = (pos.astype(jnp.int32)[:, None] if vec
                 else jnp.full((1,), pos, jnp.int32))
    q, k, v = _project_qkv(params, cfg, x, positions, dtype, is_local)
    Lc = cache["k"].shape[1]
    slot = pos % Lc
    slot_idx = jnp.arange(Lc)
    qpos = pos[:, None] if vec else pos          # broadcasts to (B, Lc) / (Lc,)
    if is_local:
        # Slot s holds absolute position pos - ((pos - s) mod Lc); valid if >= 0.
        slot_pos = qpos - jnp.mod(qpos - slot_idx, Lc)
        key_valid = slot_pos >= 0
    else:
        key_valid = slot_idx <= qpos
    rules = L.current_rules()
    _mesh = rules.get("_mesh") if rules else None
    _msize = (dict(zip(_mesh.axis_names, _mesh.devices.shape)).get("model", 1)
              if _mesh is not None else 1)
    if vec:
        # Per-row ring-slot scatter; the flash-decode sharded path is
        # scalar-pos only (its owner-shard cache update keys on one slot).
        bidx = jnp.arange(B)
        kc, kread = _kv_scatter(cache["k"], k[:, 0], bidx, slot, dtype)
        vc, vread = _kv_scatter(cache["v"], v[:, 0], bidx, slot, dtype)
        out = decode_attention(q, kread, vread, key_valid=key_valid,
                               softcap=cfg.attn_softcap)
    elif rules and rules.get("decode_kv_shard") and _mesh is not None \
            and Lc % _msize == 0 \
            and not isinstance(cache["k"], alg.KVQuant):
        # Flash-decoding: cache sequence sharded over "model", partial
        # softmaxes merged with the SOFTMAX_MERGE algebra, and the cache
        # update done owner-shard-locally (a jnp-level update at a traced
        # slot makes GSPMD all-gather the whole cache) -- collectives.py.
        from repro.distributed import collectives as CC
        import numpy as _np
        mesh = rules["_mesh"]
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_total = int(_np.prod([v for k, v in sizes.items()
                                 if k in ("pod", "data")]))
        out, kc, vc = CC.flash_decode_gqa(
            mesh, q, cache["k"], cache["v"], k, v, slot, key_valid,
            softcap=cfg.attn_softcap, batch_sharded=B % dp_total == 0)
    else:
        kc, kread = _kv_update_seq(cache["k"], k, slot, dtype)
        vc, vread = _kv_update_seq(cache["v"], v, slot, dtype)
        out = decode_attention(q, kread, vread, key_valid=key_valid,
                               softcap=cfg.attn_softcap)
    out = out.reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec decoder)
# ---------------------------------------------------------------------------


def init_cross(key, cfg, dtype=jnp.float32):
    return init_gqa(key, cfg, dtype)


def cross_forward(params, cfg, x, enc_out, enc_valid_len=None):
    """x: (B,S,D) queries; enc_out: (B,T,D) keys/values (bidirectional)."""
    dtype = x.dtype
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dtype))
    q = L.shard(q, "batch", "seq_sp", "heads", None)
    qpos = jnp.arange(S)
    out = blockwise_attention(
        q.reshape(B, S, K, H // K, hd), k, v, qpos=qpos, causal=False,
        kv_len=enc_valid_len)
    out = out.reshape(B, S, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


def cross_build_cache(params, cfg, enc_out):
    dtype = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dtype))
    return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}


def cross_decode(params, cfg, x, cache):
    dtype = x.dtype
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    T = cache["k"].shape[1]
    out = decode_attention(
        q.reshape(B, 1, K, H // K, hd), cache["k"], cache["v"],
        key_valid=jnp.ones((T,), bool))
    out = out.reshape(B, 1, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# MLA (deepseek-v3): low-rank q, compressed KV, absorbed decode
# ---------------------------------------------------------------------------


def init_mla(key, cfg, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "w_dq": L.dense_init(ks[0], (d, qr), 0, dtype),
        "q_norm": L.init_rmsnorm(qr, dtype),
        "w_uq": L.dense_init(ks[1], (qr, H, nd + rd), 0, dtype),
        "w_dkv": L.dense_init(ks[2], (d, kvr + rd), 0, dtype),
        "kv_norm": L.init_rmsnorm(kvr, dtype),
        "w_uk": L.dense_init(ks[3], (kvr, H, nd), 0, dtype),
        "w_uv": L.dense_init(ks[4], (kvr, H, vd), 0, dtype),
        "wo": L.dense_init(ks[5], (H, vd, d), (0, 1), dtype),
    }


def _mla_q(params, cfg, x, positions, dtype):
    nd, rd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(dtype))
    cq = L.rmsnorm(params["q_norm"], cq, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(dtype))
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(params, cfg, x, positions, dtype):
    kvr, rd = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ckv_full = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dtype))
    ckv, k_rope = ckv_full[..., :kvr], ckv_full[..., kvr:]
    ckv = L.rmsnorm(params["kv_norm"], ckv, cfg.norm_eps)
    k_rope = L.rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, k_rope


def mla_forward(params, cfg, x, positions, *, return_cache_len=0):
    """Full-sequence MLA with expanded K/V (compute-optimal for prefill)."""
    dtype = x.dtype
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(params, cfg, x, positions, dtype)
    ckv, k_rope = _mla_ckv(params, cfg, x, positions, dtype)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uk"].astype(dtype))
    val = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uv"].astype(dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rd))],
        axis=-1)
    q = L.shard(q, "batch", "seq_sp", "heads", None)
    k = L.shard(k, "batch", None, "heads", None)
    # Pad v's head_dim to match q/k for the shared blockwise core, or use
    # grouped layout directly: here K == H (MLA exposes all heads).
    out = blockwise_attention(
        q.reshape(B, S, H, 1, nd + rd), k, val, qpos=positions, causal=True)
    out = out.reshape(B, S, H, vd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    cache = None
    if return_cache_len:
        kvr = cfg.kv_lora_rank
        ckv_c = jnp.zeros((B, return_cache_len, kvr), ckv.dtype)
        kr_c = jnp.zeros((B, return_cache_len, rd), k_rope.dtype)
        ckv_c = jax.lax.dynamic_update_slice_in_dim(ckv_c, ckv, 0, axis=1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(kr_c, k_rope, 0, axis=1)
        cache = {"ckv": ckv_c, "krope": kr_c}
    return y, cache


def init_mla_cache(cfg, batch, cache_len, dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, cache_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(params, cfg, x, cache, pos):
    """Absorbed decode: attention entirely in the compressed latent space.

    ``pos`` may be a scalar (aligned batch) or a (B,) per-slot vector
    (continuous batching)."""
    dtype = x.dtype
    B = x.shape[0]
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    vec = is_vector_pos(pos)
    positions = (pos.astype(jnp.int32)[:, None] if vec
                 else jnp.full((1,), pos, jnp.int32))
    q_nope, q_rope = _mla_q(params, cfg, x, positions, dtype)   # (B,1,H,*)
    ckv_new, krope_new = _mla_ckv(params, cfg, x, positions, dtype)
    # Absorb w_uk into q: q_abs[b,1,h,r] = sum_n q_nope[b,1,h,n] w_uk[r,h,n]
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, params["w_uk"].astype(dtype))
    scale = 1.0 / np.sqrt(nd + rd)
    Lc = cache["ckv"].shape[1]
    valid = (jnp.arange(Lc)[None, :] <= pos[:, None] if vec
             else jnp.arange(Lc) <= pos)
    rules = L.current_rules()
    _mesh = rules.get("_mesh") if rules else None
    _msize = (dict(zip(_mesh.axis_names, _mesh.devices.shape)).get("model", 1)
              if _mesh is not None else 1)
    if vec:
        bidx = jnp.arange(B)
        ckv_c = cache["ckv"].at[bidx, pos % Lc].set(
            ckv_new[:, 0].astype(cache["ckv"].dtype))
        kr_c = cache["krope"].at[bidx, pos % Lc].set(
            krope_new[:, 0].astype(cache["krope"].dtype))
        s = (jnp.einsum("bshr,btr->bhst", q_abs.astype(jnp.float32),
                        ckv_c.astype(jnp.float32)) +
             jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                        kr_c.astype(jnp.float32))) * scale
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", p.astype(jnp.float32),
                         ckv_c.astype(jnp.float32))  # (B,1,H,kvr)
    elif rules and rules.get("decode_mla_shard") and _mesh is not None \
            and cache["ckv"].shape[1] % _msize == 0:
        # Flash-decoding in the compressed latent space: cache sequence
        # sharded over "model"; q gathered (tiny at decode); cache update
        # done owner-shard-locally inside the shard_map.
        from repro.distributed import collectives as CC
        import numpy as _np
        mesh = rules["_mesh"]
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_total = int(_np.prod([v for k, v in sizes.items()
                                 if k in ("pod", "data")]))
        ctx, ckv_c, kr_c = CC.flash_decode_mla(
            mesh, q_abs, q_rope, cache["ckv"], cache["krope"],
            ckv_new, krope_new, pos, valid, scale=scale,
            batch_sharded=B % dp_total == 0)         # (B,1,H,kvr) fp32
    else:
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], krope_new.astype(cache["krope"].dtype), pos, axis=1)
        s = (jnp.einsum("bshr,btr->bhst", q_abs.astype(jnp.float32),
                        ckv_c.astype(jnp.float32)) +
             jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                        kr_c.astype(jnp.float32))) * scale
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", p.astype(jnp.float32),
                         ckv_c.astype(jnp.float32))  # (B,1,H,kvr)
    out = jnp.einsum("bshr,rhk->bshk", ctx.astype(dtype),
                     params["w_uv"].astype(dtype))   # (B,1,H,vd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, {"ckv": ckv_c, "krope": kr_c}
