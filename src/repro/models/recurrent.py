"""Recurrent mixers: RG-LRU (recurrentgemma) and mLSTM / sLSTM (xlstm).

These are where the paper's scan primitive is load-bearing:

* RG-LRU's diagonal recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2)(i_t x_t)
  runs on ``core.primitives.linear_recurrence(layout=Batched())`` -- the
  AFFINE-operator scan in the (B, T, C) channel layout, one launch for the
  whole batch (Pallas kernel on TPU, associative_scan on XLA backends).
* mLSTM's exponential-gating stabilizer m_t = max(log f_t + m_{t-1}, log i_t)
  runs on ``core.scan`` with the non-commutative MAXPLUS_AFFINE operator --
  an "arbitrary operator" the vendor libraries the paper benchmarks against
  cannot express.  With m known, the (C, n) matrix recurrence is processed
  chunkwise: intra-chunk = masked decay attention, parallel over chunks;
  inter-chunk = the per-chunk decay is a *scalar per head*, so the chunk
  states follow a diagonal linear recurrence along the chunk axis and run on
  ``linear_recurrence(layout=Batched())`` (one launch), replacing the former
  sequential lax.scan of chunk steps.  The trade: chunk-start states
  (NC x H x d_head^2) are materialized instead of streamed -- comparable to
  the (T x H x d_head) activations already produced, and what buys decode
  batches a launch count independent of sequence length.  The per-chunk
  *output* computation (whose L x L attention tensor would grow NC-fold if
  vectorized) is size-gated: fully chunk-parallel up to a footprint cutoff,
  streamed with a carry-free lax.map beyond it, so long-context prefill
  keeps its one-chunk peak.
* sLSTM's gates read h_{t-1}: a genuinely non-associative recurrence, noted
  in DESIGN.md §4 -- lowered as lax.scan over time (one XLA while loop);
  no associative operator exists for it, so it stays off the scan substrate.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched, Sharded
from repro.models import layers as L


def _recurrence_layout(seq_shard):
    """``seq_shard=(mesh, axis_name)`` opts a (B, T, C) recurrence into the
    ``linear_recurrence@sharded`` route -- T spans the mesh axis, per-shard
    affine totals meet in the exclusive cross-device carry, and the staged
    plan overlaps the carry exchange with per-channel-chunk local scans.
    None keeps the single-device Batched route (byte-identical lowering)."""
    if seq_shard is None:
        return Batched()
    mesh, axis_name = seq_shard
    return Sharded(axis_name, mesh=mesh)


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (width cfg.conv_width), with decode state
# ---------------------------------------------------------------------------


def init_conv1d(key, width, channels, dtype=jnp.float32):
    return {
        "kernel": (jax.random.normal(key, (width, channels), jnp.float32)
                   * 0.02).astype(dtype),
        "bias": jnp.zeros((channels,), dtype),
    }


def causal_conv1d(params, x):
    """x: (B, T, C); causal depthwise conv."""
    w = params["kernel"].astype(x.dtype)      # (W, C)
    W = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out + params["bias"].astype(x.dtype)


def conv1d_step(params, x_t, state):
    """x_t: (B, 1, C); state: (B, W-1, C) holding the previous inputs."""
    w = params["kernel"].astype(x_t.dtype)
    W = w.shape[0]
    window = jnp.concatenate([state, x_t], axis=1)      # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", window, w)[:, None, :] + params["bias"].astype(x_t.dtype)
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Block-diagonal linear (recurrentgemma gates; xlstm recurrent weights)
# ---------------------------------------------------------------------------


def init_blockdiag(key, heads, width, dtype=jnp.float32):
    per = width // heads
    return (jax.random.normal(key, (heads, per, per), jnp.float32)
            / np.sqrt(per)).astype(dtype)


def blockdiag_apply(w, x):
    """x: (..., width) -> (..., width) with block-diagonal w: (H, p, p)."""
    H, p, _ = w.shape
    xs = x.reshape(x.shape[:-1] + (H, p))
    out = jnp.einsum("...hp,hpq->...hq", xs, w.astype(x.dtype))
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# RG-LRU (Griffin recurrent block)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0

# Elements of the (B, NC, L, L, H) intra-chunk attention tensor above which
# mLSTM computes chunk outputs with a memory-streaming lax.map instead of
# vectorizing over all chunks (see _mlstm_chunk_scan): 2^24 bf16 elements is
# a 32 MiB attention tensor (plus its float32 feeders), comfortably VMEM/HBM
# -sane while keeping every decode and moderate-prefill shape on the fully
# parallel path.
_MLSTM_INTRA_PARALLEL_MAX_ELEMS = 1 << 24


def init_rglru_block(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 7)
    # Lambda init so that a = exp(-8 softplus(L) r) starts in [0.9, 0.999].
    lam = jax.random.uniform(ks[5], (w,), jnp.float32, 0.0, 1.0)
    a_init = 0.9 + 0.09 * lam
    lam_param = jnp.log(jnp.expm1(-jnp.log(a_init) / _RGLRU_C))
    return {
        "wx": L.dense_init(ks[0], (d, w), 0, dtype),
        "wy": L.dense_init(ks[1], (d, w), 0, dtype),
        "wo": L.dense_init(ks[2], (w, d), 0, dtype),
        "conv": init_conv1d(ks[3], cfg.conv_width, w, dtype),
        "gate_a": init_blockdiag(ks[4], cfg.n_heads, w, dtype),
        "gate_x": init_blockdiag(ks[6], cfg.n_heads, w, dtype),
        "bias_a": jnp.zeros((w,), jnp.float32),
        "bias_x": jnp.zeros((w,), jnp.float32),
        "lam": lam_param,
    }


def _rglru_gates(params, u):
    """u: (B, T, w) post-conv input -> (a, gated_input_mult)."""
    r = jax.nn.sigmoid(
        blockdiag_apply(params["gate_a"], u).astype(jnp.float32)
        + params["bias_a"])
    i = jax.nn.sigmoid(
        blockdiag_apply(params["gate_x"], u).astype(jnp.float32)
        + params["bias_x"])
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, i, mult


def rglru_forward(params, cfg, x, *, return_cache=False, valid_len=None,
                  seq_shard=None):
    """x: (B, T, D) -> (y, cache|None).  The scan primitive carries h.

    ``valid_len``: valid leading length of ``x`` (prompt bucketing).  The
    recurrence runs over the whole padded sequence -- outputs at valid
    positions only depend on earlier positions, so they are exact -- and
    the cache snapshots the state *at* ``valid_len`` instead of at ``T``.

    ``seq_shard=(mesh, axis_name)``: sequence-parallel prefill -- the
    recurrence's T axis spans the mesh axis through
    ``linear_recurrence@sharded`` (the cross-device affine carry); the
    surrounding einsums/conv stay data-parallel under jit.  None (default)
    is the single-device path, unchanged.
    """
    dtype = x.dtype
    u_pre = jnp.einsum("btd,dw->btw", x, params["wx"].astype(dtype))
    gate_branch = jnp.einsum("btd,dw->btw", x, params["wy"].astype(dtype))
    u = causal_conv1d(params["conv"], u_pre)
    u = L.shard(u, "batch", "seq_sp", "rnn")
    a, i, mult = _rglru_gates(params, u)
    b = (mult * i * u.astype(jnp.float32))
    h = forge.linear_recurrence(
        a, b, layout=_recurrence_layout(seq_shard))      # (B, T, w) fp32
    h = h.astype(dtype)
    y = jnp.einsum("btw,wd->btd", h * jax.nn.gelu(gate_branch),
                   params["wo"].astype(dtype))
    cache = None
    if return_cache:
        if valid_len is None:
            h_last = h[:, -1]
        else:
            h_last = jax.lax.dynamic_slice_in_dim(
                h, valid_len - 1, 1, axis=1)[:, 0]
        cache = {"h": h_last.astype(jnp.float32),
                 "conv": _conv_tail(cfg, u_pre, valid_len)}
    return y, cache


def _conv_tail(cfg, u_pre, valid_len=None):
    """Last ``conv_width - 1`` inputs ending at ``valid_len`` (or ``T``).

    With a traced ``valid_len`` the slice start is dynamic: left-pad
    ``W - 1`` zero rows so padded row ``t + W - 1`` is original row ``t``,
    then slice ``W - 1`` rows starting at ``valid_len``.  Short prompts
    (``valid_len < W - 1``) pick up the left-pad zeros, matching the static
    path's explicit zero-padding.
    """
    W = cfg.conv_width
    B, T, w = u_pre.shape
    if valid_len is not None:
        padded = jnp.pad(u_pre, ((0, 0), (W - 1, 0), (0, 0)))
        return jax.lax.dynamic_slice_in_dim(padded, valid_len, W - 1, axis=1)
    tail = u_pre[:, max(T - (W - 1), 0):]
    if tail.shape[1] < W - 1:
        tail = jnp.pad(tail, ((0, 0), (W - 1 - tail.shape[1], 0), (0, 0)))
    return tail


def init_rglru_cache(cfg, batch, dtype=jnp.float32):
    w = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def rglru_decode(params, cfg, x, cache):
    """x: (B, 1, D) one-step decode; O(1) state update."""
    dtype = x.dtype
    u_pre = jnp.einsum("btd,dw->btw", x, params["wx"].astype(dtype))
    gate_branch = jnp.einsum("btd,dw->btw", x, params["wy"].astype(dtype))
    u, conv_state = conv1d_step(params["conv"], u_pre, cache["conv"])
    a, i, mult = _rglru_gates(params, u)
    b = mult * i * u.astype(jnp.float32)
    h = a[:, 0] * cache["h"] + b[:, 0]                   # (B, w)
    y = jnp.einsum("btw,wd->btd", (h[:, None].astype(dtype)
                                   * jax.nn.gelu(gate_branch)),
                   params["wo"].astype(dtype))
    return y, {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# mLSTM (xlstm): chunkwise matrix-memory recurrence with exact stabilizer
# ---------------------------------------------------------------------------


def init_mlstm_block(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    inner = 2 * d
    H = cfg.n_heads
    dh = inner // H
    ks = jax.random.split(key, 9)
    return {
        "w_up": L.dense_init(ks[0], (d, inner), 0, dtype),
        "w_gate": L.dense_init(ks[1], (d, inner), 0, dtype),
        "conv": init_conv1d(ks[2], cfg.conv_width, inner, dtype),
        "wq": init_blockdiag(ks[3], H, inner, dtype),
        "wk": init_blockdiag(ks[4], H, inner, dtype),
        "wv": init_blockdiag(ks[5], H, inner, dtype),
        "w_igate": L.dense_init(ks[6], (d, H), 0, jnp.float32),
        "w_fgate": L.dense_init(ks[7], (d, H), 0, jnp.float32),
        "b_igate": jnp.zeros((H,), jnp.float32),
        "b_fgate": jnp.full((H,), 3.0, jnp.float32),   # open forget gates
        "w_down": L.dense_init(ks[8], (inner, d), 0, dtype),
        "skip_scale": jnp.zeros((inner,), dtype),
    }


def _mlstm_stabilizer(lf, li, m0=None):
    """m_t = max(lf_t + m_{t-1}, li_t) via the MAXPLUS_AFFINE scan.

    lf, li: (B, T, H).  Returns m: (B, T, H) with m_0 seeded by m0 (or 0).
    """
    A, Bm = forge.scan(alg.MAXPLUS_AFFINE, (lf, li), axis=1)
    m_init = jnp.zeros_like(lf[:, :1]) if m0 is None else m0[:, None]
    return jnp.maximum(A + m_init, Bm)


def _mlstm_chunk_scan(q, k, v, lf, li, m, state0=None,
                      state_dtype=jnp.float32, seq_shard=None):
    """Chunkwise mLSTM.  q,k,v: (B,NC,L,H,dh); lf,li,m: (B,NC,L,H).

    Fully chunk-parallel: the inter-chunk state recurrence
    ``S_c = exp(G_L,c) * S_{c-1} + U_c`` has a *scalar per-head* decay, so
    it is a diagonal linear recurrence along the chunk axis -- one
    batched ``linear_recurrence`` launch over channels = the flattened
    (H, dh, dh) state, instead of a sequential lax.scan of NC chunk steps.
    Everything else (masked decay attention intra-chunk, the state-feeding
    einsums) is chunk-independent and vectorizes over NC.

    Returns h: (B,NC,L,H,dh) and final (C', n').
    ``state_dtype``: precision of the O(dh^2) inter-chunk states -- the
    dominant HBM traffic of the layer (EXPERIMENTS.md §Perf xlstm
    iteration); the chunk-axis recurrence runs in this dtype.
    """
    Bb, NC, Lc, H, dh = q.shape
    scale = 1.0 / np.sqrt(dh)
    q = q * scale

    # Stabilized per-step gates given the global m (computed by core.scan):
    # f'_t = exp(lf_t + m_{t-1} - m_t), i'_t = exp(li_t - m_t).
    m_prev = jnp.pad(
        m.reshape(Bb, NC * Lc, H)[:, :-1], ((0, 0), (1, 0), (0, 0))
    ).reshape(Bb, NC, Lc, H)
    lf_p = lf + m_prev - m
    li_p = li - m
    # Intra-chunk cumulative log decay G_t = sum_{s<=t} lf'_s (per chunk).
    G = jnp.cumsum(lf_p, axis=2)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # Per-chunk state contributions (parallel over chunks):
    #   U_c = sum_s exp(G_L - G_s + li'_s) k_s v_s^T,   u_c likewise for n.
    gl = G[:, :, -1:, :]                         # (B,NC,1,H) end-of-chunk G_L
    wst = jnp.exp(gl - G + li_p)                 # (B,NC,L,H)
    U = jnp.einsum("bclh,bclhd,bclhe->bchde", wst, kf, vf)
    un = jnp.einsum("bclh,bclhd->bchd", wst, kf)
    eg = jnp.exp(gl[:, :, 0])                    # (B,NC,H) per-chunk decay

    if state0 is None:
        C0 = jnp.zeros((Bb, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((Bb, H, dh), jnp.float32)
    else:
        C0, n0 = jax.tree.map(lambda t: t.astype(jnp.float32), state0)

    # Inter-chunk states after every chunk, in one batched launch each:
    # channels = flattened per-head state, decay broadcast across its block.
    def chunk_states(contrib, init, chan):
        a_full = jnp.broadcast_to(
            eg[..., None], (Bb, NC, H, chan)).reshape(Bb, NC, H * chan)
        S = forge.linear_recurrence(
            a_full.astype(state_dtype),
            contrib.reshape(Bb, NC, H * chan).astype(state_dtype),
            init.reshape(Bb, H * chan).astype(state_dtype),
            layout=_recurrence_layout(seq_shard))
        # Chunk-START states: shift right, seed with the initial state.
        start = jnp.concatenate(
            [init.reshape(Bb, 1, H * chan).astype(S.dtype), S[:, :-1]], axis=1)
        return S, start.reshape((Bb, NC, H, chan)).astype(jnp.float32)

    SC, Cs = chunk_states(U, C0, dh * dh)
    Sn, ns = chunk_states(un, n0, dh)
    Cs = Cs.reshape(Bb, NC, H, dh, dh)

    # Per-chunk outputs from the precomputed chunk-start states.  Fused
    # mask+exp+product: one (B,L,L,H) tensor instead of three, feeding the
    # v/k matmuls in bf16 (§Perf xlstm iter 2).
    def chunk_out(qc, kc, vc, lic, Gc, m_c, Cs_c, ns_c):
        logw = Gc[:, :, None, :] - Gc[:, None, :, :] + lic[:, None, :, :]
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))
        qk = jnp.einsum("blhd,bshd->blsh", qc.astype(jnp.float32),
                        kc.astype(jnp.float32))
        attn = jnp.where(tri[None, :, :, None], jnp.exp(logw) * qk,
                         0.0).astype(jnp.bfloat16)
        h_intra = jnp.einsum("blsh,bshd->blhd", attn,
                             vc.astype(jnp.bfloat16),
                             preferred_element_type=jnp.float32)
        decay_t = jnp.exp(Gc)
        h_inter = jnp.einsum("blhd,bhde->blhe", qc.astype(jnp.float32),
                             Cs_c) * decay_t[..., None]
        n_intra = jnp.einsum("blsh,bshd->blhd", attn,
                             kc.astype(jnp.bfloat16),
                             preferred_element_type=jnp.float32)
        qn_intra = jnp.einsum("blhd,blhd->blh", qc.astype(jnp.float32),
                              n_intra)
        qn_inter = jnp.einsum("blhd,bhd->blh", qc.astype(jnp.float32),
                              ns_c) * decay_t
        num = h_intra + h_inter
        qn = qn_intra + qn_inter
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_c))
        return num / denom[..., None]

    # Vectorizing chunk_out over NC multiplies the peak (B,L,L,H) attention
    # footprint by NC -- fine for decode/smoke/moderate-prefill shapes and
    # fastest there, but long-context prefill (T=500k at L=64 is ~8k chunks)
    # must not trade its former streamed peak for an NC-fold one.  Past the
    # cutoff, loop chunks with a carry-free lax.map: peak stays one chunk,
    # and unlike the old lax.scan the iterations carry no state dependency
    # (the recurrence already ran above).
    args = tuple(jnp.moveaxis(t, 1, 0)
                 for t in (q, k, v, li_p, G, m, Cs, ns))
    if Bb * NC * Lc * Lc * H <= _MLSTM_INTRA_PARALLEL_MAX_ELEMS:
        hs = jax.vmap(chunk_out)(*args)
    else:
        hs = jax.lax.map(lambda a: chunk_out(*a), args)
    h = jnp.moveaxis(hs, 0, 1)

    Cf = SC[:, -1].reshape(Bb, H, dh, dh).astype(state_dtype)
    nf = Sn[:, -1].reshape(Bb, H, dh).astype(state_dtype)
    return h, (Cf, nf)


def mlstm_forward(params, cfg, x, *, return_cache=False, valid_len=None,
                  seq_shard=None):
    """x: (B, T, D) -> (y, cache|None).

    ``valid_len``: valid leading length under prompt bucketing.  Reuses the
    chunk-padding neutral-gate trick with the effective length: positions at
    or past ``valid_len`` get ``i' = 0`` / ``f' = 1``, so the (C, n) state
    after the full padded scan equals the state after ``valid_len`` real
    steps, and the cached stabilizer/conv tail are sliced at ``valid_len``.

    ``seq_shard=(mesh, axis_name)``: the inter-chunk state recurrence (the
    chunk axis NC) runs through ``linear_recurrence@sharded`` -- long-context
    prefill's chunk-state propagation spans the mesh axis with the staged
    cross-device affine carry.  None (default) is unchanged.
    """
    dtype = x.dtype
    B, T_in, D = x.shape
    H = cfg.n_heads
    inner = 2 * D
    dh = inner // H
    Lc = min(cfg.mlstm_chunk, T_in)
    # Arbitrary-length sequences: pad to a chunk multiple with *neutral*
    # gates (i = 0 => no state update; f' = 1 under the stabilizer), so the
    # cache returned for T_in tokens is exact and pad outputs are sliced off.
    T = ((T_in + Lc - 1) // Lc) * Lc
    pad = T - T_in
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    NC = T // Lc

    u = jnp.einsum("btd,dw->btw", x, params["w_up"].astype(dtype))
    z = jnp.einsum("btd,dw->btw", x, params["w_gate"].astype(dtype))
    c = causal_conv1d(params["conv"], u)
    c = jax.nn.silu(c)
    q = blockdiag_apply(params["wq"], c)
    k = blockdiag_apply(params["wk"], c)
    v = blockdiag_apply(params["wv"], u)

    xf = x.astype(jnp.float32)
    li = jnp.einsum("btd,dh->bth", xf, params["w_igate"]) + params["b_igate"]
    lf = jax.nn.log_sigmoid(
        jnp.einsum("btd,dh->bth", xf, params["w_fgate"]) + params["b_fgate"])
    eff_len = T_in if valid_len is None else valid_len
    if pad or valid_len is not None:
        tmask = (jnp.arange(T) < eff_len)[None, :, None]
        li = jnp.where(tmask, li, -1e30)   # i' = 0: pads never write state
        lf = jnp.where(tmask, lf, 0.0)     # f' = 1: pads never decay state
    m = _mlstm_stabilizer(lf, li)                     # core.scan (MAXPLUS)

    def split(t, trailing):
        return t.reshape((B, NC, Lc) + trailing)

    h, state = _mlstm_chunk_scan(
        split(q, (H, dh)), split(k, (H, dh)), split(v, (H, dh)),
        split(lf, (H,)), split(li, (H,)), split(m, (H,)),
        state_dtype=jnp.dtype(cfg.mlstm_state_dtype), seq_shard=seq_shard)
    h = h.reshape(B, T, inner).astype(dtype)
    h = h + params["skip_scale"].astype(dtype) * c
    y = jnp.einsum("btw,wd->btd", h * jax.nn.silu(z),
                   params["w_down"].astype(dtype))
    if pad:
        y = y[:, :T_in]
    cache = None
    if return_cache:
        Cf, nf = state
        if valid_len is None:
            m_last = m[:, T_in - 1]
        else:
            m_last = jax.lax.dynamic_slice_in_dim(
                m, valid_len - 1, 1, axis=1)[:, 0]
        cache = {"C": Cf, "n": nf, "m": m_last,
                 "conv": _conv_tail(cfg, u[:, :T_in], valid_len)}
    return y, cache


def init_mlstm_cache(cfg, batch, dtype=jnp.float32):
    inner = 2 * cfg.d_model
    H = cfg.n_heads
    dh = inner // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, inner), dtype),
    }


def mlstm_decode(params, cfg, x, cache):
    """One-step mLSTM: O(dh^2) state update, no sequence dimension."""
    dtype = x.dtype
    B, _, D = x.shape
    H = cfg.n_heads
    inner = 2 * D
    dh = inner // H
    u = jnp.einsum("btd,dw->btw", x, params["w_up"].astype(dtype))
    z = jnp.einsum("btd,dw->btw", x, params["w_gate"].astype(dtype))
    c, conv_state = conv1d_step(params["conv"], u, cache["conv"])
    c = jax.nn.silu(c)
    q = blockdiag_apply(params["wq"], c).reshape(B, H, dh) / np.sqrt(dh)
    k = blockdiag_apply(params["wk"], c).reshape(B, H, dh)
    v = blockdiag_apply(params["wv"], u).reshape(B, H, dh)
    xf = x[:, 0].astype(jnp.float32)
    li = jnp.einsum("bd,dh->bh", xf, params["w_igate"]) + params["b_igate"]
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bd,dh->bh", xf, params["w_fgate"]) + params["b_fgate"])
    m_new = jnp.maximum(lf + cache["m"], li)
    fp = jnp.exp(lf + cache["m"] - m_new)
    ip = jnp.exp(li - m_new)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C_new = fp[..., None, None] * cache["C"] + ip[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n_new = fp[..., None] * cache["n"] + ip[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    qn = jnp.einsum("bhd,bhd->bh", qf, n_new)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    h = (num / denom[..., None]).reshape(B, 1, inner).astype(dtype)
    h = h + params["skip_scale"].astype(dtype) * c
    y = jnp.einsum("btw,wd->btd", h * jax.nn.silu(z),
                   params["w_down"].astype(dtype))
    return y, {"C": C_new, "n": n_new, "m": m_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM (xlstm): scalar-memory cell with recurrent gate inputs
# ---------------------------------------------------------------------------


def init_slstm_block(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    ff = int(d * 4 / 3 / 64) * 64 or 64
    per = d // H
    rk = jax.random.split(ks[1], 4)
    return {
        "w_in": L.dense_init(ks[0], (d, 4, d), 0, dtype),      # z, i, f, o
        # One block-diagonal recurrent matrix per gate (h_{t-1} -> gate).
        "r": jnp.stack([init_blockdiag(rk[g], H, d, dtype) for g in range(4)]),
        "bias": jnp.zeros((4, d), jnp.float32),
        "w_out": L.dense_init(ks[2], (d, d), 0, dtype),
        "ffn": L.init_mlp(ks[3], d, ff, "gelu", dtype),
    }


def _slstm_cell(params, cfg, xg, carry):
    """One timestep.  xg: (B, 4, D) pre-activations from input; carry dict."""
    c, n, h, m = carry["c"], carry["n"], carry["h"], carry["m"]
    hd = h.astype(params["r"].dtype)
    rec = jnp.stack(
        [blockdiag_apply(params["r"][g], hd) for g in range(4)], axis=1)
    rec = rec.astype(jnp.float32)                               # (B, 4, D)
    g = xg.astype(jnp.float32) + rec + params["bias"]
    zt = jnp.tanh(g[:, 0])
    li = g[:, 1]
    lf = jax.nn.log_sigmoid(g[:, 2])
    ot = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(lf + m, li)
    ip = jnp.exp(li - m_new)
    fp = jnp.exp(lf + m - m_new)
    c_new = fp * c + ip * zt
    n_new = fp * n + ip
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_forward(params, cfg, x, *, return_cache=False, valid_len=None):
    """``valid_len``: freeze the carry past it (prompt bucketing) -- the
    scan still runs ``T`` steps, but steps at or beyond ``valid_len`` keep
    the previous state, so the returned cache is the state after exactly
    ``valid_len`` real steps.  The ``None`` path is byte-identical to the
    unmasked scan."""
    dtype = x.dtype
    B, T, D = x.shape
    xg = jnp.einsum("btd,dgk->btgk", x, params["w_in"].astype(dtype))

    carry0 = init_slstm_cache(cfg, B)
    carry0.pop("conv", None)
    if valid_len is None:
        def step(carry, xt):
            new = _slstm_cell(params, cfg, xt, carry)
            return new, new["h"]

        carry, hs = jax.lax.scan(step, carry0, jnp.moveaxis(xg, 1, 0))
    else:
        def step(carry, inp):
            xt, t = inp
            new = _slstm_cell(params, cfg, xt, carry)
            new = jax.tree.map(
                lambda a, b: jnp.where(t < valid_len, a, b), new, carry)
            return new, new["h"]

        carry, hs = jax.lax.scan(
            step, carry0, (jnp.moveaxis(xg, 1, 0), jnp.arange(T)))
    h = jnp.moveaxis(hs, 0, 1).astype(dtype)                   # (B, T, D)
    y = jnp.einsum("btd,de->bte", h, params["w_out"].astype(dtype))
    y = y + L.mlp(params["ffn"], y, "gelu")
    cache = dict(carry) if return_cache else None
    return y, cache


def init_slstm_cache(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def slstm_decode(params, cfg, x, cache):
    dtype = x.dtype
    xg = jnp.einsum("btd,dgk->btgk", x, params["w_in"].astype(dtype))[:, 0]
    new = _slstm_cell(params, cfg, xg, cache)
    h = new["h"][:, None].astype(dtype)
    y = jnp.einsum("btd,de->bte", h, params["w_out"].astype(dtype))
    y = y + L.mlp(params["ffn"], y, "gelu")
    return y, new
