"""Model blocks and wrappers for the 10 assigned architectures."""
