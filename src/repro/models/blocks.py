"""Layer blocks: residual wiring for every layer kind, all three modes.

Kinds: ``attn_global`` / ``attn_local`` (GQA + MLP), ``gqa_dense`` (alias),
``gqa_moe`` (GQA + MoE), ``mla_dense`` / ``mla_moe`` (MLA attention),
``rglru`` (Griffin recurrent), ``mlstm`` / ``slstm`` (xLSTM), ``enc_attn``
(bidirectional), ``dec_attn`` (self + cross).  Pre-norm residuals with
optional gemma-style post-norms.

``block_forward(params, kind, cfg, x, positions, mode=...)`` returns
``(x, cache, aux)`` where ``mode`` is "train" | "prefill" | "decode".
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R

ZERO_AUX = {"lb_loss": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32)}

_ATTN_KINDS = ("attn_global", "attn_local", "gqa_dense", "gqa_moe", "enc_attn")


def _has_mlp(kind):
    return kind not in ("mlstm", "slstm")


def _is_moe(kind):
    return kind.endswith("_moe")


def init_block(key, kind, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"norm1": L.init_rmsnorm(cfg.d_model, dtype)}
    if cfg.post_norm:
        p["post_norm1"] = L.init_rmsnorm(cfg.d_model, dtype)
    if kind in _ATTN_KINDS:
        p["attn"] = A.init_gqa(ks[0], cfg, dtype)
    elif kind == "dec_attn":
        p["attn"] = A.init_gqa(ks[0], cfg, dtype)
        p["cross"] = A.init_cross(ks[1], cfg, dtype)
        p["norm_cross"] = L.init_rmsnorm(cfg.d_model, dtype)
    elif kind.startswith("mla"):
        p["attn"] = A.init_mla(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = R.init_rglru_block(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = R.init_mlstm_block(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = R.init_slstm_block(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if _has_mlp(kind):
        p["norm2"] = L.init_rmsnorm(cfg.d_model, dtype)
        if cfg.post_norm:
            p["post_norm2"] = L.init_rmsnorm(cfg.d_model, dtype)
        if _is_moe(kind):
            p["moe"] = M.init_moe(ks[2], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                                  cfg.activation, dtype)
    return p


def _mixer_apply(params, kind, cfg, x, positions, *, mode, cache, pos,
                 enc_out, cache_len, valid_len=None):
    """Dispatch the sequence mixer.  Returns (y, new_cache).

    ``valid_len``: (prefill) valid leading length of ``x`` under prompt
    bucketing -- attention layers snapshot their caches at it; recurrent
    layers freeze/neutralize their state past it.  Causality already keeps
    right-pads out of every valid position's *output*, so only state
    construction needs it.
    """
    is_local = kind == "attn_local"
    if kind in _ATTN_KINDS:
        causal = kind != "enc_attn"
        if mode == "decode":
            return A.gqa_decode(params["attn"], cfg, x, cache, pos,
                                is_local=is_local)
        return A.gqa_forward(
            params["attn"], cfg, x, positions, is_local=is_local,
            causal=causal,
            return_cache_len=cache_len if mode == "prefill" else 0,
            valid_len=valid_len)
    if kind.startswith("mla"):
        if mode == "decode":
            return A.mla_decode(params["attn"], cfg, x, cache, pos)
        # MLA caches are written at [0, S) and decode masks slots > pos, so
        # right-pad garbage is overwritten before it ever becomes readable
        # -- the padded cache is already exact, no valid_len plumbing.
        return A.mla_forward(
            params["attn"], cfg, x, positions,
            return_cache_len=cache_len if mode == "prefill" else 0)
    if kind == "rglru":
        if mode == "decode":
            return R.rglru_decode(params["mixer"], cfg, x, cache)
        return R.rglru_forward(params["mixer"], cfg, x,
                               return_cache=mode == "prefill",
                               valid_len=valid_len)
    if kind == "mlstm":
        if mode == "decode":
            return R.mlstm_decode(params["mixer"], cfg, x, cache)
        return R.mlstm_forward(params["mixer"], cfg, x,
                               return_cache=mode == "prefill",
                               valid_len=valid_len)
    if kind == "slstm":
        if mode == "decode":
            return R.slstm_decode(params["mixer"], cfg, x, cache)
        return R.slstm_forward(params["mixer"], cfg, x,
                               return_cache=mode == "prefill",
                               valid_len=valid_len)
    raise ValueError(kind)


def block_forward(params, kind, cfg, x, positions, *, mode="train",
                  cache=None, pos=None, enc_out=None, cache_len=0,
                  valid_len=None):
    """Returns (x, new_cache, aux)."""
    aux = dict(ZERO_AUX)
    x = L.shard(x, "batch", "seq_sp", None)

    if kind == "dec_attn":
        h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
        self_cache = cache["self"] if mode == "decode" else None
        h, new_self = _mixer_apply(
            params, "attn_global", cfg, h, positions, mode=mode,
            cache=self_cache, pos=pos, enc_out=None, cache_len=cache_len,
            valid_len=valid_len)
        x = x + h
        hc = L.rmsnorm(params["norm_cross"], x, cfg.norm_eps)
        if mode == "decode":
            hc = A.cross_decode(params["cross"], cfg, hc, cache["cross"])
            new_cross = cache["cross"]
        else:
            hc = A.cross_forward(params["cross"], cfg, hc, enc_out)
            new_cross = (A.cross_build_cache(params["cross"], cfg, enc_out)
                         if mode == "prefill" else None)
        x = x + hc
        new_cache = ({"self": new_self, "cross": new_cross}
                     if mode != "train" else None)
    else:
        h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
        h, new_cache = _mixer_apply(
            params, kind, cfg, h, positions, mode=mode, cache=cache, pos=pos,
            enc_out=enc_out, cache_len=cache_len, valid_len=valid_len)
        if cfg.post_norm:
            h = L.rmsnorm(params["post_norm1"], h, cfg.norm_eps)
        x = x + h

    if _has_mlp(kind):
        h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        if _is_moe(kind):
            h, aux = M.moe_forward(params["moe"], cfg, h)
        else:
            h = L.mlp(params["mlp"], h, cfg.activation)
        if cfg.post_norm:
            h = L.rmsnorm(params["post_norm2"], h, cfg.norm_eps)
        x = x + h

    return x, new_cache, aux


def init_block_cache(kind, cfg, batch, cache_len, dtype=jnp.bfloat16):
    """Zero decode cache for one block (used by serve engines + dry-run)."""
    if kind in _ATTN_KINDS:
        return A.init_gqa_cache(cfg, batch, cache_len,
                                kind == "attn_local", dtype)
    if kind == "dec_attn":
        return {
            "self": A.init_gqa_cache(cfg, batch, cache_len, False, dtype),
            "cross": {
                "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
            },
        }
    if kind.startswith("mla"):
        return A.init_mla_cache(cfg, batch, cache_len, dtype)
    if kind == "rglru":
        return R.init_rglru_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return R.init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return R.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)
