import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place the 512 placeholder
# devices are requested; smoke tests and benches see the real device count.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent end-to-end:
sharding propagation succeeds, every collective is partitionable, and the
compiled artifact yields the memory/cost/collective numbers the roofline
analysis (benchmarks/roofline.py, EXPERIMENTS.md §Roofline) consumes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both

Outputs one JSON per cell under results/dryrun/.
"""
import argparse
import functools
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, input_specs, list_archs
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.training import optimizer as OPT
from repro.training import train_step as TS

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _token_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo: str) -> dict:
    """Per-collective byte totals from post-partitioning HLO.

    Shapes in compiled HLO are per-device (local); the roofline term divides
    by per-chip link bandwidth directly.  Convention: the moved volume of one
    op is the largest tensor it touches (gather: output, scatter: input,
    reduce/permute/a2a: tensor size); ring-algorithm factors are applied in
    the roofline calculation, not here.
    """
    out = {op: {"count": 0, "bytes": 0} for op in COLLECTIVE_OPS}
    for line in hlo.splitlines():
        stripped = line.strip()
        if " = " not in stripped:
            continue
        rhs = stripped.split(" = ", 1)[1]
        for op in COLLECTIVE_OPS:
            # Match the op as the instruction name: "bf16[...] all-gather(..."
            m = re.search(r"\b" + op + r"(?:-start|-done)?\(", rhs)
            if not m:
                continue
            if op == "all-gather" and "all-gather-done" in rhs:
                continue  # -done carries no new bytes (counted at -start)
            toks = _SHAPE_RE.findall(stripped)
            if not toks:
                continue
            size = max(_token_bytes(dt, dims) for dt, dims in toks)
            out[op]["count"] += 1
            out[op]["bytes"] += size
            break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _pick_train_cfg(cfg):
    big = cfg.param_count() > 60e9
    return TS.TrainConfig(
        optimizer=OPT.OptimizerConfig(
            name="adafactor" if big else "adamw"),
        remat="full",
        grad_dtype="bfloat16",
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               unroll: bool = False, overrides: str = ""):
    """Build, lower and compile one cell.  Returns (record, compiled).

    ``unroll=True`` fully unrolls the layer-stack and inner KV/chunk loops so
    that cost_analysis (which counts while-loop bodies once) reports
    trip-count-correct FLOPs/bytes/collectives -- required for the roofline.
    The rolled variant is what production would lower (small HLO).
    """
    from repro.models import attention as _attn
    lm.SCAN_UNROLL = unroll
    # Inner KV/chunk loops stay rolled even in unroll mode (compile cost);
    # benchmarks/roofline.py applies the analytic inner-loop correction.
    _attn.KV_UNROLL = False
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        kv = {}
        for item in overrides.split(","):
            key_, val = item.split("=")
            field_type = type(getattr(cfg, key_))
            kv[key_] = field_type(val) if field_type is not bool \
                else val.lower() in ("1", "true")
        cfg = dataclasses.replace(cfg, **kv)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    key = jax.random.PRNGKey(0)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "params_total": None, "params_active": None,
    }

    if shape.kind == "decode" and shape_name == "long_500k" and cfg.quadratic:
        rec["skipped"] = "full-attention arch: long_500k needs sub-quadratic"
        return rec, None

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            tc = _pick_train_cfg(cfg)
            state_shape = jax.eval_shape(
                functools.partial(TS.init_state, cfg=cfg, train_cfg=tc), key)
            batch_shape = input_specs(cfg, shape)
            sspec = TS.state_specs(state_shape, cfg, mesh)
            bspec = SH.batch_specs(batch_shape, cfg, mesh)
            step = TS.make_train_step(cfg, mesh, tc)
            jitted = jax.jit(
                step,
                in_shardings=(SH.named(mesh, sspec), SH.named(mesh, bspec)),
                out_shardings=(SH.named(mesh, sspec), None),
                donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch_shape)
            rec["optimizer"] = tc.optimizer.name
        elif shape.kind == "prefill":
            batch_shape = input_specs(cfg, shape)
            params_shape = jax.eval_shape(
                functools.partial(lm.init_params, cfg=cfg), key)
            pspec = SH.param_specs(params_shape, cfg, mesh)
            bspec = SH.batch_specs(batch_shape, cfg, mesh)
            step = TS.make_prefill_step(
                cfg, mesh, cache_len=shape.seq_len + cfg.num_prefix_embeds)
            jitted = jax.jit(
                step,
                in_shardings=(SH.named(mesh, pspec), SH.named(mesh, bspec)))
            lowered = jitted.lower(params_shape, batch_shape)
        else:  # decode
            B = shape.global_batch
            cache_len = shape.seq_len + cfg.num_prefix_embeds
            params_shape, cache_shape = TS.serve_state_shapes(
                cfg, B, cache_len)
            pspec = SH.param_specs(params_shape, cfg, mesh)
            cspec = SH.cache_specs(cache_shape, cfg, mesh)
            tok_shape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            tspec = SH.batch_specs({"tokens": tok_shape}, cfg, mesh)["tokens"]
            step = TS.make_decode_step(cfg, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(SH.named(mesh, pspec), SH.named(mesh, cspec),
                              jax.NamedSharding(mesh, tspec), None),
                out_shardings=(None, SH.named(mesh, cspec)),
                donate_argnums=(1,))
            lowered = jitted.lower(
                params_shape, cache_shape, tok_shape,
                jax.ShapeDtypeStruct((), jnp.int32))

        compiled = lowered.compile()

    rec["lower_compile_s"] = round(time.time() - t0, 1)
    rec["params_total"] = cfg.param_count()
    rec["params_active"] = cfg.active_param_count()

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    rec["cost_analysis"] = {
        "flops": float(ca.get("flops", -1)),
        "bytes_accessed": float(ca.get("bytes accessed", -1)),
        "transcendentals": float(ca.get("transcendentals", -1)),
    }
    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory_analysis"] = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", -1),
            "output_bytes": getattr(ma, "output_size_in_bytes", -1),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", -1),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", -1),
            "generated_code_bytes": getattr(
                ma, "generated_code_size_in_bytes", -1),
        }
    hlo_text = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo_text)
    # Trip-count-aware costs (XLA's cost_analysis counts while bodies once;
    # see benchmarks/hlo_cost.py).  This is what the roofline consumes.
    try:
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))
        from benchmarks import hlo_cost
        rec["hlo_cost"] = hlo_cost.analyze(hlo_text)
    except Exception as e:  # noqa: BLE001
        rec["hlo_cost_error"] = f"{type(e).__name__}: {e}"
    return rec, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--unroll", action="store_true",
                    help="trip-count-correct cost accounting (slow compiles)")
    ap.add_argument("--override", default="",
                    help="config overrides, e.g. mlstm_chunk=256,...")
    ap.add_argument("--tag", default="",
                    help="suffix for output filenames (perf experiments)")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
                if args.tag:
                    tag += "__" + args.tag
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip cached] {tag}", flush=True)
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec, compiled = lower_cell(arch, shape_name, multi_pod,
                                               unroll=args.unroll,
                                               overrides=args.override)
                    if "skipped" in rec:
                        print(f"  -> skipped: {rec['skipped']}", flush=True)
                    else:
                        print(f"  -> ok in {rec['lower_compile_s']}s  "
                              f"flops={rec['cost_analysis']['flops']:.3e}  "
                              f"coll={rec['collectives']['total_bytes']:.3e}B",
                              flush=True)
                        del compiled
                except Exception as e:  # noqa: BLE001 -- record and continue
                    failures += 1
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"  -> FAILED: {type(e).__name__}: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
    print(f"dry-run complete; {failures} failures", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
