"""Production mesh construction (FUNCTION, not module constant -- importing
this module never touches jax device state).

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis carries
data parallelism across the inter-pod (DCN-class) links and is the axis the
optional pipeline-parallel mode stages over.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over however many (fake) devices exist -- tests only."""
    n = n_devices or len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
