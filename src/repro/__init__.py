"""repro: KernelForge-TPU -- portable parallel primitives + multi-pod LM framework."""

__version__ = "0.1.0"
