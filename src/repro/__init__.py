"""repro: KernelForge -- portable parallel primitives + multi-pod LM framework.

Backend selection surface (the documented way to pick a lowering):

    import repro
    repro.available_backends()           # ("pallas-gpu", "pallas-interpret", ...)
    repro.supports("scan@flat", "pallas-gpu")
    with repro.use_backend("pallas-gpu"):
        forge.scan(op, xs)               # every dispatch in scope uses it

``use_backend`` is thread-safe and scoped; an explicit ``backend=`` argument
on a primitive call still wins.  The legacy ``force_backend()`` global pin
survives as a warn-once deprecated shim in ``repro.core.intrinsics``.
"""

from repro.core.intrinsics import (  # noqa: F401
    available_backends,
    current_backend,
    force_backend,  # deprecated shim (warns once); not in __all__
    supports,
    use_backend,
)

__all__ = [
    "available_backends",
    "current_backend",
    "supports",
    "use_backend",
]

__version__ = "0.1.0"
