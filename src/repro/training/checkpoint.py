"""Sharded checkpointing with a logical-index manifest + elastic restore.

Design (scaled-down from what a 1000-node deployment needs, same API):

* Every state leaf is saved as one ``.npy`` holding the *logical* (unsharded)
  array, keyed by its pytree path in ``manifest.json``.  Because the manifest
  is mesh-agnostic, restore can target **any** mesh shape -- elastic scaling
  is a restore-time resharding, not a format change.  (At true fleet scale
  each host would write per-shard files plus the same logical index; the
  manifest schema already carries shape/dtype per leaf so that change is
  IO-layout only.)
* Writes are atomic: a ``step_N.tmp`` directory is renamed to ``step_N`` only
  after the manifest lands -- a crash mid-save can never corrupt the latest
  valid checkpoint.
* ``AsyncCheckpointer`` moves serialization off the training thread
  (device->host copies happen synchronously, disk IO in the background).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def save(ckpt_dir: str, step: int, state: Pytree) -> str:
    """Synchronous atomic save.  Returns the checkpoint path."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = {}
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    for i, (path, leaf) in enumerate(flat):
        name = f"leaf_{i:05d}.npy"
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name), arr)
        leaves[_path_str(path)] = {
            "file": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": leaves}, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, state_shape: Pytree,
            shardings: Pytree | None = None) -> Pytree:
    """Restore onto any mesh (elastic): logical arrays are resharded on load."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shape)
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(flat))
    out = []
    for (leaf_path, leaf), sh in zip(flat, shard_leaves):
        key = _path_str(leaf_path)
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        assert tuple(arr.shape) == tuple(leaf.shape), \
            f"{key}: checkpoint shape {arr.shape} != expected {leaf.shape}"
        if sh is not None:
            out.append(jax.make_array_from_callback(
                arr.shape, sh, lambda idx, a=arr: a[idx]))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out)


def cleanup(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, state: Pytree):
        self.wait()
        # Device->host copy must happen before the train loop mutates
        # (donates) the buffers; the disk write runs in the background.
        host_state = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), state)

        def work():
            try:
                save(self.ckpt_dir, step, host_state)
                cleanup(self.ckpt_dir, self.keep)
            except Exception as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
