"""Training substrate: optimizer, data, checkpoint, trainer, steps."""
