"""jit-ready train / prefill / decode step builders with full sharding.

``make_train_step(cfg, mesh, ...)`` returns (step_fn, state_shape, shardings)
where ``step_fn(state, batch) -> (state, metrics)`` is ready for
``jax.jit(..., in_shardings=..., out_shardings=..., donate_argnums=0)``.
The same builders feed the trainer, the serving engine, and the multi-pod
dry-run (which lowers them against ShapeDtypeStructs).

Distributed-optimization features:
* bf16 parameter cast inside the loss => gradient all-reduce/reduce-scatter
  runs in bf16 (half the collective bytes; ``grad_dtype`` flag);
* microbatch gradient accumulation via lax.scan (``accum_steps``);
* per-unit rematerialization (``remat``);
* donated state buffers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as SH
from repro.models import layers as L
from repro.models import lm
from repro.training import optimizer as OPT

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OPT.OptimizerConfig = OPT.OptimizerConfig()
    remat: str = "full"               # full | dots | none
    accum_steps: int = 1
    grad_dtype: str = "bfloat16"      # collective compression (bf16 reduce)
    z_loss: float = 1e-4
    lb_coef: float = 0.01
    seed: int = 0


def init_state(key, cfg, train_cfg: TrainConfig):
    params = lm.init_params(key, cfg)
    opt_init, _ = OPT.make_optimizer(train_cfg.optimizer)
    return {
        "params": params,
        "opt": opt_init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(state_shape, cfg, mesh):
    pspecs = SH.param_specs(state_shape["params"], cfg, mesh)

    def opt_spec(path, leaf):
        # Optimizer state mirrors the param layout; factored adafactor
        # moments drop the last axis -- match by reusing param_spec on the
        # (possibly reduced) shape via the same path tail.
        return SH.param_spec(SH._path_str(path), leaf.shape, cfg, mesh)

    ospecs = jax.tree_util.tree_map_with_path(opt_spec, state_shape["opt"])
    return {"params": pspecs, "opt": ospecs, "step": P()}


def make_train_step(cfg, mesh, train_cfg: TrainConfig):
    rules = SH.make_rules(cfg, mesh)
    opt_init, opt_update = OPT.make_optimizer(train_cfg.optimizer)
    gdtype = jnp.dtype(train_cfg.grad_dtype)

    def loss_fn(params, batch):
        # Collective compression: grads of bf16 params reduce in bf16.
        p_low = jax.tree.map(
            lambda x: x.astype(gdtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
        loss, metrics = lm.forward_train(
            p_low, cfg, batch, remat=train_cfg.remat,
            z_loss=train_cfg.z_loss, lb_coef=train_cfg.lb_coef)
        return loss, metrics

    def train_step(state, batch):
        with L.sharding_rules(rules):
            params = state["params"]
            if train_cfg.accum_steps > 1:
                na = train_cfg.accum_steps

                def micro(carry, mb):
                    g_acc, l_acc = carry
                    (loss, metrics), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + loss), metrics

                mbs = jax.tree.map(
                    lambda x: x.reshape((na, x.shape[0] // na) + x.shape[1:]),
                    batch)
                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), metrics = jax.lax.scan(micro, (g0, 0.0), mbs)
                grads = jax.tree.map(lambda g: g / na, grads)
                loss = loss / na
                metrics = jax.tree.map(lambda m: m[-1], metrics)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)

            grads, gnorm = OPT.clip_by_global_norm(
                grads, train_cfg.optimizer.grad_clip)
            new_params, new_opt = opt_update(
                grads, state["opt"], params, state["step"])
            new_state = {
                "params": new_params,
                "opt": new_opt,
                "step": state["step"] + 1,
            }
            metrics = dict(metrics)
            metrics["grad_norm"] = gnorm
            metrics["lr"] = OPT.lr_schedule(train_cfg.optimizer, state["step"])
        return new_state, metrics

    return train_step


def jit_train_step(cfg, mesh, train_cfg, state_shape, batch_shape):
    """jit with explicit in/out shardings + donation (production entry)."""
    step = make_train_step(cfg, mesh, train_cfg)
    sspec = state_specs(state_shape, cfg, mesh)
    bspec = SH.batch_specs(batch_shape, cfg, mesh)
    mspec = None  # metrics: let the compiler choose (scalars)
    return jax.jit(
        step,
        in_shardings=(SH.named(mesh, sspec), SH.named(mesh, bspec)),
        out_shardings=(SH.named(mesh, sspec), None),
        donate_argnums=(0,),
    )


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg, mesh, cache_len):
    rules = SH.make_rules(cfg, mesh)

    def prefill_step(params, batch):
        with L.sharding_rules(rules):
            kwargs = {}
            if cfg.is_encdec:
                kwargs["src_embeds"] = batch["src_embeds"]
            if cfg.num_prefix_embeds:
                kwargs["vision_embeds"] = batch["vision_embeds"]
            if batch.get("valid_len") is not None:
                kwargs["valid_len"] = batch["valid_len"]
            p_low = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
            return lm.prefill(p_low, cfg, batch["tokens"],
                              cache_len=cache_len, **kwargs)

    return prefill_step


def make_decode_step(cfg, mesh):
    rules = SH.make_rules(cfg, mesh)
    if rules:
        # Measured regression (EXPERIMENTS.md §Perf cell 2): the
        # zero-collective MoE dispatch gathers every local expert's weights,
        # which loses at decode batch sizes (T_local ~ 8 tokens) -- GSPMD's
        # lowering moves less there.  Dispatch trick is train/prefill-only.
        rules = {**rules, "moe_shard_map": False, "decode_mla_shard": False}

    def decode_step(params, caches, tokens, pos):
        step_rules = rules
        if rules and getattr(pos, "ndim", 0):
            # Per-slot (B,) positions (continuous batching): the flash-decode
            # shard-map paths key their owner-local cache update on a single
            # scalar slot, so they are scalar-pos only -- attention falls back
            # to the per-row scatter path, and the rules say so explicitly.
            step_rules = {**rules, "decode_kv_shard": False,
                          "decode_mla_shard": False}
        with L.sharding_rules(step_rules):
            p_low = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
            logits, new_caches = lm.decode_step(p_low, cfg, caches, tokens, pos)
        return logits, new_caches

    return decode_step


def serve_state_shapes(cfg, batch, cache_len):
    """Abstract (params, caches) shapes for the decode dry-run."""
    params_shape = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    cache_shape = jax.eval_shape(
        functools.partial(lm.init_caches, cfg, batch, cache_len))
    return params_shape, cache_shape
