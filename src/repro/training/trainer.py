"""Training loop with fault tolerance: checkpoint/restart, failure recovery,
straggler detection.

Failure model (what a 1000-node fleet actually sees, scaled to a test rig):
* **Crash/restart**: the loop resumes from the newest complete checkpoint;
  the stateless step-indexed data pipeline replays exactly the right batches.
* **Step failure** (device error, NaN loss, injected fault): the step is
  retried from the last checkpoint up to ``max_retries`` times, skipping the
  poisoned batch (batch index advances past it) -- the standard "bad node /
  bad batch" quarantine move.
* **Stragglers**: per-step wall time is tracked against a rolling median;
  steps slower than ``straggler_factor`` x median are counted and logged
  (on a real fleet this signal feeds the scheduler's hot-spare swap; here it
  feeds metrics + tests).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import checkpoint as CKPT
from repro.training import train_step as TS
from repro.training.data import SyntheticDataset


@dataclasses.dataclass
class RunConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    max_retries: int = 2
    straggler_factor: float = 3.0


class Trainer:
    def __init__(self, cfg, mesh, train_cfg: TS.TrainConfig,
                 run_cfg: RunConfig, dataset: SyntheticDataset,
                 step_fn: Callable | None = None,
                 fault_hook: Callable | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.train_cfg = train_cfg
        self.run_cfg = run_cfg
        self.dataset = dataset
        self.fault_hook = fault_hook  # (step) -> None, may raise (tests)
        self.step_times: list = []
        self.straggler_steps: list = []
        self.recoveries = 0
        self.metrics_log: list = []

        self.state_shape = jax.eval_shape(
            lambda k: TS.init_state(k, cfg, train_cfg), jax.random.PRNGKey(0))
        if step_fn is not None:
            self.step_fn = step_fn
        else:
            self.step_fn = jax.jit(TS.make_train_step(cfg, mesh, train_cfg),
                                   donate_argnums=(0,))
        self.ckpt = CKPT.AsyncCheckpointer(run_cfg.ckpt_dir,
                                           keep=run_cfg.keep_ckpts)

    # -- state ------------------------------------------------------------

    def init_or_restore(self):
        last = CKPT.latest_step(self.run_cfg.ckpt_dir)
        if last is not None:
            state = CKPT.restore(self.run_cfg.ckpt_dir, last, self.state_shape)
            print(f"[trainer] restored step {last}", flush=True)
            return state, last
        state = TS.init_state(jax.random.PRNGKey(self.train_cfg.seed),
                              self.cfg, self.train_cfg)
        return state, 0

    # -- loop --------------------------------------------------------------

    def run(self):
        state, start = self.init_or_restore()
        step = start
        skip_batches: set = set()
        while step < self.run_cfg.total_steps:
            data_step = step
            while data_step in skip_batches:
                data_step += 1
            batch = self.dataset.batch(data_step)
            t0 = time.time()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}")
            except Exception as e:  # noqa: BLE001 -- recovery path
                self.recoveries += 1
                if self.recoveries > self.run_cfg.max_retries:
                    raise
                print(f"[trainer] step {step} failed ({e}); recovering",
                      flush=True)
                skip_batches.add(data_step)
                self.ckpt.wait()
                last = CKPT.latest_step(self.run_cfg.ckpt_dir)
                if last is not None:
                    state = CKPT.restore(self.run_cfg.ckpt_dir, last,
                                         self.state_shape)
                    step = last
                else:
                    state, step = self.init_or_restore()
                continue

            dt = time.time() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times[-20:]))
            if len(self.step_times) > 5 and dt > self.run_cfg.straggler_factor * med:
                self.straggler_steps.append(step)
                print(f"[trainer] straggler: step {step} took {dt:.2f}s "
                      f"(median {med:.2f}s)", flush=True)

            step += 1
            if step % self.run_cfg.log_every == 0 or step == 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["step_time_s"] = dt
                self.metrics_log.append(m)
                print(f"[trainer] step {step}: loss={m['loss']:.4f} "
                      f"ce={m.get('ce_loss', float('nan')):.4f} "
                      f"gnorm={m.get('grad_norm', float('nan')):.2f} "
                      f"({dt:.2f}s)", flush=True)
            if step % self.run_cfg.ckpt_every == 0:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        CKPT.save(self.run_cfg.ckpt_dir, step, state)
        return state
