"""Optimizers in pure JAX: AdamW and Adafactor, with clipping + schedules.

Optimizer state lives in the same pytree layout as params, so parameter
PartitionSpecs transfer leafwise (ZeRO: sharded optimizer state for free).
Adafactor (factored second moment, no momentum by default) exists because
671B-class models cannot afford 3x fp32 state per weight -- see
EXPERIMENTS.md §Dry-run memory notes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # adamw | adafactor
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def lr_schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    return {
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def adamw_update(cfg: OptimizerConfig, grads, state, params, step):
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    mu_leaves = treedef.flatten_up_to(state["mu"])
    nu_leaves = treedef.flatten_up_to(state["nu"])
    new_mu, new_nu, new_p = [], [], []
    for g, mu, nu, p in zip(g_leaves, mu_leaves, nu_leaves, p_leaves):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        update = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_mu.append(mu)
        new_nu.append(nu)
        new_p.append((p.astype(jnp.float32) - lr * update).astype(p.dtype))
    return treedef.unflatten(new_p), {"mu": treedef.unflatten(new_mu),
                                      "nu": treedef.unflatten(new_nu)}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory ~= params in fp32 row/col sums)
# ---------------------------------------------------------------------------


def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(init, params)}


def adafactor_update(cfg: OptimizerConfig, grads, state, params, step):
    lr = lr_schedule(cfg, step)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8
    eps = 1e-30

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    v_leaves = treedef.flatten_up_to(state["v"])
    new_v, new_p = [], []
    for g, v, p in zip(g_leaves, v_leaves, p_leaves):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p.shape):
            vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps) + eps)
            cfac = jax.lax.rsqrt(vc + eps)
            update = g * rfac[..., None] * cfac[..., None, :]
            nv = {"vr": vr, "vc": vc}
        else:
            nvv = decay * v["v"] + (1 - decay) * g2
            update = g * jax.lax.rsqrt(nvv + eps)
            nv = {"v": nvv}
        # Update clipping (RMS <= 1) per Adafactor.
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + eps)
        update = update / jnp.maximum(1.0, rms)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_v.append(nv)
        new_p.append((p.astype(jnp.float32) - lr * update).astype(p.dtype))
    return treedef.unflatten(new_p), {"v": treedef.unflatten(new_v)}


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return adamw_init, functools.partial(adamw_update, cfg)
    if cfg.name == "adafactor":
        return adafactor_init, functools.partial(adafactor_update, cfg)
    raise ValueError(cfg.name)
