"""Synthetic data pipeline: deterministic, step-indexed, shardable.

Every batch is a pure function of ``(seed, step)`` -- no iterator state to
checkpoint, so restart/elastic-resume is exact: the trainer records only the
step counter.  On a multi-host cluster each host materializes only its
addressable shard via ``jax.make_array_from_callback``; in this container the
full array is materialized locally and sharded across the (fake) devices.

The token stream is a deterministic Zipf-ish mixture with a learnable
structure (repeated n-grams) so that a few hundred steps of training show a
real loss decrease in the examples -- a plain uniform stream has no signal.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as SH


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 128
    global_batch: int = 8
    vocab_size: int = 512
    seed: int = 17


def _host_batch(cfg, model_cfg, step: int) -> dict:
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # Markov-ish stream: each token depends on the previous via a fixed
    # random permutation most of the time -> learnable structure.
    perm = np.random.default_rng(cfg.seed).permutation(V)
    toks = np.empty((B, S + 1), np.int32)
    toks[:, 0] = rng.integers(0, V, B)
    noise = rng.random((B, S)) < 0.15
    rand = rng.integers(0, V, (B, S))
    for t in range(S):
        nxt = perm[toks[:, t]]
        toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if model_cfg is not None and model_cfg.is_encdec:
        batch["src_embeds"] = rng.standard_normal(
            (B, S, model_cfg.d_model), np.float32).astype(np.float32) * 0.1
    if model_cfg is not None and model_cfg.num_prefix_embeds:
        batch["vision_embeds"] = rng.standard_normal(
            (B, model_cfg.num_prefix_embeds, model_cfg.d_model),
            np.float32).astype(np.float32) * 0.1
    return batch


class SyntheticDataset:
    """Stateless step-indexed loader."""

    def __init__(self, cfg: DataConfig, model_cfg=None, mesh=None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.mesh = mesh

    def host_batch(self, step: int) -> dict:
        return _host_batch(self.cfg, self.model_cfg, step)

    def batch(self, step: int) -> dict:
        """Device batch, sharded over dp when a mesh is provided."""
        host = self.host_batch(step)
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        specs = SH.batch_specs(
            {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in host.items()},
            self.model_cfg, self.mesh)
        out = {}
        for k, v in host.items():
            sharding = jax.NamedSharding(self.mesh, specs[k])
            out[k] = jax.make_array_from_callback(
                v.shape, sharding, lambda idx, v=v: v[idx])
        return out
