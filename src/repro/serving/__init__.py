"""Serving: KV/state caches, batched engine."""
