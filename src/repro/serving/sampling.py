"""Counter-based sampling shared by the serving engine and every decoding
strategy (serving/strategies/).

The key discipline is the serving stack's determinism anchor: the key for
request ``r``'s ``j``-th token is ``fold_in(fold_in(base, seed_r), j)`` -- a
pure function of (engine seed, request seed, token index), independent of
batch composition, admission order, or which engine runs it.  Decoding
strategies that need *additional* random streams (the draft proposals of
speculative decoding) derive them by folding a per-stream tag into the base
key first (:func:`stream_key`), so the extra stream inherits the same
composition-independence without ever colliding with the verify stream.

``sample_tokens`` routes temperature>0 sampling through the primitive
substrate: ``top_k(layout=Segmented(...))`` over the flat per-request vocab
stream plus a ``scan(layout=Batched())`` nucleus cutoff over the (B, k)
candidate grid; see its docstring for the pinned nucleus semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched, Segmented

# Per-stream tags folded into the engine base key (:func:`stream_key`).
# The verify/vanilla stream uses the *untagged* base key -- that identity is
# load-bearing: exact-match speculative verification samples the target's
# authoritative token with the untagged key, which is why its stream is
# bit-identical to vanilla decoding at the same seeds.
DRAFT_STREAM = 0x5D1A_F7  # draft-proposal stream of speculative decoding


def stream_key(base_key, tag: int):
    """Derive a decoding-strategy stream key: ``fold_in(base, tag)``.

    Request/step folding on top of the returned key follows the exact
    counter scheme of :func:`request_step_keys`, so tagged streams are as
    batch-composition- and draft-depth-independent as the vanilla stream.
    """
    return jax.random.fold_in(base_key, jnp.uint32(tag))


def request_step_keys(base_key, seeds, steps):
    """(B,) per-row keys: fold_in(fold_in(base, seed_b), step_b)."""
    def fold(s, t):
        return jax.random.fold_in(jax.random.fold_in(base_key, s), t)

    return jax.vmap(fold)(seeds.astype(jnp.uint32), steps.astype(jnp.uint32))


def chosen_logprobs(logits, tok):
    """log p of each batch row's sampled token under this step's logits."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]


def sample_tokens(base_key, logits, seeds, steps, *, temperature, top_k,
                  top_p, top_p_candidates):
    """Sample one token per batch row.  Returns (B,) int32.

    Greedy when ``temperature <= 0``; otherwise per-row Gumbel-argmax with
    counter-based keys (see module docstring), filtered through the
    segmented top-k / batched nucleus-cutoff primitives when configured.

    **Nucleus semantics**: the top-p cutoff is measured on the softmax
    *renormalized over the k retained candidates* (``top_k``, or
    ``top_p_candidates`` when only top-p is set), not on the full-vocab
    distribution.  Consequences this module pins with conformance tests,
    so alternative logits paths (e.g. quantized decode) cannot silently
    change them: (a) the first (highest) candidate always survives -- its
    exclusive prefix mass is 0 < top_p; (b) when the candidates' full-vocab
    mass is below ``top_p`` the renormalized masses still sum to 1, so the
    cutoff binds at the same prefix as if the tail mass were redistributed
    -- in particular every candidate survives iff the renormalized
    exclusive prefix stays below ``top_p``, regardless of how little
    full-vocab mass the k candidates carry.
    """
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    keys = request_step_keys(base_key, seeds, steps)
    B, V = logits.shape
    if top_k or top_p < 1.0:
        k = min(top_k if top_k else top_p_candidates, V)
        flat = logits.astype(jnp.float32).reshape(-1)
        offsets = jnp.arange(B + 1, dtype=jnp.int32) * V
        vals, idx = forge.top_k(flat, k, layout=Segmented(offsets=offsets))
        scaled = vals / temperature                   # (B, k) descending
        # Keep the shortest prefix whose mass reaches top_p (the first
        # candidate always survives: its exclusive prefix mass is 0).  The
        # (B, k) candidate grid is exactly the batched-scan layout: one
        # launch scans every request's row, whatever the batch size.
        probs = jax.nn.softmax(scaled, axis=-1)
        cum = forge.scan(alg.ADD, probs, inclusive=False, layout=Batched())
        filtered = jnp.where(cum < top_p, scaled, -jnp.inf)
        g = jax.vmap(lambda kk: jax.random.gumbel(kk, (k,), jnp.float32))(keys)
        choice = jnp.argmax(filtered + g, axis=-1)
        return jnp.take_along_axis(
            idx, choice[:, None], axis=1)[:, 0].astype(jnp.int32)
    g = jax.vmap(lambda kk: jax.random.gumbel(kk, (V,), jnp.float32))(keys)
    return jnp.argmax(logits.astype(jnp.float32) / temperature + g,
                      axis=-1).astype(jnp.int32)


def masked_seq_logprobs(logps, emitted):
    """Per-slot sequence scores over the ragged (slots, steps) buffer:
    one masked ``mapreduce(layout=Batched())`` launch, identity at masked
    steps -- identical code path at any live-slot count."""
    T = logps.shape[1]
    mask = (jnp.arange(T, dtype=jnp.int32)[None, :]
            < emitted[:, None]).astype(jnp.int32)
    return forge.mapreduce(
        lambda t: jnp.where(t[1] != 0, t[0], 0.0), alg.ADD,
        (logps, mask), layout=Batched())
