"""Slot-indexed decode cache: per-request paged/ring state + CSR accounting.

The continuous-batching engine keeps ONE device-resident cache pytree for
the whole batch (the same ``lm.init_caches`` tree the padded engine uses)
and treats its batch axis as an array of *slots*: a request owns a slot from
admission to eviction, and every layer's state for that request -- KV rings
for attention layers, O(1) recurrent states, conv tails -- lives at that
slot index.  This module is the address layer:

* :func:`scatter_slot` writes a freshly prefilled single-request cache into
  one slot of the live tree (handling the ``units`` stacking, whose leading
  axis is the layer axis, not the batch axis);
* :func:`poison_slot` overwrites a freed slot with a sentinel value -- used
  by the stale-state-bleed tests (a recycled slot must behave exactly like a
  fresh engine, so tests poison on eviction and diff the outputs) and
  available as a debugging mode;
* :func:`ring_slot` is the ring-buffer address map shared with
  ``attention.gqa_decode`` (slot = pos mod window), kept here so the
  wraparound tests pin the exact arithmetic the kernels use;
* :class:`SlotLedger` tracks ragged per-slot lengths on the host and renders
  them as the CSR ``offsets`` descriptor of the ``Segmented`` layout -- the
  engine's own docstring promise that ragged per-request state is "a
  descriptor change, not a new code path";
* :func:`compact_ragged` drains ragged per-slot output buffers into one
  flat stream + CSR offsets, with the exclusive +scan of lengths running on
  ``core.primitives.scan`` (the same primitive the MoE dispatch uses for
  its CSR construction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Flat


def _update(live_leaf, single_leaf, slot, axis):
    return jax.lax.dynamic_update_slice_in_dim(
        live_leaf, single_leaf.astype(live_leaf.dtype), slot, axis=axis)


def scatter_slot(live, single, slot):
    """Write a batch=1 cache tree ``single`` into ``slot`` of ``live``.

    ``live`` is the full-batch decode cache (leaves lead with the slot axis;
    ``units`` leaves lead with the layer axis, slot axis second -- the
    ``lax.scan``-stacked layout of ``lm._stack_cache``).  ``slot`` may be a
    traced scalar, so admission runs inside one jitted program.
    """
    out = dict(live)
    for part in ("prefix", "suffix"):
        out[part] = jax.tree.map(
            lambda lv, sg: _update(lv, sg, slot, 0), live[part], single[part])
    out["units"] = jax.tree.map(
        lambda lv, sg: _update(lv, sg, slot, 1), live["units"], single["units"])
    return out


def select_slots(mask, new, old):
    """Per-slot select between two full-batch cache trees.

    ``mask`` is (B,) bool over the slot axis; leaf ``l`` takes ``new``'s
    slot where ``mask`` holds, ``old``'s otherwise.  This is the cache
    *rollback* primitive of speculative decoding: the post-verify commit
    keeps the advanced cache only on slots whose proposal was accepted,
    broadcast per leaf over the slot axis (axis 0 for prefix/suffix leaves,
    axis 1 under the ``units`` layer stacking).
    """
    def sel(axis):
        def leaf(nw, od):
            shape = [1] * nw.ndim
            shape[axis] = mask.shape[0]
            return jnp.where(mask.reshape(shape), nw, od)
        return leaf

    out = dict(old)
    for part in ("prefix", "suffix"):
        out[part] = jax.tree.map(sel(0), new[part], old[part])
    out["units"] = jax.tree.map(sel(1), new["units"], old["units"])
    return out


def gather_slots(live, rows):
    """Reindex the slot axis: slot ``i`` of the result is slot ``rows[i]``
    of ``live`` (``rows``: (B,) int32; identity rows leave a slot alone).

    This is beam search's beam-reorder move: after the per-round top-k over
    beam x vocab candidates, each surviving beam inherits the cache of the
    beam it extends -- one gather over the slot axis of every leaf.
    """
    out = dict(live)
    for part in ("prefix", "suffix"):
        out[part] = jax.tree.map(
            lambda l: jnp.take(l, rows, axis=0), live[part])
    out["units"] = jax.tree.map(
        lambda l: jnp.take(l, rows, axis=1), live["units"])
    return out


def poison_slot(live, slot, value=float("nan")):
    """Overwrite every leaf of ``slot``'s state with ``value``.

    Freed-slot hygiene check: if any downstream compute ever reads a freed
    slot's state, a NaN poison turns the silent stale-read into a loud one.
    Integer leaves get the truncated value (NaN -> large sentinel via -1).
    """
    def poison(leaf, axis):
        shape = list(leaf.shape)
        shape[axis] = 1
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            fill = jnp.full(shape, value, leaf.dtype)
        else:
            fill = jnp.full(shape, -1, leaf.dtype)
        return jax.lax.dynamic_update_slice_in_dim(leaf, fill, slot, axis=axis)

    out = dict(live)
    for part in ("prefix", "suffix"):
        out[part] = jax.tree.map(lambda l: poison(l, 0), live[part])
    out["units"] = jax.tree.map(lambda l: poison(l, 1), live["units"])
    return out


def ring_slot(pos, window: int):
    """Ring-buffer slot of absolute position ``pos`` in a ``window`` cache.

    This is the address map ``attention.gqa_decode`` (local layers) and the
    engine's position bookkeeping both use; ``pos`` may be scalar or array.
    """
    return pos % window


def slot_position(slot_idx, pos, window: int):
    """Absolute position currently held by ring slot ``slot_idx`` when the
    writer is at ``pos`` (negative: slot not yet written)."""
    return pos - (pos - slot_idx) % window


def quantize_kv_tree(caches, mode: str):
    """Replace every attention KV leaf with a ``KVQuant`` (values, scales)
    node -- the opt-in ``quantize_kv=`` cache form.

    Attention KV leaves are the ``"k"``/``"v"`` dict entries of rank >= 4
    ((slot, pos, kv_head, head_dim), plus a leading layer axis under the
    ``units`` stacking); everything else -- MLA latents, recurrent states,
    conv tails -- stays dense.  Because ``KVQuant`` is a registered pytree
    whose children share the leaf's leading axes, :func:`scatter_slot`,
    :func:`poison_slot` and the ring address math above work on the
    quantized tree unchanged.
    """
    def walk(node):
        if isinstance(node, dict):
            return {
                key: (alg.quantize_kv(val, mode)
                      if key in ("k", "v") and getattr(val, "ndim", 0) >= 4
                      else walk(val))
                for key, val in node.items()}
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(caches)


class SlotError(IndexError):
    """A slot index outside ``[0, num_slots)`` reached the ledger.

    Raised instead of letting numpy's negative-index wraparound silently
    redirect the update into another live slot's length accounting."""


class SlotLedger:
    """Host-side ragged length accounting for the live slots.

    One integer length per slot (tokens currently resident in the slot's
    cache); rendered on demand as the CSR ``offsets`` descriptor that the
    ``Segmented(offsets=...)`` layout consumes.  The ledger is pure host
    bookkeeping -- it never forces a device sync.
    """

    def __init__(self, num_slots: int, cache_len: int):
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.lengths = np.zeros(num_slots, np.int64)

    def _check_slot(self, slot: int) -> int:
        slot = int(slot)
        if not 0 <= slot < self.num_slots:
            raise SlotError(
                f"slot {slot} outside [0, {self.num_slots}): negative or "
                "out-of-range slots would wrap into another slot's ledger "
                "entry")
        return slot

    def occupy(self, slot: int, length: int):
        slot = self._check_slot(slot)
        if not 0 <= length <= self.cache_len:
            raise ValueError(
                f"slot {slot}: length {length} outside [0, {self.cache_len}]")
        self.lengths[slot] = length

    def advance(self, slot: int, by: int = 1):
        slot = self._check_slot(slot)
        self.lengths[slot] = min(self.lengths[slot] + by, self.cache_len)

    def free(self, slot: int):
        slot = self._check_slot(slot)
        self.lengths[slot] = 0

    def offsets(self) -> jax.Array:
        """CSR offsets (num_slots + 1,) int32 -- the Segmented descriptor."""
        return jnp.asarray(
            np.concatenate([[0], np.cumsum(self.lengths)]), jnp.int32)

    def segment_of(self, slot: int) -> tuple[int, int]:
        """[start, end) of ``slot``'s segment in the flat CSR stream."""
        slot = self._check_slot(slot)
        start = int(self.lengths[:slot].sum())
        return start, start + int(self.lengths[slot])


def compact_ragged(buf, counts):
    """Drain ragged per-slot rows into (flat stream, CSR offsets).

    ``buf``: (B, T) per-slot buffers; ``counts``: (B,) valid prefix lengths.
    Returns ``(flat, offsets)`` with ``flat[offsets[b]:offsets[b+1]] ==
    buf[b, :counts[b]]`` -- the CSR compaction pattern (exclusive +scan of
    counts = segment starts, then a gather), with the scan on the library's
    own primitive.  Host-side drain helper: runs eagerly on small arrays.
    """
    B, T = buf.shape
    # The flat extent must be a host int (it shapes the gather).  When the
    # counts are already concrete -- the ledger hands over host numpy --
    # summing them locally avoids the blocking device->host sync that
    # ``int(incl[-1])`` forces, keeping the drain path on the module's
    # no-sync promise; only genuinely device-resident counts pay the wait.
    host_counts = None if isinstance(counts, jax.Array) else np.asarray(counts)
    counts = jnp.asarray(counts, jnp.int32)
    incl = forge.scan(alg.ADD, counts, layout=Flat())        # (B,) inclusive
    starts = incl - counts                                   # exclusive form
    if host_counts is not None:
        total = int(host_counts.sum()) if B else 0
    else:
        total = int(incl[-1]) if B else 0
    offsets = jnp.concatenate(
        [starts.astype(jnp.int32), jnp.asarray([total], jnp.int32)])
    # Gather: flat[k] = buf[b, k - starts[b]] for k in [starts[b], incl[b]).
    seg = jnp.searchsorted(incl, jnp.arange(total, dtype=jnp.int32),
                           side="right").astype(jnp.int32)
    col = jnp.arange(total, dtype=jnp.int32) - starts[seg]
    flat = buf[seg, col]
    return flat, offsets
