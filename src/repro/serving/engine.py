"""Serving engine: continuous batching with a device-resident decode loop.

Two execution paths share one model, one sampler and one RNG discipline:

**Continuous (the serving path, ``generate`` / ``serve``)** -- a host-side
FIFO scheduler (serving/scheduler.py) admits requests into live batch
*slots*; each admission prefilles the request alone at its exact prompt
length and scatters the resulting caches into its slot (serving/cache.py).
Decode then runs **on device** as one ``lax.while_loop`` whose carry holds
the caches, per-slot positions, sampled tokens, EOS/length state and the
output buffers -- between prefill and completion there is *zero* host<->
device token traffic: the all-done predicate is a ``mapreduce`` over the
active flags, EOS masking and per-slot length tracking are elementwise over
the slot axis, and per-request ``seq_logprob`` is a masked
``mapreduce(layout=Batched())`` over the (slots, steps) log-prob buffer.
Slots free as requests hit EOS / ``max_new_tokens``; the scheduler recycles
them for waiting arrivals (open-loop traffic), so the batch is continuously
full instead of padded to the slowest request.

**Padded (the reference oracle, ``generate_padded``)** -- the original
fixed-batch host loop: one prefill over the left-padded batch, one decode
dispatch + host sync per token.  It stays as the differential oracle for the
parity suite (tests/test_serving_parity.py): same requests, same seeds =>
identical token streams.

Cross-path determinism is anchored in counter-based sampling keys: the key
for request ``r``'s ``j``-th token is ``fold_in(fold_in(base, seed_r), j)``
-- a pure function of (engine seed, request seed, token index), independent
of batch composition, admission order, or which engine runs it.  Batch rows
never mix inside the model (attention/recurrence are row-local), so a
request's stream depends only on its own prompt + seed; that is what makes
continuous-vs-padded parity exact and staggered admission safe.

Sampling: ``temperature > 0`` with ``top_k``/``top_p`` set filters each
step's logits through ``top_k(..., layout=Segmented(offsets=...))`` over
the flat per-request vocab stream (uniform V-sized segments -- the batched
layout in segment clothing; a future ragged/per-request vocab mask is a
descriptor change, not a new code path) plus a ``scan(..., layout=
Batched())`` nucleus cutoff over the (B, k) candidate grid.  These run
*inside* the while-loop body -- the whole decode hot path, sampler
included, lives in the compiled layer.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched, Flat, Segmented
from repro.models import lm
from repro.serving import cache as CA
from repro.serving.scheduler import Scheduler
from repro.training import train_step as TS


@dataclasses.dataclass
class Request:
    prompt: list          # token ids
    max_new_tokens: int = 16
    eos_id: int = -1      # -1: never stops early
    # Per-request sampling seed; None = the engine assigns the submission
    # index.  The j-th sampled token uses fold_in(fold_in(base, seed), j),
    # so a request's stream is reproducible under any batching/scheduling.
    seed: int | None = None


# ---------------------------------------------------------------------------
# Sampling (shared by both paths; all batched, no per-request host loops)
# ---------------------------------------------------------------------------


def request_step_keys(base_key, seeds, steps):
    """(B,) per-row keys: fold_in(fold_in(base, seed_b), step_b)."""
    def fold(s, t):
        return jax.random.fold_in(jax.random.fold_in(base_key, s), t)

    return jax.vmap(fold)(seeds.astype(jnp.uint32), steps.astype(jnp.uint32))


def chosen_logprobs(logits, tok):
    """log p of each batch row's sampled token under this step's logits."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]


def sample_tokens(base_key, logits, seeds, steps, *, temperature, top_k,
                  top_p, top_p_candidates):
    """Sample one token per batch row.  Returns (B,) int32.

    Greedy when ``temperature <= 0``; otherwise per-row Gumbel-argmax with
    counter-based keys (see module docstring), filtered through the
    segmented top-k / batched nucleus-cutoff primitives when configured.

    **Nucleus semantics**: the top-p cutoff is measured on the softmax
    *renormalized over the k retained candidates* (``top_k``, or
    ``top_p_candidates`` when only top-p is set), not on the full-vocab
    distribution.  Consequences this module pins with conformance tests,
    so alternative logits paths (e.g. quantized decode) cannot silently
    change them: (a) the first (highest) candidate always survives -- its
    exclusive prefix mass is 0 < top_p; (b) when the candidates' full-vocab
    mass is below ``top_p`` the renormalized masses still sum to 1, so the
    cutoff binds at the same prefix as if the tail mass were redistributed
    -- in particular every candidate survives iff the renormalized
    exclusive prefix stays below ``top_p``, regardless of how little
    full-vocab mass the k candidates carry.
    """
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    keys = request_step_keys(base_key, seeds, steps)
    B, V = logits.shape
    if top_k or top_p < 1.0:
        k = min(top_k if top_k else top_p_candidates, V)
        flat = logits.astype(jnp.float32).reshape(-1)
        offsets = jnp.arange(B + 1, dtype=jnp.int32) * V
        vals, idx = forge.top_k(flat, k, layout=Segmented(offsets=offsets))
        scaled = vals / temperature                   # (B, k) descending
        # Keep the shortest prefix whose mass reaches top_p (the first
        # candidate always survives: its exclusive prefix mass is 0).  The
        # (B, k) candidate grid is exactly the batched-scan layout: one
        # launch scans every request's row, whatever the batch size.
        probs = jax.nn.softmax(scaled, axis=-1)
        cum = forge.scan(alg.ADD, probs, inclusive=False, layout=Batched())
        filtered = jnp.where(cum < top_p, scaled, -jnp.inf)
        g = jax.vmap(lambda kk: jax.random.gumbel(kk, (k,), jnp.float32))(keys)
        choice = jnp.argmax(filtered + g, axis=-1)
        return jnp.take_along_axis(
            idx, choice[:, None], axis=1)[:, 0].astype(jnp.int32)
    g = jax.vmap(lambda kk: jax.random.gumbel(kk, (V,), jnp.float32))(keys)
    return jnp.argmax(logits.astype(jnp.float32) / temperature + g,
                      axis=-1).astype(jnp.int32)


def _has_global_attn(cfg) -> bool:
    kinds = tuple(cfg.prefix) + tuple(cfg.unit) + tuple(cfg.suffix)
    return any(k not in ("attn_local", "rglru", "mlstm", "slstm")
               for k in kinds)


class Engine:
    def __init__(self, cfg, mesh, params, *, cache_len: int, batch_size: int,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
                 top_p_candidates: int = 64, seed: int = 0,
                 max_new_cap: int | None = None, poison_on_evict: bool = False,
                 quantize_kv: str | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.params = params
        self.cache_len = cache_len
        self.batch_size = batch_size
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.top_p_candidates = top_p_candidates
        self.max_new_cap = max_new_cap or cache_len
        self.poison_on_evict = poison_on_evict
        if quantize_kv == "fp8":              # spelling alias: default format
            quantize_kv = "fp8_e4m3"
        if quantize_kv is not None and quantize_kv not in alg.QUANT_MODES:
            raise ValueError(
                f"quantize_kv={quantize_kv!r} not in {alg.QUANT_MODES}")
        self.quantize_kv = quantize_kv
        self._base_key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            TS.make_prefill_step(cfg, mesh, cache_len) if mesh is not None
            else functools.partial(self._plain_prefill, cache_len=cache_len))
        self._decode = jax.jit(
            TS.make_decode_step(cfg, mesh) if mesh is not None
            else lambda p, c, t, pos: lm.decode_step(p, cfg, c, t, pos))
        self._sample = functools.partial(
            sample_tokens, temperature=self.temperature, top_k=self.top_k,
            top_p=self.top_p, top_p_candidates=self.top_p_candidates)
        self._admit_fn = jax.jit(self._admit_impl)
        self._loop_fn = {
            stop_on_free: jax.jit(functools.partial(
                self._loop_impl, stop_on_free=stop_on_free))
            for stop_on_free in (False, True)}
        self.last_stats: dict = {}
        self.last_scores = np.zeros((0,), np.float32)

    def _plain_prefill(self, params, batch, *, cache_len):
        kwargs = {}
        if self.cfg.is_encdec:
            kwargs["src_embeds"] = batch["src_embeds"]
        if self.cfg.num_prefix_embeds:
            kwargs["vision_embeds"] = batch["vision_embeds"]
        return lm.prefill(params, self.cfg, batch["tokens"],
                          cache_len=cache_len, **kwargs)

    def _make_batch(self, toks: np.ndarray) -> dict:
        cfg = self.cfg
        B, plen = toks.shape
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.is_encdec:
            batch["src_embeds"] = jnp.zeros((B, plen, cfg.d_model), jnp.float32)
        if cfg.num_prefix_embeds:
            batch["vision_embeds"] = jnp.zeros(
                (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
        return batch

    # -----------------------------------------------------------------------
    # Continuous-batching path
    # -----------------------------------------------------------------------

    def _fresh_state(self) -> dict:
        """Device-resident engine state: caches + per-slot control arrays.

        The cache tree is shaped/dtyped via ``eval_shape`` of the prefill
        (batched to ``batch_size``) so slot scatters are always exact-dtype
        -- mixed-precision caches (f32 recurrent states riding bf16 KV) get
        no silent casts.
        """
        B, T = self.batch_size, self.max_new_cap
        _, cache_shape = jax.eval_shape(
            self._prefill, self.params,
            self._make_batch(np.zeros((B, 1), np.int32)))
        if self.quantize_kv is not None:
            # Shape-level transform: the resident tree holds KVQuant
            # (values, scales) nodes for every attention KV leaf.
            cache_shape = jax.eval_shape(
                functools.partial(CA.quantize_kv_tree, mode=self.quantize_kv),
                cache_shape)
        return {
            "caches": jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), cache_shape),
            "tok": jnp.zeros((B,), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "emitted": jnp.zeros((B,), jnp.int32),
            "active": jnp.zeros((B,), bool),
            "out": jnp.zeros((B, T), jnp.int32),
            "logps": jnp.zeros((B, T), jnp.float32),
            "seeds": jnp.zeros((B,), jnp.int32),
            "max_new": jnp.zeros((B,), jnp.int32),
            "eos": jnp.full((B,), -1, jnp.int32),
        }

    def _admit_impl(self, state, caches1, logits1, slot, seed, max_new, eos,
                    pos0):
        """Scatter a prefilled request into ``slot`` + sample its first token
        -- all on device; the token never visits the host."""
        T = self.max_new_cap
        tok1 = self._sample(self._base_key, logits1, seed[None],
                            jnp.zeros((1,), jnp.int32))[0]
        lp1 = chosen_logprobs(logits1, tok1[None])[0]
        st = dict(state)
        if self.quantize_kv is not None:
            caches1 = CA.quantize_kv_tree(caches1, mode=self.quantize_kv)
        st["caches"] = CA.scatter_slot(state["caches"], caches1, slot)
        st["tok"] = state["tok"].at[slot].set(tok1)
        st["pos"] = state["pos"].at[slot].set(pos0)
        st["emitted"] = state["emitted"].at[slot].set(1)
        st["active"] = state["active"].at[slot].set(
            (tok1 != eos) & (max_new > 1))
        st["out"] = state["out"].at[slot].set(
            jnp.zeros((T,), jnp.int32).at[0].set(tok1))
        st["logps"] = state["logps"].at[slot].set(
            jnp.zeros((T,), jnp.float32).at[0].set(lp1))
        st["seeds"] = state["seeds"].at[slot].set(seed)
        st["max_new"] = state["max_new"].at[slot].set(max_new)
        st["eos"] = state["eos"].at[slot].set(eos)
        return st

    def _loop_impl(self, params, state, budget, *, stop_on_free):
        """The device-resident decode loop: ONE ``lax.while_loop`` dispatch.

        Runs until every live slot is done (EOS or length cap), or until
        ``budget`` steps have executed (the scheduler bounds a dispatch at
        the next arrival event), or -- with ``stop_on_free`` (waiters are
        queued) -- as soon as any slot frees.  Returns (state, steps_run).
        """
        B = self.batch_size
        active0 = state["active"]
        bidx = jnp.arange(B)

        def cond(carry):
            st, t = carry
            # All-done predicate as a commutative mapreduce over the active
            # flags -- the loop predicate itself runs on the primitive layer.
            any_active = forge.mapreduce(
                lambda a: a, alg.MAX, st["active"].astype(jnp.int32),
                layout=Flat()) > 0
            go = any_active & (t < budget)
            if stop_on_free:
                go &= jnp.all(~active0 | st["active"])
            return go

        def body(carry):
            st, t = carry
            was_active = st["active"]
            logits, caches = self._decode(
                params, st["caches"], st["tok"][:, None], st["pos"])
            nxt = self._sample(self._base_key, logits, st["seeds"],
                               st["emitted"])
            lp = chosen_logprobs(logits, nxt)
            widx = jnp.minimum(st["emitted"], self.max_new_cap - 1)
            out = st["out"].at[bidx, widx].set(
                jnp.where(was_active, nxt, st["out"][bidx, widx]))
            logps = st["logps"].at[bidx, widx].set(
                jnp.where(was_active, lp, st["logps"][bidx, widx]))
            emitted = st["emitted"] + was_active
            hit_eos = was_active & (nxt == st["eos"])
            hit_cap = emitted >= st["max_new"]
            new = dict(st)
            new["caches"] = caches
            new["tok"] = jnp.where(was_active, nxt, st["tok"])
            new["pos"] = st["pos"] + was_active
            new["emitted"] = emitted
            new["active"] = was_active & ~hit_eos & ~hit_cap
            new["out"] = out
            new["logps"] = logps
            return new, t + 1

        state, steps = jax.lax.while_loop(
            cond, body, (state, jnp.zeros((), jnp.int32)))
        return state, steps

    def _dispatch_loop(self, state, budget, stop_on_free):
        """One device-loop dispatch (separate method so tests can wrap it in
        a transfer guard: nothing here may sync tokens to host)."""
        return self._loop_fn[stop_on_free](
            self.params, state, jnp.asarray(budget, jnp.int32))

    def _seq_logprobs(self, state):
        """Per-slot sequence scores over the ragged (slots, steps) buffer:
        one masked ``mapreduce(layout=Batched())`` launch, identity at
        masked steps -- identical code path at any live-slot count."""
        T = self.max_new_cap
        mask = (jnp.arange(T, dtype=jnp.int32)[None, :]
                < state["emitted"][:, None]).astype(jnp.int32)
        return forge.mapreduce(
            lambda t: jnp.where(t[1] != 0, t[0], 0.0), alg.ADD,
            (state["logps"], mask), layout=Batched())

    def _validate_request(self, r: Request):
        plen = len(r.prompt) + self.cfg.num_prefix_embeds
        if plen > self.cache_len:
            raise ValueError(
                f"prompt ({plen} tokens incl. prefix) exceeds cache_len="
                f"{self.cache_len}")
        if r.max_new_tokens > self.max_new_cap:
            raise ValueError(
                f"max_new_tokens={r.max_new_tokens} exceeds the engine's "
                f"output buffer cap {self.max_new_cap} (raise max_new_cap)")
        if _has_global_attn(self.cfg) and \
                plen + r.max_new_tokens > self.cache_len:
            raise ValueError(
                f"prompt+max_new ({plen}+{r.max_new_tokens}) exceeds "
                f"cache_len={self.cache_len} for a global-attention arch "
                f"(the KV ring would overwrite live context)")

    def serve(self, arrivals) -> list:
        """Run an open-loop arrival trace to completion.

        ``arrivals``: iterable of ``(arrival_step, Request)`` (or bare
        ``Request``s, all arriving at step 0); the step clock is the decode-
        step clock -- arrivals between device dispatches are admitted into
        whatever slots have freed.  Returns the scheduler's completed
        ``RequestState`` records in submission order (tokens, seq_logprob,
        submit/admit/finish steps).
        """
        if self.cfg.is_encdec:
            raise NotImplementedError(
                "continuous batching for enc-dec archs: cross-attention "
                "caches are source-length-shaped, which breaks uniform slot "
                "scatter -- use generate_padded()")
        pending = []
        for a in arrivals:
            step, req = a if isinstance(a, tuple) else (0, a)
            self._validate_request(req)
            pending.append((int(step), req))
        pending.sort(key=lambda a: a[0])
        pending = list(reversed(pending))   # pop() = earliest

        sched = Scheduler(self.batch_size)
        state = self._fresh_state()
        now = 0
        stats = {"loop_dispatches": 0, "decode_steps": 0, "prefill_s": 0.0,
                 "decode_s": 0.0, "admissions": 0}
        t_serve = time.time()

        def submit_due():
            while pending and pending[-1][0] <= now:
                step, req = pending.pop()
                sched.submit(req, step=max(step, now))

        submit_due()
        while not (sched.all_done and not pending):
            # -- admission: prefill each new request alone, scatter its cache
            for rec in sched.admit(step=now):
                r = rec.request
                if r.max_new_tokens < 1:
                    sched.complete(rec.slot, step=now)
                    continue
                t0 = time.time()
                toks = np.asarray(r.prompt, np.int32)[None, :]
                logits1, caches1 = self._prefill(
                    self.params, self._make_batch(toks))
                pos0 = toks.shape[1] + self.cfg.num_prefix_embeds
                state = self._admit_fn(
                    state, caches1, logits1,
                    jnp.asarray(rec.slot, jnp.int32),
                    jnp.asarray(rec.seed, jnp.int32),
                    jnp.asarray(r.max_new_tokens, jnp.int32),
                    jnp.asarray(r.eos_id, jnp.int32),
                    jnp.asarray(pos0, jnp.int32))
                stats["prefill_s"] += time.time() - t0
                stats["admissions"] += 1

            live = sched.live_slots
            if not live:
                if pending:
                    now = max(now, pending[-1][0])
                    submit_due()
                    continue
                break
            # An admitted request may be done already (EOS/cap on its first
            # token); drain before dispatching an empty loop.
            self._drain_done(sched, state, now)
            if not sched.live_slots:
                submit_due()
                continue

            # -- one device-loop dispatch: run until all-done, bounded by the
            # next arrival event; break out early on a freed slot only when
            # someone is waiting for it.
            budget = int(np.max(np.asarray(
                state["max_new"] - state["emitted"]))) + 1
            if pending:
                budget = max(1, min(budget, pending[-1][0] - now))
            stop_on_free = sched.has_waiting or bool(pending)
            t0 = time.time()
            state, steps = self._dispatch_loop(state, budget, stop_on_free)
            steps = int(steps)                     # control-plane sync only
            stats["decode_s"] += time.time() - t0
            stats["loop_dispatches"] += 1
            stats["decode_steps"] += steps
            now += steps
            submit_due()
            state = self._drain_done(sched, state, now)

        recs = [sched.records[rid] for rid in sorted(sched.records)]
        stats["serve_s"] = time.time() - t_serve
        n_tok = sum(len(rec.tokens) for rec in recs)
        stats["decode_tok_per_s"] = n_tok / max(stats["decode_s"], 1e-9)
        stats["seq_logprob"] = [rec.seq_logprob for rec in recs]
        stats["total_tokens"] = n_tok
        stats["final_step"] = now
        self.last_stats = stats
        self.last_scores = np.asarray(
            [rec.seq_logprob for rec in recs], np.float32)
        return recs

    def _drain_done(self, sched: Scheduler, state, now):
        """Evict finished slots: pull their ragged outputs (the only token
        sync -- at completion) through the CSR compaction descriptor."""
        done_slots = [s for s in sched.live_slots
                      if not bool(state["active"][s])]
        if not done_slots:
            return state
        seq_lp = self._seq_logprobs(state)
        flat, offsets = CA.compact_ragged(state["out"], state["emitted"])
        flat = np.asarray(flat)
        offsets = np.asarray(offsets)
        for slot in done_slots:
            rec = sched.complete(slot, step=now)
            rec.tokens = [int(t) for t in flat[offsets[slot]:offsets[slot + 1]]]
            rec.seq_logprob = float(seq_lp[slot])
            if self.poison_on_evict:
                state = dict(state)
                state["caches"] = CA.poison_slot(
                    state["caches"], jnp.asarray(slot, jnp.int32))
        return state

    def generate(self, requests: list) -> list:
        """Run requests to completion (continuous batching); token lists in
        input order.  More requests than ``batch_size`` simply queue."""
        if self.cfg.is_encdec:
            return self.generate_padded(requests)
        recs = self.serve([(0, r) for r in requests])
        return [rec.tokens for rec in recs]

    # -----------------------------------------------------------------------
    # Padded-batch reference path (the parity oracle)
    # -----------------------------------------------------------------------

    def generate_padded(self, requests: list) -> list:
        """Fixed-batch reference: pad to ``batch_size``, left-align prompts,
        one decode dispatch + host sync per token.  Kept as the differential
        oracle; same seeds => bit-identical tokens vs the continuous path."""
        cfg = self.cfg
        B = self.batch_size
        n_req = len(requests)
        assert n_req <= B
        seeds = np.arange(B, dtype=np.int32)
        for i, r in enumerate(requests):
            if r.seed is not None:
                seeds[i] = r.seed
        seeds = jnp.asarray(seeds)
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = self._make_batch(toks)

        t0 = time.time()
        logits, caches = self._prefill(self.params, batch)
        prefill_s = time.time() - t0

        max_new = max(r.max_new_tokens for r in requests)
        outputs = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        tok = self._sample(self._base_key, logits, seeds,
                           jnp.zeros((B,), jnp.int32))
        tok_h = np.asarray(tok).astype(np.int32)
        step_logps = [chosen_logprobs(logits, tok)]  # stays on device
        pos0 = plen + cfg.num_prefix_embeds
        t1 = time.time()
        for i, r in enumerate(requests):
            # First sampled token: subject to the same cap/EOS bookkeeping as
            # every later token (a 0-budget request emits nothing, and EOS as
            # the first token finishes the request).
            if r.max_new_tokens >= 1:
                outputs[i].append(int(tok_h[i]))
            if len(outputs[i]) >= r.max_new_tokens or \
                    (outputs[i] and outputs[i][-1] == r.eos_id):
                done[i] = True
        for t in range(1, max_new):
            if done[:n_req].all():
                break
            logits, caches = self._decode(
                self.params, caches, jnp.asarray(tok_h[:, None]),
                jnp.asarray(pos0 + t - 1, jnp.int32))
            tok = self._sample(self._base_key, logits, seeds,
                               jnp.full((B,), t, jnp.int32))
            tok_h = np.asarray(tok).astype(np.int32)
            step_logps.append(chosen_logprobs(logits, tok))
            for i, r in enumerate(requests):
                if not done[i] and len(outputs[i]) < r.max_new_tokens:
                    outputs[i].append(int(tok_h[i]))
                    if outputs[i][-1] == r.eos_id or \
                            len(outputs[i]) >= r.max_new_tokens:
                        done[i] = True
        decode_s = time.time() - t1
        n_tok = sum(len(o) for o in outputs[:n_req])

        # Sequence scores over the ragged batch: one batched-mapreduce row
        # per request, masked to its realized length -- a single launch over
        # (n_req, steps) with no per-request host loop or flatten, and the
        # identical code path whether n_req is 1 or the full batch.
        lengths = jnp.asarray([len(o) for o in outputs[:n_req]], jnp.int32)
        lp = jnp.stack(step_logps, axis=1)[:n_req]      # (n_req, steps)
        steps = lp.shape[1]
        mask = (jnp.arange(steps, dtype=jnp.int32)[None, :]
                < lengths[:, None]).astype(jnp.int32)
        seq_logprob = forge.mapreduce(
            lambda t: jnp.where(t[1] != 0, t[0], 0.0), alg.ADD,
            (lp.astype(jnp.float32), mask), layout=Batched())
        self.last_scores = np.asarray(seq_logprob)

        self.last_stats = {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "decode_tok_per_s": n_tok / max(decode_s, 1e-9),
            "seq_logprob": self.last_scores.tolist(),
        }
        return outputs[:n_req]
