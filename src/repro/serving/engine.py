"""Serving engine: continuous batching with a device-resident decode loop.

Two execution paths share one model, one sampler and one RNG discipline:

**Continuous (the serving path, ``generate`` / ``serve``)** -- a host-side
FIFO scheduler (serving/scheduler.py) admits requests into live batch
*slots*; each admission prefilles the request alone (at its exact prompt
length, or right-padded to a bucket length with ``prefill_buckets=`` so a
handful of compiled prefill shapes covers every prompt) and scatters the
resulting caches into its slot (serving/cache.py).  Decode then runs **on
device** as one ``lax.while_loop`` whose carry holds the caches, per-slot
positions, sampled tokens, EOS/length state and the output buffers --
between prefill and completion there is *zero* host<->device token traffic:
the all-done predicate is a ``mapreduce`` over the active flags, and every
per-token decision (sampling, EOS masking, length caps, logprob
accumulation) happens inside the loop body.  Slots free as requests hit EOS
/ ``max_new_tokens``; the scheduler recycles them for waiting arrivals
(open-loop traffic), so the batch is continuously full instead of padded to
the slowest request.

**What the loop body does is a pluggable policy**: a
:class:`~repro.serving.strategies.DecodeStrategy` (``Engine(strategy=...)``)
owns the device state layout, the admission scatter, the loop-body step and
the drain rendering -- greedy/top-k/top-p is the trivial default
(``strategies.Vanilla``), and speculative decoding, beam search and
grammar-constrained sampling ride the same while-loop/scheduler machinery
(serving/strategies/).  The engine keeps the policy-free parts: scheduler,
prefill admission, the loop *condition* (any-active / budget /
stop-on-free), the transfer-guard dispatch seam, and stats.

**Padded (the reference oracle, ``generate_padded``)** -- the original
fixed-batch host loop: one prefill over the left-padded batch, one decode
dispatch + host sync per token.  It stays as the differential oracle for the
parity suite (tests/test_serving_parity.py): same requests, same seeds =>
identical token streams.  It is a *vanilla-sampling* oracle and refuses to
run under any other strategy.

Cross-path determinism is anchored in counter-based sampling keys: the key
for request ``r``'s ``j``-th token is ``fold_in(fold_in(base, seed_r), j)``
-- a pure function of (engine seed, request seed, token index), independent
of batch composition, admission order, or which engine runs it.  Batch rows
never mix inside the model (attention/recurrence are row-local), so a
request's stream depends only on its own prompt + seed; that is what makes
continuous-vs-padded parity exact, staggered admission safe, and exact-match
speculative verification bit-identical (strategies/speculative.py).  The
sampler itself lives in serving/sampling.py (re-exported here).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Flat
from repro.models import lm
from repro.serving import cache as CA
from repro.serving import strategies as ST
from repro.serving.sampling import (  # noqa: F401  (re-exported API)
    chosen_logprobs, request_step_keys, sample_tokens)
from repro.serving.scheduler import Scheduler
from repro.training import train_step as TS


@dataclasses.dataclass
class Request:
    prompt: list          # token ids
    max_new_tokens: int = 16
    eos_id: int = -1      # -1: never stops early
    # Per-request sampling seed; None = the engine assigns the submission
    # index.  The j-th sampled token uses fold_in(fold_in(base, seed), j),
    # so a request's stream is reproducible under any batching/scheduling.
    seed: int | None = None


def _has_global_attn(cfg) -> bool:
    kinds = tuple(cfg.prefix) + tuple(cfg.unit) + tuple(cfg.suffix)
    return any(k not in ("attn_local", "rglru", "mlstm", "slstm")
               for k in kinds)


class Engine:
    def __init__(self, cfg, mesh, params, *, cache_len: int, batch_size: int,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
                 top_p_candidates: int = 64, seed: int = 0,
                 max_new_cap: int | None = None, poison_on_evict: bool = False,
                 quantize_kv: str | None = None, strategy=None,
                 prefill_buckets=None):
        self.cfg = cfg
        self.mesh = mesh
        self.params = params
        self.cache_len = cache_len
        self.batch_size = batch_size
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.top_p_candidates = top_p_candidates
        self.max_new_cap = max_new_cap or cache_len
        self.poison_on_evict = poison_on_evict
        if quantize_kv == "fp8":              # spelling alias: default format
            quantize_kv = "fp8_e4m3"
        if quantize_kv is not None and quantize_kv not in alg.QUANT_MODES:
            raise ValueError(
                f"quantize_kv={quantize_kv!r} not in {alg.QUANT_MODES}")
        self.quantize_kv = quantize_kv
        self.strategy = ST.resolve_strategy(strategy)
        if cfg.is_encdec and self.strategy.name != "vanilla":
            raise NotImplementedError(
                f"strategy {self.strategy.name!r} requires the continuous "
                "decode loop; enc-dec archs route through the padded "
                "vanilla oracle only")
        self.prefill_buckets = self._resolve_buckets(prefill_buckets)
        self._base_key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            TS.make_prefill_step(cfg, mesh, cache_len) if mesh is not None
            else functools.partial(self._plain_prefill, cache_len=cache_len))
        self._decode = jax.jit(
            TS.make_decode_step(cfg, mesh) if mesh is not None
            else lambda p, c, t, pos: lm.decode_step(p, cfg, c, t, pos))
        self._sample = functools.partial(
            sample_tokens, temperature=self.temperature, top_k=self.top_k,
            top_p=self.top_p, top_p_candidates=self.top_p_candidates)
        self.strategy.bind(self)
        self._strategy_params = self.strategy.loop_params(self)
        self._admit_fn = jax.jit(self._admit_impl)
        self._loop_fn = {
            stop_on_free: jax.jit(functools.partial(
                self._loop_impl, stop_on_free=stop_on_free))
            for stop_on_free in (False, True)}
        self.last_stats: dict = {}
        self.last_scores = np.zeros((0,), np.float32)

    def _resolve_buckets(self, spec):
        """Normalize ``prefill_buckets`` to a sorted tuple (or None).

        ``"pow2"`` generates powers of two up to the cache budget; an
        explicit sequence is validated against it.  Prompts longer than the
        largest bucket fall back to exact-length prefill.
        """
        limit = self.cache_len - self.cfg.num_prefix_embeds
        if spec is None:
            return None
        if spec == "pow2":
            out, b = [], 8
            while b < limit:
                out.append(b)
                b *= 2
            out.append(limit)
            return tuple(out)
        buckets = sorted({int(b) for b in spec})
        if not buckets or buckets[0] < 1 or buckets[-1] > limit:
            raise ValueError(
                f"prefill_buckets={spec!r} must be nonempty ints in "
                f"[1, {limit}] (cache_len minus prefix embeds)")
        return tuple(buckets)

    def _plain_prefill(self, params, batch, *, cache_len):
        kwargs = {}
        if self.cfg.is_encdec:
            kwargs["src_embeds"] = batch["src_embeds"]
        if self.cfg.num_prefix_embeds:
            kwargs["vision_embeds"] = batch["vision_embeds"]
        if "valid_len" in batch:
            kwargs["valid_len"] = batch["valid_len"]
        return lm.prefill(params, self.cfg, batch["tokens"],
                          cache_len=cache_len, **kwargs)

    def _make_batch(self, toks: np.ndarray, valid_len=None) -> dict:
        cfg = self.cfg
        B, plen = toks.shape
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.is_encdec:
            batch["src_embeds"] = jnp.zeros((B, plen, cfg.d_model), jnp.float32)
        if cfg.num_prefix_embeds:
            batch["vision_embeds"] = jnp.zeros(
                (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
        if valid_len is not None:
            batch["valid_len"] = jnp.asarray(valid_len, jnp.int32)
        return batch

    def _pad_prompt(self, prompt):
        """Right-pad a prompt to its bucket length.  Returns (toks (1, L)
        int32, valid_len | None); None = exact-length (no bucketing, or the
        prompt exceeds the largest bucket)."""
        plen = len(prompt)
        if self.prefill_buckets:
            for b in self.prefill_buckets:
                if b >= plen:
                    toks = np.zeros((1, b), np.int32)
                    toks[0, :plen] = prompt
                    return toks, (plen if b > plen else None)
        return np.asarray(prompt, np.int32)[None, :], None

    # -----------------------------------------------------------------------
    # Continuous-batching path
    # -----------------------------------------------------------------------

    def _cache_zeros(self, batch: int):
        """Zeroed decode-cache tree for ``batch`` slots, shaped/dtyped via
        ``eval_shape`` of the prefill so slot scatters are always exact-dtype
        -- mixed-precision caches (f32 recurrent states riding bf16 KV) get
        no silent casts."""
        _, cache_shape = jax.eval_shape(
            self._prefill, self.params,
            self._make_batch(np.zeros((batch, 1), np.int32)))
        if self.quantize_kv is not None:
            # Shape-level transform: the resident tree holds KVQuant
            # (values, scales) nodes for every attention KV leaf.
            cache_shape = jax.eval_shape(
                functools.partial(CA.quantize_kv_tree, mode=self.quantize_kv),
                cache_shape)
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), cache_shape)

    def _base_state(self, *, cache_batch: int | None = None) -> dict:
        """The standard device-resident state: caches + per-slot control
        arrays.  Strategies with richer state extend (or replace) this."""
        B, T = self.batch_size, self.max_new_cap
        return {
            "caches": self._cache_zeros(cache_batch or B),
            "tok": jnp.zeros((B,), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "emitted": jnp.zeros((B,), jnp.int32),
            "active": jnp.zeros((B,), bool),
            "out": jnp.zeros((B, T), jnp.int32),
            "logps": jnp.zeros((B, T), jnp.float32),
            "seeds": jnp.zeros((B,), jnp.int32),
            "max_new": jnp.zeros((B,), jnp.int32),
            "eos": jnp.full((B,), -1, jnp.int32),
        }

    def _fresh_state(self) -> dict:
        return self.strategy.init_state(self)

    def _admit_impl(self, state, caches1, logits1, extras, slot, seed,
                    max_new, eos, pos0):
        """Admission, delegated to the strategy -- all on device; the first
        token never visits the host."""
        if self.quantize_kv is not None:
            caches1 = CA.quantize_kv_tree(caches1, mode=self.quantize_kv)
        return self.strategy.admit(
            self, state, caches1, logits1, extras, slot=slot, seed=seed,
            max_new=max_new, eos=eos, pos0=pos0)

    def _loop_impl(self, params, sparams, state, budget, *, stop_on_free):
        """The device-resident decode loop: ONE ``lax.while_loop`` dispatch.

        Runs until every live slot is done (EOS or length cap), or until
        ``budget`` steps have executed (the scheduler bounds a dispatch at
        the next arrival event), or -- with ``stop_on_free`` (waiters are
        queued) -- as soon as any slot frees.  The body is the strategy's
        ``step``; the condition stays policy-free.  Returns (state,
        steps_run).
        """
        active0 = state["active"]

        def cond(carry):
            st, t = carry
            # All-done predicate as a commutative mapreduce over the active
            # flags -- the loop predicate itself runs on the primitive layer.
            any_active = forge.mapreduce(
                lambda a: a, alg.MAX, st["active"].astype(jnp.int32),
                layout=Flat()) > 0
            go = any_active & (t < budget)
            if stop_on_free:
                go &= jnp.all(~active0 | st["active"])
            return go

        def body(carry):
            st, t = carry
            return self.strategy.step(self, params, sparams, st), t + 1

        state, steps = jax.lax.while_loop(
            cond, body, (state, jnp.zeros((), jnp.int32)))
        return state, steps

    def _dispatch_loop(self, state, budget, stop_on_free):
        """One device-loop dispatch (separate method so tests can wrap it in
        a transfer guard: nothing here may sync tokens to host)."""
        return self._loop_fn[stop_on_free](
            self.params, self._strategy_params, state,
            jnp.asarray(budget, jnp.int32))

    def _validate_request(self, r: Request):
        plen = len(r.prompt) + self.cfg.num_prefix_embeds
        if plen > self.cache_len:
            raise ValueError(
                f"prompt ({plen} tokens incl. prefix) exceeds cache_len="
                f"{self.cache_len}")
        if r.max_new_tokens > self.max_new_cap:
            raise ValueError(
                f"max_new_tokens={r.max_new_tokens} exceeds the engine's "
                f"output buffer cap {self.max_new_cap} (raise max_new_cap)")
        if _has_global_attn(self.cfg) and \
                plen + r.max_new_tokens > self.cache_len:
            raise ValueError(
                f"prompt+max_new ({plen}+{r.max_new_tokens}) exceeds "
                f"cache_len={self.cache_len} for a global-attention arch "
                f"(the KV ring would overwrite live context)")

    def serve(self, arrivals) -> list:
        """Run an open-loop arrival trace to completion.

        ``arrivals``: iterable of ``(arrival_step, Request)`` (or bare
        ``Request``s, all arriving at step 0); the step clock is the decode-
        step clock (one step = one loop iteration; a speculative iteration
        may emit several tokens) -- arrivals between device dispatches are
        admitted into whatever slots have freed.  Returns the scheduler's
        completed ``RequestState`` records in submission order (tokens,
        seq_logprob, submit/admit/finish steps).
        """
        if self.cfg.is_encdec:
            raise NotImplementedError(
                "continuous batching for enc-dec archs: cross-attention "
                "caches are source-length-shaped, which breaks uniform slot "
                "scatter -- use generate_padded()")
        pending = []
        for a in arrivals:
            step, req = a if isinstance(a, tuple) else (0, a)
            self._validate_request(req)
            pending.append((int(step), req))
        pending.sort(key=lambda a: a[0])
        pending = list(reversed(pending))   # pop() = earliest

        sched = Scheduler(self.batch_size)
        state = self._fresh_state()
        now = 0
        stats = {"loop_dispatches": 0, "decode_steps": 0, "prefill_s": 0.0,
                 "decode_s": 0.0, "admissions": 0}
        t_serve = time.time()

        def submit_due():
            while pending and pending[-1][0] <= now:
                step, req = pending.pop()
                sched.submit(req, step=max(step, now))

        submit_due()
        while not (sched.all_done and not pending):
            # -- admission: prefill each new request alone, scatter its cache
            for rec in sched.admit(step=now):
                r = rec.request
                if r.max_new_tokens < 1:
                    sched.complete(rec.slot, step=now)
                    continue
                t0 = time.time()
                toks, vlen = self._pad_prompt(r.prompt)
                logits1, caches1 = self._prefill(
                    self.params, self._make_batch(toks, valid_len=vlen))
                extras = self.strategy.host_prefill(self, toks, vlen)
                pos0 = len(r.prompt) + self.cfg.num_prefix_embeds
                state = self._admit_fn(
                    state, caches1, logits1, extras,
                    jnp.asarray(rec.slot, jnp.int32),
                    jnp.asarray(rec.seed, jnp.int32),
                    jnp.asarray(r.max_new_tokens, jnp.int32),
                    jnp.asarray(r.eos_id, jnp.int32),
                    jnp.asarray(pos0, jnp.int32))
                stats["prefill_s"] += time.time() - t0
                stats["admissions"] += 1

            live = sched.live_slots
            if not live:
                if pending:
                    now = max(now, pending[-1][0])
                    submit_due()
                    continue
                break
            # An admitted request may be done already (EOS/cap on its first
            # token); drain before dispatching an empty loop.
            self._drain_done(sched, state, now)
            if not sched.live_slots:
                submit_due()
                continue

            # -- one device-loop dispatch: run until all-done, bounded by the
            # next arrival event; break out early on a freed slot only when
            # someone is waiting for it.
            budget = int(np.max(np.asarray(
                state["max_new"] - state["emitted"]))) + 1
            if pending:
                budget = max(1, min(budget, pending[-1][0] - now))
            stop_on_free = sched.has_waiting or bool(pending)
            t0 = time.time()
            state, steps = self._dispatch_loop(state, budget, stop_on_free)
            steps = int(steps)                     # control-plane sync only
            stats["decode_s"] += time.time() - t0
            stats["loop_dispatches"] += 1
            stats["decode_steps"] += steps
            now += steps
            submit_due()
            state = self._drain_done(sched, state, now)

        recs = [sched.records[rid] for rid in sorted(sched.records)]
        stats["serve_s"] = time.time() - t_serve
        n_tok = sum(len(rec.tokens) for rec in recs)
        stats["decode_tok_per_s"] = n_tok / max(stats["decode_s"], 1e-9)
        stats["seq_logprob"] = [rec.seq_logprob for rec in recs]
        stats["total_tokens"] = n_tok
        stats["final_step"] = now
        stats.update(self.strategy.stats(self, state))
        self.last_stats = stats
        self.last_scores = np.asarray(
            [rec.seq_logprob for rec in recs], np.float32)
        return recs

    def _drain_done(self, sched: Scheduler, state, now):
        """Evict finished slots: pull their ragged outputs (the only token
        sync -- at completion) through the CSR compaction descriptor."""
        done_slots = [s for s in sched.live_slots
                      if not bool(state["active"][s])]
        if not done_slots:
            return state
        outs = self.strategy.outputs(self, state)
        seq_lp = outs["seq_logprob"]
        flat, offsets = CA.compact_ragged(outs["out"], outs["emitted"])
        flat = np.asarray(flat)
        offsets = np.asarray(offsets)
        meta = outs.get("meta", {})
        for slot in done_slots:
            rec = sched.complete(slot, step=now)
            rec.tokens = [int(t) for t in flat[offsets[slot]:offsets[slot + 1]]]
            rec.seq_logprob = float(seq_lp[slot])
            for key, per_slot in meta.items():
                rec.meta[key] = np.asarray(per_slot[slot]).item()
            if self.poison_on_evict:
                state = dict(state)
                state["caches"] = self.strategy.poison(
                    self, state["caches"], jnp.asarray(slot, jnp.int32))
        return state

    def generate(self, requests: list) -> list:
        """Run requests to completion (continuous batching); token lists in
        input order.  More requests than ``batch_size`` simply queue."""
        if self.cfg.is_encdec:
            return self.generate_padded(requests)
        recs = self.serve([(0, r) for r in requests])
        return [rec.tokens for rec in recs]

    # -----------------------------------------------------------------------
    # Padded-batch reference path (the vanilla parity oracle)
    # -----------------------------------------------------------------------

    def generate_padded(self, requests: list) -> list:
        """Fixed-batch reference: pad to ``batch_size``, left-align prompts,
        one decode dispatch + host sync per token.  Kept as the differential
        oracle for *vanilla sampling*; same seeds => bit-identical tokens vs
        the continuous path.  Non-vanilla strategies have their own
        reference decoders (strategies/ref.py) and refuse this path."""
        if self.strategy.name != "vanilla":
            raise NotImplementedError(
                "generate_padded is the vanilla-sampling parity oracle; "
                f"strategy {self.strategy.name!r} has its own reference "
                "decoder in serving/strategies/ref.py")
        cfg = self.cfg
        B = self.batch_size
        n_req = len(requests)
        assert n_req <= B
        seeds = np.arange(B, dtype=np.int32)
        for i, r in enumerate(requests):
            if r.seed is not None:
                seeds[i] = r.seed
        seeds = jnp.asarray(seeds)
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = self._make_batch(toks)

        t0 = time.time()
        logits, caches = self._prefill(self.params, batch)
        prefill_s = time.time() - t0

        max_new = max(r.max_new_tokens for r in requests)
        outputs = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        tok = self._sample(self._base_key, logits, seeds,
                           jnp.zeros((B,), jnp.int32))
        tok_h = np.asarray(tok).astype(np.int32)
        step_logps = [chosen_logprobs(logits, tok)]  # stays on device
        pos0 = plen + cfg.num_prefix_embeds
        t1 = time.time()
        for i, r in enumerate(requests):
            # First sampled token: subject to the same cap/EOS bookkeeping as
            # every later token (a 0-budget request emits nothing, and EOS as
            # the first token finishes the request).
            if r.max_new_tokens >= 1:
                outputs[i].append(int(tok_h[i]))
            if len(outputs[i]) >= r.max_new_tokens or \
                    (outputs[i] and outputs[i][-1] == r.eos_id):
                done[i] = True
        for t in range(1, max_new):
            if done[:n_req].all():
                break
            logits, caches = self._decode(
                self.params, caches, jnp.asarray(tok_h[:, None]),
                jnp.asarray(pos0 + t - 1, jnp.int32))
            tok = self._sample(self._base_key, logits, seeds,
                               jnp.full((B,), t, jnp.int32))
            tok_h = np.asarray(tok).astype(np.int32)
            step_logps.append(chosen_logprobs(logits, tok))
            for i, r in enumerate(requests):
                if not done[i] and len(outputs[i]) < r.max_new_tokens:
                    outputs[i].append(int(tok_h[i]))
                    if outputs[i][-1] == r.eos_id or \
                            len(outputs[i]) >= r.max_new_tokens:
                        done[i] = True
        decode_s = time.time() - t1
        n_tok = sum(len(o) for o in outputs[:n_req])

        # Sequence scores over the ragged batch: one batched-mapreduce row
        # per request, masked to its realized length -- a single launch over
        # (n_req, steps) with no per-request host loop or flatten, and the
        # identical code path whether n_req is 1 or the full batch.
        lengths = [len(o) for o in outputs[:n_req]]
        lp = jnp.stack(step_logps, axis=1)[:n_req]      # (n_req, steps)
        from repro.serving.sampling import masked_seq_logprobs
        seq_logprob = masked_seq_logprobs(
            lp.astype(jnp.float32), jnp.asarray(lengths, jnp.int32))
        self.last_scores = np.asarray(seq_logprob)

        self.last_stats = {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "decode_tok_per_s": n_tok / max(decode_s, 1e-9),
            "seq_logprob": self.last_scores.tolist(),
        }
        return outputs[:n_req]
