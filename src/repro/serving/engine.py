"""Batched serving engine: prefill + decode with KV / recurrent-state caches.

Requests are padded to a fixed batch and right-aligned to a common prompt
length (static shapes => one compiled prefill + one compiled decode step);
finished sequences are masked out.  For the recurrent/hybrid archs the
"cache" is O(1) state + ring-buffered local-attention windows, which is what
makes the ``long_500k`` serving shape feasible.

The decode hot path is *batch-native*: every per-request quantity is
computed by one grid-batched primitive launch over the whole batch
(kernels/batched.py), never by a ``vmap`` of per-request 1-D calls or a
per-request Python loop.

Per-request sequence scores: the batch is *ragged* -- requests finish at
different lengths -- so the per-step chosen-token log-probs are reduced with
``mapreduce(..., layout=Batched())`` over a (requests, steps) grid with a
per-request length mask (``last_scores`` / ``last_stats["seq_logprob"]``):
one launch, one row per request, masked steps contribute the identity.

Sampling: ``temperature > 0`` with ``top_k``/``top_p`` set filters each
step's logits through ``top_k(..., layout=Segmented(offsets=...))`` over
the flat per-request vocab stream (uniform V-sized segments -- the batched
layout in segment clothing) plus a ``scan(..., layout=Batched())`` nucleus
cutoff over the (B, k) candidate grid -- the serving-side consumers of the
sort family (kernels/sort.py) and the batched family (kernels/batched.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched, Segmented
from repro.models import lm
from repro.training import train_step as TS


@dataclasses.dataclass
class Request:
    prompt: list          # token ids
    max_new_tokens: int = 16
    eos_id: int = -1      # -1: never stops early


class Engine:
    def __init__(self, cfg, mesh, params, *, cache_len: int, batch_size: int,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
                 top_p_candidates: int = 64, seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.params = params
        self.cache_len = cache_len
        self.batch_size = batch_size
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.top_p_candidates = top_p_candidates
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            TS.make_prefill_step(cfg, mesh, cache_len) if mesh is not None
            else functools.partial(self._plain_prefill, cache_len=cache_len))
        self._decode = jax.jit(
            TS.make_decode_step(cfg, mesh) if mesh is not None
            else lambda p, c, t, pos: lm.decode_step(p, cfg, c, t, pos))

    def _plain_prefill(self, params, batch, *, cache_len):
        kwargs = {}
        if self.cfg.is_encdec:
            kwargs["src_embeds"] = batch["src_embeds"]
        if self.cfg.num_prefix_embeds:
            kwargs["vision_embeds"] = batch["vision_embeds"]
        return lm.prefill(params, self.cfg, batch["tokens"],
                          cache_len=cache_len, **kwargs)

    def _sample(self, logits):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        if self.top_k or self.top_p < 1.0:
            return self._topk_topp_sample(sub, logits)
        return jax.random.categorical(sub, logits / self.temperature, axis=-1)

    def _topk_topp_sample(self, key, logits):
        """Top-k / nucleus sampling via the segmented sort primitives.

        The decode batch is treated as one flat stream of per-request vocab
        segments (CSR offsets -- the same descriptors the seq-logprob
        reduction uses, so a future ragged/per-request vocab mask is a
        descriptor change, not a new code path).  ``segmented_top_k`` returns
        each request's k highest logits descending plus their within-segment
        indices, which *are* the vocab ids; the nucleus filter is then an
        exclusive +scan of the candidate probabilities along the k axis.

        With ``top_p`` alone, the nucleus is drawn from the
        ``top_p_candidates`` highest-probability tokens rather than all V
        -- the standard serving approximation that keeps the per-step sort
        bounded (tokens beyond that set carry negligible mass for any
        practical ``top_p``); raise ``top_p_candidates`` to widen it.
        """
        B, V = logits.shape
        k = min(self.top_k if self.top_k else self.top_p_candidates, V)
        flat = logits.astype(jnp.float32).reshape(-1)
        offsets = jnp.arange(B + 1, dtype=jnp.int32) * V
        vals, idx = forge.top_k(flat, k, layout=Segmented(offsets=offsets))
        scaled = vals / self.temperature                   # (B, k) descending
        # Keep the shortest prefix whose mass reaches top_p (the first
        # candidate always survives: its exclusive prefix mass is 0).  The
        # (B, k) candidate grid is exactly the batched-scan layout: one
        # launch scans every request's row, whatever the batch size.
        probs = jax.nn.softmax(scaled, axis=-1)
        cum = forge.scan(alg.ADD, probs, inclusive=False, layout=Batched())
        filtered = jnp.where(cum < self.top_p, scaled, -jnp.inf)
        choice = jax.random.categorical(key, filtered, axis=-1)
        return jnp.take_along_axis(idx, choice[:, None], axis=1)[:, 0]

    @staticmethod
    @jax.jit
    def _chosen_logprobs(logits, tok):
        """log p of each batch row's sampled token under this step's logits."""
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return jnp.take_along_axis(
            logp, jnp.asarray(tok)[:, None], axis=-1)[:, 0]

    def generate(self, requests: list) -> list:
        """Run a batch of requests to completion; returns token lists."""
        cfg = self.cfg
        B = self.batch_size
        assert len(requests) <= B
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.is_encdec:
            batch["src_embeds"] = jnp.zeros(
                (B, plen, cfg.d_model), jnp.float32)
        if cfg.num_prefix_embeds:
            batch["vision_embeds"] = jnp.zeros(
                (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)

        t0 = time.time()
        logits, caches = self._prefill(self.params, batch)
        prefill_s = time.time() - t0

        max_new = max(r.max_new_tokens for r in requests)
        outputs = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        tok = np.asarray(self._sample(logits)).astype(np.int32)
        step_logps = [self._chosen_logprobs(logits, tok)]  # stays on device
        pos0 = plen + cfg.num_prefix_embeds
        t1 = time.time()
        for i, r in enumerate(requests):
            outputs[i].append(int(tok[i]))
        for t in range(1, max_new):
            logits, caches = self._decode(
                self.params, caches, jnp.asarray(tok[:, None]),
                jnp.asarray(pos0 + t - 1, jnp.int32))
            tok = np.asarray(self._sample(logits)).astype(np.int32)
            step_logps.append(self._chosen_logprobs(logits, tok))
            for i, r in enumerate(requests):
                if i < len(requests) and not done[i] and len(outputs[i]) < r.max_new_tokens:
                    outputs[i].append(int(tok[i]))
                    if outputs[i][-1] == r.eos_id:
                        done[i] = True
            if done[:len(requests)].all():
                break
        decode_s = time.time() - t1
        n_req = len(requests)
        n_tok = sum(len(o) for o in outputs[:n_req])

        # Sequence scores over the ragged batch: one batched-mapreduce row
        # per request, masked to its realized length -- a single launch over
        # (n_req, steps) with no per-request host loop or flatten, and the
        # identical code path whether n_req is 1 or the full batch.
        lengths = jnp.asarray([len(o) for o in outputs[:n_req]], jnp.int32)
        lp = jnp.stack(step_logps, axis=1)[:n_req]      # (n_req, steps)
        steps = lp.shape[1]
        mask = (jnp.arange(steps, dtype=jnp.int32)[None, :]
                < lengths[:, None]).astype(jnp.int32)
        seq_logprob = forge.mapreduce(
            lambda t: jnp.where(t[1] != 0, t[0], 0.0), alg.ADD,
            (lp.astype(jnp.float32), mask), layout=Batched())
        self.last_scores = np.asarray(seq_logprob)

        self.last_stats = {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "decode_tok_per_s": n_tok / max(decode_s, 1e-9),
            "seq_logprob": self.last_scores.tolist(),
        }
        return outputs[:n_req]
