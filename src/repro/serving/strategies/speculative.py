"""Draft-and-verify speculative decoding with an exact-match acceptance rule.

One loop-body iteration (a *round*) runs a combined draft+verify
``lax.scan`` of ``k + 1`` steps.  At step ``s`` both models decode the same
input token ``x_s`` (``x_0`` = the slot's current token, ``x_{s+1}`` = the
draft's proposal ``d_s``):

* the **target** samples its authoritative token ``t_s`` with the engine's
  *untagged* counter key at token index ``emitted + s`` -- exactly the key
  vanilla decoding would use for that token, which is what makes the
  accepted stream bit-identical to vanilla at the same seeds (the lossless
  acceptance rule: a draft token is accepted iff it *equals* the target's
  sample, so the emitted stream is the target's own sample path, always);
* the **draft** samples its proposal ``d_s`` from the
  :data:`~repro.serving.sampling.DRAFT_STREAM`-tagged key at the same
  index, so draft randomness never collides with (or perturbs) the verify
  stream and is batch-composition- and depth-independent (satellite S2).

Acceptance is resolved *after* the scan as a batched exclusive ``scan`` over
the per-step match flags: token ``t_i`` is valid iff every earlier step
matched (its context was correct) and no earlier valid token was EOS --
``prefix_ok = (exclusive +scan of failures) == 0``.  Each round therefore
emits between 1 (immediate mismatch: the target's own ``t_0`` is always
correct) and ``k + 1`` tokens per active slot.

**Cache rollback** is per-step select-commit: inside the scan, both models'
caches advance only where the acceptance chain is still alive
(:func:`repro.serving.cache.select_slots` over the slot axis), so a slot
whose chain broke at step ``s`` keeps the cache state of its last valid
token -- no post-hoc rewind of ring-buffer writes is needed, and the scheme
is valid for *every* architecture (including O(1) recurrent states, which
cannot be rewound).  The only over-commit happens on EOS/length-cap paths,
which provably end with the slot inactive; a recycled slot is fully
re-scattered at admission, so the stale suffix is unreachable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched
from repro.models import lm
from repro.serving import cache as CA
from repro.serving import sampling as SP
from repro.serving.strategies.base import DecodeStrategy, vanilla_admit


class Speculative(DecodeStrategy):
    """Draft-and-verify speculative decoding (``k`` proposals per round).

    ``draft_cfg``/``draft_params`` are a (smaller) model sharing the
    target's vocabulary; its caches ride the same slot machinery in a
    parallel tree.  Output streams are bit-identical to ``Vanilla`` at the
    same seeds -- speculation only changes *how many* target-forward
    launches the stream costs, never its tokens.
    """

    name = "speculative"

    def __init__(self, draft_cfg, draft_params, *, k: int = 4):
        if k < 1:
            raise ValueError(f"speculative k must be >= 1, got {k}")
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.k = k

    def bind(self, eng):
        if self.draft_cfg.vocab_size != eng.cfg.vocab_size:
            raise ValueError(
                f"draft vocab_size {self.draft_cfg.vocab_size} != target "
                f"vocab_size {eng.cfg.vocab_size}: draft proposals must be "
                "target token ids")
        if self.draft_cfg.is_encdec:
            raise ValueError("draft model must be decoder-only")
        if self.draft_cfg.num_prefix_embeds or eng.cfg.num_prefix_embeds:
            raise ValueError(
                "speculative decoding requires num_prefix_embeds == 0 on "
                "both models (position bookkeeping is shared)")

        def dft_prefill(params, batch):
            kwargs = {}
            if "valid_len" in batch:
                kwargs["valid_len"] = batch["valid_len"]
            return lm.prefill(params, self.draft_cfg, batch["tokens"],
                              cache_len=eng.cache_len, **kwargs)

        self._dft_prefill = jax.jit(dft_prefill)
        self._dft_decode = functools.partial(
            lambda cfg, p, c, t, pos: lm.decode_step(p, cfg, c, t, pos),
            self.draft_cfg)

    def loop_params(self, eng):
        return self.draft_params

    def host_prefill(self, eng, toks, valid_len):
        batch = {"tokens": jnp.asarray(toks)}
        if valid_len is not None:
            batch["valid_len"] = jnp.asarray(valid_len, jnp.int32)
        _, dft_caches1 = self._dft_prefill(self.draft_params, batch)
        return dft_caches1

    def stats(self, eng, state) -> dict:
        prop = int(state["tot_prop"])
        acc = int(state["tot_acc"])
        return {
            "spec_rounds": int(state["tot_rounds"]),
            "spec_proposed": prop,
            "spec_accepted": acc,
            "spec_acceptance_rate": acc / max(prop, 1),
        }

    def init_state(self, eng) -> dict:
        st = eng._base_state()
        B = eng.batch_size
        _, dft_shape = jax.eval_shape(
            self._dft_prefill, self.draft_params,
            {"tokens": np.zeros((B, 1), np.int32)})
        st["dft_caches"] = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), dft_shape)
        # Per-slot round accounting (reset at admission, drained into the
        # record's meta) + engine-lifetime totals (read once in stats()).
        for key in ("acc", "prop", "rounds"):
            st[key] = jnp.zeros((B,), jnp.int32)
        for key in ("tot_acc", "tot_prop", "tot_rounds"):
            st[key] = jnp.zeros((), jnp.int32)
        return st

    def admit(self, eng, state, caches1, logits1, extras, *, slot, seed,
              max_new, eos, pos0):
        st = vanilla_admit(eng, state, caches1, logits1, slot=slot,
                           seed=seed, max_new=max_new, eos=eos, pos0=pos0)
        st["dft_caches"] = CA.scatter_slot(state["dft_caches"], extras, slot)
        for key in ("acc", "prop", "rounds"):
            st[key] = st[key].at[slot].set(0)
        return st

    def step(self, eng, params, sparams, st):
        B, S, T = eng.batch_size, self.k + 1, eng.max_new_cap
        was_active = st["active"]
        e0 = st["emitted"]
        dkey = SP.stream_key(eng._base_key, SP.DRAFT_STREAM)

        def substep(carry, s):
            tgt_c, dft_c, x, pos, accepting = carry
            logits_t, tgt_c2 = eng._decode(params, tgt_c, x[:, None], pos)
            logits_d, dft_c2 = self._dft_decode(sparams, dft_c, x[:, None],
                                                pos)
            t = eng._sample(eng._base_key, logits_t, st["seeds"], e0 + s)
            lp = SP.chosen_logprobs(logits_t, t)
            d = eng._sample(dkey, logits_d, st["seeds"], e0 + s)
            # Commit the step's cache writes only where the acceptance
            # chain is still alive -- this IS the rollback.
            commit = accepting & was_active
            tgt_c = CA.select_slots(commit, tgt_c2, tgt_c)
            dft_c = CA.select_slots(commit, dft_c2, dft_c)
            return ((tgt_c, dft_c, d, pos + commit, accepting & (t == d)),
                    (t, lp, t == d))

        carry0 = (st["caches"], st["dft_caches"], st["tok"], st["pos"],
                  jnp.ones((B,), bool))
        (tgt_c, dft_c, _, pos2, _), (ts, lps, ms) = jax.lax.scan(
            substep, carry0, jnp.arange(S, dtype=jnp.int32))
        ts, lps, ms = ts.T, lps.T, ms.T                     # (B, S)

        # Validity: t_i is authoritative iff every earlier step matched AND
        # no earlier valid token was EOS -- the batched exclusive scan over
        # acceptance flags.  t_0 is always valid: prefix failures are 0.
        fail = (~(ms & (ts != st["eos"][:, None]))).astype(jnp.int32)
        prefix_ok = forge.scan(alg.ADD, fail, inclusive=False,
                               layout=Batched()) == 0
        idx = jnp.arange(S, dtype=jnp.int32)[None, :]
        rem = (st["max_new"] - e0)[:, None]
        emit = prefix_ok & (idx < rem) & was_active[:, None]
        n_emit = emit.sum(axis=1).astype(jnp.int32)

        # Deterministic ragged append into the (B, T) output buffers: a
        # where-based gather (never a scatter -- duplicate-index scatter
        # conflicts would be nondeterministic at the clipped tail).
        rel = jnp.arange(T, dtype=jnp.int32)[None, :] - e0[:, None]
        take = (rel >= 0) & (rel < n_emit[:, None])
        src = jnp.clip(rel, 0, S - 1)
        out = jnp.where(take, jnp.take_along_axis(ts, src, axis=1),
                        st["out"])
        logps = jnp.where(take, jnp.take_along_axis(lps, src, axis=1),
                          st["logps"])

        emitted = e0 + n_emit
        hit_eos = (emit & (ts == st["eos"][:, None])).any(axis=1)
        hit_cap = emitted >= st["max_new"]
        last = jnp.take_along_axis(
            ts, jnp.clip(n_emit - 1, 0, S - 1)[:, None], axis=1)[:, 0]

        accepted = jnp.where(was_active, n_emit - 1, 0)
        act = was_active.astype(jnp.int32)
        new = dict(st)
        new["caches"] = tgt_c
        new["dft_caches"] = dft_c
        new["tok"] = jnp.where(was_active, last, st["tok"])
        new["pos"] = pos2
        new["emitted"] = emitted
        new["active"] = was_active & ~hit_eos & ~hit_cap
        new["out"] = out
        new["logps"] = logps
        new["acc"] = st["acc"] + accepted
        new["prop"] = st["prop"] + self.k * act
        new["rounds"] = st["rounds"] + act
        new["tot_acc"] = st["tot_acc"] + accepted.sum()
        new["tot_prop"] = st["tot_prop"] + self.k * act.sum()
        new["tot_rounds"] = st["tot_rounds"] + act.sum()
        return new

    def outputs(self, eng, state):
        return {
            "out": state["out"], "emitted": state["emitted"],
            "seq_logprob": SP.masked_seq_logprobs(
                state["logps"], state["emitted"]),
            "meta": {"spec_accepted": state["acc"],
                     "spec_proposed": state["prop"],
                     "spec_rounds": state["rounds"]},
        }
