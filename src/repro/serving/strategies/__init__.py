"""Pluggable decoding strategies for the continuous-batching engine.

The registry mirrors the primitive backend registry's shape: strategies
register under a short name, lookups fail with a uniform ValueError listing
what is available, and ``Engine(strategy=...)`` accepts a name (for
zero-config strategies), a :class:`DecodeStrategy` instance (for strategies
with required arguments -- a draft model, a beam width, a token grammar),
or None for the vanilla default.
"""
from __future__ import annotations

from repro.serving.strategies.base import DecodeStrategy, Vanilla

_STRATEGIES: dict = {}


def register_strategy(cls):
    """Class decorator: register a DecodeStrategy subclass under its
    ``name``."""
    _STRATEGIES[cls.name] = cls
    return cls


def available_strategies():
    return sorted(_STRATEGIES)


def get_strategy(name: str):
    """Look up a registered strategy class by name."""
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r} "
            f"(available: {', '.join(available_strategies())})") from None


def resolve_strategy(spec):
    """Normalize ``Engine(strategy=...)``: None -> Vanilla(), a name ->
    that class constructed with no arguments, an instance -> itself."""
    if spec is None:
        return Vanilla()
    if isinstance(spec, str):
        return get_strategy(spec)()
    if isinstance(spec, DecodeStrategy):
        return spec
    raise TypeError(
        f"strategy must be None, a registered name, or a DecodeStrategy "
        f"instance; got {type(spec).__name__}")


register_strategy(Vanilla)

from repro.serving.strategies.speculative import Speculative  # noqa: E402
from repro.serving.strategies.beam import BeamSearch          # noqa: E402
from repro.serving.strategies.constrained import Constrained  # noqa: E402

register_strategy(Speculative)
register_strategy(BeamSearch)
register_strategy(Constrained)

__all__ = [
    "DecodeStrategy", "Vanilla", "Speculative", "BeamSearch", "Constrained",
    "register_strategy", "available_strategies", "get_strategy",
    "resolve_strategy",
]
