"""The decoding-strategy interface and the trivial default strategy.

A :class:`DecodeStrategy` owns everything about the serving engine's state
that is *decoding-policy* shaped: what extra device state rides the
``lax.while_loop`` carry, what happens at admission, what one loop-body
iteration does (sampling, EOS, logprob bookkeeping), and how the finished
per-slot state renders into ragged token streams at drain.  The engine
(serving/engine.py) keeps everything policy-free: the scheduler, the
prefill admission path, the loop *condition* (any-active / budget /
stop-on-free), the transfer-guard dispatch seam, and stats.

Every hook that runs on device (``admit``, ``step``, ``outputs``) is traced
inside the engine's jitted programs, so strategies must stay functional and
sync-free -- the transfer-guard test in tests/test_serving.py holds for
every strategy, not just the default.

``Vanilla`` is the engine's historical greedy/top-k/top-p behavior moved
behind the interface verbatim: the parity suite (continuous vs padded,
staggered admission, slot recycling) pins that the refactor is
bit-identical.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.serving import cache as CA
from repro.serving import sampling as SP


class DecodeStrategy:
    """Pluggable decoding policy for the continuous-batching engine.

    Subclasses override the hooks below; ``bind`` is called once from
    ``Engine.__init__`` (validate the config, build any extra jits), the
    device hooks are traced into the engine's admit/loop programs.
    """

    name = "?"

    # -- host-side, once per engine -----------------------------------------

    def bind(self, eng) -> None:
        """Validate the engine config and set up strategy-owned resources."""

    def loop_params(self, eng):
        """Extra parameter pytree threaded into the jitted loop/admit
        programs (e.g. the draft model's params).  Must be a pytree of
        device arrays; () when the strategy needs none."""
        return ()

    def host_prefill(self, eng, toks, valid_len):
        """Host-side extra prefill work at admission (e.g. the draft
        model's prefill).  Returns a pytree of device arrays handed to
        ``admit`` as ``extras``."""
        return ()

    def stats(self, eng, state) -> dict:
        """Strategy-specific entries merged into ``engine.last_stats`` at
        the end of ``serve`` (host sync is fine here: serving is done)."""
        return {}

    # -- device-side, traced -------------------------------------------------

    def init_state(self, eng) -> dict:
        """The full device-resident state dict (the while-loop carry).

        Required keys the engine reads: ``active`` (B,) bool, ``emitted``
        and ``max_new`` (B,) int32 (budget bookkeeping), ``caches`` (slot
        eviction poisoning).  Everything else is strategy-owned.
        """
        return eng._base_state()

    def admit(self, eng, state, caches1, logits1, extras, *, slot, seed,
              max_new, eos, pos0) -> dict:
        raise NotImplementedError

    def step(self, eng, params, sparams, st) -> dict:
        """One while-loop body iteration.  Must keep ``active`` honest:
        the engine's loop condition and drain both read it."""
        raise NotImplementedError

    def outputs(self, eng, state) -> dict:
        """Render finished state for drain: ``{"out": (B, T) int32,
        "emitted": (B,) int32, "seq_logprob": (B,) float32}`` plus an
        optional ``"meta"`` dict of per-slot (B,) arrays copied onto each
        completed record's ``meta``."""
        raise NotImplementedError

    def poison(self, eng, caches, slot):
        """Poison a freed slot's cache state (``poison_on_evict``)."""
        return CA.poison_slot(caches, slot)


def vanilla_admit(eng, state, caches1, logits1, *, slot, seed, max_new, eos,
                  pos0):
    """Scatter a prefilled request into ``slot`` + sample its first token
    -- all on device; the token never visits the host.  Shared by every
    strategy that keeps the vanilla one-token-per-slot state layout."""
    T = eng.max_new_cap
    tok1 = eng._sample(eng._base_key, logits1, seed[None],
                       jnp.zeros((1,), jnp.int32))[0]
    lp1 = SP.chosen_logprobs(logits1, tok1[None])[0]
    st = dict(state)
    st["caches"] = CA.scatter_slot(state["caches"], caches1, slot)
    st["tok"] = state["tok"].at[slot].set(tok1)
    st["pos"] = state["pos"].at[slot].set(pos0)
    st["emitted"] = state["emitted"].at[slot].set(1)
    st["active"] = state["active"].at[slot].set(
        (tok1 != eos) & (max_new > 1))
    st["out"] = state["out"].at[slot].set(
        jnp.zeros((T,), jnp.int32).at[0].set(tok1))
    st["logps"] = state["logps"].at[slot].set(
        jnp.zeros((T,), jnp.float32).at[0].set(lp1))
    st["seeds"] = state["seeds"].at[slot].set(seed)
    st["max_new"] = state["max_new"].at[slot].set(max_new)
    st["eos"] = state["eos"].at[slot].set(eos)
    return st


class Vanilla(DecodeStrategy):
    """Greedy / top-k / top-p sampling -- the engine's default policy.

    One target decode + one sampled token per loop iteration, per-slot
    EOS/length-cap masking, logprob accumulation into the (B, T) buffer;
    exactly the pre-strategy engine behavior (the parity suite pins it).
    """

    name = "vanilla"

    def admit(self, eng, state, caches1, logits1, extras, *, slot, seed,
              max_new, eos, pos0):
        return vanilla_admit(eng, state, caches1, logits1, slot=slot,
                             seed=seed, max_new=max_new, eos=eos, pos0=pos0)

    def _adjust_logits(self, eng, st, logits):
        """Hook: transform the step's logits before sampling (identity
        here; constrained sampling masks the disallowed vocabulary)."""
        return logits

    def _post_step(self, eng, st, new, nxt, was_active):
        """Hook: extend the committed state after the vanilla bookkeeping
        (identity here; constrained sampling advances its DFA state)."""
        return new

    def step(self, eng, params, sparams, st):
        bidx = jnp.arange(eng.batch_size)
        was_active = st["active"]
        logits, caches = eng._decode(
            params, st["caches"], st["tok"][:, None], st["pos"])
        logits = self._adjust_logits(eng, st, logits)
        nxt = eng._sample(eng._base_key, logits, st["seeds"], st["emitted"])
        lp = SP.chosen_logprobs(logits, nxt)
        widx = jnp.minimum(st["emitted"], eng.max_new_cap - 1)
        out = st["out"].at[bidx, widx].set(
            jnp.where(was_active, nxt, st["out"][bidx, widx]))
        logps = st["logps"].at[bidx, widx].set(
            jnp.where(was_active, lp, st["logps"][bidx, widx]))
        emitted = st["emitted"] + was_active
        hit_eos = was_active & (nxt == st["eos"])
        hit_cap = emitted >= st["max_new"]
        new = dict(st)
        new["caches"] = caches
        new["tok"] = jnp.where(was_active, nxt, st["tok"])
        new["pos"] = st["pos"] + was_active
        new["emitted"] = emitted
        new["active"] = was_active & ~hit_eos & ~hit_cap
        new["out"] = out
        new["logps"] = logps
        return self._post_step(eng, st, new, nxt, was_active)

    def outputs(self, eng, state):
        return {"out": state["out"], "emitted": state["emitted"],
                "seq_logprob": SP.masked_seq_logprobs(
                    state["logps"], state["emitted"])}
