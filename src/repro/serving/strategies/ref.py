"""Host-side reference decoders for the strategy conformance suite.

These mirror the pattern of ``kernels/ref.py``: slow, obviously-correct
oracles the device strategies are differential-tested against.  Each oracle
drives the *engine's own* prefill/decode programs one hypothesis at a time
(batch-1 caches, a plain Python loop, numpy control flow), so the model
numerics are shared and only the decoding policy differs:

* :func:`reference_beam` -- NMT-style beam search with explicit hypothesis
  lists, mirroring the device tie rules exactly (stable ascending sort read
  backwards => equal scores prefer the higher candidate id; finished beats
  continuing at equal score);
* :func:`reference_constrained` -- DFA-masked sampling with the shared
  counter-key sampler.

**Speculative decoding needs no oracle of its own**: its acceptance rule is
lossless, so the reference for ``strategy=Speculative(...)`` is the vanilla
engine itself -- the differential test asserts bit-identical token streams
at the same seeds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import sampling as SP


def _prefill1(eng, prompt):
    """Batch-1 prefill of ``prompt`` through the engine's own program."""
    toks = np.asarray(prompt, np.int32)[None, :]
    logits, caches = eng._prefill(eng.params, eng._make_batch(toks))
    pos0 = len(prompt) + eng.cfg.num_prefix_embeds
    return np.asarray(logits, np.float32), caches, pos0


def _decode1(eng, caches, tok, pos):
    """One batch-1 decode step; returns (np logits (V,), caches)."""
    logits, caches = eng._decode(
        eng.params, caches, jnp.asarray([[tok]], jnp.int32),
        jnp.asarray([pos], jnp.int32))
    return np.asarray(logits, np.float32)[0], caches


def _log_softmax(x):
    x = x - x.max()
    return x - np.log(np.exp(x).sum())


def reference_beam(eng, prompt, *, width, max_new, eos_id=-1,
                   length_penalty=0.0):
    """NMT-style beam search oracle; returns (tokens, score).

    Keeps explicit per-hypothesis batch-1 caches; each round scores every
    beam x vocab continuation, retains the top ``2*width`` (ties: higher
    candidate id), routes EOS continuations into the finished pool (top
    ``width`` kept, ties: later pool entry) and extends with the first
    ``width`` non-EOS candidates.  Stops when the worst finished hypothesis
    dominates the best continuation, or at ``max_new``; the answer is the
    best of finished + continuing, finished preferred on ties.

    ``length_penalty`` mirrors the device strategy's GNMT alpha: live
    beams carry raw logprobs; scores are divided by
    ``lp(n) = ((5 + n) / 6) ** alpha`` on finished-pool insertion, in the
    stop rule, and when live beams enter the final answer pool.
    """
    def lp(n):
        return np.float32((5.0 + n) / 6.0) ** np.float32(length_penalty)

    logits1, cache1, pos0 = _prefill1(eng, prompt)
    logp = _log_softmax(logits1[0])
    order = np.argsort(-logp, kind="stable")[:width]   # desc, low id on ties
    beams = []          # (tokens tuple, score, cache, pos)
    finished = []       # (tokens tuple, score); index order = pool id order
    for tok in order:
        if tok == eos_id:
            # lp(1) == 1, matching the device's unnormalized admit round.
            finished.append(((int(tok),), float(logp[tok])))
        else:
            beams.append(([int(tok)], float(logp[tok]), cache1, pos0))
    finished = sorted(finished, key=lambda h: h[1], reverse=True)[:width]

    while beams and len(beams[0][0]) < max_new:
        best_cont = max(b[1] for b in beams)
        if best_cont == float("-inf"):
            break
        cur_len = len(beams[0][0])
        if len(finished) == width and \
                min(h[1] for h in finished) >= best_cont / lp(cur_len):
            break
        # Score all beam x vocab candidates; device tie rule: ascending
        # stable sort read backwards == higher candidate id wins ties.
        cands = []          # (score, cand_id, src, tok)
        steps = []
        for w, (toks, score, cache, pos) in enumerate(beams):
            logits, cache2 = _decode1(eng, cache, toks[-1], pos)
            steps.append(cache2)
            lpv = _log_softmax(logits)
            for v in range(lpv.shape[0]):
                cands.append((score + float(lpv[v]), w * lpv.shape[0] + v,
                              w, v))
        cands.sort(key=lambda c: (c[0], c[1]))          # ascending, stable
        top = cands[-2 * width:][::-1]
        # EOS candidates -> finished pool (incumbents get lower pool ids;
        # ties prefer the *higher* pool id, i.e. this round's entry --
        # matching the device's reversed stable sort).
        pool = [(s, i, toks) for i, (toks, s) in enumerate(finished)]
        base = len(pool)
        new_hyps = []
        for j, (score, _, src, tok) in enumerate(top):
            if tok == eos_id:
                pool.append((score / lp(len(beams[src][0]) + 1), base + j,
                             tuple(beams[src][0]) + (tok,)))
            elif len(new_hyps) < width:
                new_hyps.append((beams[src][0] + [tok], score,
                                 steps[src], beams[src][3] + 1))
        pool.sort(key=lambda p: (p[0], p[1]))
        finished = [(toks, s) for s, _, toks in pool[-width:][::-1]]
        beams = new_hyps
        if not beams:
            break

    # Final answer: finished first (wins ties), then continuations.
    candidates = [(s, 0, toks) for toks, s in finished]
    candidates += [(s / lp(len(toks)), 1, tuple(toks))
                   for toks, s, _, _ in beams]
    if not candidates:
        return [], float("-inf")
    best = max(candidates, key=lambda c: (c[0], -c[1]))
    return list(best[2]), float(best[0])


def reference_constrained(eng, prompt, seed, *, allowed, transitions,
                          max_new, eos_id=-1, start_state=0):
    """DFA-constrained decode oracle; returns (tokens, states_visited).

    Batch-1 incremental decode with the shared counter-key sampler
    (``sampling.sample_tokens`` with the engine's own temperature/top-k/
    top-p), logits masked to -inf outside the current DFA state's allowed
    row -- the same quantity the device strategy samples from.
    """
    allowed = np.asarray(allowed, bool)
    transitions = np.asarray(transitions, np.int32)
    seeds = jnp.asarray([seed], jnp.int32)

    def sample(logits_np, state, j):
        masked = np.where(allowed[state], logits_np, -np.inf)
        tok = eng._sample(eng._base_key, jnp.asarray(masked[None, :]),
                          seeds, jnp.asarray([j], jnp.int32))
        return int(np.asarray(tok)[0])

    logits1, caches, pos0 = _prefill1(eng, prompt)
    state = start_state
    tok = sample(logits1[0], state, 0)
    tokens, states = [tok], [state]
    state = int(transitions[state, tok])
    pos = pos0
    while len(tokens) < max_new and tokens[-1] != eos_id:
        logits, caches = _decode1(eng, caches, tokens[-1], pos)
        tok = sample(logits, state, len(tokens))
        tokens.append(tok)
        states.append(state)
        state = int(transitions[state, tok])
        pos += 1
    return tokens, states
