"""Deterministic beam search on the slot cache and the sort primitives.

A slot holds ``width`` beams: the cache tree is allocated at
``batch_size * width`` rows and slot ``b``'s beams live at rows
``b*width .. (b+1)*width - 1`` -- admission tiles the batch-1 prefill
``width`` ways into those rows through the ordinary slot scatter, and the
per-round beam reorder is one gather over the slot axis
(:func:`repro.serving.cache.gather_slots`), so beam state management is
pure slot-cache address math, no new kernels.

Each round scores every ``beam x vocab`` continuation and ranks the
``width * V`` candidates per slot with ONE ``sort_pairs`` launch under
``Segmented(offsets=...)`` -- the slots are equal-width contiguous segments
of the flat candidate stream (stable LSD radix over f32 keys, so the -inf
sentinels of dead beams order deterministically).  The top ``2*width``
candidates are retained: since each source beam contributes at most one EOS
continuation, at most ``width`` of them are EOS, so at least ``width``
non-EOS candidates survive -- the classic 2W-candidate guarantee.  EOS
candidates move to the per-slot finished store (merged with the incumbents
by a second segmented ``sort_pairs`` over the ``3*width`` pool); non-EOS
candidates become the next beams, their rank among non-EOS candidates
computed as a batched exclusive ``scan`` over the non-EOS flags.

Ties are deterministic and mirrored exactly by the numpy reference
(strategies/ref.py): ascending stable sort read backwards, so equal scores
prefer the *higher* candidate id; the final answer prefers finished over
continuing hypotheses at equal score.

Beam search is score-maximizing and therefore deterministic: ``bind``
rejects ``temperature > 0`` engines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched, Flat, Segmented
from repro.serving import cache as CA
from repro.serving.strategies.base import DecodeStrategy

NEG_INF = -jnp.inf


def _sort_rows(keys, values):
    """Per-row stable ascending ``sort_pairs`` of a (B, N) batch, lowered as
    one segmented launch over the flat ``B * N`` stream (equal-width
    contiguous segments)."""
    B, N = keys.shape
    seg = Segmented(offsets=jnp.arange(B + 1, dtype=jnp.int32) * N)
    sk, sv = forge.sort_pairs(
        keys.reshape(B * N), values.reshape(B * N), layout=seg)
    return sk.reshape(B, N), sv.reshape(B, N)


class BeamSearch(DecodeStrategy):
    """Beam search over the continuous-batching engine (``width`` beams per
    slot).  Requests finish when every beam slot's finished store dominates
    the best continuation, or at the length cap; the answer is the highest-
    scoring hypothesis (finished preferred on ties), its score reported as
    ``seq_logprob``.

    ``length_penalty`` is the GNMT alpha: hypotheses are ranked by
    ``logprob / lp(|y|)`` with ``lp(n) = ((5 + n) / 6) ** alpha``.  Live
    beams carry *raw* cumulative logprobs (extension order is
    length-invariant within a round); the divide happens where lengths
    differ -- at finished-pool insertion, in the stop rule, and when live
    continuations enter the final answer pool -- so ``seq_logprob``
    reports the normalized score.  ``alpha=0`` is the unnormalized default
    and stays bit-identical (the penalty code is skipped entirely)."""

    name = "beam"

    def __init__(self, width: int = 4, length_penalty: float = 0.0):
        if width < 1:
            raise ValueError(f"beam width must be >= 1, got {width}")
        if length_penalty < 0:
            raise ValueError(
                f"length_penalty must be >= 0, got {length_penalty}")
        self.width = width
        self.length_penalty = float(length_penalty)

    def _lp(self, length):
        """GNMT length penalty ``((5 + |y|) / 6) ** alpha``."""
        return ((5.0 + length.astype(jnp.float32)) / 6.0
                ) ** self.length_penalty

    def bind(self, eng):
        if eng.temperature > 0:
            raise ValueError(
                "beam search is deterministic: construct the Engine with "
                f"temperature=0 (got temperature={eng.temperature})")

    def init_state(self, eng) -> dict:
        B, W, T = eng.batch_size, self.width, eng.max_new_cap
        return {
            "caches": eng._cache_zeros(B * W),
            "scores": jnp.full((B, W), NEG_INF, jnp.float32),
            "btok": jnp.zeros((B, W), jnp.int32),
            "hyp": jnp.zeros((B, W, T), jnp.int32),
            "fin_scores": jnp.full((B, W), NEG_INF, jnp.float32),
            "fin_toks": jnp.zeros((B, W, T), jnp.int32),
            "fin_lens": jnp.zeros((B, W), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "emitted": jnp.zeros((B,), jnp.int32),
            "active": jnp.zeros((B,), bool),
            "max_new": jnp.zeros((B,), jnp.int32),
            "eos": jnp.full((B,), -1, jnp.int32),
        }

    def admit(self, eng, state, caches1, logits1, extras, *, slot, seed,
              max_new, eos, pos0):
        W, T = self.width, eng.max_new_cap
        # Tile the batch-1 prefill W ways into rows slot*W .. slot*W+W-1
        # (scatter_slot's dynamic_update_slice takes any width).
        tiledc = {
            part: jax.tree.map(
                lambda l: jnp.repeat(l, W, axis=0), caches1[part])
            for part in ("prefix", "suffix")}
        tiledc["units"] = jax.tree.map(
            lambda l: jnp.repeat(l, W, axis=1), caches1["units"])
        st = dict(state)
        st["caches"] = CA.scatter_slot(state["caches"], tiledc, slot * W)

        # Initial expansion: the top-W first tokens of the prompt's
        # distribution seed the W beams.
        logp = jax.nn.log_softmax(logits1.astype(jnp.float32), axis=-1)[0]
        vals, idx = forge.top_k(logp, W, layout=Flat())
        is_eos = idx == eos
        cont = jnp.where(is_eos, NEG_INF, vals)
        st["scores"] = state["scores"].at[slot].set(cont)
        st["btok"] = state["btok"].at[slot].set(idx)
        hyp0 = jnp.zeros((W, T), jnp.int32).at[:, 0].set(idx)
        st["hyp"] = state["hyp"].at[slot].set(hyp0)
        # (lp(1) == 1.0 exactly, so admission-round EOS scores need no
        # length-penalty divide.)
        st["fin_scores"] = state["fin_scores"].at[slot].set(
            jnp.where(is_eos, vals, NEG_INF))
        st["fin_toks"] = state["fin_toks"].at[slot].set(hyp0)
        st["fin_lens"] = state["fin_lens"].at[slot].set(
            jnp.where(is_eos, 1, 0))
        st["pos"] = state["pos"].at[slot].set(pos0)
        st["emitted"] = state["emitted"].at[slot].set(1)
        st["max_new"] = state["max_new"].at[slot].set(max_new)
        st["eos"] = state["eos"].at[slot].set(eos)

        max_cont = jnp.max(cont)
        min_fin = jnp.min(jnp.where(is_eos, vals, NEG_INF))
        stop = (max_cont == NEG_INF) | (min_fin >= max_cont)
        st["active"] = state["active"].at[slot].set(
            (max_new > 1) & ~stop)
        return st

    def step(self, eng, params, sparams, st):
        B, W, T = eng.batch_size, self.width, eng.max_new_cap
        was_active = st["active"]
        bidx = jnp.arange(B, dtype=jnp.int32)

        # Decode every beam row; score all beam x vocab continuations.
        pos_rows = jnp.repeat(st["pos"], W)
        logits, caches2 = eng._decode(
            params, st["caches"], st["btok"].reshape(B * W, 1), pos_rows)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        V = logp.shape[-1]
        cand = (st["scores"][:, :, None] + logp.reshape(B, W, V)
                ).reshape(B, W * V)

        # ONE segmented sort ranks each slot's W*V candidates; the last 2W
        # columns, read backwards, are the top-2W descending (ties: higher
        # candidate id -- the rule ref.py mirrors).
        ids = jnp.broadcast_to(
            jnp.arange(W * V, dtype=jnp.int32)[None, :], (B, W * V))
        skeys, sids = _sort_rows(cand, ids)
        top_s = skeys[:, -2 * W:][:, ::-1]                  # (B, 2W) desc
        top_i = sids[:, -2 * W:][:, ::-1]
        c_src = top_i // V
        c_tok = top_i % V
        c_eos = c_tok == st["eos"][:, None]

        # Continuing beams: the first W non-EOS candidates; each one's rank
        # among non-EOS candidates is the batched exclusive scan over the
        # non-EOS flags (the 2W-candidate guarantee: >= W of them exist).
        rank = forge.scan(alg.ADD, (~c_eos).astype(jnp.int32),
                          inclusive=False, layout=Batched())
        keep = ~c_eos & (rank < W)
        dest = jnp.where(keep, rank, W)                     # W = spill column
        def place(vals, fill, dtype):
            buf = jnp.full((B, W + 1), fill, dtype)
            return buf.at[bidx[:, None], dest].set(
                jnp.where(keep, vals, fill))[:, :W]
        new_scores = place(top_s, NEG_INF, jnp.float32)
        new_btok = place(c_tok, 0, jnp.int32)
        new_src = place(c_src, 0, jnp.int32)

        # Beam reorder: each surviving beam inherits the advanced cache of
        # the beam it extends -- a gather over the slot axis, identity on
        # inactive slots.
        ident = jnp.broadcast_to(
            jnp.arange(W, dtype=jnp.int32)[None, :], (B, W))
        src_rows = jnp.where(was_active[:, None],
                             bidx[:, None] * W + new_src,
                             bidx[:, None] * W + ident).reshape(B * W)
        caches3 = CA.gather_slots(caches2, src_rows)

        # Hypothesis buffers follow the same reorder + append.
        hyp_g = jnp.take_along_axis(st["hyp"], new_src[:, :, None], axis=1)
        at_t = (jnp.arange(T, dtype=jnp.int32)[None, None, :]
                == st["emitted"][:, None, None])
        new_hyp = jnp.where(at_t, new_btok[:, :, None], hyp_g)

        # Finished store: merge incumbents (pool ids 0..W-1) with this
        # round's EOS candidates (ids W..3W-1, non-EOS masked to -inf) and
        # keep the top W -- the second batched sort of the round.
        cand_hyp = jnp.take_along_axis(st["hyp"], c_src[:, :, None], axis=1)
        cand_hyp = jnp.where(at_t, c_tok[:, :, None], cand_hyp)
        fin_cand = top_s
        if self.length_penalty:
            # An EOS candidate finishes at emitted + 1 tokens; incumbents
            # are already stored normalized, so divide on the way in.
            fin_cand = top_s / self._lp(st["emitted"] + 1)[:, None]
        pool_s = jnp.concatenate(
            [st["fin_scores"], jnp.where(c_eos, fin_cand, NEG_INF)], axis=1)
        pool_ids = jnp.broadcast_to(
            jnp.arange(3 * W, dtype=jnp.int32)[None, :], (B, 3 * W))
        pkeys, pids = _sort_rows(pool_s, pool_ids)
        fin_sel = pids[:, -W:][:, ::-1]                     # (B, W) desc
        fin_scores2 = pkeys[:, -W:][:, ::-1]
        pool_toks = jnp.concatenate([st["fin_toks"], cand_hyp], axis=1)
        pool_lens = jnp.concatenate(
            [st["fin_lens"],
             jnp.broadcast_to((st["emitted"] + 1)[:, None], (B, 2 * W))],
            axis=1)
        fin_toks2 = jnp.take_along_axis(
            pool_toks, fin_sel[:, :, None], axis=1)
        fin_lens2 = jnp.take_along_axis(pool_lens, fin_sel, axis=1)

        emitted2 = st["emitted"] + 1
        max_cont = new_scores[:, 0]                         # desc order
        min_fin = fin_scores2[:, -1]
        max_cont_n = max_cont
        if self.length_penalty:
            # Compare like with like: the stored finished scores are
            # normalized, so normalize the best continuation at its
            # current length (the standard practical stop rule; mirrored
            # by the reference).
            max_cont_n = max_cont / self._lp(emitted2)
        stop = (min_fin >= max_cont_n) | (max_cont == NEG_INF)
        active2 = was_active & (emitted2 < st["max_new"]) & ~stop

        # Commit only on active slots (the loop decodes dead rows too, but
        # their state must stay frozen for the drain).
        def commit(nw, old, bdims):
            m = was_active.reshape((B,) + (1,) * (bdims - 1))
            return jnp.where(m, nw, old)
        new = dict(st)
        new["caches"] = CA.select_slots(
            jnp.repeat(was_active, W), caches3, st["caches"])
        new["scores"] = commit(new_scores, st["scores"], 2)
        new["btok"] = commit(new_btok, st["btok"], 2)
        new["hyp"] = commit(new_hyp, st["hyp"], 3)
        new["fin_scores"] = commit(fin_scores2, st["fin_scores"], 2)
        new["fin_toks"] = commit(fin_toks2, st["fin_toks"], 3)
        new["fin_lens"] = commit(fin_lens2, st["fin_lens"], 2)
        new["pos"] = st["pos"] + was_active
        new["emitted"] = commit(emitted2, st["emitted"], 1)
        new["active"] = active2
        return new

    def outputs(self, eng, state):
        B, W = eng.batch_size, self.width
        # Answer pool: finished hypotheses first (so argmax's first-max
        # rule prefers finished at equal score), then live continuations
        # (the length-cap fallback).
        live_s = state["scores"]
        if self.length_penalty:
            # Live continuations enter the pool at their current length;
            # finished incumbents are stored normalized already.
            live_s = live_s / self._lp(state["emitted"])[:, None]
        all_s = jnp.concatenate([state["fin_scores"], live_s], axis=1)
        all_t = jnp.concatenate([state["fin_toks"], state["hyp"]], axis=1)
        all_l = jnp.concatenate(
            [state["fin_lens"],
             jnp.broadcast_to(state["emitted"][:, None], (B, W))], axis=1)
        best = jnp.argmax(all_s, axis=1)
        out = jnp.take_along_axis(
            all_t, best[:, None, None], axis=1)[:, 0]
        emitted = jnp.take_along_axis(all_l, best[:, None], axis=1)[:, 0]
        score = jnp.take_along_axis(all_s, best[:, None], axis=1)[:, 0]
        return {"out": out, "emitted": emitted, "seq_logprob": score}

    def poison(self, eng, caches, slot):
        for w in range(self.width):
            caches = CA.poison_slot(caches, slot * self.width + w)
        return caches
