"""Grammar/JSON-constrained sampling: a token-level DFA masks the vocab.

The grammar is compiled (offline, by the caller) to a token-level DFA over
two dense device tables:

* ``allowed``: (n_states, V) bool -- which tokens may be emitted from each
  state;
* ``transitions``: (n_states, V) int32 -- the state reached after emitting
  each token.

Each slot carries its DFA state in the while-loop carry; every step gathers
its state's ``allowed`` row and masks the logits to ``-inf`` outside it
*before* the ordinary sampler runs -- the masked logits then flow through
the exact same ``top_k(layout=Segmented)`` + nucleus ``scan(layout=
Batched())`` path as vanilla sampling (masked entries sort to the bottom
under the pinned f32 key order and carry zero probability mass), so
constrained decoding is a logits transform, not a sampler fork.  The first
token is constrained too: admission masks the prefill logits with the start
state's row.

Reported ``seq_logprob`` is the sequence's log-probability under the
*constrained* (renormalized) distribution -- ``chosen_logprobs`` runs on
the masked logits, which is the quantity nucleus/temperature sampling
actually sampled from.

``bind`` validates the tables host-side: every state must allow at least
one token (a dead state would force the sampler to pick an argmax over all
``-inf`` -- a silent grammar violation), and transitions must stay in
range.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.serving.strategies.base import Vanilla, vanilla_admit


class Constrained(Vanilla):
    """DFA-constrained sampling riding the vanilla state layout (the DFA
    state is one extra (B,) int32 in the carry)."""

    name = "constrained"

    def __init__(self, allowed, transitions, *, start_state: int = 0):
        allowed = np.asarray(allowed, bool)
        transitions = np.asarray(transitions, np.int32)
        if allowed.ndim != 2 or transitions.shape != allowed.shape:
            raise ValueError(
                f"allowed {allowed.shape} and transitions "
                f"{transitions.shape} must both be (n_states, vocab)")
        n_states = allowed.shape[0]
        dead = np.where(~allowed.any(axis=1))[0]
        if dead.size:
            raise ValueError(
                f"DFA states {dead.tolist()} allow no token: every state "
                "must keep at least one continuation or sampling would "
                "pick an argmax over an all-masked vocabulary")
        if transitions.min() < 0 or transitions.max() >= n_states:
            raise ValueError(
                f"transitions must map into [0, {n_states}); got range "
                f"[{transitions.min()}, {transitions.max()}]")
        if not 0 <= start_state < n_states:
            raise ValueError(
                f"start_state {start_state} outside [0, {n_states})")
        self.start_state = start_state
        self._allowed = jnp.asarray(allowed)
        self._trans = jnp.asarray(transitions)

    def bind(self, eng):
        if self._allowed.shape[1] != eng.cfg.vocab_size:
            raise ValueError(
                f"DFA tables cover a vocab of {self._allowed.shape[1]} but "
                f"the model's vocab_size is {eng.cfg.vocab_size}")

    def init_state(self, eng) -> dict:
        st = eng._base_state()
        st["cstate"] = jnp.full(
            (eng.batch_size,), self.start_state, jnp.int32)
        return st

    def admit(self, eng, state, caches1, logits1, extras, *, slot, seed,
              max_new, eos, pos0):
        logits1 = jnp.where(self._allowed[self.start_state][None, :],
                            logits1, -jnp.inf)
        st = vanilla_admit(eng, state, caches1, logits1, slot=slot,
                           seed=seed, max_new=max_new, eos=eos, pos0=pos0)
        st["cstate"] = state["cstate"].at[slot].set(
            self._trans[self.start_state, st["tok"][slot]])
        return st

    def _adjust_logits(self, eng, st, logits):
        return jnp.where(self._allowed[st["cstate"]], logits, -jnp.inf)

    def _post_step(self, eng, st, new, nxt, was_active):
        new["cstate"] = jnp.where(
            was_active, self._trans[st["cstate"], nxt], st["cstate"])
        return new
