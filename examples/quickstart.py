"""Quickstart: the KernelForge primitives on arbitrary types and operators.

    PYTHONPATH=src python examples/quickstart.py

Every call dispatches through the two-layer architecture: on TPU the Pallas
kernels run; on CPU the portable XLA fallback runs; `backend="pallas-interpret"`
executes the TPU kernel bodies in Python (used here so the quickstart
exercises the real kernels on any machine).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched, Segmented

B = "pallas-interpret" if jax.default_backend() != "tpu" else None
key = jax.random.PRNGKey(0)

print("== 1. prefix sums (the classic) ==")
x = jax.random.normal(key, (1000,), jnp.float32)
print("scan(+):", np.asarray(forge.scan(alg.ADD, x, backend=B))[:4], "...")
print("scan(max), exclusive:",
      np.asarray(forge.scan(alg.MAX, x, inclusive=False, backend=B))[:4])

print("\n== 2. arbitrary struct types: quaternion composition ==")
q = tuple(jax.random.normal(jax.random.fold_in(key, i), (256,), jnp.float32)
          * 0.1 + (1.0 if i == 0 else 0.0) for i in range(4))
w, xi, yj, zk = forge.scan(alg.QUATERNION_MUL, q, backend=B)
print("cumulative quaternion product (non-commutative!):",
      f"w={float(w[-1]):.4f} x={float(xi[-1]):.4f}")

print("\n== 3. custom 8-bit type with free promotion (UnitFloat8) ==")
u8 = jax.random.randint(key, (100_000,), 0, 256, jnp.int32).astype(jnp.uint8)
s = forge.mapreduce(alg.unitfloat8_decode, alg.ADD, u8, backend=B)
print(f"sum of 100k UnitFloat8 values: {float(s):.2f} "
      "(decoded to f32 in-register; bandwidth = 1 byte/element)")

print("\n== 4. semiring matvec: tropical shortest paths ==")
# One Bellman-Ford relaxation: dist' = min_i (dist[i] + W[i, j]).
W = jnp.where(jax.random.uniform(key, (64, 64)) < 0.2,
              jax.random.uniform(key, (64, 64), maxval=10.0), jnp.inf)
W = W.at[jnp.arange(64), jnp.arange(64)].set(0.0)
dist = jnp.full((64,), jnp.inf).at[0].set(0.0)
for _ in range(4):
    dist = forge.semiring_matvec(alg.TROPICAL_MIN_PLUS, W, dist, backend=B)
print("4-hop shortest distances from node 0 (first 8):",
      np.round(np.asarray(dist[:8]), 2))

print("\n== 5. log-semiring vecmat: stable HMM forward step ==")
logA = jnp.log(jax.nn.softmax(jax.random.normal(key, (32, 32)), axis=1))
logp = jnp.log(jax.nn.softmax(jax.random.normal(key, (32,))))
logp = forge.semiring_vecmat(alg.LOG_SEMIRING, logA, logp, backend=B)
print("updated log-probs (logsumexp accumulation), max:",
      float(jnp.max(logp)))

print("\n== 6. segmented layout: ragged batches without padding ==")
# Three "requests" of lengths 3, 5, 2 flattened into one stream -- the same
# scan/mapreduce entry points, with layout passed as a value.
vals = jnp.arange(10, dtype=jnp.float32)
offs = jnp.asarray([0, 3, 8, 10], jnp.int32)
print("per-request running sums:",
      np.asarray(forge.scan(alg.ADD, vals,
                            layout=Segmented(offsets=offs), backend=B)))
print("per-request totals:      ",
      np.asarray(forge.mapreduce(lambda v: v, alg.ADD, vals,
                                 layout=Segmented(offsets=offs), backend=B)))

print("\n== 7. linear recurrence: the model-stack workhorse ==")
a = jax.random.uniform(key, (2, 128, 256), jnp.float32, 0.9, 0.99)
b = jax.random.normal(jax.random.fold_in(key, 9), (2, 128, 256), jnp.float32)
h = forge.linear_recurrence(a, b, backend=B)
print("h_t = a_t*h_{t-1} + b_t over (B=2, T=128, C=256):",
      "final-state norm =", float(jnp.linalg.norm(h[:, -1])))

print("\n== 7b. batched layout: one launch per uniform batch ==")
probs = jax.nn.softmax(
    jax.random.normal(jax.random.fold_in(key, 12), (4, 8), jnp.float32), -1)
cum = forge.scan(alg.ADD, probs, inclusive=False, layout=Batched(), backend=B)
print("per-request exclusive nucleus mass (B=4 rows, one launch):",
      np.round(np.asarray(cum[:, -1]), 3).tolist())
lens = jnp.asarray([8, 3, 5, 1], jnp.int32)
msk = (jnp.arange(8, dtype=jnp.int32)[None, :] < lens[:, None]).astype(jnp.int32)
tot = forge.mapreduce(
    lambda t: jnp.where(t[1] != 0, t[0], 0.0), alg.ADD, (probs, msk),
    layout=Batched(), backend=B)
print("masked per-request sums (ragged lengths, no host loop):",
      np.round(np.asarray(tot), 3).tolist())

print("\n== 8. radix sort / top-k: derived primitives on the scan substrate ==")
expert = jax.random.randint(jax.random.fold_in(key, 10), (24,), 0, 4,
                            jnp.int32).astype(jnp.uint32)
tok = jnp.arange(24, dtype=jnp.int32)
se, st = forge.sort_pairs(expert, tok, key_bits=2, backend=B)
print("expert-sorted token stream (stable, 1 digit pass):",
      np.asarray(se)[:12], "...")
logits = jax.random.normal(jax.random.fold_in(key, 11), (10,), jnp.float32)
v, i = forge.top_k(logits, 2, layout=Segmented(offsets=offs), backend=B)
print("per-request top-2 logits:", np.round(np.asarray(v), 2).tolist(),
      "ids:", np.asarray(i).tolist())
print("\n== 9. backend selection: scoped, queryable, zero call changes ==")
import repro

print("available:", ", ".join(repro.available_backends()))
print("scan@flat native on pallas-gpu?",
      repro.supports("scan@flat", "pallas-gpu"))
with repro.use_backend("pallas-gpu"):   # GPU kernel bodies (interpreted on CPU)
    g = forge.scan(alg.ADD, x[:300])
print("scan under use_backend('pallas-gpu'):", np.asarray(g)[:4], "...")

print("\n(quickstart done -- one entry point per primitive, layout as a"
      " value, four backends, zero code changes)")
