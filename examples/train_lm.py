"""End-to-end training driver: train a small LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --preset ci      # runs here
    PYTHONPATH=src python examples/train_lm.py --preset 100m    # real HW

The ``100m`` preset is a ~100M-parameter llama-style model (the task-spec
e2e scale); on this 1-core CPU container a single step takes ~a minute, so
``ci`` (default) runs a ~5M-parameter model for 200 steps in a few minutes
and demonstrates the full substrate: synthetic pipeline -> jit'd train step
(remat, grad clip, schedule) -> async checkpointing -> restart recovery.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.training import optimizer as OPT
from repro.training import train_step as TS
from repro.training.data import DataConfig, SyntheticDataset
from repro.training.trainer import RunConfig, Trainer

PRESETS = {
    "ci": dict(
        model=ModelConfig(
            name="ci-lm", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
            head_dim=32, d_ff=512, vocab_size=2048,
            unit=("attn_global",), n_units=4, activation="swiglu"),
        seq_len=128, global_batch=8, steps=200, lr=3e-3),
    "100m": dict(
        model=ModelConfig(
            name="lm-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2304, vocab_size=32768,
            unit=("attn_global",), n_units=12, activation="swiglu"),
        seq_len=1024, global_batch=64, steps=300, lr=6e-4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    p = PRESETS[args.preset]
    cfg = p["model"]
    steps = args.steps or p["steps"]

    tc = TS.TrainConfig(
        optimizer=OPT.OptimizerConfig(peak_lr=p["lr"], warmup_steps=20,
                                      decay_steps=steps),
        remat="none" if args.preset == "ci" else "full")
    data = SyntheticDataset(
        DataConfig(seq_len=p["seq_len"], global_batch=p["global_batch"],
                   vocab_size=cfg.vocab_size), cfg)
    run = RunConfig(total_steps=steps, ckpt_dir=args.ckpt_dir,
                    ckpt_every=max(steps // 4, 25), log_every=10)

    from repro.models import lm
    n_params = lm.count_params(lm.init_params(jax.random.PRNGKey(0), cfg))
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{steps} steps, batch {p['global_batch']} x seq {p['seq_len']}")

    t = Trainer(cfg, None, tc, run, data)
    t0 = time.time()
    t.run()
    dt = time.time() - t0
    first = t.metrics_log[0]["ce_loss"]
    last = t.metrics_log[-1]["ce_loss"]
    print(f"[train_lm] done in {dt:.0f}s: ce_loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
