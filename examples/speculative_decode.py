"""Speculative decoding demo: draft-and-verify on the serving engine.

    PYTHONPATH=src python examples/speculative_decode.py [--k 4]

A small draft model proposes ``k`` tokens per round; the target verifies
them in one combined scan and accepts the longest exact-match prefix.  The
acceptance rule is *lossless*: the emitted stream is bit-identical to the
vanilla engine at the same seeds -- speculation only changes how many
target-forward rounds the stream costs.  The demo runs the same requests
through a vanilla engine and a speculative engine (twice: once with the
target itself as a "perfect" draft, once with an independently initialized
draft), checks the streams match, and prints the acceptance telemetry.

Uses reduced (smoke) configs so it runs on any host; on real hardware the
draft would be a genuinely smaller architecture sharing the tokenizer.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import base as C
from repro.models import lm
from repro.serving.engine import Engine, Request
from repro.serving.strategies import Speculative


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b", choices=C.list_archs())
    ap.add_argument("--k", type=int, default=4,
                    help="draft proposals per round")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (default; a perfect draft then accepts "
                         "nearly everything). Nonzero temperatures stay "
                         "bit-identical too, but exact-match acceptance is "
                         "rare because draft and target sample from "
                         "different key streams.")
    args = ap.parse_args()

    cfg = C.get_config(args.arch, smoke=True)
    print(f"[spec] arch={args.arch} (reduced config: {cfg.n_layers}L "
          f"d={cfg.d_model}), k={args.k}")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    draft_params = lm.init_params(jax.random.PRNGKey(7), cfg)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab_size, n).tolist(),
                    max_new_tokens=args.max_new, seed=i)
            for i, n in enumerate(rng.integers(3, 12, size=4))]
    kw = dict(cache_len=128, batch_size=4, temperature=args.temperature,
              top_k=40, seed=0)

    van = Engine(cfg, None, params, **kw)
    t0 = time.time()
    base = van.generate(reqs)
    print(f"[spec] vanilla: {van.last_stats['decode_steps']} loop rounds "
          f"({time.time() - t0:.1f}s incl. compile)")

    for label, dp in (("perfect draft (target params)", params),
                      ("independent draft", draft_params)):
        eng = Engine(cfg, None, params, **kw,
                     strategy=Speculative(cfg, dp, k=args.k))
        t0 = time.time()
        outs = eng.generate(reqs)
        st = eng.last_stats
        match = "bit-identical" if outs == base else "MISMATCH (bug!)"
        print(f"[spec] {label}: {st['spec_rounds']} rounds, "
              f"acceptance {st['spec_acceptance_rate']:.2f} "
              f"({st['spec_accepted']}/{st['spec_proposed']} draft tokens), "
              f"streams {match} ({time.time() - t0:.1f}s incl. compile)")
        assert outs == base

    print("[spec] sample stream:", base[0][:16], "...")


if __name__ == "__main__":
    main()
