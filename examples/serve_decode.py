"""End-to-end serving driver: continuous batching with on-device decode.

    PYTHONPATH=src python examples/serve_decode.py [--arch gemma2-27b]

Uses the reduced (smoke) config of the chosen architecture so it runs on any
host; the same Engine drives the full configs on real hardware (the mesh and
shardings come from the same builders the dry-run compiles).

Two demos: a closed batch (``generate`` -- everything admitted at step 0,
one device-loop dispatch decodes the whole batch to completion) and an
open-loop Poisson trace (``serve`` -- more requests than slots, admitted as
earlier requests hit their budget and free their slot).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import base as C
from repro.models import lm
from repro.serving.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b",
                    choices=C.list_archs())
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = C.get_config(args.arch, smoke=True)
    print(f"[serve] arch={args.arch} (reduced config: {cfg.n_layers}L "
          f"d={cfg.d_model})")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, None, params, cache_len=256, batch_size=args.batch,
                 temperature=0.0)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, 12)),
                    max_new_tokens=args.max_new)
            for _ in range(args.batch)]
    outs = eng.generate(reqs)           # includes compile
    t0 = time.time()
    outs = eng.generate(reqs)           # steady-state
    dt = time.time() - t0
    for i, o in enumerate(outs):
        print(f"  req{i}: {o[:12]}{'...' if len(o) > 12 else ''}")
    s = eng.last_stats
    print(f"[serve] prefill {s['prefill_s']*1e3:.1f}ms, decode "
          f"{s['decode_tok_per_s']:.1f} tok/s (host CPU), "
          f"{s['loop_dispatches']} device-loop dispatch(es), wall {dt:.2f}s")

    # Open-loop traffic: 3x more requests than slots arriving over time;
    # the scheduler recycles slots as requests finish.
    trace = []
    step = 0.0
    for i in range(3 * args.batch):
        step += rng.exponential(2.0)
        trace.append((int(step), Request(
            prompt=list(rng.integers(1, cfg.vocab_size, 8)),
            max_new_tokens=int(rng.integers(4, args.max_new + 1)), seed=i)))
    recs = eng.serve(trace)
    s = eng.last_stats
    lat = [r.finish_step - r.submit_step for r in recs]
    print(f"[serve] open loop: {len(recs)} requests through "
          f"{args.batch} slots, {s['decode_tok_per_s']:.1f} tok/s, "
          f"latency p50 {int(np.percentile(lat, 50))} steps / "
          f"max {max(lat)} steps, {s['loop_dispatches']} dispatches")


if __name__ == "__main__":
    main()
