"""Long-context decode with the sub-quadratic archs (the long_500k story).

    PYTHONPATH=src python examples/long_context.py [--arch recurrentgemma-2b]

Demonstrates why the hybrid/SSM archs run the 524288-token cell: their decode
state is O(1) in sequence length (RG-LRU hidden state + ring-buffered local
window / mLSTM matrix memory), so stepping at position 500_000 costs exactly
what stepping at position 50 costs.  The KernelForge scan primitive carries
the recurrent state math (AFFINE / MAXPLUS_AFFINE operators).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as C
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b",
                    choices=["recurrentgemma-2b", "xlstm-1.3b"])
    args = ap.parse_args()

    cfg = C.get_config(args.arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B = 1
    # Cache sized by the *window*, not the sequence: O(1) in context length.
    caches = lm.init_caches(cfg, B, cache_len=max(cfg.local_window, 64))
    leaves = jax.tree.leaves(caches)
    state_bytes = sum(l.size * l.dtype.itemsize for l in leaves)
    print(f"[long-context] {args.arch}: decode state = "
          f"{state_bytes/1024:.1f} KiB regardless of position")

    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, cfg, c, t, pos))
    tok = jnp.ones((B, 1), jnp.int32)

    # Warm up + feed some context.
    for i in range(8):
        logits, caches = step(params, caches, tok, jnp.asarray(i, jnp.int32))

    def time_steps(pos0, n=16):
        nonlocal caches, tok
        t0 = time.time()
        for i in range(n):
            logits, caches = step(params, caches, tok,
                                  jnp.asarray(pos0 + i, jnp.int32))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(logits)
        return (time.time() - t0) / n

    early = time_steps(8)
    late = time_steps(500_000)
    print(f"[long-context] per-token decode: pos~10: {early*1e3:.2f}ms, "
          f"pos~500k: {late*1e3:.2f}ms (ratio {late/early:.2f}x -- flat)")
    assert late < early * 3, "decode cost must not grow with position"
    print("[long-context] OK: O(1)-state decode verified")


if __name__ == "__main__":
    main()
