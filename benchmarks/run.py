"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Sections:
  1. kernel tables mirroring the paper (copy / scan / mapreduce / matvec /
     arbitrary-operator suite);
  2. roofline analysis over the multi-pod dry-run artifacts (§Roofline);
  3. a small *measured* end-to-end train-step microbench on the reduced
     config (CPU wall time -- the only honest wall-clock in this container).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def train_microbench():
    print("\n== Train-step microbench (reduced config, host CPU) ==")
    from repro.configs import base as C
    from repro.training import optimizer as OPT
    from repro.training import train_step as TS
    from repro.training.data import DataConfig, SyntheticDataset
    cfg = C.get_config("minitron-4b", smoke=True)
    tc = TS.TrainConfig(optimizer=OPT.OptimizerConfig(), remat="none")
    data = SyntheticDataset(DataConfig(seq_len=64, global_batch=8,
                                       vocab_size=cfg.vocab_size), cfg)
    state = TS.init_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(TS.make_train_step(cfg, None, tc), donate_argnums=(0,))
    state, m = step(state, data.batch(0))      # compile
    jax.block_until_ready(m["loss"])
    t0 = time.time()
    n = 5
    for s in range(1, n + 1):
        state, m = step(state, data.batch(s))
    jax.block_until_ready(m["loss"])
    dt = (time.time() - t0) / n
    toks = 8 * 64
    print(f"reduced minitron-4b: {dt*1e3:.1f} ms/step, "
          f"{toks/dt:.0f} tok/s (host CPU), loss={float(m['loss']):.3f}")


def main():
    print("=" * 72)
    print("KernelForge-TPU benchmark suite")
    print("=" * 72)
    from benchmarks import bench_kernels
    bench_kernels.main()

    print("\n" + "=" * 72)
    from benchmarks import roofline
    results_dir = os.path.join(os.path.dirname(__file__), "..",
                               "results", "dryrun")
    if os.path.isdir(results_dir) and os.listdir(results_dir):
        roofline.main(results_dir)
    else:
        print("(no dry-run artifacts under results/dryrun; run "
              "PYTHONPATH=src python -m repro.launch.dryrun first)")

    train_microbench()
    print("\nbenchmarks complete")


if __name__ == "__main__":
    main()
