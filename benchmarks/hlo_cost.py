"""While-loop-aware cost model over compiled (post-partitioning) HLO text.

XLA's ``HloCostAnalysis`` (behind ``compiled.cost_analysis()``) counts every
computation ONCE -- a ``lax.scan`` over 58 layers contributes one body's
FLOPs (verified by micro-probe; EXPERIMENTS.md §Dry-run).  This walker
re-derives roofline inputs with trip counts:

* parses every computation into an instruction table (name -> type/op/operands),
* extracts while trip counts from the loop condition's ``constant(N)``,
* walks the call graph multiplying nested trip counts,
* accumulates matmul FLOPs (``dot``), per-instruction HBM bytes, and
  collective bytes, each scaled by its enclosing multiplier.

Byte model: each top-level instruction contributes (operand bytes + output
bytes); fusion-internal instructions contribute FLOPs (dots execute on the
MXU regardless) but not bytes (fused intermediates never round-trip HBM) --
closer to real TPU HBM traffic than XLA's "bytes accessed", which counts
fusion internals.  parameters/constants/tuples/GTEs/bitcasts are free.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_TOKEN = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_OP_CALL = re.compile(r"(?:^|\s)([a-zA-Z][\w\-]*)\(([^)]*)\)")
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "bitcast-convert", "after-all", "partition-id", "replica-id",
             "iota", "copy", "copy-start", "copy-done",
             # Control-flow wrappers: their bodies are walked with
             # multipliers; charging the instruction itself would bill the
             # full carried tuple per trip.
             "while", "conditional", "call", "optimization-barrier"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str):
    m = _SHAPE_TOKEN.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Instr:
    __slots__ = ("name", "type_str", "op", "operands", "attrs", "line")

    def __init__(self, name, type_str, op, operands, attrs, line):
        self.name, self.type_str, self.op = name, type_str, op
        self.operands, self.attrs, self.line = operands, attrs, line


def _parse_instr(line: str) -> Instr | None:
    if " = " not in line:
        return None
    lhs, rhs = line.split(" = ", 1)
    name = lhs.strip().lstrip("%")
    # Cut metadata (contains slashes/parens that confuse op matching).
    rhs_main = rhs.split(", metadata=")[0]
    m = _OP_CALL.search(rhs_main)
    if m is None:
        return None
    op = m.group(1)
    operands = [o.strip().lstrip("%") for o in m.group(2).split(",")
                if o.strip().startswith("%")]
    type_str = rhs_main[:m.start()]
    attrs = rhs_main[m.end():]
    return Instr(name, type_str, op, operands, attrs, rhs_main)


def parse_computations(hlo: str) -> dict:
    """comp name -> list[Instr]."""
    comps: dict[str, list] = {}
    cur = None
    for raw in hlo.splitlines():
        if not raw:
            continue
        if not raw.startswith(" "):
            s = raw.strip()
            if s.startswith(("ENTRY ", "%")) and s.endswith("{"):
                hdr = s[len("ENTRY "):] if s.startswith("ENTRY ") else s
                cur = hdr.split("(")[0].strip().lstrip("%").strip()
                comps[cur] = []
            continue
        if cur is None:
            continue
        ins = _parse_instr(raw.strip().lstrip("ROOT ").strip())
        if ins is not None:
            comps[cur].append(ins)
    return comps


def _entry_name(hlo: str) -> str | None:
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            return line.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
    return None


def _attr_comp(attrs: str, key: str):
    m = re.search(key + r"=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def _called_comps(ins: Instr) -> list:
    out = []
    m = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
    if m:
        out.append(m.group(1))
    m = re.search(r"to_apply=%?([\w\.\-]+)", ins.attrs)
    if m:
        out.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
    if m:
        out += [c.strip().lstrip("%") for c in m.group(1).split(",")]
    return out


def _trip_count(comps, cond_name, depth=0) -> int:
    best = 1
    for ins in comps.get(cond_name, ()):
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            best = max(best, int(m.group(1)))
        if depth < 2:
            for c in _called_comps(ins):
                best = max(best, _trip_count(comps, c, depth + 1))
    return best


def _dot_flops(ins: Instr, table: dict) -> float:
    out_dims = _first_shape_dims(ins.type_str)
    out = 1
    for d in out_dims:
        out *= d
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if m and ins.operands:
        lhs = table.get(ins.operands[0])
        lhs_dims = _first_shape_dims(lhs.type_str) if lhs else []
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out * contract


def _instr_bytes(ins: Instr, table: dict) -> int:
    """HBM traffic attributed to one instruction: its output, written once
    and read ~once downstream (2x).  Operand reads are charged to their
    producers, so dynamic-slice/gather fusions are charged the slice they
    materialize, not the full buffer they index.

    In-place accumulation patterns (dynamic-update-slice / scatter, bare or
    as a fusion root aliasing one operand) are charged the *update* they
    move, not the aliased buffer: XLA updates these in place, and charging
    the buffer x trip-count inflated loop-heavy cells by >100x (the xlstm
    §Perf investigation)."""
    out_b = _type_bytes(ins.type_str)
    op_bytes = [_type_bytes(table[o].type_str) for o in ins.operands
                if o in table]
    if out_b > 0 and any(b == out_b for b in op_bytes):
        others = sum(op_bytes) - out_b
        if 0 < others < out_b:      # aliased in-place update: move the delta
            return 2 * others
    return 2 * out_b


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    entry = _entry_name(hlo) or (next(iter(comps)) if comps else None)
    tables = {name: {i.name: i for i in instrs}
              for name, instrs in comps.items()}

    mult: dict[str, float] = defaultdict(float)
    fused_ctx: set = set()

    def visit(name: str, m: float, in_fusion: bool):
        mult[name] += m
        if in_fusion:
            fused_ctx.add(name)
        for ins in comps.get(name, ()):
            if ins.op == "while":
                body = _attr_comp(ins.attrs, "body")
                cond = _attr_comp(ins.attrs, "condition")
                trip = _trip_count(comps, cond) if cond else 1
                if body:
                    visit(body, m * trip, in_fusion)
                if cond:
                    visit(cond, m * trip, in_fusion)
                continue
            callees = _called_comps(ins)
            child_fused = in_fusion or ins.op == "fusion"
            for c in callees:
                visit(c, m, child_fused)

    if entry:
        visit(entry, 1.0, False)

    flops = 0.0
    bytes_hbm = 0.0
    coll = {op: {"count": 0.0, "bytes": 0.0} for op in COLLECTIVES}

    # Entry parameters are read once (argument streaming).
    for ins in comps.get(entry, ()):
        if ins.op == "parameter":
            bytes_hbm += _type_bytes(ins.type_str)

    for name, m in mult.items():
        if m <= 0:
            continue
        table = tables.get(name, {})
        in_fusion = name in fused_ctx
        for ins in comps.get(name, ()):
            if ins.op in ("dot", "dot-general"):
                flops += m * _dot_flops(ins, table)
            if ins.op in _FREE_OPS:
                continue
            base_op = ins.op.replace("-start", "").replace("-done", "")
            if base_op in COLLECTIVES and not ins.op.endswith("-done"):
                size = max(_type_bytes(ins.type_str),
                           sum(_type_bytes(table[o].type_str)
                               for o in ins.operands if o in table) or 0)
                coll[base_op]["count"] += m
                coll[base_op]["bytes"] += m * size
            if not in_fusion:
                bytes_hbm += m * _instr_bytes(ins, table)

    coll["total_bytes"] = sum(v["bytes"] for k, v in coll.items()
                              if isinstance(v, dict))
    return {
        "flops": flops,
        "bytes_hbm": bytes_hbm,
        "collectives": coll,
        "n_computations": len(comps),
    }
