"""Hardware model constants (TPU v5e target) + paper reference points.

This container has no TPU: the benchmark harness derives *structural* costs
(bytes moved by construction of the BlockSpecs, HLO bytes/FLOPs from compiled
fallbacks) and converts them to modeled times against these constants.  The
A40/CUB numbers from the paper's tables are included so each table prints the
reproduction target next to our model.
"""

# TPU v5e (target), per chip.
PEAK_FLOPS_BF16 = 197e12         # FLOP/s
HBM_BW = 819e9                   # B/s
ICI_BW_PER_LINK = 50e9           # B/s per link (~ per mesh-axis neighbor)
HBM_GB = 16

# NVIDIA A40 (the paper's primary platform), for scaling reference numbers.
A40_BW = 696e9

# Paper reference rows (kernel-only microseconds, Tables III & IV, A40).
PAPER_SCAN_F32 = {10**6: 21.5, 10**7: 149.4, 10**8: 1460.0, 10**9: 14553.0}
PAPER_SCAN_CUB_F32 = {10**6: 20.7, 10**7: 149.5, 10**8: 1435.0, 10**9: 14287.0}
PAPER_SCAN_F64 = {10**6: 34.4, 10**7: 290.6, 10**8: 2841.0, 10**9: 28327.0}
PAPER_MR_F32 = {10**6: 6.1, 10**7: 71.2, 10**8: 679.9, 10**9: 6562.0}
PAPER_MR_CUB_F32 = {10**6: 9.4, 10**7: 75.6, 10**8: 683.2, 10**9: 6809.0}
PAPER_MR_UF8 = {10**6: 4.9, 10**7: 23.3, 10**8: 178.4, 10**9: 1718.0}
PAPER_MR_CUB_U8 = {10**6: 8.0, 10**7: 25.4, 10**8: 175.2, 10**9: 1724.0}


def modeled_time_s(bytes_moved: float, flops: float = 0.0) -> float:
    """Roofline-modeled kernel time on v5e: max of memory and compute terms."""
    return max(bytes_moved / HBM_BW, flops / PEAK_FLOPS_BF16)


def bw_fraction(bytes_moved: float, time_s: float) -> float:
    return (bytes_moved / time_s) / HBM_BW if time_s > 0 else 0.0
