"""Structural HBM-traffic model for every KernelForge-TPU kernel.

Derived from the same grid/BlockSpec arithmetic the kernels use -- each input
block is transferred HBM->VMEM exactly once per grid step that maps it, and
each output block VMEM->HBM exactly once (sequential-grid revisiting keeps
the block resident).  This is the structural 2n-movement argument of the
paper's scan (§V-B) made checkable: the numbers below are what the lowered
kernel *must* move, including ragged-tail padding.

For the XLA-fallback baselines, bytes come from compiled ``cost_analysis()``
instead -- the honest CPU-only stand-in for the paper's measured vendor
baselines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import intrinsics as ki


def _pad(n, b):
    return ki.cdiv(n, b) * b


def scan_bytes(n: int, dtypes, policy=None) -> int:
    """1-D scan: exactly one read + one write per (padded) element."""
    policy = policy or ki.resolve_tuning()
    sub = max(ki.min_tile(d)[0] for d in dtypes)
    block = policy.nitem_scan * sub * ki.LANES
    np_ = _pad(n, block)
    per_elem = sum(jnp.dtype(d).itemsize for d in dtypes)
    return 2 * np_ * per_elem


def mapreduce_bytes(n: int, in_dtypes, out_dtypes, policy=None) -> int:
    """Reduce: one read per element + O(1) output."""
    policy = policy or ki.resolve_tuning()
    sub = max(ki.min_tile(d)[0] for d in in_dtypes)
    block = policy.nitem_reduce * sub * ki.LANES
    np_ = _pad(n, block)
    return np_ * sum(jnp.dtype(d).itemsize for d in in_dtypes) + \
        sum(jnp.dtype(d).itemsize for d in out_dtypes)


def matvec_bytes(n: int, p: int, dtype, out_dtype=None, policy=None) -> int:
    """y[j] = op_i f(x[i], A[i,j]): A once, x re-read per column stripe."""
    from repro.kernels.ops import _pick_blocks_matvec
    policy = policy or ki.resolve_tuning()
    sz = jnp.dtype(dtype).itemsize
    osz = jnp.dtype(out_dtype or dtype).itemsize
    if p <= 64 and n >= 4 * ki.LANES:
        # Lane-packed tall-narrow path: g row groups share the 128 lanes.
        g = max(ki.LANES // p, 1)
        ng = _pad(n, g) // g
        rn = policy.matvec_rows * ki.min_tile(dtype)[0]
        return (_pad(ng, rn) * g * p + _pad(ng, rn) * g) * sz + ki.LANES * osz
    rn, cp = _pick_blocks_matvec(policy, jnp.zeros((1, 1), dtype), n, p)
    a_bytes = _pad(n, rn) * _pad(p, cp) * sz
    x_bytes = ki.cdiv(p, cp) * _pad(n, rn) * sz       # x per column stripe
    y_bytes = _pad(p, cp) * osz
    return a_bytes + x_bytes + y_bytes


def vecmat_bytes(n: int, p: int, dtype, out_dtype=None, policy=None) -> int:
    """z[i] = op_j f(A[i,j], x[j]): A once, x re-read per row stripe."""
    from repro.kernels.ops import _pick_blocks_vecmat
    policy = policy or ki.resolve_tuning()
    sz = jnp.dtype(dtype).itemsize
    osz = jnp.dtype(out_dtype or dtype).itemsize
    ri, cj = _pick_blocks_vecmat(policy, jnp.zeros((1, 1), dtype), n, p)
    a_bytes = _pad(n, ri) * _pad(p, cj) * sz
    x_bytes = ki.cdiv(n, ri) * _pad(p, cj) * sz
    z_bytes = _pad(n, ri) * osz
    return a_bytes + x_bytes + z_bytes


def copy_bytes(n: int, dtype, nitem: int, policy=None) -> int:
    sub = ki.min_tile(dtype)[0]
    block = nitem * sub * ki.LANES
    return 2 * _pad(n, block) * jnp.dtype(dtype).itemsize


def xla_baseline_cost(fn, *args) -> dict:
    """Compile ``fn`` on the host backend and read its cost analysis."""
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}
