"""Structural HBM-traffic model for every KernelForge-TPU kernel.

Derived from the same grid/BlockSpec arithmetic the kernels use -- each input
block is transferred HBM->VMEM exactly once per grid step that maps it, and
each output block VMEM->HBM exactly once (sequential-grid revisiting keeps
the block resident).  This is the structural 2n-movement argument of the
paper's scan (§V-B) made checkable: the numbers below are what the lowered
kernel *must* move, including ragged-tail padding.

For the XLA-fallback baselines, bytes come from compiled ``cost_analysis()``
instead -- the honest CPU-only stand-in for the paper's measured vendor
baselines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import intrinsics as ki


def _pad(n, b):
    return ki.cdiv(n, b) * b


def scan_bytes(n: int, dtypes, policy=None) -> int:
    """1-D scan: exactly one read + one write per (padded) element."""
    policy = policy or ki.resolve_tuning()
    sub = max(ki.min_tile(d)[0] for d in dtypes)
    block = policy.nitem_scan * sub * ki.LANES
    np_ = _pad(n, block)
    per_elem = sum(jnp.dtype(d).itemsize for d in dtypes)
    return 2 * np_ * per_elem


def mapreduce_bytes(n: int, in_dtypes, out_dtypes, policy=None) -> int:
    """Reduce: one read per element + O(1) output."""
    policy = policy or ki.resolve_tuning()
    sub = max(ki.min_tile(d)[0] for d in in_dtypes)
    block = policy.nitem_reduce * sub * ki.LANES
    np_ = _pad(n, block)
    return np_ * sum(jnp.dtype(d).itemsize for d in in_dtypes) + \
        sum(jnp.dtype(d).itemsize for d in out_dtypes)


def matvec_bytes(n: int, p: int, dtype, out_dtype=None, policy=None) -> int:
    """y[j] = op_i f(x[i], A[i,j]): A once, x re-read per column stripe."""
    from repro.kernels.ops import _pick_blocks_matvec
    policy = policy or ki.resolve_tuning()
    sz = jnp.dtype(dtype).itemsize
    osz = jnp.dtype(out_dtype or dtype).itemsize
    if p <= 64 and n >= 4 * ki.LANES:
        # Lane-packed tall-narrow path: g row groups share the 128 lanes.
        g = max(ki.LANES // p, 1)
        ng = _pad(n, g) // g
        rn = policy.matvec_rows * ki.min_tile(dtype)[0]
        return (_pad(ng, rn) * g * p + _pad(ng, rn) * g) * sz + ki.LANES * osz
    rn, cp = _pick_blocks_matvec(policy, jnp.zeros((1, 1), dtype), n, p)
    a_bytes = _pad(n, rn) * _pad(p, cp) * sz
    x_bytes = ki.cdiv(p, cp) * _pad(n, rn) * sz       # x per column stripe
    y_bytes = _pad(p, cp) * osz
    return a_bytes + x_bytes + y_bytes


def vecmat_bytes(n: int, p: int, dtype, out_dtype=None, policy=None) -> int:
    """z[i] = op_j f(A[i,j], x[j]): A once, x re-read per row stripe."""
    from repro.kernels.ops import _pick_blocks_vecmat
    policy = policy or ki.resolve_tuning()
    sz = jnp.dtype(dtype).itemsize
    osz = jnp.dtype(out_dtype or dtype).itemsize
    ri, cj = _pick_blocks_vecmat(policy, jnp.zeros((1, 1), dtype), n, p)
    a_bytes = _pad(n, ri) * _pad(p, cj) * sz
    x_bytes = ki.cdiv(n, ri) * _pad(p, cj) * sz
    z_bytes = _pad(n, ri) * osz
    return a_bytes + x_bytes + z_bytes


def quantized_matvec_bytes(n: int, p: int, block: int = 64,
                           policy=None) -> int:
    """Quantized matvec: 1-byte A values + one f32 scale per ``block`` rows
    per column, f32 x/y.  Block picks mirror the dense f32 route with the
    row extent rounded up to whole scale blocks (kernels/ops.py), so the
    model tracks exactly what the quantized kernel streams."""
    from repro.kernels.ops import _pick_blocks_matvec
    policy = policy or ki.resolve_tuning()
    rn, cp = _pick_blocks_matvec(policy, jnp.zeros((1, 1), jnp.float32), n, p)
    rn = ki.round_up(rn, block)
    v_bytes = _pad(n, rn) * _pad(p, cp) * 1
    s_bytes = (_pad(n, rn) // block) * _pad(p, cp) * 4
    x_bytes = ki.cdiv(p, cp) * _pad(n, rn) * 4
    y_bytes = _pad(p, cp) * 4
    return v_bytes + s_bytes + x_bytes + y_bytes


def quantized_vecmat_bytes(n: int, p: int, block: int = 64,
                           policy=None) -> int:
    """Quantized vecmat: same (values + scales) streaming model with the
    vecmat stripe shape; scale blocks still tile the row axis."""
    from repro.kernels.ops import _pick_blocks_vecmat
    policy = policy or ki.resolve_tuning()
    ri, cj = _pick_blocks_vecmat(policy, jnp.zeros((1, 1), jnp.float32), n, p)
    ri = ki.round_up(ri, block)
    v_bytes = _pad(n, ri) * _pad(p, cj) * 1
    s_bytes = (_pad(n, ri) // block) * _pad(p, cj) * 4
    x_bytes = ki.cdiv(n, ri) * _pad(p, cj) * 4
    z_bytes = _pad(n, ri) * 4
    return v_bytes + s_bytes + x_bytes + z_bytes


def gpu_quantized_matvec_bytes(n: int, p: int, block: int = 64,
                               policy=None) -> int:
    """GPU two-phase quantized matvec: values + scales in, f32 partials
    round-tripped once, y out (kernels/gpu.py rounds the row strip up to
    whole scale blocks via lcm)."""
    import math
    policy = policy or ki.resolve_tuning("gpu_generic")
    rows = math.lcm(policy.matvec_rows * ki.WARP, block)
    cols = max(policy.matvec_cols * ki.vec_width(jnp.float32, flavor="gpu"),
               1)
    v_bytes = _pad(n, rows) * _pad(p, cols) * 1
    s_bytes = (_pad(n, rows) // block) * _pad(p, cols) * 4
    x_bytes = ki.cdiv(p, cols) * _pad(n, rows) * 4
    part_bytes = 2 * ki.cdiv(n, rows) * _pad(p, cols) * 4
    y_bytes = _pad(p, cols) * 4
    return v_bytes + s_bytes + x_bytes + part_bytes + y_bytes


def segmented_scan_bytes(n: int, dtypes, policy=None) -> int:
    """Segmented scan: 2n value movement + one int32 flag read per element
    (scanned flags stay in-register and are never written back)."""
    policy = policy or ki.resolve_tuning()
    sub = max(ki.min_tile(d)[0] for d in dtypes)
    block = policy.nitem_scan * sub * ki.LANES
    np_ = _pad(n, block)
    per_elem = sum(jnp.dtype(d).itemsize for d in dtypes)
    return 2 * np_ * per_elem + np_ * 4


def batched_scan_bytes(batch: int, n: int, dtypes, policy=None) -> int:
    """Batched scan: one read + one write per (padded) element of every row,
    in a single launch -- the 2*B*n element-movement bound.  Padding is per
    row (each row tiles independently on the inner grid axis)."""
    policy = policy or ki.resolve_tuning()
    sub = max(ki.min_tile(d)[0] for d in dtypes)
    block = policy.nitem_scan * sub * ki.LANES
    np_ = _pad(n, block)
    per_elem = sum(jnp.dtype(d).itemsize for d in dtypes)
    return 2 * batch * np_ * per_elem


def batched_mapreduce_bytes(batch: int, n: int, in_dtypes, out_dtypes,
                            policy=None) -> int:
    """Batched reduce: one read per element of every row + one output
    element per row."""
    policy = policy or ki.resolve_tuning()
    sub = max(ki.min_tile(d)[0] for d in in_dtypes)
    block = policy.nitem_reduce * sub * ki.LANES
    np_ = _pad(n, block)
    return batch * (np_ * sum(jnp.dtype(d).itemsize for d in in_dtypes) +
                    sum(jnp.dtype(d).itemsize for d in out_dtypes))


def batched_matvec_bytes(batch: int, n: int, p: int, dtype, out_dtype=None,
                         policy=None) -> int:
    """B independent matvecs in one launch: per-row traffic times B (the
    batch grid dimension maps disjoint blocks, so no amplification)."""
    from repro.kernels.ops import _pick_blocks_matvec
    policy = policy or ki.resolve_tuning()
    sz = jnp.dtype(dtype).itemsize
    osz = jnp.dtype(out_dtype or dtype).itemsize
    rn, cp = _pick_blocks_matvec(policy, jnp.zeros((1, 1), dtype), n, p)
    a_bytes = _pad(n, rn) * _pad(p, cp) * sz
    x_bytes = ki.cdiv(p, cp) * _pad(n, rn) * sz
    y_bytes = _pad(p, cp) * osz
    return batch * (a_bytes + x_bytes + y_bytes)


def channel_scan_bytes(batch: int, t: int, c: int, n_leaves_in: int,
                       n_leaves_out: int, dtype, policy=None) -> int:
    """(B, T, C) channelwise scan (the batched linear-recurrence layout):
    one read per input leaf element, one write per output leaf element,
    padded to the (t_rows, LANES) tile grid."""
    policy = policy or ki.resolve_tuning()
    sub = ki.min_tile(dtype)[0]
    t_rows = min(policy.nitem_scan * sub,
                 max(sub, 1 << (max(t - 1, 1)).bit_length()))
    tp = _pad(t, t_rows)
    cp_ = _pad(c, ki.LANES)
    sz = jnp.dtype(dtype).itemsize
    return batch * tp * cp_ * sz * (n_leaves_in + n_leaves_out)


# ---------------------------------------------------------------------------
# pallas-gpu routes: block = gpu_threads * nitem * vec_width (float4-style
# transactions), and the decoupled-lookback scan adds only the O(n/block)
# cross-block mailbox on top of the 2n element movement -- the single-pass
# argument of the paper's GPU scan made checkable.
# ---------------------------------------------------------------------------


def _gpu_block(policy, nitem: int, dtypes) -> int:
    vw = min(ki.vec_width(d, flavor="gpu") for d in dtypes)
    return policy.gpu_threads * nitem * vw


def gpu_scan_bytes(n: int, dtypes, policy) -> int:
    """Single-pass lookback scan: one read + one write per (padded) element,
    plus the per-block (partial, status) mailbox -- 2n + O(n/block), NOT the
    3n of scan-then-propagate or the multi-launch reduce-then-scan."""
    block = _gpu_block(policy, policy.nitem_scan, dtypes)
    np_ = _pad(n, block)
    nb = np_ // block
    per_elem = sum(jnp.dtype(d).itemsize for d in dtypes)
    # Mailbox: each block writes its inclusive partial + an int32 status
    # flag and reads its predecessor's.
    return 2 * np_ * per_elem + 2 * nb * (per_elem + 4)


def gpu_batched_scan_bytes(batch: int, n: int, dtypes, policy) -> int:
    """Per-row lookback rides the inner grid axis: B x the flat traffic."""
    return batch * gpu_scan_bytes(n, dtypes, policy)


def gpu_mapreduce_bytes(n: int, in_dtypes, out_dtypes, policy) -> int:
    """Block partials written once, folded once: n reads + 2*(n/block)."""
    block = _gpu_block(policy, policy.nitem_reduce, in_dtypes)
    np_ = _pad(n, block)
    nb = np_ // block
    out_elem = sum(jnp.dtype(d).itemsize for d in out_dtypes)
    return (np_ * sum(jnp.dtype(d).itemsize for d in in_dtypes)
            + 2 * nb * out_elem + out_elem)


def gpu_batched_mapreduce_bytes(batch: int, n: int, in_dtypes, out_dtypes,
                                policy) -> int:
    return batch * gpu_mapreduce_bytes(n, in_dtypes, out_dtypes, policy)


def gpu_matvec_bytes(n: int, p: int, dtype, out_dtype=None,
                     policy=None) -> int:
    """A once, x re-read per column stripe; two-phase partials form: each
    row strip writes its own (nbi, p) partial row (no output revisiting,
    so the kernel is exact on parallel grids), the strips fold outside the
    kernel (read back once), y written once."""
    policy = policy or ki.resolve_tuning("gpu_generic")
    sz = jnp.dtype(dtype).itemsize
    osz = jnp.dtype(out_dtype or dtype).itemsize
    rows = policy.matvec_rows * ki.WARP
    cols = max(policy.matvec_cols * ki.vec_width(dtype, flavor="gpu"), 1)
    a_bytes = _pad(n, rows) * _pad(p, cols) * sz
    x_bytes = ki.cdiv(p, cols) * _pad(n, rows) * sz
    part_bytes = 2 * ki.cdiv(n, rows) * _pad(p, cols) * osz
    y_bytes = _pad(p, cols) * osz
    return a_bytes + x_bytes + part_bytes + y_bytes


def gpu_vecmat_bytes(n: int, p: int, dtype, out_dtype=None,
                     policy=None) -> int:
    policy = policy or ki.resolve_tuning("gpu_generic")
    sz = jnp.dtype(dtype).itemsize
    osz = jnp.dtype(out_dtype or dtype).itemsize
    rows = policy.vecmat_rows * ki.WARP
    cols = max(policy.vecmat_cols * ki.vec_width(dtype, flavor="gpu"), 1)
    a_bytes = _pad(n, rows) * _pad(p, cols) * sz
    x_bytes = ki.cdiv(n, rows) * _pad(p, cols) * sz
    part_bytes = 2 * ki.cdiv(p, cols) * _pad(n, rows) * osz
    z_bytes = _pad(n, rows) * osz
    return a_bytes + x_bytes + part_bytes + z_bytes


def gpu_copy_bytes(n: int, dtype, nitem: int, policy) -> int:
    block = policy.gpu_threads * nitem * ki.vec_width(dtype, flavor="gpu")
    return 2 * _pad(n, block) * jnp.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# @sharded routes: per-DEVICE traffic of the staged plans
# (distributed/primitives.py).  Each model is local-stage bytes at the
# ceil(n/S) shard extent plus the collective stage priced off the operator's
# FoldSpec descriptor -- an all-reduce-shaped collective (psum/pmax/pmin)
# moves ~2x its payload through each device (ring send+recv), an all_gather
# lands S copies.  The strong-scaling claim these encode: local traffic is
# ~1/S of the flat route while the collective term is independent of n
# (except the sort family's documented O(n) portable gather).
# ---------------------------------------------------------------------------


def fold_bytes(collectives, payload: int, shards: int) -> int:
    """Per-device bytes of a FoldSpec's collective stage.

    ``collectives`` is the descriptor tuple from
    ``core.operators.collective_fold_spec(op).collectives`` -- the byte
    model prices exactly the collectives the staged plan will issue.
    """
    total = 0
    for c in collectives:
        total += shards * payload if c == "all_gather" else 2 * payload
    return total


def sharded_scan_bytes(n: int, dtypes, shards: int, policy=None) -> int:
    """scan@sharded per device: the local scan at ceil(n/S), the carry
    epilogue's re-read + write of the local prefix (op(carry, incl)), and
    the all-gathered per-shard totals (S elements -- O(S), not O(n))."""
    n_loc = ki.cdiv(n, shards)
    per_elem = sum(jnp.dtype(d).itemsize for d in dtypes)
    local = scan_bytes(n_loc, dtypes, policy)
    epilogue = 2 * n_loc * per_elem
    collective = fold_bytes(("all_gather",), per_elem, shards)
    return local + epilogue + collective


def sharded_mapreduce_bytes(n: int, in_dtypes, out_dtypes, shards: int,
                            collectives=("psum",), policy=None) -> int:
    """mapreduce@sharded per device: local reduce at ceil(n/S) + the
    operator's fold over the O(1) output -- pass the FoldSpec's
    ``collectives`` tuple for non-native operators (logsumexp is
    ("pmax", "psum"), the gather fallback is ("all_gather",))."""
    n_loc = ki.cdiv(n, shards)
    out_payload = sum(jnp.dtype(d).itemsize for d in out_dtypes)
    return (mapreduce_bytes(n_loc, in_dtypes, out_dtypes, policy)
            + fold_bytes(collectives, out_payload, shards))


def sharded_matvec_bytes(n: int, p: int, dtype, shards: int, out_dtype=None,
                         policy=None) -> int:
    """matvec@sharded per device: the local strip matvec over n//S rows,
    the replicated ``n % S`` remainder rows (folded in by the epilogue),
    and the ADD fold's psum of the (p,)-sized strip partial."""
    sz_out = jnp.dtype(out_dtype or dtype).itemsize
    n_loc = n // shards
    rem = n - n_loc * shards
    b = matvec_bytes(n_loc, p, dtype, out_dtype, policy) if n_loc else 0
    if rem:
        b += matvec_bytes(rem, p, dtype, out_dtype, policy)
    return b + fold_bytes(("psum",), p * sz_out, shards)


def sharded_vecmat_bytes(n: int, p: int, dtype, shards: int, out_dtype=None,
                         policy=None) -> int:
    """vecmat@sharded per device: the column-strip mirror -- p//S columns
    local, ``p % S`` replicated, psum of the (n,)-sized partial."""
    sz_out = jnp.dtype(out_dtype or dtype).itemsize
    p_loc = p // shards
    rem = p - p_loc * shards
    b = vecmat_bytes(n, p_loc, dtype, out_dtype, policy) if p_loc else 0
    if rem:
        b += vecmat_bytes(n, rem, dtype, out_dtype, policy)
    return b + fold_bytes(("psum",), n * sz_out, shards)


def sharded_channel_scan_bytes(batch: int, t: int, c: int, shards: int,
                               dtype, policy=None) -> int:
    """linear_recurrence@sharded per device: the local (B, ceil(T/S), C)
    affine scan (2 leaves in, 2 out), the gathered per-shard (A, B) totals
    (2 x (B, C) x S -- sequence-length independent), and the epilogue's
    re-read of both inclusive planes + the h write."""
    t_loc = ki.cdiv(t, shards)
    sz = jnp.dtype(dtype).itemsize
    local = channel_scan_bytes(batch, t_loc, c, 2, 2, dtype, policy)
    epilogue = 3 * batch * t_loc * c * sz
    collective = fold_bytes(("all_gather",), 2 * batch * c * sz, shards)
    return local + epilogue + collective


def sort_pass_count(key_bits: int, digit_bits: int, num_segments: int = 1) -> int:
    """LSD scatter passes: key digits, then segment-id digits (if any)."""
    passes = ki.cdiv(key_bits, digit_bits)
    if num_segments > 1:
        passes += ki.cdiv(max((num_segments - 1).bit_length(), 1), digit_bits)
    return passes


def sort_bytes(n: int, dtype, policy=None, *, key_bits: int | None = None,
               payload_itemsize: int = 0, num_segments: int = 1) -> int:
    """Structural *key-level* movement of an LSD radix pass, the fused-kernel
    bound the design targets (and the CI budget enforces):

    * keys read for the digit extract / rank scan (1n),
    * keys re-read and written by the rank-and-scatter (2n),
    * any payload read + scattered alongside (2n x payload bytes),
    * the 2^digit_bits histogram + its offsets (O(R), not O(n)).

    Honesty note: this is what a pass *must* move -- the <= passes x 3n
    budget made checkable.  A fused TPU kernel keeps the one-hot/rank tiles
    in VMEM; the current portable composition instead materializes an
    ``(n, 2^digit_bits)`` rank intermediate through XLA per pass, so its
    realized traffic exceeds this bound by up to the digit fan-out (the
    tuning ladder's ``sort_digit_bits`` races exactly that trade-off, and
    shrinking the gap is the motivation for a future fused sort pass).
    Fewer significant ``key_bits`` (small-range keys like expert ids)
    proportionally cut the pass count in both models.
    """
    policy = policy or ki.resolve_tuning()
    sz = jnp.dtype(dtype).itemsize
    kb = key_bits if key_bits is not None else 8 * sz
    passes = sort_pass_count(kb, policy.sort_digit_bits, num_segments)
    sub = ki.min_tile(dtype)[0]
    block = policy.nitem_scan * sub * ki.LANES
    np_ = _pad(n, block)
    per_pass = (3 * np_ * sz + 2 * np_ * payload_itemsize +
                2 * (1 << policy.sort_digit_bits) * 4)
    return passes * per_pass


def top_k_bytes(n: int, k: int, dtype, policy=None, *,
                num_segments: int = 1) -> int:
    """top-k = index-carrying sort + the (S, k) gather of the winners."""
    sz = jnp.dtype(dtype).itemsize
    return (sort_bytes(n, dtype, policy, payload_itemsize=4,
                       num_segments=num_segments) +
            num_segments * k * (sz + 4))


def sharded_top_k_bytes(n: int, k: int, dtype, shards: int,
                        policy=None) -> int:
    """top_k@sharded per device: local top-k over ceil(n/S), the gathered
    S x k (value, global index) candidates, and the k-way partial merge (an
    index-carrying sort of the S*k candidate pool -- O(S*k), not O(n))."""
    sz = jnp.dtype(dtype).itemsize
    n_loc = ki.cdiv(n, shards)
    cand = shards * min(k, n_loc)
    return (top_k_bytes(n_loc, min(k, n_loc), dtype, policy)
            + fold_bytes(("all_gather",), min(k, n_loc) * (sz + 4), shards)
            + sort_bytes(cand, dtype, policy, payload_itemsize=4))


def sharded_sort_pairs_bytes(n: int, dtype, shards: int, *,
                             payload_itemsize: int = 0, policy=None) -> int:
    """sort_pairs@sharded per device: the local sort of ceil(n/S), then the
    portable splitter exchange -- the gathered full stream (keys + payload,
    the documented O(n)-per-device step of the portable merge), S rank
    passes over the gathered keys, and the scattered local output slice."""
    sz = jnp.dtype(dtype).itemsize
    n_loc = ki.cdiv(n, shards)
    n_all = shards * n_loc
    local = sort_bytes(n_loc, dtype, policy, payload_itemsize=payload_itemsize)
    gather = fold_bytes(("all_gather",), n_loc * (sz + payload_itemsize),
                        shards)
    rank = shards * n_all * sz                       # searchsorted per run
    scatter = n_all * (sz + payload_itemsize)        # read-back + local write
    return local + gather + rank + scatter


def copy_bytes(n: int, dtype, nitem: int, policy=None) -> int:
    sub = ki.min_tile(dtype)[0]
    block = nitem * sub * ki.LANES
    return 2 * _pad(n, block) * jnp.dtype(dtype).itemsize


def xla_baseline_cost(fn, *args) -> dict:
    """Compile ``fn`` on the host backend and read its cost analysis."""
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}
