"""Kernel benchmarks mirroring the paper's tables (CPU-only methodology).

``--ci`` runs the bench-smoke mode used by the CI pipeline: small-size
correctness in interpret mode, then the structural HBM-bytes model for every
kernel written to a JSON artifact and checked against the checked-in
``benchmarks/budgets.json`` -- any kernel whose structural bytes grow past
its budget (e.g. the radix sort exceeding passes x 3n key movement) fails
the job.

No TPU exists in this container, so kernel *time* cannot be measured.
Instead each table reports, per configuration:

* ``ours bytes``   -- structural HBM traffic of the Pallas kernel, derived
  from its grid x BlockSpec arithmetic (benchmarks/analytic.py).  This is
  the quantity the paper's design arguments fix (scan == 2n, etc.).
* ``xla bytes``    -- "bytes accessed" of the portable XLA fallback compiled
  for this host (the stand-in for the vendor-baseline comparison).
* ``ours v5e``     -- roofline-modeled kernel time on TPU v5e
  (bytes / 819 GB/s; all these kernels are bandwidth-bound).
* ``paper A40``    -- the paper's measured kernel time (KernelForge / CUB),
  where that table row exists, with the A40->v5e bandwidth scaling shown.

Correctness of every configuration is asserted against ref.py in
interpret mode (small sizes) as part of the bench run.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import analytic as AN
from benchmarks import hardware as HW
from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched, Segmented
from repro.kernels import ref

POLICY = ki.resolve_tuning("tpu_v5e")
# GPU structural entries are keyed to one concrete chip policy so the
# budgets are deterministic (the A100 ladder point; see intrinsics.py).
GPU_POLICY = ki.resolve_tuning("gpu_a100")


def _us(s):
    return f"{s*1e6:10.1f}us"


def _check(got, want, tol=1e-3):
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=tol, atol=tol)


def bench_scan():
    print("\n== Scan (paper Table IV analogue) ==")
    print(f"{'n':>10} {'dtype':>8} {'ours bytes':>12} {'xla bytes':>12} "
          f"{'ours v5e':>12} {'paper KF A40':>13} {'paper CUB A40':>14} "
          f"{'A40->v5e scale':>14}")
    # correctness spot-check (interpret) at small n
    x = jax.random.normal(jax.random.PRNGKey(0), (3000,), jnp.float32)
    _check(forge.scan(alg.ADD, x, backend="pallas-interpret"),
           ref.ref_scan(alg.ADD, x), 1e-3)
    for n in [10**6, 10**7, 10**8]:
        for dtype, paper, paper_cub in [
                (jnp.float32, HW.PAPER_SCAN_F32, HW.PAPER_SCAN_CUB_F32),
                (jnp.float64, HW.PAPER_SCAN_F64, None)]:
            ours = AN.scan_bytes(n, [dtype], POLICY)
            spec = jax.ShapeDtypeStruct((n,), dtype)
            xla = AN.xla_baseline_cost(jnp.cumsum, spec)["bytes"]
            t = HW.modeled_time_s(ours)
            p = paper.get(n)
            pc = paper_cub.get(n) if paper_cub else None
            scale = (p * 1e-6) * (HW.A40_BW / HW.HBM_BW) if p else None
            print(f"{n:>10} {np.dtype(dtype).name:>8} {ours:>12,} "
                  f"{int(xla):>12,} {_us(t)} "
                  f"{_us(p*1e-6) if p else '    --':>13} "
                  f"{_us(pc*1e-6) if pc else '    --':>14} "
                  f"{_us(scale) if scale else '    --':>14}")
    print("note: ours==2n x itemsize (+tile padding): the paper's single-pass"
          " bound; XLA cumsum shows the multi-pass/naive bytes on this host.")


def bench_mapreduce():
    print("\n== Mapreduce (paper Table III analogue) ==")
    print(f"{'n':>10} {'type':>9} {'ours bytes':>12} {'xla bytes':>12} "
          f"{'ours v5e':>12} {'paper KF A40':>13} {'paper CUB A40':>14}")
    u = jax.random.randint(jax.random.PRNGKey(1), (4096,), 0, 255, jnp.int32
                           ).astype(jnp.uint8)
    _check(forge.mapreduce(alg.unitfloat8_decode, alg.ADD, u,
                           backend="pallas-interpret"),
           ref.ref_mapreduce(alg.unitfloat8_decode, alg.ADD, u), 1e-2)
    for n in [10**6, 10**7, 10**8]:
        rows = [
            ("f32", jnp.float32, jnp.float32, HW.PAPER_MR_F32[n],
             HW.PAPER_MR_CUB_F32[n]),
            ("uf8->f32", jnp.uint8, jnp.float32, HW.PAPER_MR_UF8[n],
             HW.PAPER_MR_CUB_U8[n]),
        ]
        for name, din, dout, p, pc in rows:
            ours = AN.mapreduce_bytes(n, [din], [dout], POLICY)
            spec = jax.ShapeDtypeStruct((n,), din)
            xla = AN.xla_baseline_cost(
                lambda v: jnp.sum(v.astype(jnp.float32)), spec)["bytes"]
            t = HW.modeled_time_s(ours)
            print(f"{n:>10} {name:>9} {ours:>12,} {int(xla):>12,} "
                  f"{_us(t)} {_us(p*1e-6):>13} {_us(pc*1e-6):>14}")
    print("note: UnitFloat8 promotion is free at the bandwidth limit -- the "
          "uint8 rows move 4x fewer bytes than f32 at equal n (paper §VII-B).")


def bench_matvec():
    print("\n== MatVec / VecMat (paper Tables V & VI analogue) ==")
    print(f"{'n':>9} {'p':>9} {'orient':>7} {'ours bytes':>14} "
          f"{'xla bytes':>14} {'ours v5e':>12} {'xla v5e':>12}")
    A = jax.random.normal(jax.random.PRNGKey(2), (257, 129), jnp.float32)
    xv = jax.random.normal(jax.random.PRNGKey(3), (257,), jnp.float32)
    _check(forge.semiring_matvec(alg.ARITHMETIC, A, xv,
                                 backend="pallas-interpret"),
           ref.ref_matvec(alg.ARITHMETIC.f, alg.ADD, A, xv), 1e-3)
    shapes = [(10**3, 10**4), (10**4, 10**3), (10, 10**6), (10**6, 10),
              (10**4, 10**4)]
    for n, p in shapes:
        for orient in ("matvec", "vecmat"):
            if orient == "matvec":
                ours = AN.matvec_bytes(n, p, jnp.float32, policy=POLICY)
                sa = jax.ShapeDtypeStruct((n, p), jnp.float32)
                sx = jax.ShapeDtypeStruct((n,), jnp.float32)
                xla = AN.xla_baseline_cost(
                    lambda a, v: jnp.einsum("np,n->p", a, v), sa, sx)["bytes"]
            else:
                ours = AN.vecmat_bytes(n, p, jnp.float32, policy=POLICY)
                sa = jax.ShapeDtypeStruct((n, p), jnp.float32)
                sx = jax.ShapeDtypeStruct((p,), jnp.float32)
                xla = AN.xla_baseline_cost(
                    lambda a, v: jnp.einsum("np,p->n", a, v), sa, sx)["bytes"]
            flops = 2.0 * n * p
            t_ours = HW.modeled_time_s(ours, flops)
            t_xla = HW.modeled_time_s(xla, flops)
            print(f"{n:>9} {p:>9} {orient:>7} {int(ours):>14,} "
                  f"{int(xla):>14,} {_us(t_ours)} {_us(t_xla)}")
    print("note: both orientations move ~n*p + n + p elements; the paper's "
          "tall/wide strategies appear here as block-shape choices "
          "(ops.py _pick_blocks_*), not extra traffic.")


def bench_copy():
    print("\n== Copy bandwidth ceiling (paper Fig. 1 analogue) ==")
    print(f"{'n':>10} {'nitem':>6} {'bytes':>14} {'v5e time':>12} "
          f"{'eff. fraction':>14}")
    x = jax.random.normal(jax.random.PRNGKey(4), (100000,), jnp.float32)
    got = forge.copy(x, backend="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
    n = 10**8
    ideal = 2 * n * 4
    for nitem in [1, 4, 8, 16]:
        b = AN.copy_bytes(n, jnp.float32, nitem)
        t = HW.modeled_time_s(b)
        print(f"{n:>10} {nitem:>6} {b:>14,} {_us(t)} {ideal/b:>13.3f}")
    print("note: tile padding overhead shrinks as blocks grow; on real "
          "hardware larger Nitem additionally amortizes grid/DMA issue "
          "overhead (the quantity Fig. 1 sweeps).")


def bench_sort():
    print("\n== Radix sort / top-k (CUB's flagship derived primitive) ==")
    print(f"{'n':>10} {'dtype':>8} {'passes':>6} {'ours bytes':>14} "
          f"{'xla bytes':>14} {'ours v5e':>12}")
    # correctness spot-check (interpret) at small n, floats with specials
    x = jax.random.normal(jax.random.PRNGKey(8), (140,), jnp.float32)
    x = x.at[3].set(jnp.nan).at[9].set(-jnp.inf).at[11].set(-0.0)
    _check_exact(forge.argsort(x, backend="pallas-interpret"),
                 ref.ref_argsort(x))
    u = jax.random.randint(jax.random.PRNGKey(9), (300,), 0, 256, jnp.int32
                           ).astype(jnp.uint8)
    _check_exact(forge.sort(u, backend="pallas-interpret"), ref.ref_sort(u))
    for n in [10**6, 10**7, 10**8]:
        for dtype in (jnp.uint32, jnp.float32):
            passes = AN.sort_pass_count(8 * jnp.dtype(dtype).itemsize,
                                        POLICY.sort_digit_bits)
            ours = AN.sort_bytes(n, dtype, POLICY)
            spec = jax.ShapeDtypeStruct((n,), dtype)
            xla = AN.xla_baseline_cost(jnp.sort, spec)["bytes"]
            t = HW.modeled_time_s(ours)
            print(f"{n:>10} {np.dtype(dtype).name:>8} {passes:>6} "
                  f"{int(ours):>14,} {int(xla):>14,} {_us(t)}")
    print("note: ours==passes x 3n key movement -- the fused-kernel bound "
          "the budget enforces (CUB onesweep moves ~2n/pass); the portable "
          "composition additionally materializes the (n, 2^digit) rank "
          "intermediate (see analytic.sort_bytes).  Small-range keys cut "
          "passes via key_bits= -- MoE expert ids pay 1 pass, not 4.")


def _check_exact(got, want):
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def bench_batched():
    print("\n== Batched family (decode hot path: one launch per batch) ==")
    print(f"{'B':>6} {'n':>9} {'kind':>10} {'ours bytes':>14} "
          f"{'per-row x B':>14} {'ours v5e':>12}")
    # correctness spot-check (interpret) at small sizes
    key = jax.random.PRNGKey(10)
    x = jax.random.normal(key, (4, 300), jnp.float32)
    _check(forge.scan(alg.ADD, x, layout=Batched(),
                      backend="pallas-interpret"),
           ref.ref_batched_scan(alg.ADD, x), 1e-3)
    _check(forge.mapreduce(lambda v: v, alg.ADD, x, layout=Batched(),
                           backend="pallas-interpret"),
           ref.ref_batched_mapreduce(lambda v: v, alg.ADD, x), 1e-3)
    for Bn, n, kind in [(64, 16384, "scan"), (256, 4096, "scan"),
                        (64, 16384, "mapreduce"), (64, 4096, "matvec")]:
        if kind == "scan":
            ours = AN.batched_scan_bytes(Bn, n, [jnp.float32], POLICY)
            per_row = Bn * AN.scan_bytes(n, [jnp.float32], POLICY)
        elif kind == "mapreduce":
            ours = AN.batched_mapreduce_bytes(Bn, n, [jnp.float32],
                                              [jnp.float32], POLICY)
            per_row = Bn * AN.mapreduce_bytes(n, [jnp.float32],
                                              [jnp.float32], POLICY)
        else:
            ours = AN.batched_matvec_bytes(Bn, n, 128, jnp.float32,
                                           policy=POLICY)
            per_row = Bn * AN.matvec_bytes(n, 128, jnp.float32, policy=POLICY)
        t = HW.modeled_time_s(ours)
        print(f"{Bn:>6} {n:>9} {kind:>10} {int(ours):>14,} "
              f"{int(per_row):>14,} {_us(t)}")
    print("note: bytes match B x the per-row model -- batching costs nothing "
          "in traffic; what it removes is B-1 kernel launches and B-1 "
          "tuning lookups per step (the dispatch amplification the batched "
          "family exists to kill).")


def bench_semiring():
    print("\n== Arbitrary types & operators (paper's generality claims) ==")
    t0 = time.time()
    # Tropical shortest-path step: d' = min_i (d_i + W[i,j]).
    W = jax.random.uniform(jax.random.PRNGKey(5), (128, 128), jnp.float32)
    d = jax.random.uniform(jax.random.PRNGKey(6), (128,), jnp.float32)
    got = forge.semiring_matvec(alg.TROPICAL_MIN_PLUS, W, d,
                                backend="pallas-interpret")
    want = ref.ref_matvec(alg.TROPICAL_MIN_PLUS.f, alg.MIN, W, d)
    _check(got, want, 1e-4)
    print("tropical (min,+) matvec 128x128: OK (shortest-path relaxation)")
    # Log-space accumulation.
    got = forge.semiring_vecmat(alg.LOG_SEMIRING, W, d,
                                backend="pallas-interpret")
    want = ref.ref_vecmat(alg.LOG_SEMIRING.f, alg.LOGSUMEXP, W, d)
    _check(got, want, 1e-4)
    print("log-semiring vecmat 128x128: OK (stable likelihood accumulation)")
    # Non-commutative quaternion scan (composite struct type).
    q = tuple(jax.random.normal(jax.random.PRNGKey(7 + i), (1000,),
                                jnp.float32) * 0.1 + (1.0 if i == 0 else 0.0)
              for i in range(4))
    got = forge.scan(alg.QUATERNION_MUL, q, backend="pallas-interpret")
    want = ref.ref_scan(alg.QUATERNION_MUL, q)
    _check(got, want, 1e-2)
    print("quaternion-product scan n=1000: OK (non-commutative struct type)")
    # Affine recurrence (the model-stack workhorse).
    a = jax.random.uniform(jax.random.PRNGKey(11), (4, 64, 256), jnp.float32,
                           0.5, 1.0)
    b = jax.random.normal(jax.random.PRNGKey(12), (4, 64, 256), jnp.float32)
    _check(forge.linear_recurrence(a, b, backend="pallas-interpret"),
           ref.ref_linear_recurrence(a, b), 1e-3)
    print("affine linear recurrence (4,64,256): OK (RG-LRU/mLSTM layout)")
    print(f"(semiring correctness suite: {time.time()-t0:.1f}s interpret)")


# ---------------------------------------------------------------------------
# bench-smoke CI mode: structural-bytes regression gate.
# ---------------------------------------------------------------------------


def ci_structural_entries() -> dict:
    """Structural HBM bytes per kernel configuration under the v5e policy.

    Pure shape arithmetic (benchmarks/analytic.py) -- nothing here allocates
    or compiles at these sizes, so the entries are exact and deterministic,
    which is what makes them CI-enforceable.
    """
    N = 10**6
    f32, bf16, u8, u32 = jnp.float32, jnp.bfloat16, jnp.uint8, jnp.uint32
    e = {
        "copy@flat/float32/n=1e6": AN.copy_bytes(N, f32, POLICY.nitem_copy),
        "scan@flat/float32/n=1e6": AN.scan_bytes(N, [f32], POLICY),
        "scan@flat/bfloat16/n=1e6": AN.scan_bytes(N, [bf16], POLICY),
        "mapreduce@flat/float32/n=1e6":
            AN.mapreduce_bytes(N, [f32], [f32], POLICY),
        "mapreduce@flat/uint8/n=1e6":
            AN.mapreduce_bytes(N, [u8], [f32], POLICY),
        "scan@segmented/float32/n=1e6":
            AN.segmented_scan_bytes(N, [f32], POLICY),
        "matvec@flat/float32/1e3x1e4": AN.matvec_bytes(10**3, 10**4, f32,
                                                       policy=POLICY),
        "vecmat@flat/float32/1e4x1e3": AN.vecmat_bytes(10**4, 10**3, f32,
                                                       policy=POLICY),
        "sort@flat/uint8/n=1e6": AN.sort_bytes(N, u8, POLICY),
        "sort@flat/uint32/n=1e6": AN.sort_bytes(N, u32, POLICY),
        "sort@flat/float32/n=1e6": AN.sort_bytes(N, f32, POLICY),
        "sort@flat/bfloat16/n=1e6": AN.sort_bytes(N, bf16, POLICY),
        "sort@flat/uint32/n=1e6/key_bits=8": AN.sort_bytes(N, u32, POLICY,
                                                           key_bits=8),
        "sort_pairs@flat/float32+8B/n=1e6": AN.sort_bytes(
            N, f32, POLICY, payload_itemsize=8),
        "argsort@flat/float32/n=1e6": AN.sort_bytes(N, f32, POLICY,
                                                    payload_itemsize=4),
        "top_k@flat/float32/n=1e6/k=64": AN.top_k_bytes(N, 64, f32, POLICY),
        "sort@segmented/float32/n=1e6/S=64":
            AN.sort_bytes(N, f32, POLICY, num_segments=64),
        "top_k@segmented/float32/n=1e6/S=64/k=8":
            AN.top_k_bytes(N, 8, f32, POLICY, num_segments=64),
        # Batched family: <= 2*B*n element movement (scan), single launch.
        "scan@batched/float32/B=64xn=16384":
            AN.batched_scan_bytes(64, 16384, [f32], POLICY),
        "scan@batched/bfloat16/B=128xn=32768":
            AN.batched_scan_bytes(128, 32768, [bf16], POLICY),
        "mapreduce@batched/float32/B=64xn=16384":
            AN.batched_mapreduce_bytes(64, 16384, [f32], [f32], POLICY),
        "matvec@batched/float32/B=64x4096x128":
            AN.batched_matvec_bytes(64, 4096, 128, f32, policy=POLICY),
        "linear_recurrence@batched/float32/B=64xT=4096xC=256":
            AN.channel_scan_bytes(64, 4096, 256, 2, 2, f32, POLICY),
        # pallas-gpu routes (gpu_a100 policy).  The scan entries encode the
        # single-pass decoupled-lookback bound: 2n element movement plus
        # only the O(n/block) cross-block mailbox -- NOT the 3n of
        # scan-then-propagate.
        "copy@flat/pallas-gpu/float32/n=1e6":
            AN.gpu_copy_bytes(N, f32, GPU_POLICY.nitem_copy, GPU_POLICY),
        "scan@flat/pallas-gpu/float32/n=1e6":
            AN.gpu_scan_bytes(N, [f32], GPU_POLICY),
        "scan@flat/pallas-gpu/bfloat16/n=1e6":
            AN.gpu_scan_bytes(N, [bf16], GPU_POLICY),
        "scan@batched/pallas-gpu/float32/B=64xn=16384":
            AN.gpu_batched_scan_bytes(64, 16384, [f32], GPU_POLICY),
        "mapreduce@flat/pallas-gpu/float32/n=1e6":
            AN.gpu_mapreduce_bytes(N, [f32], [f32], GPU_POLICY),
        "mapreduce@flat/pallas-gpu/uint8/n=1e6":
            AN.gpu_mapreduce_bytes(N, [u8], [f32], GPU_POLICY),
        "mapreduce@batched/pallas-gpu/float32/B=64xn=16384":
            AN.gpu_batched_mapreduce_bytes(64, 16384, [f32], [f32],
                                           GPU_POLICY),
        "matvec@flat/pallas-gpu/float32/1e3x1e4":
            AN.gpu_matvec_bytes(10**3, 10**4, f32, policy=GPU_POLICY),
        "vecmat@flat/pallas-gpu/float32/1e4x1e3":
            AN.gpu_vecmat_bytes(10**4, 10**3, f32, policy=GPU_POLICY),
        # Quantized operand routes: 1-byte values + per-block f32 scales
        # (int8 and fp8 share byte structure -- both store 1B/element).
        # bf16 comparator at the same shape so the traffic win is a gated
        # ratio, not a prose claim.
        "matvec@flat/bfloat16/1e3x1e4": AN.matvec_bytes(10**3, 10**4, bf16,
                                                        policy=POLICY),
        "matvec@flat/int8q64/1e3x1e4":
            AN.quantized_matvec_bytes(10**3, 10**4, block=64, policy=POLICY),
        "matvec@flat/fp8_e4m3q64/1e3x1e4":
            AN.quantized_matvec_bytes(10**3, 10**4, block=64, policy=POLICY),
        "vecmat@flat/int8q64/1e4x1e3":
            AN.quantized_vecmat_bytes(10**4, 10**3, block=64, policy=POLICY),
        "matvec@flat/pallas-gpu/int8q64/1e3x1e4":
            AN.gpu_quantized_matvec_bytes(10**3, 10**4, block=64,
                                          policy=GPU_POLICY),
    }
    # @sharded routes: per-DEVICE traffic of the staged plans at S=8 --
    # local stage at ceil(n/S) + the collective stage priced off each
    # operator's FoldSpec descriptor (analytic.fold_bytes).  The logsumexp
    # mapreduce leg pins a rewrite fold (pmax + psum, NOT the all_gather
    # fallback) staying O(1) in n.
    S8 = 8
    from repro.core import operators as _alg
    e.update({
        "scan@sharded/float32/n=1e6/s=8":
            AN.sharded_scan_bytes(N, [f32], S8, POLICY),
        "mapreduce@sharded/float32/n=1e6/s=8":
            AN.sharded_mapreduce_bytes(
                N, [f32], [f32], S8,
                collectives=_alg.collective_fold_spec(_alg.ADD).collectives,
                policy=POLICY),
        "mapreduce@sharded/logsumexp/float32/n=1e6/s=8":
            AN.sharded_mapreduce_bytes(
                N, [f32], [f32], S8,
                collectives=_alg.collective_fold_spec(
                    _alg.LOGSUMEXP).collectives,
                policy=POLICY),
        "matvec@sharded/float32/1e3x1e4/s=8":
            AN.sharded_matvec_bytes(10**3, 10**4, f32, S8, policy=POLICY),
        "vecmat@sharded/float32/1e4x1e3/s=8":
            AN.sharded_vecmat_bytes(10**4, 10**3, f32, S8, policy=POLICY),
        "linear_recurrence@sharded/float32/B=8xT=32768xC=256/s=8":
            AN.sharded_channel_scan_bytes(8, 32768, 256, S8, f32, POLICY),
        "top_k@sharded/float32/n=1e6/k=64/s=8":
            AN.sharded_top_k_bytes(N, 64, f32, S8, POLICY),
        "sort_pairs@sharded/float32+8B/n=1e6/s=8":
            AN.sharded_sort_pairs_bytes(N, f32, S8, payload_itemsize=8,
                                        policy=POLICY),
    })
    # Strong-scaling gates: per-device traffic of a sharded route must sit
    # well under the flat route's (the local slice shrinks 1/S; the
    # collective term must not scale with n).
    assert (6 * e["matvec@sharded/float32/1e3x1e4/s=8"]
            <= e["matvec@flat/float32/1e3x1e4"]), \
        "matvec@sharded lost its ~1/S per-device traffic"
    assert (3 * e["mapreduce@sharded/logsumexp/float32/n=1e6/s=8"]
            <= e["mapreduce@flat/float32/n=1e6"]), \
        "logsumexp fold stopped being O(1) -- gather fallback crept in?"
    # ~2n: element movement + tile padding + the O(n/block) mailbox, with
    # a 5% structural allowance -- far below the 3n of a two-pass scan.
    assert e["scan@flat/pallas-gpu/float32/n=1e6"] <= int(2.1 * N * 4), \
        "gpu scan lost its single-pass ~2n bound"
    # The quantized route's reason to exist: at the decode-GEMV shape its
    # streamed bytes must be well under the bf16 route's (the ISSUE-8
    # acceptance bound of 0.55x; values shrink 4->1 byte, scales add back
    # ~1/block of an f32 plane).
    assert (e["matvec@flat/int8q64/1e3x1e4"]
            <= 0.55 * e["matvec@flat/bfloat16/1e3x1e4"]), \
        "int8 quantized matvec lost its <=0.55x-of-bf16 byte bound"
    return {k: int(v) for k, v in e.items()}


def ci_correctness():
    """Small-size interpret-mode correctness sweep (real kernel bodies)."""
    t0 = time.time()
    B = "pallas-interpret"
    x = jax.random.normal(jax.random.PRNGKey(0), (3000,), jnp.float32)
    _check(forge.scan(alg.ADD, x, backend=B), ref.ref_scan(alg.ADD, x), 1e-3)
    u = jax.random.randint(jax.random.PRNGKey(1), (4096,), 0, 255, jnp.int32
                           ).astype(jnp.uint8)
    _check(forge.mapreduce(alg.unitfloat8_decode, alg.ADD, u, backend=B),
           ref.ref_mapreduce(alg.unitfloat8_decode, alg.ADD, u), 1e-2)
    offs = jnp.asarray([0, 100, 100, 2500, 3000], jnp.int32)
    _check(forge.scan(alg.ADD, x[:3000], layout=Segmented(offsets=offs),
                      backend=B),
           ref.ref_segmented_scan(alg.ADD, x[:3000],
                                  offsets=np.asarray(offs)), 1e-3)
    ks = jax.random.normal(jax.random.PRNGKey(2), (140,), jnp.float32)
    ks = ks.at[3].set(jnp.nan).at[9].set(-jnp.inf).at[11].set(-0.0)
    _check_exact(forge.argsort(ks, backend=B), ref.ref_argsort(ks))
    ku = jax.random.randint(jax.random.PRNGKey(3), (300,), 0, 256, jnp.int32
                            ).astype(jnp.uint8)
    _check_exact(forge.sort(ku, backend=B), ref.ref_sort(ku))
    v, i = forge.top_k(ks, 4,
                       layout=Segmented(offsets=jnp.asarray([0, 5, 5, 140])),
                       backend=B)
    rv, ri = ref.ref_segmented_top_k(ks, 4, offsets=[0, 5, 5, 140])
    for a, b in zip(jax.tree.leaves((v, i)), jax.tree.leaves((rv, ri))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   equal_nan=True)
    # Batched family: the kernels being budgeted must work, including the
    # non-commutative (order-preserving) route and the block-boundary tail.
    xb = jax.random.normal(jax.random.PRNGKey(4), (3, 2049), jnp.float32)
    _check(forge.scan(alg.ADD, xb, layout=Batched(), backend=B),
           ref.ref_batched_scan(alg.ADD, xb), 1e-3)
    _check(forge.mapreduce(lambda v_: v_, alg.ADD, xb, layout=Batched(),
                           backend=B),
           ref.ref_batched_mapreduce(lambda v_: v_, alg.ADD, xb), 1e-3)
    Ab = jax.random.normal(jax.random.PRNGKey(5), (2, 33, 17), jnp.float32)
    vb = jax.random.normal(jax.random.PRNGKey(6), (2, 33), jnp.float32)
    _check(forge.matvec(lambda xv, av: xv * av, alg.ADD, Ab, vb,
                        layout=Batched(), backend=B),
           ref.ref_batched_matvec(lambda xv, av: xv * av, alg.ADD, Ab, vb),
           1e-3)
    ab = jax.random.uniform(jax.random.PRNGKey(7), (2, 37, 130), jnp.float32,
                            0.5, 1.0)
    bb = jax.random.normal(jax.random.PRNGKey(8), (2, 37, 130), jnp.float32)
    _check(forge.linear_recurrence(ab, bb, layout=Batched(), backend=B),
           ref.ref_batched_linear_recurrence(ab, bb), 1e-3)
    # pallas-gpu kernel bodies under interpret mode: the lookback scan
    # crossing a block boundary, the partials-fold reduce, and the radix
    # composition riding both.
    G = "pallas-gpu"
    _check(forge.scan(alg.ADD, x, backend=G), ref.ref_scan(alg.ADD, x), 1e-3)
    _check(forge.scan(alg.ADD, xb, layout=Batched(), backend=G),
           ref.ref_batched_scan(alg.ADD, xb), 1e-3)
    _check(forge.mapreduce(alg.unitfloat8_decode, alg.ADD, u, backend=G),
           ref.ref_mapreduce(alg.unitfloat8_decode, alg.ADD, u), 1e-2)
    _check_exact(forge.sort(ku, backend=G), ref.ref_sort(ku))
    _check(forge.matvec(lambda xv, av: xv * av, alg.ADD, Ab[0], vb[0],
                        backend=G),
           ref.ref_matvec(lambda xv, av: xv * av, alg.ADD, Ab[0], vb[0]),
           1e-3)
    # Quantized operand legs: the budgeted int8/fp8 routes must dequantize
    # in-kernel to the same result as the dense reference on the *decoded*
    # matrix (tight check), on both kernel families.
    Aq = jax.random.normal(jax.random.PRNGKey(9), (65, 17), jnp.float32)
    vq = jax.random.normal(jax.random.PRNGKey(10), (65,), jnp.float32)
    for mode in ("int8", "fp8_e4m3"):
        q = alg.quantize(Aq, mode=mode, block=32)
        dec = q.dequantize()
        for be in (B, G):
            _check(forge.matvec(lambda xv, av: xv * av, alg.ADD, q, vq,
                                backend=be),
                   ref.ref_matvec(lambda xv, av: xv * av, alg.ADD, dec, vq),
                   1e-3)
            _check(forge.vecmat(lambda av, xv: av * xv, alg.ADD, q,
                                vq[:17], backend=be),
                   ref.ref_vecmat(lambda av, xv: av * xv, alg.ADD, dec,
                                  vq[:17]),
                   1e-3)
    qb = alg.quantize(Ab, mode="int8", block=16)
    _check(forge.matvec(lambda xv, av: xv * av, alg.ADD, qb, vb,
                        layout=Batched(), backend=B),
           ref.ref_batched_matvec(lambda xv, av: xv * av, alg.ADD,
                                  qb.dequantize(), vb),
           1e-3)
    print(f"ci correctness (interpret, small sizes): OK "
          f"({time.time()-t0:.1f}s)")


def validate_budget_keys(budgets: dict, budgets_path: str) -> list[str]:
    """Budget keys must be ``primitive@layout/config`` naming a registry
    route.  The pre-layout spellings (``segmented_scan/...``, bare
    ``scan/...``) were canonicalized "for one release" after the layout
    redesign; that release has shipped, so an unknown or legacy-format key
    is now a **hard CI error** -- a silently tolerated spelling is a budget
    entry that silently stops being enforced.
    """
    errors = []
    routes = ki.route_keys()
    for key in budgets:
        prim, sep, rest = key.partition("/")
        if "@" not in prim:
            errors.append(
                f"{key!r}: legacy pre-layout key format -- rename it to its "
                f"primitive@layout spelling in {budgets_path}")
        elif prim not in routes:
            errors.append(
                f"{key!r}: {prim!r} names no PrimitiveDef registry route "
                f"(known: {', '.join(sorted(routes))})")
        elif not sep or not rest:
            errors.append(f"{key!r}: missing the /config suffix")
    return errors


def run_ci(out_path: str, budgets_path: str | None) -> int:
    ci_correctness()
    entries = ci_structural_entries()
    payload = {"policy": POLICY.name, "entries": entries}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"wrote {out_path} ({len(entries)} entries)")
    if budgets_path is None:
        return 0
    with open(budgets_path) as f:
        budgets = json.load(f)["entries"]
    key_errors = validate_budget_keys(budgets, budgets_path)
    if key_errors:
        print("\nBUDGET KEY FORMAT ERRORS:")
        for line in key_errors:
            print(f"  FAIL {line}")
        return 1
    failures = []
    for key, got in sorted(entries.items()):
        budget = budgets.get(key)
        if budget is None:
            failures.append(f"{key}: no budget -- add it to {budgets_path}")
        elif got > budget:
            failures.append(f"{key}: {got:,} bytes > budget {budget:,} "
                            f"(+{100.0 * (got - budget) / budget:.1f}%)")
        else:
            print(f"  ok {key}: {got:,} <= {budget:,}")
    for key in sorted(set(budgets) - set(entries)):
        failures.append(f"{key}: budgeted kernel no longer benchmarked")
    if failures:
        print("\nSTRUCTURAL BYTES REGRESSION:")
        for line in failures:
            print(f"  FAIL {line}")
        return 1
    print("all structural budgets hold")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="bench-smoke mode: small-size correctness + "
                         "structural-bytes budget enforcement")
    ap.add_argument("--out", default="BENCH_ci.json",
                    help="where --ci writes the structural-bytes artifact")
    ap.add_argument("--budgets", default=None,
                    help="budgets JSON to enforce (omit to only emit)")
    args = ap.parse_args(argv)
    if args.ci:
        sys.exit(run_ci(args.out, args.budgets))
    bench_copy()
    bench_scan()
    bench_mapreduce()
    bench_matvec()
    bench_batched()
    bench_sort()
    bench_semiring()


if __name__ == "__main__":
    main()
