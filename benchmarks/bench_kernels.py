"""Kernel benchmarks mirroring the paper's tables (CPU-only methodology).

No TPU exists in this container, so kernel *time* cannot be measured.
Instead each table reports, per configuration:

* ``ours bytes``   -- structural HBM traffic of the Pallas kernel, derived
  from its grid x BlockSpec arithmetic (benchmarks/analytic.py).  This is
  the quantity the paper's design arguments fix (scan == 2n, etc.).
* ``xla bytes``    -- "bytes accessed" of the portable XLA fallback compiled
  for this host (the stand-in for the vendor-baseline comparison).
* ``ours v5e``     -- roofline-modeled kernel time on TPU v5e
  (bytes / 819 GB/s; all these kernels are bandwidth-bound).
* ``paper A40``    -- the paper's measured kernel time (KernelForge / CUB),
  where that table row exists, with the A40->v5e bandwidth scaling shown.

Correctness of every configuration is asserted against ref.py in
interpret mode (small sizes) as part of the bench run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import analytic as AN
from benchmarks import hardware as HW
from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.core import primitives as forge
from repro.kernels import ref

POLICY = ki.resolve_tuning("tpu_v5e")


def _us(s):
    return f"{s*1e6:10.1f}us"


def _check(got, want, tol=1e-3):
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=tol, atol=tol)


def bench_scan():
    print("\n== Scan (paper Table IV analogue) ==")
    print(f"{'n':>10} {'dtype':>8} {'ours bytes':>12} {'xla bytes':>12} "
          f"{'ours v5e':>12} {'paper KF A40':>13} {'paper CUB A40':>14} "
          f"{'A40->v5e scale':>14}")
    # correctness spot-check (interpret) at small n
    x = jax.random.normal(jax.random.PRNGKey(0), (3000,), jnp.float32)
    _check(forge.scan(alg.ADD, x, backend="pallas-interpret"),
           ref.ref_scan(alg.ADD, x), 1e-3)
    for n in [10**6, 10**7, 10**8]:
        for dtype, paper, paper_cub in [
                (jnp.float32, HW.PAPER_SCAN_F32, HW.PAPER_SCAN_CUB_F32),
                (jnp.float64, HW.PAPER_SCAN_F64, None)]:
            ours = AN.scan_bytes(n, [dtype], POLICY)
            spec = jax.ShapeDtypeStruct((n,), dtype)
            xla = AN.xla_baseline_cost(jnp.cumsum, spec)["bytes"]
            t = HW.modeled_time_s(ours)
            p = paper.get(n)
            pc = paper_cub.get(n) if paper_cub else None
            scale = (p * 1e-6) * (HW.A40_BW / HW.HBM_BW) if p else None
            print(f"{n:>10} {np.dtype(dtype).name:>8} {ours:>12,} "
                  f"{int(xla):>12,} {_us(t)} "
                  f"{_us(p*1e-6) if p else '    --':>13} "
                  f"{_us(pc*1e-6) if pc else '    --':>14} "
                  f"{_us(scale) if scale else '    --':>14}")
    print("note: ours==2n x itemsize (+tile padding): the paper's single-pass"
          " bound; XLA cumsum shows the multi-pass/naive bytes on this host.")


def bench_mapreduce():
    print("\n== Mapreduce (paper Table III analogue) ==")
    print(f"{'n':>10} {'type':>9} {'ours bytes':>12} {'xla bytes':>12} "
          f"{'ours v5e':>12} {'paper KF A40':>13} {'paper CUB A40':>14}")
    u = jax.random.randint(jax.random.PRNGKey(1), (4096,), 0, 255, jnp.int32
                           ).astype(jnp.uint8)
    _check(forge.mapreduce(alg.unitfloat8_decode, alg.ADD, u,
                           backend="pallas-interpret"),
           ref.ref_mapreduce(alg.unitfloat8_decode, alg.ADD, u), 1e-2)
    for n in [10**6, 10**7, 10**8]:
        rows = [
            ("f32", jnp.float32, jnp.float32, HW.PAPER_MR_F32[n],
             HW.PAPER_MR_CUB_F32[n]),
            ("uf8->f32", jnp.uint8, jnp.float32, HW.PAPER_MR_UF8[n],
             HW.PAPER_MR_CUB_U8[n]),
        ]
        for name, din, dout, p, pc in rows:
            ours = AN.mapreduce_bytes(n, [din], [dout], POLICY)
            spec = jax.ShapeDtypeStruct((n,), din)
            xla = AN.xla_baseline_cost(
                lambda v: jnp.sum(v.astype(jnp.float32)), spec)["bytes"]
            t = HW.modeled_time_s(ours)
            print(f"{n:>10} {name:>9} {ours:>12,} {int(xla):>12,} "
                  f"{_us(t)} {_us(p*1e-6):>13} {_us(pc*1e-6):>14}")
    print("note: UnitFloat8 promotion is free at the bandwidth limit -- the "
          "uint8 rows move 4x fewer bytes than f32 at equal n (paper §VII-B).")


def bench_matvec():
    print("\n== MatVec / VecMat (paper Tables V & VI analogue) ==")
    print(f"{'n':>9} {'p':>9} {'orient':>7} {'ours bytes':>14} "
          f"{'xla bytes':>14} {'ours v5e':>12} {'xla v5e':>12}")
    A = jax.random.normal(jax.random.PRNGKey(2), (257, 129), jnp.float32)
    xv = jax.random.normal(jax.random.PRNGKey(3), (257,), jnp.float32)
    _check(forge.semiring_matvec(alg.ARITHMETIC, A, xv,
                                 backend="pallas-interpret"),
           ref.ref_matvec(alg.ARITHMETIC.f, alg.ADD, A, xv), 1e-3)
    shapes = [(10**3, 10**4), (10**4, 10**3), (10, 10**6), (10**6, 10),
              (10**4, 10**4)]
    for n, p in shapes:
        for orient in ("matvec", "vecmat"):
            if orient == "matvec":
                ours = AN.matvec_bytes(n, p, jnp.float32, policy=POLICY)
                sa = jax.ShapeDtypeStruct((n, p), jnp.float32)
                sx = jax.ShapeDtypeStruct((n,), jnp.float32)
                xla = AN.xla_baseline_cost(
                    lambda a, v: jnp.einsum("np,n->p", a, v), sa, sx)["bytes"]
            else:
                ours = AN.vecmat_bytes(n, p, jnp.float32, policy=POLICY)
                sa = jax.ShapeDtypeStruct((n, p), jnp.float32)
                sx = jax.ShapeDtypeStruct((p,), jnp.float32)
                xla = AN.xla_baseline_cost(
                    lambda a, v: jnp.einsum("np,p->n", a, v), sa, sx)["bytes"]
            flops = 2.0 * n * p
            t_ours = HW.modeled_time_s(ours, flops)
            t_xla = HW.modeled_time_s(xla, flops)
            print(f"{n:>9} {p:>9} {orient:>7} {int(ours):>14,} "
                  f"{int(xla):>14,} {_us(t_ours)} {_us(t_xla)}")
    print("note: both orientations move ~n*p + n + p elements; the paper's "
          "tall/wide strategies appear here as block-shape choices "
          "(ops.py _pick_blocks_*), not extra traffic.")


def bench_copy():
    print("\n== Copy bandwidth ceiling (paper Fig. 1 analogue) ==")
    print(f"{'n':>10} {'nitem':>6} {'bytes':>14} {'v5e time':>12} "
          f"{'eff. fraction':>14}")
    x = jax.random.normal(jax.random.PRNGKey(4), (100000,), jnp.float32)
    got = forge.copy(x, backend="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
    n = 10**8
    ideal = 2 * n * 4
    for nitem in [1, 4, 8, 16]:
        b = AN.copy_bytes(n, jnp.float32, nitem)
        t = HW.modeled_time_s(b)
        print(f"{n:>10} {nitem:>6} {b:>14,} {_us(t)} {ideal/b:>13.3f}")
    print("note: tile padding overhead shrinks as blocks grow; on real "
          "hardware larger Nitem additionally amortizes grid/DMA issue "
          "overhead (the quantity Fig. 1 sweeps).")


def bench_semiring():
    print("\n== Arbitrary types & operators (paper's generality claims) ==")
    t0 = time.time()
    # Tropical shortest-path step: d' = min_i (d_i + W[i,j]).
    W = jax.random.uniform(jax.random.PRNGKey(5), (128, 128), jnp.float32)
    d = jax.random.uniform(jax.random.PRNGKey(6), (128,), jnp.float32)
    got = forge.semiring_matvec(alg.TROPICAL_MIN_PLUS, W, d,
                                backend="pallas-interpret")
    want = ref.ref_matvec(alg.TROPICAL_MIN_PLUS.f, alg.MIN, W, d)
    _check(got, want, 1e-4)
    print("tropical (min,+) matvec 128x128: OK (shortest-path relaxation)")
    # Log-space accumulation.
    got = forge.semiring_vecmat(alg.LOG_SEMIRING, W, d,
                                backend="pallas-interpret")
    want = ref.ref_vecmat(alg.LOG_SEMIRING.f, alg.LOGSUMEXP, W, d)
    _check(got, want, 1e-4)
    print("log-semiring vecmat 128x128: OK (stable likelihood accumulation)")
    # Non-commutative quaternion scan (composite struct type).
    q = tuple(jax.random.normal(jax.random.PRNGKey(7 + i), (1000,),
                                jnp.float32) * 0.1 + (1.0 if i == 0 else 0.0)
              for i in range(4))
    got = forge.scan(alg.QUATERNION_MUL, q, backend="pallas-interpret")
    want = ref.ref_scan(alg.QUATERNION_MUL, q)
    _check(got, want, 1e-2)
    print("quaternion-product scan n=1000: OK (non-commutative struct type)")
    # Affine recurrence (the model-stack workhorse).
    a = jax.random.uniform(jax.random.PRNGKey(11), (4, 64, 256), jnp.float32,
                           0.5, 1.0)
    b = jax.random.normal(jax.random.PRNGKey(12), (4, 64, 256), jnp.float32)
    _check(forge.linear_recurrence(a, b, backend="pallas-interpret"),
           ref.ref_linear_recurrence(a, b), 1e-3)
    print("affine linear recurrence (4,64,256): OK (RG-LRU/mLSTM layout)")
    print(f"(semiring correctness suite: {time.time()-t0:.1f}s interpret)")


def main():
    bench_copy()
    bench_scan()
    bench_mapreduce()
    bench_matvec()
    bench_semiring()


if __name__ == "__main__":
    main()
