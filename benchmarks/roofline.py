"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

For every (arch x shape) cell on the single-pod mesh:

    compute term    = per-device HLO FLOPs / 197 TFLOP/s          [s]
    memory term     = per-device HLO bytes accessed / 819 GB/s    [s]
    collective term = per-device collective bytes / 50 GB/s/link  [s]
                      (all-reduce counted 2x: ring moves ~2 volumes)

``cost_analysis()`` on the partitioned module reports per-device numbers
(verified empirically), so no further division by chip count is needed.
MODEL_FLOPS uses 6*N_active*tokens (train, fwd+bwd) / 2*N_active*tokens
(prefill) / 2*N_active*batch (decode), per device.

Conventions/caveats recorded in EXPERIMENTS.md: host-CPU HLO is the stand-in
for TPU HLO (no libtpu in this container), bf16 peak is used for the compute
term, and `bytes accessed` over-counts relative to real HBM traffic when XLA
fuses differently on TPU.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import hardware as HW

RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops_per_device(rec) -> float:
    from repro.configs import SHAPES
    shape = SHAPES[rec["shape"]]
    n_active = rec["params_active"]
    chips = CHIPS[rec["mesh"]]
    if rec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    return 2.0 * n_active * shape.global_batch / chips  # decode: 1 token


def analyze(rec) -> dict:
    ca = rec["cost_analysis"]
    if "hlo_cost" in rec:
        # Trip-count-aware walker numbers (benchmarks/hlo_cost.py): XLA's
        # cost_analysis counts while-loop bodies once, undercounting scanned
        # layer stacks by 12-80x.
        flops = rec["hlo_cost"]["flops"]
        bytes_hbm = rec["hlo_cost"]["bytes_hbm"]
        coll = rec["hlo_cost"]["collectives"]
    else:
        flops = ca["flops"]
        bytes_hbm = ca["bytes_accessed"]
        coll = rec["collectives"]
    compute_t = flops / HW.PEAK_FLOPS_BF16
    memory_t = bytes_hbm / HW.HBM_BW
    coll_bytes = sum(RING_FACTOR.get(k, 1.0) * v["bytes"]
                     for k, v in coll.items() if isinstance(v, dict))
    coll_t = coll_bytes / HW.ICI_BW_PER_LINK
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)
    bound_t = terms[dominant]
    mf = model_flops_per_device(rec)
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t,
        "dominant": dominant,
        # Fraction of the compute roofline actually achievable given the
        # bottleneck: 1.0 when compute-bound.
        "roofline_fraction": compute_t / bound_t if bound_t > 0 else 0.0,
        "model_flops_per_dev": mf,
        "useful_ratio": mf / flops if flops > 0 else 0.0,
        "hbm_gb_per_dev": (rec["memory_analysis"]["argument_bytes"]
                           + rec["memory_analysis"]["temp_bytes"]) / 2**30
        if "memory_analysis" in rec else -1,
    }
    return out


_SUGGEST = {
    "compute": "compute-bound: raise useful-FLOP ratio (remat policy, fuse "
               "attention, drop redundant recompute)",
    "memory": "HBM-bound: fuse/eliminate materialized intermediates, widen "
              "per-step tiles, cast more traffic to bf16",
    "collective": "ICI-bound: reshard to cut all-gathers (head/seq split), "
                  "overlap collectives with compute, shrink KV replication",
}


def suggestion(row) -> str:
    return _SUGGEST[row["dominant"]]


def load_cells(results_dir: str, mesh: str = "16x16") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("mesh") != mesh:
            continue
        if "error" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "error": rec["error"]})
        elif "skipped" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "skipped": rec["skipped"]})
        else:
            rows.append(analyze(rec))
    return rows


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(rows) -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "roofline-frac | useful-ratio | HBM GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                         f"skipped | -- | -- | -- |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                         f"{r['error'][:40]} | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_ratio']:.2f} | {r['hbm_gb_per_dev']:.1f} |")
    return hdr + "\n".join(lines)


def main(results_dir="results/dryrun", out_json="results/roofline.json"):
    rows = load_cells(results_dir)
    print("== Roofline (single-pod 16x16, per-device terms) ==")
    print(table(rows))
    analyzed = [r for r in rows if "compute_s" in r]
    if analyzed:
        worst = min(analyzed, key=lambda r: r["roofline_fraction"])
        collbound = max(analyzed, key=lambda r: r["collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_fraction']:.3f}) -> {suggestion(worst)}")
        print(f"most collective-bound: {collbound['arch']}/"
              f"{collbound['shape']} ({fmt_s(collbound['collective_s'])}) "
              f"-> {suggestion(collbound)}")
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
