"""Serving benchmark: continuous batching vs the padded-batch baseline.

Drives the continuous-batching engine with a synthetic **open-loop Poisson
arrival trace** (exponential inter-arrival gaps in decode-step units, seeded
=> reproducible) and reports:

* decode throughput (tokens/s, wall-clock) and device-loop dispatch count;
* per-request latency: submit -> finish in decode *steps* (deterministic,
  the CI-stable quantity) and modeled seconds (steps x measured s/step);
* the same request set through the legacy padded fixed-batch path, giving a
  **machine-independent throughput ratio** (continuous / padded on the same
  host, same model, same requests).

``--ci`` runs the small smoke configuration, writes ``BENCH_serving.json``
and hard-fails if the throughput ratio regresses more than 10% below the
committed baseline (``benchmarks/BENCH_serving_baseline.json``).  The ratio
-- not absolute tokens/s -- is gated so the check survives runner-hardware
changes: both paths run the same matmuls on the same machine, so the ratio
isolates exactly what continuous batching is supposed to buy (no per-token
host syncs, no padded-slot waste, slot recycling under load).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np


def poisson_trace(rng, n_requests, rate, vocab, max_plen, max_new):
    """Open-loop arrivals: (step, Request) with exp(rate) gaps, random
    prompts/budgets -- the load is generated regardless of server state."""
    from repro.serving.engine import Request

    arrivals, step = [], 0.0
    for i in range(n_requests):
        step += rng.exponential(1.0 / rate)
        plen = int(rng.integers(2, max_plen + 1))
        prompt = rng.integers(1, vocab, plen).tolist()
        arrivals.append((int(step), Request(
            prompt=prompt, max_new_tokens=int(rng.integers(2, max_new + 1)),
            seed=1000 + i)))
    return arrivals


def run_continuous(eng, arrivals):
    recs = eng.serve(arrivals)
    st = eng.last_stats
    lat_steps = np.asarray([r.finish_step - r.submit_step for r in recs],
                           np.float64)
    s_per_step = st["decode_s"] / max(st["decode_steps"], 1)
    return {
        "tok_per_s": st["decode_tok_per_s"],
        "total_tokens": st["total_tokens"],
        "decode_steps": st["decode_steps"],
        "loop_dispatches": st["loop_dispatches"],
        "admissions": st["admissions"],
        "prefill_s": st["prefill_s"],
        "decode_s": st["decode_s"],
        "latency_steps": {
            "p50": float(np.percentile(lat_steps, 50)),
            "p99": float(np.percentile(lat_steps, 99)),
            "max": float(lat_steps.max()),
        },
        # steps are the deterministic latency unit; seconds are modeled from
        # the measured step time so the numbers travel across hosts.
        "latency_s_modeled": {
            "p50": float(np.percentile(lat_steps, 50) * s_per_step),
            "p99": float(np.percentile(lat_steps, 99) * s_per_step),
        },
        "s_per_step": s_per_step,
    }


def run_padded(eng, arrivals):
    """Same requests through the legacy fixed-batch path, admitted in
    arrival order in full batches (its best case: no arrival gaps modeled,
    so the ratio under-states the continuous win under sparse traffic)."""
    reqs = [r for _, r in arrivals]
    toks = 0
    decode_s = 0.0
    for i in range(0, len(reqs), eng.batch_size):
        chunk = reqs[i:i + eng.batch_size]
        outs = eng.generate_padded(chunk)
        toks += sum(len(o) for o in outs)
        decode_s += eng.last_stats["decode_s"]
    return {"tok_per_s": toks / max(decode_s, 1e-9),
            "total_tokens": toks, "decode_s": decode_s}


def run_bench(*, arch, cache_len, batch_size, n_requests, rate, max_plen,
              max_new, seed, temperature, top_k):
    from repro.configs import base as C
    from repro.models import lm
    from repro.serving.engine import Engine, Request

    cfg = C.get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    arrivals = poisson_trace(rng, n_requests, rate, cfg.vocab_size,
                             max_plen, max_new)
    kw = dict(cache_len=cache_len, batch_size=batch_size,
              temperature=temperature, top_k=top_k)

    # Warm the measured engine's jit caches off the clock (jit caches live
    # on the engine's closures, so warming a different instance warms
    # nothing): a small trace that touches every prompt length plus both
    # loop variants (arrival-bounded and free-slot-bounded).
    cont_eng = Engine(cfg, None, params, **kw)
    cont_eng.serve(
        [(0, Request(prompt=list(range(1, p + 1)), max_new_tokens=2, seed=0))
         for p in range(2, max_plen + 1)] +
        [(1, Request(prompt=[1, 2], max_new_tokens=2, seed=0))])

    # Best-of-N on both paths: the gated quantity is their ratio, and taking
    # each side's best run strips scheduler-noise outliers that would flake
    # a 10% gate on a single sample.
    repeats = 3
    t0 = time.time()
    cont = max((run_continuous(cont_eng, arrivals) for _ in range(repeats)),
               key=lambda r: r["tok_per_s"])
    cont["wall_s"] = time.time() - t0

    pad_eng = Engine(cfg, None, params, **kw)
    pad_eng.generate_padded([Request(prompt=[1, 2], max_new_tokens=2,
                                     seed=0)])            # warm
    padded = max((run_padded(pad_eng, arrivals) for _ in range(repeats)),
                 key=lambda r: r["tok_per_s"])

    # Quantized-KV leg: same trace through the continuous engine with the
    # opt-in int8 KV cache.  The recorded quantity is again a same-host
    # ratio (quantized / dense continuous) -- on CPU smoke it mostly prices
    # the per-step quantize/dequantize overhead; on real accelerators it
    # shows the HBM-bytes win.
    qkv_eng = Engine(cfg, None, params, quantize_kv="int8", **kw)
    qkv_eng.serve(
        [(0, Request(prompt=list(range(1, p + 1)), max_new_tokens=2, seed=0))
         for p in range(2, max_plen + 1)] +
        [(1, Request(prompt=[1, 2], max_new_tokens=2, seed=0))])  # warm
    qkv = max((run_continuous(qkv_eng, arrivals) for _ in range(repeats)),
              key=lambda r: r["tok_per_s"])
    qkv["mode"] = "int8"
    qkv["ratio_vs_dense"] = qkv["tok_per_s"] / cont["tok_per_s"]

    # Speculative leg: the same trace decoded draft-and-verify.  The draft
    # here is the target itself (zero-cost stand-in with a perfect-ish
    # acceptance rate under greedy; sampled traces accept less), so the leg
    # prices the strategy machinery -- scan-of-(k+1)-substeps vs
    # one-token rounds -- and records the acceptance telemetry.  Tokens/s is
    # again reported as a same-host ratio vs dense continuous.
    from repro.serving.strategies import BeamSearch, Speculative

    spec_eng = Engine(cfg, None, params,
                      strategy=Speculative(cfg, params, k=3), **kw)
    spec_eng.serve(
        [(0, Request(prompt=list(range(1, p + 1)), max_new_tokens=2, seed=0))
         for p in range(2, max_plen + 1)] +
        [(1, Request(prompt=[1, 2], max_new_tokens=2, seed=0))])  # warm
    spec = max((run_continuous(spec_eng, arrivals) for _ in range(repeats)),
               key=lambda r: r["tok_per_s"])
    st = spec_eng.last_stats
    spec["k"] = 3
    spec["acceptance_rate"] = st["spec_acceptance_rate"]
    spec["rounds"] = st["spec_rounds"]
    spec["proposed"] = st["spec_proposed"]
    spec["accepted"] = st["spec_accepted"]
    spec["ratio_vs_dense"] = spec["tok_per_s"] / cont["tok_per_s"]

    # Beam leg: width-2 beams per slot (beam search is deterministic, so
    # its engine runs greedy regardless of the trace's sampling settings).
    beam_kw = dict(kw, temperature=0.0, top_k=0)
    beam_eng = Engine(cfg, None, params, strategy=BeamSearch(width=2),
                      **beam_kw)
    beam_eng.serve(
        [(0, Request(prompt=list(range(1, p + 1)), max_new_tokens=2, seed=0))
         for p in range(2, max_plen + 1)] +
        [(1, Request(prompt=[1, 2], max_new_tokens=2, seed=0))])  # warm
    beam = max((run_continuous(beam_eng, arrivals) for _ in range(repeats)),
               key=lambda r: r["tok_per_s"])
    beam["width"] = 2
    beam["ratio_vs_dense"] = beam["tok_per_s"] / cont["tok_per_s"]

    return {
        "config": {"arch": arch, "cache_len": cache_len,
                   "batch_size": batch_size, "n_requests": n_requests,
                   "poisson_rate": rate, "max_plen": max_plen,
                   "max_new": max_new, "seed": seed,
                   "temperature": temperature, "top_k": top_k,
                   "backend": jax.default_backend(),
                   "jax": jax.__version__},
        "continuous": cont,
        "padded": padded,
        "quantized_kv": qkv,
        "speculative": spec,
        "beam": beam,
        "ratio_vs_padded": cont["tok_per_s"] / padded["tok_per_s"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="smoke sizes + regression gate vs the baseline")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--baseline", default=None,
                    help="baseline json; with --ci, fail if ratio_vs_padded "
                         "drops >10%% below its ratio")
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate (requests per decode step)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n_requests = args.requests or (10 if args.ci else 32)
    result = run_bench(
        arch=args.arch, cache_len=64, batch_size=4, n_requests=n_requests,
        rate=args.rate, max_plen=6, max_new=10, seed=args.seed,
        temperature=0.8, top_k=5)

    c, p = result["continuous"], result["padded"]
    print(f"continuous: {c['tok_per_s']:8.1f} tok/s  "
          f"({c['total_tokens']} tokens, {c['decode_steps']} steps, "
          f"{c['loop_dispatches']} loop dispatches)")
    print(f"  latency p50/p99: {c['latency_steps']['p50']:.0f}/"
          f"{c['latency_steps']['p99']:.0f} steps  "
          f"({c['latency_s_modeled']['p50']*1e3:.0f}/"
          f"{c['latency_s_modeled']['p99']*1e3:.0f} ms modeled)")
    print(f"padded:     {p['tok_per_s']:8.1f} tok/s  "
          f"({p['total_tokens']} tokens)")
    q = result["quantized_kv"]
    print(f"quantized:  {q['tok_per_s']:8.1f} tok/s  "
          f"(kv={q['mode']}, {q['ratio_vs_dense']:.2f}x of dense continuous)")
    s = result["speculative"]
    print(f"speculative:{s['tok_per_s']:8.1f} tok/s  "
          f"(k={s['k']}, acceptance {s['acceptance_rate']:.2f}, "
          f"{s['rounds']} rounds, {s['ratio_vs_dense']:.2f}x of dense)")
    b = result["beam"]
    print(f"beam:       {b['tok_per_s']:8.1f} tok/s  "
          f"(width={b['width']}, {b['ratio_vs_dense']:.2f}x of dense)")
    print(f"ratio continuous/padded: {result['ratio_vs_padded']:.2f}x")

    with open(args.out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")

    if args.ci and args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)["ratio_vs_padded"]
        floor = base * 0.9
        got = result["ratio_vs_padded"]
        if got < floor:
            print(f"FAIL serving throughput ratio regressed: {got:.2f} < "
                  f"{floor:.2f} (baseline {base:.2f} - 10%)")
            return 1
        print(f"  ok ratio {got:.2f} >= {floor:.2f} "
              f"(baseline {base:.2f} - 10%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
