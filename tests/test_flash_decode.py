"""Distributed flash-decoding: sharded-vs-local decode parity.

(The SOFTMAX_MERGE operator-fold equivalence assertion that used to live
here moved to tests/test_sharded.py, where it is exercised both in numpy
form and through the real 8-device collective behind
``mapreduce(SOFTMAX_MERGE, layout=Sharded(...))``.)
"""
import os
import subprocess
import sys

import pytest

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import base as C
from repro.distributed import sharding as SH
from repro.models import lm
from repro.training import train_step as TS

from repro.models import layers as L

# gemma3: kv heads (2) do not divide model (4) -> GQA flash-decoding path.
# dsv3:   MLA compressed cache -> latent-space flash-decoding path.
for arch in ["gemma3-4b", "deepseek-v3-671b"]:
    cfg = C.get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=16.0)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    B, S = 2, 16
    cache_len = 32  # divisible by model axis
    params = lm.init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)

    # Reference: unsharded path (no mesh -> plain decode_attention).
    ref_logits, ref_caches = lm.prefill(params, cfg, toks[:, :S-1],
                                        cache_len=cache_len)
    ref_step, _ = lm.decode_step(params, cfg, ref_caches, toks[:, S-1:S],
                                 jnp.asarray(S-1, jnp.int32))

    with mesh:
        rules = SH.make_rules(cfg, mesh)
        def prefill_f32(p, batch):
            with L.sharding_rules(rules):
                return lm.prefill(p, cfg, batch["tokens"],
                                  cache_len=cache_len)
        def decode_f32(p, c, t, pos):
            with L.sharding_rules(rules):
                return lm.decode_step(p, cfg, c, t, pos)
        logits, caches = jax.jit(prefill_f32)(params,
                                              {"tokens": toks[:, :S-1]})
        step_logits, _ = jax.jit(decode_f32)(params, caches, toks[:, S-1:S],
                                             jnp.asarray(S-1, jnp.int32))

    np.testing.assert_allclose(np.asarray(step_logits), np.asarray(ref_step),
                               rtol=2e-3, atol=2e-3, err_msg=arch)
    print(f"{arch}: sharded == local")
print("FLASH_DECODE_OK")
"""


@pytest.mark.slow
def test_sharded_decode_matches_local(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "sharded.py"
    script.write_text(SHARDED_SCRIPT)
    out = subprocess.run([sys.executable, str(script), src],
                         capture_output=True, text=True, timeout=560)
    assert "FLASH_DECODE_OK" in out.stdout, out.stdout + out.stderr[-3000:]
