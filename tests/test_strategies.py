"""Decoding-strategy conformance: speculative / beam / constrained vs their
pure-Python references, plus the strategy registry, the counter-key stream
discipline, prompt-length bucketing parity, and the zero-sync loop property
for every strategy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as C
from repro.models import lm
from repro.serving import sampling as SP
from repro.serving.engine import Engine, Request
from repro.serving.strategies import (
    BeamSearch, Constrained, Speculative, Vanilla, available_strategies,
    get_strategy, resolve_strategy)
from repro.serving.strategies.ref import (
    reference_beam, reference_constrained)


@pytest.fixture(scope="module")
def setup():
    cfg = C.get_config("gemma2-27b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    draft_params = lm.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params, draft_params


REQS = [Request(prompt=[1, 2, 3, 4], max_new_tokens=8, seed=0),
        Request(prompt=[9, 8], max_new_tokens=6, seed=1)]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_lists_all_strategies():
    names = available_strategies()
    for n in ("vanilla", "speculative", "beam", "constrained"):
        assert n in names


def test_registry_unknown_name_is_actionable():
    with pytest.raises(ValueError, match="available"):
        get_strategy("nonexistent")


def test_resolve_strategy_forms(setup):
    assert isinstance(resolve_strategy(None), Vanilla)
    inst = BeamSearch(width=2)
    assert resolve_strategy(inst) is inst
    assert isinstance(resolve_strategy("vanilla"), Vanilla)
    with pytest.raises(TypeError):
        resolve_strategy(42)


# ---------------------------------------------------------------------------
# Speculative: the reference is the vanilla engine itself (lossless rule)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 4])
def test_speculative_bit_identical_greedy(setup, k):
    """Exact-match acceptance is lossless: any draft, any k, greedy streams
    are bit-identical to vanilla at the same seeds."""
    cfg, params, draft_params = setup
    van = Engine(cfg, None, params, cache_len=64, batch_size=2).generate(REQS)
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 strategy=Speculative(cfg, draft_params, k=k))
    assert eng.generate(REQS) == van


def test_speculative_bit_identical_sampled(setup):
    """temperature>0: the verify stream uses the *untagged* counter keys, so
    sampled streams match vanilla bit-for-bit too."""
    cfg, params, draft_params = setup
    kw = dict(cache_len=64, batch_size=2, temperature=1.0, top_k=5, seed=3)
    van = Engine(cfg, None, params, **kw).generate(REQS)
    eng = Engine(cfg, None, params, **kw,
                 strategy=Speculative(cfg, draft_params, k=3))
    assert eng.generate(REQS) == van


def test_speculative_perfect_draft_accepts(setup):
    """Draft == target under greedy: proposals always match, so the stream
    completes in ~ceil(tokens / (k+1)) rounds with high acceptance."""
    cfg, params, _ = setup
    van_eng = Engine(cfg, None, params, cache_len=64, batch_size=2)
    van = van_eng.generate(REQS)
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 strategy=Speculative(cfg, params, k=4))
    assert eng.generate(REQS) == van
    st = eng.last_stats
    n_loop_tokens = sum(len(o) for o in van) - len(REQS)  # 1st at admission
    assert st["spec_rounds"] < n_loop_tokens   # strictly fewer rounds
    assert st["spec_acceptance_rate"] > 0.5
    assert st["spec_accepted"] <= st["spec_proposed"]


def test_speculative_mismatched_draft_still_exact(setup):
    """A draft from different random init almost never matches greedy target
    argmaxes -- acceptance collapses but the stream stays exact."""
    cfg, params, draft_params = setup
    van = Engine(cfg, None, params, cache_len=64, batch_size=2).generate(REQS)
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 strategy=Speculative(cfg, draft_params, k=4))
    assert eng.generate(REQS) == van
    assert eng.last_stats["spec_acceptance_rate"] < 0.5


def test_speculative_validation(setup):
    cfg, params, _ = setup
    with pytest.raises(ValueError, match="k must be"):
        Speculative(cfg, params, k=0)


# ---------------------------------------------------------------------------
# Counter-key discipline (satellite S2): the draft stream is a tagged fork
# of the base key; the recipe is pinned so a refactor cannot silently change
# sampled streams.
# ---------------------------------------------------------------------------


def test_draft_stream_key_recipe_pinned():
    assert SP.DRAFT_STREAM == 0x5D1A_F7
    base = jax.random.PRNGKey(11)
    expect = jax.random.fold_in(base, jnp.uint32(SP.DRAFT_STREAM))
    got = SP.stream_key(base, SP.DRAFT_STREAM)
    assert jnp.array_equal(got, expect)
    # The tagged stream must actually differ from the untagged one.
    assert not jnp.array_equal(got, base)


def test_draft_keys_batch_composition_independent(setup):
    """Per-request acceptance counts (rec.meta) are a pure function of
    (engine seed, request seed, prompt): the same request accepted the same
    number of draft tokens alone and inside a batch."""
    cfg, params, draft_params = setup
    kw = dict(cache_len=64, batch_size=2, temperature=1.0, top_k=5, seed=3)

    def spec_meta(reqs):
        eng = Engine(cfg, None, params, **kw,
                     strategy=Speculative(cfg, draft_params, k=3))
        recs = eng.serve([(0, r) for r in reqs])
        return {tuple(r.request.prompt): r.meta["spec_accepted"]
                for r in recs}

    alone = spec_meta([REQS[0]])
    batched = spec_meta(REQS)
    key = tuple(REQS[0].prompt)
    assert alone[key] == batched[key]


# ---------------------------------------------------------------------------
# Beam search vs the NMT-style reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", [2, 3])
def test_beam_matches_reference(setup, width):
    cfg, params, _ = setup
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 strategy=BeamSearch(width=width))
    outs = eng.generate(REQS)
    scores = eng.last_stats["seq_logprob"]
    for r, o, s in zip(REQS, outs, scores):
        ref_toks, ref_score = reference_beam(
            eng, r.prompt, width=width, max_new=r.max_new_tokens)
        assert list(o) == ref_toks
        assert s == pytest.approx(ref_score, abs=2e-4)


def test_beam_eos_routes_to_finished(setup):
    """With eos set to a token the width-2 beam actually reaches, the device
    search must agree with the reference's finished-pool handling."""
    cfg, params, _ = setup
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 strategy=BeamSearch(width=2))
    probe = eng.generate([Request(prompt=[5, 6, 7], max_new_tokens=5)])[0]
    eos = probe[2]
    req = Request(prompt=[5, 6, 7], max_new_tokens=7, eos_id=eos)
    out = eng.generate([req])[0]
    score = eng.last_stats["seq_logprob"][0]
    ref_toks, ref_score = reference_beam(
        eng, req.prompt, width=2, max_new=7, eos_id=eos)
    assert list(out) == ref_toks
    assert score == pytest.approx(ref_score, abs=2e-4)


def test_beam_rejects_sampling_engine(setup):
    cfg, params, _ = setup
    with pytest.raises(ValueError, match="deterministic"):
        Engine(cfg, None, params, cache_len=64, batch_size=2,
               temperature=1.0, strategy=BeamSearch(width=2))
    with pytest.raises(ValueError, match="width"):
        BeamSearch(width=0)
    with pytest.raises(ValueError, match="length_penalty"):
        BeamSearch(width=2, length_penalty=-0.5)


def test_beam_length_penalty_matches_reference(setup):
    """GNMT length-normalized beam (alpha=0.6) vs the oracle, with an EOS
    the beams reach -- the penalty reranks finished hypotheses of
    different lengths, so the divide points must agree exactly."""
    cfg, params, _ = setup
    probe_eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                       strategy=BeamSearch(width=2))
    probe = probe_eng.generate(
        [Request(prompt=[5, 6, 7], max_new_tokens=5)])[0]
    eos = probe[2]
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 strategy=BeamSearch(width=2, length_penalty=0.6))
    req = Request(prompt=[5, 6, 7], max_new_tokens=7, eos_id=eos)
    out = eng.generate([req])[0]
    score = eng.last_stats["seq_logprob"][0]
    ref_toks, ref_score = reference_beam(
        eng, req.prompt, width=2, max_new=7, eos_id=eos,
        length_penalty=0.6)
    assert list(out) == ref_toks
    assert score == pytest.approx(ref_score, abs=2e-4)


def test_beam_length_penalty_zero_is_default(setup):
    """alpha=0 must stay bit-identical to the unnormalized default."""
    cfg, params, _ = setup
    kw = dict(cache_len=64, batch_size=2)
    eng0 = Engine(cfg, None, params, **kw, strategy=BeamSearch(width=2))
    engz = Engine(cfg, None, params, **kw,
                  strategy=BeamSearch(width=2, length_penalty=0.0))
    out0 = eng0.generate(REQS)
    s0 = eng0.last_stats["seq_logprob"]
    outz = engz.generate(REQS)
    sz = engz.last_stats["seq_logprob"]
    for a, b in zip(out0, outz):
        assert list(a) == list(b)
    assert jnp.array_equal(s0, sz)


# ---------------------------------------------------------------------------
# Constrained sampling vs the DFA-walk reference
# ---------------------------------------------------------------------------


def _dfa(cfg, seed=0, n_states=3, density=0.3):
    rng = np.random.default_rng(seed)
    V = cfg.vocab_size
    allowed = rng.random((n_states, V)) < density
    allowed[:, 0] = True     # no dead states
    trans = rng.integers(0, n_states, (n_states, V)).astype(np.int32)
    return allowed, trans


def test_constrained_matches_reference_and_mask(setup):
    cfg, params, _ = setup
    allowed, trans = _dfa(cfg)
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 temperature=1.0, top_k=8, seed=5,
                 strategy=Constrained(allowed, trans))
    outs = eng.generate(REQS)
    for r, o in zip(REQS, outs):
        ref_toks, _ = reference_constrained(
            eng, r.prompt, r.seed, allowed=allowed, transitions=trans,
            max_new=r.max_new_tokens)
        assert list(o) == ref_toks
        # Walk the DFA: every emitted token must be allowed in its state.
        s = 0
        for t in o:
            assert allowed[s, t]
            s = trans[s, t]


def test_constrained_greedy_never_emits_masked(setup):
    """Greedy (argmax over masked logits) obeys the DFA too -- the mask is a
    logits transform, not a sampler feature."""
    cfg, params, _ = setup
    allowed, trans = _dfa(cfg, seed=2, density=0.1)
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 strategy=Constrained(allowed, trans))
    for o in eng.generate(REQS):
        s = 0
        for t in o:
            assert allowed[s, t]
            s = trans[s, t]


def test_constrained_table_validation(setup):
    cfg, params, _ = setup
    V = cfg.vocab_size
    ok = np.ones((2, V), bool)
    trans = np.zeros((2, V), np.int32)
    dead = ok.copy()
    dead[1] = False
    with pytest.raises(ValueError, match="allow no token"):
        Constrained(dead, trans)
    bad_t = trans.copy()
    bad_t[0, 0] = 5
    with pytest.raises(ValueError, match="transitions"):
        Constrained(ok, bad_t)
    with pytest.raises(ValueError, match="start_state"):
        Constrained(ok, trans, start_state=9)
    with pytest.raises(ValueError, match="vocab"):
        Engine(cfg, None, params, cache_len=64, batch_size=2,
               strategy=Constrained(np.ones((2, V + 1), bool),
                                    np.zeros((2, V + 1), np.int32)))


# ---------------------------------------------------------------------------
# Staggered admission + recycled slots: a request's stream must not depend
# on when it was admitted or whether its slot previously held another
# request (satellite S3).
# ---------------------------------------------------------------------------

STAGGER = [Request(prompt=[1, 2, 3], max_new_tokens=5, seed=0),
           Request(prompt=[4, 5], max_new_tokens=4, seed=1),
           Request(prompt=[6, 7, 8], max_new_tokens=6, seed=2),
           Request(prompt=[2, 9], max_new_tokens=3, seed=3)]


def _staggered(eng):
    """4 requests through 2 slots with mid-flight arrivals => slot reuse."""
    recs = eng.serve([(0, STAGGER[0]), (0, STAGGER[1]),
                      (2, STAGGER[2]), (3, STAGGER[3])])
    return [r.tokens for r in recs]


def test_staggered_speculative_matches_vanilla(setup):
    cfg, params, draft_params = setup
    van = Engine(cfg, None, params, cache_len=64, batch_size=2)
    expect = [van.generate([r])[0] for r in STAGGER]
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 strategy=Speculative(cfg, draft_params, k=3))
    assert _staggered(eng) == expect


def test_staggered_beam_matches_reference(setup):
    cfg, params, _ = setup
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 strategy=BeamSearch(width=2))
    outs = _staggered(eng)
    for r, o in zip(STAGGER, outs):
        ref_toks, _ = reference_beam(eng, r.prompt, width=2,
                                     max_new=r.max_new_tokens)
        assert list(o) == ref_toks


def test_staggered_constrained_matches_reference(setup):
    cfg, params, _ = setup
    allowed, trans = _dfa(cfg, seed=1)
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 temperature=1.0, top_k=8, seed=4,
                 strategy=Constrained(allowed, trans))
    outs = _staggered(eng)
    for r, o in zip(STAGGER, outs):
        ref_toks, _ = reference_constrained(
            eng, r.prompt, r.seed, allowed=allowed, transitions=trans,
            max_new=r.max_new_tokens)
        assert list(o) == ref_toks


# ---------------------------------------------------------------------------
# Zero per-token host syncs for EVERY strategy (satellite S6): one
# while-loop dispatch decodes the batch to completion under a hard
# device->host transfer guard.
# ---------------------------------------------------------------------------


def _strategies_for_guard(cfg, params, draft_params):
    allowed, trans = _dfa(cfg)
    return [
        ("speculative", dict(strategy=Speculative(cfg, draft_params, k=3),
                             temperature=1.0, top_k=5, seed=2)),
        ("beam", dict(strategy=BeamSearch(width=2))),
        ("constrained", dict(strategy=Constrained(allowed, trans),
                             temperature=1.0, top_k=8, seed=2)),
    ]


@pytest.mark.parametrize("idx", [0, 1, 2],
                         ids=["speculative", "beam", "constrained"])
def test_strategy_single_dispatch_no_token_syncs(setup, idx):
    cfg, params, draft_params = setup
    name, kw = _strategies_for_guard(cfg, params, draft_params)[idx]
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2, **kw)
    assert eng.strategy.name == name
    eng.generate(REQS)        # warm the jit caches

    real, calls = eng._dispatch_loop, []

    def guarded(state, budget, stop_on_free):
        calls.append(int(budget))
        with jax.transfer_guard_device_to_host("disallow"):
            return real(state, budget, stop_on_free)

    eng._dispatch_loop = guarded
    outs = eng.generate(REQS)
    assert len(calls) == 1
    assert eng.last_stats["loop_dispatches"] == 1
    assert [len(o) > 0 for o in outs] == [True, True]


# ---------------------------------------------------------------------------
# Prompt-length bucketing (satellite S1): right-padded prefill at bucket
# lengths must reproduce exact-length first tokens.
# ---------------------------------------------------------------------------

BUCKET_REQS = [Request(prompt=list(range(1, 6)), max_new_tokens=6, seed=0),
               Request(prompt=[9, 8, 7], max_new_tokens=5, seed=1),
               Request(prompt=list(range(3, 20)), max_new_tokens=4, seed=2)]


@pytest.mark.parametrize("arch", ["gemma2-27b", "recurrentgemma-2b"])
def test_bucketed_prefill_parity(arch):
    """Attention archs are bit-identical under right-padded prefill (the
    causal mask keeps pads out of every valid query); recurrent archs
    snapshot their state at valid_len and must emit the same tokens."""
    cfg = C.get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    exact = Engine(cfg, None, params, cache_len=64,
                   batch_size=2).generate(BUCKET_REQS)
    bucketed = Engine(cfg, None, params, cache_len=64, batch_size=2,
                      prefill_buckets="pow2").generate(BUCKET_REQS)
    assert bucketed == exact


def test_bucketed_prefill_parity_sampled(setup):
    """Sampling runs on the same logits => bucketing can't shift the RNG."""
    cfg, params, _ = setup
    kw = dict(cache_len=64, batch_size=2, temperature=1.0, top_k=5, seed=3)
    exact = Engine(cfg, None, params, **kw).generate(BUCKET_REQS)
    bucketed = Engine(cfg, None, params, **kw,
                      prefill_buckets=[8, 32]).generate(BUCKET_REQS)
    assert bucketed == exact


def test_bucketed_prefill_compiles_fewer_shapes(setup):
    """The point of bucketing: prompts of many lengths hit few prefill
    shapes.  Count distinct (padded) prompt lengths reaching _prefill."""
    cfg, params, _ = setup
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 prefill_buckets="pow2")
    seen = []
    real = eng._prefill

    def spy(params, batch):
        # Admission prefills are batch-1; ignore the engine's own
        # eval_shape cache-shape probe (batch = batch_size, length 1).
        if batch["tokens"].shape[0] == 1:
            seen.append(batch["tokens"].shape[1])
        return real(params, batch)

    eng._prefill = spy
    reqs = [Request(prompt=list(range(1, n)), max_new_tokens=2, seed=n)
            for n in (3, 5, 6, 8, 9, 17, 20)]
    eng.generate(reqs)
    assert len(seen) == len(reqs)
    assert set(seen) <= {8, 16, 32}     # pow2 buckets, never exact lengths


def test_bucket_spec_validation(setup):
    cfg, params, _ = setup
    with pytest.raises(ValueError, match="prefill_buckets"):
        Engine(cfg, None, params, cache_len=64, batch_size=2,
               prefill_buckets=[8, 4096])


def test_buckets_compose_with_speculative(setup):
    """Bucketed prefill feeds both models' caches; streams stay exact."""
    cfg, params, draft_params = setup
    van = Engine(cfg, None, params, cache_len=64,
                 batch_size=2).generate(BUCKET_REQS)
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 prefill_buckets="pow2",
                 strategy=Speculative(cfg, draft_params, k=3))
    assert eng.generate(BUCKET_REQS) == van


# ---------------------------------------------------------------------------
# Oracle routing guards (satellite S6)
# ---------------------------------------------------------------------------


def test_generate_padded_refuses_non_vanilla(setup):
    cfg, params, draft_params = setup
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 strategy=Speculative(cfg, draft_params, k=2))
    with pytest.raises(NotImplementedError, match="vanilla"):
        eng.generate_padded(REQS)


def test_encdec_rejects_non_vanilla_strategy():
    # The constructor raises before params are ever touched, so no init.
    cfg = C.get_config("seamless-m4t-medium", smoke=True)
    with pytest.raises(NotImplementedError, match="enc-dec"):
        Engine(cfg, None, None, cache_len=64, batch_size=2,
               strategy=BeamSearch(width=2))
