"""Differential parity: continuous-batching engine vs the padded oracle.

The padded fixed-batch path (``generate_padded``) is the reference
implementation: one prefill over the aligned batch, one decode dispatch per
token, host bookkeeping everywhere.  The continuous path must reproduce its
token streams *exactly* -- same requests, same seeds, bit-identical tokens
-- across greedy, top-k and top-p sampling, under staggered admission, and
through slot recycling.  What makes this possible (and what these tests
therefore pin):

* batch rows never mix inside the model -- attention/recurrence are
  row-local, so a request's stream depends only on its own prompt+seed;
* sampling keys are counter-based per request
  (``fold_in(fold_in(base, seed), token_index)``), independent of batch
  composition, slot index or admission time.

One asymmetry is deliberate: the *padded* oracle left-pads ragged prompts,
and pad tokens attend as real context -- a known contamination of the
legacy path that continuous batching removes (each request prefills alone
at its exact length).  So multi-request oracle comparisons use equal-length
prompts, and ragged prompts are checked per-request against a batch-of-one
oracle (no padding => no contamination).

seq_logprob is compared to tight tolerance, not bitwise: both paths sum the
same per-token log-probs with a batched mapreduce, but over different
buffer extents (the padded path's buffer is trimmed to realized steps), so
the reduction tree may differ in the last ulp.
"""
import jax
import numpy as np
import pytest

from repro.configs import base as C
from repro.models import lm
from repro.serving.engine import Engine, Request

SAMPLERS = {
    "greedy": dict(),
    "topk": dict(temperature=0.8, top_k=5),
    "topp": dict(temperature=0.9, top_p=0.85),
}


@pytest.fixture(scope="module")
def gemma():
    cfg = C.get_config("gemma2-27b", smoke=True)
    return cfg, lm.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def recurrent():
    cfg = C.get_config("recurrentgemma-2b", smoke=True)
    return cfg, lm.init_params(jax.random.PRNGKey(1), cfg)


def _engines(model, **kw):
    cfg, params = model
    mk = lambda: Engine(cfg, None, params, cache_len=64, batch_size=4, **kw)
    return mk(), mk()


def _lp_close(a, b):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Equal-length multi-request parity, all sampling modes.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", sorted(SAMPLERS))
def test_equal_length_batch_parity(gemma, mode):
    reqs = [Request([3, 5, 7], max_new_tokens=6, seed=11),
            Request([2, 4, 9], max_new_tokens=5, seed=22),
            Request([9, 1, 8], max_new_tokens=4, seed=33)]
    cont, padded = _engines(gemma, **SAMPLERS[mode])
    out_c = cont.generate(reqs)
    out_p = padded.generate_padded(reqs)
    assert out_c == out_p
    _lp_close(cont.last_stats["seq_logprob"], padded.last_stats["seq_logprob"])


def test_recurrent_arch_parity(recurrent):
    """Recurrent + local-attention arch: state is O(1) per slot, scattered
    whole at admission -- tokens must still match the padded oracle."""
    reqs = [Request([5, 2, 6], max_new_tokens=6, seed=3),
            Request([1, 7, 4], max_new_tokens=6, seed=4)]
    cont, padded = _engines(recurrent, temperature=0.7, top_k=6)
    out_c = cont.generate(reqs)
    out_p = padded.generate_padded(reqs)
    assert out_c == out_p
    _lp_close(cont.last_stats["seq_logprob"], padded.last_stats["seq_logprob"])


# ---------------------------------------------------------------------------
# Ragged prompts: per-request oracle (padding-free batch of one).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", sorted(SAMPLERS))
def test_ragged_prompts_match_per_request_oracle(gemma, mode):
    cfg, params = gemma
    reqs = [Request([3, 5, 7], max_new_tokens=6, seed=11),
            Request([2, 4], max_new_tokens=5, seed=22),
            Request([9, 1, 8, 6], max_new_tokens=4, seed=33)]
    cont = Engine(cfg, None, params, cache_len=64, batch_size=4,
                  **SAMPLERS[mode])
    out_c = cont.generate(reqs)
    for i, r in enumerate(reqs):
        oracle = Engine(cfg, None, params, cache_len=64, batch_size=1,
                        **SAMPLERS[mode])
        out_1 = oracle.generate_padded([r])
        assert out_1[0] == out_c[i], f"request {i} diverged"
        _lp_close([oracle.last_stats["seq_logprob"][0]],
                  [cont.last_stats["seq_logprob"][i]])


# ---------------------------------------------------------------------------
# Staggered admission: requests joining a running batch sample identically.
# ---------------------------------------------------------------------------


def test_staggered_admission_parity(gemma):
    cfg, params = gemma
    reqs = [Request([3, 5, 7], max_new_tokens=8, seed=1),
            Request([2, 4, 6], max_new_tokens=6, seed=2),
            Request([9, 1, 8], max_new_tokens=5, seed=3)]
    eng = Engine(cfg, None, params, cache_len=64, batch_size=4,
                 temperature=0.8, top_k=5)
    recs = eng.serve([(0, reqs[0]), (3, reqs[1]), (6, reqs[2])])
    assert [r.admit_step for r in recs] == [0, 3, 6]
    assert recs[1].admit_step > recs[0].admit_step  # genuinely mid-flight
    for i, r in enumerate(reqs):
        oracle = Engine(cfg, None, params, cache_len=64, batch_size=1,
                        temperature=0.8, top_k=5)
        out_1 = oracle.generate_padded([r])
        assert out_1[0] == recs[i].tokens, \
            f"request {i} admitted at step {recs[i].admit_step} diverged"


def test_slot_recycling_parity(gemma):
    """More requests than slots: late requests decode in recycled slots and
    still match the per-request oracle bit for bit."""
    cfg, params = gemma
    reqs = [Request([i + 1, i + 2], max_new_tokens=3 + i % 3, seed=100 + i)
            for i in range(6)]
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2,
                 temperature=0.8, top_k=5)
    out = eng.generate(reqs)
    assert eng.last_stats["admissions"] == 6
    for i, r in enumerate(reqs):
        oracle = Engine(cfg, None, params, cache_len=64, batch_size=1,
                        temperature=0.8, top_k=5)
        assert oracle.generate_padded([r])[0] == out[i]


# ---------------------------------------------------------------------------
# Boundary accounting (the legacy off-by-ones, now fixed in BOTH paths).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("path", ["continuous", "padded"])
def test_max_new_tokens_one(gemma, path):
    """Regression: exactly one token when max_new_tokens=1 (the legacy loop
    appended the first sample before any cap bookkeeping)."""
    cfg, params = gemma
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2)
    reqs = [Request([3, 5], max_new_tokens=1), Request([2, 4], max_new_tokens=4)]
    out = eng.generate(reqs) if path == "continuous" \
        else eng.generate_padded(reqs)
    assert len(out[0]) == 1
    assert len(out[1]) == 4


@pytest.mark.parametrize("path", ["continuous", "padded"])
def test_max_new_tokens_zero(gemma, path):
    cfg, params = gemma
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2)
    reqs = [Request([3, 5], max_new_tokens=0), Request([2, 4], max_new_tokens=3)]
    out = eng.generate(reqs) if path == "continuous" \
        else eng.generate_padded(reqs)
    assert out[0] == []
    assert len(out[1]) == 3


@pytest.mark.parametrize("path", ["continuous", "padded"])
def test_eos_as_first_token_stops(gemma, path):
    """Regression: EOS sampled as the very first token ends the request (the
    legacy loop only checked EOS on tokens 2+)."""
    cfg, params = gemma
    probe = Engine(cfg, None, params, cache_len=64, batch_size=1)
    first = probe.generate([Request([3, 5], max_new_tokens=1)])[0][0]
    eng = Engine(cfg, None, params, cache_len=64, batch_size=1)
    req = Request([3, 5], max_new_tokens=8, eos_id=first)
    out = eng.generate([req]) if path == "continuous" \
        else eng.generate_padded([req])
    assert out[0] == [first]


def test_request_overflow_legacy_asserts_continuous_queues(gemma):
    cfg, params = gemma
    reqs = [Request([1, 2], max_new_tokens=2, seed=i) for i in range(3)]
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2)
    assert len(eng.generate(reqs)) == 3          # continuous: queues
    with pytest.raises(AssertionError):
        eng.generate_padded(reqs)                # padded: fixed batch
