"""Training substrate tests: optimizer, accumulation, trainer loop, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_trees_close
from repro.configs import base as C
from repro.training import optimizer as OPT
from repro.training import train_step as TS
from repro.training.data import DataConfig, SyntheticDataset
from repro.training.trainer import RunConfig, Trainer


def small_cfg():
    return C.get_config("minitron-4b", smoke=True)


def small_train_cfg(**kw):
    opt = OPT.OptimizerConfig(peak_lr=1e-2, warmup_steps=5, decay_steps=100,
                              weight_decay=0.0)
    return TS.TrainConfig(optimizer=opt, remat="none", **kw)


def test_adamw_minimizes_quadratic():
    opt_cfg = OPT.OptimizerConfig(peak_lr=0.1, warmup_steps=0, decay_steps=200,
                                  weight_decay=0.0)
    init, update = OPT.make_optimizer(opt_cfg)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init(params)
    for step in range(150):
        g = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])))(params)
        params, state = update(g, state, params, jnp.asarray(step))
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adafactor_minimizes_quadratic():
    opt_cfg = OPT.OptimizerConfig(name="adafactor", peak_lr=0.1,
                                  warmup_steps=0, decay_steps=300,
                                  weight_decay=0.0)
    init, update = OPT.make_optimizer(opt_cfg)
    params = {"w": jnp.full((4, 3), 2.0)}
    state = init(params)
    for step in range(200):
        g = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])))(params)
        params, state = update(g, state, params, jnp.asarray(step))
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_lr_schedule_shape():
    cfg = OPT.OptimizerConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                              min_lr_ratio=0.1)
    lrs = [float(OPT.lr_schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100, 200]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)
    assert lrs[5] == pytest.approx(0.1, rel=1e-3)


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = OPT.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(OPT.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_train_loss_decreases(rng):
    """~40 steps on the structured synthetic stream must cut the loss."""
    cfg = small_cfg()
    tc = small_train_cfg()
    data = SyntheticDataset(DataConfig(seq_len=32, global_batch=8,
                                       vocab_size=cfg.vocab_size), cfg)
    state = TS.init_state(rng, cfg, tc)
    step_fn = jax.jit(TS.make_train_step(cfg, None, tc), donate_argnums=(0,))
    losses = []
    for s in range(40):
        state, metrics = step_fn(state, data.batch(s))
        losses.append(float(metrics["ce_loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_grad_accumulation_equivalence(rng):
    """accum_steps=2 over a 2x batch ~= single step (same total batch)."""
    cfg = small_cfg()
    tc1 = small_train_cfg(accum_steps=1)
    tc2 = small_train_cfg(accum_steps=2)
    data = SyntheticDataset(DataConfig(seq_len=16, global_batch=8,
                                       vocab_size=cfg.vocab_size), cfg)
    batch = data.batch(0)
    s1 = TS.init_state(rng, cfg, tc1)
    s2 = jax.tree.map(lambda x: x, s1)
    n1, _ = jax.jit(TS.make_train_step(cfg, None, tc1))(s1, batch)
    n2, _ = jax.jit(TS.make_train_step(cfg, None, tc2))(s2, batch)
    # bf16 grads + different reduction order: loose but telling tolerance.
    assert_trees_close(n1["params"], n2["params"], rtol=3e-2, atol=3e-2)


def test_data_determinism_and_sharding():
    cfg = small_cfg()
    d1 = SyntheticDataset(DataConfig(seed=7, seq_len=16, global_batch=4,
                                     vocab_size=64), cfg)
    d2 = SyntheticDataset(DataConfig(seed=7, seq_len=16, global_batch=4,
                                     vocab_size=64), cfg)
    b1, b2 = d1.host_batch(123), d2.host_batch(123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.host_batch(124)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_trainer_recovers_from_injected_fault(tmp_path, rng):
    """Failure mid-run -> trainer reloads last checkpoint and continues."""
    cfg = small_cfg()
    tc = small_train_cfg()
    run = RunConfig(total_steps=12, ckpt_dir=str(tmp_path / "ckpt"),
                    ckpt_every=4, log_every=100, max_retries=2)
    data = SyntheticDataset(DataConfig(seq_len=16, global_batch=4,
                                       vocab_size=cfg.vocab_size), cfg)
    boom = {"armed": True}

    def fault_hook(step):
        if step == 6 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    t = Trainer(cfg, None, tc, run, data, fault_hook=fault_hook)
    state = t.run()
    assert t.recoveries == 1
    assert int(state["step"]) == 12


def test_trainer_resumes_from_checkpoint(tmp_path, rng):
    cfg = small_cfg()
    tc = small_train_cfg()
    data = SyntheticDataset(DataConfig(seq_len=16, global_batch=4,
                                       vocab_size=cfg.vocab_size), cfg)
    run1 = RunConfig(total_steps=6, ckpt_dir=str(tmp_path / "c"),
                     ckpt_every=3, log_every=100)
    t1 = Trainer(cfg, None, tc, run1, data)
    t1.run()
    run2 = RunConfig(total_steps=10, ckpt_dir=str(tmp_path / "c"),
                     ckpt_every=3, log_every=100)
    t2 = Trainer(cfg, None, tc, run2, data)
    state = t2.run()
    assert int(state["step"]) == 10
