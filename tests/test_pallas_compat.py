"""kernels/pallas_compat.py must import cleanly and expose the compat
surface on every supported jax pin (0.4.37 and current) -- CI runs this
file under both.  The assertions are written against the *contract*, not
a particular pin: names exist, aliases point at real dataclasses, and the
tolerant ``gpu_compiler_params`` builder never raises on either pin.
"""
import jax

from repro.kernels import pallas_compat as pc


def test_reexports_exist():
    assert pc.pl is not None
    assert pc.pltpu is not None
    for name in pc.__all__:
        assert hasattr(pc, name), name


def test_tpu_compiler_params_alias():
    # Both the renamed and the legacy spelling must resolve after import.
    assert hasattr(pc.pltpu, "CompilerParams")
    params = pc.pltpu.CompilerParams()
    assert params is not None


def test_gpu_compiler_params_builder():
    params = pc.gpu_compiler_params(num_warps=4, num_stages=2)
    if pc.pltriton is None:
        assert params is None
    else:
        assert isinstance(params, pc.pltriton.CompilerParams)
        # Unknown-field tolerance: whatever survived must round-trip.
        fields = pc.pltriton.CompilerParams.__dataclass_fields__
        if "num_warps" in fields:
            assert params.num_warps == 4


def test_gpu_compiler_params_defaults():
    params = pc.gpu_compiler_params()
    assert params is None or isinstance(params, pc.pltriton.CompilerParams)


def test_triton_alias_when_present():
    if pc.pltriton is not None:
        assert hasattr(pc.pltriton, "CompilerParams")


def test_mosaic_gpu_alias_when_present():
    if pc.plmgpu is not None and hasattr(pc.plmgpu, "GPUCompilerParams"):
        assert hasattr(pc.plmgpu, "CompilerParams")


def test_interpret_call_ignores_gpu_params():
    """An interpret-mode pallas_call must work with compiler_params absent
    (the shape gpu.py uses on CPU) on every pin."""
    import jax.numpy as jnp

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2

    x = jnp.arange(8, dtype=jnp.float32)
    out = pc.pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True)(x)
    assert (out == x * 2).all()
