"""Layer-1 intrinsics conformance: every flavor against one oracle.

The portability contract of the intrinsics layer is that the flavored
primitives -- ``tile_scan`` / ``tile_reduce`` shift combines, the
``memory_fence`` visibility edge, the ``vec_width`` hint -- are
*semantically identical* across flavors: the TPU roll+select combine and
the GPU identity-padded ``shfl_up`` combine must produce bit-equivalent
scans for any associative operator, commutative or not, scalar or pytree.

Seeded fuzz over (backend x operator x extent), comparing every registered
backend's flavor against the ``pallas-interpret`` oracle flavor and against
an independent Python-loop reference.
"""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_trees_close, make_operand
from repro.core import intrinsics as ki
from repro.core import operators as alg

# Non-commutative pytree ops force the order-preserving identity-padded
# path; logsumexp exercises a non-trivial identity (-inf).
OP_NAMES = ["add", "max", "logsumexp", "affine", "quaternion_mul",
            "mat2_mul"]
# Extents straddle powers of two: the log-step loop and the non-pow2
# reduce fallback both get hit.
EXTENTS = [1, 2, 3, 7, 8, 9, 31, 64, 100]

ORACLE_BACKEND = "pallas-interpret"


def _seed(*parts):
    return zlib.crc32("|".join(str(p) for p in parts).encode())


def _ref_scan(op, x, extent):
    """Python-loop inclusive scan along axis 0 (independent oracle)."""
    acc = None
    rows = []
    for i in range(extent):
        elem = jax.tree.map(lambda l: l[i:i + 1], x)
        acc = elem if acc is None else op(acc, elem)
        rows.append(acc)
    return jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=0), *rows)


@pytest.mark.parametrize("backend", sorted(ki.available_backends()))
@pytest.mark.parametrize("op_name", OP_NAMES)
def test_tile_scan_matches_oracle_flavor(backend, op_name):
    op = alg.STD_OPS[op_name]
    flavor = ki.get_flavor(backend).name
    oracle = ki.get_flavor(ORACLE_BACKEND).name
    nprng = np.random.default_rng(_seed("scan", backend, op_name))
    for n in EXTENTS:
        x = make_operand(op_name, nprng, (n,))
        got = ki.tile_scan(op, x, axis=0, flavor=flavor)
        want = ki.tile_scan(op, x, axis=0, flavor=oracle)
        assert_trees_close(got, want, rtol=1e-5, atol=1e-5,
                           err=f"tile_scan {backend}/{op_name} n={n}")
        ref = _ref_scan(op, x, n)
        assert_trees_close(got, ref, rtol=1e-4, atol=1e-4,
                           err=f"tile_scan-vs-ref {backend}/{op_name} n={n}")


@pytest.mark.parametrize("backend", sorted(ki.available_backends()))
@pytest.mark.parametrize("op_name", OP_NAMES)
def test_tile_reduce_matches_oracle_flavor(backend, op_name):
    op = alg.STD_OPS[op_name]
    flavor = ki.get_flavor(backend).name
    oracle = ki.get_flavor(ORACLE_BACKEND).name
    nprng = np.random.default_rng(_seed("reduce", backend, op_name))
    for n in EXTENTS:
        x = make_operand(op_name, nprng, (n,))
        got = ki.tile_reduce(op, x, axis=0, flavor=flavor)
        want = ki.tile_reduce(op, x, axis=0, flavor=oracle)
        assert_trees_close(got, want, rtol=1e-5, atol=1e-5,
                           err=f"tile_reduce {backend}/{op_name} n={n}")
        ref = ki.tile_take_last(_ref_scan(op, x, n), axis=0)
        assert_trees_close(got, ref, rtol=1e-4, atol=1e-4,
                           err=f"tile_reduce-vs-ref {backend}/{op_name} n={n}")


@pytest.mark.parametrize("op_name", ["add", "mat2_mul"])
def test_tile_scan_axis1_flavors_agree(op_name):
    """2-D tiles, scanned along the minor axis (the in-kernel layout)."""
    op = alg.STD_OPS[op_name]
    nprng = np.random.default_rng(_seed("axis1", op_name))
    x = make_operand(op_name, nprng, (4, 37))
    got_g = ki.tile_scan(op, x, axis=1, flavor="gpu")
    got_t = ki.tile_scan(op, x, axis=1, flavor="tpu")
    assert_trees_close(got_g, got_t, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", sorted(ki.available_backends()))
def test_memory_fence_is_semantically_identity(backend):
    """The fence orders visibility; it must never change the values, for
    scalars, arrays and (publish, flag) pytrees alike."""
    flavor = ki.get_flavor(backend).name
    x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    np.testing.assert_array_equal(
        np.asarray(ki.memory_fence(x, flavor=flavor)), np.asarray(x))
    pub, flag = ki.memory_fence((x, jnp.int32(1)), flavor=flavor)
    np.testing.assert_array_equal(np.asarray(pub), np.asarray(x))
    assert int(flag) == 1


def test_memory_fence_traces_under_jit():
    """The fence must be jit-traceable on every flavor (it sits inside
    kernel bodies and their surrounding jitted wrappers)."""
    for flavor in ("tpu", "gpu"):
        f = jax.jit(lambda v: ki.memory_fence((v, v * 2), flavor=flavor))
        a, b = f(jnp.ones((4,)))
        np.testing.assert_array_equal(np.asarray(a), np.ones((4,)))
        np.testing.assert_array_equal(np.asarray(b), 2 * np.ones((4,)))


def test_vec_width_transaction_arithmetic():
    """float4-style widths: vec_bytes / itemsize, floored at one element."""
    assert ki.vec_width(jnp.float32, flavor="gpu") == 4
    assert ki.vec_width(jnp.bfloat16, flavor="gpu") == 8
    assert ki.vec_width(jnp.int8, flavor="gpu") == 16
    assert ki.vec_width(jnp.float64, flavor="gpu") == 2
    # TPU flavor: a full lane-row of f32.
    assert ki.vec_width(jnp.float32, flavor="tpu") == ki.LANES
    for backend in ki.available_backends():
        assert ki.vec_width(jnp.float32, flavor=backend) >= 1


def test_every_backend_resolves_a_flavor():
    for backend in ki.available_backends():
        flavor = ki.get_flavor(backend)
        assert flavor.name in ("tpu", "gpu")
        assert flavor.vec_bytes > 0


def test_unknown_flavor_raises():
    with pytest.raises(ValueError, match="unknown intrinsics flavor"):
        ki.get_flavor("cuda-graphs")
