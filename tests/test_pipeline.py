"""GPipe over the pod axis == sequential stage execution (subprocess)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import gpipe_forward

mesh = jax.make_mesh((2, 4), ("pod", "data"))
key = jax.random.PRNGKey(0)
n_stages, d = 2, 32
Ws = jax.random.normal(key, (n_stages, d, d), jnp.float32) / np.sqrt(d)
bs = jax.random.normal(jax.random.fold_in(key, 1), (n_stages, d), jnp.float32)

def stage(params, x):
    W, b = params
    return jnp.tanh(x @ W + b)

x = jax.random.normal(jax.random.fold_in(key, 2), (16, d), jnp.float32)

# Sequential reference.
ref = x
for i in range(n_stages):
    ref = stage((Ws[i], bs[i]), ref)

with mesh:
    got = jax.jit(lambda p, xx: gpipe_forward(
        mesh, stage, p, xx, n_micro=4))((Ws, bs), x)

np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_gpipe_matches_sequential(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "pipe.py"
    script.write_text(SCRIPT)
    out = subprocess.run([sys.executable, str(script), src],
                         capture_output=True, text=True, timeout=300)
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr[-3000:]
