"""End-to-end behaviour tests: per-arch smoke (deliverable f) + consistency.

For every assigned architecture, the REDUCED config runs one forward/train
step on CPU asserting output shapes + finiteness, and the prefill->decode
path is checked for *consistency with the full forward pass* -- the KV/ring/
recurrent-state caches must reproduce the same last-token logits as a fresh
full-sequence forward (the strongest cheap invariant a serving stack has).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as C
from repro.models import lm

ARCHS = C.list_archs()


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.is_encdec:
        batch["src_embeds"] = jax.random.normal(ks[2], (B, S, cfg.d_model),
                                                jnp.float32) * 0.1
    if cfg.num_prefix_embeds:
        batch["vision_embeds"] = jax.random.normal(
            ks[3], (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch, rng):
    """One forward/train step on the reduced config: shapes + no NaNs."""
    cfg = C.get_config(arch, smoke=True)
    assert len(cfg.layer_pattern()) == cfg.n_layers
    params = lm.init_params(rng, cfg)
    batch = _batch(cfg, rng)
    loss, metrics = jax.jit(
        lambda p, b: lm.forward_train(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    for k, v in metrics.items():
        assert np.isfinite(float(v)), f"{arch}: metric {k} non-finite"
    # Gradients exist and are finite for every parameter.
    grads = jax.jit(jax.grad(
        lambda p, b: lm.forward_train(p, cfg, b)[0]))(params, batch)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all(), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_prefill_decode_consistency(arch, rng):
    """decode_step(cache(prefill(t[:S-1])), t[S-1]) == prefill(t[:S]) logits.

    Run in float32: this is a cache-logic invariant (bf16 would only add
    rounding noise between the blockwise and direct softmax paths).
    """
    import dataclasses
    cfg = C.get_config(arch, smoke=True)
    # float32 for exactness; high capacity_factor because capacity-*dropped*
    # tokens are a documented source of batched-vs-incremental divergence in
    # capacity-based MoE (serving uses dropless capacity) -- this test checks
    # the cache logic, not drop policy.
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=16.0)
    B, S = 2, 16
    batch = _batch(cfg, rng, B=B, S=S)
    params = lm.init_params(rng, cfg)
    kwargs = {k: batch[k] for k in ("src_embeds", "vision_embeds")
              if k in batch}
    cache_len = S + cfg.num_prefix_embeds + 4

    # Ground truth: full prefill over S tokens.
    full_logits, _ = jax.jit(lambda p, t: lm.prefill(
        p, cfg, t, cache_len=cache_len, **kwargs))(params, batch["tokens"])

    # Cached path: prefill S-1 then one decode step with token S-1.
    kwargs_m1 = dict(kwargs)
    if "src_embeds" in kwargs_m1:
        pass  # encoder input unchanged (full source)
    part_logits, caches = jax.jit(lambda p, t: lm.prefill(
        p, cfg, t, cache_len=cache_len, **kwargs_m1))(
            params, batch["tokens"][:, :S - 1])
    pos = S - 1 + cfg.num_prefix_embeds
    step_logits, _ = jax.jit(lambda p, c, t: lm.decode_step(
        p, cfg, c, t, jnp.asarray(pos, jnp.int32)))(
            params, caches, batch["tokens"][:, S - 1:S])

    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full_logits),
        rtol=1e-3, atol=1e-3,
        err_msg=f"{arch}: decode path diverges from full forward")


def test_moe_router_invariants(rng):
    from repro.models import moe as M
    cfg = C.get_config("moonshot-v1-16b-a3b", smoke=True)
    params = M.init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 8, cfg.d_model), jnp.float32) * 0.3
    y, aux = M.moe_forward(params, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["lb_loss"]) >= 0.99  # >= 1 at balance by construction
    # Zero input -> router uniform-ish, output finite.
    y0, _ = M.moe_forward(params, cfg, jnp.zeros_like(x))
    assert np.isfinite(np.asarray(y0)).all()


def test_moe_capacity_drop(rng):
    """With capacity_factor << 1 tokens drop but output stays finite."""
    import dataclasses
    from repro.models import moe as M
    cfg = C.get_config("moonshot-v1-16b-a3b", smoke=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.1)
    params = M.init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 32, cfg.d_model), jnp.float32)
    y, _ = M.moe_forward(params, cfg, x)
    assert np.isfinite(np.asarray(y)).all()


def test_blockwise_attention_matches_naive(rng):
    from repro.models.attention import blockwise_attention
    B, S, K, G, hd = 2, 64, 2, 2, 16
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    qpos = jnp.arange(S)
    got = blockwise_attention(q, k, v, qpos=qpos, causal=True, kv_block=16)
    # naive reference
    s = jnp.einsum("bskgd,btkd->bskgt", q / np.sqrt(hd), k)
    mask = qpos[:, None] >= jnp.arange(S)[None, :]
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bskgt,btkd->bskgd", p, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_blockwise_attention_window(rng):
    from repro.models.attention import blockwise_attention
    B, S, K, G, hd, W = 1, 48, 1, 2, 8, 8
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    qpos = jnp.arange(S)
    got = blockwise_attention(q, k, v, qpos=qpos, causal=True, window=W,
                              kv_block=16)
    s = jnp.einsum("bskgd,btkd->bskgt", q / np.sqrt(hd), k)
    kpos = jnp.arange(S)
    mask = (qpos[:, None] >= kpos[None, :]) & (qpos[:, None] - kpos[None, :] < W)
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    want = jnp.einsum("bskgt,btkd->bskgd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_rglru_decode_matches_forward(rng):
    """Step-by-step RG-LRU decode reproduces the scan-based forward."""
    from repro.models import recurrent as R
    cfg = C.get_config("recurrentgemma-2b", smoke=True)
    params = R.init_rglru_block(rng, cfg)
    x = jax.random.normal(rng, (2, 12, cfg.d_model), jnp.float32) * 0.3
    y_full, cache = R.rglru_forward(params, cfg, x, return_cache=True)
    cache0 = R.init_rglru_cache(cfg, 2)
    ys = []
    c = cache0
    for t in range(12):
        yt, c = R.rglru_decode(params, cfg, x[:, t:t + 1], c)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(c["h"]), np.asarray(cache["h"]),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_decode_matches_forward(rng):
    """One-step mLSTM decode continues the chunkwise forward exactly."""
    from repro.models import recurrent as R
    cfg = C.get_config("xlstm-1.3b", smoke=True)
    params = R.init_mlstm_block(rng, cfg)
    T = 2 * cfg.mlstm_chunk
    x = jax.random.normal(rng, (2, T + 1, cfg.d_model), jnp.float32) * 0.3
    # Full forward over T+1 is not chunk-divisible; instead compare:
    # forward over T with cache, then decode step T+1 == sequential decode.
    y_full, cache = R.mlstm_forward(params, cfg, x[:, :T], return_cache=True)
    c = R.init_mlstm_cache(cfg, 2)
    for t in range(T):
        yt, c = R.mlstm_decode(params, cfg, x[:, t:t + 1], c)
        np.testing.assert_allclose(
            np.asarray(yt[:, 0]), np.asarray(y_full[:, t]), rtol=5e-3,
            atol=5e-3, err_msg=f"mlstm t={t}")
    # States agree at the boundary.
    np.testing.assert_allclose(np.asarray(c["C"]), np.asarray(cache["C"]),
                               rtol=5e-3, atol=5e-3)
    y1, _ = R.mlstm_decode(params, cfg, x[:, T:T + 1], cache)
    y2, _ = R.mlstm_decode(params, cfg, x[:, T:T + 1], c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=5e-3,
                               atol=5e-3)
