"""pallas-gpu backend conformance, run under Pallas interpret mode on CPU.

Everything goes through the public forge surface with
``backend="pallas-gpu"`` (or the scoped ``repro.use_backend``), so the
whole route -- registry resolution, the ``gpu_interpret`` tuning policy,
block-size arithmetic, the decoupled-lookback scan kernel, the
partials-fold mapreduce and matvec/vecmat, and the radix composition on
top of them -- is exercised exactly as a GPU user would hit it.  Shapes are fuzzed around the *GPU* block boundary
(``gpu_threads * nitem * vec_width``), which is where lookback carries,
masking and grid arithmetic all change behavior.

CI runs this file in the dedicated ``gpu-interpret`` job.
"""
import threading
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_trees_close, make_operand
import repro
from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched, Segmented
from repro.kernels import gpu as gpu_k
from repro.kernels import ops
from repro.kernels import ref

GPU = "pallas-gpu"
POL = ki.resolve_tuning("gpu_interpret")


def _seed(*parts):
    return zlib.crc32("|".join(str(p) for p in parts).encode())


def _block(nitem_field, dtype=jnp.float32):
    """The pallas-gpu tile extent under the gpu_interpret policy."""
    nitem = getattr(POL, nitem_field)
    return POL.gpu_threads * nitem * ki.vec_width(dtype, flavor="gpu")


def _boundary_ns(block):
    return [0, 1, block - 1, block, block + 1, 3 * block + 5]


# ---------------------------------------------------------------------------
# scan @ flat / @ batched
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op_name", ["add", "logsumexp", "mat2_mul"])
@pytest.mark.parametrize("inclusive", [True, False])
def test_scan_flat_gpu(op_name, inclusive):
    op = alg.STD_OPS[op_name]
    block = _block("nitem_scan")
    nprng = np.random.default_rng(_seed("scan-flat", op_name, inclusive))
    for n in _boundary_ns(block):
        x = make_operand(op_name, nprng, (n,))
        got = forge.scan(op, x, inclusive=inclusive, backend=GPU)
        want = ref.ref_scan(op, x, inclusive=inclusive)
        tol = 1e-2 if op_name == "mat2_mul" else 1e-3
        assert_trees_close(got, want, rtol=tol, atol=tol,
                           err=f"scan@flat gpu {op_name} n={n}")


def test_scan_flat_gpu_reverse():
    op = alg.STD_OPS["add"]
    block = _block("nitem_scan")
    nprng = np.random.default_rng(_seed("scan-rev"))
    x = make_operand("add", nprng, (block + 3,))
    got = forge.scan(op, x, reverse=True, backend=GPU)
    want = ref.ref_scan(op, x, reverse=True)
    assert_trees_close(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("op_name", ["add", "quaternion_mul"])
def test_scan_batched_gpu(op_name):
    op = alg.STD_OPS[op_name]
    block = _block("nitem_scan")
    nprng = np.random.default_rng(_seed("scan-batched", op_name))
    for (b, n) in [(0, 5), (3, 0), (1, 1), (3, 7),
                   (2, block - 1), (1, block), (2, block + 1)]:
        x = make_operand(op_name, nprng, (b, n))
        got = forge.scan(op, x, layout=Batched(), backend=GPU)
        want = ref.ref_batched_scan(op, x)
        assert_trees_close(got, want, rtol=1e-3, atol=1e-3,
                           err=f"scan@batched gpu {op_name} shape=({b},{n})")


def test_scan_gpu_int_dtype_bit_exact():
    block = _block("nitem_scan", jnp.int32)
    x = jnp.asarray(
        np.random.default_rng(_seed("int")).integers(-50, 50, block + 7),
        jnp.int32)
    got = forge.scan(alg.ADD, x, backend=GPU)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.cumsum(np.asarray(x), dtype=np.int32))


# ---------------------------------------------------------------------------
# mapreduce @ flat / @ batched
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op_name", ["add", "max", "logsumexp"])
def test_mapreduce_flat_gpu(op_name):
    op = alg.STD_OPS[op_name]
    block = _block("nitem_reduce")
    nprng = np.random.default_rng(_seed("mr-flat", op_name))
    for n in [1, block - 1, block, block + 1, 3 * block + 5]:
        x = make_operand(op_name, nprng, (n,))
        got = forge.mapreduce(lambda v: v, op, x, backend=GPU)
        want = ref.ref_mapreduce(lambda v: v, op, x)
        assert_trees_close(got, want, rtol=1e-5, atol=1e-5,
                           err=f"mapreduce@flat gpu {op_name} n={n}")


def test_mapreduce_flat_gpu_nontrivial_f():
    nprng = np.random.default_rng(_seed("mr-f"))
    block = _block("nitem_reduce")
    x = make_operand("add", nprng, (2 * block + 9,))
    got = forge.mapreduce(lambda v: v * v, alg.ADD, x, backend=GPU)
    want = ref.ref_mapreduce(lambda v: v * v, alg.ADD, x)
    assert_trees_close(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("axis", [0, 1])
def test_mapreduce_2d_axis_gpu(axis):
    nprng = np.random.default_rng(_seed("mr-2d", axis))
    x = make_operand("add", nprng, (3, _block("nitem_reduce") + 2))
    got = forge.mapreduce(lambda v: v, alg.ADD, x, axis=axis, backend=GPU)
    want = ref.ref_mapreduce(lambda v: v, alg.ADD, x, axis=axis)
    assert_trees_close(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op_name", ["add", "quaternion_mul"])
def test_mapreduce_batched_gpu(op_name):
    op = alg.STD_OPS[op_name]
    block = _block("nitem_reduce")
    nprng = np.random.default_rng(_seed("mr-batched", op_name))
    for (b, n) in [(0, 5), (3, 0), (3, 7), (2, block), (2, block + 1)]:
        x = make_operand(op_name, nprng, (b, n))
        got = forge.mapreduce(lambda v: v, op, x, layout=Batched(),
                              backend=GPU)
        want = ref.ref_batched_mapreduce(lambda v: v, op, x)
        assert_trees_close(got, want, rtol=1e-5, atol=1e-5,
                           err=f"mapreduce@batched gpu {op_name} ({b},{n})")


# ---------------------------------------------------------------------------
# matvec / vecmat @ flat / @ batched
# ---------------------------------------------------------------------------


def _mv_shapes():
    rows = POL.matvec_rows * ki.WARP
    return [(1, 1), (3, 5), (rows - 1, 4), (rows, 3), (rows + 1, 7)]


@pytest.mark.parametrize("op_name", ["add", "min"])
def test_matvec_gpu(op_name):
    op = alg.STD_OPS[op_name]
    nprng = np.random.default_rng(_seed("mv", op_name))
    f = lambda xi, aij: xi * aij
    for (n, p) in _mv_shapes():
        A = jnp.asarray(nprng.standard_normal((n, p)), jnp.float32)
        x = jnp.asarray(nprng.standard_normal((n,)), jnp.float32)
        got = forge.matvec(f, op, A, x, backend=GPU)
        want = ref.ref_matvec(f, op, A, x)
        assert_trees_close(got, want, rtol=1e-4, atol=1e-4,
                           err=f"matvec gpu {op_name} ({n},{p})")


@pytest.mark.parametrize("op_name", ["add", "min"])
def test_vecmat_gpu(op_name):
    op = alg.STD_OPS[op_name]
    nprng = np.random.default_rng(_seed("vm", op_name))
    cols = POL.vecmat_cols * ki.vec_width(jnp.float32, flavor="gpu")
    f = lambda aij, xj: aij * xj
    for (n, p) in [(1, 1), (5, 3), (4, cols - 1), (3, cols), (7, cols + 1)]:
        A = jnp.asarray(nprng.standard_normal((n, p)), jnp.float32)
        x = jnp.asarray(nprng.standard_normal((p,)), jnp.float32)
        got = forge.vecmat(f, op, A, x, backend=GPU)
        want = ref.ref_vecmat(f, op, A, x)
        assert_trees_close(got, want, rtol=1e-4, atol=1e-4,
                           err=f"vecmat gpu {op_name} ({n},{p})")


def test_batched_matvec_vecmat_gpu():
    nprng = np.random.default_rng(_seed("bmv"))
    rows = POL.matvec_rows * ki.WARP
    f = lambda u, v: u * v
    A = jnp.asarray(nprng.standard_normal((3, rows + 2, 5)), jnp.float32)
    x = jnp.asarray(nprng.standard_normal((3, rows + 2)), jnp.float32)
    got = forge.matvec(f, alg.ADD, A, x, layout=Batched(), backend=GPU)
    want = ref.ref_batched_matvec(f, alg.ADD, A, x)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4, err="batched matvec")
    xv = jnp.asarray(nprng.standard_normal((3, 5)), jnp.float32)
    got = forge.vecmat(f, alg.ADD, A, xv, layout=Batched(), backend=GPU)
    want = ref.ref_batched_vecmat(f, alg.ADD, A, xv)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4, err="batched vecmat")


# ---------------------------------------------------------------------------
# linear_recurrence, copy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("with_h0", [False, True])
@pytest.mark.parametrize("reverse", [False, True])
def test_linear_recurrence_gpu(with_h0, reverse):
    nprng = np.random.default_rng(_seed("linrec", with_h0, reverse))
    block = _block("nitem_scan")
    B, T, C = 2, block + 3, 3
    a = jnp.asarray(nprng.uniform(0.5, 1.0, (B, T, C)), jnp.float32)
    b = jnp.asarray(nprng.standard_normal((B, T, C)), jnp.float32)
    h0 = (jnp.asarray(nprng.standard_normal((B, C)), jnp.float32)
          if with_h0 else None)
    got = forge.linear_recurrence(a, b, h0, reverse=reverse, backend=GPU)
    want = ref.ref_linear_recurrence(a, b, h0, reverse=reverse)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4)


def test_copy_gpu():
    nprng = np.random.default_rng(_seed("copy"))
    block = _block("nitem_copy")
    for n in [1, block - 1, block, block + 1]:
        x = jnp.asarray(nprng.standard_normal((n,)), jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(forge.copy(x, backend=GPU)), np.asarray(x))
    x2 = jnp.asarray(nprng.standard_normal((5, 7)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(forge.copy(x2, backend=GPU)), np.asarray(x2))


# ---------------------------------------------------------------------------
# The sort family composes on top of the gpu scan/mapreduce routes.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.uint32, jnp.float32])
def test_sort_pairs_gpu(dtype):
    block = _block("nitem_scan")
    nprng = np.random.default_rng(_seed("sort", np.dtype(dtype).name))
    for n in [0, 1, 37, block + 3]:
        if np.issubdtype(np.dtype(dtype), np.floating):
            keys = jnp.asarray(nprng.standard_normal(n), dtype)
        else:
            keys = jnp.asarray(nprng.integers(0, 1 << 16, n), dtype)
        vals = jnp.arange(n, dtype=jnp.int32)
        gk, gv = forge.sort_pairs(keys, vals, backend=GPU)
        wk, wv = ref.ref_sort_pairs(keys, vals)
        np.testing.assert_array_equal(np.asarray(gk), np.asarray(wk),
                                      err_msg=f"sort_pairs keys n={n}")
        np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv),
                                      err_msg=f"sort_pairs vals n={n}")


def test_sort_argsort_topk_gpu():
    nprng = np.random.default_rng(_seed("satk"))
    keys = jnp.asarray(nprng.integers(0, 1000, 101), jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(forge.sort(keys, backend=GPU)),
        np.asarray(ref.ref_sort(keys)))
    np.testing.assert_array_equal(
        np.asarray(forge.argsort(keys, backend=GPU)),
        np.asarray(ref.ref_argsort(keys)))
    gv, gi = forge.top_k(keys, 7, backend=GPU)
    wv, wi = ref.ref_top_k(keys, 7)
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


# ---------------------------------------------------------------------------
# Selection surface: scoping, fallback, error reporting.
# ---------------------------------------------------------------------------


def test_use_backend_scopes_and_nests():
    before = repro.current_backend()
    with repro.use_backend(GPU):
        assert repro.current_backend() == GPU
        with repro.use_backend("xla"):
            assert repro.current_backend() == "xla"
        assert repro.current_backend() == GPU
    assert repro.current_backend() == before


def test_use_backend_is_thread_local():
    seen = {}

    def worker():
        seen["inner"] = repro.current_backend()

    with repro.use_backend(GPU):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen["inner"] != GPU


def test_use_backend_routes_dispatch():
    nprng = np.random.default_rng(_seed("scoped"))
    x = make_operand("add", nprng, (_block("nitem_scan") + 1,))
    with repro.use_backend(GPU):
        got = forge.scan(alg.ADD, x)
    want = ref.ref_scan(alg.ADD, x)
    assert_trees_close(got, want, rtol=1e-3, atol=1e-3)


def test_supports_reports_gpu_coverage():
    for route in ("scan@flat", "scan@batched", "mapreduce@flat",
                  "matvec@flat", "vecmat@batched", "sort_pairs@flat",
                  "top_k@flat", "linear_recurrence@batched"):
        assert repro.supports(route, GPU), route
    # Segmented scan/mapreduce deliberately have no gpu route yet.
    assert not repro.supports("scan@segmented", GPU)
    assert not repro.supports("mapreduce@segmented", GPU)
    assert GPU in repro.available_backends()


def test_segmented_falls_back_to_xla_under_gpu_scope():
    nprng = np.random.default_rng(_seed("seg"))
    x = make_operand("add", nprng, (23,))
    flags = jnp.zeros(23, jnp.int32).at[jnp.array([0, 7, 15])].set(1)
    with repro.use_backend(GPU):
        got = forge.scan(alg.ADD, x, layout=Segmented(flags=flags))
    want = ref.ref_segmented_scan(alg.ADD, x, flags=flags)
    assert_trees_close(got, want, rtol=1e-5, atol=1e-5)


def test_unknown_backend_errors_name_the_route():
    x = jnp.ones(8, jnp.float32)
    with pytest.raises(ValueError, match=r"scan@flat: unknown backend"):
        forge.scan(alg.ADD, x, backend="pallas-rocm")
    with pytest.raises(ValueError, match="unknown backend"):
        repro.use_backend("metal").__enter__()


def test_supports_raises_on_unknown_names():
    # Mirrors dispatch/use_backend: unknown *names* are user errors, not a
    # quiet False that reads as "would fall back to xla".
    with pytest.raises(ValueError, match="unknown backend"):
        repro.supports("scan@flat", "metal")
    with pytest.raises(ValueError, match="unknown route"):
        repro.supports("scan@bogus", "xla")


# ---------------------------------------------------------------------------
# Hardware gate: the single-probe lookback is exact only on in-order grids,
# so it must never compile for parallel hardware -- the kernel entry points
# refuse, and the registered routes dispatch to xla instead.
# ---------------------------------------------------------------------------


def test_lookback_scan_refuses_to_compile_for_hardware():
    assert not gpu_k.HARDWARE_LOOKBACK_READY  # flip the gate when it lands
    x = jnp.arange(8, dtype=jnp.float32)
    with pytest.raises(NotImplementedError, match="acquire-spin"):
        gpu_k.scan_flat_gpu(alg.ADD, x, interpret=False)
    with pytest.raises(NotImplementedError, match="acquire-spin"):
        gpu_k.scan_batched_gpu(alg.ADD, x[None], interpret=False)


def test_scan_routes_fall_back_to_xla_when_lookback_unavailable():
    # interpret=False is exactly what the registered wrappers resolve on a
    # real GPU platform; the guard must hand the call to xla, not race.
    nprng = np.random.default_rng(_seed("gate"))
    x1 = make_operand("add", nprng, (37,))
    got = ops._scan_gpu(alg.ADD, x1, inclusive=False, interpret=False)
    assert_trees_close(got, ref.ref_scan(alg.ADD, x1, inclusive=False),
                       rtol=1e-5, atol=1e-5)
    x2 = make_operand("add", nprng, (3, 21))
    got = ops._batched_scan_gpu(alg.ADD, x2, interpret=False)
    assert_trees_close(got, ref.ref_batched_scan(alg.ADD, x2),
                       rtol=1e-5, atol=1e-5)
    a = jnp.asarray(nprng.uniform(0.5, 1.0, (2, 9, 3)), jnp.float32)
    b = jnp.asarray(nprng.standard_normal((2, 9, 3)), jnp.float32)
    got = ops._linrec_gpu(a, b, interpret=False)
    assert_trees_close(got, ref.ref_linear_recurrence(a, b),
                       rtol=1e-5, atol=1e-5)
