"""Radix sort / argsort / top-k family vs the numpy/Python-loop oracles.

Covers every supported key dtype (unsigned, signed, f32/bf16 with negatives,
±0.0, ±inf and NaNs -- the pinned NaN-last total order), pytree payloads,
stability under heavy duplication, descending order, the key_bits fast path,
both segment descriptors, and zero-length inputs, on both the xla and
pallas-interpret backends.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_trees_close
from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Segmented
from repro.kernels import ref

BACKENDS = ["xla", "pallas-interpret"]

# Every dtype on xla (cheap); the interpret kernel bodies are exercised on a
# spread of widths/transforms (unsigned, signed, float, bfloat) at sizes
# keeping the pass count x grid-step budget test-suite friendly.
DTYPES_XLA = ["uint8", "uint16", "uint32", "int8", "int32",
              "float32", "bfloat16"]
DTYPES_INTERPRET = ["uint8", "int16", "float32", "bfloat16"]


def _keys(dtype, n, seed=0, specials=True):
    rng = np.random.default_rng(seed)
    dt = jnp.dtype(dtype)
    if jnp.issubdtype(dt, jnp.integer):
        info = jnp.iinfo(dt)
        return jnp.asarray(
            rng.integers(info.min, int(info.max) + 1, n), dt)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32), dt)
    if specials and n >= 16:
        x = (x.at[1].set(jnp.nan).at[5].set(-jnp.nan)
              .at[7].set(jnp.inf).at[9].set(-jnp.inf)
              .at[11].set(0.0).at[13].set(-0.0))
    return x


def _equal_with_nans(got, want, err=""):
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64),
        rtol=0, atol=0, equal_nan=True, err_msg=err)


@pytest.mark.parametrize("backend,dtype",
                         [("xla", d) for d in DTYPES_XLA] +
                         [("pallas-interpret", d) for d in DTYPES_INTERPRET])
def test_sort_matches_oracle(backend, dtype):
    n = 300 if backend == "xla" or jnp.dtype(dtype).itemsize < 4 else 150
    k = _keys(dtype, n)
    got = forge.sort(k, backend=backend)
    assert got.dtype == k.dtype
    _equal_with_nans(got, ref.ref_sort(k), err=f"{dtype}/{backend}")


@pytest.mark.parametrize("backend,dtype",
                         [("xla", d) for d in DTYPES_XLA] +
                         [("pallas-interpret", d) for d in DTYPES_INTERPRET])
def test_argsort_stable_and_exact(backend, dtype):
    """Heavy duplication: the permutation itself must match the stable
    oracle exactly (not just produce equal keys)."""
    rng = np.random.default_rng(3)
    n = 257 if backend == "xla" else 130
    dt = jnp.dtype(dtype)
    if jnp.issubdtype(dt, jnp.integer):
        k = jnp.asarray(rng.integers(0, 7, n), dt)   # ~37 ties per value
    else:
        k = jnp.asarray(rng.integers(0, 7, n).astype(np.float32), dt)
        k = k.at[2].set(jnp.nan).at[40].set(jnp.nan).at[17].set(-0.0)
    got = forge.argsort(k, backend=backend)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.ref_argsort(k)),
                                  err_msg=f"{dtype}/{backend}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("descending", [False, True])
def test_sort_pairs_pytree_payload(backend, descending):
    """Arbitrary pytree payload, incl. a 2-D leaf, rides the permutation."""
    rng = np.random.default_rng(4)
    n = 300 if backend == "xla" else 260
    k = jnp.asarray(rng.integers(0, 50, n), jnp.uint16)
    payload = {"idx": jnp.arange(n, dtype=jnp.int32),
               "w": (jnp.asarray(rng.normal(size=n), jnp.float32),
                     jnp.asarray(rng.normal(size=(n, 3)), jnp.float32))}
    ks, vs = forge.sort_pairs(k, payload, descending=descending,
                              backend=backend)
    rk, rv = ref.ref_sort_pairs(k, payload, descending=descending)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rk))
    assert_trees_close(vs, rv, rtol=0, atol=0,
                       err=f"{backend}/desc={descending}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_top_k_ties_stable(backend):
    rng = np.random.default_rng(5)
    n = 200
    k = jnp.asarray(rng.integers(0, 9, n).astype(np.float32))
    for largest in (True, False):
        v, i = forge.top_k(k, 17, largest=largest, backend=backend)
        rv, ri = ref.ref_top_k(k, 17, largest=largest)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_top_k_nan_ranks_above_inf():
    k = jnp.asarray([1.0, jnp.inf, jnp.nan, -jnp.inf, 2.0], jnp.float32)
    v, i = forge.top_k(k, 2, backend="xla")
    assert np.isnan(np.asarray(v)[0]) and int(i[0]) == 2
    assert np.isinf(np.asarray(v)[1]) and int(i[1]) == 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_key_bits_small_range(backend):
    """key_bits caps the pass count; result identical to the full sort."""
    rng = np.random.default_rng(6)
    k = jnp.asarray(rng.integers(0, 13, 300), jnp.uint32)   # fits in 4 bits
    got = forge.argsort(k, key_bits=4, backend=backend)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.ref_argsort(k)))


def test_key_bits_validation():
    with pytest.raises(ValueError):
        forge.sort(jnp.zeros((4,), jnp.float32), key_bits=8, backend="xla")
    with pytest.raises(ValueError):
        forge.sort(jnp.zeros((4,), jnp.int32), key_bits=8, backend="xla")
    with pytest.raises(ValueError):
        forge.sort(jnp.zeros((4,), jnp.uint8), key_bits=0, backend="xla")
    with pytest.raises(ValueError):
        forge.sort(jnp.zeros((4,), jnp.uint8), key_bits=9, backend="xla")
    with pytest.raises(TypeError):
        forge.sort(jnp.zeros((4,), jnp.complex64), backend="xla")


@pytest.mark.parametrize("backend", BACKENDS)
def test_zero_length_inputs(backend):
    empty = jnp.zeros((0,), jnp.float32)
    assert forge.sort(empty, backend=backend).shape == (0,)
    assert forge.argsort(empty, backend=backend).shape == (0,)
    ks, vs = forge.sort_pairs(empty, jnp.zeros((0,), jnp.int32),
                              backend=backend)
    assert ks.shape == (0,) and vs.shape == (0,)
    v, i = forge.top_k(empty, 0, backend=backend)
    assert v.shape == (0,) and i.shape == (0,)
    with pytest.raises(ValueError):
        forge.top_k(empty, 1, backend=backend)


def test_radix_bit_transform_roundtrip():
    """key_to_radix_bits is order-preserving and (canonicalization aside)
    invertible for every supported dtype."""
    rng = np.random.default_rng(7)
    for dtype in DTYPES_XLA:
        k = _keys(dtype, 64, seed=8)
        bits = alg.key_to_radix_bits(k)
        assert jnp.issubdtype(bits.dtype, jnp.unsignedinteger)
        assert bits.dtype.itemsize == jnp.dtype(dtype).itemsize
        # order preservation against the oracle order
        order = np.asarray(ref.ref_argsort(k))
        b = np.asarray(bits)[order].astype(np.uint64)
        assert (np.diff(b) >= 0).all(), dtype
        back = alg.radix_bits_to_key(bits, k.dtype)
        _equal_with_nans(back, jnp.where(k == 0, jnp.zeros_like(k), k)
                         if jnp.issubdtype(jnp.dtype(dtype), jnp.floating)
                         else k, err=dtype)


# ---------------------------------------------------------------------------
# Segmented variants.
# ---------------------------------------------------------------------------

OFFSETS = [0, 7, 7, 40, 41, 170, 300]


def _flags_from_offsets(offsets, n):
    f = np.zeros(n, np.int32)
    f[[o for o in offsets[:-1] if o < n]] = 1
    f[0] = 1
    return jnp.asarray(f)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("variant", ["offsets", "flags"])
def test_segmented_sort_and_argsort(backend, variant):
    rng = np.random.default_rng(9)
    n = OFFSETS[-1]
    k = jnp.asarray(rng.integers(0, 2**16, n), jnp.uint16)
    kw = ({"offsets": jnp.asarray(OFFSETS, jnp.int32)}
          if variant == "offsets"
          else {"flags": _flags_from_offsets(OFFSETS, n)})
    got = forge.sort(k, backend=backend, layout=Segmented(**kw))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.ref_segmented_sort(k, offsets=OFFSETS)),
        err_msg=f"{backend}/{variant}")
    ga = forge.argsort(k, backend=backend, layout=Segmented(**kw))
    np.testing.assert_array_equal(
        np.asarray(ga),
        np.asarray(ref.ref_segmented_argsort(k, offsets=OFFSETS)),
        err_msg=f"{backend}/{variant}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_segmented_sort_pairs_floats_with_specials(backend):
    n = OFFSETS[-1] if backend == "xla" else 170
    offsets = OFFSETS if backend == "xla" else [0, 7, 7, 40, 41, 170]
    k = _keys("float32", n, seed=10)
    vals = jnp.arange(n, dtype=jnp.int32)
    ks, vs = forge.sort_pairs(
        k, vals, layout=Segmented(offsets=jnp.asarray(offsets, jnp.int32)),
        backend=backend)
    rk, rv = ref.ref_segmented_sort_pairs(k, vals, offsets=offsets)
    _equal_with_nans(ks, rk, err=backend)
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(rv))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("variant", ["offsets", "flags"])
def test_segmented_top_k_ragged(backend, variant):
    """k exceeds some segment lengths; empty + never-started segments fill."""
    rng = np.random.default_rng(11)
    n = OFFSETS[-1]
    k = jnp.asarray(rng.normal(size=n), jnp.float32)
    if variant == "offsets":
        kw = {"offsets": jnp.asarray(OFFSETS, jnp.int32)}
        ns = len(OFFSETS) - 1
        rv, ri = ref.ref_segmented_top_k(k, 9, offsets=OFFSETS)
    else:
        # Flags cannot express empty segments: segments are the flagged
        # runs, numbered in flag order (the segmented_mapreduce convention),
        # plus never-started trailing ones up to num_segments.
        kw = {"flags": _flags_from_offsets(OFFSETS, n), "num_segments": 8}
        ns = 8
        rv, ri = ref.ref_segmented_top_k(
            k, 9, flags=np.asarray(kw["flags"]), num_segments=8)
    v, i = forge.top_k(k, 9, backend=backend, layout=Segmented(**kw))
    assert v.shape == (ns, 9) and i.shape == (ns, 9)
    _equal_with_nans(v, rv, err=f"{backend}/{variant}")
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    # declared-but-elementless segments come back entirely filled: the empty
    # offsets segment, or the never-started trailing flag segments
    empty_row = 1 if variant == "offsets" else ns - 1
    assert np.isneginf(np.asarray(v)[empty_row]).all()
    assert (np.asarray(i)[empty_row] == -1).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_segmented_sort_multiblock(backend):
    """Segments crossing kernel grid-step boundaries, incl. one segment
    spanning every block of the rank scan."""
    rng = np.random.default_rng(12)
    n = 2600
    k = jnp.asarray(rng.integers(0, 256, n), jnp.uint8)
    offsets = jnp.asarray([0, 1, 2047, 2050, 2600], jnp.int32)
    got = forge.sort(k, layout=Segmented(offsets=offsets), backend=backend)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.ref_segmented_sort(k, offsets=np.asarray(offsets))))
    # one segment spanning everything == the flat sort
    got = forge.sort(k, layout=Segmented(offsets=jnp.asarray([0, n], jnp.int32)),
                     backend=backend)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(forge.sort(k, backend=backend)))


@pytest.mark.parametrize("backend", BACKENDS)
def test_segmented_zero_length(backend):
    empty = jnp.zeros((0,), jnp.float32)
    got = forge.sort(empty, layout=Segmented(offsets=jnp.asarray([0, 0, 0])),
                     backend=backend)
    assert got.shape == (0,)
    v, i = forge.top_k(empty, 3, layout=Segmented(offsets=jnp.asarray([0, 0, 0])),
                       backend=backend)
    assert v.shape == (2, 3) and np.isneginf(np.asarray(v)).all()
    assert (np.asarray(i) == -1).all()


def test_segmented_descriptor_validation():
    k = jnp.arange(8, dtype=jnp.float32)
    with pytest.raises(ValueError):
        forge.sort(k, layout=Segmented(), backend="xla")
    with pytest.raises(ValueError):
        forge.top_k(k, 2, layout=Segmented(flags=jnp.ones(8, jnp.int32)),
                    backend="xla")   # flags need num_segments


# ---------------------------------------------------------------------------
# Consumer-shaped compositions.
# ---------------------------------------------------------------------------


def test_moe_dispatch_shape_sort_matches_argsort():
    """The moe_sharded dispatch pattern: stable expert-id sort_pairs must
    reproduce the XLA argsort-based stream it replaced."""
    rng = np.random.default_rng(13)
    E, n = 16, 512
    flat_e = jnp.asarray(rng.integers(0, E, n), jnp.int32)
    flat_t = jnp.arange(n, dtype=jnp.int32)
    flat_g = jnp.asarray(rng.normal(size=n), jnp.float32)
    se, (st, sg) = forge.sort_pairs(
        flat_e.astype(jnp.uint32), (flat_t, flat_g),
        key_bits=(E - 1).bit_length(), backend="xla")
    order = np.argsort(np.asarray(flat_e), kind="stable")
    np.testing.assert_array_equal(np.asarray(se), np.asarray(flat_e)[order])
    np.testing.assert_array_equal(np.asarray(st), np.asarray(flat_t)[order])
    np.testing.assert_array_equal(np.asarray(sg), np.asarray(flat_g)[order])


def test_sort_under_jit():
    """The composition is jit-traceable (static shapes throughout)."""
    k = jnp.asarray(np.random.default_rng(14).normal(size=256), jnp.float32)

    @jax.jit
    def f(keys):
        return forge.top_k(keys, 8, backend="xla")

    v, i = f(k)
    rv, ri = ref.ref_top_k(k, 8)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
