"""Uniform validation errors from the PrimitiveDef dispatch layer.

Every malformed call must fail with a ``ValueError`` whose message names the
primitive and the layout (``"scan@segmented: ..."``), raised *before* any
kernel work -- the rules live declaratively on the RouteDef rows
(``core/intrinsics.py``), so one test per rule covers every family that
declares it.
"""
import jax.numpy as jnp
import pytest

from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched, Flat, Segmented, Sharded

X = jnp.arange(8, dtype=jnp.float32)
FLAGS = jnp.ones((8,), jnp.int32)
OFFS = jnp.asarray([0, 3, 8], jnp.int32)


def _raises(match):
    return pytest.raises(ValueError, match=match)


# ---------------------------------------------------------------------------
# Segment descriptors: exactly one of flags/offsets, flag-variant reductions
# need a static num_segments.
# ---------------------------------------------------------------------------


def test_segmented_neither_descriptor():
    with _raises(r"scan@segmented: pass exactly one of flags= or offsets="):
        forge.scan(alg.ADD, X, layout=Segmented(), backend="xla")


def test_segmented_both_descriptors():
    with _raises(r"scan@segmented: pass exactly one"):
        forge.scan(alg.ADD, X, backend="xla",
                   layout=Segmented(flags=FLAGS, offsets=OFFS))


@pytest.mark.parametrize("call", [
    lambda lo: forge.mapreduce(lambda v: v, alg.ADD, X, layout=lo,
                               backend="xla"),
    lambda lo: forge.sort(X, layout=lo, backend="xla"),
    lambda lo: forge.top_k(X, 2, layout=lo, backend="xla"),
])
def test_descriptor_exclusivity_is_uniform_across_families(call):
    """The same rule fires with the same message shape for every segmented
    route -- it is one validator on the table, not per-family copies."""
    with _raises(r"@segmented: pass exactly one"):
        call(Segmented())
    with _raises(r"@segmented: pass exactly one"):
        call(Segmented(flags=FLAGS, offsets=OFFS))


def test_flag_variant_reduction_needs_num_segments():
    with _raises(r"mapreduce@segmented: .*num_segments"):
        forge.mapreduce(lambda v: v, alg.ADD, X,
                        layout=Segmented(flags=FLAGS), backend="xla")
    with _raises(r"top_k@segmented: .*num_segments"):
        forge.top_k(X, 2, layout=Segmented(flags=FLAGS), backend="xla")
    # The offsets variant carries its own extent: no num_segments needed.
    forge.mapreduce(lambda v: v, alg.ADD, X,
                    layout=Segmented(offsets=OFFS), backend="xla")


# ---------------------------------------------------------------------------
# Rank / shape checks per layout.
# ---------------------------------------------------------------------------


def test_batched_scan_rejects_non_rank2_leaves():
    with _raises(r"scan@batched: .*rank-2 leaves.*got shape \(8,\)"):
        forge.scan(alg.ADD, X, layout=Batched(), backend="xla")


def test_batched_mapreduce_rejects_non_rank2_leaves():
    with _raises(r"mapreduce@batched: .*rank-2"):
        forge.mapreduce(lambda v: v, alg.ADD, jnp.zeros((2, 3, 4)),
                        layout=Batched(), backend="xla")


def test_batched_matvec_rejects_flat_operands():
    A2, x1 = jnp.zeros((4, 5)), jnp.zeros((4,))
    with _raises(r"matvec@batched: .*rank-3"):
        forge.matvec(lambda x, a: x * a, alg.ADD, A2, x1,
                     layout=Batched(), backend="xla")
    with _raises(r"vecmat@batched: .*rank-3"):
        forge.vecmat(lambda a, x: a * x, alg.ADD, A2, x1,
                     layout=Batched(), backend="xla")


def test_flat_matvec_rejects_batched_operands():
    A3, x2 = jnp.zeros((2, 4, 5)), jnp.zeros((2, 4))
    with _raises(r"matvec@flat: .*rank-2"):
        forge.matvec(lambda x, a: x * a, alg.ADD, A3, x2, backend="xla")


def test_segmented_scan_rejects_rank2_leaves():
    with _raises(r"scan@segmented: .*rank-1"):
        forge.scan(alg.ADD, jnp.zeros((2, 4)),
                   layout=Segmented(offsets=OFFS), backend="xla")


def test_linear_recurrence_rank_check():
    with _raises(r"linear_recurrence@batched: .*rank-3"):
        forge.linear_recurrence(jnp.zeros((4, 4)), jnp.zeros((4, 4)),
                                layout=Batched(), backend="xla")


# ---------------------------------------------------------------------------
# Commutativity requirements.
# ---------------------------------------------------------------------------


def test_flat_mapreduce_rejects_non_commutative_op():
    q = tuple(jnp.ones((8,)) for _ in range(4))
    with _raises(r"mapreduce@flat: requires a commutative operator, got "
                 r"'quaternion_mul'"):
        forge.mapreduce(lambda v: v, alg.QUATERNION_MUL, q, backend="xla")


def test_segmented_mapreduce_accepts_non_commutative_op():
    """The segmented route is order-preserving by construction (segmented
    scan + gather-lasts), so -- unlike the flat route -- non-commutative
    operators are valid, per its table row."""
    a = jnp.linspace(0.5, 1.0, 8)
    out = forge.mapreduce(lambda v: v, alg.AFFINE, (a, a),
                          layout=Segmented(offsets=OFFS), backend="xla")
    assert all(l.shape == (2,) for l in out)


def test_batched_mapreduce_accepts_non_commutative_op():
    """The batched route reroutes through the order-preserving scan instead
    of raising -- the relaxation is declared on its table row."""
    q = tuple(jnp.ones((2, 8)) * c for c in (1.0, 0.1, 0.0, 0.0))
    out = forge.mapreduce(lambda v: v, alg.QUATERNION_MUL, q,
                          layout=Batched(), backend="xla")
    assert all(l.shape == (2,) for l in out)


# ---------------------------------------------------------------------------
# Unsupported (primitive, layout) pairs and layout-pinned kwargs.
# ---------------------------------------------------------------------------


def test_unsupported_layout_names_primitive_and_options():
    with _raises(r"sort: unsupported layout 'batched' .*flat.*segmented"):
        forge.sort(X, layout=Batched(), backend="xla")
    with _raises(r"copy: unsupported layout 'segmented'"):
        forge.copy(X, layout=Segmented(offsets=OFFS), backend="xla")


def test_layout_pinned_kwargs_rejected():
    with _raises(r"scan@batched: axis= is pinned"):
        forge.scan(alg.ADD, jnp.zeros((2, 4)), axis=1, layout=Batched(),
                   backend="xla")
    with _raises(r"scan@segmented: reverse= is pinned"):
        forge.scan(alg.ADD, X, reverse=True,
                   layout=Segmented(offsets=OFFS), backend="xla")


def test_layout_must_be_a_descriptor():
    with pytest.raises(TypeError, match="layout= must be a Layout"):
        forge.scan(alg.ADD, X, layout="batched", backend="xla")


# ---------------------------------------------------------------------------
# Sharded layout: mesh-aware validation.
# ---------------------------------------------------------------------------


def test_sharded_axis_must_name_a_mesh_axis():
    import jax
    mesh = jax.make_mesh((1,), ("model",))
    with _raises(r"scan@sharded: axis 'nope' is not an axis of the mesh "
                 r"\(axes: \('model',\)\)"):
        forge.scan(alg.ADD, X, layout=Sharded("nope", mesh=mesh),
                   backend="xla")
    with _raises(r"mapreduce@sharded: axis 'nope'"):
        forge.mapreduce(lambda v: v, alg.ADD, X,
                        layout=Sharded("nope", mesh=mesh), backend="xla")


def test_sharded_axis_must_be_a_name():
    with _raises(r"scan@sharded: Sharded\(axis=...\) must name a mesh axis"):
        forge.scan(alg.ADD, X, layout=Sharded(axis=""), backend="xla")


def test_sharded_mapreduce_rejects_non_commutative_op():
    """The cross-device fold of mapreduce@sharded requires commutativity
    (declared on its table row), unlike the order-preserving scan route."""
    q = tuple(jnp.ones((8,)) for _ in range(4))
    with _raises(r"mapreduce@sharded: requires a commutative operator, got "
                 r"'quaternion_mul'"):
        forge.mapreduce(lambda v: v, alg.QUATERNION_MUL, q,
                        layout=Sharded("model"), backend="xla")


def test_sharded_scan_pinned_kwargs():
    with _raises(r"scan@sharded: reverse= is pinned"):
        forge.scan(alg.ADD, X, reverse=True, layout=Sharded("model"),
                   backend="xla")


def test_sharded_unsupported_primitives_name_their_routes():
    with _raises(r"argsort: unsupported layout 'sharded'"):
        forge.argsort(X, layout=Sharded("model"), backend="xla")
    with _raises(r"copy: unsupported layout 'sharded'"):
        forge.copy(X, layout=Sharded("model"), backend="xla")


def test_registry_routes_all_have_impls_and_validation_fields():
    """Registry sanity: every declared route resolves an implementation on
    the portable backend, segmented routes all declare the descriptor
    requirement, and sharded routes all declare the mesh requirement (the
    rules the uniform errors above come from)."""
    for route in ki.iter_routes():
        assert ki.resolve_impl(route.key, "xla") is not None
        if route.layout == "segmented":
            assert route.needs_descriptor
        if route.layout == "sharded":
            assert route.needs_mesh
    assert ki.get_route("scan", Flat().kind).key == "scan@flat"


# ---------------------------------------------------------------------------
# Backend selection: unknown names fail loudly, uniformly naming the route.
# ---------------------------------------------------------------------------


def test_unknown_backend_string_names_the_route():
    with _raises(r"scan@flat: unknown backend 'pallas-rocm' \(available: "):
        forge.scan(alg.ADD, X, backend="pallas-rocm")
    with _raises(r"sort@flat: unknown backend 'cub'"):
        forge.sort(jnp.arange(8, dtype=jnp.uint32), backend="cub")
    with _raises(r"mapreduce@batched: unknown backend 'tirton'"):
        forge.mapreduce(lambda v: v, alg.ADD, jnp.ones((2, 8)),
                        layout=Batched(), backend="tirton")


def test_use_backend_rejects_unknown_names_up_front():
    """A typo fails at the `with` statement, not as a silent xla fallback."""
    with _raises(r"unknown backend 'metal' \(available: "):
        with ki.use_backend("metal"):
            pass  # pragma: no cover - never entered


def test_known_backend_without_route_falls_back_not_raises():
    """Known backends missing a native route fall back to the portable
    implementation -- only unknown *names* are errors."""
    got = forge.scan(
        alg.ADD, X,
        layout=Segmented(flags=jnp.zeros(8, jnp.int32).at[0].set(1)),
        backend="pallas-gpu")
    assert got.shape == X.shape
