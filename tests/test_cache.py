"""Unit tests for the slot-indexed decode cache (serving/cache.py).

Three layers of protection for slot recycling:

* address arithmetic: ``ring_slot`` / ``slot_position`` wraparound at
  ``cache_len``, pinned against a brute-force reference so the tests break
  if the engine's bookkeeping and the attention kernels ever disagree;
* tree surgery: ``scatter_slot`` writes exactly one slot (including the
  layer-stacked ``units`` leaves whose batch axis is axis 1), works with a
  traced slot index under jit, and casts to the live leaf dtype;
  ``poison_slot`` NaN/sentinel-fills exactly one slot;
* end-to-end hygiene: a recycled slot in a real engine -- with every freed
  slot poison-filled -- produces bit-identical output to a fresh engine, so
  no stale state bleeds across requests (the poison turns any stale read
  into NaN logits, which would change the tokens loudly).

Plus the CSR side: ``SlotLedger.offsets()`` renders ragged slot lengths as
a ``Segmented`` descriptor, and ``compact_ragged`` drains ragged buffers
through the library's own scan primitive.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import cache as CA


def _fake_tree(B=4, L=8, U=3, D=5):
    """A cache pytree shaped like lm.init_caches: prefix/suffix lead with
    the slot axis, units lead with the layer axis (slot axis second)."""
    key = iter(jax.random.split(jax.random.PRNGKey(0), 8))
    return {
        "prefix": [{"k": jax.random.normal(next(key), (B, L, 2, D)),
                    "pos": jnp.zeros((B,), jnp.int32)}],
        "units": {"k": jax.random.normal(next(key), (U, B, L, D),
                                         jnp.bfloat16),
                  "h": jax.random.normal(next(key), (U, B, D),
                                         jnp.float32)},
        "suffix": [{"conv": jax.random.normal(next(key), (B, 4, D))}],
    }


def _single_like(tree, value=1.0):
    """A batch=1 tree congruent with ``tree`` (units keep the layer axis)."""
    def one(leaf, axis):
        shape = list(leaf.shape)
        shape[axis] = 1
        return jnp.full(shape, value, leaf.dtype)

    return {
        "prefix": jax.tree.map(lambda l: one(l, 0), tree["prefix"]),
        "units": jax.tree.map(lambda l: one(l, 1), tree["units"]),
        "suffix": jax.tree.map(lambda l: one(l, 0), tree["suffix"]),
    }


def _slot_view(tree, slot):
    return {
        "prefix": jax.tree.map(lambda l: l[slot], tree["prefix"]),
        "units": jax.tree.map(lambda l: l[:, slot], tree["units"]),
        "suffix": jax.tree.map(lambda l: l[slot], tree["suffix"]),
    }


# ---------------------------------------------------------------------------
# scatter_slot / poison_slot
# ---------------------------------------------------------------------------


def test_scatter_writes_exactly_one_slot():
    live = _fake_tree()
    single = _single_like(live, 7.0)
    out = CA.scatter_slot(live, single, 2)
    for s in range(4):
        got = _slot_view(out, s)
        want = _slot_view(single if s == 2 else live,
                          0 if s == 2 else s)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(g, np.float32),
                                          np.asarray(w, np.float32))


def test_scatter_traced_slot_under_jit():
    live = _fake_tree()
    single = _single_like(live, 3.0)
    f = jax.jit(CA.scatter_slot)
    for slot in (0, 3):
        out = f(live, single, jnp.asarray(slot, jnp.int32))
        leaf = np.asarray(out["units"]["h"], np.float32)
        assert (leaf[:, slot] == 3.0).all()
        others = [s for s in range(4) if s != slot]
        np.testing.assert_array_equal(
            leaf[:, others], np.asarray(live["units"]["h"])[:, others])


def test_scatter_casts_to_live_dtype():
    live = _fake_tree()                      # units "k" is bf16
    single = _single_like(live, 1.0)
    single["units"]["k"] = single["units"]["k"].astype(jnp.float32)
    out = CA.scatter_slot(live, single, 1)
    assert out["units"]["k"].dtype == jnp.bfloat16


def test_poison_fills_exactly_one_slot():
    live = _fake_tree()
    out = CA.poison_slot(live, 1)
    # Floats NaN, ints sentinel, only slot 1; slot 0/2/3 untouched.
    assert np.isnan(np.asarray(out["units"]["h"], np.float32)[:, 1]).all()
    assert np.isnan(np.asarray(out["prefix"][0]["k"])[1]).all()
    assert (np.asarray(out["prefix"][0]["pos"])[1] == -1).all()
    for s in (0, 2, 3):
        for g, w in zip(jax.tree.leaves(_slot_view(out, s)),
                        jax.tree.leaves(_slot_view(live, s))):
            np.testing.assert_array_equal(np.asarray(g, np.float32),
                                          np.asarray(w, np.float32))


# ---------------------------------------------------------------------------
# Ring addressing -- wraparound at cache_len.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [1, 4, 7])
def test_ring_wraparound(window):
    pos = np.arange(5 * window)
    slots = np.asarray(CA.ring_slot(jnp.asarray(pos), window))
    assert (slots == pos % window).all()
    assert set(slots) == set(range(window))    # every slot gets reused


@pytest.mark.parametrize("window", [3, 8])
def test_slot_position_inverts_ring_slot(window):
    """slot_position recovers the newest absolute position living in each
    ring slot -- the exact validity rule gqa_decode's local path applies."""
    for pos in range(3 * window):
        for s in range(window):
            sp = int(CA.slot_position(s, pos, window))
            # brute force: newest p <= pos with p % window == s (or negative
            # if the slot has never been written)
            cand = [p for p in range(pos + 1) if p % window == s]
            want = cand[-1] if cand else sp   # sp < 0 expected when unwritten
            if cand:
                assert sp == want
            else:
                assert sp < 0


# ---------------------------------------------------------------------------
# SlotLedger -- ragged lengths as a CSR Segmented descriptor.
# ---------------------------------------------------------------------------


def test_ledger_offsets_are_csr():
    led = CA.SlotLedger(4, cache_len=16)
    for slot, n in enumerate([3, 0, 16, 7]):
        led.occupy(slot, n)
    off = np.asarray(led.offsets())
    assert off.dtype == np.int32
    np.testing.assert_array_equal(off, [0, 3, 3, 19, 26])
    assert led.segment_of(2) == (3, 19)
    led.advance(2)                      # clamped at cache_len
    assert led.lengths[2] == 16
    led.free(2)
    np.testing.assert_array_equal(np.asarray(led.offsets()), [0, 3, 3, 3, 10])


def test_ledger_rejects_overlong():
    led = CA.SlotLedger(2, cache_len=8)
    with pytest.raises(ValueError):
        led.occupy(0, 9)


@pytest.mark.parametrize("slot", [-1, 4, 100])
def test_ledger_rejects_out_of_range_slot(slot):
    """Regression: slot = -1 used to wrap (numpy negative indexing) into the
    LAST live slot's ledger entry -- a silent cross-request length
    corruption.  Every mutating entry point must raise SlotError instead."""
    led = CA.SlotLedger(4, cache_len=16)
    led.occupy(3, 5)                       # the slot -1 would alias into
    for fn in (lambda: led.occupy(slot, 2),
               lambda: led.advance(slot),
               lambda: led.free(slot),
               lambda: led.segment_of(slot)):
        with pytest.raises(CA.SlotError):
            fn()
    assert led.lengths[3] == 5             # the aliased slot is untouched


def test_slot_error_is_index_error():
    """SlotError subclasses IndexError so pre-existing except IndexError
    handlers keep working."""
    assert issubclass(CA.SlotError, IndexError)


# ---------------------------------------------------------------------------
# compact_ragged -- CSR drain of ragged slot buffers.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_compact_ragged_matches_reference(seed):
    rng = np.random.default_rng(seed)
    B, T = 5, 9
    buf = rng.integers(0, 100, (B, T)).astype(np.int32)
    counts = rng.integers(0, T + 1, (B,)).astype(np.int32)
    flat, offsets = CA.compact_ragged(jnp.asarray(buf), counts)
    flat, offsets = np.asarray(flat), np.asarray(offsets)
    np.testing.assert_array_equal(
        flat, np.concatenate([buf[b, :counts[b]] for b in range(B)]))
    np.testing.assert_array_equal(offsets,
                                  np.concatenate([[0], np.cumsum(counts)]))


def test_compact_ragged_all_empty():
    flat, offsets = CA.compact_ragged(jnp.zeros((3, 4), jnp.int32),
                                      np.zeros(3, np.int32))
    assert flat.shape == (0,)
    np.testing.assert_array_equal(np.asarray(offsets), [0, 0, 0, 0])


def test_compact_ragged_host_counts_skip_device_sync(monkeypatch):
    """Regression for the drain path's no-sync promise: with concrete host
    counts (what the ledger hands over), the flat extent must come from the
    host sum, never from ``int(device_scalar)``.  jax's transfer guard is
    blind on the CPU backend (zero-copy), so the check is structural: shadow
    ``int`` in the module namespace and fail if it ever receives a device
    array while counts are host-side."""
    real_int = int

    def guarded_int(x=0, *args):
        assert not isinstance(x, jax.Array), (
            "compact_ragged forced a device->host sync despite concrete "
            "host counts")
        return real_int(x, *args)

    monkeypatch.setattr(CA, "int", guarded_int, raising=False)
    buf = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)
    flat, offsets = CA.compact_ragged(buf, np.asarray([2, 0, 3], np.int32))
    np.testing.assert_array_equal(np.asarray(flat), [0, 1, 8, 9, 10])
    np.testing.assert_array_equal(np.asarray(offsets), [0, 2, 2, 5])


def test_compact_ragged_device_counts_still_work():
    """Genuinely device-resident counts take the (blocking) int(incl[-1])
    path and must produce the same CSR drain."""
    buf = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)
    flat, offsets = CA.compact_ragged(buf, jnp.asarray([2, 0, 3], jnp.int32))
    np.testing.assert_array_equal(np.asarray(flat), [0, 1, 8, 9, 10])
    np.testing.assert_array_equal(np.asarray(offsets), [0, 2, 2, 5])


# ---------------------------------------------------------------------------
# End-to-end slot hygiene: recycled slot == fresh engine, under poison.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["gemma2-27b", "recurrentgemma-2b"])
def test_recycled_slot_no_stale_bleed(arch):
    """Serve two requests through ONE slot with every freed slot poison-
    filled (NaN floats).  If the second request ever read the first's
    leftover state, its logits would go NaN and its tokens would change;
    instead it must match a fresh engine that only ever saw request B."""
    from repro.configs import base as C
    from repro.models import lm
    from repro.serving.engine import Engine, Request

    cfg = C.get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ra = Request([3, 1, 4], max_new_tokens=5, seed=7)
    rb = Request([2, 7, 2], max_new_tokens=5, seed=9)

    eng = Engine(cfg, None, params, cache_len=32, batch_size=1,
                 temperature=0.7, top_k=8, poison_on_evict=True)
    out_both = eng.generate([ra, rb])          # rb recycles ra's slot

    fresh = Engine(cfg, None, params, cache_len=32, batch_size=1,
                   temperature=0.7, top_k=8)
    out_fresh = fresh.generate([rb])
    assert out_both[1] == out_fresh[0]
    assert not np.isnan(eng.last_scores).any()


@pytest.mark.parametrize("mode", ["int8", "fp8"])
def test_recycled_slot_no_stale_bleed_quantized_kv(mode):
    """Slot hygiene must survive the quantized KV cache form: the KVQuant
    (values, scales) leaves ride the same scatter/poison/ring address math,
    so a recycled slot under quantize_kv= must still match a fresh engine
    bit-for-bit (and poison on the scales leaf keeps stale reads loud)."""
    from repro.configs import base as C
    from repro.models import lm
    from repro.serving.engine import Engine, Request

    cfg = C.get_config("gemma2-27b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ra = Request([3, 1, 4], max_new_tokens=5, seed=7)
    rb = Request([2, 7, 2], max_new_tokens=5, seed=9)

    eng = Engine(cfg, None, params, cache_len=32, batch_size=1,
                 temperature=0.7, top_k=8, poison_on_evict=True,
                 quantize_kv=mode)
    out_both = eng.generate([ra, rb])          # rb recycles ra's slot

    fresh = Engine(cfg, None, params, cache_len=32, batch_size=1,
                   temperature=0.7, top_k=8, quantize_kv=mode)
    out_fresh = fresh.generate([rb])
    assert out_both[1] == out_fresh[0]
    assert not np.isnan(eng.last_scores).any()
