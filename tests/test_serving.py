"""Serving engine tests: batched generate, determinism, stats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as C
from repro.models import lm
from repro.serving.engine import Engine, Request, sample_tokens


@pytest.fixture(scope="module")
def setup():
    cfg = C.get_config("gemma2-27b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, None, params, cache_len=64, batch_size=4)
    return cfg, params, eng


def test_generate_batched(setup):
    cfg, params, eng = setup
    reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=6),
            Request(prompt=[9, 8], max_new_tokens=4)]
    outs = eng.generate(reqs)
    assert len(outs) == 2
    assert len(outs[0]) == 6 and len(outs[1]) == 4
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)
    assert eng.last_stats["decode_tok_per_s"] > 0


def test_generate_greedy_deterministic(setup):
    cfg, params, eng = setup
    reqs = [Request(prompt=[5, 6, 7], max_new_tokens=5)]
    a = eng.generate(reqs)
    b = eng.generate(reqs)
    assert a == b


def test_generate_eos_stops(setup):
    cfg, params, eng = setup
    # Find what the greedy chain emits, then set eos to its first token.
    first = eng.generate([Request(prompt=[3, 1], max_new_tokens=3)])[0]
    outs = eng.generate([Request(prompt=[3, 1], max_new_tokens=8,
                                 eos_id=first[1] if len(first) > 1 else -2)])
    assert len(outs[0]) <= 8


def test_recurrent_arch_serving():
    """Hybrid arch: ring/state caches serve beyond the local window."""
    cfg = C.get_config("recurrentgemma-2b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2)
    outs = eng.generate([Request(prompt=list(range(1, 40)),
                                 max_new_tokens=8)])
    assert len(outs[0]) == 8
    assert all(np.isfinite(t) for t in outs[0])


def test_topk_sampling_generates_valid_tokens(setup):
    """temperature>0 with top_k routes through segmented_top_k sampling."""
    cfg, params, _ = setup
    eng = Engine(cfg, None, params, cache_len=64, batch_size=4,
                 temperature=1.0, top_k=5, seed=3)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=5),
            Request(prompt=[4, 5], max_new_tokens=5)]
    outs = eng.generate(reqs)
    assert len(outs) == 2 and all(len(o) == 5 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)
    assert len(eng.last_stats["seq_logprob"]) == 2


def test_topk1_equals_greedy(setup):
    """k=1 sampling must collapse to argmax regardless of temperature."""
    cfg, params, _ = setup
    reqs = [Request(prompt=[2, 7, 1], max_new_tokens=4)]
    greedy = Engine(cfg, None, params, cache_len=64,
                    batch_size=2).generate(reqs)
    topk1 = Engine(cfg, None, params, cache_len=64, batch_size=2,
                   temperature=0.7, top_k=1, seed=5).generate(reqs)
    assert greedy == topk1


def test_tiny_topp_equals_greedy(setup):
    """A nucleus below the first token's mass keeps only the argmax."""
    cfg, params, _ = setup
    reqs = [Request(prompt=[3, 3, 9], max_new_tokens=4)]
    greedy = Engine(cfg, None, params, cache_len=64,
                    batch_size=2).generate(reqs)
    topp = Engine(cfg, None, params, cache_len=64, batch_size=2,
                  temperature=0.9, top_p=1e-6, seed=7).generate(reqs)
    assert greedy == topp


# ---------------------------------------------------------------------------
# Nucleus (top-p) semantics conformance: sample_tokens' docstring pins the
# cutoff to the softmax *renormalized over the retained candidates*; these
# tests pin the documented consequences directly against the sampler, so an
# alternative logits path (quantized decode, a new kernel) that silently
# switched to full-vocab-mass semantics would fail here, not in production.
# ---------------------------------------------------------------------------


def _sample_draws(logits_row, *, top_k, top_p, n=256, temperature=1.0):
    """n independent draws from one logits row (distinct per-row seeds)."""
    logits = jnp.tile(jnp.asarray(logits_row, jnp.float32)[None, :], (n, 1))
    toks = sample_tokens(
        jax.random.PRNGKey(0), logits, jnp.arange(n, dtype=jnp.int32),
        jnp.zeros(n, jnp.int32), temperature=temperature, top_k=top_k,
        top_p=top_p, top_p_candidates=64)
    return np.asarray(toks)


def test_nucleus_all_candidates_survive_on_renormalized_mass():
    """8 equal-probability candidates, top_p=0.95: the renormalized
    exclusive prefix tops out at 7/8 < 0.95, so ALL candidates stay in the
    nucleus -- even though the candidates carry only ~5% of the *full-vocab*
    probability mass here.  Full-vocab-mass semantics would keep every
    below-cutoff token instead; the renormalized contract is what the
    docstring promises."""
    V = 1024
    logits = np.full(V, 3.0, np.float32)
    cands = np.arange(0, 80, 10)               # 8 spread-out candidate ids
    logits[cands] = 5.0
    draws = _sample_draws(logits, top_k=8, top_p=0.95)
    assert set(draws) == set(cands.tolist())   # all 8 survive & get sampled


def test_nucleus_truncates_on_renormalized_prefix():
    """Candidate renormalized masses ~[0.7, 0.1, 0.1, 0.1] with top_p=0.75:
    the exclusive prefix is [0, 0.7, 0.8, 0.9], so exactly the first two
    candidates survive the cum < top_p filter -- the third token (prefix
    0.8) must never be sampled."""
    V = 64
    logits = np.full(V, -30.0, np.float32)
    logits[7] = np.log(0.7)
    logits[[13, 21, 34]] = np.log(0.1)
    draws = _sample_draws(logits, top_k=4, top_p=0.75)
    assert set(draws) == {7, 13}


def test_nucleus_first_candidate_always_survives():
    """top_p below any achievable prefix mass still keeps the argmax: its
    exclusive prefix mass is exactly 0 < top_p."""
    rng = np.random.default_rng(3)
    logits = rng.normal(size=128).astype(np.float32)
    draws = _sample_draws(logits, top_k=8, top_p=1e-6)
    assert (draws == int(np.argmax(logits))).all()


@pytest.fixture
def dispatch_spy(monkeypatch):
    """Record every primitive name resolved through the Layer-1 registry."""
    from repro.core import intrinsics as ki
    calls = []
    real = ki.resolve_impl

    def spy(primitive, backend=None):
        calls.append(primitive)
        return real(primitive, backend)

    monkeypatch.setattr(ki, "resolve_impl", spy)
    return calls


def test_single_and_full_batch_same_batched_path(setup, dispatch_spy):
    """Batch-size invariance of the decode hot path: a single request and a
    max-size batch must dispatch the *same set of primitives* -- no
    shape-specialized fallback (per-row loop, vmap-of-1-D, scalar special
    case) may appear at either extreme.  The batched family makes the batch
    a grid dimension, so the dispatched set is size-independent by
    construction; this pins that property.

    The device-resident loop resolves primitives at *trace* time, so each
    measurement uses a fresh Engine (fresh jit caches => the loop re-traces
    and the spy sees the full dispatch set)."""
    cfg, params, _ = setup
    B = 4

    def dispatched(n_req):
        eng = Engine(cfg, None, params, cache_len=64, batch_size=B,
                     temperature=1.0, top_k=5, top_p=0.9, seed=2)
        dispatch_spy.clear()
        eng.generate([Request(prompt=[1 + i, 2], max_new_tokens=3)
                      for i in range(n_req)])
        return set(dispatch_spy)

    single = dispatched(1)
    full = dispatched(B)

    # The decode path runs on the batched family...  (flat scan/mapreduce
    # still legitimately appear *inside* the radix composition backing
    # top_k@segmented -- single launches over the whole flat candidate
    # stream, not per-request calls.)
    assert "scan@batched" in single          # nucleus cutoff over (B, k)
    assert "mapreduce@batched" in single     # masked per-request seq scores
    assert "top_k@segmented" in single       # per-request candidate top-k
    # ...and hits the identical primitive set at both batch extremes: the
    # slot count is a grid dimension of one compiled loop, never a reason
    # to re-specialize.
    assert single == full


def test_decode_loop_single_dispatch_no_token_syncs(setup):
    """The acceptance property of the device-resident loop: a batch that
    fits in the slots decodes to completion in ONE ``lax.while_loop``
    dispatch, with ZERO device->host transfers between prefill and
    completion -- every per-token decision (EOS, length caps, sampling,
    logprob accumulation) happens on device.  A transfer guard makes any
    hidden per-token sync a hard error."""
    cfg, params, _ = setup
    eng = Engine(cfg, None, params, cache_len=64, batch_size=4,
                 temperature=1.0, top_k=5, seed=2)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=6),
            Request(prompt=[4, 5], max_new_tokens=4)]
    eng.generate(reqs)  # warm the jit caches (compile-time is off the clock)

    real, calls = eng._dispatch_loop, []

    def guarded(state, budget, stop_on_free):
        calls.append(int(budget))
        with jax.transfer_guard_device_to_host("disallow"):
            return real(state, budget, stop_on_free)

    eng._dispatch_loop = guarded
    outs = eng.generate(reqs)
    assert len(calls) == 1
    assert eng.last_stats["loop_dispatches"] == 1
    assert eng.last_stats["decode_steps"] >= 5   # 6 tokens, 1st at admission
    assert len(outs[0]) == 6 and len(outs[1]) == 4


def test_serve_open_loop_arrivals(setup):
    """serve(): open-loop trace with arrivals mid-flight; the virtual clock
    advances by executed decode steps and every record is self-consistent."""
    cfg, params, _ = setup
    eng = Engine(cfg, None, params, cache_len=64, batch_size=2)
    reqs = [Request(prompt=[1, 2], max_new_tokens=5, seed=0),
            Request(prompt=[3, 4], max_new_tokens=4, seed=1),
            Request(prompt=[5, 6], max_new_tokens=3, seed=2)]
    recs = eng.serve([(0, reqs[0]), (2, reqs[1]), (4, reqs[2])])
    assert [len(r.tokens) for r in recs] == [5, 4, 3]
    for rec in recs:
        assert rec.done
        assert rec.submit_step <= rec.admit_step <= rec.finish_step
    assert eng.last_stats["decode_steps"] > 0
    assert eng.last_stats["total_tokens"] == 12
