"""Quantized codec unit tests (int8 / emulated fp8 blockwise matrices,
per-vector KV quantization) -- the algebra-level contracts the kernel
conformance suite (tests/test_conformance.py) builds on.  Deliberately
hypothesis-free so the codecs stay tested where that dependency is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as alg


# ---------------------------------------------------------------------------
# Quantized codecs (int8 / emulated fp8): the algebra-level contracts the
# kernel conformance suite builds on.
# ---------------------------------------------------------------------------

QUANT_MODES = ["int8", "fp8_e4m3", "fp8_e5m2"]


@pytest.mark.parametrize("mode", QUANT_MODES)
def test_quantize_within_error_bound(mode):
    """|dequantize(quantize(A)) - A| <= error_bound(), elementwise, across
    block-boundary shapes, batch ranks, and wildly mixed magnitudes."""
    rng = np.random.default_rng(5)
    for shape in [(1, 1), (31, 3), (32, 4), (33, 5), (2, 40, 7)]:
        A = jnp.asarray(rng.normal(size=shape) *
                        rng.uniform(0.01, 10.0, shape), jnp.float32)
        q = alg.quantize(A, mode=mode, block=32)
        assert q.shape == A.shape
        assert q.qtag == f"{mode}q32"
        err = np.abs(np.asarray(q.dequantize()) - np.asarray(A))
        bound = np.asarray(q.error_bound())
        assert (err <= bound + 1e-7).all(), (
            f"{mode} {shape}: max excess {float((err - bound).max()):.3e}")


@pytest.mark.parametrize("mode", ["fp8_e4m3", "fp8_e5m2"])
def test_fp8_codes_are_canonical(mode):
    """encode(decode(code)) == code for every code quantize emits: the
    encoder picks the nearest representable, so re-encoding a decoded
    value must be the identity (no drift under repeated round-trips)."""
    rng = np.random.default_rng(11)
    A = jnp.asarray(rng.normal(size=(64, 5)) * 3.0, jnp.float32)
    q = alg.quantize(A, mode=mode, block=16)
    re = alg.fp8_encode(alg.fp8_decode(q.values, mode), mode)
    np.testing.assert_array_equal(np.asarray(re), np.asarray(q.values))


def test_quantized_pytree_static_aux_survives_jit():
    A = jnp.asarray(np.arange(24, dtype=np.float32).reshape(8, 3))
    q = alg.quantize(A, mode="fp8_e5m2", block=4)
    leaves, treedef = jax.tree.flatten(q)
    q2 = jax.tree.unflatten(treedef, leaves)
    assert (q2.mode, q2.block) == ("fp8_e5m2", 4)
    got = jax.jit(lambda t: t.dequantize())(q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(q.dequantize()))


@pytest.mark.parametrize("mode", QUANT_MODES)
def test_kv_quant_roundtrip_and_pytree(mode):
    """Per-vector KV codec: scales are per trailing vector, the round-trip
    error obeys the mode's half-ulp bound, and the (values, scales) node
    survives tree flatten/unflatten with its static mode."""
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(size=(2, 6, 3, 8)) * 2.0, jnp.float32)
    kv = alg.quantize_kv(x, mode)
    assert kv.shape == x.shape
    err = np.abs(np.asarray(kv.dequantize()) - np.asarray(x))
    scales = np.asarray(kv.scales)
    if mode == "int8":
        bound = 0.5 * scales
    else:
        man = alg.FP8_FORMATS[mode][1]
        # decoded magnitude <= qmax => relative half-ulp of 2**-man, plus
        # the subnormal absolute floor, all scaled back up.
        bound = (np.abs(np.asarray(x)) * (2.0 ** -man)) + scales
    assert (err <= bound + 1e-6).all()
    leaves, treedef = jax.tree.flatten(kv)
    kv2 = jax.tree.unflatten(treedef, leaves)
    assert kv2.mode == mode and len(leaves) == 2
