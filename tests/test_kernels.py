"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py).

Every Pallas kernel runs in interpret mode (the kernel body executes on CPU
exactly as Mosaic would schedule it on TPU) across shapes straddling tile
boundaries (the paper's 31/33-element edge cases), dtypes, and operators --
including non-commutative ones.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_trees_close
from repro.core import operators as alg
from repro.core import primitives as forge
from repro.kernels import ref

B = "pallas-interpret"

SIZES = [1, 7, 31, 33, 127, 128, 129, 255, 257, 1000, 4096, 5000]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_scan_add_sizes(n, dtype, rng):
    if dtype == jnp.int32:
        x = jax.random.randint(rng, (n,), -100, 100, dtype)
    else:
        x = jax.random.normal(rng, (n,), dtype)
    got = forge.scan(alg.ADD, x, backend=B)
    want = ref.ref_scan(alg.ADD, x)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-3, err=f"scan n={n}")


@pytest.mark.parametrize("op_name", ["max", "min", "mul"])
def test_scan_ops(op_name, rng):
    op = alg.STD_OPS[op_name]
    x = jax.random.uniform(rng, (777,), jnp.float32, 0.9, 1.1)
    assert_trees_close(forge.scan(op, x, backend=B), ref.ref_scan(op, x),
                       rtol=1e-4, atol=1e-4, err=op_name)


@pytest.mark.parametrize("inclusive", [True, False])
@pytest.mark.parametrize("reverse", [True, False])
def test_scan_modes(inclusive, reverse, rng):
    x = jax.random.normal(rng, (513,), jnp.float32)
    got = forge.scan(alg.ADD, x, inclusive=inclusive, reverse=reverse, backend=B)
    want = ref.ref_scan(alg.ADD, x, inclusive=inclusive, reverse=reverse)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-3)


def test_scan_noncommutative_quaternion(rng):
    ks = jax.random.split(rng, 4)
    q = tuple(jax.random.normal(k, (300,), jnp.float32) * 0.2 for k in ks)
    q = (q[0] + 1.0, q[1], q[2], q[3])
    got = forge.scan(alg.QUATERNION_MUL, q, backend=B)
    want = ref.ref_scan(alg.QUATERNION_MUL, q)
    # 300-element non-commutative products accumulate association-order
    # float drift between the tile tree and associative_scan's tree.
    assert_trees_close(got, want, rtol=1e-2, atol=1e-2)


def test_scan_mat2(rng):
    ks = jax.random.split(rng, 4)
    m = tuple(jax.random.normal(k, (200,), jnp.float32) * 0.3 for k in ks)
    m = (m[0] + 1.0, m[1], m[2], m[3] + 1.0)
    got = forge.scan(alg.MAT2_MUL, m, backend=B)
    want = ref.ref_scan(alg.MAT2_MUL, m)
    assert_trees_close(got, want, rtol=1e-3, atol=1e-3)


def test_scan_maxplus_affine(rng):
    k1, k2 = jax.random.split(rng)
    a = -jax.random.uniform(k1, (400,), jnp.float32, 0.0, 1.0)
    b = jax.random.normal(k2, (400,), jnp.float32)
    got = forge.scan(alg.MAXPLUS_AFFINE, (a, b), backend=B)
    want = ref.ref_scan(alg.MAXPLUS_AFFINE, (a, b))
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 5, 3), (2, 37, 130), (3, 64, 128),
                                   (2, 100, 1)])
def test_channel_scan_linrec(shape, rng):
    k1, k2 = jax.random.split(rng)
    a = jax.random.uniform(k1, shape, jnp.float32, 0.5, 1.0)
    b = jax.random.normal(k2, shape, jnp.float32)
    got = forge.linear_recurrence(a, b, backend=B)
    want = ref.ref_linear_recurrence(a, b)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4, err=str(shape))


def test_channel_scan_h0_and_reverse(rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    a = jax.random.uniform(k1, (2, 33, 140), jnp.float32, 0.6, 1.0)
    b = jax.random.normal(k2, (2, 33, 140), jnp.float32)
    h0 = jax.random.normal(k3, (2, 140), jnp.float32)
    assert_trees_close(
        forge.linear_recurrence(a, b, h0, backend=B),
        ref.ref_linear_recurrence(a, b, h0), rtol=1e-4, atol=1e-4)
    assert_trees_close(
        forge.linear_recurrence(a, b, reverse=True, backend=B),
        ref.ref_linear_recurrence(a, b, reverse=True), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [1, 33, 257, 10000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.uint8])
def test_mapreduce_sizes(n, dtype, rng):
    if dtype == jnp.uint8:
        x = jax.random.randint(rng, (n,), 0, 255, jnp.int32).astype(jnp.uint8)
        f = alg.unitfloat8_decode
    else:
        x = jax.random.normal(rng, (n,), dtype)
        f = lambda v: v
    got = forge.mapreduce(f, alg.ADD, x, backend=B)
    want = ref.ref_mapreduce(f, alg.ADD, x)
    assert_trees_close(got, want, rtol=1e-3, atol=1e-2, err=f"mr n={n}")


def test_mapreduce_logsumexp(rng):
    x = jax.random.normal(rng, (3000,), jnp.float32) * 3
    got = forge.mapreduce(lambda v: v, alg.LOGSUMEXP, x, backend=B)
    want = ref.ref_mapreduce(lambda v: v, alg.LOGSUMEXP, x)
    assert_trees_close(got, want, rtol=1e-5, atol=1e-5)


def test_mapreduce_2d_axes(rng):
    x = jax.random.normal(rng, (100, 200), jnp.float32)
    got0 = forge.mapreduce(lambda v: v, alg.MAX, x, axis=0, backend=B)
    np.testing.assert_allclose(np.asarray(got0), np.max(np.asarray(x), 0),
                               rtol=1e-6)
    got1 = forge.mapreduce(lambda v: v, alg.MAX, x, axis=1, backend=B)
    np.testing.assert_allclose(np.asarray(got1), np.max(np.asarray(x), 1),
                               rtol=1e-6)


MAT_SHAPES = [(1, 100), (100, 1), (33, 65), (128, 128), (1000, 30), (30, 1000)]


@pytest.mark.parametrize("shape", MAT_SHAPES)
def test_matvec_shapes(shape, rng):
    n, p = shape
    k1, k2 = jax.random.split(rng)
    A = jax.random.normal(k1, (n, p), jnp.float32)
    x = jax.random.normal(k2, (n,), jnp.float32)
    got = forge.semiring_matvec(alg.ARITHMETIC, A, x, backend=B)
    want = ref.ref_matvec(alg.ARITHMETIC.f, alg.ADD, A, x)
    assert_trees_close(got, want, rtol=1e-3, atol=1e-3, err=str(shape))


@pytest.mark.parametrize("shape", MAT_SHAPES)
def test_vecmat_shapes(shape, rng):
    n, p = shape
    k1, k2 = jax.random.split(rng)
    A = jax.random.normal(k1, (n, p), jnp.float32)
    x = jax.random.normal(k2, (p,), jnp.float32)
    got = forge.semiring_vecmat(alg.ARITHMETIC, A, x, backend=B)
    want = ref.ref_vecmat(alg.ARITHMETIC.f, alg.ADD, A, x)
    assert_trees_close(got, want, rtol=1e-3, atol=1e-3, err=str(shape))


@pytest.mark.parametrize("semiring", ["tropical_min_plus", "tropical_max_plus",
                                      "log"])
def test_semiring_matvec(semiring, rng):
    sr = alg.STD_SEMIRINGS[semiring]
    k1, k2 = jax.random.split(rng)
    A = jax.random.normal(k1, (77, 50), jnp.float32)
    x = jax.random.normal(k2, (77,), jnp.float32)
    got = forge.semiring_matvec(sr, A, x, backend=B)
    want = ref.ref_matvec(sr.f, sr.op, A, x)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4, err=semiring)
    x2 = jax.random.normal(k2, (50,), jnp.float32)
    got = forge.semiring_vecmat(sr, A, x2, backend=B)
    want = ref.ref_vecmat(sr.f, sr.op, A, x2)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4, err=semiring)


@pytest.mark.parametrize("shape", [(1000, 10), (4096, 1), (999, 7),
                                   (600, 33), (515, 2)])
def test_matvec_lane_packed_tall_narrow(shape, rng):
    """p <= 64 dispatches the lane-packed kernel (ragged n via tail fold)."""
    n, p = shape
    k1, k2 = jax.random.split(rng)
    A = jax.random.normal(k1, (n, p), jnp.float32)
    x = jax.random.normal(k2, (n,), jnp.float32)
    got = forge.semiring_matvec(alg.ARITHMETIC, A, x, backend=B)
    want = ref.ref_matvec(alg.ARITHMETIC.f, alg.ADD, A, x)
    assert_trees_close(got, want, rtol=1e-3, atol=1e-3, err=str(shape))
    got = forge.semiring_matvec(alg.TROPICAL_MIN_PLUS, A, x, backend=B)
    want = ref.ref_matvec(alg.TROPICAL_MIN_PLUS.f, alg.MIN, A, x)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4, err=str(shape))


def test_matvec_noncommutative_order(rng):
    """In-order reduction: matvec with MAT2 composition along rows."""
    n, p = 40, 3
    ks = jax.random.split(rng, 2)
    A = jax.random.normal(ks[0], (n, p), jnp.float32) * 0.2
    x = jax.random.normal(ks[1], (n,), jnp.float32) * 0.2
    # f maps scalars to a 2x2 matrix tuple; op composes in row order.
    f = lambda xv, av: (1.0 + 0 * av, xv * av, 0 * av, 1.0 + 0 * av)
    got = forge.matvec(f, alg.MAT2_MUL, A, x, backend=B)
    want = ref.ref_matvec(f, alg.MAT2_MUL, A, x)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4)


def test_matvec_tall_narrow_noncommutative_ragged_tail(rng):
    """Regression: the lane-packed kernel interleaves row groups and folds
    the ``n % g != 0`` tail out of row order -- correct only for commutative
    ops.  A tall-narrow shape that *would* take the packed path must, with a
    non-commutative op, dispatch to the order-preserving kernel and still
    match the oracle exactly."""
    n, p = 515, 3                    # p <= 64, n >= 4*128 => packed gate;
    assert n % (128 // p) != 0       # ragged tail rows exist
    ks = jax.random.split(rng, 2)
    A = jax.random.normal(ks[0], (n, p), jnp.float32) * 0.1
    x = jax.random.normal(ks[1], (n,), jnp.float32) * 0.1
    f = lambda xv, av: (1.0 + 0 * av, xv * av, 0 * av, 1.0 + 0 * av)
    got = forge.matvec(f, alg.MAT2_MUL, A, x, backend=B)
    want = ref.ref_matvec(f, alg.MAT2_MUL, A, x)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4)


def test_matvec_packed_rejects_noncommutative(rng):
    """Calling the packed kernel directly with a non-commutative op is a
    hard error, not a silent reorder."""
    from repro.kernels import matvec as matvec_k
    A = jnp.ones((512, 4), jnp.float32)
    x = jnp.ones((512,), jnp.float32)
    f = lambda xv, av: (1.0 + 0 * av, xv * av, 0 * av, 1.0 + 0 * av)
    with pytest.raises(ValueError, match="commutative|row order"):
        matvec_k.matvec_packed_pallas(f, alg.MAT2_MUL, A, x,
                                      block_rows=16, interpret=True)


@pytest.mark.parametrize("n", [100, 4096, 100000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.uint8])
def test_copy(n, dtype, rng):
    if dtype == jnp.uint8:
        x = jax.random.randint(rng, (n,), 0, 255, jnp.int32).astype(dtype)
    else:
        x = jax.random.normal(rng, (n,), jnp.float32).astype(dtype)
    got = forge.copy(x, backend=B)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


@pytest.mark.parametrize("nitem", [1, 4, 8])
def test_copy_nitem_sweep(nitem, rng):
    x = jax.random.normal(rng, (5000,), jnp.float32)
    got = forge.copy(x, nitem=nitem, backend=B)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_xla_backend_parity(rng):
    """The portable XLA fallback agrees with the oracle too."""
    x = jax.random.normal(rng, (1234,), jnp.float32)
    assert_trees_close(forge.scan(alg.ADD, x, backend="xla"),
                       ref.ref_scan(alg.ADD, x), rtol=1e-4, atol=1e-4)
    A = jax.random.normal(rng, (64, 32), jnp.float32)
    xv = jax.random.normal(rng, (64,), jnp.float32)
    assert_trees_close(forge.semiring_matvec(alg.ARITHMETIC, A, xv, backend="xla"),
                       ref.ref_matvec(alg.ARITHMETIC.f, alg.ADD, A, xv),
                       rtol=1e-4, atol=1e-4)
