import os
import sys

# Tests run against the source tree (PYTHONPATH=src also works; this makes
# bare `pytest tests/` work too).  NOTE: no XLA_FLAGS here on purpose --
# smoke tests and benches must see the real (single) device; multi-device
# tests spawn subprocesses that set their own flags.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


def assert_trees_close(a, b, rtol=1e-5, atol=1e-5, err=""):
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), f"{err}: leaf count {len(la)} != {len(lb)}"
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=rtol, atol=atol,
            err_msg=f"{err}: leaf {i}")


@pytest.fixture
def rng():
    import jax
    return jax.random.PRNGKey(42)


def make_operand(op_name: str, nprng, shape, dtype=None):
    """Random pytree element for the operator named ``op_name``.

    Shared by the property suite (tests/test_properties.py) and the
    differential fuzz harness (tests/test_conformance.py).  Values are kept
    in ranges where float products/exps stay well-conditioned, so
    associativity drift is bounded and kernel-vs-oracle comparisons are
    meaningful at tight tolerances.
    """
    import jax.numpy as jnp
    dtype = dtype or jnp.float32

    def arr(lo, hi):
        return jnp.asarray(nprng.uniform(lo, hi, shape), dtype)

    if op_name in ("add", "max", "min"):
        if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
            return jnp.asarray(nprng.integers(-100, 100, shape), dtype)
        return arr(-100.0, 100.0)
    if op_name == "mul":
        return arr(0.7, 1.3)
    if op_name == "logsumexp":
        return arr(-5.0, 5.0)
    if op_name == "affine":
        return (arr(0.5, 1.2), arr(-2.0, 2.0))
    if op_name == "maxplus_affine":
        return (arr(-1.0, 0.0), arr(-3.0, 3.0))
    if op_name == "softmax_merge":
        return (arr(-3.0, 3.0), arr(0.1, 2.0), arr(-2.0, 2.0))
    if op_name == "quaternion_mul":
        return (arr(0.7, 1.3), arr(-0.3, 0.3), arr(-0.3, 0.3),
                arr(-0.3, 0.3))
    if op_name == "mat2_mul":
        return (arr(0.7, 1.3), arr(-0.3, 0.3), arr(-0.3, 0.3),
                arr(0.7, 1.3))
    raise ValueError(f"no operand generator for operator {op_name!r}")
