import os
import sys

# Tests run against the source tree (PYTHONPATH=src also works; this makes
# bare `pytest tests/` work too).  NOTE: no XLA_FLAGS here on purpose --
# smoke tests and benches must see the real (single) device; multi-device
# tests spawn subprocesses that set their own flags.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


def assert_trees_close(a, b, rtol=1e-5, atol=1e-5, err=""):
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), f"{err}: leaf count {len(la)} != {len(lb)}"
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=rtol, atol=atol,
            err_msg=f"{err}: leaf {i}")


@pytest.fixture
def rng():
    import jax
    return jax.random.PRNGKey(42)
