"""Fused attention kernel vs naive oracle, across shapes/masks/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas


def naive(q, k, v, causal=True, window=0, softcap=0.0):
    d = q.shape[-1]
    s = jnp.einsum("nsd,ntd->nst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    S, T = q.shape[1], k.shape[1]
    qpos, kpos = jnp.arange(S)[:, None], jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nst,ntd->nsd", p, v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("shape", [(2, 64, 32), (1, 100, 64), (3, 33, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_naive(shape, causal, rng):
    N, S, d = shape
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (N, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (N, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (N, S, d), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, q_block=32,
                                 kv_block=32, interpret=True)
    want = naive(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_window_and_softcap(rng):
    N, S, d = 2, 96, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (N, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (N, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (N, S, d), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, window=16,
                                 softcap=30.0, q_block=32, kv_block=32,
                                 interpret=True)
    want = naive(q, k, v, causal=True, window=16, softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_cross_lengths(rng):
    """S != T (prefill against a longer cache)."""
    N, S, T, d = 1, 24, 72, 32
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (N, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (N, T, d), jnp.float32)
    v = jax.random.normal(ks[2], (N, T, d), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=False, q_block=16,
                                 kv_block=32, interpret=True)
    want = naive(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_bf16(rng):
    N, S, d = 2, 64, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (N, S, d), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (N, S, d), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (N, S, d), jnp.float32).astype(jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, q_block=32, kv_block=32,
                                 interpret=True)
    want = naive(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2)
