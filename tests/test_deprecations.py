"""The legacy (pre-layout) names: warn-once shims over the new surface.

Every ``segmented_*`` / ``batched_*`` name must (1) emit exactly one
``DeprecationWarning`` per process -- on the first call, never again --
(2) forward its kwargs faithfully, and (3) produce bit-identical results to
the layout-polymorphic call it wraps.  This file is intentionally the only
in-repo caller of the legacy names.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_operand
from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched, Segmented

OFFSETS = jnp.asarray([0, 7, 7, 40, 64], jnp.int32)
N = 64


def _nprng(name):
    return np.random.default_rng(abs(hash(name)) % (2**31))


def _keys(n=N, seed=3):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=n), jnp.float32)


# (legacy name, legacy call, equivalent new-surface call).  Operands come
# from the conformance suite's make_operand fixtures so the shims are
# checked on the same element types the kernels are fuzzed with.
def _cases():
    x2 = make_operand("add", _nprng("bs"), (3, 33))
    m2 = make_operand("mat2_mul", _nprng("bsm"), (2, 17))
    x1 = make_operand("add", _nprng("ss"), (N,))
    A3 = jnp.asarray(_nprng("mv").normal(size=(2, 9, 5)), jnp.float32)
    v2 = jnp.asarray(_nprng("mvx").normal(size=(2, 9)), jnp.float32)
    p2 = jnp.asarray(_nprng("vmx").normal(size=(2, 5)), jnp.float32)
    a3 = jnp.asarray(_nprng("lr").uniform(0.5, 1.0, (2, 11, 6)), jnp.float32)
    b3 = jnp.asarray(_nprng("lrb").normal(size=(2, 11, 6)), jnp.float32)
    h0 = jnp.asarray(_nprng("lrh").normal(size=(2, 6)), jnp.float32)
    flags = jnp.zeros((N,), jnp.int32).at[jnp.asarray([0, 7, 40])].set(1)
    keys = _keys()
    vals = jnp.arange(N, dtype=jnp.int32)
    seg = Segmented(offsets=OFFSETS)
    mvf = lambda x, a: x * a
    vmf = lambda a, x: a * x
    return [
        ("batched_scan",
         lambda: forge.batched_scan(alg.ADD, x2, inclusive=False,
                                    reverse=True, backend="xla"),
         lambda: forge.scan(alg.ADD, x2, inclusive=False, reverse=True,
                            layout=Batched(), backend="xla")),
        ("batched_mapreduce",
         lambda: forge.batched_mapreduce(lambda t: t, alg.MAT2_MUL, m2,
                                         backend="xla"),
         lambda: forge.mapreduce(lambda t: t, alg.MAT2_MUL, m2,
                                 layout=Batched(), backend="xla")),
        ("batched_matvec",
         lambda: forge.batched_matvec(mvf, alg.ADD, A3, v2, backend="xla"),
         lambda: forge.matvec(mvf, alg.ADD, A3, v2, layout=Batched(),
                              backend="xla")),
        ("batched_vecmat",
         lambda: forge.batched_vecmat(vmf, alg.MIN, A3, p2, backend="xla"),
         lambda: forge.vecmat(vmf, alg.MIN, A3, p2, layout=Batched(),
                              backend="xla")),
        ("batched_semiring_matvec",
         lambda: forge.batched_semiring_matvec(alg.ARITHMETIC, A3, v2,
                                               backend="xla"),
         lambda: forge.semiring_matvec(alg.ARITHMETIC, A3, v2,
                                       layout=Batched(), backend="xla")),
        ("batched_semiring_vecmat",
         lambda: forge.batched_semiring_vecmat(alg.ARITHMETIC, A3, p2,
                                               backend="xla"),
         lambda: forge.semiring_vecmat(alg.ARITHMETIC, A3, p2,
                                       layout=Batched(), backend="xla")),
        ("batched_linear_recurrence",
         lambda: forge.batched_linear_recurrence(a3, b3, h0, reverse=True,
                                                 backend="xla"),
         lambda: forge.linear_recurrence(a3, b3, h0, reverse=True,
                                         layout=Batched(), backend="xla")),
        ("segmented_scan",
         lambda: forge.segmented_scan(alg.ADD, x1, offsets=OFFSETS,
                                      inclusive=False, backend="xla"),
         lambda: forge.scan(alg.ADD, x1, inclusive=False, layout=seg,
                            backend="xla")),
        ("segmented_mapreduce",
         lambda: forge.segmented_mapreduce(lambda v: v, alg.MAX, x1,
                                           flags=flags, num_segments=5,
                                           backend="xla"),
         lambda: forge.mapreduce(lambda v: v, alg.MAX, x1, backend="xla",
                                 layout=Segmented(flags=flags,
                                                  num_segments=5))),
        ("segmented_sort",
         lambda: forge.segmented_sort(keys, offsets=OFFSETS,
                                      descending=True, backend="xla"),
         lambda: forge.sort(keys, descending=True, layout=seg,
                            backend="xla")),
        ("segmented_sort_pairs",
         lambda: forge.segmented_sort_pairs(keys, vals, offsets=OFFSETS,
                                            backend="xla"),
         lambda: forge.sort_pairs(keys, vals, layout=seg, backend="xla")),
        ("segmented_argsort",
         lambda: forge.segmented_argsort(keys, offsets=OFFSETS,
                                         backend="xla"),
         lambda: forge.argsort(keys, layout=seg, backend="xla")),
        ("segmented_top_k",
         lambda: forge.segmented_top_k(keys, 9, offsets=OFFSETS,
                                       largest=False, backend="xla"),
         lambda: forge.top_k(keys, 9, largest=False, layout=seg,
                             backend="xla")),
    ]


_CASES = {name: (legacy, new) for name, legacy, new in _cases()}


@pytest.fixture
def fresh_warn_state():
    """Reset the warn-once bookkeeping so each test observes a first call."""
    saved = set(forge._WARNED)
    forge._WARNED.clear()
    yield
    forge._WARNED.clear()
    forge._WARNED.update(saved)


@pytest.mark.parametrize("name", sorted(_CASES))
def test_legacy_name_warns_once_and_matches_new_surface(name,
                                                        fresh_warn_state):
    legacy, new = _CASES[name]
    with warnings.catch_warnings(record=True) as first:
        warnings.simplefilter("always")
        got = legacy()
    deps = [w for w in first if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, f"{name}: expected exactly one DeprecationWarning"
    assert name in str(deps[0].message)
    assert "layout" in str(deps[0].message) or "Segmented" in str(
        deps[0].message) or "Batched" in str(deps[0].message)

    # Second call: silent (once per process, not once per call site).
    with warnings.catch_warnings(record=True) as second:
        warnings.simplefilter("always")
        got2 = legacy()
    assert not [w for w in second
                if issubclass(w.category, DeprecationWarning)], (
        f"{name}: legacy shim warned twice")

    # Kwargs forwarded faithfully: bit-identical to the new surface (and to
    # its own second call -- the shim is stateless beyond the warning).
    want = new()
    for g, g2, w in zip(jax.tree.leaves(got), jax.tree.leaves(got2),
                        jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(g2),
                                      err_msg=name)


def test_every_legacy_name_is_covered():
    """The shim list in core/primitives.py and the cases here must not
    drift: any public segmented_*/batched_* callable gets a case."""
    legacy = sorted(
        n for n in dir(forge)
        if (n.startswith("segmented_") or n.startswith("batched_"))
        and callable(getattr(forge, n)))
    assert legacy == sorted(_CASES), (
        f"uncovered legacy shims: {sorted(set(legacy) ^ set(_CASES))}")


def test_new_surface_does_not_warn():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        forge.scan(alg.ADD, jnp.arange(8, dtype=jnp.float32), backend="xla")
        forge.mapreduce(lambda t: t, alg.ADD, jnp.ones((2, 4)),
                        layout=Batched(), backend="xla")
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]


# ---------------------------------------------------------------------------
# force_backend(): the process-global pin, deprecated in favor of the
# scoped use_backend() context manager.
# ---------------------------------------------------------------------------


@pytest.fixture
def fresh_force_backend_state():
    """Reset the force_backend warn-once flag and any forced global."""
    saved_warned = ki._FORCE_BACKEND_WARNED
    saved_forced = ki._FORCED_BACKEND
    ki._FORCE_BACKEND_WARNED = False
    ki._FORCED_BACKEND = None
    yield
    ki._FORCE_BACKEND_WARNED = saved_warned
    ki._FORCED_BACKEND = saved_forced


def test_force_backend_warns_once_and_matches_use_backend(
        fresh_force_backend_state):
    x = make_operand("add", _nprng("fb"), (33,))

    with warnings.catch_warnings(record=True) as first:
        warnings.simplefilter("always")
        ki.force_backend("pallas-interpret")
    deps = [w for w in first if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, "expected exactly one DeprecationWarning"
    assert "use_backend" in str(deps[0].message)

    # While forced, dispatch resolves through the pin...
    assert ki.current_backend() == "pallas-interpret"
    got = forge.scan(alg.ADD, x)

    # ...and later calls (including clearing the pin) stay silent.
    with warnings.catch_warnings(record=True) as second:
        warnings.simplefilter("always")
        ki.force_backend(None)
    assert not [w for w in second
                if issubclass(w.category, DeprecationWarning)], (
        "force_backend warned twice")
    assert ki._FORCED_BACKEND is None

    # Bit-identical to the scoped replacement.
    with ki.use_backend("pallas-interpret"):
        want = forge.scan(alg.ADD, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_use_backend_scope_beats_forced_global(fresh_force_backend_state):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ki.force_backend("pallas-interpret")
    with ki.use_backend("xla"):
        assert ki.current_backend() == "xla"
    assert ki.current_backend() == "pallas-interpret"


# ---------------------------------------------------------------------------
# sub_backend=: the pre-backend-API spelling on the composition entry
# points (radix sorts, sharded folds), deprecated in favor of the uniform
# backend= parameter.
# ---------------------------------------------------------------------------


@pytest.fixture
def fresh_sub_backend_state():
    saved = ki._SUB_BACKEND_WARNED
    ki._SUB_BACKEND_WARNED = False
    yield
    ki._SUB_BACKEND_WARNED = saved


def test_sub_backend_alias_warns_once_and_matches(fresh_sub_backend_state):
    from repro.kernels import sort as sort_k

    keys = _keys(41)
    vals = jnp.arange(41, dtype=jnp.int32)

    with warnings.catch_warnings(record=True) as first:
        warnings.simplefilter("always")
        got = sort_k.sort_radix(keys, sub_backend="xla")
    deps = [w for w in first if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, "expected exactly one DeprecationWarning"
    assert "sub_backend" in str(deps[0].message)

    # Later aliased calls (any entry point) stay silent.
    with warnings.catch_warnings(record=True) as second:
        warnings.simplefilter("always")
        gk, gv = sort_k.sort_pairs_radix(keys, vals, sub_backend="xla")
    assert not [w for w in second
                if issubclass(w.category, DeprecationWarning)], (
        "sub_backend alias warned twice")

    # Faithful forwarding: bit-identical to the backend= spelling.
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(sort_k.sort_radix(keys, backend="xla")))
    wk, wv = sort_k.sort_pairs_radix(keys, vals, backend="xla")
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(wk))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))


def test_sub_backend_alias_rejects_both_spellings(fresh_sub_backend_state):
    from repro.kernels import sort as sort_k

    with pytest.raises(TypeError, match="both backend= and"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            sort_k.sort_radix(_keys(8), backend="xla", sub_backend="xla")
