"""Deterministic simulation suite for the continuous-batching scheduler.

The scheduler is pure host bookkeeping (serving/scheduler.py), so the whole
admission/eviction state machine runs here against a *fake model*: each
request carries a service time (its decode-step budget), a simulated decode
step advances every live slot by one, and completion fires exactly when the
budget is spent -- no device, no model, thousands of steps per second.

Invariants pinned by this suite (the engine inherits them wholesale):

* no double-occupancy: a slot holds at most one live request, a request at
  most one slot;
* FIFO admission: slot grants follow submission order exactly;
* eviction exactly on completion: ``finish_step - admit_step`` equals the
  request's service time, never more, never less;
* zero starvation: every submitted request finishes, even when bursts
  exceed ``batch_size`` many times over or the queue goes repeatedly empty.

Property tests run under hypothesis when installed and fall back to a
seeded sweep otherwise (same pattern as tests/test_properties.py).
"""
import dataclasses

import pytest

from repro.serving.scheduler import Scheduler, SchedulerInvariantError

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def seeded(test):
    """Drive ``test(seed)`` by hypothesis when available, else a fixed
    seed sweep -- one decorator, identical test bodies."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=30, deadline=None)(
            given(seed=st.integers(0, 2**32 - 1))(test))
    return pytest.mark.parametrize("seed", [37 * i + 5 for i in range(12)])(
        test)


@dataclasses.dataclass
class FakeRequest:
    max_new_tokens: int
    seed: int | None = None


def run_sim(arrivals, num_slots, max_steps=100_000):
    """Run an arrival trace against the fake model to completion.

    ``arrivals``: list of ``(arrival_step, service_steps)``.  Returns
    ``(sched, log)`` where ``log["admit_order"]`` is the slot-grant order
    and ``log["max_live"]`` the peak concurrency.  Invariants are audited
    after every admission wave and every simulated decode step.
    """
    sched = Scheduler(num_slots)
    remaining = {}
    pending = sorted(arrivals, key=lambda a: a[0])
    log = {"admit_order": [], "max_live": 0}
    now, i = 0, 0
    while i < len(pending) or not sched.all_done:
        while i < len(pending) and pending[i][0] <= now:
            rid = sched.submit(FakeRequest(pending[i][1]), step=now)
            remaining[rid] = pending[i][1]
            i += 1
        for rec in sched.admit(step=now):
            log["admit_order"].append(rec.rid)
            if remaining[rec.rid] <= 0:   # zero-budget: done at admission
                sched.complete(rec.slot, step=now)
        sched.check_invariants()
        log["max_live"] = max(log["max_live"], len(sched.live_slots))
        for slot in list(sched.live_slots):
            rec = sched.slot_record(slot)
            remaining[rec.rid] -= 1
            if remaining[rec.rid] <= 0:
                sched.complete(slot, step=now + 1)
        sched.check_invariants()
        now += 1
        assert now < max_steps, "simulation did not terminate (starvation?)"
    return sched, log


# ---------------------------------------------------------------------------
# Deterministic traces.
# ---------------------------------------------------------------------------


def test_fifo_admission_order():
    sched, log = run_sim([(0, 3)] * 10, num_slots=3)
    assert log["admit_order"] == sorted(log["admit_order"])
    assert log["admit_order"] == list(range(10))


def test_eviction_exactly_at_budget():
    sched, _ = run_sim([(0, 5), (0, 2), (1, 7)], num_slots=2)
    for rec in sched.records.values():
        assert rec.done
        assert rec.finish_step - rec.admit_step == \
            max(rec.request.max_new_tokens, 0)


def test_burst_larger_than_batch():
    """A burst 8x the slot count: peak concurrency is capped at the slot
    count, everyone still finishes, and grants stay FIFO."""
    n_slots = 2
    sched, log = run_sim([(0, 4)] * (8 * n_slots), num_slots=n_slots)
    assert log["max_live"] == n_slots
    assert all(rec.done for rec in sched.records.values())
    assert log["admit_order"] == sorted(log["admit_order"])


def test_empty_queue_and_gaps():
    """Arrival gaps empty the queue; admissions resume when traffic does."""
    sched, _ = run_sim([(0, 2), (50, 3), (100, 1)], num_slots=4)
    recs = sorted(sched.records.values(), key=lambda r: r.rid)
    assert [r.admit_step for r in recs] == [0, 50, 100]
    assert Scheduler(4).all_done
    assert Scheduler(4).admit() == []


def test_zero_budget_request_completes_without_decode():
    sched, _ = run_sim([(0, 0), (0, 3)], num_slots=1)
    rec0 = sched.records[0]
    assert rec0.done and rec0.finish_step == rec0.admit_step
    assert sched.records[1].done


def test_no_starvation_under_sustained_overload():
    """2 slots, arrivals every step for 100 steps: strictly FIFO means the
    wait is bounded by queue position, not unbounded."""
    sched, log = run_sim([(t, 2) for t in range(100)], num_slots=2)
    assert all(rec.done for rec in sched.records.values())
    assert log["admit_order"] == list(range(100))


def test_single_slot_serializes():
    sched, _ = run_sim([(0, 3), (0, 4), (0, 2)], num_slots=1)
    recs = sorted(sched.records.values(), key=lambda r: r.rid)
    # Strict serialization: each admission waits for the previous finish.
    for prev, nxt in zip(recs, recs[1:]):
        assert nxt.admit_step >= prev.finish_step


# ---------------------------------------------------------------------------
# Direct API / invariant-violation behavior.
# ---------------------------------------------------------------------------


def test_lifecycle_flags():
    sched = Scheduler(2)
    rid = sched.submit(FakeRequest(3))
    rec = sched.record(rid)
    assert rec.waiting and not rec.live and not rec.done
    sched.admit()
    assert rec.live and not rec.waiting and not rec.done
    sched.complete(rec.slot, step=3)
    assert rec.done and not rec.live and not rec.waiting


def test_seed_defaulting():
    sched = Scheduler(2)
    r0 = sched.submit(FakeRequest(1, seed=None))      # -> rid
    r1 = sched.submit(FakeRequest(1, seed=777))       # -> request's seed
    r2 = sched.submit(FakeRequest(1), seed=42)        # -> explicit override
    assert sched.record(r0).seed == r0
    assert sched.record(r1).seed == 777
    assert sched.record(r2).seed == 42


def test_complete_free_slot_raises():
    sched = Scheduler(2)
    with pytest.raises(SchedulerInvariantError):
        sched.complete(0)


def test_double_complete_raises():
    sched = Scheduler(1)
    sched.submit(FakeRequest(1))
    slot = sched.admit()[0].slot
    sched.complete(slot)
    with pytest.raises(SchedulerInvariantError):
        sched.complete(slot)


def test_corrupted_slot_table_detected():
    sched = Scheduler(2)
    sched.submit(FakeRequest(1))
    sched.admit()
    sched.slots[1] = sched.slots[0]   # forge a double-occupancy
    with pytest.raises(SchedulerInvariantError):
        sched.check_invariants()


def test_bad_slot_count_rejected():
    with pytest.raises(ValueError):
        Scheduler(0)


# ---------------------------------------------------------------------------
# Properties over random traces.
# ---------------------------------------------------------------------------


def _random_trace(seed):
    import random
    rng = random.Random(seed)
    n = rng.randint(1, 40)
    return ([(rng.randint(0, 60), rng.randint(0, 10)) for _ in range(n)],
            rng.randint(1, 5))


@seeded
def test_property_all_complete_fifo_capped(seed):
    """Random open-loop traces: every request completes, admissions are
    FIFO, concurrency never exceeds the slot count, budgets are exact."""
    arrivals, n_slots = _random_trace(seed)
    sched, log = run_sim(arrivals, num_slots=n_slots)
    assert len(sched.records) == len(arrivals)
    assert all(rec.done for rec in sched.records.values())
    assert log["admit_order"] == sorted(log["admit_order"])
    assert log["max_live"] <= n_slots
    for rec in sched.records.values():
        assert rec.finish_step - rec.admit_step == \
            max(rec.request.max_new_tokens, 0)
        assert rec.admit_step >= rec.submit_step


@seeded
def test_property_burst_waves(seed):
    """Bursts of (slots * k) simultaneous arrivals in waves -- the stress
    shape for slot recycling -- never break invariants or strand work."""
    import random
    rng = random.Random(seed)
    n_slots = rng.randint(1, 4)
    arrivals = []
    for wave in range(rng.randint(1, 4)):
        at = wave * rng.randint(1, 10)
        arrivals += [(at, rng.randint(1, 6))
                     for _ in range(n_slots * rng.randint(2, 5))]
    sched, log = run_sim(arrivals, num_slots=n_slots)
    assert all(rec.done for rec in sched.records.values())
    assert log["max_live"] <= n_slots
