"""Checkpoint tests: roundtrip, atomicity, async, elastic restore."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_trees_close
from repro.configs import base as C
from repro.training import checkpoint as CKPT
from repro.training import optimizer as OPT
from repro.training import train_step as TS


def _state(rng):
    cfg = C.get_config("gemma2-27b", smoke=True)
    tc = TS.TrainConfig(optimizer=OPT.OptimizerConfig())
    return TS.init_state(rng, cfg, tc)


def test_roundtrip(tmp_path, rng):
    state = _state(rng)
    CKPT.save(str(tmp_path), 7, state)
    shape = jax.eval_shape(lambda: state)
    restored = CKPT.restore(str(tmp_path), 7, shape)
    assert_trees_close(restored, state, rtol=0, atol=0)


def test_latest_and_cleanup(tmp_path, rng):
    state = _state(rng)
    for s in [1, 2, 3, 4]:
        CKPT.save(str(tmp_path), s, state)
    assert CKPT.latest_step(str(tmp_path)) == 4
    CKPT.cleanup(str(tmp_path), keep=2)
    assert sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)) == [3, 4]


def test_atomicity_tmp_never_visible(tmp_path, rng):
    state = _state(rng)
    CKPT.save(str(tmp_path), 1, state)
    # A leftover tmp dir (simulated crash) is ignored by latest_step.
    os.makedirs(tmp_path / "step_9.tmp")
    assert CKPT.latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path, rng):
    state = _state(rng)
    ck = CKPT.AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save(1, state)
    ck.save(2, state)   # waits for the first
    ck.wait()
    assert CKPT.latest_step(str(tmp_path)) == 2


def test_manifest_schema(tmp_path, rng):
    state = _state(rng)
    path = CKPT.save(str(tmp_path), 3, state)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["step"] == 3
    n_leaves = len(jax.tree.leaves(state))
    assert len(manifest["leaves"]) == n_leaves
    for meta in manifest["leaves"].values():
        assert os.path.exists(os.path.join(path, meta["file"]))


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.configs import base as C
from repro.distributed import sharding as SH
from repro.training import checkpoint as CKPT, optimizer as OPT, train_step as TS

cfg = C.get_config("gemma2-27b", smoke=True)
tc = TS.TrainConfig(optimizer=OPT.OptimizerConfig())
state = TS.init_state(jax.random.PRNGKey(0), cfg, tc)
ckpt_dir = sys.argv[2]

# Save from a (4 data x 2 model) mesh...
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
CKPT.save(ckpt_dir, 5, state)

# ...restore onto a (2 data x 4 model) mesh: elastic resharding on load.
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
shape = jax.eval_shape(lambda: state)
specs = TS.state_specs(shape, cfg, mesh_b)
shardings = SH.named(mesh_b, specs)
restored = CKPT.restore(ckpt_dir, 5, shape, shardings)
for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(a.sharding.device_set) >= 1
print("ELASTIC_OK")
"""


def test_elastic_restart_across_meshes(tmp_path):
    """Deliverable: checkpoint saved under one mesh restores onto another
    (different data/model split) with identical values -- elastic scaling."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "elastic.py"
    script.write_text(ELASTIC_SCRIPT)
    out = subprocess.run(
        [sys.executable, str(script), src, str(tmp_path / "ck")],
        capture_output=True, text=True, timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stdout + out.stderr
