"""Autotuned dispatch: benchmark-once semantics and on-disk cache round-trip."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core import tuning
from repro.core.layout import Batched, Segmented


@pytest.fixture
def tuner(tmp_path):
    t = tuning.enable(str(tmp_path / "tuning.json"))
    yield t
    tuning.disable()


def test_first_call_benchmarks_second_call_hits(tuner):
    x = jnp.arange(4096, dtype=jnp.float32)
    y = forge.scan(alg.ADD, x, backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(y), np.cumsum(np.arange(4096)),
                               rtol=1e-5)
    assert tuner.stats["benchmarks"] == 1
    assert tuner.stats["bench_calls"] == len(
        tuning.TUNABLE["scan@flat"].candidates)

    # Identical key (same primitive/op/dtype/shape-bucket): no re-benchmark.
    y2 = forge.scan(alg.ADD, x * 2, backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(y2),
                               np.cumsum(2.0 * np.arange(4096)), rtol=1e-5)
    assert tuner.stats["benchmarks"] == 1
    assert tuner.stats["hits"] >= 1


def test_cache_round_trips_across_tuner_instances(tuner, tmp_path):
    x = jnp.arange(4096, dtype=jnp.float32)
    forge.scan(alg.ADD, x, backend="pallas-interpret")
    path = tuner.cache_path
    entry = json.load(open(path))
    assert len(entry) == 1
    (key, val), = entry.items()
    assert key.startswith("scan@flat|op=add|dtype=float32|n=4096|")
    assert "overrides" in val

    # A fresh tuner reading the same file performs no re-benchmarking.
    fresh = tuning.enable(path)
    forge.scan(alg.ADD, x + 3, backend="pallas-interpret")
    assert fresh.stats["benchmarks"] == 0
    assert fresh.stats["hits"] == 1


def test_distinct_keys_tune_separately(tuner):
    x = jnp.arange(4096, dtype=jnp.float32)
    forge.scan(alg.ADD, x, backend="pallas-interpret")
    forge.scan(alg.MAX, x, backend="pallas-interpret")      # different op
    forge.scan(alg.ADD, x.astype(jnp.bfloat16),             # different dtype
               backend="pallas-interpret")
    assert tuner.stats["benchmarks"] == 3


def test_segmented_scan_is_tuned_and_correct(tuner):
    x = jnp.arange(3000, dtype=jnp.float32)
    offs = jnp.asarray([0, 100, 2500, 3000], jnp.int32)
    got = forge.scan(alg.ADD, x, layout=Segmented(offsets=offs),
                     backend="pallas-interpret")
    assert tuner.stats["benchmarks"] == 1
    want = np.concatenate([np.cumsum(np.asarray(x)[s:e])
                           for s, e in zip([0, 100, 2500], [100, 2500, 3000])])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4)


def test_explicit_policy_bypasses_tuner(tuner):
    from repro.core import intrinsics as ki
    x = jnp.arange(1024, dtype=jnp.float32)
    impl = ki.resolve_impl("scan@flat", "pallas-interpret")
    impl(alg.ADD, x, policy=ki.resolve_tuning("interpret"))
    assert tuner.stats["benchmarks"] == 0


def test_xla_backend_not_tuned(tuner):
    x = jnp.arange(1024, dtype=jnp.float32)
    forge.scan(alg.ADD, x, backend="xla")
    assert tuner.stats["benchmarks"] == 0


def test_shape_bucket_shares_entries(tuner):
    a = jnp.arange(3000, dtype=jnp.float32)   # bucket 4096
    b = jnp.arange(4000, dtype=jnp.float32)   # bucket 4096
    forge.scan(alg.ADD, a, backend="pallas-interpret")
    forge.scan(alg.ADD, b, backend="pallas-interpret")
    assert tuner.stats["benchmarks"] == 1


def test_corrupt_cache_re_tunes_instead_of_raising(tmp_path):
    """A truncated/corrupt JSON cache (interrupted concurrent writer) must
    never raise: the tuner starts empty, re-benchmarks, and the next save
    rewrites a valid file."""
    path = tmp_path / "tuning.json"
    path.write_text('{"scan@flat|op=add|dtype=float32|n=4096"')   # truncated
    t = tuning.enable(str(path))
    try:
        x = jnp.arange(4096, dtype=jnp.float32)
        y = forge.scan(alg.ADD, x, backend="pallas-interpret")
        np.testing.assert_allclose(np.asarray(y),
                                   np.cumsum(np.arange(4096)), rtol=1e-5)
        assert t.stats["benchmarks"] == 1        # re-tuned, no crash
        data = json.load(open(path))             # save rewrote valid JSON
        assert len(data) == 1
    finally:
        tuning.disable()


def test_concurrent_writers_merge_not_clobber(tmp_path):
    """Two tuners sharing one cache path (parallel test shards /
    self-hosted runners): the second save must merge with what's on disk,
    not overwrite it with its own stale view."""
    path = str(tmp_path / "tuning.json")
    a = tuning.Autotuner(path)
    b = tuning.Autotuner(path)                   # loads the same empty file
    a._cache["key_a"] = {"overrides": {"nitem_scan": 8}, "seconds": 1.0}
    a._save()
    b._cache["key_b"] = {"overrides": {"nitem_scan": 16}, "seconds": 2.0}
    b._save()                                    # must not drop key_a
    data = json.load(open(path))
    assert set(data) == {"key_a", "key_b"}
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]


def test_batched_keys_carry_batch_bucket(tuner):
    """Batched-family cache keys bucket the batch separately from the
    per-row extent, and one race covers the whole batch -- not one per row."""
    x = jnp.ones((4, 4096), jnp.float32)
    forge.scan(alg.ADD, x, layout=Batched(), backend="pallas-interpret")
    assert tuner.stats["benchmarks"] == 1          # one race for all 4 rows
    key = [k for k in tuner._cache if k.startswith("scan@batched|")]
    assert key and "|n=4096|batch=4|" in key[0]
    # Same rows, different batch bucket: tunes separately (small batches
    # and large batches want different block policies).
    forge.scan(alg.ADD, jnp.ones((32, 4096), jnp.float32),
               layout=Batched(), backend="pallas-interpret")
    assert tuner.stats["benchmarks"] == 2
    # Same batch bucket again: pure cache hit.
    forge.scan(alg.ADD, x * 3, layout=Batched(), backend="pallas-interpret")
    assert tuner.stats["benchmarks"] == 2
    assert tuner.stats["hits"] >= 1


def test_sharded_keys_carry_mesh_topology(tuner):
    """@sharded cache keys pin the mesh topology (axis name + extent, mesh
    shape) and every key's platform part carries the device count -- a
    1-device winner is never replayed on an N-device mesh."""
    import jax
    from repro.core.layout import Sharded
    mesh = jax.make_mesh((1,), ("shard",))
    x = jnp.arange(512, dtype=jnp.float32)
    got = forge.scan(alg.ADD, x, layout=Sharded("shard", mesh=mesh),
                     backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(got), np.cumsum(np.arange(512)),
                               rtol=1e-5)
    keys = [k for k in tuner._cache if k.startswith("scan@sharded|")]
    assert keys, list(tuner._cache)
    assert "|mesh=shard=1:1|" in keys[0], keys[0]
    assert "/d1" in keys[0], keys[0]   # device count in the platform part
    # The flat route's key carries the device count too (no mesh part).
    forge.scan(alg.ADD, x, backend="pallas-interpret")
    flat = [k for k in tuner._cache if k.startswith("scan@flat|")]
    assert flat and "/d1" in flat[0] and "|mesh=" not in flat[0]


def test_sort_ladder_races_digit_width(tuner):
    """The sort family is tuned over digit width x block policy and stays
    correct under every candidate."""
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.integers(0, 256, 256), jnp.uint8)
    got = forge.sort(k, backend="pallas-interpret")
    assert tuner.stats["benchmarks"] >= 1
    key = [c for c in tuner._cache if c.startswith("sort@flat|")]
    assert key and "overrides" in tuner._cache[key[0]]
    assert set(tuner._cache[key[0]]["overrides"]) <= {"sort_digit_bits",
                                                      "nitem_scan"}
    np.testing.assert_array_equal(np.asarray(got),
                                  np.sort(np.asarray(k), kind="stable"))
