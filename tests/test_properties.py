"""Property-based conformance suite for the operator algebra.

Every ``AssocOp`` the kernels dispatch on carries three load-bearing claims:

* ``combine`` is **associative** -- the entire scan/reduce substrate
  (tile scans, grid carries, the Blelloch segmented lift, the batched
  family) is only correct if it holds;
* ``identity(like)`` is an **exact** identity -- it is what masked tile
  tails and carry initialization inject, so ``op(identity, x) == x`` must
  hold bit-for-bit, not approximately;
* ``commutative`` is an honest declaration -- kernels take the balanced
  fold (and the lane-packed matvec) only when it is set, so a false claim
  silently reorders reductions.

This suite machine-checks all three on random pytree values for every
operator in ``alg.STD_OPS``, plus the segmented-lift laws the segmented
kernels build on.  It uses hypothesis when installed and falls back to a
seeded sample sweep otherwise, so the laws are exercised in every
environment.  It also pins the oracle bookkeeping: the conformance matrix in
``tests/test_conformance.py`` (which op is fuzzed against which primitive)
must stay complete as primitives are added.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_trees_close, make_operand
from repro.core import operators as alg
from test_conformance import CONFORMANCE_MATRIX, FIXED_OP_PRIMITIVES

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def seeded(test):
    """Drive ``test(op_name, seed)`` by hypothesis when available, else by a
    fixed seed sweep -- one decorator, identical test bodies."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=20, deadline=None)(
            given(seed=st.integers(0, 2**32 - 1))(test))
    return pytest.mark.parametrize("seed", [31 * i + 1 for i in range(10)])(
        test)


OP_NAMES = sorted(alg.STD_OPS)


def _triple(op_name, seed, shape=(4,)):
    nprng = np.random.default_rng(seed)
    return tuple(make_operand(op_name, nprng, shape) for _ in range(3))


# ---------------------------------------------------------------------------
# The three AssocOp laws.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op_name", OP_NAMES)
@seeded
def test_associativity(op_name, seed):
    op = alg.STD_OPS[op_name]
    x, y, z = _triple(op_name, seed)
    left = op(op(x, y), z)
    right = op(x, op(y, z))
    assert_trees_close(left, right, rtol=1e-5, atol=1e-5,
                       err=f"{op_name} associativity (seed {seed})")


@pytest.mark.parametrize("op_name", OP_NAMES)
@seeded
def test_identity_exact(op_name, seed):
    """op(identity, x) == x and op(x, identity) == x, bit-exactly: the
    identity is injected under tile masks, where approximation would leak
    padding into real elements."""
    op = alg.STD_OPS[op_name]
    x, _, _ = _triple(op_name, seed)
    ident = op.identity(x)
    for got in (op(ident, x), op(x, ident)):
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(x)):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w),
                err_msg=f"{op_name} identity not exact (seed {seed})")


@pytest.mark.parametrize("op_name", OP_NAMES)
@seeded
def test_commutativity_where_claimed(op_name, seed):
    op = alg.STD_OPS[op_name]
    if not op.commutative:
        pytest.skip("declared non-commutative; witness checked separately")
    x, y, _ = _triple(op_name, seed)
    assert_trees_close(op(x, y), op(y, x), rtol=1e-6, atol=1e-6,
                       err=f"{op_name} claims commutativity (seed {seed})")


@pytest.mark.parametrize("op_name",
                         [n for n in OP_NAMES
                          if not alg.STD_OPS[n].commutative])
def test_noncommutative_claim_has_witness(op_name):
    """A declared-non-commutative op must actually have a counterexample --
    otherwise the declaration needlessly forces the slow ordered paths."""
    op = alg.STD_OPS[op_name]
    for seed in range(8):
        x, y, _ = _triple(op_name, seed)
        lhs = jax.tree.leaves(op(x, y))
        rhs = jax.tree.leaves(op(y, x))
        if any(not np.allclose(np.asarray(a), np.asarray(b))
               for a, b in zip(lhs, rhs)):
            return
    pytest.fail(f"{op_name}: no non-commutativity witness in 8 samples")


# ---------------------------------------------------------------------------
# Segmented (Blelloch) lift laws: what the segmented kernels rely on.
# ---------------------------------------------------------------------------

_LIFT_BASES = ["add", "max", "affine", "quaternion_mul"]


def _lifted_triple(op_name, seed, shape=(4,)):
    nprng = np.random.default_rng(seed)
    return tuple(
        (jnp.asarray(nprng.integers(0, 2, shape), jnp.int32),
         make_operand(op_name, nprng, shape))
        for _ in range(3))


@pytest.mark.parametrize("op_name", _LIFT_BASES)
@seeded
def test_segmented_lift_associativity(op_name, seed):
    seg = alg.segmented(alg.STD_OPS[op_name])
    x, y, z = _lifted_triple(op_name, seed)
    assert_trees_close(seg(seg(x, y), z), seg(x, seg(y, z)),
                       rtol=1e-5, atol=1e-5,
                       err=f"segmented[{op_name}] associativity")


@pytest.mark.parametrize("op_name", _LIFT_BASES)
@seeded
def test_segmented_lift_reset_and_identity(op_name, seed):
    """Boundary reset: combining into a flagged element discards the left
    operand's value entirely.  Identity: the lifted identity is (0, ident)."""
    op = alg.STD_OPS[op_name]
    seg = alg.segmented(op)
    x, y, _ = _lifted_triple(op_name, seed)
    flagged = (jnp.ones_like(y[0]), y[1])
    f_out, v_out = seg(x, flagged)
    for g, w in zip(jax.tree.leaves(v_out), jax.tree.leaves(y[1])):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"segmented[{op_name}] reset")
    np.testing.assert_array_equal(np.asarray(f_out), 1)
    ident = seg.identity(x)
    got = seg(ident, x)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(x)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"segmented[{op_name}] identity")
    assert not seg.commutative, "the lift is positional, never commutative"


# ---------------------------------------------------------------------------
# Oracle bookkeeping: which ops cover which primitives.
# ---------------------------------------------------------------------------

_PYTREE_NONCOMMUTATIVE = {"affine", "maxplus_affine", "quaternion_mul",
                          "mat2_mul"}


def test_conformance_matrix_coverage():
    """Every batched primitive is fuzzed against >= 3 distinct operators,
    at least one a non-commutative pytree op (forcing the order-preserving
    kernel paths) -- except primitives whose operator is fixed by
    construction, which must use a non-commutative pytree op outright."""
    for prim, ops in CONFORMANCE_MATRIX.items():
        assert len(set(ops)) == len(ops), f"{prim}: duplicate ops"
        noncomm = set(ops) & _PYTREE_NONCOMMUTATIVE
        if prim in FIXED_OP_PRIMITIVES:
            assert noncomm, f"{prim}: fixed op must be non-commutative pytree"
            continue
        assert len(ops) >= 3, f"{prim}: needs >= 3 oracle operators"
        assert noncomm, f"{prim}: needs a non-commutative pytree operator"


def test_conformance_matrix_ops_exist():
    for prim, ops in CONFORMANCE_MATRIX.items():
        for name in ops:
            assert name in alg.STD_OPS, f"{prim} references unknown op {name}"
