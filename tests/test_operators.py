"""Property-based tests (hypothesis) for the operator algebra + primitives.

System invariants under test:
* every AssocOp is associative; identity is exact (op(id, x) == x);
* scan with a random *non-commutative* affine operator matches a sequential
  Python fold (the ground truth independent of any JAX machinery);
* commutative-op scans are permutation-consistent reductions;
* UnitFloat8 encode/decode roundtrip (the paper's custom 8-bit type).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import assert_trees_close
from repro.core import operators as alg
from repro.core import primitives as forge

SETTINGS = dict(max_examples=20, deadline=None)

floats = st.floats(-3.0, 3.0, allow_nan=False, width=32)


def _leaves(op_name, vals3):
    """Build three elements of the given op's element type from floats."""
    a, b, c = [jnp.asarray(v, jnp.float32) for v in vals3]
    if op_name in ("affine", "maxplus_affine"):
        return (a, b), (b, c), (c, a)
    if op_name in ("quaternion_mul", "mat2_mul"):
        return ((a, b, c, a), (b, c, a, b), (c, a, b, c))
    return a, b, c


@pytest.mark.parametrize("op_name", list(alg.STD_OPS))
@settings(**SETTINGS)
@given(vals=st.tuples(floats, floats, floats))
def test_associativity(op_name, vals):
    op = alg.STD_OPS[op_name]
    if op_name == "softmax_merge":
        pytest.skip("needs structured (m,l,o) elements; covered below")
    x, y, z = _leaves(op_name, vals)
    lhs = op(op(x, y), z)
    rhs = op(x, op(y, z))
    assert_trees_close(lhs, rhs, rtol=1e-4, atol=1e-4, err=op_name)


@pytest.mark.parametrize("op_name", list(alg.STD_OPS))
@settings(**SETTINGS)
@given(v=floats)
def test_identity_exact(op_name, v):
    op = alg.STD_OPS[op_name]
    if op_name == "softmax_merge":
        pytest.skip("covered below")
    x, _, _ = _leaves(op_name, (v, v / 2 + 0.1, -v))
    ident = op.identity(x)
    assert_trees_close(op(ident, x), x, rtol=1e-6, atol=1e-6, err=op_name)
    assert_trees_close(op(x, ident), x, rtol=1e-6, atol=1e-6, err=op_name)


@settings(**SETTINGS)
@given(m1=floats, m2=floats, l1=st.floats(0.1, 2.0), l2=st.floats(0.1, 2.0))
def test_softmax_merge_assoc_and_identity(m1, m2, l1, l2):
    mk = lambda m, l: (jnp.asarray(m, jnp.float32),
                       jnp.asarray(l, jnp.float32),
                       jnp.asarray(l * 0.5, jnp.float32))
    op = alg.SOFTMAX_MERGE
    x, y, z = mk(m1, l1), mk(m2, l2), mk((m1 + m2) / 2, l1 + l2)
    assert_trees_close(op(op(x, y), z), op(x, op(y, z)), rtol=1e-4, atol=1e-4)
    ident = op.identity(x)
    assert_trees_close(op(ident, x), x, rtol=1e-6, atol=1e-6)
    # Commutativity (it is declared commutative).
    assert_trees_close(op(x, y), op(y, x), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(data=st.lists(st.tuples(st.floats(0.25, 1.0, width=32), floats),
                     min_size=1, max_size=60))
def test_scan_affine_vs_python_fold(data):
    """Non-commutative affine scan == sequential Python ground truth."""
    a = jnp.asarray([d[0] for d in data], jnp.float32)
    b = jnp.asarray([d[1] for d in data], jnp.float32)
    got_a, got_b = forge.scan(alg.AFFINE, (a, b), backend="pallas-interpret")
    h, acc_a = 0.0, 1.0
    want_b, want_a = [], []
    for ai, bi in data:
        h = ai * h + bi
        acc_a *= ai
        want_b.append(h)
        want_a.append(acc_a)
    np.testing.assert_allclose(np.asarray(got_b), want_b, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got_a), want_a, rtol=1e-3, atol=1e-3)


@settings(**SETTINGS)
@given(xs=st.lists(floats, min_size=1, max_size=80))
def test_mapreduce_matches_numpy(xs):
    x = jnp.asarray(xs, jnp.float32)
    got = forge.mapreduce(lambda v: v, alg.ADD, x, backend="pallas-interpret")
    np.testing.assert_allclose(float(got), float(np.sum(xs)),
                               rtol=1e-4, atol=1e-3)
    got = forge.mapreduce(lambda v: v, alg.MAX, x, backend="pallas-interpret")
    assert float(got) == pytest.approx(max(xs), rel=1e-6)


@settings(**SETTINGS)
@given(u=st.lists(st.integers(0, 255), min_size=1, max_size=50))
def test_unitfloat8_roundtrip(u):
    arr = jnp.asarray(u, jnp.uint8)
    dec = alg.unitfloat8_decode(arr)
    assert float(jnp.max(jnp.abs(dec))) <= 1.0 + 1e-6
    re = alg.unitfloat8_encode(dec)
    np.testing.assert_array_equal(np.asarray(re), np.asarray(arr))


@settings(**SETTINGS)
@given(n=st.integers(1, 300))
def test_scan_length_property(n):
    """Scan output length == input length for every n (tile raggedness)."""
    x = jnp.arange(n, dtype=jnp.float32)
    out = forge.scan(alg.ADD, x, backend="pallas-interpret")
    assert out.shape == (n,)
    np.testing.assert_allclose(float(out[-1]), n * (n - 1) / 2, rtol=1e-5)
