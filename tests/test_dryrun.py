"""Mini multi-pod dry-run in a subprocess (512 fake devices) + results audit.

The full sweep lives in results/dryrun (produced by repro.launch.dryrun);
this test (a) exercises the dry-run code path end-to-end on the cheapest
cell, (b) audits whatever full-sweep results exist for completeness.
"""
import glob
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "seamless-m4t-medium", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=560, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(tmp_path /
                         "seamless-m4t-medium__decode_32k__single.json"))
    assert "error" not in rec
    assert rec["cost_analysis"]["flops"] > 0
    assert rec["collectives"]["total_bytes"] >= 0
    assert rec["memory_analysis"]["temp_bytes"] > 0


def test_sweep_results_complete():
    """Every (arch x shape x mesh) cell has a result: ok or documented skip."""
    cells = glob.glob(os.path.join(RESULTS, "*.json"))
    if len(cells) < 80:
        pytest.skip(f"full sweep not finished ({len(cells)}/80 cells)")
    errs, skips, oks = [], 0, 0
    for c in cells:
        r = json.load(open(c))
        if "error" in r:
            errs.append((os.path.basename(c), r["error"]))
        elif "skipped" in r:
            skips += 1
        else:
            oks += 1
            assert r["cost_analysis"]["flops"] > 0, c
    assert not errs, errs
    # 8 quadratic archs x long_500k x 2 meshes = 16 documented skips.
    assert skips == 16, f"expected 16 long_500k skips, got {skips}"
    assert oks == len(cells) - skips
