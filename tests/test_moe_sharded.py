"""shard_map MoE (zero-collective dispatch) vs the local reference path."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import base as C
from repro.distributed import sharding as SH
from repro.distributed.moe_sharded import moe_forward_sharded
from repro.models import moe as M, layers as L

cfg = C.get_config("moonshot-v1-16b-a3b", smoke=True)
# High capacity: per-shard capacity rounding must not drop tokens in the
# parity check (drop policy intentionally differs: global vs per-dp-shard).
cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=16.0,
                          n_experts=8, moe_top_k=2)
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
params = M.init_moe(key, cfg)
B, S = 4, 8
x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32) * 0.5

ref, ref_aux = M.moe_forward(params, cfg, x)   # no rules -> local path
with mesh:
    got, aux = jax.jit(lambda p, xx: moe_forward_sharded(p, cfg, xx, mesh))(
        params, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=2e-3, atol=2e-3)
# Aux load-balance loss is the whole-batch statistic (global expert counts
# and mean-probs folded across the data axes via mapreduce@sharded), so it
# tracks the unsharded reference closely.
np.testing.assert_allclose(float(aux["lb_loss"]), float(ref_aux["lb_loss"]),
                           rtol=1e-2)
print("MOE_SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_moe_matches_local(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "moe_sharded.py"
    script.write_text(SCRIPT)
    out = subprocess.run([sys.executable, str(script), src],
                         capture_output=True, text=True, timeout=560)
    assert "MOE_SHARDED_OK" in out.stdout, out.stdout + out.stderr[-3000:]
