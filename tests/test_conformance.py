"""Differential fuzz harness: primitives vs. the ref.py oracles.

A seeded random sweep over (primitive x operator x dtype x shape/batch
bucket x backend) comparing ``pallas-interpret`` (the real TPU kernel bodies
interpreted on CPU) and ``xla`` (the portable fallback) against the
independent Python-loop oracles in ``kernels/ref.py``.  Coverage is aimed at
the places grid-batched kernels actually break:

* batch = 0 and length-0 rows (zero-extent grid dimensions),
* per-row extents straddling the kernels' block boundary by exactly +-1
  (computed from the interpret TuningPolicy, not hard-coded),
* non-commutative pytree operators, which force the order-preserving paths.

``CONFORMANCE_MATRIX`` below is the declared oracle coverage per primitive;
``tests/test_properties.py`` machine-checks the operator *laws* the same
matrix relies on and asserts the matrix itself stays complete.  To add a new
primitive to the conformance suite: give it a Python-loop oracle in
``kernels/ref.py``, list >= 3 operators here (at least one non-commutative
pytree operator unless the primitive's algebra forbids it -- then say so in
``FIXED_OP_PRIMITIVES``), and add a sweep test over its shape grid.
"""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_trees_close, make_operand
from repro.core import intrinsics as ki
from repro.core import operators as alg
from repro.core import primitives as forge
from repro.core.layout import Batched
from repro.kernels import ref

BACKENDS = ["pallas-interpret", "pallas-gpu", "xla"]

# Declared oracle coverage, keyed by registry route (primitive@layout):
# operator names exercised per batched route.  Non-commutative pytree ops
# (mat2_mul / quaternion_mul / affine) force the order-preserving kernel
# paths; test_matrix_enumerates_batched_registry below asserts the matrix
# covers *exactly* the @batched routes of the PrimitiveDef registry, and
# tests/test_properties.py::test_conformance_matrix_coverage checks the
# per-route operator requirements.
CONFORMANCE_MATRIX = {
    "scan@batched": ["add", "max", "mat2_mul"],
    "mapreduce@batched": ["add", "logsumexp", "quaternion_mul"],
    "matvec@batched": ["add", "min", "mat2_mul"],
    "vecmat@batched": ["add", "min", "mat2_mul"],
    "linear_recurrence@batched": ["affine"],
}
# Routes whose operator is fixed by construction (linear_recurrence IS
# the AFFINE scan -- a non-commutative pytree operator -- so the >=3-ops
# requirement does not apply to it).
FIXED_OP_PRIMITIVES = {"linear_recurrence@batched"}


def test_matrix_enumerates_batched_registry():
    """The declared coverage is derived from the PrimitiveDef registry:
    every @batched route must be fuzzed here, and nothing else may claim
    coverage -- adding a batched route without an oracle sweep fails CI."""
    batched = {k for k in ki.route_keys() if k.endswith("@batched")}
    assert set(CONFORMANCE_MATRIX) == batched


def _seed(*parts):
    """Stable cross-process seed (Python's hash() is process-salted)."""
    return zlib.crc32("|".join(str(p) for p in parts).encode())


def _scan_block(dtype, nitem_field="nitem_scan"):
    """The interpret-policy block extent the kernels tile rows with."""
    pol = ki.resolve_tuning("interpret")
    sub = ki.min_tile(dtype)[0]
    return getattr(pol, nitem_field) * sub * ki.LANES


def _batch_shapes(block):
    """(B, n) grid: zero extents, tiny rows, block boundary +-1."""
    return [(0, 5), (3, 0), (1, 1), (3, 7),
            (2, block - 1), (1, block), (2, block + 1)]


# ---------------------------------------------------------------------------
# batched_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("op_name", CONFORMANCE_MATRIX["scan@batched"])
def test_batched_scan_conformance(op_name, backend):
    op = alg.STD_OPS[op_name]
    nprng = np.random.default_rng(_seed(op_name, backend))
    block = _scan_block(jnp.float32)
    shapes = _batch_shapes(block)
    if op_name == "mat2_mul":
        # Pytree ops are slow under interpret, so trade the three boundary
        # shapes for the single strongest one: (2, block + 1) crosses the
        # block boundary AND hands the per-row carry across blocks with a
        # non-commutative operator -- the order-sensitive case.  Long
        # non-commutative products re-associate, hence the looser tolerance.
        shapes = [s for s in shapes if s[1] < block - 1] + [(2, block + 1)]
    tol = 1e-2 if op_name == "mat2_mul" else 1e-3
    for B, n in shapes:
        xs = make_operand(op_name, nprng, (B, n))
        got = forge.scan(op, xs, layout=Batched(), backend=backend)
        want = ref.ref_batched_scan(op, xs)
        assert_trees_close(got, want, rtol=tol, atol=tol,
                           err=f"batched_scan {op_name} B={B} n={n}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("inclusive", [True, False])
@pytest.mark.parametrize("reverse", [True, False])
def test_batched_scan_modes(inclusive, reverse, backend):
    nprng = np.random.default_rng(7)
    x = make_operand("add", nprng, (3, 130))
    got = forge.scan(alg.ADD, x, inclusive=inclusive,
                     reverse=reverse, layout=Batched(), backend=backend)
    want = ref.ref_batched_scan(alg.ADD, x, inclusive=inclusive,
                                reverse=reverse)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.bfloat16])
def test_batched_scan_dtypes(dtype, backend):
    nprng = np.random.default_rng(11)
    if dtype == jnp.int32:
        x = make_operand("add", nprng, (2, 300), dtype)
        got = forge.scan(alg.ADD, x, layout=Batched(), backend=backend)
        want = ref.ref_batched_scan(alg.ADD, x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        return
    # bf16: positive operands keep the prefix sums well-conditioned (a
    # near-zero partial sum of +-100 terms has no meaningful relative error
    # at 8 mantissa bits); tolerance covers association-order rounding.
    x = jnp.asarray(nprng.uniform(0.1, 1.0, (2, 300)), dtype)
    got = forge.scan(alg.ADD, x, layout=Batched(), backend=backend)
    want = ref.ref_batched_scan(alg.ADD, x)
    assert_trees_close(jax.tree.map(lambda l: l.astype(jnp.float32), got),
                       jax.tree.map(lambda l: l.astype(jnp.float32), want),
                       rtol=5e-2, atol=1.0)


# ---------------------------------------------------------------------------
# batched_mapreduce
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("op_name", CONFORMANCE_MATRIX["mapreduce@batched"])
def test_batched_mapreduce_conformance(op_name, backend):
    op = alg.STD_OPS[op_name]
    nprng = np.random.default_rng(_seed("mr", op_name, backend))
    block = _scan_block(jnp.float32, "nitem_reduce")
    shapes = _batch_shapes(block)
    if op_name == "quaternion_mul":
        # As in test_batched_scan_conformance: one multi-block case keeps
        # the cross-block, order-preserving (scan-route) reduction covered
        # for a non-commutative pytree op without the full boundary sweep.
        shapes = [s for s in shapes if s[1] < block - 1] + [(2, block + 1)]
    tol = 1e-2 if op_name == "quaternion_mul" else 1e-3
    for B, n in shapes:
        xs = make_operand(op_name, nprng, (B, n))
        got = forge.mapreduce(lambda t: t, op, xs, layout=Batched(), backend=backend)
        want = ref.ref_batched_mapreduce(lambda t: t, op, xs)
        assert_trees_close(got, want, rtol=tol, atol=tol,
                           err=f"batched_mapreduce {op_name} B={B} n={n}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_mapreduce_mapped_dtype(backend):
    """f changes the element type (uint8 -> f32), per row."""
    nprng = np.random.default_rng(13)
    u = jnp.asarray(nprng.integers(0, 256, (3, 500)), jnp.uint8)
    got = forge.mapreduce(alg.unitfloat8_decode, alg.ADD, u,
                          layout=Batched(), backend=backend)
    want = ref.ref_batched_mapreduce(alg.unitfloat8_decode, alg.ADD, u)
    assert_trees_close(got, want, rtol=1e-3, atol=1e-2)


# ---------------------------------------------------------------------------
# batched_matvec / batched_vecmat
# ---------------------------------------------------------------------------

_MV_CASES = {
    # name -> (f_matvec, f_vecmat, op): f argument order is (x, a) for
    # matvec and (a, x) for vecmat, mirroring the flat primitives.
    "add": (lambda x, a: x * a, lambda a, x: a * x, alg.ADD),
    "min": (lambda x, a: x + a, lambda a, x: a + x, alg.MIN),
    # Non-commutative pytree: each (row, col) term becomes a shear matrix;
    # the reduction composes them in row/column order.
    "mat2_mul": (
        lambda x, a: (1.0 + 0 * a, x * a, 0 * a, 1.0 + 0 * a),
        lambda a, x: (1.0 + 0 * a, a * x, 0 * a, 1.0 + 0 * a),
        alg.MAT2_MUL),
}


def _mv_shapes():
    pol = ki.resolve_tuning("interpret")
    rn = pol.matvec_rows * ki.min_tile(jnp.float32)[0]
    return [(0, 4, 3), (2, 0, 3), (1, 1, 1), (3, rn - 1, 5), (2, rn, 2),
            (2, rn + 1, 7), (1, 40, 130)]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case", sorted(_MV_CASES))
def test_batched_matvec_conformance(case, backend):
    f, _, op = _MV_CASES[case]
    nprng = np.random.default_rng(_seed("mv", case, backend))
    for B, n, p in _mv_shapes():
        A = jnp.asarray(nprng.normal(size=(B, n, p)) * 0.2, jnp.float32)
        x = jnp.asarray(nprng.normal(size=(B, n)) * 0.2, jnp.float32)
        got = forge.matvec(f, op, A, x, layout=Batched(), backend=backend)
        want = ref.ref_batched_matvec(f, op, A, x)
        assert_trees_close(got, want, rtol=1e-3, atol=1e-3,
                           err=f"batched_matvec {case} {B}x{n}x{p}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case", sorted(_MV_CASES))
def test_batched_vecmat_conformance(case, backend):
    _, f, op = _MV_CASES[case]
    nprng = np.random.default_rng(_seed("vm", case, backend))
    for B, n, p in _mv_shapes():
        A = jnp.asarray(nprng.normal(size=(B, n, p)) * 0.2, jnp.float32)
        x = jnp.asarray(nprng.normal(size=(B, p)) * 0.2, jnp.float32)
        got = forge.vecmat(f, op, A, x, layout=Batched(), backend=backend)
        want = ref.ref_batched_vecmat(f, op, A, x)
        assert_trees_close(got, want, rtol=1e-3, atol=1e-3,
                           err=f"batched_vecmat {case} {B}x{n}x{p}")


# ---------------------------------------------------------------------------
# batched_linear_recurrence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_linear_recurrence_conformance(backend):
    nprng = np.random.default_rng(_seed("lr", backend))
    for B, T, C in [(1, 1, 1), (2, 5, 3), (2, 33, 130), (3, 64, 128),
                    (1, 100, 1)]:
        a = jnp.asarray(nprng.uniform(0.5, 1.0, (B, T, C)), jnp.float32)
        b = jnp.asarray(nprng.normal(size=(B, T, C)), jnp.float32)
        h0 = jnp.asarray(nprng.normal(size=(B, C)), jnp.float32)
        for h in (None, h0):
            got = forge.linear_recurrence(a, b, h, layout=Batched(), backend=backend)
            want = ref.ref_batched_linear_recurrence(a, b, h)
            assert_trees_close(got, want, rtol=1e-4, atol=1e-4,
                               err=f"batched_linrec {B}x{T}x{C} h0={h is not None}")
    a = jnp.asarray(nprng.uniform(0.5, 1.0, (2, 17, 5)), jnp.float32)
    b = jnp.asarray(nprng.normal(size=(2, 17, 5)), jnp.float32)
    got = forge.linear_recurrence(a, b, reverse=True, layout=Batched(),
                                  backend=backend)
    want = ref.ref_batched_linear_recurrence(a, b, reverse=True)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Quantized matrix operand: the same matvec/vecmat routes, a Quantized
# (values, scales) pytree in the matrix slot, dequantize-in-kernel.  Two
# oracles per case: a *tight* check against the dense reference on the
# decoded matrix (the kernel must reproduce its own codec exactly, up to
# f32 association order), and an *error-bounded* check against the dense
# f32 reference on the original matrix, using the analytic per-output
# bound from kernels/ref.py -- the codec's accuracy contract.
# ---------------------------------------------------------------------------

QUANT_MODES = ["int8", "fp8_e4m3", "fp8_e5m2"]
_Q_BLOCK = 32


def _q_shapes():
    """Flat (n, p): quantization-block boundary +-1 on the row axis.

    n = 0 is excluded: the flat matvec contract requires a non-empty
    reduction axis (the @batched routes own the zero-extent guard)."""
    b = _Q_BLOCK
    return [(1, 1), (b - 1, 5), (b, 2), (b + 1, 7), (40, 130)]


def _q_batched_shapes():
    b = _Q_BLOCK
    return [(0, 5, 4), (2, 0, 4), (1, 1, 1), (2, b - 1, 5), (1, b, 2),
            (2, b + 1, 7), (1, 40, 130)]


def _assert_within_bound(got, dense, bound, err):
    gap = np.abs(np.asarray(got) - np.asarray(dense))
    limit = np.asarray(bound) + 1e-5
    assert np.all(gap <= limit), (
        f"{err}: quantization error {gap.max():.3e} exceeds analytic "
        f"bound {limit.max():.3e}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", QUANT_MODES)
def test_quantized_matvec_conformance(mode, backend):
    nprng = np.random.default_rng(_seed("qmv", mode, backend))
    for n, p in _q_shapes():
        A = jnp.asarray(nprng.normal(size=(n, p)) * 0.2, jnp.float32)
        x = jnp.asarray(nprng.normal(size=(n,)) * 0.2, jnp.float32)
        q = alg.quantize(A, mode=mode, block=_Q_BLOCK)
        got = forge.matvec(lambda xv, av: xv * av, alg.ADD, q, x,
                           backend=backend)
        want = ref.ref_matvec(lambda xv, av: xv * av, alg.ADD,
                              q.dequantize(), x)
        assert_trees_close(got, want, rtol=1e-3, atol=1e-3,
                           err=f"quantized matvec {mode} {n}x{p}")
        dense = ref.ref_matvec(lambda xv, av: xv * av, alg.ADD, A, x)
        _assert_within_bound(got, dense, ref.ref_quantized_matvec_bound(q, x),
                             f"quantized matvec {mode} {n}x{p}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", QUANT_MODES)
def test_quantized_vecmat_conformance(mode, backend):
    nprng = np.random.default_rng(_seed("qvm", mode, backend))
    for n, p in _q_shapes():
        A = jnp.asarray(nprng.normal(size=(n, p)) * 0.2, jnp.float32)
        x = jnp.asarray(nprng.normal(size=(p,)) * 0.2, jnp.float32)
        q = alg.quantize(A, mode=mode, block=_Q_BLOCK)
        got = forge.vecmat(lambda av, xv: av * xv, alg.ADD, q, x,
                           backend=backend)
        want = ref.ref_vecmat(lambda av, xv: av * xv, alg.ADD,
                              q.dequantize(), x)
        assert_trees_close(got, want, rtol=1e-3, atol=1e-3,
                           err=f"quantized vecmat {mode} {n}x{p}")
        dense = ref.ref_vecmat(lambda av, xv: av * xv, alg.ADD, A, x)
        _assert_within_bound(got, dense, ref.ref_quantized_vecmat_bound(q, x),
                             f"quantized vecmat {mode} {n}x{p}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", ["int8", "fp8_e4m3"])
def test_quantized_batched_matvec_conformance(mode, backend):
    nprng = np.random.default_rng(_seed("qbmv", mode, backend))
    for B, n, p in _q_batched_shapes():
        A = jnp.asarray(nprng.normal(size=(B, n, p)) * 0.2, jnp.float32)
        x = jnp.asarray(nprng.normal(size=(B, n)) * 0.2, jnp.float32)
        q = alg.quantize(A, mode=mode, block=_Q_BLOCK)
        got = forge.matvec(lambda xv, av: xv * av, alg.ADD, q, x,
                           layout=Batched(), backend=backend)
        want = ref.ref_batched_matvec(lambda xv, av: xv * av, alg.ADD,
                                      q.dequantize(), x)
        assert_trees_close(got, want, rtol=1e-3, atol=1e-3,
                           err=f"quantized batched_matvec {mode} {B}x{n}x{p}")
        dense = ref.ref_batched_matvec(lambda xv, av: xv * av, alg.ADD, A, x)
        _assert_within_bound(got, dense, ref.ref_quantized_matvec_bound(q, x),
                             f"quantized batched_matvec {mode} {B}x{n}x{p}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", ["int8", "fp8_e4m3"])
def test_quantized_batched_vecmat_conformance(mode, backend):
    nprng = np.random.default_rng(_seed("qbvm", mode, backend))
    for B, n, p in _q_batched_shapes():
        A = jnp.asarray(nprng.normal(size=(B, n, p)) * 0.2, jnp.float32)
        x = jnp.asarray(nprng.normal(size=(B, p)) * 0.2, jnp.float32)
        q = alg.quantize(A, mode=mode, block=_Q_BLOCK)
        got = forge.vecmat(lambda av, xv: av * xv, alg.ADD, q, x,
                           layout=Batched(), backend=backend)
        want = ref.ref_batched_vecmat(lambda av, xv: av * xv, alg.ADD,
                                      q.dequantize(), x)
        assert_trees_close(got, want, rtol=1e-3, atol=1e-3,
                           err=f"quantized batched_vecmat {mode} {B}x{n}x{p}")
        dense = ref.ref_batched_vecmat(lambda av, xv: av * xv, alg.ADD, A, x)
        _assert_within_bound(got, dense, ref.ref_quantized_vecmat_bound(q, x),
                             f"quantized batched_vecmat {mode} {B}x{n}x{p}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_quantized_matvec_arbitrary_operator(backend):
    """The quantized operand composes with non-arithmetic algebra: tropical
    max-plus matvec on a decoded int8 matrix (tight oracle only -- the
    additive error-bound model applies to sum-of-products reductions)."""
    nprng = np.random.default_rng(_seed("qtrop", backend))
    A = jnp.asarray(nprng.normal(size=(40, 13)), jnp.float32)
    x = jnp.asarray(nprng.normal(size=(40,)), jnp.float32)
    q = alg.quantize(A, mode="int8", block=_Q_BLOCK)
    got = forge.matvec(lambda xv, av: xv + av, alg.MAX, q, x,
                       backend=backend)
    want = ref.ref_matvec(lambda xv, av: xv + av, alg.MAX, q.dequantize(), x)
    assert_trees_close(got, want, rtol=1e-4, atol=1e-4,
                       err="quantized tropical matvec")


# ---------------------------------------------------------------------------
# Cross-backend agreement: interpret and xla must agree with each other,
# not merely each be close to the oracle.
# ---------------------------------------------------------------------------


def test_backends_agree_with_each_other():
    nprng = np.random.default_rng(29)
    x = make_operand("add", nprng, (3, 515))
    got_i = forge.scan(alg.ADD, x, layout=Batched(), backend="pallas-interpret")
    got_x = forge.scan(alg.ADD, x, layout=Batched(), backend="xla")
    assert_trees_close(got_i, got_x, rtol=1e-5, atol=1e-4)
    m = make_operand("mat2_mul", nprng, (2, 140))
    got_i = forge.mapreduce(lambda t: t, alg.MAT2_MUL, m, layout=Batched(),
                            backend="pallas-interpret")
    got_x = forge.mapreduce(lambda t: t, alg.MAT2_MUL, m, layout=Batched(),
                            backend="xla")
    assert_trees_close(got_i, got_x, rtol=1e-4, atol=1e-4)
